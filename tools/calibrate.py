"""Calibration harness: prints the paper-claim scoreboard for the sim.

Usage:  PYTHONPATH=src python tools/calibrate.py [--quick]

Targets (paper):
  Fig 2 classes: 6 CS-BS-PS, 8 CS-BS, 6 BS-PS, 3 CS, 3 BS, 3 I
  Fig 9 geomeans over w1..w14 (weighted speedup over baseline):
    equal off ~1.10, only bw ~1.04, only pref ~1.09, only cache ~1.28,
    bw+pref ~1.10, bw+cache ~1.37, cache+pref ~1.39, CPpf ~1.39, CBP ~1.50
  CBP vs best-two ~ +11%; CBP up to +86%
  Fig 10: CBP ANTT ~0.73 vs baseline
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.sim import (
    APP_NAMES, MANAGER_NAMES, PROFILES, WORKLOADS,
    antt, baseline_ipc, run_all_managers, weighted_speedup,
)
from repro.sim.characterization import classify_all, sensitivity_table


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--ms", type=float, default=100.0)
    args = ap.parse_args()

    print("=== Fig 2: per-app sensitivity classification ===")
    classes = classify_all()
    counts: dict = {}
    for name, cls in classes.items():
        counts[cls] = counts.get(cls, 0) + 1
    tab = sensitivity_table()
    for name in APP_NAMES:
        r = tab[name]
        print(f"{name:12s} {classes[name]:9s} "
              f"C-L {r['C-L']:+6.1%}  C-H {r['C-H']:+6.1%}  "
              f"B-L {r['B-L']:+6.1%}  B-H {r['B-H']:+6.1%}  "
              f"P-B {r['P-B']:+6.1%}")
    print("counts:", dict(sorted(counts.items())))
    print("target: {'BS': 3, 'BS-PS': 6, 'CS': 3, 'CS-BS': 8, "
          "'CS-BS-PS': 6, 'I': 3}")

    if args.quick:
        return

    print("\n=== Fig 9/10: managers over w1..w14 ===")
    ws: dict = {m: [] for m in MANAGER_NAMES}
    antts: dict = {m: [] for m in MANAGER_NAMES}
    t0 = time.time()
    for wname, apps in WORKLOADS.items():
        base = baseline_ipc(apps)
        results = run_all_managers(apps, total_ms=args.ms)
        row = []
        for m in MANAGER_NAMES:
            s = weighted_speedup(results[m].ipc, base)
            ws[m].append(s)
            antts[m].append(antt(results[m].ipc, base))
            row.append(f"{m}={s:.3f}")
        print(f"{wname}: " + " ".join(row))
    print(f"[{time.time()-t0:.1f}s]")

    print("\n=== geomeans ===")
    target = {
        "baseline": 1.00, "equal off": 1.10, "only cache": 1.28,
        "only bw": 1.04, "only pref": 1.09, "bw+pref": 1.10,
        "bw+cache": 1.37, "cache+pref": 1.39, "CPpf": 1.39, "CBP": 1.50,
    }
    for m in MANAGER_NAMES:
        g = float(np.exp(np.mean(np.log(ws[m]))))
        ga = float(np.exp(np.mean(np.log(antts[m]))))
        print(f"{m:11s} ws={g:.3f} (target {target.get(m, float('nan')):.2f})"
              f"  antt={ga:.3f}")
    cbp = np.array(ws["CBP"])
    best2 = np.maximum.reduce([np.array(ws["cache+pref"]),
                               np.array(ws["bw+cache"]),
                               np.array(ws["CPpf"]),
                               np.array(ws["bw+pref"])])
    print(f"CBP vs best-two per workload: geomean "
          f"{float(np.exp(np.mean(np.log(cbp / best2)))) - 1.0:+.3%}, "
          f"max CBP {cbp.max():.3f}")


if __name__ == "__main__":
    sys.exit(main())
