"""Streaming sweep service CLI — the operator's entry point.

Runs (or resumes) a fault-tolerant chunked sweep over a scenario stream
(:mod:`repro.sim.stream_sweep`) and prints the final :class:`StreamReport`
as JSON.  Typical uses:

  # a million-mix overnight run with checkpoints every 32 chunks
  PYTHONPATH=src python tools/stream_sweep.py --mixes 1000000 \\
      --chunk-size 2048 --managers baseline,CBP --popularity zipf \\
      --checkpoint-dir results/stream_ck --checkpoint-every 32

  # the run died (OOM, preemption, SIGKILL): resume from the last
  # complete checkpoint; the final aggregates are bit-identical to an
  # uninterrupted run of the same command
  PYTHONPATH=src python tools/stream_sweep.py ... --resume

  # rehearse the failure paths against a fault plan (JSON list of
  # {"kind","chunk","count","seconds"} dicts, see repro.runtime.faultinject)
  PYTHONPATH=src python tools/stream_sweep.py --mixes 1024 \\
      --fault-plan faults.json

Exit status is non-zero when coverage is below ``--min-coverage``
(default 1.0): a degraded run is visible to the calling automation, never
a silent truncation.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--mixes", type=int, default=100_000)
    ap.add_argument("--chunk-size", type=int, default=1024)
    ap.add_argument("--managers", default=None,
                    help="comma-separated Table-3 manager names "
                         "(default: all)")
    ap.add_argument("--total-ms", type=float, default=50.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--apps-per-mix", type=int, default=16)
    # scenario knobs
    ap.add_argument("--popularity", choices=("uniform", "zipf"),
                    default="uniform")
    ap.add_argument("--zipf-exponent", type=float, default=1.2)
    ap.add_argument("--catalog-size", type=int, default=4096)
    ap.add_argument("--diurnal-period-chunks", type=int, default=0)
    ap.add_argument("--diurnal-amplitude", type=float, default=0.5)
    ap.add_argument("--phase-app-fraction", type=float, default=0.0)
    ap.add_argument("--phase-period-chunks", type=int, default=8)
    # robustness knobs
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=8)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--max-retries", type=int, default=3)
    ap.add_argument("--on-divergence", choices=("quarantine", "raise"),
                    default="quarantine")
    ap.add_argument("--max-consecutive-quarantines", type=int, default=8)
    ap.add_argument("--no-overlap", action="store_true",
                    help="serial chunk dispatch (debugging / benchmarking)")
    ap.add_argument("--fault-plan", default=None,
                    help="JSON file of fault dicts (testing/rehearsal)")
    ap.add_argument("--min-coverage", type=float, default=1.0,
                    help="exit non-zero below this coverage fraction")
    ap.add_argument("--out", default=None, help="write report JSON here")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    from repro.runtime.faultinject import FaultPlan
    from repro.sim.stream_sweep import RetryPolicy, StreamConfig, run_stream
    from repro.sim.workloads import StreamScenario

    scenario = StreamScenario(
        apps_per_mix=args.apps_per_mix,
        popularity=args.popularity,
        zipf_exponent=args.zipf_exponent,
        catalog_size=args.catalog_size,
        diurnal_period_chunks=args.diurnal_period_chunks,
        diurnal_amplitude=args.diurnal_amplitude,
        phase_app_fraction=args.phase_app_fraction,
        phase_period_chunks=args.phase_period_chunks,
    )
    cfg = StreamConfig(
        n_mixes=args.mixes,
        chunk_size=args.chunk_size,
        managers=(tuple(m.strip() for m in args.managers.split(","))
                  if args.managers else None),
        total_ms=args.total_ms,
        seed=args.seed,
        scenario=scenario,
        retry=RetryPolicy(max_retries=args.max_retries),
        on_divergence=args.on_divergence,
        max_consecutive_quarantines=args.max_consecutive_quarantines,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
    )
    plan = None
    if args.fault_plan:
        plan = FaultPlan.from_dicts(
            json.loads(pathlib.Path(args.fault_plan).read_text()))
    report = run_stream(cfg, fault_plan=plan, resume=args.resume,
                        overlap=not args.no_overlap)
    payload = report.to_dict()
    payload["config"] = {
        **{k: v for k, v in dataclasses.asdict(cfg).items()
           if k not in ("scenario", "params", "retry")},
        "scenario": dataclasses.asdict(scenario),
        "fingerprint": cfg.fingerprint(),
    }
    text = json.dumps(payload, indent=1, default=float)
    if args.out:
        pathlib.Path(args.out).write_text(text)
    print(text)
    if report.coverage < args.min_coverage:
        print(f"ERROR: coverage {report.coverage:.4f} < required "
              f"{args.min_coverage} "
              f"(quarantined chunks: {[c for c, _ in report.quarantined]})",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
