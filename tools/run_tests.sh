#!/usr/bin/env bash
# Tier-1 verify wrapper — the single entry point used by CI
# (.github/workflows/ci.yml) and by ROADMAP.md.  Extra args are forwarded
# to pytest (e.g. ./tools/run_tests.sh tests/test_sim_sweep.py -k parity).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
