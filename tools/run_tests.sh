#!/usr/bin/env bash
# Tier-1 verify wrapper — the single entry point used by CI
# (.github/workflows/ci.yml) and by ROADMAP.md.  Extra args are forwarded
# to pytest (e.g. ./tools/run_tests.sh tests/test_sim_sweep.py -k parity).
#
# --smoke additionally runs the fused-timeline sweep smoke
# (benchmarks/sweep_smoke.py): asserts zero per-mix host allocator calls
# and records sweep wall-time JSON under results/bench/ — plus the Fig. 5
# static-search smoke (benchmarks/fig5_smoke.py): device-dispatch budget,
# batched-vs-numpy parity spot checks and the min-of-2 warm wall record —
# plus the serving-engine smoke (benchmarks/serving_bench.py --smoke):
# one-dispatch-per-reconfig-interval budget and the jit-vs-host-loop
# tokens/sec record, warm wall gated against the committed JSON — plus
# the streaming-service smoke (benchmarks/stream_bench.py --smoke):
# resume-parity gate (injected dispatch failure retried, NaN-poisoned
# chunk quarantined, mid-run kill + resume -> bit-identical aggregates)
# and the 3-dispatches-per-chunk budget — plus the runtime-bindings
# smoke (benchmarks/runtime_bench.py --smoke): fused TrainingPlant
# one-dispatch budget + bit-parity vs the host coordinator and the
# batched block-planner one-dispatch parity, warm wall gated against
# the committed record.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

SMOKE=0
PYTEST_ARGS=()
for arg in "$@"; do
  if [ "$arg" = "--smoke" ]; then
    SMOKE=1
  else
    PYTEST_ARGS+=("$arg")
  fi
done

python -m pytest -x -q ${PYTEST_ARGS[@]+"${PYTEST_ARGS[@]}"}

if [ "$SMOKE" = "1" ]; then
  timeout 120 python -m benchmarks.sweep_smoke
  timeout 180 python -m benchmarks.fig5_smoke
  timeout 180 python -m benchmarks.serving_bench --smoke
  timeout 300 python -m benchmarks.stream_bench --smoke
  timeout 180 python -m benchmarks.runtime_bench --smoke
fi
