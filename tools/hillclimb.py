"""§Perf hillclimb harness: lower a cell variant, report the three roofline
terms.  Each variant encodes one hypothesis from EXPERIMENTS.md §Perf.

  PYTHONPATH=src python tools/hillclimb.py --cell moe_train --variant v1
  PYTHONPATH=src python tools/hillclimb.py --all

``--fig5-seed`` instead refines the Fig. 5 static-allocation winners on a
finer lattice, seeded from the batched device search's top-k
(``repro.sim.static_search.search_static(k=...)``):

  PYTHONPATH=src python tools/hillclimb.py --fig5-seed

With ``--multi-objective`` the search folds the (weighted speedup,
min-fairness) Pareto front instead and the climb seeds from the front's
KNEE point first (``StaticSearchResult.knee_index`` — the balanced
trade-off member), then the remaining front members:

  PYTHONPATH=src python tools/hillclimb.py --fig5-seed --multi-objective
"""
import argparse
import dataclasses
import json
import os
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

OUT = pathlib.Path(__file__).resolve().parent.parent / "results" / "perf"

# cell -> (arch, shape, optimizer, baseline_microbatches)
CELLS = {
    "moe_train": ("qwen3-moe-30b-a3b", "train_4k", "adafactor", 2),
    "grok_train": ("grok-1-314b", "train_4k", "adafactor", 8),
    "dense_decode": ("qwen3-8b", "decode_32k", "adamw", 1),
}

# variant -> (config overrides, microbatch override, note)
VARIANTS = {
    "moe_train": {
        "baseline": ({}, None, "paper-faithful baseline (remat=full, cf=1.25, mb=2)"),
        "v1_remat_dots": ({"remat": "dots"}, None,
                          "H: full remat re-reads each layer in bwd; saving dot outputs cuts HBM term ~25% at higher peak mem"),
        "v2_cf_1.0": ({"capacity_factor": 1.0}, None,
                      "H: capacity 1.25->1.0 trims expert compute+buffer traffic ~20% (drops overflow tokens)"),
        "v3_mb_1": ({}, 1, "H: single microbatch halves per-step expert-weight re-reads"),
        "v4_chunk_2048": ({"attn_chunk": 2048, "capacity_factor": 1.0},
                          None,
                          "H: halving the q-chunk count halves per-layer K/V re-reads in the chunked attention (+ keep the confirmed cf=1.0 trim)"),
    },
    "grok_train": {
        "baseline": ({}, None, "paper-faithful baseline (mb=8, FSDP experts)"),
        "v1_mb_2": ({}, 2, "H: FSDP weight all-gathers repeat per microbatch; mb 8->2 divides the AG term ~4x"),
        "v2_mb_2_dots": ({"remat": "dots"}, 2,
                         "H: remat recompute re-gathers weights; dots policy avoids the remat re-AG"),
        "v3_mb_1": ({}, 1, "H: mb=1 halves AG again if activations fit"),
        "v4_gather_weights": ({"moe_gather_weights": True}, 2,
                              "H: the residual collectives are partial-sum ARs from the FSDP d-contraction; gathering weights first costs one 613MB AG/layer instead"),
        "v5_cf_1.0": ({"capacity_factor": 1.0}, 2,
                      "H: the 720GiB AR is the row-parallel expert DOWN output, sized e*cap = cf*topk*tokens; cf 1.25->1.0 trims it (and the dispatch buffers) 20%"),
    },
    "dense_decode": {
        "baseline": ({"decode_cache_update": "dus", "decode_gqa": "repeat"}, None, "paper-faithful baseline (DUS cache write)"),
        "v1_onehot": ({"decode_cache_update": "onehot"}, None,
                      "H: dynamic-slice write into the seq-sharded cache makes GSPMD all-gather it; one-hot masked update stays sharded -> collective term collapses"),
        "v2_onehot_chunk": ({"decode_cache_update": "onehot",
                             "attn_chunk": 2048}, None,
                            "H: after C1 the memory term (cache read) dominates and is irreducible per token; chunk size should be neutral"),
        "v3_seq_sharded_q": ({"decode_cache_update": "onehot"}, None,
                             "H: the 72 GiB of AGs are GSPMD replicating the repeat_kv broadcast (q heads-sharded vs cache seq-sharded); replicating the tiny q keeps attention seq-local -> collective term collapses"),
        "v4_grouped_gqa": ({"decode_cache_update": "onehot",
                            "decode_gqa": "grouped"}, None,
                           "H: repeat_kv materializes 4x the cache per layer; the grouped einsum reads KV once -> memory term ~-60%"),
        "v5_int8_kv": ({"decode_cache_update": "onehot",
                        "decode_gqa": "grouped",
                        "kv_cache_dtype": "int8"}, None,
                       "H: int8 KV cache halves the dominant cache-read traffic -> memory term ~-40% (accuracy traded; serving-standard)"),
    },
}

_STACK = None


def _model_stack() -> dict:
    """Lazy-load the model/launch stack for the roofline cells.

    The 512-forced-device ``XLA_FLAGS`` (needed to build production
    meshes on a laptop) is only set here, immediately before JAX
    initializes — the ``--fig5-seed`` mode runs on the real device set
    and must not inherit it.
    """
    global _STACK
    if _STACK is not None:
        return _STACK
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

    from repro import configs
    from repro.distributed import set_dp_axes, use_mesh
    from repro.launch import shardings as sh
    from repro.launch.dryrun import (
        HBM_BW, LINK_BW, PEAK_FLOPS, build_cell, model_flops,
    )
    from repro.launch.hlo_parse import analyze
    from repro.launch.mesh import dp_size, make_production_mesh, model_size
    from repro.models import SHAPES, build

    _STACK = dict(
        configs=configs, set_dp_axes=set_dp_axes, use_mesh=use_mesh,
        sh=sh, HBM_BW=HBM_BW, LINK_BW=LINK_BW, PEAK_FLOPS=PEAK_FLOPS,
        build_cell=build_cell, model_flops=model_flops, analyze=analyze,
        dp_size=dp_size, make_production_mesh=make_production_mesh,
        model_size=model_size, SHAPES=SHAPES, build=build,
    )
    return _STACK


def run_variant(cell: str, variant: str, force: bool = False) -> dict:
    OUT.mkdir(parents=True, exist_ok=True)
    path = OUT / f"{cell}__{variant}.json"
    if path.exists() and not force:
        return json.loads(path.read_text())
    m = _model_stack()
    arch, shape, optimizer, base_mb = CELLS[cell]
    overrides, mb, note = VARIANTS[cell][variant]
    mesh = m["make_production_mesh"]()
    cfg = m["configs"].get(arch).with_mesh(
        m["model_size"](mesh), m["dp_size"](mesh))
    cfg = dataclasses.replace(cfg, **overrides)
    model = m["build"](cfg)
    spec = m["SHAPES"][shape]
    rec = {"cell": cell, "variant": variant, "note": note,
           "overrides": overrides, "microbatches": mb or base_mb}
    t0 = time.time()
    try:
        m["set_dp_axes"](m["sh"].dp_axes_for(cfg))
        with m["use_mesh"](mesh):
            fn, args = m["build_cell"](model, shape, mesh, optimizer,
                                       mb or base_mb)
            compiled = fn.lower(*args).compile()
            mem = compiled.memory_analysis()
            cost = m["analyze"](compiled.as_text())
        terms = {
            "compute_s": cost.flops / m["PEAK_FLOPS"],
            "memory_s": cost.hbm_bytes / m["HBM_BW"],
            "collective_s": cost.total_collective_bytes / m["LINK_BW"],
        }
        rec.update({
            "status": "ok",
            "compile_s": round(time.time() - t0, 1),
            **{k: round(v, 4) for k, v in terms.items()},
            "dominant": max(terms, key=terms.get),
            "bound_s": round(max(terms.values()), 4),
            "roofline_fraction": round(
                terms["compute_s"] / max(max(terms.values()), 1e-12), 4),
            "useful_ratio": round(
                m["model_flops"](cfg, spec, mesh.size)
                / max(cost.flops, 1.0), 4),
            "peak_gib": round((mem.argument_size_in_bytes
                               + mem.temp_size_in_bytes) / 2**30, 2),
            "collective_bytes": {k: round(v / 2**30, 2)
                                 for k, v in cost.collective_bytes.items()},
        })
    except Exception as exc:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(exc).__name__}: {exc}"[:500]
    finally:
        m["set_dp_axes"](("pod", "data"))
    path.write_text(json.dumps(rec, indent=1, default=float))
    return rec


def fig5_seeded_hillclimb(n_workloads: int = 4, k: int = 4,
                          force: bool = False,
                          multi_objective: bool = False) -> dict:
    """Refine Fig. 5 static winners beyond the coarse paper grid.

    The batched device search (``repro.sim.static_search``) solves the
    {8,16,32}-unit / {2,4,6}-GB/s grid in one program; its top-k configs
    per workload then seed a greedy host hillclimb over budget-preserving
    TRANSFER moves (shift 2/4 cache units or 0.5/1 GB/s from one app to
    another) plus prefetch flips — the winning coarse configs sit on the
    budget boundary, where only transfers stay feasible.  Multiple seeds
    matter: near-tied coarse optima routinely climb to different local
    maxima.

    With ``multi_objective`` the seeds come from the Pareto front over
    (weighted speedup, min-fairness), knee point first — climbing from
    the balanced trade-off member rather than the raw ws maximizer —
    then the remaining front members.
    """
    import numpy as np

    from repro.sim import memsys
    from repro.sim.apps import stack
    from repro.sim.static_search import FIG5_FAMILIES, search_static
    from repro.sim.workloads import random_workloads

    OUT.mkdir(parents=True, exist_ok=True)
    seed_mode = "pareto_knee" if multi_objective else "scalar_topk"
    path = OUT / "fig5_hillclimb.json"
    if path.exists() and not force:
        cached = json.loads(path.read_text())
        # The cache is only valid for the parameters it recorded.
        if (cached.get("n_workloads") == n_workloads
                and cached.get("k_seeds") == k
                and cached.get("seed_mode", "scalar_topk") == seed_mode):
            return cached

    fam = "cache+bw+pref"
    wls = random_workloads(n_workloads, 4, seed=7)
    res = search_static(wls, families={fam: FIG5_FAMILIES[fam]}, k=k,
                        multi_objective=multi_objective)
    knee = res.knee_index(fam) if multi_objective else None
    grid = res.grids[fam]
    total_units = grid.total_cache_units
    total_bw = grid.total_bandwidth_gbps

    rows = []
    for wi, w in enumerate(wls):
        arr = stack(w)
        n = len(w)
        base = res.baseline_ipc[wi]

        def ws_of(c, b, p):
            ss = memsys.evaluate(
                arr, c, b, p, total_cache_units=total_units,
                total_bandwidth_gbps=total_bw, iters=40)
            return float(np.mean(ss.ipc / base))

        seed_ids = [int(i) for i in res.topk_index[fam][wi] if i >= 0]
        if knee is not None:
            # Knee first: the balanced-trade-off front member leads the
            # climb; the rest of the front follows as alternate seeds.
            kn = int(knee[wi])
            seed_ids = [kn] + [i for i in seed_ids if i != kn]

        best_ws, best_cfg = -np.inf, None
        for idx in seed_ids:
            c = grid.cache[idx].copy()
            b = grid.bandwidth[idx].copy()
            p = grid.prefetch[idx].copy()
            # Re-score the seed with the same (numpy) model the moves
            # use: the device search's value differs by up to 1e-5 rel,
            # which would swamp the 1e-9 acceptance threshold.
            cur = ws_of(c, b, p)
            improved = True
            while improved:
                improved = False
                moves = []
                for i in range(n):
                    moves.append(("p", i, i, 0.0))
                    for j in range(n):
                        if i == j:
                            continue
                        moves.extend((("c", i, j, s) for s in (2.0, 4.0)))
                        moves.extend((("b", i, j, s) for s in (0.5, 1.0)))
                for kind, i, j, step in moves:
                    c2, b2, p2 = c.copy(), b.copy(), p.copy()
                    if kind == "c":        # transfer units from j to i
                        c2[i] += step
                        c2[j] -= step
                        if c2[j] < 4.0:
                            continue
                    elif kind == "b":      # transfer bandwidth j -> i
                        b2[i] += step
                        b2[j] -= step
                        if b2[j] < 0.5:
                            continue
                    else:
                        p2[i] = 1.0 - p2[i]
                    trial = ws_of(c2, b2, p2)
                    if trial > cur + 1e-9:
                        c, b, p, cur = c2, b2, p2, trial
                        improved = True
            if cur > best_ws:
                best_ws = cur
                best_cfg = {"cache_units": c.tolist(),
                            "bandwidth_gbps": b.tolist(),
                            "prefetch_on": p.tolist()}
        grid_best = float(res.best_ws(fam)[wi])
        rows.append({
            "workload": w,
            "grid_best_ws": round(grid_best, 4),
            "refined_ws": round(best_ws, 4),
            "refine_gain": round(best_ws / grid_best - 1, 4),
            "config": best_cfg,
        })
    rec = {
        "family": fam, "n_workloads": n_workloads, "k_seeds": k,
        "seed_mode": seed_mode,
        "mean_refine_gain": round(
            float(np.mean([r["refine_gain"] for r in rows])), 4),
        "rows": rows,
    }
    path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=list(CELLS))
    ap.add_argument("--variant", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--fig5-seed", action="store_true",
                    help="refine Fig. 5 static winners from the batched "
                         "search's top-k seeds")
    ap.add_argument("--workloads", type=int, default=4)
    ap.add_argument("--seeds", type=int, default=4)
    ap.add_argument("--multi-objective", action="store_true",
                    help="seed from the (ws, min-fairness) Pareto front, "
                         "knee point first")
    args = ap.parse_args()

    if args.fig5_seed:
        rec = fig5_seeded_hillclimb(args.workloads, args.seeds,
                                    force=args.force,
                                    multi_objective=args.multi_objective)
        print(f"fig5_hillclimb: mean refine gain {rec['mean_refine_gain']}"
              f" over {rec['n_workloads']} workloads "
              f"({rec['k_seeds']} seeds each, {rec['seed_mode']})",
              flush=True)
        for r in rec["rows"]:
            print(f"  {','.join(r['workload'])}: grid {r['grid_best_ws']}"
                  f" -> refined {r['refined_ws']} (+{r['refine_gain']})",
                  flush=True)
        return

    cells = [args.cell] if args.cell else list(CELLS)
    for cell in cells:
        variants = ([args.variant] if args.variant
                    else list(VARIANTS[cell]))
        for v in variants:
            rec = run_variant(cell, v, force=args.force)
            if rec["status"] == "ok":
                print(f"{cell}/{v}: dom={rec['dominant']} "
                      f"bound={rec['bound_s']}s "
                      f"(C={rec['compute_s']} M={rec['memory_s']} "
                      f"X={rec['collective_s']}) frac="
                      f"{rec['roofline_fraction']} peak={rec['peak_gib']}GiB",
                      flush=True)
            else:
                print(f"{cell}/{v}: ERROR {rec['error'][:150]}", flush=True)


if __name__ == "__main__":
    main()
