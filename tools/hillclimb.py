"""§Perf hillclimb harness: lower a cell variant, report the three roofline
terms.  Each variant encodes one hypothesis from EXPERIMENTS.md §Perf.

  PYTHONPATH=src python tools/hillclimb.py --cell moe_train --variant v1
  PYTHONPATH=src python tools/hillclimb.py --all
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

import jax

from repro import configs
from repro.distributed import set_dp_axes, use_mesh
from repro.launch import shardings as sh
from repro.launch.dryrun import (
    HBM_BW, LINK_BW, PEAK_FLOPS, build_cell, model_flops,
)
from repro.launch.hlo_parse import analyze
from repro.launch.mesh import dp_size, make_production_mesh, model_size
from repro.models import SHAPES, build

OUT = pathlib.Path(__file__).resolve().parent.parent / "results" / "perf"

# cell -> (arch, shape, optimizer, baseline_microbatches)
CELLS = {
    "moe_train": ("qwen3-moe-30b-a3b", "train_4k", "adafactor", 2),
    "grok_train": ("grok-1-314b", "train_4k", "adafactor", 8),
    "dense_decode": ("qwen3-8b", "decode_32k", "adamw", 1),
}

# variant -> (config overrides, microbatch override, note)
VARIANTS = {
    "moe_train": {
        "baseline": ({}, None, "paper-faithful baseline (remat=full, cf=1.25, mb=2)"),
        "v1_remat_dots": ({"remat": "dots"}, None,
                          "H: full remat re-reads each layer in bwd; saving dot outputs cuts HBM term ~25% at higher peak mem"),
        "v2_cf_1.0": ({"capacity_factor": 1.0}, None,
                      "H: capacity 1.25->1.0 trims expert compute+buffer traffic ~20% (drops overflow tokens)"),
        "v3_mb_1": ({}, 1, "H: single microbatch halves per-step expert-weight re-reads"),
        "v4_chunk_2048": ({"attn_chunk": 2048, "capacity_factor": 1.0},
                          None,
                          "H: halving the q-chunk count halves per-layer K/V re-reads in the chunked attention (+ keep the confirmed cf=1.0 trim)"),
    },
    "grok_train": {
        "baseline": ({}, None, "paper-faithful baseline (mb=8, FSDP experts)"),
        "v1_mb_2": ({}, 2, "H: FSDP weight all-gathers repeat per microbatch; mb 8->2 divides the AG term ~4x"),
        "v2_mb_2_dots": ({"remat": "dots"}, 2,
                         "H: remat recompute re-gathers weights; dots policy avoids the remat re-AG"),
        "v3_mb_1": ({}, 1, "H: mb=1 halves AG again if activations fit"),
        "v4_gather_weights": ({"moe_gather_weights": True}, 2,
                              "H: the residual collectives are partial-sum ARs from the FSDP d-contraction; gathering weights first costs one 613MB AG/layer instead"),
        "v5_cf_1.0": ({"capacity_factor": 1.0}, 2,
                      "H: the 720GiB AR is the row-parallel expert DOWN output, sized e*cap = cf*topk*tokens; cf 1.25->1.0 trims it (and the dispatch buffers) 20%"),
    },
    "dense_decode": {
        "baseline": ({"decode_cache_update": "dus", "decode_gqa": "repeat"}, None, "paper-faithful baseline (DUS cache write)"),
        "v1_onehot": ({"decode_cache_update": "onehot"}, None,
                      "H: dynamic-slice write into the seq-sharded cache makes GSPMD all-gather it; one-hot masked update stays sharded -> collective term collapses"),
        "v2_onehot_chunk": ({"decode_cache_update": "onehot",
                             "attn_chunk": 2048}, None,
                            "H: after C1 the memory term (cache read) dominates and is irreducible per token; chunk size should be neutral"),
        "v3_seq_sharded_q": ({"decode_cache_update": "onehot"}, None,
                             "H: the 72 GiB of AGs are GSPMD replicating the repeat_kv broadcast (q heads-sharded vs cache seq-sharded); replicating the tiny q keeps attention seq-local -> collective term collapses"),
        "v4_grouped_gqa": ({"decode_cache_update": "onehot",
                            "decode_gqa": "grouped"}, None,
                           "H: repeat_kv materializes 4x the cache per layer; the grouped einsum reads KV once -> memory term ~-60%"),
        "v5_int8_kv": ({"decode_cache_update": "onehot",
                        "decode_gqa": "grouped",
                        "kv_cache_dtype": "int8"}, None,
                       "H: int8 KV cache halves the dominant cache-read traffic -> memory term ~-40% (accuracy traded; serving-standard)"),
    },
}


def run_variant(cell: str, variant: str, force: bool = False) -> dict:
    OUT.mkdir(parents=True, exist_ok=True)
    path = OUT / f"{cell}__{variant}.json"
    if path.exists() and not force:
        return json.loads(path.read_text())
    arch, shape, optimizer, base_mb = CELLS[cell]
    overrides, mb, note = VARIANTS[cell][variant]
    mesh = make_production_mesh()
    cfg = configs.get(arch).with_mesh(model_size(mesh), dp_size(mesh))
    cfg = dataclasses.replace(cfg, **overrides)
    model = build(cfg)
    spec = SHAPES[shape]
    rec = {"cell": cell, "variant": variant, "note": note,
           "overrides": overrides, "microbatches": mb or base_mb}
    t0 = time.time()
    try:
        set_dp_axes(sh.dp_axes_for(cfg))
        with use_mesh(mesh):
            fn, args = build_cell(model, shape, mesh, optimizer,
                                  mb or base_mb)
            compiled = fn.lower(*args).compile()
            mem = compiled.memory_analysis()
            cost = analyze(compiled.as_text())
        terms = {
            "compute_s": cost.flops / PEAK_FLOPS,
            "memory_s": cost.hbm_bytes / HBM_BW,
            "collective_s": cost.total_collective_bytes / LINK_BW,
        }
        rec.update({
            "status": "ok",
            "compile_s": round(time.time() - t0, 1),
            **{k: round(v, 4) for k, v in terms.items()},
            "dominant": max(terms, key=terms.get),
            "bound_s": round(max(terms.values()), 4),
            "roofline_fraction": round(
                terms["compute_s"] / max(max(terms.values()), 1e-12), 4),
            "useful_ratio": round(
                model_flops(cfg, spec, mesh.size) / max(cost.flops, 1.0),
                4),
            "peak_gib": round((mem.argument_size_in_bytes
                               + mem.temp_size_in_bytes) / 2**30, 2),
            "collective_bytes": {k: round(v / 2**30, 2)
                                 for k, v in cost.collective_bytes.items()},
        })
    except Exception as exc:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(exc).__name__}: {exc}"[:500]
    finally:
        set_dp_axes(("pod", "data"))
    path.write_text(json.dumps(rec, indent=1, default=float))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=list(CELLS))
    ap.add_argument("--variant", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    cells = [args.cell] if args.cell else list(CELLS)
    for cell in cells:
        variants = ([args.variant] if args.variant
                    else list(VARIANTS[cell]))
        for v in variants:
            rec = run_variant(cell, v, force=args.force)
            if rec["status"] == "ok":
                print(f"{cell}/{v}: dom={rec['dominant']} "
                      f"bound={rec['bound_s']}s "
                      f"(C={rec['compute_s']} M={rec['memory_s']} "
                      f"X={rec['collective_s']}) frac="
                      f"{rec['roofline_fraction']} peak={rec['peak_gib']}GiB",
                      flush=True)
            else:
                print(f"{cell}/{v}: ERROR {rec['error'][:150]}", flush=True)


if __name__ == "__main__":
    main()
