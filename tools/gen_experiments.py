"""Regenerate the data-driven sections of EXPERIMENTS.md from results/.

Usage: PYTHONPATH=src python tools/gen_experiments.py
Writes the §Dry-run and §Roofline tables between the AUTOGEN markers.
"""
from __future__ import annotations

import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
from benchmarks.roofline_report import load_cells, markdown_table  # noqa: E402

ROOT = pathlib.Path(__file__).resolve().parent.parent


def dryrun_summary() -> str:
    out = []
    for mesh, label in (("single", "single-pod 16x16 (256 chips)"),
                        ("multi", "multi-pod 2x16x16 (512 chips)")):
        cells = load_cells(mesh)
        ok = [c for c in cells if c["status"] == "ok"]
        skip = [c for c in cells if c["status"] == "skip"]
        err = [c for c in cells if c["status"] == "error"]
        out.append(f"**{label}**: {len(ok)} compiled OK, "
                   f"{len(skip)} policy skips, {len(err)} errors "
                   f"(cells: {len(cells)}/40).")
        if err:
            for c in err:
                out.append(f"  - ERROR {c['arch']} x {c['shape']}: "
                           f"{c.get('error', '')[:120]}")
    return "\n".join(out)


def collective_table(mesh: str = "single") -> str:
    rows = ["| arch | shape | AG GiB | AR GiB | RS GiB | A2A GiB | "
            "CP GiB | #colls |", "|---|---|---|---|---|---|---|---|"]
    for c in load_cells(mesh):
        if c["status"] != "ok" or c["kind"] != "train":
            continue
        cb = c["parsed"]["collective_bytes"]
        cc = c["parsed"]["collective_counts"]
        g = lambda k: cb.get(k, 0.0) / 2**30
        rows.append(
            f"| {c['arch']} | {c['shape']} | {g('all-gather'):.1f} | "
            f"{g('all-reduce'):.1f} | {g('reduce-scatter'):.1f} | "
            f"{g('all-to-all'):.1f} | {g('collective-permute'):.1f} | "
            f"{sum(cc.values())} |")
    return "\n".join(rows)


def main() -> None:
    exp = ROOT / "EXPERIMENTS.md"
    text = exp.read_text() if exp.exists() else ""
    block = (
        "<!-- AUTOGEN:DRYRUN START -->\n"
        + dryrun_summary()
        + "\n\n### Roofline table — single-pod (16, 16) mesh, "
          "TPU v5e constants (197 TFLOP/s bf16, 819 GB/s HBM, "
          "50 GB/s/link)\n\n"
        + markdown_table("single")
        + "\n\n### Per-step collective bytes by kind (train cells, "
          "per device)\n\n"
        + collective_table("single")
        + "\n<!-- AUTOGEN:DRYRUN END -->"
    )
    if "<!-- AUTOGEN:DRYRUN START -->" in text:
        pre = text.split("<!-- AUTOGEN:DRYRUN START -->")[0]
        post = text.split("<!-- AUTOGEN:DRYRUN END -->")[1]
        text = pre + block + post
    else:
        text = text + "\n" + block + "\n"
    exp.write_text(text)
    print(f"wrote {exp}")


if __name__ == "__main__":
    main()
