"""Roofline report: aggregates the dry-run JSON cache into the
EXPERIMENTS.md §Roofline table (single-pod mesh, per spec)."""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

from benchmarks.common import emit, timer

DRYRUN = pathlib.Path(__file__).resolve().parent.parent / "results" / "dryrun"


def _analytic_gib(c: Dict) -> float:
    rec = c["memory"].get("analytic")
    if rec:
        return rec["total_bytes"] / 2**30
    from repro import configs
    from repro.launch.analytic import analytic_memory
    from repro.models.model import SHAPES
    cfg = configs.get(c["arch"]).with_mesh(16, 16 if c["mesh"] == "single"
                                           else 32)
    opt = {"grok-1-314b": "adafactor",
           "qwen3-moe-30b-a3b": "adafactor"}.get(c["arch"], "adamw")
    return analytic_memory(cfg, SHAPES[c["shape"]], c["chips"],
                           opt)["total_bytes"] / 2**30


def load_cells(mesh: str = "single") -> List[Dict]:
    cells = []
    for p in sorted(DRYRUN.glob(f"{mesh}__*.json")):
        cells.append(json.loads(p.read_text()))
    return cells


def table(mesh: str = "single") -> List[Dict]:
    rows = []
    for c in load_cells(mesh):
        row = {"arch": c["arch"], "shape": c["shape"],
               "status": c["status"]}
        if c["status"] == "ok":
            r = c["roofline"]
            p = c["parsed"]
            row.update({
                "compute_s": r["compute_s"],
                "memory_s": r["memory_s"],
                "collective_s": r["collective_s"],
                "dominant": r["dominant"],
                "roofline_fraction": r["roofline_fraction"],
                "useful_flops_ratio": r["useful_flops_ratio"],
                "mem_gib": c["memory"]["peak_estimate_bytes"] / 2**30,
                "analytic_gib": _analytic_gib(c),
                "collective_gib": p["total_collective_bytes"] / 2**30,
            })
        rows.append(row)
    return rows


def markdown_table(mesh: str = "single") -> str:
    rows = table(mesh)
    out = ["| arch | shape | compute_s | memory_s | collective_s | "
           "dominant | frac | useful | mem GiB (analytic) |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"SKIP (full-attention @500k) | — | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant'].replace('_s', '')} | "
            f"{r['roofline_fraction']:.2f} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{r['mem_gib']:.1f} ({r['analytic_gib']:.1f}) |")
    return "\n".join(out)


def roofline_report() -> None:
    with timer() as t:
        rows = [r for r in table("single") if r["status"] == "ok"]
        if not rows:
            emit("roofline_report", t.seconds,
                 {"error": "run repro.launch.dryrun first"})
            return
        dominated = {}
        for r in rows:
            dominated[r["dominant"]] = dominated.get(r["dominant"], 0) + 1
        worst = min(rows, key=lambda r: r["roofline_fraction"])
        best = max(rows, key=lambda r: r["roofline_fraction"])
        multi = [r for r in table("multi") if r["status"] == "ok"]
    emit("roofline_report", t.seconds, {
        "single_pod_cells_ok": len(rows),
        "multi_pod_cells_ok": len(multi),
        "skips": 8,
        "dominant_term_histogram": dominated,
        "worst_cell": f"{worst['arch']}x{worst['shape']}"
                      f"={worst['roofline_fraction']:.3f}",
        "best_cell": f"{best['arch']}x{best['shape']}"
                     f"={best['roofline_fraction']:.3f}",
        "mean_fraction_train": round(
            sum(r["roofline_fraction"] for r in rows
                if r["shape"] == "train_4k")
            / max(len([r for r in rows if r["shape"] == "train_4k"]), 1),
            3),
    })
