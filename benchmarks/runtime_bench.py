"""Runtime-binding smoke: fused TrainingPlant + batched block planner.

The CI gate for the PR that closed the runtime-binding loop (ROADMAP
item 5): the training-plant coordinator and the kernel block planner were
the last subsystems outside the <=-few-dispatches contract.  Gates (all
``RuntimeError`` — never bare asserts, so ``python -O`` cannot skip them):

* **fused dispatch budget** — a full Fig. 8 knob schedule through
  :func:`repro.runtime.plant_jax.run_fused_schedule` (cache Lookahead,
  Algorithm-1 bandwidth, Algorithm-2 A/B throttling) costs exactly ONE
  recorded device program per run (counter:
  :func:`repro.core.device_dispatches`), not one per interval;
* **fused bit-parity** — the fused trajectory equals the host
  ``CBPCoordinator`` golden (:func:`host_reference_run`) bit for bit on
  every knob field, the same contract ``tests/test_plant_jax.py`` pins;
* **planner dispatch + parity** — :func:`plan_matmul_blocks_batched`
  plans a fleet of shapes (square, rectangular, prime/odd, sub-8) in ONE
  device call and returns blocks identical to the scalar numpy planner;
* **wall trajectory** — warm fused wall vs the committed
  ``results/bench/runtime_bench.json`` record, slack
  ``RUNTIME_BENCH_BUDGET_X`` (default 3x; the shard8 CI job widens it),
  checked BEFORE the record refreshes.

    PYTHONPATH=src python -m benchmarks.runtime_bench [--smoke]

(The full mode adds a longer-horizon scale record on top of the same
gates; ``--smoke`` is what CI and ``tools/run_tests.sh --smoke`` run.)
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import RESULTS, emit
from repro.core import CBPParams, device_dispatches
from repro.runtime.cbp_runtime import (
    plan_matmul_blocks,
    plan_matmul_blocks_batched,
)
from repro.runtime.plant_jax import host_reference_run, run_fused_schedule
from repro.train.plant_model import make_stream_plant_model

#: Knob-trajectory fields under the bit-parity gate.
FIELDS = ("kinds", "t_ms", "duration_ms", "cache_units", "bandwidth",
          "prefetch_on", "ipc", "queuing_delay_ns")

#: Planner gate shapes: square, large, rectangular, prime/odd, sub-8.
PLAN_SHAPES = ((512, 512, 512), (1024, 1024, 1024), (384, 768, 96),
               (97, 53, 160), (6, 4, 512))

SMOKE_SHAPE = dict(n_clients=4, total_units=48, total_bandwidth=64.0,
                   total_ms=60.0)
FULL_SHAPE = dict(n_clients=12, total_units=96, total_bandwidth=128.0,
                  total_ms=400.0)

#: Fields owned by the full mode, preserved across smoke refreshes.
FULL_FIELDS = ("full_n_clients", "full_total_ms", "full_segments",
               "full_wall_s_fused_warm")


def _prior() -> dict:
    path = RESULTS / "runtime_bench.json"
    if not path.exists():
        return {}
    try:
        return json.loads(path.read_text()).get("derived", {})
    except (ValueError, OSError):
        return {}


def _fused_gate(shape: dict, params: CBPParams) -> dict:
    """One-dispatch + bit-parity gate at ``shape``; returns the record."""
    step_fn, step_model = make_stream_plant_model(
        shape["n_clients"], shape["total_units"], shape["total_bandwidth"])
    kw = dict(shape, params=params)
    host = host_reference_run(step_fn, **kw)
    run_fused_schedule(step_model, **kw)          # jit warm-up
    before = device_dispatches()
    fused = run_fused_schedule(step_model, **kw)
    dispatches = device_dispatches() - before
    if dispatches != 1:
        raise RuntimeError(
            f"fused TrainingPlant schedule cost {dispatches} device "
            f"programs; the contract is ONE per run (was one per "
            f"interval before the fused port)")
    # Best-of-3 warm wall: the fused run is milliseconds, so a single
    # sample would make the CI wall gate jitter-bound.
    wall = float("inf")
    for _ in range(3):
        t0 = time.monotonic()
        run_fused_schedule(step_model, **kw)
        wall = min(wall, time.monotonic() - t0)
    for field in FIELDS:
        a, b = getattr(fused, field), getattr(host, field)
        if a.dtype != b.dtype or not np.array_equal(a, b):
            raise RuntimeError(
                f"fused-vs-host bit-parity broken on {field!r}: the "
                f"fused scan drifted off the CBPCoordinator golden")
    return {
        "segments": int(len(fused.kinds)),
        "wall_s_fused_warm": round(wall, 4),
        "dispatches_per_run": dispatches,
    }


def _planner_gate() -> dict:
    """Batched planner: one dispatch, blocks identical to scalar numpy."""
    golden = [plan_matmul_blocks(m, n, k, allocator_backend="numpy")
              for m, n, k in PLAN_SHAPES]
    plan_matmul_blocks_batched(list(PLAN_SHAPES))  # jit warm-up
    before = device_dispatches()
    t0 = time.monotonic()
    batched = plan_matmul_blocks_batched(list(PLAN_SHAPES))
    wall = time.monotonic() - t0
    dispatches = device_dispatches() - before
    if dispatches != 1:
        raise RuntimeError(
            f"batched block planner cost {dispatches} device programs "
            f"for {len(PLAN_SHAPES)} shapes; the contract is ONE")
    if list(batched) != golden:
        raise RuntimeError(
            f"batched planner blocks differ from the scalar numpy "
            f"planner: {list(batched)} != {golden}")
    return {
        "planner_shapes": len(PLAN_SHAPES),
        "planner_dispatches": dispatches,
        "planner_wall_s_warm": round(wall, 4),
        "planner_blocks": [list(b) for b in batched],
    }


def smoke() -> None:
    prior = _prior()
    params = CBPParams(reconfiguration_interval_ms=10.0, min_ways=2,
                       min_bandwidth_allocation=2.0)
    fused = _fused_gate(SMOKE_SHAPE, params)
    planner = _planner_gate()

    wall = fused["wall_s_fused_warm"]
    budget_x = float(os.environ.get("RUNTIME_BENCH_BUDGET_X", "3.0"))
    prior_warm = prior.get("wall_s_fused_warm")
    comparable = (prior.get("n_clients") == SMOKE_SHAPE["n_clients"]
                  and prior.get("segments") == fused["segments"])
    if prior_warm and comparable and wall > budget_x * prior_warm:
        raise RuntimeError(
            f"fused TrainingPlant wall regression: warm {wall:.4f}s vs "
            f"recorded {prior_warm:.4f}s (budget {budget_x}x)")

    derived = {
        "n_clients": SMOKE_SHAPE["n_clients"],
        "total_units": SMOKE_SHAPE["total_units"],
        "total_ms": SMOKE_SHAPE["total_ms"],
        **fused,
        **planner,
    }
    derived.update({k: prior[k] for k in FULL_FIELDS if k in prior})
    emit("runtime_bench", wall, derived)


def full() -> None:
    """Smoke gates plus the longer-horizon scale record (400 ms, n=12)."""
    smoke()
    prior = _prior()
    params = CBPParams(reconfiguration_interval_ms=5.0, min_ways=2,
                       min_bandwidth_allocation=1.0)
    fused = _fused_gate(FULL_SHAPE, params)
    derived = dict(prior)
    derived.update({
        "full_n_clients": FULL_SHAPE["n_clients"],
        "full_total_ms": FULL_SHAPE["total_ms"],
        "full_segments": fused["segments"],
        "full_wall_s_fused_warm": fused["wall_s_fused_warm"],
    })
    emit("runtime_bench", fused["wall_s_fused_warm"], derived)


def main(smoke_mode: bool = True) -> None:
    if smoke_mode:
        smoke()
    else:
        full()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    main(args.smoke)
