"""Shared benchmark plumbing: CSV emission + timing."""
from __future__ import annotations

import json
import pathlib
import time
from typing import Any, Dict

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results" / "bench"


def emit(name: str, seconds: float, derived: Dict[str, Any]) -> None:
    """Print the ``name,us_per_call,derived`` CSV row and persist JSON."""
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.json").write_text(
        json.dumps({"name": name, "seconds": seconds, "derived": derived},
                   indent=1, default=float))
    flat = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{seconds * 1e6:.0f},{flat}", flush=True)


class timer:
    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *a):
        self.seconds = time.monotonic() - self.t0
