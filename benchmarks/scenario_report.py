"""Scenario-diversity report: generated mixes beyond the paper's w1-w14.

``random_mixes`` draws class-balanced 16-app mixes (every sensitivity
class of paper Fig. 2 represented); one device-resident sweep evaluates
every Table-3 manager over all of them and this report summarizes how the
paper's headline ordering holds up across the broader scenario space —
spread of the CBP weighted speedup, win rate against the best
two-technique manager, and which generated mixes are hardest.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import emit, timer
from repro.sim import MANAGER_NAMES, random_mixes, run_sweep
from repro.sim.workloads import _CLASS_BUCKETS

PAIR_MANAGERS = ("bw+pref", "bw+cache", "cache+pref", "CPpf")


def scenario_diversity(n_mixes: int = 32, n_apps: int = 16, seed: int = 0,
                       total_ms: float = 40.0) -> Dict[str, object]:
    """Sweep ``n_mixes`` generated scenarios x all managers in one call."""
    with timer() as t:
        mixes = random_mixes(n_mixes, n_apps, seed=seed)
        res = run_sweep(mixes, total_ms=total_ms)
        ws = {m: np.asarray(res.weighted_speedup(m)) for m in MANAGER_NAMES}
        cbp = ws["CBP"]
        best_pair = np.max([ws[m] for m in PAIR_MANAGERS], axis=0)

        distinct = sorted({a for mix in mixes for a in mix})
        class_cover = {
            cls: sum(any(a in bucket for a in mix) for mix in mixes)
            for cls, bucket in _CLASS_BUCKETS.items()
        }
        hardest = int(np.argmin(cbp))
        derived = {
            "n_mixes": n_mixes,
            "n_apps_per_mix": n_apps,
            "distinct_apps": len(distinct),
            "class_coverage_mixes": class_cover,
            "geomean_ws": {
                m: round(float(np.exp(np.mean(np.log(ws[m])))), 3)
                for m in MANAGER_NAMES},
            "cbp_ws_p10_p50_p90": [
                round(float(np.percentile(cbp, p)), 3) for p in (10, 50, 90)],
            "cbp_win_rate_vs_best_pair": round(
                float(np.mean(cbp >= best_pair - 1e-9)), 3),
            "cbp_beats_baseline_rate": round(float(np.mean(cbp > 1.0)), 3),
            "hardest_mix_index": hardest,
            "hardest_mix_cbp_ws": round(float(cbp[hardest]), 3),
            "hardest_mix_apps": mixes[hardest],
        }
    emit("scenario_diversity", t.seconds, derived)
    return derived


if __name__ == "__main__":
    scenario_diversity()
