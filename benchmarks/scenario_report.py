"""Scenario-diversity report: generated mixes beyond the paper's w1-w14.

``random_mixes`` draws class-balanced 16-app mixes (every sensitivity
class of paper Fig. 2 represented); one device-resident sweep evaluates
every Table-3 manager over all of them and this report summarizes how the
paper's headline ordering holds up across the broader scenario space —
spread of the CBP weighted speedup, win rate against the best
two-technique manager, and which generated mixes are hardest.

Since PR 3 the report also times each scenario family over both timeline
backends — the fused one-program-per-(manager, timeline) path
(:mod:`repro.sim.timeline_jax`) and the PR 2 per-segment host loop — so
the fused speedup is visible per family, not just in the CI smoke.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import emit, timer
from repro.sim import MANAGER_NAMES, WORKLOADS, random_mixes, run_sweep
from repro.sim.runner import CMPConfig
from repro.sim.workloads import _CLASS_BUCKETS

PAIR_MANAGERS = ("bw+pref", "bw+cache", "cache+pref", "CPpf")


def _families(n_mixes: int, n_apps: int, seed: int) -> Dict[str, List]:
    """Scenario families reported on: the transcribed paper mixes and the
    class-balanced generated space (two seeds = two disjoint draws)."""
    return {
        "paper_w1_w14": list(WORKLOADS.values()),
        "random_balanced": random_mixes(n_mixes, n_apps, seed=seed),
        "random_balanced_alt": random_mixes(n_mixes, n_apps, seed=seed + 1),
    }


def _timed_sweep(mixes, total_ms: float, config=None):
    """(result, warm wall seconds) — first call warms the jit caches."""
    run_sweep(mixes, total_ms=total_ms, config=config)
    t0 = time.monotonic()
    res = run_sweep(mixes, total_ms=total_ms, config=config)
    return res, time.monotonic() - t0


def scenario_diversity(n_mixes: int = 32, n_apps: int = 16, seed: int = 0,
                       total_ms: float = 40.0) -> Dict[str, object]:
    """Sweep every scenario family x all managers, fused and segment."""
    segment_cfg = CMPConfig(timeline_backend="segment")
    with timer() as t:
        families = _families(n_mixes, n_apps, seed)
        walls: Dict[str, Dict[str, float]] = {}
        res = None
        for fam, mixes in families.items():
            fused_res, wall_fused = _timed_sweep(mixes, total_ms)
            _, wall_seg = _timed_sweep(mixes, total_ms, segment_cfg)
            walls[fam] = {
                "n_mixes": len(mixes),
                "wall_s_fused": round(wall_fused, 3),
                "wall_s_segment": round(wall_seg, 3),
                "fused_speedup": round(wall_seg / max(wall_fused, 1e-9), 2),
            }
            if fam == "random_balanced":
                res = fused_res

        mixes = families["random_balanced"]
        ws = {m: np.asarray(res.weighted_speedup(m)) for m in MANAGER_NAMES}
        cbp = ws["CBP"]
        best_pair = np.max([ws[m] for m in PAIR_MANAGERS], axis=0)

        distinct = sorted({a for mix in mixes for a in mix})
        class_cover = {
            cls: sum(any(a in bucket for a in mix) for mix in mixes)
            for cls, bucket in _CLASS_BUCKETS.items()
        }
        hardest = int(np.argmin(cbp))
        derived = {
            "n_mixes": n_mixes,
            "n_apps_per_mix": n_apps,
            "distinct_apps": len(distinct),
            "class_coverage_mixes": class_cover,
            "timeline_wall_s": walls,
            "geomean_ws": {
                m: round(float(np.exp(np.mean(np.log(ws[m])))), 3)
                for m in MANAGER_NAMES},
            "cbp_ws_p10_p50_p90": [
                round(float(np.percentile(cbp, p)), 3) for p in (10, 50, 90)],
            "cbp_win_rate_vs_best_pair": round(
                float(np.mean(cbp >= best_pair - 1e-9)), 3),
            "cbp_beats_baseline_rate": round(float(np.mean(cbp > 1.0)), 3),
            "hardest_mix_index": hardest,
            "hardest_mix_cbp_ws": round(float(cbp[hardest]), 3),
            "hardest_mix_apps": mixes[hardest],
        }
    emit("scenario_diversity", t.seconds, derived)
    return derived


if __name__ == "__main__":
    scenario_diversity()
