"""Fig. 5 static-search smoke: the CI gate for the batched search path.

Runs the potential-study search (``repro.sim.static_search``) over a
fixed set of 4-app random workloads and asserts the contracts that make
the search scale:

* AT MOST TWO device programs for the whole search — every family's
  chunked grid scan stacked back to back inside ONE program plus one
  shared baseline evaluation — checked with the
  :func:`repro.core.device_dispatches` counter on the warm runs;
* batched-vs-numpy parity: best weighted speedups match the
  ``benchmarks.paper_figs._exhaustive_best`` host reference within 1e-5
  relative on a spot-check subset (the full parity matrix lives in
  ``tests/test_static_search.py``);
* the potential-study invariant: the all-three family's best static
  allocation dominates every subset family per workload (its grid is a
  strict superset).

The search runs three times; the jit-warm wall time (min over the two
warm runs) is the trajectory metric, gated against the committed
``results/bench/fig5_smoke.json`` record via ``FIG5_SMOKE_BUDGET_X``
(default 3x, slack for machine variance).  ``--compare-host`` times the
pre-PR 4 host loop (one ``_exhaustive_best`` call per (workload,
family)) and records the speedup; CI skips it to stay inside its
wall-time budget, and the refreshed record preserves the recorded
comparison fields.

    PYTHONPATH=src python -m benchmarks.fig5_smoke [--compare-host]

With ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` the same
smoke exercises the multi-device path: the workload axis shards over the
N forced host devices via ``repro.distributed`` (the CI ``shard8`` job).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import RESULTS, emit
from benchmarks.paper_figs import _exhaustive_best
from repro.core import device_dispatches, reset_device_dispatches
from repro.sim.static_search import FIG5_FAMILIES, search_static
from repro.sim.workloads import random_workloads

DEFAULT_WORKLOADS = 16

#: Prior-record fields preserved across runs that skip the comparison.
HOST_FIELDS = ("wall_s_host_loop", "host_loop_speedup_warm",
               "host_loop_dispatch_equivalent")

#: (family, workload index) spot checks against the numpy reference —
#: the cheap families on two workloads plus the big all-three grid once.
PARITY_CHECKS = (
    ("only_pref", (0, 1)),
    ("bw+pref", (0, 1)),
    ("cache+bw", (0, 1)),
    ("cache+bw+pref", (0,)),
)


def _prior_record() -> dict:
    path = RESULTS / "fig5_smoke.json"
    if not path.exists():
        return {}
    try:
        return json.loads(path.read_text()).get("derived", {})
    except (ValueError, OSError):
        return {}


def _host_loop(workloads) -> np.ndarray:
    """The pre-PR 4 path: one numpy solve per (workload, family)."""
    return np.array([
        [_exhaustive_best(w, spec.manage_cache, spec.manage_bw,
                          spec.manage_pf, spec.pf_all_on)
         for w in workloads]
        for spec in FIG5_FAMILIES.values()
    ])


def main(n_workloads: int = DEFAULT_WORKLOADS,
         compare_host: bool = False) -> None:
    prior = _prior_record()
    wls = random_workloads(n_workloads, 4, seed=7)
    families = list(FIG5_FAMILIES)

    t0 = time.monotonic()
    res = search_static(wls)
    wall_cold = time.monotonic() - t0

    # Hard failures, not asserts: this is a CI gate and must still trip
    # under python -O / PYTHONOPTIMIZE.
    for fam, idxs in PARITY_CHECKS:
        spec = FIG5_FAMILIES[fam]
        for wi in idxs:
            ref = _exhaustive_best(
                wls[wi], spec.manage_cache, spec.manage_bw,
                spec.manage_pf, spec.pf_all_on)
            got = float(res.best_ws(fam)[wi])
            if abs(got - ref) > 1e-5 * abs(ref):
                raise RuntimeError(
                    f"batched-vs-numpy parity violation: {fam}[{wi}] "
                    f"batched {got!r} vs reference {ref!r}")
    all3 = res.best_ws("cache+bw+pref")
    for fam in families:
        if not (all3 >= res.best_ws(fam) - 1e-9).all():
            raise RuntimeError(
                f"all-three family does not dominate {fam}: its grid is "
                "a superset, so this is a search bug")

    # Warm runs: the compile-free trajectory metric (min of two), with
    # the dispatch counter checking the stacked-search budget (ONE
    # program for all families + one shared baseline) on each run.
    wall_warm = float("inf")
    dispatch_budget = 2
    for _ in range(2):
        reset_device_dispatches()
        t0 = time.monotonic()
        search_static(wls)
        wall_warm = min(wall_warm, time.monotonic() - t0)
        dispatches = device_dispatches()
        if dispatches > dispatch_budget:
            raise RuntimeError(
                f"static search launched {dispatches} device programs; "
                f"the stacked-program-plus-baseline budget allows "
                f"{dispatch_budget}")

    derived = {
        "n_workloads": n_workloads,
        "n_families": len(families),
        "device_dispatches_warm": dispatches,
        "dispatch_budget": dispatch_budget,
        "wall_s_batched_warm": round(wall_warm, 3),
        "wall_s_batched_cold": round(wall_cold, 3),
        "geo_all3": round(res.geomean("cache+bw+pref"), 4),
    }
    if compare_host:
        t0 = time.monotonic()
        host = _host_loop(wls)
        wall_host = time.monotonic() - t0
        np.testing.assert_allclose(          # full-matrix parity while here
            np.stack([res.best_ws(f) for f in families]), host, rtol=1e-5)
        derived.update({
            "wall_s_host_loop": round(wall_host, 3),
            "host_loop_speedup_warm": round(
                wall_host / max(wall_warm, 1e-9), 2),
            "host_loop_dispatch_equivalent": n_workloads * len(families),
        })
    elif prior.get("n_workloads") == n_workloads:
        # Carry the recorded comparison over only at the same shape —
        # a host-loop wall time measured at another workload count would
        # mislabel the refreshed record.
        derived.update({k: prior[k] for k in HOST_FIELDS if k in prior})

    # Trajectory gate BEFORE refreshing the record: a regressed run must
    # not re-baseline the tracked JSON it just failed against.
    budget_x = float(os.environ.get("FIG5_SMOKE_BUDGET_X", "3.0"))
    prior_warm = prior.get("wall_s_batched_warm")
    if (prior_warm and prior.get("n_workloads") == n_workloads
            and wall_warm > budget_x * prior_warm):
        raise RuntimeError(
            f"fig5 search wall-time regression: warm {wall_warm:.2f}s vs "
            f"recorded {prior_warm:.2f}s (budget {budget_x}x)")
    # Non-default shapes go to a scratch record so local experiments never
    # clobber the committed baseline.
    emit("fig5_smoke" if n_workloads == DEFAULT_WORKLOADS
         else "fig5_smoke_custom", wall_warm, derived)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--workloads", type=int, default=DEFAULT_WORKLOADS)
    ap.add_argument("--compare-host", action="store_true")
    args = ap.parse_args()
    main(args.workloads, args.compare_host)
