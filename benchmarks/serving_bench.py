"""Serving-engine throughput: device-resident loop vs the host loop.

Measures greedy-decode tokens/sec of :class:`repro.serving.
JitServingEngine` (one jitted program per reconfiguration interval,
in-trace CBP) against the host-loop :class:`ServingEngine` (one decode
dispatch per TOKEN plus a Python slot scan), on the tiny smoke model so
CPU CI exercises the full engine. Results land in
``results/bench/serving_bench.json`` keyed by slot count, so the smoke
shape and the committed 256-4096 sweep coexist in one record.

Default mode sweeps ``--slots 256 1024 4096`` and FAILS unless the jitted
engine clears >= ``SERVING_BENCH_SPEEDUP_MIN`` (default 5x, the ISSUE 7
acceptance bar) over the host loop at every slot count >= 256 where the
host comparison ran (the host loop is timed at the smallest swept count;
``--compare-host-all`` times it everywhere, minutes at 4096).

``--smoke`` is the CI gate: one small slot count, host comparison on,
failing on

* dispatch-budget violations — each reconfiguration interval must be ONE
  recorded device dispatch (<= 2 is the contract; this engine uses 1);
* warm-wall regressions beyond ``SERVING_BENCH_BUDGET_X`` (default 3x)
  against the committed record for the same slot shape.

Only the keys the run produced are refreshed; other slot counts keep
their committed values (the sweep_smoke prior-record pattern). With
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and ``--groups 8``
the engine shards its stream groups over the forced devices via
``repro.distributed.shard_grid`` (the CI shard8 job).

    PYTHONPATH=src python -m benchmarks.serving_bench [--smoke]
        [--slots N ...] [--groups G] [--compare-host-all]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional

import numpy as np

from benchmarks.common import RESULTS, emit

DEFAULT_SLOTS = [256, 1024, 4096]
SMOKE_SLOTS = [64]
PROMPT_LEN = 4
MAX_NEW = 16
REQS_PER_SLOT = 2


def _prior_record() -> dict:
    path = RESULTS / "serving_bench.json"
    if not path.exists():
        return {}
    try:
        return json.loads(path.read_text()).get("derived", {})
    except (ValueError, OSError):
        return {}


def _requests(vocab: int, n: int, n_streams: int, seed: int = 0):
    from repro.serving import Request

    rng = np.random.default_rng(seed)
    return [
        Request(stream=int(rng.integers(n_streams)),
                prompt=rng.integers(1, vocab, size=PROMPT_LEN).astype(
                    np.int32),
                max_new_tokens=MAX_NEW)
        for _ in range(n)
    ]


def _engine_cfg(slots: int, n_streams: int):
    from repro.serving import EngineConfig

    return EngineConfig(
        batch_slots=slots, max_len=32, page_tokens=8,
        total_pages=max(4 * n_streams, slots // 2),
        reconfig_every_steps=16, min_slot_share=0.25)


def _tokens(reqs) -> int:
    return sum(len(r.generated) for r in reqs if r.generated is not None)


def bench_slots(model, params, vocab: int, slots: int, groups: int,
                compare_host: bool) -> Dict:
    from repro.core.dispatch import (
        device_dispatches,
        reset_device_dispatches,
    )
    from repro.serving import JitServingEngine, ServingEngine

    n_streams = max(groups, slots // 16)
    n_streams -= n_streams % groups
    cfg = _engine_cfg(slots, n_streams)
    eng = JitServingEngine(model, params, n_streams=n_streams, cfg=cfg,
                           n_groups=groups)
    eng.run(_requests(vocab, REQS_PER_SLOT * slots, n_streams),
            max_steps=2_000)  # cold: compile + first schedule

    wall = float("inf")
    tokens = 0
    for _ in range(2):
        reqs = _requests(vocab, REQS_PER_SLOT * slots, n_streams)
        reset_device_dispatches()
        t0 = time.monotonic()
        eng.run(reqs, max_steps=2_000)
        wall = min(wall, time.monotonic() - t0)
        dispatches = device_dispatches()
        if dispatches > eng.intervals:
            raise RuntimeError(
                f"{dispatches} dispatches for {eng.intervals} "
                f"reconfiguration intervals; the one-program-per-interval "
                f"contract allows at most {eng.intervals}")
        tokens = _tokens(reqs)
    out = {
        "slots": slots,
        "n_streams": n_streams,
        "n_groups": groups,
        "requests": REQS_PER_SLOT * slots,
        "tokens": tokens,
        "steps": eng.steps,
        "reconfigs": eng.reconfigs,
        "intervals": eng.intervals,
        "dispatches_warm": dispatches,
        "jit_wall_s": round(wall, 3),
        "jit_tok_s": round(tokens / max(wall, 1e-9), 1),
    }
    if compare_host:
        host = ServingEngine(model, params, n_streams=n_streams, cfg=cfg)
        host.run(_requests(vocab, min(4, n_streams), n_streams),
                 max_steps=60)  # warm the decode jit off the clock
        host = ServingEngine(model, params, n_streams=n_streams, cfg=cfg)
        hreqs = _requests(vocab, REQS_PER_SLOT * slots, n_streams)
        t0 = time.monotonic()
        host.run(hreqs, max_steps=2_000)
        hwall = time.monotonic() - t0
        htokens = _tokens(hreqs)
        out.update({
            "host_wall_s": round(hwall, 3),
            "host_tok_s": round(htokens / max(hwall, 1e-9), 1),
            "speedup": round((tokens / max(wall, 1e-9))
                             / max(htokens / max(hwall, 1e-9), 1e-9), 2),
        })
    return out


def main(slot_counts: List[int], groups: int, smoke: bool,
         compare_host_all: bool) -> None:
    import dataclasses

    import jax

    from repro import configs
    from repro.models.model import Model

    # The bench isolates ENGINE overhead (scheduling, admission, CBP,
    # dispatch) — both engines run the identical jitted decode, so model
    # FLOPs only dilute the comparison.  Shrink the smoke model's
    # FLOP-heavy dims (vocab logits + MLP) below the per-step engine
    # costs being measured.
    cfg = dataclasses.replace(
        configs.get_smoke("qwen3-8b"), name="qwen3-8b-servebench",
        n_layers=1, d_ff=64, vocab_size=128)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    prior = _prior_record()
    prior_shapes: Dict[str, dict] = dict(prior.get("by_slots", {}))
    budget_x = float(os.environ.get("SERVING_BENCH_BUDGET_X", "3.0"))
    speedup_min = float(os.environ.get("SERVING_BENCH_SPEEDUP_MIN", "5.0"))

    by_slots: Dict[str, dict] = {}
    primary: Optional[dict] = None
    for i, slots in enumerate(sorted(slot_counts)):
        compare = smoke or compare_host_all or i == 0
        row = bench_slots(model, params, cfg.vocab_size, slots, groups,
                          compare_host=compare)
        # Grouped runs get their own record key: the shard8 CI smoke must
        # not overwrite the committed single-group baseline for the same
        # slot count.
        key = str(slots) if groups == 1 else f"{slots}g{groups}"
        # Warm-wall gate BEFORE refreshing: a regressed run must not
        # re-baseline the record it just failed against.
        old = prior_shapes.get(key)
        comparable = old and all(
            old.get(k) == row[k]
            for k in ("n_streams", "n_groups", "requests"))
        if comparable and row["jit_wall_s"] > budget_x * old["jit_wall_s"]:
            raise RuntimeError(
                f"serving wall-time regression at {slots} slots: "
                f"{row['jit_wall_s']:.2f}s vs recorded "
                f"{old['jit_wall_s']:.2f}s (budget {budget_x}x)")
        if not smoke and slots >= 256 and "speedup" in row:
            if row["speedup"] < speedup_min:
                raise RuntimeError(
                    f"jitted engine only {row['speedup']}x over the host "
                    f"loop at {slots} slots (bar: {speedup_min}x)")
        by_slots[key] = row
        primary = primary or row
        print(f"  slots={slots}: jit {row['jit_tok_s']} tok/s"
              + (f", host {row['host_tok_s']} tok/s "
                 f"({row['speedup']}x)" if "speedup" in row else ""),
              flush=True)

    prior_shapes.update(by_slots)
    derived = {
        "by_slots": prior_shapes,
        "prompt_len": PROMPT_LEN,
        "max_new": MAX_NEW,
        "dispatch_contract": "1 device program per reconfig interval",
    }
    emit("serving_bench", primary["jit_wall_s"], derived)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI shape: small slot count, gates only")
    ap.add_argument("--slots", type=int, nargs="+", default=None)
    ap.add_argument("--groups", type=int, default=1)
    ap.add_argument("--compare-host-all", action="store_true")
    args = ap.parse_args()
    counts = args.slots or (SMOKE_SLOTS if args.smoke else DEFAULT_SLOTS)
    main(counts, args.groups, args.smoke, args.compare_host_all)
