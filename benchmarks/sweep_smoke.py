"""Device-resident sweep smoke: the CI gate for the batched allocator path.

Runs an all-manager x many-mix sweep and asserts the contract that makes
sweeps scale: the batched path performs ZERO per-mix host allocator calls
(counter hook on the numpy ``lookahead_allocate``).  The sweep runs twice;
the second, jit-warm wall time is the primary trajectory metric (the cold
run mostly measures XLA compilation) and is checked against the committed
``results/bench/sweep_smoke.json`` record — a regression beyond
``SWEEP_SMOKE_BUDGET_X`` (default 3x, slack for machine variance) fails
the smoke.  The refreshed record keeps any prior ``--compare-host``
fields, so plain CI runs don't clobber the recorded host-path evidence.

``--compare-host`` additionally times the same sweep with the allocator
forced onto the host (``CMPConfig(allocator_backend="numpy")`` — the PR 1
per-mix Python loop) and records the speedup.  CI skips the comparison to
stay inside its 60 s budget; run it locally when touching the allocator.

    PYTHONPATH=src python -m benchmarks.sweep_smoke [--compare-host]
"""
from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks.common import RESULTS, emit
from repro.core import allocator_calls
from repro.sim import MANAGER_NAMES, random_mixes, run_sweep
from repro.sim.runner import CMPConfig

DEFAULT_MIXES = 32
DEFAULT_TOTAL_MS = 100.0

#: Prior-record fields preserved across runs that skip ``--compare-host``.
HOST_FIELDS = ("host_allocator_calls_host_path", "wall_s_host_alloc",
               "allocator_speedup_warm")


def _prior_record() -> dict:
    path = RESULTS / "sweep_smoke.json"
    if not path.exists():
        return {}
    try:
        return json.loads(path.read_text()).get("derived", {})
    except (ValueError, OSError):
        return {}


def main(n_mixes: int = DEFAULT_MIXES, total_ms: float = DEFAULT_TOTAL_MS,
         compare_host: bool = False) -> None:
    prior = _prior_record()
    mixes = random_mixes(n_mixes, 16, seed=1)

    t0 = time.monotonic()
    before = allocator_calls()
    res = run_sweep(mixes, total_ms=total_ms)
    wall_cold = time.monotonic() - t0
    host_calls = allocator_calls() - before
    # Hard failures, not asserts: this is a CI gate and must still trip
    # under python -O / PYTHONOPTIMIZE.
    if host_calls != 0:
        raise RuntimeError(
            f"device-resident sweep made {host_calls} host allocator calls")
    summary = res.summary()
    if not summary["CBP"] > summary["baseline"]:
        raise RuntimeError(f"CBP does not beat baseline: {summary}")

    # Second run with warm jit caches: the compile-free trajectory metric.
    t0 = time.monotonic()
    run_sweep(mixes, total_ms=total_ms)
    wall_warm = time.monotonic() - t0

    derived = {
        "n_mixes": n_mixes,
        "n_managers": len(MANAGER_NAMES),
        "total_ms": total_ms,
        "host_allocator_calls": host_calls,
        "wall_s_device_alloc_warm": round(wall_warm, 3),
        "wall_s_device_alloc_cold": round(wall_cold, 3),
        "cbp_geomean_ws": summary["CBP"],
    }
    if compare_host:
        cfg = CMPConfig(allocator_backend="numpy")
        t0 = time.monotonic()
        before = allocator_calls()
        run_sweep(mixes, total_ms=total_ms, config=cfg)
        wall_host = time.monotonic() - t0
        derived.update({
            "host_allocator_calls_host_path": allocator_calls() - before,
            "wall_s_host_alloc": round(wall_host, 3),
            "allocator_speedup_warm": round(
                wall_host / max(wall_warm, 1e-9), 2),
        })
    else:
        derived.update({k: prior[k] for k in HOST_FIELDS if k in prior})

    # Trajectory gate BEFORE refreshing the record: a regressed run must
    # not re-baseline the tracked JSON it just failed against.
    budget_x = float(os.environ.get("SWEEP_SMOKE_BUDGET_X", "3.0"))
    prior_warm = prior.get("wall_s_device_alloc_warm")
    comparable = (prior.get("n_mixes") == n_mixes
                  and prior.get("total_ms") == total_ms)
    if prior_warm and comparable and wall_warm > budget_x * prior_warm:
        raise RuntimeError(
            f"sweep wall-time regression: warm {wall_warm:.2f}s vs "
            f"recorded {prior_warm:.2f}s (budget {budget_x}x)")
    # Non-default shapes go to a scratch record so local experiments never
    # clobber the committed 32-mix baseline.
    default_shape = (n_mixes == DEFAULT_MIXES and total_ms == DEFAULT_TOTAL_MS)
    emit("sweep_smoke" if default_shape else "sweep_smoke_custom",
         wall_warm, derived)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mixes", type=int, default=DEFAULT_MIXES)
    ap.add_argument("--total-ms", type=float, default=DEFAULT_TOTAL_MS)
    ap.add_argument("--compare-host", action="store_true")
    args = ap.parse_args()
    main(args.mixes, args.total_ms, args.compare_host)
