"""Device-resident sweep smoke: the CI gate for the stacked timeline path.

Runs an all-manager x many-mix sweep and asserts the contracts that make
sweeps scale:

* ZERO per-mix host allocator calls (counter hook on the numpy
  ``lookahead_allocate``), and
* AT MOST TWO device programs for the whole sweep — the stacked manager
  set runs as ONE program (every Table-3 timeline batched along a
  leading manager axis, ``repro.sim.timeline_jax.run_timelines``) plus
  the shared baseline evaluation, checked with the
  :func:`repro.core.device_dispatches` counter on the warm run.

The sweep runs three times; the jit-warm wall time (min over the two
warm runs — the cold run mostly measures XLA compilation, and the min
de-noises shared-runner interference) is the primary trajectory metric,
checked against the committed ``results/bench/sweep_smoke.json`` record —
a regression beyond ``SWEEP_SMOKE_BUDGET_X`` (default 3x, slack for
machine variance) fails the smoke.  The refreshed record keeps any prior
``--compare-host`` / ``--compare-segment`` fields, so plain CI runs don't
clobber the recorded comparison evidence.

``--compare-fused`` additionally times the per-manager fused path
(``CMPConfig(timeline_backend="fused")``, one program per manager) and
FAILS if the stacked program is slower — the frozen-row-skipping gate.
``--compare-segment`` times the PR 2 per-segment host loop
(``CMPConfig(timeline_backend="segment")``) and records the
fused-timeline speedup.  ``--compare-host`` times the PR 1 configuration
(segment loop + host numpy allocator).  CI skips all three to stay
inside its wall-time budget; run them locally when touching the
timeline or the allocator.

    PYTHONPATH=src python -m benchmarks.sweep_smoke \\
        [--compare-fused] [--compare-segment] [--compare-host]

With ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` the same
smoke exercises the multi-device path: the stacked program shards its
2-D (manager x mix) grid over the N forced host devices via
``repro.distributed.shard_grid`` — the 14 registered managers (the full
policy registry, auction/qos/bank bw included) x 32 mixes on 8 forced
devices factor into a (2, 4) mesh (that is the CI ``shard8`` job).
"""
from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks.common import RESULTS, emit
from repro.core import (
    allocator_calls,
    device_dispatches,
    reset_device_dispatches,
)
from repro.sim import MANAGER_NAMES, random_mixes, run_sweep
from repro.sim.runner import CMPConfig

DEFAULT_MIXES = 32
DEFAULT_TOTAL_MS = 100.0

#: Prior-record fields preserved across runs that skip the comparisons.
HOST_FIELDS = ("host_allocator_calls_host_path", "wall_s_host_alloc",
               "allocator_speedup_warm")
SEGMENT_FIELDS = ("wall_s_segment_timeline", "fused_timeline_speedup_warm")
FUSED_FIELDS = ("wall_s_fused_timeline", "stacked_vs_fused_warm")


def _prior_record() -> dict:
    path = RESULTS / "sweep_smoke.json"
    if not path.exists():
        return {}
    try:
        return json.loads(path.read_text()).get("derived", {})
    except (ValueError, OSError):
        return {}


def main(n_mixes: int = DEFAULT_MIXES, total_ms: float = DEFAULT_TOTAL_MS,
         compare_host: bool = False, compare_segment: bool = False,
         compare_fused: bool = False) -> None:
    prior = _prior_record()
    mixes = random_mixes(n_mixes, 16, seed=1)

    t0 = time.monotonic()
    before = allocator_calls()
    res = run_sweep(mixes, total_ms=total_ms)
    wall_cold = time.monotonic() - t0
    host_calls = allocator_calls() - before
    # Hard failures, not asserts: this is a CI gate and must still trip
    # under python -O / PYTHONOPTIMIZE.
    if host_calls != 0:
        raise RuntimeError(
            f"device-resident sweep made {host_calls} host allocator calls")
    summary = res.summary()
    if not summary["CBP"] > summary["baseline"]:
        raise RuntimeError(f"CBP does not beat baseline: {summary}")

    # Warm-jit runs: the compile-free trajectory metric (min of two), with
    # the dispatch counter checking the stacked-sweep contract (ONE
    # program for the whole manager set + 1 baseline evaluation) on each
    # run.
    wall_warm = float("inf")
    dispatch_budget = 2
    for _ in range(2):
        reset_device_dispatches()
        t0 = time.monotonic()
        run_sweep(mixes, total_ms=total_ms)
        wall_warm = min(wall_warm, time.monotonic() - t0)
        dispatches = device_dispatches()
        if dispatches > dispatch_budget:
            raise RuntimeError(
                f"stacked sweep launched {dispatches} device programs; "
                f"the one-stacked-program-plus-baseline contract allows "
                f"{dispatch_budget}")

    derived = {
        "n_mixes": n_mixes,
        "n_managers": len(MANAGER_NAMES),
        "total_ms": total_ms,
        "host_allocator_calls": host_calls,
        "device_dispatches_warm": dispatches,
        "dispatch_budget": dispatch_budget,
        "wall_s_device_alloc_warm": round(wall_warm, 3),
        "wall_s_device_alloc_cold": round(wall_cold, 3),
        "cbp_geomean_ws": summary["CBP"],
    }
    if compare_fused:
        # Frozen-row-skipping gate: the single stacked program must stay
        # within 5% of the per-manager fused programs it replaced (those
        # get XLA's inter-program overlap for free; the stacked path has
        # to earn the near-tie through bucketed short scans + the unrolled
        # boundary greedy).  The tolerance covers the policy-registry
        # machinery — the wider batched boundary greedy (auction/qos are
        # cache-dynamic) and the per-row registry dispatch — which 11 of
        # the 14 per-manager programs statically elide but the one
        # stacked program must carry for everyone.
        cfg = CMPConfig(timeline_backend="fused")
        run_sweep(mixes, total_ms=total_ms, config=cfg)  # warm its jits
        wall_fused = float("inf")
        for _ in range(6):
            t0 = time.monotonic()
            run_sweep(mixes, total_ms=total_ms, config=cfg)
            wall_fused = min(wall_fused, time.monotonic() - t0)
            t0 = time.monotonic()
            run_sweep(mixes, total_ms=total_ms)
            wall_warm = min(wall_warm, time.monotonic() - t0)
        derived.update({
            "wall_s_fused_timeline": round(wall_fused, 3),
            "stacked_vs_fused_warm": round(
                wall_warm / max(wall_fused, 1e-9), 3),
        })
        derived["wall_s_device_alloc_warm"] = round(wall_warm, 3)
        if wall_warm > 1.05 * wall_fused:
            raise RuntimeError(
                f"stacked sweep slower than per-manager fused: "
                f"{wall_warm:.3f}s vs {wall_fused:.3f}s (5% tolerance)")
    else:
        derived.update({k: prior[k] for k in FUSED_FIELDS if k in prior})
    if compare_segment:
        cfg = CMPConfig(timeline_backend="segment")
        run_sweep(mixes, total_ms=total_ms, config=cfg)  # warm its jits
        wall_seg = float("inf")
        for _ in range(2):
            t0 = time.monotonic()
            run_sweep(mixes, total_ms=total_ms, config=cfg)
            wall_seg = min(wall_seg, time.monotonic() - t0)
        derived.update({
            "wall_s_segment_timeline": round(wall_seg, 3),
            "fused_timeline_speedup_warm": round(
                wall_seg / max(wall_warm, 1e-9), 2),
        })
    else:
        derived.update({k: prior[k] for k in SEGMENT_FIELDS if k in prior})
    if compare_host:
        cfg = CMPConfig(allocator_backend="numpy",
                        timeline_backend="segment")
        t0 = time.monotonic()
        before = allocator_calls()
        run_sweep(mixes, total_ms=total_ms, config=cfg)
        wall_host = time.monotonic() - t0
        derived.update({
            "host_allocator_calls_host_path": allocator_calls() - before,
            "wall_s_host_alloc": round(wall_host, 3),
            "allocator_speedup_warm": round(
                wall_host / max(wall_warm, 1e-9), 2),
        })
    else:
        derived.update({k: prior[k] for k in HOST_FIELDS if k in prior})

    # Trajectory gate BEFORE refreshing the record: a regressed run must
    # not re-baseline the tracked JSON it just failed against.
    budget_x = float(os.environ.get("SWEEP_SMOKE_BUDGET_X", "3.0"))
    prior_warm = prior.get("wall_s_device_alloc_warm")
    comparable = (prior.get("n_mixes") == n_mixes
                  and prior.get("total_ms") == total_ms
                  and prior.get("n_managers") == len(MANAGER_NAMES))
    if prior_warm and comparable and wall_warm > budget_x * prior_warm:
        raise RuntimeError(
            f"sweep wall-time regression: warm {wall_warm:.2f}s vs "
            f"recorded {prior_warm:.2f}s (budget {budget_x}x)")
    # Non-default shapes go to a scratch record so local experiments never
    # clobber the committed 32-mix baseline.
    default_shape = (n_mixes == DEFAULT_MIXES and total_ms == DEFAULT_TOTAL_MS)
    emit("sweep_smoke" if default_shape else "sweep_smoke_custom",
         wall_warm, derived)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mixes", type=int, default=DEFAULT_MIXES)
    ap.add_argument("--total-ms", type=float, default=DEFAULT_TOTAL_MS)
    ap.add_argument("--compare-host", action="store_true")
    ap.add_argument("--compare-segment", action="store_true")
    ap.add_argument("--compare-fused", action="store_true")
    args = ap.parse_args()
    main(args.mixes, args.total_ms, args.compare_host, args.compare_segment,
         args.compare_fused)
