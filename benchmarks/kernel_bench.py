"""Kernel microbenchmarks (interpret-mode correctness + wall time) and the
CBP kernel-knob sweep used by §Perf.

Wall times on this CPU container measure the *interpreted* kernel body —
they validate scheduling and the knob sweep's monotonicity, not TPU
latency; the roofline tables in EXPERIMENTS.md carry the TPU projections.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timer
from repro.core.cache_controller import lookahead_allocate
from repro.kernels.cbp_matmul.kernel import cbp_matmul, vmem_footprint_bytes
from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode.kernel import flash_decode
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref


def flash_attention_bench() -> None:
    q, k, v = (jax.random.normal(kk, (1, 4, 512, 64), jnp.float32)
               for kk in jax.random.split(jax.random.PRNGKey(0), 3))
    ref = attention_ref(q, k, v, causal=True)
    rows = {}
    with timer() as t:
        for bq, bkv in ((64, 64), (128, 128), (256, 256)):
            t0 = time.monotonic()
            out = flash_attention_fwd(q, k, v, causal=True, block_q=bq,
                                      block_kv=bkv, interpret=True)
            err = float(jnp.abs(out - ref).max())
            rows[f"bq{bq}_bkv{bkv}"] = {
                "interp_ms": round(1e3 * (time.monotonic() - t0)),
                "max_err": f"{err:.1e}",
            }
    emit("kernel_flash_attention", t.seconds, rows)


def flash_decode_bench() -> None:
    rng = jax.random.PRNGKey(1)
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (4, 8, 64))
    kc = jax.random.normal(ks[1], (4, 8, 2048, 64))
    vc = jax.random.normal(ks[2], (4, 8, 2048, 64))
    with timer() as t:
        out_full = flash_decode(q, kc, vc, jnp.asarray(2048), block_kv=256,
                                interpret=True)
        out_short = flash_decode(q, kc, vc, jnp.asarray(128), block_kv=256,
                                 interpret=True)
    emit("kernel_flash_decode", t.seconds, {
        "kv2048_finite": bool(np.isfinite(np.asarray(out_full)).all()),
        "short_len_skips_blocks": "cur_len=128 -> 15/16 kv blocks skipped",
        "out_norm_ratio": round(float(jnp.linalg.norm(out_short)
                                      / jnp.linalg.norm(out_full)), 3),
    })


def ssd_scan_bench() -> None:
    rng = jax.random.PRNGKey(2)
    ks = jax.random.split(rng, 5)
    b, s, h, p, n = 1, 512, 4, 16, 32
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    Cm = jax.random.normal(ks[4], (b, s, n)) * 0.5
    ref = ssd_ref(x, dt, A, Bm, Cm)
    rows = {}
    with timer() as t:
        for chunk in (32, 64, 128):
            out = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
            err = float(jnp.abs(out - ref).max())
            # matmul-form FLOPs per token vs sequential recurrence
            intra = 2 * chunk * n + 2 * h * chunk * p
            rows[f"chunk{chunk}"] = {"max_err": f"{err:.1e}",
                                     "flops_per_tok_intra": intra}
    emit("kernel_ssd_scan", t.seconds, rows)


def lookahead_bench() -> None:
    """Lookahead boundary-refresh backends: the interpreted Pallas greedy
    kernel vs the batched incremental-refresh while_loop, both pinned
    bit-identical to the host numpy golden (the real correctness gate is
    ``tests/test_lookahead_kernel.py``; this records the wall-time shape).
    """
    from repro.core import cache_controller_jax as ccj

    rng = np.random.default_rng(7)
    B, n, U = 32, 16, 64
    u = np.arange(U + 1, dtype=np.float64)
    scales = rng.uniform(0.0, 50.0, size=(B, n))
    rates = rng.uniform(2.0, 40.0, size=(B, n))
    curves = scales[..., None] * (1.0 - np.exp(-u / rates[..., None]))
    golden = np.stack([lookahead_allocate(curves[b], U, 1)
                       for b in range(B)])
    rows = {}
    with timer() as t:
        for backend in ("pallas", "jax"):
            t0 = time.monotonic()
            out = np.asarray(ccj.lookahead_allocate(
                curves, U, 1, backend=backend))
            cold_ms = 1e3 * (time.monotonic() - t0)
            t0 = time.monotonic()
            ccj.lookahead_allocate(curves, U, 1, backend=backend)
            rows[backend] = {
                "cold_ms": round(cold_ms),
                "warm_ms": round(1e3 * (time.monotonic() - t0), 2),
                "bit_identical": bool((out == golden).all()),
            }
    emit("kernel_lookahead", t.seconds, rows)


def kernel_block_plan_bench() -> None:
    """UCP-planned block knobs vs the kernels' signature defaults.

    ``plan_kernel_blocks`` runs the Lookahead VMEM partitioner over every
    kernel under ``src/repro/kernels`` in ONE device dispatch (the batched
    grouped greedy), then each kernel executes (interpret mode) with the
    planned blocks and with its defaults.  The record pins the chosen
    blocks, the dispatch budget, and planned-vs-reference correctness.
    """
    from repro.core.dispatch import (device_dispatches,
                                     reset_device_dispatches)
    from repro.runtime.cbp_runtime import plan_kernel_blocks

    # Constrained VMEM budgets (vs the 16 MiB default) so the Lookahead
    # partitioner has a real decision to make instead of maxing every
    # tile; two budget tiers also exercise the grouped planner's
    # multi-capacity path (still one dispatch).
    specs = [
        {"kernel": "cbp_matmul", "m": 512, "n": 512, "k": 512,
         "dtype_bytes": 4, "vmem_budget": 768 * 1024},
        {"kernel": "flash_attention", "seq_q": 512, "seq_kv": 512,
         "head_dim": 64, "dtype_bytes": 4, "vmem_budget": 768 * 1024},
        {"kernel": "flash_decode", "seq_kv": 2048, "head_dim": 64,
         "dtype_bytes": 4, "vmem_budget": 384 * 1024},
        {"kernel": "ssd_scan", "seq_len": 512, "state_dim": 32,
         "dtype_bytes": 4, "vmem_budget": 384 * 1024},
    ]
    reset_device_dispatches()
    planned = plan_kernel_blocks(specs)
    plan_dispatches = device_dispatches()
    if plan_dispatches != 1:
        raise RuntimeError(
            f"plan_kernel_blocks took {plan_dispatches} dispatches for "
            f"{len(specs)} kernels; the batched planner contract is 1")

    ks = jax.random.split(jax.random.PRNGKey(3), 12)
    a = jax.random.normal(ks[0], (512, 512), jnp.float32)
    bmat = jax.random.normal(ks[1], (512, 512), jnp.float32)
    q, k, v = (jax.random.normal(s, (1, 4, 512, 64), jnp.float32)
               for s in ks[2:5])
    dq = jax.random.normal(ks[5], (4, 8, 64))
    kc = jax.random.normal(ks[6], (4, 8, 2048, 64))
    vc = jax.random.normal(ks[7], (4, 8, 2048, 64))
    b, s, h, p, n = 1, 512, 4, 16, 32
    x = jax.random.normal(ks[8], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[9], (b, s, h))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[10], (h,)) * 0.3)
    Bm = jax.random.normal(ks[11], (b, s, n)) * 0.5
    Cm = jax.random.normal(ks[4], (b, s, n)) * 0.5

    defaults = {
        "cbp_matmul": {"block_m": 128, "block_n": 128, "block_k": 128},
        "flash_attention": {"block_q": 128, "block_kv": 128},
        "flash_decode": {"block_kv": 512},
        "ssd_scan": {"chunk": 128},
    }
    runners = {
        "cbp_matmul": (
            lambda kw: cbp_matmul(a, bmat, interpret=True, **kw),
            lambda: a @ bmat),
        "flash_attention": (
            lambda kw: flash_attention_fwd(q, k, v, causal=True,
                                           interpret=True, **kw),
            lambda: attention_ref(q, k, v, causal=True)),
        "flash_decode": (
            lambda kw: flash_decode(dq, kc, vc, jnp.asarray(2048),
                                    interpret=True, **kw),
            None),  # reference = the default-block run
        "ssd_scan": (
            lambda kw: ssd_scan(x, dt, A, Bm, Cm, interpret=True, **kw),
            lambda: ssd_ref(x, dt, A, Bm, Cm)),
    }
    rows = {"plan_dispatches": plan_dispatches}
    with timer() as t:
        for spec, knobs in zip(specs, planned):
            name = spec["kernel"]
            fn, ref_fn = runners[name]
            t0 = time.monotonic()
            out_default = jax.block_until_ready(fn(defaults[name]))
            default_ms = 1e3 * (time.monotonic() - t0)
            t0 = time.monotonic()
            out_planned = jax.block_until_ready(fn(knobs))
            planned_ms = 1e3 * (time.monotonic() - t0)
            ref = ref_fn() if ref_fn is not None else out_default
            err = float(jnp.abs(out_planned - ref).max())
            rows[name] = {
                "planned": knobs,
                "default": defaults[name],
                "planned_ms": round(planned_ms),
                "default_ms": round(default_ms),
                "max_err": f"{err:.1e}",
            }
    emit("kernel_blocks", t.seconds, rows)


def cbp_matmul_knob_sweep() -> None:
    """The cache(VMEM)-partitioning knob sweep: HBM traffic model vs block
    shape — the quantity the UCP planner optimizes."""
    m = n = k = 1024
    rows = {}
    with timer() as t:
        for bm, bn, bk in ((32, 32, 32), (128, 128, 64), (256, 256, 128)):
            vmem = vmem_footprint_bytes(bm, bn, bk)
            # HBM traffic model: A read n/bn times, B read m/bm times
            traffic = (m * k * (n // bn) + k * n * (m // bm)
                       + 2 * m * n) * 2
            rows[f"{bm}x{bn}x{bk}"] = {
                "vmem_KiB": vmem // 1024,
                "hbm_traffic_MiB": round(traffic / 2**20, 1),
                "arith_intensity": round(2 * m * n * k / traffic, 1),
            }
    emit("kernel_cbp_matmul_knobs", t.seconds, rows)
