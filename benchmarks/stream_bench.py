"""Streaming sweep bench: resume-parity gate + pipeline overlap record.

Two modes:

``--smoke`` (CI, both jobs; seconds not minutes) runs a small stream and
HARD-GATES the robustness contracts of :mod:`repro.sim.stream_sweep`:

* **resume parity** — a stream with an injected dispatch failure
  (retried successfully), a NaN-poisoned chunk (quarantined) and a
  mid-run process kill is resumed from its checkpoint and must produce
  final aggregates **bit-identical** to the same-seed uninterrupted run,
  with coverage < 1.0 naming the quarantined chunk;
* **dispatch budget** — exactly 3 recorded device programs per chunk
  (stacked manager set + shared baseline + metrics/finite-guard), so the
  streaming service can never regress to per-mix or per-manager dispatch;
* **overlap sanity** — the double-buffered pipeline must not be slower
  than serial dispatch beyond measurement noise;
* **wall trajectory** — warm wall vs the committed
  ``results/bench/stream_bench.json`` record, slack
  ``STREAM_BENCH_BUDGET_X`` (default 3x; the shard8 CI job widens it).

The default (full) mode is the scale record behind ROADMAP item 3: a
10^5-mix zipf/diurnal/phase-drift stream through the double-buffered
pipeline, plus a serial-dispatch run of the same shape over a sub-stream
for the per-chunk overlap margin.  It records end-to-end wall, per-chunk
walls, the overlap speedup, peak RSS (the stream must hold aggregates —
a few KB of sketches — not rows) and the CBP-vs-baseline geomean.  Full
records refresh the smoke's prior-record fields, not replace them.

    PYTHONPATH=src python -m benchmarks.stream_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import tempfile
import time

import numpy as np

from benchmarks.common import RESULTS, emit
from repro.core import device_dispatches
from repro.runtime.faultinject import (
    FaultPlan,
    FaultSpec,
    InjectedProcessKill,
)
from repro.sim.stream_sweep import StreamConfig, run_stream
from repro.sim.workloads import StreamScenario

#: Fields owned by the full-scale run, preserved across smoke refreshes.
FULL_FIELDS = ("full_n_mixes", "full_chunk_size", "full_wall_s",
               "full_mixes_per_s", "full_overlap_speedup",
               "full_serial_chunk_s", "full_overlap_chunk_s", "full_cores",
               "full_peak_rss_mb", "full_cbp_geomean_ws", "full_coverage")

_NO_SLEEP = lambda s: None  # noqa: E731


def _prior() -> dict:
    path = RESULTS / "stream_bench.json"
    if not path.exists():
        return {}
    try:
        return json.loads(path.read_text()).get("derived", {})
    except (ValueError, OSError):
        return {}


def _trees_equal(a, b) -> bool:
    ta, tb = a.aggregates.to_tree(), b.aggregates.to_tree()
    return all(np.array_equal(ta[k], tb[k], equal_nan=True) for k in ta)


def _smoke_cfg(**kw) -> StreamConfig:
    base = dict(n_mixes=64, chunk_size=16, managers=("baseline", "CBP"),
                total_ms=20.0, seed=11,
                scenario=StreamScenario(popularity="zipf",
                                        phase_app_fraction=0.25))
    base.update(kw)
    return StreamConfig(**base)


def resume_parity_gate() -> dict:
    """The acceptance gate: >=1 retried dispatch failure, >=1 quarantined
    NaN chunk, 1 mid-run kill + resume -> bit-identical final aggregates
    vs the same-seed uninterrupted run, coverage < 1.0 naming the chunk."""
    plan = FaultPlan((FaultSpec("dispatch_error", 0, count=1),
                      FaultSpec("nan_poison", 1),
                      FaultSpec("kill", 2)))
    with tempfile.TemporaryDirectory() as tmp:
        cfg = _smoke_cfg(checkpoint_dir=os.path.join(tmp, "ck"),
                         checkpoint_every=1)
        try:
            run_stream(cfg, fault_plan=plan, sleep_fn=_NO_SLEEP)
            raise RuntimeError("injected kill did not fire")
        except InjectedProcessKill:
            pass
        resumed = run_stream(cfg, fault_plan=plan.without_kills(),
                             resume=True, sleep_fn=_NO_SLEEP)
    clean = run_stream(_smoke_cfg(), fault_plan=plan.without_kills(),
                       sleep_fn=_NO_SLEEP)
    if resumed.resumed_from is None:
        raise RuntimeError("resume did not restore from a checkpoint")
    if not _trees_equal(resumed, clean):
        raise RuntimeError(
            "resumed aggregates differ from uninterrupted run — the "
            "bit-identical resume contract is broken")
    if resumed.retries < 1:
        raise RuntimeError("injected dispatch failure was never retried")
    quarantined = [c for c, _ in resumed.quarantined]
    if quarantined != [1] or resumed.coverage >= 1.0:
        raise RuntimeError(
            f"expected chunk 1 quarantined with coverage < 1, got "
            f"chunks {quarantined} at coverage {resumed.coverage}")
    if "mix" not in resumed.quarantined[0][1]:
        raise RuntimeError(
            f"quarantine reason does not name the offending mix: "
            f"{resumed.quarantined[0][1]!r}")
    return {
        "parity_retries": resumed.retries,
        "parity_quarantined_chunks": quarantined,
        "parity_coverage": round(resumed.coverage, 4),
        "parity_resumed_from_chunk": resumed.resumed_from,
    }


def smoke() -> None:
    prior = _prior()
    parity = resume_parity_gate()

    cfg = _smoke_cfg()
    run_stream(cfg)  # jit warm-up (compile dominates the cold run)
    before = device_dispatches()
    t0 = time.monotonic()
    r_overlap = run_stream(cfg, overlap=True)
    wall_overlap = time.monotonic() - t0
    dispatches = device_dispatches() - before
    budget = 3 * cfg.n_chunks
    if dispatches != budget:
        raise RuntimeError(
            f"stream launched {dispatches} device programs for "
            f"{cfg.n_chunks} chunks; the 3-per-chunk contract allows "
            f"{budget}")
    t0 = time.monotonic()
    r_serial = run_stream(cfg, overlap=False)
    wall_serial = time.monotonic() - t0
    if not _trees_equal(r_overlap, r_serial):
        raise RuntimeError("overlap and serial aggregates differ")
    if r_overlap.geomean_ws["CBP"] <= r_overlap.geomean_ws["baseline"]:
        raise RuntimeError(
            f"CBP does not beat baseline: {r_overlap.geomean_ws}")
    if wall_overlap > 1.5 * wall_serial:
        raise RuntimeError(
            f"double-buffered pipeline slower than serial beyond noise: "
            f"{wall_overlap:.3f}s vs {wall_serial:.3f}s")

    derived = {
        "n_mixes": cfg.n_mixes, "chunk_size": cfg.chunk_size,
        "n_managers": len(cfg.manager_names),
        "device_dispatches_warm": dispatches,
        "dispatch_budget": budget,
        "wall_s_overlap_warm": round(wall_overlap, 3),
        "wall_s_serial_warm": round(wall_serial, 3),
        "cbp_geomean_ws": r_overlap.geomean_ws["CBP"],
        "coverage": r_overlap.coverage,
        **parity,
    }
    derived.update({k: prior[k] for k in FULL_FIELDS if k in prior})

    budget_x = float(os.environ.get("STREAM_BENCH_BUDGET_X", "3.0"))
    prior_warm = prior.get("wall_s_overlap_warm")
    comparable = (prior.get("n_mixes") == cfg.n_mixes
                  and prior.get("chunk_size") == cfg.chunk_size)
    if prior_warm and comparable and wall_overlap > budget_x * prior_warm:
        raise RuntimeError(
            f"stream wall regression: warm {wall_overlap:.2f}s vs "
            f"recorded {prior_warm:.2f}s (budget {budget_x}x)")
    emit("stream_bench", wall_overlap, derived)


def full(n_mixes: int = 100_000, chunk_size: int = 512,
         serial_chunks: int = 12) -> None:
    """The 10^5-mix scale record: bounded memory, overlap margin."""
    prior = _prior()
    scenario = StreamScenario(popularity="zipf", diurnal_period_chunks=24,
                              phase_app_fraction=0.25)
    cfg = StreamConfig(n_mixes=n_mixes, chunk_size=chunk_size,
                       managers=("baseline", "CBP"), total_ms=50.0,
                       seed=11, scenario=scenario)
    # Serial reference on a sub-stream of identical chunk shape (the full
    # serial run would double the bench wall for no extra information);
    # per-chunk walls are compared warm-vs-warm.
    sub = StreamConfig(n_mixes=serial_chunks * chunk_size,
                       chunk_size=chunk_size, managers=("baseline", "CBP"),
                       total_ms=50.0, seed=11, scenario=scenario)
    run_stream(sub, overlap=False)  # compile warm-up
    t0 = time.monotonic()
    run_stream(sub, overlap=False)
    serial_chunk_s = (time.monotonic() - t0) / sub.n_chunks

    t0 = time.monotonic()
    report = run_stream(cfg, overlap=True)
    wall = time.monotonic() - t0
    overlap_chunk_s = wall / cfg.n_chunks
    peak_rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024
    overlap_speedup = serial_chunk_s / overlap_chunk_s

    if report.coverage != 1.0:
        raise RuntimeError(
            f"healthy full stream lost coverage: {report.coverage} "
            f"(quarantined {report.quarantined})")
    # The double-buffered pipeline hides HOST work (chunk generation,
    # aggregate folds, checkpoint writes) behind device compute.  On the
    # CPU backend with a single core there is no spare core to hide it
    # on — device "compute" and host generation time-slice the same CPU
    # — so the best possible outcome is a tie; the gate then only
    # enforces no-regression (the pipeline must not cost wall time).
    # With >1 core the margin must be real.
    cores = os.cpu_count() or 1
    floor = 1.0 if cores > 1 else 0.95
    if overlap_speedup <= floor:
        raise RuntimeError(
            f"double buffering does not beat serial dispatch "
            f"(floor {floor} at {cores} cores): "
            f"{serial_chunk_s * 1e3:.1f} ms/chunk serial vs "
            f"{overlap_chunk_s * 1e3:.1f} ms/chunk overlapped")

    derived = dict(prior)
    derived.update({
        "full_n_mixes": n_mixes,
        "full_chunk_size": chunk_size,
        "full_wall_s": round(wall, 1),
        "full_mixes_per_s": round(n_mixes / wall, 1),
        "full_overlap_speedup": round(overlap_speedup, 3),
        "full_serial_chunk_s": round(serial_chunk_s, 4),
        "full_overlap_chunk_s": round(overlap_chunk_s, 4),
        "full_cores": cores,
        "full_peak_rss_mb": round(peak_rss_mb, 1),
        "full_cbp_geomean_ws": report.geomean_ws["CBP"],
        "full_coverage": report.coverage,
    })
    emit("stream_bench", wall, derived)


def main(smoke_mode: bool, n_mixes: int = 100_000,
         chunk_size: int = 512) -> None:
    if smoke_mode:
        smoke()
    else:
        full(n_mixes, chunk_size)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mixes", type=int, default=100_000)
    ap.add_argument("--chunk-size", type=int, default=512)
    args = ap.parse_args()
    main(args.smoke, args.mixes, args.chunk_size)
