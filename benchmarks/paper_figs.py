"""Benchmarks reproducing every CBP paper figure (Figs. 1-5, 9-12).

Each function prints one or more ``name,us_per_call,derived`` rows and
persists JSON under results/bench/ for EXPERIMENTS.md.
"""
from __future__ import annotations

import itertools
from typing import Dict, List

import numpy as np

from benchmarks.common import emit, timer
from repro.core.types import CBPParams
from repro.sim import (
    MANAGER_NAMES,
    WORKLOADS,
    baseline_ipc,
    equal_share,
    evaluate,
    run_all_managers,
    run_sweep,
    stack,
    weighted_speedup,
)
from repro.sim.static_search import FIG5_TWO_RESOURCE, search_static
from repro.sim.apps import EXPECTED_CLASS_COUNTS
from repro.sim.characterization import (
    classify_all,
    leslie3d_interactions,
    prefetch_vs_allocation,
    sensitivity_table,
)
from repro.sim.runner import CMPConfig
from repro.sim.workloads import random_workloads

PAPER_GEOMEANS = {
    "equal off": 1.10, "only cache": 1.28, "only bw": 1.04,
    "only pref": 1.09, "bw+pref": 1.10, "bw+cache": 1.37,
    "cache+pref": 1.39, "CPpf": 1.39, "CBP": 1.50,
}


def fig1_motivation() -> None:
    """Two-app motivating example (lbm + xalancbmk)."""
    with timer() as t:
        from repro.sim.runner import CMPConfig
        apps = ["lbm", "xalancbmk"]
        # Paper Fig. 1 setup: 2 MB total cache, 16 GB/s total bandwidth.
        cfgF = CMPConfig(total_cache_units=64, total_bandwidth=16.0)
        base = baseline_ipc(apps, cfgF)
        res = run_all_managers(apps, total_ms=100.0, config=cfgF)
        ws = {m: weighted_speedup(res[m].ipc, base) for m in MANAGER_NAMES}
        pairs = max(ws["bw+pref"], ws["bw+cache"], ws["cache+pref"])
    emit("fig1_motivation", t.seconds, {
        "cbp": round(ws["CBP"], 3),
        "best_pair": round(pairs, 3),
        "cbp_gain_over_best_pair": round(ws["CBP"] / pairs - 1, 3),
        "paper_gain": 0.15,
    })


def fig2_characterization() -> None:
    """29-app sensitivity classification."""
    with timer() as t:
        classes = classify_all()
        counts: Dict[str, int] = {}
        for c in classes.values():
            counts[c] = counts.get(c, 0) + 1
        tab = sensitivity_table()
        n = len(classes)
        sens = sum(1 for c in classes.values() if c != "I") / n
        multi = sum(1 for c in classes.values() if "-" in c) / n
        max_c = max(max(abs(r["C-L"]), abs(r["C-H"]))
                    for r in tab.values())
        max_b = max(max(abs(r["B-L"]), abs(r["B-H"]))
                    for r in tab.values())
    emit("fig2_characterization", t.seconds, {
        "counts_match_paper": counts == EXPECTED_CLASS_COUNTS,
        "counts": counts,
        "frac_sensitive": round(sens, 2),
        "frac_multi_sensitive": round(multi, 2),
        "paper": "0.90 / 0.70",
        "max_cache_effect": round(max_c, 2),
        "max_bw_effect": round(max_b, 2),
    })


def fig3_prefetch_alloc() -> None:
    """Prefetch sensitivity vs cache/bw allocation (hmmer, gcc)."""
    with timer() as t:
        hm = prefetch_vs_allocation("hmmer")
        gc = prefetch_vs_allocation("gcc")
    emit("fig3_prefetch_alloc", t.seconds, {
        "hmmer_P-L": round(hm["P-L"], 3), "hmmer_P-B": round(hm["P-B"], 3),
        "hmmer_low_alloc_sensitive": hm["P-L"] >= 0.10 > hm["P-B"],
        "gcc_P-L": round(gc["P-L"], 3), "gcc_P-H": round(gc["P-H"], 3),
        "gcc_high_alloc_sensitive": gc["P-H"] > gc["P-L"],
    })


def fig4_leslie3d() -> None:
    """leslie3d pairwise interactions (observations 3-5)."""
    with timer() as t:
        r = leslie3d_interactions()
        obs3 = (r["fig4a"]["on"][-1] / r["fig4a"]["off"][-1]
                > r["fig4a"]["on"][0] / r["fig4a"]["off"][0])
        obs4 = r["fig4c"]["on"][0] >= 0.95 * r["fig4c"]["off"][2]
        obs5 = r["fig4d"]["gain"][0] > r["fig4d"]["gain"][-1]
    emit("fig4_leslie3d", t.seconds, {
        "obs3_bw_compensates_prefetch": bool(obs3),
        "obs4_cache_prefetch_tradeoff": bool(obs4),
        "obs5_cache_gain_higher_at_low_bw": bool(obs5),
        "gain_2MB_at_1GBs": round(r["fig4d"]["gain"][0], 3),
        "gain_2MB_at_16GBs": round(r["fig4d"]["gain"][-1], 3),
    })


def _exhaustive_best(apps: List[str], manage_cache: bool, manage_bw: bool,
                     manage_pf: bool, pf_all_on: bool = False) -> float:
    """Paper Fig. 5 protocol: best static allocation via exhaustive search
    over cache {256k,512k,1M}, bw {2,4,6} GB/s, pf {off,on} per app.

    This is the numpy GOLDEN REFERENCE for the batched device search
    (:func:`repro.sim.static_search.search_static`, the path
    :func:`fig5_potential` actually runs on): one vectorized host solve
    per (workload, family).  ``tests/test_static_search.py`` pins the
    batched search to it within 1e-5; change this first, then the
    batched side.
    """
    arr = stack(apps)
    n = len(apps)
    cache_opts = [(8, 16, 32) if manage_cache else (16,)] * n
    bw_opts = [(2.0, 4.0, 6.0) if manage_bw else (4.0,)] * n
    pf_opts = [((False, True) if manage_pf else
                ((True,) if pf_all_on else (False,)))] * n

    caches = [c for c in itertools.product(*cache_opts)
              if sum(c) <= 16 * n]
    bws = [b for b in itertools.product(*bw_opts) if sum(b) <= 4.0 * n]
    pfs = list(itertools.product(*pf_opts))
    combos = [(c, b, p) for c in caches for b in bws for p in pfs]
    cache_arr = np.array([c for c, _, _ in combos], dtype=np.float64)
    bw_arr = np.array([b for _, b, _ in combos], dtype=np.float64)
    pf_arr = np.array([p for _, _, p in combos], dtype=np.float64)
    ss = evaluate(arr, cache_arr, bw_arr, pf_arr,
                  total_cache_units=16.0 * n, total_bandwidth_gbps=4.0 * n,
                  iters=40)
    units_eq, bw_eq = equal_share(n, 16 * n, 4.0 * n)
    base = evaluate(arr, units_eq.astype(np.float64), bw_eq,
                    np.zeros(n), total_cache_units=16.0 * n,
                    total_bandwidth_gbps=4.0 * n, iters=40,
                    cache_partitioned=True, bandwidth_partitioned=True)
    ws = np.mean(ss.ipc / base.ipc, axis=-1)
    return float(ws.max())


def fig5_potential(n_workloads: int = 640,
                   backend: str = "jax") -> Dict[str, object]:
    """Potential study: exhaustive search over 4-app random workloads.

    Runs on the batched static-search subsystem
    (:mod:`repro.sim.static_search`): every manager family is ONE device
    program scanning its whole config grid over all workloads, plus one
    shared baseline evaluation — instead of the old host loop of one
    numpy solve per (workload, family).  ``backend="numpy"`` keeps the
    vectorized host reference path.
    """
    with timer() as t:
        wls = random_workloads(n_workloads, 4, seed=7)
        res = search_static(wls, backend=backend)
        geo = {name: res.geomean(name) for name in res.family_names}
        frac10 = {name: res.frac_at_least(name, 1.10)
                  for name in res.family_names}
        best_two = max(geo[f] for f in FIG5_TWO_RESOURCE)
    derived = {
        "n_workloads": n_workloads,
        "backend": backend,
        **{f"geo_{k}": round(v, 3) for k, v in geo.items()},
        "all3_vs_best2": round(geo["cache+bw+pref"] / best_two - 1, 3),
        "paper_all3_vs_best2": 0.05,
        **{f"frac10_{k}": round(v, 2) for k, v in frac10.items()},
        "paper_frac10_all3": 0.90,
    }
    emit("fig5_potential", t.seconds, derived)
    return derived


def fig9_fig10_main(total_ms: float = 100.0) -> Dict[str, Dict[str, float]]:
    """Main evaluation: weighted speedup + ANTT, w1..w14 x all managers.

    Runs on the batched sweep substrate (``repro.sim.sweep``): all 14 mixes
    are evaluated per manager in single jitted device calls.
    """
    per_wl: Dict[str, Dict[str, float]] = {}
    with timer() as t:
        wnames = list(WORKLOADS)
        res = run_sweep([WORKLOADS[w] for w in wnames], total_ms=total_ms)
        ws = {m: res.weighted_speedup(m) for m in MANAGER_NAMES}   # (14,)
        per_wl = {
            w: {m: round(float(ws[m][i]), 4) for m in MANAGER_NAMES}
            for i, w in enumerate(wnames)
        }
        geo = {m: float(np.exp(np.mean(np.log(ws[m])))) for m in MANAGER_NAMES}
        geo_antt = {m: float(np.exp(np.mean(np.log(res.antt(m)))))
                    for m in MANAGER_NAMES}
        cbp = ws["CBP"]
        best2 = np.max([ws[m]
                        for m in ("bw+pref", "bw+cache", "cache+pref",
                                  "CPpf")], axis=0)
    emit("fig9_weighted_speedup", t.seconds, {
        **{f"geo_{m.replace(' ', '_')}": round(geo[m], 3)
           for m in MANAGER_NAMES},
        "cbp_vs_best_two_geo": round(
            float(np.exp(np.mean(np.log(cbp / best2)))) - 1, 3),
        "paper_cbp_vs_best_two": 0.11,
        "cbp_max": round(float(cbp.max()), 3),
        "paper_cbp": "geo 1.50, max 1.86",
        "cbp_best_in_n_of_14": int(np.sum(cbp >= best2 - 1e-9)),
        "per_workload": per_wl,
    })
    emit("fig10_antt", 0.0, {
        **{f"antt_{m.replace(' ', '_')}": round(geo_antt[m], 3)
           for m in MANAGER_NAMES},
        "paper_cbp_antt_gain": 0.27,
        "cbp_antt_gain": round(1 - geo_antt["CBP"], 3),
    })
    return per_wl


def fig11_case_study() -> None:
    """w2 per-application IPC under the main managers (sweep substrate)."""
    with timer() as t:
        apps = WORKLOADS["w2"]
        managers = ["bw+cache", "cache+pref", "CBP"]
        res = run_sweep([apps], managers=managers, total_ms=100.0)
        base = res.baseline_ipc[0]
        ipc = {m: res.ipc[m][0] for m in managers}
        rows = {}
        for i, name in enumerate(apps):
            rows[f"{i}:{name}"] = {
                m: round(float(ipc[m][i] / base[i]), 3)
                for m in managers
            }
        # group-1 apps prefer cache+pref; group-2 prefer bw+cache; CBP
        # should track the better of the two for most apps.
        better = 0
        for i in range(len(apps)):
            target = max(ipc["bw+cache"][i], ipc["cache+pref"][i])
            if ipc["CBP"][i] >= 0.9 * target:
                better += 1
    emit("fig11_case_study_w2", t.seconds, {
        "apps_where_cbp_within_10pct_of_best_pair": f"{better}/16",
        "per_app": rows,
    })


def fig12_sensitivity() -> None:
    """Design-parameter sensitivity: reconfiguration interval, cache size,
    min-bandwidth, prefetch sampling period.

    Each parameter family is ONE ``run_sweep(param_grid=...)`` call: the
    CBPParams axis batches on device (same-schedule params share a single
    batch; schedule-distinct ones run as separate batches of the same
    sweep).  Only the cache-size axis needs separate calls, because it
    changes ``CMPConfig`` (model capacity), not ``CBPParams``.
    """
    apps = WORKLOADS["w1"]

    def cbp_ws(grid: List[CBPParams], cache_units: int = 256,
               llc_extra: float = 0.0) -> List[float]:
        cfgS = CMPConfig(total_cache_units=cache_units,
                         llc_extra_cycles=llc_extra)
        res = run_sweep([apps], managers=["CBP"], total_ms=100.0,
                        param_grid=grid, config=cfgS)
        ws = np.asarray(res.weighted_speedup("CBP"))[:, 0]
        return [round(float(x), 3) for x in ws]

    with timer() as t:
        ivals = (1.0, 10.0, 100.0)
        interval = dict(zip(
            (f"{ms}ms" for ms in ivals),
            cbp_ws([CBPParams(reconfiguration_interval_ms=ms,
                              prefetch_interval_ms=ms) for ms in ivals])))
        cache = {
            "512kB_tile": cbp_ws([CBPParams()])[0],
            # 1 MB tiles: double capacity, +4 cycles LLC latency (CACTI)
            "1MB_tile": cbp_ws([CBPParams()], cache_units=512,
                               llc_extra=4.0)[0],
        }
        mbs = (0.5, 1.0)
        minbw = dict(zip(
            (f"{mb}GBs" for mb in mbs),
            cbp_ws([CBPParams(min_bandwidth_allocation=mb) for mb in mbs])))
        sps = (0.25, 0.5, 1.0)
        sampling = dict(zip(
            (f"{sp}ms" for sp in sps),
            cbp_ws([CBPParams(prefetch_sampling_period_ms=sp)
                    for sp in sps])))
    emit("fig12_sensitivity", t.seconds, {
        "reconfig_interval": interval,
        "paper_interval": "10ms best trade-off",
        "cache_size": cache,
        "min_bandwidth": minbw,
        "pf_sampling": sampling,
        "paper_sampling": "0.5ms best",
    })
