"""Benchmark harness — one entry per paper table/figure + the roofline and
kernel benches.  Prints ``name,us_per_call,derived`` CSV rows.

Each bench runs under a wall timeout (``--bench-timeout``, SIGALRM): a
hung bench fails with a named culprit instead of stalling the whole
harness until the CI job's global timeout reaps it anonymously.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
      [--bench-timeout SECONDS]
"""
from __future__ import annotations

import argparse
import signal
import sys


class BenchTimeout(RuntimeError):
    """A bench exceeded its wall budget."""


def _run_with_timeout(name: str, fn, seconds: int) -> None:
    if seconds <= 0 or not hasattr(signal, "SIGALRM"):
        fn()
        return

    def _alarm(signum, frame):
        raise BenchTimeout(
            f"bench {name!r} exceeded its {seconds}s wall timeout")

    prev_handler = signal.signal(signal.SIGALRM, _alarm)
    signal.alarm(seconds)
    try:
        fn()
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, prev_handler)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="subsample fig5's 640 workloads to 64")
    ap.add_argument("--only", default=None)
    ap.add_argument("--bench-timeout", type=int, default=1800,
                    help="per-bench wall timeout in seconds "
                         "(0 disables; default 1800)")
    args = ap.parse_args()

    from benchmarks import (
        fig5_smoke,
        kernel_bench,
        paper_figs,
        roofline_report,
        runtime_bench,
        scenario_report,
        serving_bench,
        stream_bench,
    )

    benches = {
        "fig1": paper_figs.fig1_motivation,
        "fig2": paper_figs.fig2_characterization,
        "fig3": paper_figs.fig3_prefetch_alloc,
        "fig4": paper_figs.fig4_leslie3d,
        # fig5 runs on the batched static-search subsystem (one device
        # program per manager family — repro.sim.static_search).
        "fig5": (lambda: paper_figs.fig5_potential(
            64 if args.quick else 640)),
        "fig5_smoke": fig5_smoke.main,
        # serving engine: device-resident continuous batching vs the host
        # loop; --quick runs the CI smoke shape, default the 256-4096
        # slot sweep with the >= 5x acceptance gate at >= 256 slots.
        "serving_bench": (lambda: serving_bench.main(
            serving_bench.SMOKE_SLOTS if args.quick
            else serving_bench.DEFAULT_SLOTS,
            groups=1, smoke=args.quick, compare_host_all=False)),
        # streaming sweep service: --quick runs the CI smoke (resume
        # parity + dispatch budget), default the 10^5-mix scale record.
        "stream_bench": (lambda: stream_bench.main(smoke_mode=args.quick)),
        # runtime bindings: fused TrainingPlant one-dispatch + bit-parity
        # vs the host coordinator, batched block-planner parity; default
        # adds the 400 ms / 12-client scale record.
        "runtime_bench": (lambda: runtime_bench.main(smoke_mode=args.quick)),
        "fig9_10": paper_figs.fig9_fig10_main,
        "fig11": paper_figs.fig11_case_study,
        "fig12": paper_figs.fig12_sensitivity,
        "scenario_diversity": (lambda: scenario_report.scenario_diversity(
            8 if args.quick else 32)),
        "kernel_flash_attention": kernel_bench.flash_attention_bench,
        "kernel_flash_decode": kernel_bench.flash_decode_bench,
        "kernel_ssd_scan": kernel_bench.ssd_scan_bench,
        "kernel_cbp_matmul": kernel_bench.cbp_matmul_knob_sweep,
        "kernel_blocks": kernel_bench.kernel_block_plan_bench,
        "kernel_lookahead": kernel_bench.lookahead_bench,
        "roofline": roofline_report.roofline_report,
    }
    selected = {name: fn for name, fn in benches.items()
                if not args.only or args.only in name}
    if not selected:
        # A typo'd --only used to print the CSV header and exit 0 — green
        # CI with zero benches run.  Fail loudly with the valid names.
        sys.exit(f"--only {args.only!r} matches no bench; known benches: "
                 + ", ".join(benches))
    failed = []
    print("name,us_per_call,derived")
    for name, fn in selected.items():
        try:
            _run_with_timeout(name, fn, args.bench_timeout)
        except Exception as exc:  # noqa: BLE001
            failed.append(name)
            print(f"{name},0,ERROR={type(exc).__name__}:{exc}",
                  flush=True)
    if failed:
        # The ERROR rows keep the CSV parseable, but a broken bench must
        # not exit 0 — CI reads the exit code, not the rows.
        sys.exit(f"{len(failed)} bench(es) errored: {', '.join(failed)}")


if __name__ == "__main__":
    main()
