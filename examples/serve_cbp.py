"""End-to-end driver: serve a small LM with batched requests under CBP
management (the paper's technique bound to the TPU-serving substrate).

Two tenant streams share one KV-page pool and a fixed decode batch:
  * stream 0 ("chatbot"): many requests over a shared hot prefix — high
    page reuse (cache-sensitive, like xalancbmk);
  * stream 1 ("batch scorer"): long streaming prompts, no reuse
    (bandwidth-hungry, like lbm).

CBP partitions the pool with UCP over measured stack-distance curves,
allocates decode slots by queue delay (Algorithm 1), and throttles KV
readahead (Algorithm 2).  Compare the hit rates and partitions printed at
the end with an unmanaged run (--no-cbp: static equal partition).

  PYTHONPATH=src python examples/serve_cbp.py [--no-cbp]
"""
import argparse

import jax
import numpy as np

from repro import configs
from repro.models import build
from repro.serving import EngineConfig, Request, ServingEngine


def make_requests(n_per_stream: int = 8):
    reqs = []
    rng = np.random.default_rng(0)
    for i in range(n_per_stream):
        # chatbot: shared 6-token system prefix + short turn
        prompt = np.concatenate([np.arange(6), rng.integers(6, 60, 4)])
        reqs.append(Request(stream=0, prompt=prompt.astype(np.int32),
                            max_new_tokens=6))
        # scorer: long unique prompt
        prompt = rng.integers(0, 500, 24)
        reqs.append(Request(stream=1, prompt=prompt.astype(np.int32),
                            max_new_tokens=2))
    return reqs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-cbp", action="store_true",
                    help="static equal partition, no reconfiguration")
    args = ap.parse_args()

    cfg = configs.get_smoke("qwen3-8b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(batch_slots=4, max_len=64, total_pages=24,
                        page_tokens=4,
                        reconfig_every_steps=(10 ** 9 if args.no_cbp
                                              else 16))
    engine = ServingEngine(model, params, n_streams=2, cfg=ecfg)
    reqs = make_requests()
    engine.run(reqs, max_steps=2000)

    print(f"CBP managed: {not args.no_cbp} "
          f"(reconfigurations: {engine.reconfigs})")
    for s in range(2):
        st = engine.pool.stats[s]
        print(f"stream {s}: partition={int(engine.pool.partition[s]):3d} "
              f"pages  hit-rate={st.hit_rate:5.1%}  "
              f"evictions={st.evictions}  "
              f"slot-share={engine.slot_share[s]:.2f}")
    done = sum(1 for r in reqs if r.generated is not None)
    print(f"requests completed: {done}/{len(reqs)}, "
          f"decode steps: {engine.steps}")


if __name__ == "__main__":
    main()
