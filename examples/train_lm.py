"""Train a small LM end-to-end with the full production loop:
checkpoint/restart, straggler watchdog, CBP-managed prefetch, grad accum.

The default (CPU) run trains the reduced qwen3-8b family config for 120
steps and demonstrates a mid-run restart from checkpoint.  On a TPU pod
the same loop takes ``--full`` + the production mesh (the dry-run proves
every (arch x shape) compiles there).

  PYTHONPATH=src python examples/train_lm.py --arch qwen3-8b --steps 120
"""
import argparse
import pathlib
import shutil
import tempfile

from repro import configs
from repro.launch.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=configs.names())
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=2)
    args = ap.parse_args()

    ckpt = pathlib.Path(tempfile.mkdtemp(prefix="repro_ckpt_"))
    try:
        print(f"== phase 1: train to step {args.steps // 2} ==")
        out1 = train_loop(
            args.arch, steps=args.steps // 2, batch=args.batch,
            seq=args.seq, microbatches=args.microbatches,
            ckpt_dir=ckpt, ckpt_every=args.steps // 4)
        print(f"== phase 2: simulated crash; restart from checkpoint ==")
        out2 = train_loop(
            args.arch, steps=args.steps, batch=args.batch,
            seq=args.seq, microbatches=args.microbatches,
            ckpt_dir=ckpt, ckpt_every=args.steps // 4)
        print(f"phase-1 final loss {out1['final_loss']:.4f}  ->  "
              f"phase-2 final loss {out2['final_loss']:.4f}")
        assert out2["final_loss"] < out1["losses"][0], "loss did not drop"
        print("training resumed from checkpoint and loss decreased: OK")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
