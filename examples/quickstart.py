"""Quickstart: the CBP resource manager on the paper's own substrate.

Runs the Fig. 1 motivating workload (lbm + xalancbmk) under every Table-3
resource manager and prints the weighted speedups — the 60-second tour of
the reproduction.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.sim import (
    MANAGER_NAMES, baseline_ipc, run_all_managers, weighted_speedup,
)
from repro.sim.runner import CMPConfig

WORKLOAD = ["lbm", "xalancbmk"]
# Paper Fig. 1 setup: 2 MB total LLC, 16 GB/s total bandwidth.
CONFIG = CMPConfig(total_cache_units=64, total_bandwidth=16.0)


def main() -> None:
    base = baseline_ipc(WORKLOAD, CONFIG)
    print(f"workload: {WORKLOAD}  baseline IPC: {np.round(base, 3)}")
    results = run_all_managers(WORKLOAD, total_ms=100.0, config=CONFIG)
    print(f"{'manager':12s} {'w-speedup':>9s}   notes")
    for name in MANAGER_NAMES:
        res = results[name]
        ws = weighted_speedup(res.ipc, base)
        note = ""
        if name == "CBP":
            a = res.final_alloc
            note = (f"cache={a.cache_units.tolist()} pages, "
                    f"bw={np.round(a.bandwidth, 1).tolist()} GB/s, "
                    f"pf={a.prefetch_on.tolist()}")
        print(f"{name:12s} {ws:9.3f}   {note}")
    print("\nPaper Fig. 1: managing all three knobs beats any pair; "
          "xalancbmk gets the cache, lbm gets bandwidth + prefetching.")


if __name__ == "__main__":
    main()
