"""CBP at the kernel level: UCP-planned VMEM partitioning for a Pallas
matmul, plus the flash-attention block-size knobs.

Shows the paper's cache-partitioning algorithm picking (block_m, block_n,
block_k) under a VMEM budget, and that the knobs change scheduling/VMEM
footprint but never results.

  PYTHONPATH=src python examples/kernel_knobs.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cbp_matmul.kernel import cbp_matmul, vmem_footprint_bytes
from repro.kernels.cbp_matmul.ref import matmul_ref
from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref
from repro.runtime import plan_matmul_blocks


def main() -> None:
    m = n = k = 512
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    ref = matmul_ref(a, b)

    print("UCP-planned VMEM partitions for (512,512)@(512,512):")
    for budget_mb in (1, 4, 16):
        bm, bn, bk = plan_matmul_blocks(m, n, k,
                                        vmem_budget=budget_mb << 20)
        out = cbp_matmul(a, b, block_m=bm, block_n=bn, block_k=bk,
                         interpret=True)
        err = float(jnp.abs(out - ref).max())
        print(f"  budget {budget_mb:3d}MiB -> blocks ({bm},{bn},{bk})  "
              f"VMEM {vmem_footprint_bytes(bm, bn, bk)/2**20:.2f}MiB  "
              f"max|err| {err:.1e}")

    print("\nflash-attention block knobs (cache<->prefetch trade):")
    q, kk, v = (jax.random.normal(kx, (1, 4, 512, 64))
                for kx in jax.random.split(jax.random.PRNGKey(2), 3))
    ref_o = attention_ref(q, kk, v, causal=True)
    for bq, bkv in ((64, 256), (128, 128), (256, 64)):
        out = flash_attention_fwd(q, kk, v, causal=True, block_q=bq,
                                  block_kv=bkv, interpret=True)
        vmem = (bq * 64 + 2 * bkv * 64 * 2 + bq * bkv) * 4
        print(f"  (block_q={bq:3d}, block_kv={bkv:3d})  "
              f"~VMEM {vmem/2**10:.0f}KiB  "
              f"max|err| {float(jnp.abs(out-ref_o).max()):.1e}")


if __name__ == "__main__":
    main()
