"""The fused Fig. 8 timeline (PR 3): parity, dispatch contract, sharding.

Contracts under test (see ``src/repro/sim/timeline_jax.py``):

* fused trajectories match the PR 2 segment-loop path — identical integer
  and boolean controller decisions, float results to well within the 1e-5
  model tolerance;
* a full ``run_sweep`` is ONE device program per (manager, timeline) plus
  a single baseline evaluation (dispatch counter), with zero host
  allocator calls;
* the ``CBPParams`` decay constants default to the paper's 0.5 and sweep
  through ``param_grid``;
* capacity invariants raise real exceptions (not ``assert``);
* the mix axis shards across forced host devices with identical results.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (
    BandwidthController,
    CBPParams,
    allocator_calls,
    device_dispatches,
    reset_device_dispatches,
)
from repro.sim import MANAGER_NAMES, WORKLOADS, random_mixes, run_sweep
from repro.sim.runner import CMPConfig
from repro.sim.sweep import (
    CapacityInvariantError,
    _check_bandwidth_capacity,
    _check_units_capacity,
)

SEGMENT = CMPConfig(timeline_backend="segment")


def test_fused_matches_segment_loop_all_managers():
    """Whole-timeline fusion vs the per-segment host loop, every manager."""
    mixes = [WORKLOADS["w1"], WORKLOADS["w2"]]
    fused = run_sweep(mixes, total_ms=40.0)
    seg = run_sweep(mixes, total_ms=40.0, config=SEGMENT)
    for name in MANAGER_NAMES:
        err = np.max(np.abs(fused.ipc[name] - seg.ipc[name])
                     / (np.abs(seg.ipc[name]) + 1e-12))
        assert err < 1e-9, (name, err)
        fa, sa = fused.final_alloc[name], seg.final_alloc[name]
        np.testing.assert_array_equal(fa.cache_units, sa.cache_units,
                                      err_msg=name)
        np.testing.assert_array_equal(fa.prefetch_on, sa.prefetch_on,
                                      err_msg=name)
        np.testing.assert_allclose(fa.bandwidth, sa.bandwidth,
                                   rtol=1e-12, err_msg=name)


def test_fused_sweep_is_one_program_per_manager_timeline():
    """The PR 3 dispatch contract: len(managers) timeline programs plus
    one baseline evaluation — nothing per segment, nothing per mix."""
    mixes = random_mixes(3, 16, seed=9)
    names = ["baseline", "only cache", "bw+pref", "CPpf", "CBP"]
    before_alloc = allocator_calls()
    reset_device_dispatches()
    res = run_sweep(mixes, managers=names, total_ms=20.0)
    assert device_dispatches() == len(names) + 1
    assert allocator_calls() == before_alloc
    for name in names:
        assert np.isfinite(res.ipc[name]).all()


def test_segment_loop_dispatches_per_segment():
    """Sanity check that the counter measures what it claims: the segment
    path pays many device calls per timeline."""
    mixes = random_mixes(2, 16, seed=9)
    reset_device_dispatches()
    run_sweep(mixes, managers=["CBP"], total_ms=20.0, config=SEGMENT)
    assert device_dispatches() > 10


def test_decay_defaults_pinned_to_paper_halving():
    p = CBPParams()
    assert p.atd_decay == 0.5
    assert p.bandwidth_delay_decay == 0.5
    assert BandwidthController(64.0, 1.0).decay == 0.5


def test_decay_constants_sweep_through_param_grid():
    mixes = [WORKLOADS["w1"]]
    grid = [CBPParams(),
            CBPParams(atd_decay=0.9, bandwidth_delay_decay=0.2)]
    res = run_sweep(mixes, managers=["CBP"], total_ms=30.0, param_grid=grid)
    assert res.ipc["CBP"].shape == (2, 1, 16)
    for pi, p in enumerate(grid):
        ref = run_sweep(mixes, managers=["CBP"], total_ms=30.0, params=p)
        np.testing.assert_array_equal(res.ipc["CBP"][pi], ref.ipc["CBP"])
    # the decay constants are live knobs: sweeping them moves the result
    assert not np.array_equal(res.ipc["CBP"][0], res.ipc["CBP"][1])


def test_capacity_invariant_checks_raise_real_exceptions():
    """Must trip under ``python -O`` too — never a bare assert."""
    _check_units_capacity(np.full((2, 4), 64), 256, "t")
    with pytest.raises(CapacityInvariantError):
        _check_units_capacity(np.full((2, 4), 63), 256, "t")
    _check_bandwidth_capacity(np.full((2, 4), 16.0), 64.0, "t")
    with pytest.raises(CapacityInvariantError):
        _check_bandwidth_capacity(np.full((2, 4), 15.0), 64.0, "t")
    assert issubclass(CapacityInvariantError, RuntimeError)


_SHARD_SCRIPT = """
import json, sys
import numpy as np
import jax
from repro.sim import WORKLOADS, run_sweep
assert jax.device_count() == 8, jax.device_count()
res = run_sweep([WORKLOADS["w1"], WORKLOADS["w2"]], managers=["CBP"],
                total_ms=20.0)
json.dump({"ipc": np.asarray(res.ipc["CBP"]).tolist(),
           "units": np.asarray(
               res.final_alloc["CBP"].cache_units).tolist()},
          sys.stdout)
"""


def test_mix_axis_shards_across_forced_host_devices():
    """The same sweep on 8 forced host devices (mix axis sharded via
    repro.distributed.shard_map, padded 2 -> 8) matches the single-device
    run to float64 round-off."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        flags += " --xla_force_host_platform_device_count=8"
    env["XLA_FLAGS"] = flags.strip()
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(repo, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-c", _SHARD_SCRIPT], env=env,
        capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-2000:]
    sharded = json.loads(proc.stdout)

    ref = run_sweep([WORKLOADS["w1"], WORKLOADS["w2"]], managers=["CBP"],
                    total_ms=20.0)
    np.testing.assert_allclose(
        np.asarray(sharded["ipc"]), ref.ipc["CBP"], rtol=1e-12, atol=1e-12)
    np.testing.assert_array_equal(
        np.asarray(sharded["units"]), ref.final_alloc["CBP"].cache_units)
