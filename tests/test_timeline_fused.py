"""The fused/stacked Fig. 8 timelines: parity, dispatch contract, sharding.

Contracts under test (see ``src/repro/sim/timeline_jax.py``):

* stacked trajectories (every manager in ONE device program, the default)
  are BIT-IDENTICAL to the per-manager fused path
  (``CMPConfig(timeline_backend="fused")``) for every Table-3 manager, on
  1 and 8 forced host devices;
* fused trajectories match the PR 2 segment-loop path — identical integer
  and boolean controller decisions, float results to well within the 1e-5
  model tolerance;
* a full ``run_sweep`` is AT MOST TWO device programs: the stacked
  manager set plus the shared baseline evaluation (dispatch counter),
  with zero host allocator calls;
* the ``CBPParams`` decay constants default to the paper's 0.5 and sweep
  through ``param_grid``;
* capacity invariants raise real exceptions (not ``assert``);
* the (manager, mix) grid shards across forced host devices via
  ``repro.distributed.shard_grid`` with bit-identical results, and shard
  counts clamp to the axis extents (padding never exceeds real rows).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (
    BandwidthController,
    CBPParams,
    allocator_calls,
    device_dispatches,
    reset_device_dispatches,
)
from repro.core.coordinator import ScheduleSegment
from repro.sim import MANAGER_NAMES, WORKLOADS, random_mixes, run_sweep
from repro.sim.runner import CMPConfig
from repro.sim.sweep import (
    CapacityInvariantError,
    _check_bandwidth_capacity,
    _check_units_capacity,
)
from repro.sim.timeline_jax import (
    NOOP,
    RUN,
    _length_buckets,
    cppf_schedule,
    segment_table,
    stack_tables,
)

SEGMENT = CMPConfig(timeline_backend="segment")


def test_fused_matches_segment_loop_all_managers():
    """Whole-timeline fusion vs the per-segment host loop, every manager."""
    mixes = [WORKLOADS["w1"], WORKLOADS["w2"]]
    fused = run_sweep(mixes, total_ms=40.0)
    seg = run_sweep(mixes, total_ms=40.0, config=SEGMENT)
    for name in MANAGER_NAMES:
        err = np.max(np.abs(fused.ipc[name] - seg.ipc[name])
                     / (np.abs(seg.ipc[name]) + 1e-12))
        assert err < 1e-9, (name, err)
        fa, sa = fused.final_alloc[name], seg.final_alloc[name]
        np.testing.assert_array_equal(fa.cache_units, sa.cache_units,
                                      err_msg=name)
        np.testing.assert_array_equal(fa.prefetch_on, sa.prefetch_on,
                                      err_msg=name)
        np.testing.assert_allclose(fa.bandwidth, sa.bandwidth,
                                   rtol=1e-12, err_msg=name)


def test_stacked_sweep_is_two_device_programs():
    """The stacked dispatch contract: ONE program for the whole manager
    set plus one baseline evaluation — nothing per manager, segment or
    mix."""
    mixes = random_mixes(3, 16, seed=9)
    names = ["baseline", "only cache", "bw+pref", "CPpf", "CBP"]
    before_alloc = allocator_calls()
    reset_device_dispatches()
    res = run_sweep(mixes, managers=names, total_ms=20.0)
    assert device_dispatches() == 2
    assert allocator_calls() == before_alloc
    for name in names:
        assert np.isfinite(res.ipc[name]).all()


def test_per_manager_fused_path_dispatches_one_program_each():
    """The stacking parity reference keeps the PR 3 shape: one program
    per (manager, timeline) plus the baseline evaluation."""
    mixes = random_mixes(2, 16, seed=9)
    names = ["only cache", "CPpf", "CBP"]
    reset_device_dispatches()
    run_sweep(mixes, managers=names, total_ms=20.0,
              config=CMPConfig(timeline_backend="fused"))
    assert device_dispatches() == len(names) + 1


def test_stacked_bit_identical_to_per_manager_fused_every_manager():
    """THE stacking property: batching the manager axis changes nothing.
    Every Table-3 manager's per-mix IPC and final allocation out of the
    stacked program equal the per-manager fused run bit for bit."""
    mixes = [WORKLOADS["w1"], WORKLOADS["w2"]] + random_mixes(1, 16, seed=5)
    stacked = run_sweep(mixes, total_ms=40.0)
    fused = run_sweep(mixes, total_ms=40.0,
                      config=CMPConfig(timeline_backend="fused"))
    np.testing.assert_array_equal(stacked.baseline_ipc, fused.baseline_ipc)
    for name in MANAGER_NAMES:
        np.testing.assert_array_equal(stacked.ipc[name], fused.ipc[name],
                                      err_msg=name)
        sa, fa = stacked.final_alloc[name], fused.final_alloc[name]
        np.testing.assert_array_equal(sa.cache_units, fa.cache_units,
                                      err_msg=name)
        np.testing.assert_array_equal(sa.prefetch_on, fa.prefetch_on,
                                      err_msg=name)
        np.testing.assert_array_equal(sa.bandwidth, fa.bandwidth,
                                      err_msg=name)


def test_segment_loop_dispatches_per_segment():
    """Sanity check that the counter measures what it claims: the segment
    path pays many device calls per timeline."""
    mixes = random_mixes(2, 16, seed=9)
    reset_device_dispatches()
    run_sweep(mixes, managers=["CBP"], total_ms=20.0, config=SEGMENT)
    assert device_dispatches() > 10


def test_decay_defaults_pinned_to_paper_halving():
    p = CBPParams()
    assert p.atd_decay == 0.5
    assert p.bandwidth_delay_decay == 0.5
    assert BandwidthController(64.0, 1.0).decay == 0.5


def test_decay_constants_sweep_through_param_grid():
    mixes = [WORKLOADS["w1"]]
    grid = [CBPParams(),
            CBPParams(atd_decay=0.9, bandwidth_delay_decay=0.2)]
    res = run_sweep(mixes, managers=["CBP"], total_ms=30.0, param_grid=grid)
    assert res.ipc["CBP"].shape == (2, 1, 16)
    for pi, p in enumerate(grid):
        ref = run_sweep(mixes, managers=["CBP"], total_ms=30.0, params=p)
        np.testing.assert_array_equal(res.ipc["CBP"][pi], ref.ipc["CBP"])
    # the decay constants are live knobs: sweeping them moves the result
    assert not np.array_equal(res.ipc["CBP"][0], res.ipc["CBP"][1])


def test_capacity_invariant_checks_raise_real_exceptions():
    """Must trip under ``python -O`` too — never a bare assert."""
    _check_units_capacity(np.full((2, 4), 64), 256, "t")
    with pytest.raises(CapacityInvariantError):
        _check_units_capacity(np.full((2, 4), 63), 256, "t")
    _check_bandwidth_capacity(np.full((2, 4), 16.0), 64.0, "t")
    with pytest.raises(CapacityInvariantError):
        _check_bandwidth_capacity(np.full((2, 4), 15.0), 64.0, "t")
    assert issubclass(CapacityInvariantError, RuntimeError)


def test_stack_tables_preserves_trailing_boundary_rows():
    """Satellite: a timeline that ENDS on a reconfigure boundary carries
    it as a zero-duration NOOP row (``segment_table``); stacking that
    table under a longer one (which right-pads it with more NOOPs) must
    not drop or reorder the boundary."""
    p = CBPParams()
    short = segment_table(cppf_schedule(20.0, p))   # ends: (NOOP, 0, True)
    assert short[0][-1] == NOOP and bool(short[2][-1])
    long = segment_table(
        [ScheduleSegment("run", 10.0)] * 6)          # 6 rows, no boundary
    kinds, acc, reconf = stack_tables([short, long], [RUN, None])
    # every boundary of the short table survives, the trailing one on a
    # NOOP row, and padding slots carry no flags.
    assert reconf[0].sum() == short[2].sum()
    last = int(np.flatnonzero(reconf[0])[-1])
    assert kinds[0, last] == NOOP and acc[0, last] == 0.0
    # row placement is order-preserving: kinds appear in table order.
    placed = kinds[0][kinds[0] != NOOP]
    orig = short[0][short[0] != NOOP]
    np.testing.assert_array_equal(placed, orig)


def test_trailing_boundary_realloc_fires_on_exact_multiple_total_ms():
    """Satellite pin: total_ms an exact multiple of the reconfigure
    interval makes CPpf's FINAL reallocation ride the trailing
    zero-duration NOOP row.  The stacked (bucketed) program must fire it
    exactly like the per-segment host loop does."""
    mixes = [WORKLOADS["w1"], WORKLOADS["w2"]]
    p = CBPParams()
    assert (20.0 / p.reconfiguration_interval_ms) % 1.0 == 0.0
    stacked = run_sweep(mixes, managers=["CPpf", "CBP"], total_ms=20.0)
    seg = run_sweep(mixes, managers=["CPpf", "CBP"], total_ms=20.0,
                    config=SEGMENT)
    for name in ("CPpf", "CBP"):
        np.testing.assert_array_equal(
            stacked.final_alloc[name].cache_units,
            seg.final_alloc[name].cache_units, err_msg=name)
        np.testing.assert_array_equal(
            stacked.final_alloc[name].prefetch_on,
            seg.final_alloc[name].prefetch_on, err_msg=name)


def test_length_buckets_group_exact_length():
    """The frozen-row-skipping rule: a bucket holds exactly the managers
    with the SAME table length — zero frozen rows inside every bucket,
    and same-length tables share reconfigure slots so their boundary
    greedies merge into one concatenated while_loop."""
    assert _length_buckets([1, 1, 30, 10, 13, 30]) == [[0, 1], [3], [4],
                                                       [2, 5]]
    assert _length_buckets([5]) == [[0]]
    for lens in ([1, 2, 3, 4], [7, 7, 7], [1, 100], [3, 9, 27]):
        buckets = _length_buckets(lens)
        assert sorted(i for b in buckets for i in b) == list(
            range(len(lens)))
        for b in buckets:
            assert len({lens[i] for i in b}) == 1


def test_donated_chunk_buffers_bitwise_parity_and_consumed():
    """Satellite (ROADMAP item 3 leftover): ``donate=True`` hands a
    chunk's grid buffers to XLA — same results bit for bit, same dispatch
    count, and the donated device handles are consumed by the program, so
    a stream never holds two chunks' grids live at once."""
    from repro.sim.stream_sweep import StreamConfig, _build_specs
    from repro.sim.workloads import scenario_chunk
    from repro.sim import timeline_jax

    cfg = StreamConfig(n_mixes=8, chunk_size=8, total_ms=20.0)
    specs = _build_specs(cfg, cfg.scenario.apps_per_mix)
    params = scenario_chunk(cfg.scenario, cfg.seed, 0, cfg.chunk_size)
    params.pop("mix_indices", None)
    kw = dict(total_units=cfg.total_cache_units,
              total_bandwidth=cfg.total_bandwidth,
              min_ways=cfg.params.min_ways,
              speedup_threshold=cfg.params.speedup_threshold,
              min_bandwidth_allocation=cfg.params.min_bandwidth_allocation,
              atd_decay=cfg.params.atd_decay,
              bandwidth_delay_decay=cfg.params.bandwidth_delay_decay,
              shard=False)  # donation is the single-host path

    reset_device_dispatches()
    plain = timeline_jax.run_timelines(params, specs, **kw)
    plain_dispatches = device_dispatches()

    reset_device_dispatches()
    pending = timeline_jax.run_timelines_async(params, specs, donate=True,
                                               **kw)
    assert device_dispatches() == plain_dispatches
    assert pending.donated_inputs, "donated dispatch must keep its handles"
    donated = pending.result()
    assert all(buf.is_deleted() for buf in pending.donated_inputs)

    for d, p in zip(donated, plain):
        np.testing.assert_array_equal(d.ipc_acc, p.ipc_acc)
        np.testing.assert_array_equal(d.cache_units, p.cache_units)
        np.testing.assert_array_equal(d.bandwidth, p.bandwidth)
        np.testing.assert_array_equal(d.prefetch_on, p.prefetch_on)
        assert d.w_acc == p.w_acc

    # The non-donated path keeps its inputs alive (no handle tracking).
    assert timeline_jax.run_timelines_async(
        params, specs, **kw).donated_inputs is None


_SHARD_SCRIPT = """
import json, sys
import numpy as np
import jax
from repro import distributed
from repro.sim import MANAGER_NAMES, WORKLOADS, run_sweep
assert jax.device_count() == 8, jax.device_count()
# 14 managers x 2 mixes on 8 forced devices factor into a genuine 2-D
# (manager, mix) mesh — the manager axis is really being split here.
assert distributed.grid_shard_counts(len(MANAGER_NAMES), 2) == (4, 2)
res = run_sweep([WORKLOADS["w1"], WORKLOADS["w2"]], total_ms=20.0)
json.dump({name: {"ipc": np.asarray(res.ipc[name]).tolist(),
                  "units": np.asarray(
                      res.final_alloc[name].cache_units).tolist()}
           for name in MANAGER_NAMES}, sys.stdout)
"""


def _forced_device_env(n: int = 8) -> dict:
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        flags += f" --xla_force_host_platform_device_count={n}"
    env["XLA_FLAGS"] = flags.strip()
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(repo, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    return env


@pytest.mark.slow
def test_manager_mix_grid_shards_across_forced_host_devices():
    """The same stacked sweep on 8 forced host devices — the (manager,
    mix) grid sharded over a (4, 2) mesh via repro.distributed.shard_grid,
    managers padded 14 -> 16 — is BIT-IDENTICAL to the single-device run
    for every registered manager, including the auction / qos / bank bw
    policy families."""
    proc = subprocess.run(
        [sys.executable, "-c", _SHARD_SCRIPT], env=_forced_device_env(),
        capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-2000:]
    sharded = json.loads(proc.stdout)

    ref = run_sweep([WORKLOADS["w1"], WORKLOADS["w2"]], total_ms=20.0)
    for name in MANAGER_NAMES:
        np.testing.assert_array_equal(
            np.asarray(sharded[name]["ipc"]), ref.ipc[name], err_msg=name)
        np.testing.assert_array_equal(
            np.asarray(sharded[name]["units"]),
            ref.final_alloc[name].cache_units, err_msg=name)


_CLAMP_SCRIPT = """
import jax
from repro import distributed
assert jax.device_count() == 8, jax.device_count()
# row shards clamp to the row count: 3 mixes never shard 8 ways.
assert distributed.row_shard_count(3) == 3
assert distributed.row_shard_count(100) == 8
assert distributed.row_shard_count(0) == 1
# padding never exceeds the real rows for any clamped shard count.
for n_rows in range(1, 33):
    s = distributed.row_shard_count(n_rows)
    pad = -(-n_rows // s) * s - n_rows
    assert s <= n_rows and pad < n_rows, (n_rows, s, pad)
# grid counts clamp per axis and never exceed the device count.
assert distributed.grid_shard_counts(1, 3) == (1, 3)
assert distributed.grid_shard_counts(2, 2) == (2, 2)
a, b = distributed.grid_shard_counts(11, 32)
assert a * b <= 8 and a <= 11 and b <= 32 and (a, b) == (2, 4)
import numpy as np
from repro.sim import run_sweep, random_mixes
res = run_sweep(random_mixes(3, 16, seed=2), managers=["CBP"],
                total_ms=20.0)
assert np.isfinite(np.asarray(res.ipc["CBP"])).all()
print("OK")
"""


@pytest.mark.slow
def test_row_shard_count_clamps_to_rows_on_forced_devices():
    """Regression: 8 forced devices + 3 mixes used to build 8 shards and
    pad 3 rows to 8 (more padding than data); shard counts now clamp to
    the axis extent and the padded row count stays below the real one."""
    proc = subprocess.run(
        [sys.executable, "-c", _CLAMP_SCRIPT], env=_forced_device_env(),
        capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout


_PRIME_SCRIPT = """
import json
import sys

import jax
import numpy as np

from repro import distributed
from repro.sim import WORKLOADS, run_sweep

assert jax.device_count() == 7, jax.device_count()
# 7 is prime: no factorization covers every device, so the mesh search
# settles for the best a * b <= 7 and leaves the residual device idle.
assert distributed.grid_shard_counts(3, 2) == (3, 2)     # uses 6 of 7
assert distributed.grid_shard_counts(7, 1) == (7, 1)
assert distributed.grid_shard_counts(1, 7) == (1, 7)
for K in range(1, 12):
    for M in range(1, 12):
        a, b = distributed.grid_shard_counts(K, M)
        assert 1 <= a <= K and 1 <= b <= M and a * b <= 7, (K, M, a, b)
        # padding per axis stays below one shard's worth of rows.
        assert -(-K // a) * a - K < a and -(-M // b) * b - M < b

names = ["only cache", "CPpf", "CBP"]
res = run_sweep([WORKLOADS["w1"], WORKLOADS["w2"]], managers=names,
                total_ms=20.0)
json.dump({name: {"ipc": np.asarray(res.ipc[name]).tolist(),
                  "units": np.asarray(
                      res.final_alloc[name].cache_units).tolist()}
           for name in names}, sys.stdout)
"""


@pytest.mark.slow
def test_prime_device_count_shards_and_stays_bit_identical():
    """Satellite regression: a PRIME forced device count (7) can't tile
    the (3 manager, 2 mix) grid exactly — ``grid_shard_counts`` must
    still produce in-range per-axis counts (3, 2) on 6 of 7 devices, and
    the sharded sweep stays bit-identical to the single-device run."""
    proc = subprocess.run(
        [sys.executable, "-c", _PRIME_SCRIPT], env=_forced_device_env(7),
        capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-2000:]
    sharded = json.loads(proc.stdout)

    names = ["only cache", "CPpf", "CBP"]
    ref = run_sweep([WORKLOADS["w1"], WORKLOADS["w2"]], managers=names,
                    total_ms=20.0)
    for name in names:
        np.testing.assert_array_equal(
            np.asarray(sharded[name]["ipc"]), ref.ipc[name], err_msg=name)
        np.testing.assert_array_equal(
            np.asarray(sharded[name]["units"]),
            ref.final_alloc[name].cache_units, err_msg=name)
