"""Unit + property tests for the CBP controllers (paper §3.2)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    SampledATD,
    StackDistanceMonitor,
    allocate_bandwidth,
    lookahead_allocate,
    throttle_decision,
)

# --------------------------------------------------------------------- #
# Lookahead / UCP (paper §3.2.1)
# --------------------------------------------------------------------- #


def _concave_curve(total, scale, rate):
    u = np.arange(total + 1, dtype=np.float64)
    return scale * (1.0 - np.exp(-u / rate))


def test_lookahead_prefers_high_utility_client():
    total = 64
    curves = np.stack([
        _concave_curve(total, scale=100.0, rate=8.0),   # cache-hungry
        _concave_curve(total, scale=1.0, rate=8.0),     # insensitive
    ])
    alloc = lookahead_allocate(curves, total, min_units=4)
    assert alloc.sum() == total
    assert alloc[0] > alloc[1]
    assert alloc[1] >= 4


def test_lookahead_flat_curves_split_evenly_ish():
    total = 64
    curves = np.zeros((4, total + 1))
    alloc = lookahead_allocate(curves, total, min_units=4)
    assert alloc.sum() == total
    assert alloc.min() >= 4


def test_lookahead_respects_min_units():
    total = 32
    curves = np.stack([
        _concave_curve(total, 100.0, 4.0),
        np.zeros(total + 1),
    ])
    alloc = lookahead_allocate(curves, total, min_units=6)
    assert alloc[1] >= 6
    assert alloc.sum() == total


def test_lookahead_rejects_infeasible_min():
    with pytest.raises(ValueError):
        lookahead_allocate(np.zeros((4, 9)), 8, min_units=4)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(2, 6),
    total=st.integers(24, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_lookahead_properties(n, total, seed):
    """Capacity is always fully distributed; floors always respected."""
    rng = np.random.default_rng(seed)
    scales = rng.uniform(0.0, 50.0, size=n)
    rates = rng.uniform(2.0, 40.0, size=n)
    u = np.arange(total + 1, dtype=np.float64)
    curves = scales[:, None] * (1.0 - np.exp(-u[None, :] / rates[:, None]))
    alloc = lookahead_allocate(curves, total, min_units=2)
    assert int(alloc.sum()) == total
    assert (alloc >= 2).all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_lookahead_monotone_in_utility(seed):
    """A strictly more cache-hungry client never gets less cache."""
    total = 64
    rng = np.random.default_rng(seed)
    base = _concave_curve(total, rng.uniform(5, 20), rng.uniform(4, 30))
    hungry = 3.0 * base
    other = _concave_curve(total, rng.uniform(5, 20), rng.uniform(4, 30))
    a1 = lookahead_allocate(np.stack([base, other]), total, 4)
    a2 = lookahead_allocate(np.stack([hungry, other]), total, 4)
    assert a2[0] >= a1[0]


# --------------------------------------------------------------------- #
# Bandwidth controller / Algorithm 1 (paper §3.2.2)
# --------------------------------------------------------------------- #


def test_bandwidth_proportional_to_delay():
    alloc = allocate_bandwidth(np.array([3.0, 1.0]), 16.0, 1.0)
    # floors: 1 each; remaining 14 split 3:1
    np.testing.assert_allclose(alloc, [1 + 10.5, 1 + 3.5])


def test_bandwidth_zero_delay_even_split():
    alloc = allocate_bandwidth(np.zeros(4), 64.0, 1.0)
    np.testing.assert_allclose(alloc, np.full(4, 16.0))


def test_bandwidth_infeasible_floor():
    with pytest.raises(ValueError):
        allocate_bandwidth(np.ones(8), 4.0, 1.0)


@settings(max_examples=50, deadline=None)
@given(
    n=st.integers(1, 16),
    total=st.floats(16.0, 128.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_bandwidth_properties(n, total, seed):
    """Sums to total; floor respected; monotone in delay."""
    rng = np.random.default_rng(seed)
    delay = rng.uniform(0.0, 100.0, size=n)
    alloc = allocate_bandwidth(delay, total, min_allocation=0.5)
    assert np.isclose(alloc.sum(), total)
    assert (alloc >= 0.5 - 1e-9).all()
    order = np.argsort(delay)
    assert (np.diff(alloc[order]) >= -1e-9).all()


# --------------------------------------------------------------------- #
# Prefetch throttle / Algorithm 2 (paper §3.2.3)
# --------------------------------------------------------------------- #


def test_throttle_threshold():
    on = throttle_decision(
        np.array([1.10, 1.04, 0.90]), np.array([1.0, 1.0, 1.0]),
        speedup_threshold=1.05)
    assert on.tolist() == [True, False, False]


@settings(max_examples=50, deadline=None)
@given(
    ipc=st.floats(0.01, 10.0),
    speedup=st.floats(0.1, 3.0),
    thr=st.floats(1.0, 1.5),
)
def test_throttle_property(ipc, speedup, thr):
    from _hypothesis_compat import assume
    assume(abs(speedup - thr) > 1e-6)  # avoid the float knife-edge
    on = throttle_decision(
        np.array([ipc * speedup]), np.array([ipc]), speedup_threshold=thr)
    assert bool(on[0]) == (speedup > thr)


# --------------------------------------------------------------------- #
# ATD / stack-distance monitor (paper §3.4)
# --------------------------------------------------------------------- #


def test_sampled_atd_halving():
    atd = SampledATD(2, 8)
    atd.record(np.ones((2, 9)))
    atd.halve()
    np.testing.assert_allclose(atd.utility_curves(), 0.5)


def test_stack_distance_monitor_lru():
    mon = StackDistanceMonitor(max_units=4)
    for k in "abcd":
        mon.access(k)          # cold misses
    assert mon.access("d") == 0   # MRU
    assert mon.access("a") == 3   # LRU depth
    curve = mon.utility_curve()
    assert curve[0] == 0
    assert (np.diff(curve) >= 0).all()


@settings(max_examples=25, deadline=None)
@given(
    keys=st.lists(st.integers(0, 10), min_size=1, max_size=200),
    cap=st.integers(2, 12),
)
def test_stack_distance_curve_counts_hits(keys, cap):
    """With cap units, hits(cap) == number of accesses at distance < cap."""
    mon = StackDistanceMonitor(max_units=cap)
    hits_direct = 0
    for k in keys:
        d = mon.access(k)
        if d < cap:
            hits_direct += 1
    assert mon.utility_curve()[cap] == pytest.approx(hits_direct)
    # non-decreasing
    assert (np.diff(mon.utility_curve()) >= 0).all()
