"""Device-resident serving engine: parity, dispatch budget, satellites.

Contracts under test (see ``src/repro/serving/engine_jax.py``):

* the jitted engine is TOKEN-FOR-TOKEN identical to the host-loop
  ``ServingEngine`` under greedy decode (same requests, same schedule,
  same queue-wait/slot-share trajectory), at one group and at several
  groups on one device, and on 8 forced host devices with the
  (group, row) grid sharded via ``repro.distributed.shard_grid`` (slow
  tier);
* each reconfiguration interval is ONE recorded device dispatch (the
  <= 2 budget from the issue), and a CBP-off run is a single dispatch;
* staggered admissions decode at PER-SLOT positions: a request's tokens
  do not depend on what its slot neighbours are doing (the scalar
  ``pos.max()`` regression);
* queue wait is decode-steps-at-admission keyed by engine-assigned
  request id — step 0 is a valid enqueue tick, waits are exact step
  counts in both engines;
* the admission deficit pick breaks exact ties to the lowest stream
  index, FIFO within the stream, in both engines;
* ``PagedKVPool`` readahead touches land in the prefetch counters and
  leave the demand ``hit_rate`` untouched.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import configs
from repro.core.dispatch import device_dispatches, reset_device_dispatches
from repro.models.model import Model
from repro.serving import (
    EngineConfig,
    JitServingEngine,
    PagedKVPool,
    Request,
    ServingEngine,
)


def _smoke_model():
    import jax

    cfg = configs.get_smoke("qwen3-8b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(vocab, n=14, n_streams=4, seed=3, max_prompt=6, max_new=7):
    rng = np.random.default_rng(seed)
    return [
        Request(
            stream=int(rng.integers(n_streams)),
            prompt=rng.integers(
                1, vocab, size=int(rng.integers(1, max_prompt + 1))
            ).astype(np.int32),
            max_new_tokens=int(rng.integers(1, max_new + 1)),
        )
        for _ in range(n)
    ]


ECFG = EngineConfig(batch_slots=4, max_len=48, page_tokens=4,
                    total_pages=24, reconfig_every_steps=8)


def test_jit_engine_token_parity_and_dispatch_budget():
    """Greedy decode, host loop vs jitted engine: identical tokens,
    identical scheduling metrics, one dispatch per interval."""
    cfg, model, params = _smoke_model()
    host = ServingEngine(model, params, n_streams=4, cfg=ECFG)
    hreqs = _requests(cfg.vocab_size)
    host.run(hreqs, max_steps=300)

    jit_eng = JitServingEngine(model, params, n_streams=4, cfg=ECFG)
    jreqs = _requests(cfg.vocab_size)
    reset_device_dispatches()
    jit_eng.run(jreqs, max_steps=300)

    for h, j in zip(hreqs, jreqs):
        assert h.generated == j.generated
    assert jit_eng.steps == host.steps
    assert jit_eng.reconfigs == host.reconfigs
    np.testing.assert_allclose(jit_eng.queue_wait, host.queue_wait,
                               rtol=1e-5)
    np.testing.assert_allclose(jit_eng.slot_share, host.slot_share,
                               rtol=1e-5)
    # <= 2 dispatches per reconfiguration interval; this engine uses ONE.
    assert device_dispatches() == jit_eng.intervals
    assert jit_eng.intervals <= host.steps // ECFG.reconfig_every_steps + 1


def test_multi_group_single_device_matches_host_tokens():
    """Grouping splits streams into independent shards; schedules shift
    but greedy tokens are schedule-independent (per-slot positions)."""
    cfg, model, params = _smoke_model()
    host = ServingEngine(model, params, n_streams=4, cfg=ECFG)
    hreqs = _requests(cfg.vocab_size)
    host.run(hreqs, max_steps=300)

    jit_eng = JitServingEngine(model, params, n_streams=4, cfg=ECFG,
                               n_groups=2)
    jreqs = _requests(cfg.vocab_size)
    jit_eng.run(jreqs, max_steps=300)
    for h, j in zip(hreqs, jreqs):
        assert h.generated == j.generated


def test_cbp_off_is_single_dispatch():
    """reconfig_every_steps beyond the chunk cap compiles out the
    reconfigure; short runs are ONE device program."""
    cfg, model, params = _smoke_model()
    off = EngineConfig(batch_slots=4, max_len=48, page_tokens=4,
                       total_pages=24, reconfig_every_steps=10**9)
    jit_eng = JitServingEngine(model, params, n_streams=4, cfg=off)
    reqs = _requests(cfg.vocab_size)
    reset_device_dispatches()
    jit_eng.run(reqs, max_steps=300)
    assert jit_eng.reconfigs == 0
    assert device_dispatches() == 1
    assert all(r.generated for r in reqs)


def test_staggered_admission_decodes_at_per_slot_positions():
    """Regression for the scalar ``cur = int(pos.max())`` bug: a request
    admitted mid-run (position reset to 0 while neighbours sit
    mid-sequence) must generate the same tokens as when run alone."""
    cfg, model, params = _smoke_model()
    rng = np.random.default_rng(11)
    # More requests than slots with uneven prompt lengths: admissions
    # stagger, so slots decode at genuinely different positions.
    reqs = [Request(stream=i % 3,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        size=3 + 4 * (i % 3)).astype(
                                            np.int32),
                    max_new_tokens=5)
            for i in range(8)]
    for engine_cls in (ServingEngine, JitServingEngine):
        batched = [Request(r.stream, r.prompt.copy(), r.max_new_tokens)
                   for r in reqs]
        engine_cls(model, params, n_streams=3, cfg=ECFG).run(
            batched, max_steps=300)
        for i, r in enumerate(reqs):
            solo = Request(0, r.prompt.copy(), r.max_new_tokens)
            solo_cfg = EngineConfig(batch_slots=1, max_len=48,
                                    page_tokens=4, total_pages=24,
                                    reconfig_every_steps=8,
                                    min_slot_share=0.5)
            ServingEngine(model, params, n_streams=1, cfg=solo_cfg).run(
                [solo], max_steps=300)
            assert batched[i].generated == solo.generated, (
                f"{engine_cls.__name__} corrupted request {i} "
                "under staggered admission")


def test_queue_wait_is_exact_step_count():
    """Step-keyed wait accounting: with one slot and two same-stream
    requests, the second waits exactly the first's completion steps —
    and the zeroth enqueue tick (falsy!) still counts."""
    cfg, model, params = _smoke_model()
    one = EngineConfig(batch_slots=1, max_len=48, page_tokens=4,
                       total_pages=24, reconfig_every_steps=10**6,
                       min_slot_share=0.25)
    prompt = np.asarray([3], dtype=np.int32)
    for engine_cls in (ServingEngine, JitServingEngine):
        eng = engine_cls(model, params, n_streams=1, cfg=one)
        reqs = [Request(0, prompt.copy(), max_new_tokens=3),
                Request(0, prompt.copy(), max_new_tokens=2)]
        eng.run(reqs, max_steps=300)
        # request 0 occupies the slot for steps 0..2 (3 generated
        # tokens); request 1 admits at the end of step 2 with wait 2.
        assert float(np.asarray(eng.queue_wait).sum()) == 2.0, (
            engine_cls.__name__)


def test_admission_tie_break_lowest_stream_then_fifo():
    """Equal deficits admit the LOWEST stream index first; within a
    stream, FIFO — in both engines."""
    cfg, model, params = _smoke_model()
    one = EngineConfig(batch_slots=1, max_len=48, page_tokens=4,
                       total_pages=24, reconfig_every_steps=10**6,
                       min_slot_share=0.25)
    prompts = [np.asarray([5 + i], dtype=np.int32) for i in range(4)]
    for engine_cls in (ServingEngine, JitServingEngine):
        eng = engine_cls(model, params, n_streams=2, cfg=one)
        # enqueue order deliberately puts stream 1 first: the deficit
        # pick must still prefer stream 0, then alternate as the
        # token bucket balances, FIFO inside each stream.
        reqs = [Request(1, prompts[0], max_new_tokens=1),
                Request(0, prompts[1], max_new_tokens=1),
                Request(1, prompts[2], max_new_tokens=1),
                Request(0, prompts[3], max_new_tokens=1)]
        eng.run(reqs, max_steps=300)
        assert all(r.generated is not None and len(r.generated) == 1
                   for r in reqs)
    # Completion order is observable through the host engine directly:
    host = ServingEngine(model, params, n_streams=2, cfg=one)
    reqs = [Request(1, prompts[0], max_new_tokens=1),
            Request(0, prompts[1], max_new_tokens=1)]
    done_order = []
    orig = host._touch_pages

    def spy(req, pos):
        done_order.append(req.stream)
        return orig(req, pos)

    host._touch_pages = spy
    host.run(reqs, max_steps=300)
    assert done_order[0] == 0  # stream 0 won the tie despite enqueue order


def test_prefetch_touches_do_not_pollute_demand_hit_rate():
    pool = PagedKVPool(total_pages=8, n_streams=2)
    pool.access(0, "a")
    pool.access(0, "a")
    st = pool.stats[0]
    assert (st.hits, st.misses) == (1, 1)
    rate = st.hit_rate
    pool.access(0, "b", prefetch=True)
    pool.access(0, "b", prefetch=True)
    assert (st.prefetch_hits, st.prefetch_misses) == (1, 1)
    assert st.hit_rate == rate          # demand signal untouched
    assert st.prefetch_hit_rate == 0.5
    # but prefetched pages DO occupy the partition and feed the monitor
    assert pool.occupancy()[0] == 2


def test_group_divisibility_validated():
    cfg, model, params = _smoke_model()
    with pytest.raises(ValueError, match="not divisible"):
        JitServingEngine(model, params, n_streams=3, cfg=ECFG, n_groups=2)


_PARITY_SCRIPT = r"""
import json, sys
import numpy as np, jax
from repro import configs
from repro.core.dispatch import device_dispatches, reset_device_dispatches
from repro.models.model import Model
from repro.serving import (EngineConfig, JitServingEngine, Request,
                           ServingEngine)

cfg = configs.get_smoke("qwen3-8b")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))

def mk():
    rng = np.random.default_rng(7)
    return [Request(stream=int(rng.integers(8)),
                    prompt=rng.integers(1, cfg.vocab_size,
                                        size=int(rng.integers(1, 7))
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(1, 8)))
            for _ in range(40)]

ecfg = EngineConfig(batch_slots=16, max_len=48, page_tokens=4,
                    total_pages=64, reconfig_every_steps=8)
host = ServingEngine(model, params, n_streams=8, cfg=ecfg)
hreqs = mk(); host.run(hreqs, max_steps=300)
eng = JitServingEngine(model, params, n_streams=8, cfg=ecfg, n_groups=8)
jreqs = mk()
reset_device_dispatches()
eng.run(jreqs, max_steps=300)
print(json.dumps({
    "devices": jax.device_count(),
    "grid": list(eng._grid),
    "tokens_match": all(h.generated == j.generated
                        for h, j in zip(hreqs, jreqs)),
    "dispatches": device_dispatches(),
    "intervals": eng.intervals,
}))
"""


def _forced_device_env(n: int = 8) -> dict:
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        flags += f" --xla_force_host_platform_device_count={n}"
    env["XLA_FLAGS"] = flags.strip()
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(repo, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    return env


@pytest.mark.slow
def test_sharded_engine_matches_host_on_forced_devices():
    """8 groups sharded over 8 forced host devices via shard_grid: tokens
    identical to the host loop, still one dispatch per interval."""
    proc = subprocess.run(
        [sys.executable, "-c", _PARITY_SCRIPT], env=_forced_device_env(),
        capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr
    import json

    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    assert tuple(out["grid"])[2] * tuple(out["grid"])[3] == 8
    assert out["tokens_match"]
    assert out["dispatches"] == out["intervals"]
