"""Fig. 8 schedule accounting regression tests (paper §3.3).

The coordinator timeline is now data (:func:`repro.core.fig8_schedule`)
executed by both the scalar and the batched coordinator; these tests pin
its accounting: sampling periods plus remainders sum exactly to
``total_ms``, durations are non-negative, and the executed history agrees
with the declared schedule for both DYNAMIC and pinned prefetch modes.
"""
import numpy as np
import pytest

from repro.core import CBPCoordinator, CBPParams, PrefetchMode, fig8_schedule
from repro.sim.runner import CMPPlant

PF_MODES = [PrefetchMode.DYNAMIC, PrefetchMode.OFF, PrefetchMode.ON]


@pytest.mark.parametrize("prefetch_dynamic", [True, False])
@pytest.mark.parametrize("total_ms", [10.0, 25.0, 40.0, 100.0])
def test_schedule_durations_sum_to_total(total_ms, prefetch_dynamic):
    params = CBPParams()
    segments = fig8_schedule(total_ms, params, prefetch_dynamic)
    assert all(s.duration_ms >= 0.0 for s in segments)
    assert sum(s.duration_ms for s in segments) == pytest.approx(total_ms)


@pytest.mark.parametrize("prefetch_dynamic", [True, False])
def test_schedule_structure(prefetch_dynamic):
    params = CBPParams()
    segments = fig8_schedule(100.0, params, prefetch_dynamic)
    n_intervals = int(100.0 / params.reconfiguration_interval_ms)
    kinds = [s.kind for s in segments]
    # One reconfiguration boundary between consecutive intervals.
    assert kinds.count("reconfigure") == n_intervals - 1
    assert all(s.duration_ms == 0.0 for s in segments
               if s.kind == "reconfigure")
    if prefetch_dynamic:
        # Every interval starts with an off/on sampling pair.
        assert kinds.count("sample_off") == n_intervals
        assert kinds.count("sample_on") == n_intervals
        sample_ms = sum(s.duration_ms for s in segments
                        if s.kind.startswith("sample"))
        assert sample_ms == pytest.approx(
            2 * params.prefetch_sampling_period_ms * n_intervals)
    else:
        assert "sample_off" not in kinds and "sample_on" not in kinds
        assert kinds.count("run") == n_intervals


@pytest.mark.parametrize("pf_mode", PF_MODES)
def test_coordinator_history_matches_schedule(pf_mode):
    """CBPCoordinator.run executes exactly the declared timeline."""
    total_ms = 35.0
    plant = CMPPlant(["lbm", "xalancbmk"])
    coord = CBPCoordinator(plant, prefetch_mode=pf_mode)
    coord.run(total_ms)

    durations = [rec.duration_ms for rec in coord.history]
    assert all(d > 0.0 for d in durations)
    assert sum(durations) == pytest.approx(total_ms)
    # t_ms stamps are cumulative and start at zero.
    t = 0.0
    for rec in coord.history:
        assert rec.t_ms == pytest.approx(t)
        t += rec.duration_ms

    expected = [s.duration_ms for s in fig8_schedule(
        total_ms, coord.params, pf_mode == PrefetchMode.DYNAMIC)
        if s.duration_ms > 0.0]
    assert durations == pytest.approx(expected)


@pytest.mark.parametrize("pf_mode", PF_MODES)
def test_mean_ipc_is_time_weighted_over_full_run(pf_mode):
    plant = CMPPlant(["lbm", "xalancbmk"])
    coord = CBPCoordinator(plant, prefetch_mode=pf_mode)
    coord.run(30.0)
    manual = sum(rec.stats.ipc * rec.duration_ms for rec in coord.history)
    manual = manual / sum(rec.duration_ms for rec in coord.history)
    np.testing.assert_allclose(coord.mean_ipc(), manual)
