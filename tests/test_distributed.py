"""Distributed/sharding tests.

These run in a SUBPROCESS with ``--xla_force_host_platform_device_count=8``
so the main test process keeps its single-device view (the dry-run is the
only consumer of many-device meshes, per the assignment note).
"""
import json
import pathlib
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow   # 8-device subprocess training runs

ROOT = pathlib.Path(__file__).resolve().parent.parent

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.distributed import make_mesh, set_dp_axes, use_mesh
from repro.launch import shardings as sh
from repro.models import build
from repro.train.step import TrainStepConfig, build_train_step

mesh = make_mesh((2, 4), ("data", "model"))
results = {}
for arch in ["qwen3-8b", "qwen3-moe-30b-a3b", "mamba2-1.3b"]:
    cfg = configs.get_smoke(arch)
    import dataclasses
    cfg = dataclasses.replace(
        cfg, param_dtype="float32", mesh_model=4,
        moe_groups=2 if cfg.n_experts else 1,
        seq_shard_activations=True, remat="full",
        n_heads=getattr(cfg, "n_heads", 4) or 0)
    model = build(cfg)
    tcfg = TrainStepConfig(optimizer="adamw", lr=1e-3, microbatches=2)
    init_opt, train_step = build_train_step(model, tcfg)
    set_dp_axes(sh.dp_axes_for(cfg))
    with use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        pspec = sh.param_specs(cfg, params, mesh)
        params = jax.device_put(params, sh.named(pspec, mesh))
        opt = init_opt(params)
        step = jax.jit(train_step, donate_argnums=(0, 1))
        toks = jnp.zeros((8, 32), jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        losses = []
        for _ in range(3):
            params, opt, metrics = step(params, opt, batch)
            losses.append(float(metrics["loss"]))
    results[arch] = {
        "losses": losses,
        "finite": all(np.isfinite(l) for l in losses),
        "decreasing": losses[-1] < losses[0],
        "n_devices": len(jax.devices()),
    }
print("RESULT:" + json.dumps(results))
'''


@pytest.fixture(scope="module")
def dist_results():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        cwd=ROOT)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


def test_sharded_training_runs_on_8_devices(dist_results):
    for arch, r in dist_results.items():
        assert r["n_devices"] == 8
        assert r["finite"], arch


def test_sharded_training_loss_decreases(dist_results):
    for arch, r in dist_results.items():
        assert r["decreasing"], (arch, r["losses"])
