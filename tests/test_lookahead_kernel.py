"""The Lookahead greedy Pallas kernel vs the numpy golden reference.

Contract (see ``src/repro/kernels/lookahead_greedy``): the interpret-mode
kernel, its numpy ``ref.py`` oracle and the batched while_loop backend are
ALL bit-identical to the golden
(:func:`repro.core.cache_controller.lookahead_allocate` /
:func:`~repro.core.cache_controller.cppf_allocate`) away from tie
knife-edges — the kernel swaps only *how* the greedy while-loop executes,
never a tie-break or a rounding.  Random float curves make exact mu ties
measure-zero, so these tests assert exact equality.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import CacheController, allocator_calls
from repro.core import cache_controller as cc
from repro.core import cache_controller_jax as ccj
from repro.kernels.lookahead_greedy.ref import (
    lookahead_masked_ref,
    lookahead_ref,
)

pytestmark = pytest.mark.slow


def _curves(rng, n, total, kind):
    if kind == "concave":
        u = np.arange(total + 1, dtype=np.float64)
        return (rng.uniform(0.0, 50.0, n)[:, None]
                * (1.0 - np.exp(-u[None, :]
                                / rng.uniform(2.0, 40.0, n)[:, None])))
    if kind == "nonmonotone":
        return np.cumsum(rng.normal(0.0, 1.0, (n, total + 1)), axis=1)
    return np.zeros((n, total + 1))


# ------------------------------ ref.py ----------------------------- #


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(2, 8),
    total=st.integers(24, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_ref_matches_golden(n, total, seed):
    """The kernel's numpy oracle is pinned to the repo golden."""
    rng = np.random.default_rng(seed)
    for kind in ("concave", "nonmonotone", "flat"):
        curves = _curves(rng, n, total, kind)
        min_units = int(rng.integers(0, max(total // n, 1)))
        np.testing.assert_array_equal(
            lookahead_ref(curves, total, min_units),
            cc.lookahead_allocate(curves, total, min_units),
            err_msg=kind)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(2, 8),
    total=st.integers(24, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_ref_matches_cppf_golden(n, total, seed):
    rng = np.random.default_rng(seed)
    curves = np.cumsum(
        np.abs(rng.normal(0.0, 1.0, (n, total + 1))), axis=1)
    min_units = int(rng.integers(1, max(total // n, 2)))
    active = rng.integers(0, 2, n).astype(bool)
    np.testing.assert_array_equal(
        lookahead_masked_ref(curves, total, min_units, active),
        cc.cppf_allocate(curves, total, min_units, active))


def test_masked_ref_all_inactive_even_split():
    got = lookahead_masked_ref(
        np.zeros((4, 31)), 30, 4, np.zeros(4, dtype=bool))
    np.testing.assert_array_equal(got, [8, 8, 7, 7])


# ----------------------- kernel (interpret mode) -------------------- #


@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(2, 8),
    total=st.integers(24, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_bit_identical_to_golden(n, total, seed):
    """The whole batch through the Pallas backend equals the golden
    element by element — concave, non-monotone and flat curves."""
    rng = np.random.default_rng(seed)
    for kind in ("concave", "nonmonotone", "flat"):
        curves = np.stack(
            [_curves(rng, n, total, kind) for _ in range(3)])
        min_units = int(rng.integers(0, max(total // n, 1)))
        got = ccj.lookahead_allocate(
            curves, total, min_units, backend="pallas")
        for b in range(3):
            np.testing.assert_array_equal(
                got[b], cc.lookahead_allocate(curves[b], total, min_units),
                err_msg=kind)


@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(2, 8),
    total=st.integers(24, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_masked_bit_identical_to_cppf_golden(n, total, seed):
    """The masked CPpf variant through the Pallas backend, incl. pinned
    inactive clients and the all-inactive even-split fallback."""
    rng = np.random.default_rng(seed)
    curves = np.cumsum(
        np.abs(rng.normal(0.0, 1.0, (n, total + 1))), axis=1)
    min_units = int(rng.integers(1, max(total // n, 2)))
    for active in (rng.integers(0, 2, n).astype(bool),
                   np.ones(n, dtype=bool),
                   np.zeros(n, dtype=bool)):
        got = ccj.lookahead_allocate_masked(
            curves, total, min_units, active, backend="pallas")
        np.testing.assert_array_equal(
            got, cc.cppf_allocate(curves, total, min_units, active))
        assert got.sum() == total


def test_kernel_agrees_with_while_loop_backend():
    """Both device backends produce the same bits through the same
    zero-utility spread."""
    rng = np.random.default_rng(7)
    n, total = 6, 64
    batch = np.stack(
        [_curves(rng, n, total, "nonmonotone") for _ in range(5)])
    mins = np.array([0, 1, 2, 3, 4])
    np.testing.assert_array_equal(
        ccj.lookahead_allocate(batch, total, mins, backend="pallas"),
        ccj.lookahead_allocate(batch, total, mins, backend="jax"))


def test_cache_controller_pallas_backend_device_resident():
    """The facade's pallas backend matches numpy bit for bit and never
    touches the host allocator counter."""
    rng = np.random.default_rng(11)
    n, total = 6, 48
    batch = np.stack(
        [_curves(rng, n, total, "nonmonotone") for _ in range(4)])
    ctl_np = CacheController(total, min_units=2, backend="numpy")
    ctl_pl = CacheController(total, min_units=2, backend="pallas")
    before = allocator_calls()
    np.testing.assert_array_equal(
        ctl_np.allocate(batch), ctl_pl.allocate(batch))
    active = rng.integers(0, 2, size=(4, n)).astype(bool)
    np.testing.assert_array_equal(
        ctl_np.allocate_masked(batch, active),
        ctl_pl.allocate_masked(batch, active))
    # numpy side incremented the counter; the pallas side added nothing.
    assert allocator_calls() - before == 8


def test_unknown_lookahead_backend_rejected():
    with pytest.raises(ValueError):
        ccj.lookahead_allocate(np.zeros((2, 4, 9)), 8, 0, backend="mosaic")
