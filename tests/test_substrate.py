"""Substrate tests: data pipeline, optimizers, grad compression,
checkpointing (crash safety), fault tolerance, serving KV pool + engine."""
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.data import PrefetchPipeline, SyntheticTokens
from repro.optim import (
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    compress_grads,
    decompress_grads,
)
from repro.runtime import ElasticMesh, StragglerWatchdog, plan_matmul_blocks
from repro.serving import EngineConfig, PagedKVPool, Request, ServingEngine

# ----------------------------- data -------------------------------- #


def test_synthetic_tokens_deterministic_and_resumable():
    a = SyntheticTokens(2, 8, 100, seed=3)
    b1 = next(a)
    b2 = next(a)
    a2 = SyntheticTokens(2, 8, 100, seed=3, start_index=1)
    np.testing.assert_array_equal(next(a2)["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (2, 8)


def test_prefetch_pipeline_depth_and_metrics():
    src = SyntheticTokens(1, 4, 10)
    pipe = PrefetchPipeline(src, depth=2, fetch_cost_s=0.005)
    batches = [next(pipe) for _ in range(5)]
    assert len(batches) == 5
    assert pipe.mean_wait_ms() >= 0.0
    assert pipe.throughput() > 0.0
    pipe.set_depth(0)          # throttle off
    assert pipe.depth == 0
    b = next(pipe)
    assert b["tokens"].shape == (1, 4)
    pipe.stop()


# ---------------------------- optim -------------------------------- #


def _tiny_params(key):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (8, 4)),
            "b": jax.random.normal(k2, (4,))}


def test_adamw_reduces_quadratic_loss():
    params = _tiny_params(jax.random.PRNGKey(0))
    target = jax.tree.map(jnp.zeros_like, params)
    state = adamw_init(params)

    def loss(p):
        return sum(jnp.sum(jnp.square(a - b)) for a, b in
                   zip(jax.tree.leaves(p), jax.tree.leaves(target)))

    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state = adamw_update(params, g, state, lr=0.05)
    assert float(loss(params)) < 0.2 * l0


def test_adafactor_reduces_quadratic_loss():
    params = _tiny_params(jax.random.PRNGKey(1))
    state = adafactor_init(params)

    def loss(p):
        return sum(jnp.sum(jnp.square(a)) for a in jax.tree.leaves(p))

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state = adafactor_update(params, g, state, lr=0.05)
    assert float(loss(params)) < 0.5 * l0
    # factored second moment for the matrix leaf
    assert len(jax.tree.leaves(state.v)) > len(jax.tree.leaves(params))


def test_grad_compression_error_feedback_unbiased():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
    err = None
    acc_q = np.zeros((64, 64), np.float32)
    acc_raw = np.zeros((64, 64), np.float32)
    for _ in range(50):
        q, scales, err = compress_grads(g, err)
        deq = decompress_grads(q, scales)
        acc_q += np.asarray(deq["w"])
        acc_raw += np.asarray(g["w"])
    # error feedback keeps the long-run average unbiased
    np.testing.assert_allclose(acc_q / 50, acc_raw / 50, atol=2e-3)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_grad_compression_bounded_error(seed):
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))}
    q, scales, err = compress_grads(g)
    deq = decompress_grads(q, scales)
    scale = float(scales["w"])
    assert np.abs(np.asarray(deq["w"] - g["w"])).max() <= scale * 0.5 + 1e-6


# -------------------------- checkpoint ----------------------------- #


def test_checkpoint_roundtrip_and_keep_k(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    for step in (1, 2, 3):
        mgr.save(step, tree, extra={"data": {"index": step}})
    assert mgr.all_steps() == [2, 3]
    assert mgr.latest_step() == 3
    step, restored, extra = mgr.restore_latest(tree)
    assert step == 3
    assert extra["data"]["index"] == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_crash_safety(tmp_path):
    """A torn write (no LATEST update) must fall back to the previous
    complete checkpoint."""
    mgr = CheckpointManager(tmp_path, keep=3)
    tree = {"a": jnp.zeros((2,))}
    mgr.save(1, tree)
    # simulate a crash mid-save of step 2: partial dir, stale LATEST
    bad = tmp_path / "step_0000000002"
    bad.mkdir()
    (bad / "junk.npy").write_bytes(b"xx")
    assert mgr.latest_step() == 1
    out = mgr.restore_latest(tree)
    assert out is not None and out[0] == 1


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.ones((128, 128))}
    mgr.save_async(5, tree)
    mgr.wait()
    assert mgr.latest_step() == 5


# ------------------------ fault tolerance -------------------------- #


def test_straggler_watchdog_flags_and_mitigates():
    wd = StragglerWatchdog(threshold=2.0, quarantine_after=2)
    trig = []
    for step in range(20):
        t = 1.0 if step < 10 or step > 13 else 5.0  # 4 slow steps
        if wd.observe(step, t):
            trig.append(step)
    assert len(wd.events) >= 2
    assert wd.mitigations >= 1
    # healthy steps keep the EWMA near 1.0
    assert wd.ewma < 1.5


def test_elastic_mesh_remesh():
    em = ElasticMesh(model_divisors=(1, 2, 4, 8, 16), prefer_model=16)
    assert em.remesh(256) == (16, 16)
    assert em.remesh(240) == (15, 16)     # lost a host: dp shrinks
    assert em.remesh(24) == (3, 8)        # model axis falls back to 8
    with pytest.raises(ValueError):
        ElasticMesh(model_divisors=(16,), prefer_model=16).remesh(9)


# --------------------------- serving ------------------------------- #


def test_kv_pool_partitions_toward_reusing_stream():
    pool = PagedKVPool(total_pages=32, n_streams=2, min_pages=2)
    # stream 0 re-touches a 12-page working set; stream 1 streams (no reuse)
    for it in range(6):
        for p in range(12):
            pool.access(0, ("s0", p))
        for p in range(40):
            pool.access(1, ("s1", it * 40 + p))
    part = pool.reconfigure()
    assert part[0] > part[1]
    assert part.sum() == 32
    # after repartition the reusing stream hits
    s0 = pool.stats[0].hits
    for p in range(12):
        pool.access(0, ("s0", p))
    assert pool.stats[0].hits - s0 == 12


def test_kv_pool_respects_min_pages():
    pool = PagedKVPool(total_pages=16, n_streams=4, min_pages=3)
    for p in range(50):
        pool.access(0, ("hot", p % 10))
    part = pool.reconfigure()
    assert (part >= 3).all()
    assert part.sum() == 16


def test_serving_engine_end_to_end():
    from repro import configs
    from repro.models import build
    cfg = configs.get_smoke("qwen3-8b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, n_streams=2,
                        cfg=EngineConfig(batch_slots=2, max_len=32,
                                         total_pages=16,
                                         reconfig_every_steps=8))
    reqs = [
        Request(stream=i % 2,
                prompt=np.arange(3, dtype=np.int32) + i,
                max_new_tokens=4)
        for i in range(4)
    ]
    done = eng.run(reqs, max_steps=200)
    assert all(len(r.generated) == 4 for r in done)
    assert eng.reconfigs >= 1
    assert eng.pool.occupancy().sum() > 0


# ------------------------- kernel knobs ---------------------------- #


def test_plan_matmul_blocks_valid():
    bm, bn, bk = plan_matmul_blocks(512, 512, 512)
    assert 512 % bm == 0 and 512 % bn == 0 and 512 % bk == 0
    from repro.kernels.cbp_matmul.kernel import vmem_footprint_bytes
    assert vmem_footprint_bytes(bm, bn, bk) < 128 * 1024 * 1024


def test_planned_blocks_run_correctly():
    from repro.kernels.cbp_matmul.kernel import cbp_matmul
    from repro.kernels.cbp_matmul.ref import matmul_ref
    bm, bn, bk = plan_matmul_blocks(256, 128, 128)
    a = jax.random.normal(jax.random.PRNGKey(0), (256, 128))
    b = jax.random.normal(jax.random.PRNGKey(1), (128, 128))
    out = cbp_matmul(a, b, block_m=bm, block_n=bn, block_k=bk,
                     interpret=True)
    np.testing.assert_allclose(out, matmul_ref(a, b), atol=2e-5, rtol=2e-5)


# The planner is deterministic (UCP greedy + pad-aware snap), so its
# outputs are PINNED: any change to the utility curves, the greedy
# tie-breaks or the alignment rules shows up here as a diff to review, not
# a silent re-plan.  Values were produced by the current planner and
# spot-checked for feasibility/footprint below.
PLAN_GOLDENS = {
    # default budget: generous enough that every block saturates to the
    # full problem extent, for both bf16 and f32 tile bytes.
    (128, 128, 128, 2, None): (128, 128, 128),
    (128, 128, 128, 4, None): (128, 128, 128),
    (256, 128, 128, 2, None): (256, 128, 128),
    (512, 512, 512, 4, None): (512, 512, 512),
    (96, 64, 48, 2, None): (96, 64, 48),
    (96, 64, 48, 4, None): (96, 64, 48),
    # constrained budgets: the greedy actually arbitrates A/B/ACC here,
    # and dtype_bytes moves the split (f32 shrinks block_k first).
    (512, 512, 512, 2, 262144): (128, 128, 128),
    (512, 512, 512, 4, 262144): (128, 128, 64),
    (512, 512, 512, 4, 1048576): (256, 256, 256),
    (1024, 256, 512, 2, 1048576): (512, 256, 512),
    (1024, 256, 512, 4, 262144): (128, 128, 64),
    # re-pinned by the pad-aware snap fix: the old pow2 divide-down lost
    # to the largest exact ALIGNED divisor of 384/192 (96 and 192 beat
    # 64/128 — bigger blocks, zero padding, still inside the budget).
    (384, 384, 192, 2, 262144): (128, 128, 96),
    (384, 384, 192, 4, 1048576): (192, 192, 192),
    (256, 128, 128, 2, 1048576): (256, 128, 128),
    (256, 128, 128, 4, 262144): (128, 128, 64),
    # prime/odd dims: the old divide-down collapsed these to 1-wide
    # blocks; the pad-aware snap keeps an aligned block tiling the padded
    # extent (97 -> 104 = 13 x 8, 513 -> 520).
    (97, 64, 48, 2, None): (104, 64, 48),
    (97, 97, 97, 2, 262144): (104, 104, 104),
    (513, 256, 96, 2, 262144): (128, 128, 96),
    (100, 100, 100, 4, 262144): (64, 64, 64),
    # m < 8: the whole extent is one sublane-padded tile (the old
    # _pow2_clamp(lo=8, hi=m) only got here by lo>hi inversion).
    (4, 128, 128, 2, None): (4, 128, 128),
    (6, 512, 512, 4, 262144): (6, 128, 64),
}


def _block_feasible(dim, block):
    """Pad-aware feasibility: exact divisor, or an aligned block tiling
    the padded extent ceil(dim/block)*block (caller pads the operand)."""
    return dim % block == 0 or (block % 8 == 0
                                and block <= -(-dim // 8) * 8)


def test_plan_matmul_blocks_golden_grid():
    for (m, n, k, db, budget), want in PLAN_GOLDENS.items():
        kw = {} if budget is None else {"vmem_budget": budget}
        got = plan_matmul_blocks(m, n, k, dtype_bytes=db, **kw)
        assert got == want, (m, n, k, db, budget, got)
        bm, bn, bk = got
        assert _block_feasible(m, bm) and _block_feasible(n, bn) \
            and _block_feasible(k, bk), (got, m, n, k)


def test_plan_matmul_blocks_jax_backend_matches_numpy_goldens():
    """The device-side Lookahead greedy plans the SAME blocks (the
    runtime's bit-parity contract rides the allocator's)."""
    for (m, n, k, db, budget), want in PLAN_GOLDENS.items():
        kw = {} if budget is None else {"vmem_budget": budget}
        got = plan_matmul_blocks(m, n, k, dtype_bytes=db,
                                 allocator_backend="jax", **kw)
        assert got == want, (m, n, k, db, budget, got)


def test_plan_matmul_blocks_batched_matches_scalar_one_dispatch():
    """The whole golden grid plans in ONE device call, bit-identical to
    the scalar path — including shapes with different dtype_bytes and
    vmem budgets (capacity groups fuse into a single program)."""
    from repro.core.dispatch import device_dispatches, reset_device_dispatches
    from repro.runtime.cbp_runtime import VMEM_BYTES, plan_matmul_blocks_batched

    keys = list(PLAN_GOLDENS)
    shapes = [(m, n, k) for (m, n, k, _db, _vb) in keys]
    dbs = [db for (_m, _n, _k, db, _vb) in keys]
    budgets = [vb if vb is not None else VMEM_BYTES // 8
               for (_m, _n, _k, _db, vb) in keys]
    reset_device_dispatches()
    got = plan_matmul_blocks_batched(shapes, dtype_bytes=dbs,
                                     vmem_budget=budgets)
    assert device_dispatches() == 1
    assert [tuple(b) for b in got] == list(PLAN_GOLDENS.values())
    host = plan_matmul_blocks_batched(shapes, dtype_bytes=dbs,
                                      vmem_budget=budgets,
                                      allocator_backend="numpy")
    assert host == got


def test_planned_blocks_pad_aware_run_correctly():
    """A prime-dim plan runs through cbp_matmul after padding the operands
    to the planned blocks — the documented pad-aware contract."""
    from repro.kernels.cbp_matmul.kernel import cbp_matmul
    from repro.kernels.cbp_matmul.ref import matmul_ref
    m, n, k = 97, 64, 48
    bm, bn, bk = plan_matmul_blocks(m, n, k)
    assert (bm, bn, bk) == (104, 64, 48)
    mp = -(-m // bm) * bm
    a = jax.random.normal(jax.random.PRNGKey(2), (m, k))
    b = jax.random.normal(jax.random.PRNGKey(3), (k, n))
    a_pad = jnp.pad(a, ((0, mp - m), (0, 0)))
    out = cbp_matmul(a_pad, b, block_m=bm, block_n=bn, block_k=bk,
                     interpret=True)[:m]
    np.testing.assert_allclose(out, matmul_ref(a, b), atol=2e-5, rtol=2e-5)
