"""Pipeline-parallelism test (subprocess with 4 host devices: 2 pods)."""
import json
import pathlib
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow   # 4-device subprocess pipeline run

ROOT = pathlib.Path(__file__).resolve().parent.parent

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax
import jax.numpy as jnp
import numpy as np
from repro.distributed import make_mesh
from repro.train.pipeline import pipeline_apply

mesh = make_mesh((2, 2), ("pod", "data"))

D, L, S = 16, 4, 2          # 4 layers, 2 stages
rng = jax.random.PRNGKey(0)
ws = jax.random.normal(rng, (L, D, D)) * 0.3
stage_ws = ws.reshape(S, L // S, D, D)

def stage_fn(params, x):     # params: (L/S, D, D)
    def body(x, w):
        return jnp.tanh(x @ w), None
    x, _ = jax.lax.scan(body, x, params)
    return x

x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, D))  # 4 microbatches

out = pipeline_apply(stage_fn, stage_ws, x, mesh, axis="pod")

# sequential reference
def ref_fn(x):
    def body(x, w):
        return jnp.tanh(x @ w), None
    x, _ = jax.lax.scan(body, x, ws)
    return x
ref = jax.vmap(ref_fn)(x)
err = float(jnp.abs(out - ref).max())

# gradients flow through the pipeline
def loss(ws_stages):
    o = pipeline_apply(stage_fn, ws_stages, x, mesh, axis="pod")
    return jnp.sum(o ** 2)
g = jax.grad(loss)(stage_ws)
gnorm = float(jnp.linalg.norm(g.reshape(-1)))
print("RESULT:" + json.dumps({"err": err, "gnorm": gnorm,
                              "finite": bool(np.isfinite(gnorm))}))
'''


def test_pipeline_matches_sequential_and_differentiates():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        cwd=ROOT)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][0]
    r = json.loads(line[len("RESULT:"):])
    assert r["err"] < 1e-5, r
    assert r["finite"] and r["gnorm"] > 0, r
