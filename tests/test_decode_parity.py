"""Decode-vs-forward logits parity: feeding tokens one at a time through
``decode_step`` (with the optimized one-hot/grouped-GQA cache path) must
reproduce the full-sequence forward's next-token logits.  This is the
strongest end-to-end correctness check of the serving path — it exercises
the KV ring buffer, RoPE position handling, GQA grouping, SSM state
updates and the hybrid shared-attention cache at once."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build

T = 12


def _full_forward_logits(model, params, tokens):
    """Next-token logits at every position via the training-path forward."""
    cfg = model.cfg
    from repro.models import hybrid, ssm, transformer
    x = transformer.embed(params, cfg, tokens)
    positions = jnp.arange(x.shape[1])
    if cfg.family == "hybrid":
        hidden = hybrid.forward(params, cfg, x, positions)
    elif cfg.family == "ssm":
        def body(x, lp):
            return ssm.mamba_block(lp, cfg, x), None
        hidden, _ = jax.lax.scan(body, x, params["layers"])
        from repro.models import layers as L
        hidden = L.rms_norm(hidden, params["final_norm"], cfg.norm_eps)
    else:
        hidden = transformer.forward(params, cfg, x, positions)
    return transformer.logits_fn(params, cfg, hidden)


def _decode_logits(model, params, tokens):
    cfg = model.cfg
    cache = model.init_cache(tokens.shape[0], T + 4, dtype=jnp.float32)
    outs = []
    for i in range(tokens.shape[1]):
        logits, cache = model.decode_step(
            params, cache, tokens[:, i: i + 1], jnp.asarray(i, jnp.int32))
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1)  # (B, T, V)


@pytest.mark.parametrize("arch", ["qwen3-8b", "yi-34b", "mamba2-1.3b",
                                  "zamba2-7b", "qwen3-moe-30b-a3b"])
def test_decode_matches_forward(arch):
    cfg = configs.get_smoke(arch)
    # decode must use the training numerics for the comparison
    cfg = dataclasses.replace(cfg, attn_chunk=T)
    if cfg.n_experts:
        # Capacity dropping is batch-dependent by construction (GShard);
        # exact parity requires a drop-free capacity. The dropping path is
        # covered by the moe smoke tests.
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(
        jax.random.PRNGKey(1), (2, T), 0, cfg.vocab_size, dtype=jnp.int32)
    ref = np.asarray(_full_forward_logits(model, params, tokens),
                     dtype=np.float32)
    dec = np.asarray(_decode_logits(model, params, tokens),
                     dtype=np.float32)
    # compare next-token distributions position by position
    np.testing.assert_allclose(dec, ref, atol=2e-3, rtol=2e-3)


def test_decode_parity_with_int8_kv_close():
    """int8 KV quantization (the §Perf C4 knob) stays close in argmax."""
    cfg = dataclasses.replace(
        configs.get_smoke("qwen3-8b"), attn_chunk=T,
        kv_cache_dtype="int8")
    model = build(cfg)
    cfg_ref = dataclasses.replace(cfg, kv_cache_dtype="bfloat16")
    model_ref = build(cfg_ref)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(
        jax.random.PRNGKey(1), (2, T), 0, cfg.vocab_size, dtype=jnp.int32)
    dec8 = np.asarray(_decode_logits(model, params, tokens))
    dec16 = np.asarray(_decode_logits(model_ref, params, tokens))
    agree = np.mean(dec8.argmax(-1) == dec16.argmax(-1))
    assert agree >= 0.8, agree
