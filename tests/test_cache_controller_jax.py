"""Batched JAX Lookahead allocator vs the numpy golden reference.

Contract (see ``src/repro/core/cache_controller_jax.py``): bit-identical
allocations away from tie knife-edges, under the documented deterministic
tie-breaks (lowest client index wins equal marginal utility; smallest step
wins within a client; the zero-utility spread orders by remaining gain with
a stable sort).  Random float curves make exact mu ties measure-zero, so
these tests assert exact equality.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    CacheController,
    allocator_calls,
    cppf_allocate,
    lookahead_allocate,
)
from repro.core import cache_controller_jax as ccj


def _concave_curves(rng, n, total):
    u = np.arange(total + 1, dtype=np.float64)
    scales = rng.uniform(0.0, 50.0, size=n)
    rates = rng.uniform(2.0, 40.0, size=n)
    return scales[:, None] * (1.0 - np.exp(-u[None, :] / rates[:, None]))


def _nonmonotone_curves(rng, n, total):
    return np.cumsum(rng.normal(0.0, 1.0, size=(n, total + 1)), axis=1)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 8),
    total=st.integers(24, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_batched_matches_reference_on_monotone_curves(n, total, seed):
    rng = np.random.default_rng(seed)
    curves = _concave_curves(rng, n, total)
    min_units = int(rng.integers(0, max(total // n, 1)))
    ref = lookahead_allocate(curves, total, min_units)
    got = ccj.lookahead_allocate(curves, total, min_units)
    np.testing.assert_array_equal(ref, got)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 8),
    total=st.integers(24, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_batched_matches_reference_on_nonmonotone_curves(n, total, seed):
    """Non-monotone curves exercise negative marginal utilities and the
    spread-remainder branch (max mu <= 0 mid-distribution)."""
    rng = np.random.default_rng(seed)
    curves = _nonmonotone_curves(rng, n, total)
    min_units = int(rng.integers(0, max(total // n, 1)))
    ref = lookahead_allocate(curves, total, min_units)
    got = ccj.lookahead_allocate(curves, total, min_units)
    np.testing.assert_array_equal(ref, got)


def test_spread_remainder_branch_flat_curves():
    """Zero utility everywhere: the even-spread branch fires immediately
    and both backends distribute the whole balance the same way."""
    total = 37
    for n in (2, 3, 5):
        curves = np.zeros((n, total + 1))
        ref = lookahead_allocate(curves, total, min_units=2)
        got = ccj.lookahead_allocate(curves, total, min_units=2)
        np.testing.assert_array_equal(ref, got)
        assert got.sum() == total


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), min_units=st.integers(1, 5))
def test_batched_respects_min_units_floor(seed, min_units):
    rng = np.random.default_rng(seed)
    n, total = 6, 64
    curves = _nonmonotone_curves(rng, n, total)
    got = ccj.lookahead_allocate(curves, total, min_units)
    assert (got >= min_units).all()
    assert got.sum() == total
    np.testing.assert_array_equal(
        got, lookahead_allocate(curves, total, min_units))


def test_batched_leading_axes_and_per_batch_min_units():
    rng = np.random.default_rng(3)
    n, total = 5, 40
    curves = np.stack([
        np.stack([_concave_curves(rng, n, total) for _ in range(3)])
        for _ in range(2)])                        # (2, 3, n, U+1)
    mins = np.array([[1, 2, 3], [4, 0, 2]])        # broadcast per element
    got = ccj.lookahead_allocate(curves, total, mins)
    assert got.shape == (2, 3, n)
    for i in range(2):
        for j in range(3):
            np.testing.assert_array_equal(
                got[i, j],
                lookahead_allocate(curves[i, j], total, int(mins[i, j])))


def test_batched_rejects_infeasible_inputs():
    with pytest.raises(ValueError):
        ccj.lookahead_allocate(np.zeros((4, 9)), 8, min_units=4)
    with pytest.raises(ValueError):
        ccj.lookahead_allocate(np.zeros((4, 12)), 8, min_units=4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 8),
    total=st.integers(24, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_matches_cppf_reference(n, total, seed):
    """The CPpf friendly-mask variant matches the scalar subset call,
    including the min_units pinning of inactive clients."""
    rng = np.random.default_rng(seed)
    curves = np.cumsum(
        np.abs(rng.normal(0.0, 1.0, size=(n, total + 1))), axis=1)
    min_units = int(rng.integers(1, max(total // n, 2)))
    active = rng.integers(0, 2, size=n).astype(bool)
    ref = cppf_allocate(curves, total, min_units, active)
    got = ccj.lookahead_allocate_masked(curves, total, min_units, active)
    np.testing.assert_array_equal(ref, got)
    assert got.sum() == total
    if active.any():   # otherwise the even-split exceeds the floor
        assert (got[~active] == min_units).all()


def test_masked_all_inactive_distributes_remainder():
    """All-friendly CPpf mixes: capacity splits evenly and the remainder
    goes to the lowest-index clients — no unit is dropped (the former
    floor-division bug)."""
    total, n, min_units = 30, 4, 4
    curves = np.zeros((n, total + 1))
    ref = cppf_allocate(curves, total, min_units, np.zeros(n, dtype=bool))
    got = ccj.lookahead_allocate_masked(
        curves, total, min_units, np.zeros(n, dtype=bool))
    np.testing.assert_array_equal(ref, got)
    assert ref.sum() == total          # 30 = 8 + 8 + 7 + 7
    np.testing.assert_array_equal(ref, [8, 8, 7, 7])


def test_cache_controller_backend_dispatch():
    """Both backends agree through the CacheController facade, and only
    the numpy backend touches the host allocator counter."""
    rng = np.random.default_rng(11)
    n, total = 6, 48
    batch = np.stack([_nonmonotone_curves(rng, n, total) for _ in range(4)])
    ctl_np = CacheController(total, min_units=2, backend="numpy")
    ctl_jx = CacheController(total, min_units=2, backend="jax")

    before = allocator_calls()
    out_np = ctl_np.allocate(batch)
    assert allocator_calls() - before == 4      # one host call per element

    before = allocator_calls()
    out_jx = ctl_jx.allocate(batch)
    assert allocator_calls() - before == 0      # device-resident
    np.testing.assert_array_equal(out_np, out_jx)

    active = rng.integers(0, 2, size=(4, n)).astype(bool)
    np.testing.assert_array_equal(
        ctl_np.allocate_masked(batch, active),
        ctl_jx.allocate_masked(batch, active))

    # "pallas" is a valid backend since the lookahead_greedy kernel landed
    # (tests/test_lookahead_kernel.py); anything else still rejects.
    with pytest.raises(ValueError):
        CacheController(total, backend="mosaic")


def _adversarial_refresh_curves(n, U):
    """Worst case for the greedy's trip count: client 0 is concave (best
    step 1, highest mu early — many one-unit steps), every other client
    convex with near-tied shapes, so the best step and its owner keep
    shifting as the balance cap shrinks.  Under the one-stale-client
    incremental refresh this maximizes cache invalidations between
    greedy steps — each step dirties the winner AND shrinks every other
    client's cap, forcing refresh trips before the next step."""
    u = np.arange(U + 1, dtype=np.float64)
    curves = np.empty((n, U + 1))
    curves[0] = 100.0 * (1.0 - np.exp(-u / 3.0))
    for i in range(1, n):
        curves[i] = (u / U) ** 2 * (80.0 - 0.5 * i)
    return curves


def test_greedy_loop_trip_bound_never_abandons_live_rows():
    """Satellite audit of the ``_greedy_loop`` trip bound.  The
    incremental-refresh loop runs under an ``(n + 2) * U`` bound, which
    is safe: the greedy takes <= U unit-consuming steps per row, and
    between consecutive steps each of the n clients refreshes at most
    once (a refreshed entry stays valid until the next step dirties the
    winner or shrinks the cap below its k), so body applications are
    bounded by n * U + 1 < (n + 2) * U.  The adversarial curve family
    maximizes invalidations between steps; the loop must still exit with
    every row finished (balance drained or stuck), never via the bound —
    abandoning a live row would silently hand a short allocation to the
    zero-spread tail."""
    import jax.numpy as jnp

    n, U = 8, 96
    curves = np.stack([
        _adversarial_refresh_curves(n, U),
        _nonmonotone_curves(np.random.default_rng(0), n, U),
        np.zeros((n, U + 1)),
        _concave_curves(np.random.default_rng(1), n, U),
    ])
    mins = np.array([0, 3, 2, 1])
    with ccj._x64_context():
        alloc, balance, stuck, it = map(np.asarray, ccj._greedy_loop(
            jnp.asarray(curves, jnp.float64), jnp.asarray(mins),
            jnp.ones((4, n), dtype=bool),
            jnp.full((4,), U, dtype=jnp.int32), total_units=U))
    # The loop retired every row on its own terms, not via the bound.
    assert int(it) < (n + 2) * U
    assert np.all((balance == 0) | stuck)
    assert np.all(balance >= 0)
    # And the full pipeline (greedy + spread) still matches the golden.
    got = ccj.lookahead_allocate(curves, U, mins)
    for b in range(4):
        np.testing.assert_array_equal(
            got[b], lookahead_allocate(curves[b], U, int(mins[b])))
