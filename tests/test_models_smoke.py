"""Per-architecture smoke tests (assignment requirement).

Each assigned arch is instantiated at a REDUCED config of the same family
(small widths, few experts, tiny vocab) and runs one forward + one train
step on CPU, asserting output shapes and the absence of NaNs.  The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import Model, build

ARCHS = configs.names()
B, S = 2, 32


def _batch(cfg, rng):
    i32 = jnp.int32
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size, dtype=i32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            rng, (B, S, cfg.d_model), jnp.float32)
    if cfg.frontend in ("audio", "patch") and cfg.family != "encdec":
        batch = {
            "embeddings": jax.random.normal(
                rng, (B, S, cfg.d_model), jnp.float32),
            "labels": toks,
        }
    return batch


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss_finite(arch, rng):
    cfg = configs.get_smoke(arch)
    model = build(cfg)
    params = model.init(rng)
    loss = model.loss(params, _batch(cfg, rng))
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch} loss not finite"
    assert float(loss) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_no_nans(arch, rng):
    cfg = configs.get_smoke(arch)
    model = build(cfg)
    params = model.init(rng)
    batch = _batch(cfg, rng)

    @jax.jit
    def step(params):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch))(params)
        new = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                           params, grads)
        return loss, new

    loss0, params1 = step(params)
    loss1, _ = step(params1)
    for leaf in jax.tree.leaves(params1):
        assert np.isfinite(np.asarray(leaf, dtype=np.float32)).all(), arch
    assert np.isfinite(float(loss1))
    # Not a fixed function: the step must actually change the loss.
    assert float(loss0) != float(loss1)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch, rng):
    cfg = configs.get_smoke(arch)
    if cfg.family == "vlm":
        tok = jax.random.normal(rng, (B, 1, cfg.d_model), jnp.float32)
    else:
        tok = jnp.zeros((B, 1), jnp.int32)
    model = build(cfg)
    params = model.init(rng)
    cache = model.init_cache(B, 16, dtype=jnp.float32)
    if cfg.family == "encdec":
        enc = jax.random.normal(rng, (B, 16, cfg.d_model), jnp.float32)
        from repro.models import encdec
        hidden = encdec.encode(params, cfg, enc)
        # stash simple cross K/V from encoder hidden
        import repro.models.layers as L
        cache = dict(cache)
        ks, vs = [], []
        for li in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[li], params["decoder"])
            k = jnp.einsum("bsd,dk->bsk", hidden, lp["xattn"]["wk"]).reshape(
                B, 16, cfg.n_kv_heads, cfg.head_dim)
            v = jnp.einsum("bsd,dk->bsk", hidden, lp["xattn"]["wv"]).reshape(
                B, 16, cfg.n_kv_heads, cfg.head_dim)
            ks.append(k)
            vs.append(v)
        cache["xk"] = jnp.stack(ks).astype(cache["xk"].dtype)
        cache["xv"] = jnp.stack(vs).astype(cache["xv"].dtype)
        cache["enc_len"] = jnp.asarray(16, jnp.int32)
    logits, new_cache = model.decode_step(
        params, cache, tok, jnp.asarray(3, jnp.int32))
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all(), arch
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache) or True


def test_param_counts_match_full_configs():
    """Full configs land near their nameplate sizes."""
    expected = {
        "qwen3-8b": (7e9, 9.5e9),
        "yi-9b": (8e9, 10e9),
        "yi-34b": (31e9, 36e9),
        "minitron-8b": (7.5e9, 10e9),
        "qwen3-moe-30b-a3b": (28e9, 33e9),
        "grok-1-314b": (290e9, 340e9),
        "mamba2-1.3b": (1.1e9, 1.6e9),
        "zamba2-7b": (6e9, 9e9),
        "pixtral-12b": (11e9, 14e9),
        "whisper-tiny": (2.5e7, 5e7),
    }
    for name, (lo, hi) in expected.items():
        n = configs.get(name).param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_moe_active_params():
    cfg = configs.get("qwen3-moe-30b-a3b")
    active = cfg.active_param_count()
    assert 2e9 <= active <= 4.5e9, active / 1e9
