"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
shape/dtype sweeps, and hypothesis property tests (assignment requirement:
"for each Pallas kernel, sweep shapes/dtypes and assert_allclose against
the ref.py pure-jnp oracle")."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.cbp_matmul.kernel import cbp_matmul, vmem_footprint_bytes
from repro.kernels.cbp_matmul.ref import matmul_ref
from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode.kernel import flash_decode
from repro.kernels.flash_decode.ref import decode_ref
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref

# Interpret-mode Pallas emulation is slow on CPU — the whole file sits in
# the slow tier (deselected by default, run by CI and -m "slow or not slow").
pytestmark = pytest.mark.slow

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _qkv(rng, b, h, s, d, dtype):
    ks = jax.random.split(rng, 3)
    return tuple(
        jax.random.normal(k, (b, h, s, d), jnp.float32).astype(dtype)
        for k in ks)


# ------------------------- flash attention ------------------------- #


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (1, 1, 128, 64), (2, 3, 256, 64), (1, 2, 512, 128), (2, 1, 256, 32),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(dtype, shape, causal):
    b, h, s, d = shape
    q, k, v = _qkv(jax.random.PRNGKey(0), b, h, s, d, dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, block_q=64,
                              block_kv=64, interpret=True)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=causal)
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref, atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("block_q,block_kv", [(32, 64), (64, 32),
                                              (128, 128), (64, 128)])
def test_flash_attention_block_invariance(block_q, block_kv):
    """CBP VMEM-knob settings change scheduling, never results."""
    q, k, v = _qkv(jax.random.PRNGKey(1), 2, 2, 256, 64, jnp.float32)
    ref = attention_ref(q, k, v, causal=True)
    out = flash_attention_fwd(q, k, v, causal=True, block_q=block_q,
                              block_kv=block_kv, interpret=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    s_blocks=st.integers(1, 4),
    h=st.integers(1, 3),
    seed=st.integers(0, 1000),
)
def test_flash_attention_property(s_blocks, h, seed):
    s = 64 * s_blocks
    q, k, v = _qkv(jax.random.PRNGKey(seed), 1, h, s, 32, jnp.float32)
    out = flash_attention_fwd(q, k, v, causal=True, block_q=64,
                              block_kv=64, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


# --------------------------- flash decode -------------------------- #


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("smax,cur_len", [(256, 256), (256, 100),
                                          (512, 1), (512, 511)])
def test_flash_decode_matches_ref(dtype, smax, cur_len):
    rng = jax.random.PRNGKey(2)
    b, h, d = 2, 4, 64
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32).astype(dtype)
    kc = jax.random.normal(ks[1], (b, h, smax, d), jnp.float32).astype(dtype)
    vc = jax.random.normal(ks[2], (b, h, smax, d), jnp.float32).astype(dtype)
    out = flash_decode(q, kc, vc, jnp.asarray(cur_len, jnp.int32),
                       block_kv=128, interpret=True)
    ref = decode_ref(q.astype(jnp.float32), kc.astype(jnp.float32),
                     vc.astype(jnp.float32), cur_len)
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref, atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_decode_ignores_cache_tail():
    """Positions >= cur_len must not influence the output (ring-buffer
    garbage safety)."""
    rng = jax.random.PRNGKey(3)
    b, h, smax, d = 1, 2, 256, 32
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, h, d))
    kc = jax.random.normal(ks[1], (b, h, smax, d))
    vc = jax.random.normal(ks[2], (b, h, smax, d))
    out1 = flash_decode(q, kc, vc, jnp.asarray(77), block_kv=64,
                        interpret=True)
    kc2 = kc.at[:, :, 77:].set(1e6)
    vc2 = vc.at[:, :, 77:].set(-1e6)
    out2 = flash_decode(q, kc2, vc2, jnp.asarray(77), block_kv=64,
                        interpret=True)
    np.testing.assert_allclose(out1, out2, atol=1e-6)


# ----------------------------- SSD scan ---------------------------- #


def _ssd_inputs(rng, b, s, h, p, n, dtype=jnp.float32):
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    Cm = jax.random.normal(ks[4], (b, s, n)) * 0.5
    return (x.astype(dtype), dt.astype(dtype), A, Bm.astype(dtype),
            Cm.astype(dtype))


@pytest.mark.parametrize("shape", [
    (1, 64, 1, 8, 8), (2, 128, 3, 8, 16), (1, 256, 2, 16, 16),
])
@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_ssd_scan_matches_sequential_ref(shape, chunk):
    b, s, h, p, n = shape
    x, dt, A, Bm, Cm = _ssd_inputs(jax.random.PRNGKey(4), b, s, h, p, n)
    out = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    ref = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(out, ref, atol=2e-4, rtol=2e-4)


def test_ssd_model_path_matches_ref():
    """The model's chunked jnp implementation is the same math."""
    from repro.models.ssm import ssd_chunked
    x, dt, A, Bm, Cm = _ssd_inputs(jax.random.PRNGKey(5), 2, 128, 4, 8, 16)
    y, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    ref = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y, ref, atol=2e-4, rtol=2e-4)


@settings(max_examples=8, deadline=None)
@given(chunk_pow=st.integers(4, 6), seed=st.integers(0, 500))
def test_ssd_chunk_invariance(chunk_pow, seed):
    """Chunk length is a pure scheduling knob (CBP VMEM partition)."""
    x, dt, A, Bm, Cm = _ssd_inputs(jax.random.PRNGKey(seed), 1, 128, 2, 8, 8)
    out = ssd_scan(x, dt, A, Bm, Cm, chunk=2 ** chunk_pow, interpret=True)
    ref = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(out, ref, atol=3e-4, rtol=3e-4)


# ---------------------------- cbp matmul --------------------------- #


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("blocks", [(64, 64, 64), (128, 64, 32),
                                    (32, 128, 64)])
def test_cbp_matmul_matches_ref(dtype, blocks):
    bm, bn, bk = blocks
    rng = jax.random.PRNGKey(6)
    k1, k2 = jax.random.split(rng)
    a = jax.random.normal(k1, (256, 128), jnp.float32).astype(dtype)
    b = jax.random.normal(k2, (128, 256), jnp.float32).astype(dtype)
    out = cbp_matmul(a, b, block_m=bm, block_n=bn, block_k=bk,
                     interpret=True)
    ref = matmul_ref(a, b)
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("shape", [(97, 53, 160), (130, 70, 96)])
def test_cbp_matmul_pad_aware_planned_blocks(shape):
    """Prime/odd dims: the pad-aware planner returns blocks tiling
    ``ceil(dim / block) * block``; the kernel zero-pads the operands to
    that extent (exact for a matmul) and slices the result back."""
    from repro.runtime.cbp_runtime import plan_matmul_blocks

    m, n, k = shape
    bm, bn, bk = plan_matmul_blocks(m, n, k, dtype_bytes=4)
    assert bm % 8 == 0 or bm >= m  # snapped or full-extent tiling
    rng = jax.random.PRNGKey(9)
    k1, k2 = jax.random.split(rng)
    a = jax.random.normal(k1, (m, k), jnp.float32)
    b = jax.random.normal(k2, (k, n), jnp.float32)
    out = cbp_matmul(a, b, block_m=bm, block_n=bn, block_k=bk,
                     interpret=True)
    assert out.shape == (m, n)
    np.testing.assert_allclose(out, matmul_ref(a, b), atol=1e-4, rtol=1e-4)


def test_vmem_footprint_monotone():
    f1 = vmem_footprint_bytes(64, 64, 64)
    f2 = vmem_footprint_bytes(128, 128, 128)
    assert f2 > f1
    assert f1 > 0
