"""Streaming sweep service: fault classes, resume parity, workload goldens.

The contracts under test (``src/repro/sim/stream_sweep.py`` docstring):

* chunked online aggregation is invariant to chunk size and to pipeline
  overlap, bit-for-bit;
* every injected fault class (dispatch error, NaN poison, kill, straggle)
  lands in its designed recovery path — retry, quarantine + explicit
  coverage, checkpoint/resume, watchdog — never in silent truncation;
* a killed-and-resumed run reproduces the uninterrupted run's final
  aggregates bit-identically;
* chunk generation is a pure function of ``(seed, chunk_index)`` (golden
  pinned, so a refactor cannot silently reshuffle a 10^6-mix stream).
"""
import pathlib

import numpy as np
import pytest

from repro.core import device_dispatches
from repro.runtime.fault import StragglerWatchdog
from repro.runtime.faultinject import (
    FaultPlan,
    FaultSpec,
    InjectedDispatchError,
    InjectedProcessKill,
)
from repro.sim.stream_sweep import (
    CheckpointMismatchError,
    NumericalDivergenceError,
    RetryPolicy,
    StreamAbortedError,
    StreamAggregates,
    StreamConfig,
    run_stream,
)
from repro.sim.workloads import (
    StreamScenario,
    iter_mix_index_chunks,
    mix_index_chunk,
    names_from_indices,
    params_from_indices,
    scenario_chunk,
)

_NO_SLEEP = lambda s: None  # noqa: E731 — backoff must not slow tests


def _cfg(**kw):
    base = dict(
        n_mixes=16, chunk_size=4, managers=("baseline", "CBP"),
        total_ms=20.0, seed=7,
        scenario=StreamScenario(apps_per_mix=6),
    )
    base.update(kw)
    return StreamConfig(**base)


def _trees_equal(a, b):
    ta, tb = a.aggregates.to_tree(), b.aggregates.to_tree()
    return all(np.array_equal(ta[k], tb[k], equal_nan=True) for k in ta)


# ------------------------- workload goldens ------------------------- #


def test_mix_index_chunk_golden():
    """Seed-stability pin: chunk generation is a pure function of
    (seed, chunk_index) — these exact rows anchor every resumable run."""
    idx = mix_index_chunk(0, 0, 4)
    assert idx.shape == (4, 16) and idx.dtype == np.int32
    assert idx[0].tolist() == [11, 24, 24, 24, 15, 21, 25, 5, 5, 16, 8,
                               21, 0, 26, 2, 22]
    assert idx[3].tolist() == [6, 22, 11, 26, 11, 19, 23, 28, 25, 27, 19,
                               1, 20, 24, 19, 18]
    assert mix_index_chunk(0, 1, 4)[0].tolist() == [
        22, 19, 27, 1, 3, 27, 20, 0, 16, 3, 2, 8, 13, 3, 6, 23]
    # regenerating any chunk independently gives the identical array
    np.testing.assert_array_equal(idx, mix_index_chunk(0, 0, 4))


def test_iter_mix_index_chunks_truncates_and_bounds_memory():
    chunks = list(iter_mix_index_chunks(10, 4, seed=3))
    assert [c.shape[0] for c in chunks] == [4, 4, 2]
    # chunked iteration is a view of the same stream: chunk c equals the
    # standalone generation of chunk c
    np.testing.assert_array_equal(chunks[1], mix_index_chunk(3, 1, 4))
    # last chunk is a prefix of its full generation
    np.testing.assert_array_equal(chunks[2], mix_index_chunk(3, 2, 4)[:2])


def test_params_from_indices_matches_names():
    idx = mix_index_chunk(5, 0, 3)
    params = params_from_indices(idx)
    names = names_from_indices(idx)
    from repro.sim.apps import PROFILES

    assert params["mpki_min_alloc"].shape == (3, 16)
    for m in range(3):
        for a in range(16):
            assert params["cpi_base"][m, a] == PROFILES[names[m][a]].cpi_base


def test_scenario_chunk_deterministic_and_shaped():
    sc = StreamScenario(apps_per_mix=6, popularity="zipf",
                        diurnal_period_chunks=4, phase_app_fraction=0.5)
    a = scenario_chunk(sc, 11, 3, 8)
    b = scenario_chunk(sc, 11, 3, 8)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    assert a["mpki_min_alloc"].shape == (8, 6)
    # phase drift: the same scenario at another chunk differs
    c = scenario_chunk(sc, 11, 5, 8)
    assert not np.array_equal(a["mpki_min_alloc"], c["mpki_min_alloc"])


def test_zipf_popularity_concentrates_catalog():
    sc = StreamScenario(apps_per_mix=6, popularity="zipf",
                        zipf_exponent=1.5, catalog_size=64)
    rows = [scenario_chunk(sc, 0, c, 32)["mpki_min_alloc"] for c in range(4)]
    flat = np.concatenate([r.ravel() for r in rows])
    # heavy tail: a handful of catalog templates dominate the stream
    _, counts = np.unique(flat, return_counts=True)
    assert counts.max() > 4 * np.median(counts)


# ---------------------- aggregate fold closed-form ------------------ #


def test_aggregates_fold_hand_computed_chunk():
    """One hand-computed chunk (K=2 managers, M=2 mixes, n=3 apps) pins
    the histogram fold — including the overflow bucket — and the min-
    fairness fold against closed-form values.

    hist_bins=5, hist_max=4.0 -> bin_width = 4.0 / (5 - 1) = 1.0, and
    bin 4 is the overflow bucket for any slowdown >= 4.0."""
    agg = StreamAggregates(n_managers=2, hist_bins=5, hist_max_slowdown=4.0)
    assert agg.bin_width == 1.0
    ws = np.array([[1.2, 1.5], [1.0, 2.0]])
    slowdown = np.array([
        [[0.5, 1.5, 2.5], [3.5, 10.0, 0.2]],   # bins 0,1,2 | 3, OVF, 0
        [[1.0, 1.0, 1.0], [1.0, 1.0, 9.0]],    # bins 1,1,1 | 1, 1, OVF
    ])
    fairness = np.array([[0.8, 0.6], [0.9, 0.7]])
    agg.fold(ws, slowdown, fairness)

    np.testing.assert_array_equal(agg.slowdown_hist,
                                  [[2, 1, 1, 1, 1], [0, 5, 0, 0, 1]])
    np.testing.assert_array_equal(agg.mix_count, [2, 2])
    np.testing.assert_array_equal(agg.max_slowdown, [10.0, 9.0])
    np.testing.assert_array_equal(agg.min_fairness, [0.6, 0.7])
    np.testing.assert_allclose(
        agg.geomean_ws(), [np.sqrt(1.2 * 1.5), np.sqrt(2.0)], rtol=1e-15)

    # Sketch percentiles, closed form (target = q * total, total = 6):
    # m0 cum=[2,3,4,5,6]: p50 target 3.0 lands at bin 1 filled -> 2.0;
    # m1 cum=[0,5,5,5,6]: p50 target 3.0 is 3/5 through bin 1 -> 1.6.
    np.testing.assert_allclose(agg.slowdown_percentile(0.5), [2.0, 1.6])
    # p90 target 5.4: both 0.4 into the overflow bin -> (4 + 0.4) * 1.0;
    # overflow readings sit above hist_max by design (sketch saturation).
    np.testing.assert_allclose(agg.slowdown_percentile(0.9), [4.4, 4.4])
    np.testing.assert_allclose(agg.slowdown_percentile(0.99), [4.94, 4.94])


def test_aggregates_fold_accumulates_across_chunks():
    """Second fold: histograms add, min-fairness takes the running min,
    max-slowdown the running max — and the percentile tracks the merged
    histogram exactly."""
    agg = StreamAggregates(n_managers=2, hist_bins=5, hist_max_slowdown=4.0)
    agg.fold(np.array([[1.2, 1.5], [1.0, 2.0]]),
             np.array([[[0.5, 1.5, 2.5], [3.5, 10.0, 0.2]],
                       [[1.0, 1.0, 1.0], [1.0, 1.0, 9.0]]]),
             np.array([[0.8, 0.6], [0.9, 0.7]]))
    agg.fold(np.ones((2, 2)),
             np.full((2, 2, 3), 0.1),                # all bin 0
             np.array([[0.9, 0.95], [0.5, 0.8]]))

    np.testing.assert_array_equal(agg.slowdown_hist,
                                  [[8, 1, 1, 1, 1], [6, 5, 0, 0, 1]])
    np.testing.assert_array_equal(agg.mix_count, [4, 4])
    np.testing.assert_array_equal(agg.min_fairness, [0.6, 0.5])
    np.testing.assert_array_equal(agg.max_slowdown, [10.0, 9.0])
    np.testing.assert_allclose(agg.geomean_ws(),
                               [1.8 ** 0.25, 2.0 ** 0.25], rtol=1e-15)
    # p50 target 6 of 12: m0 is 6/8 through bin 0 -> 0.75; m1's cum hits
    # exactly 6 at bin 0's edge -> 1.0 (left searchsorted keeps bin 0).
    np.testing.assert_allclose(agg.slowdown_percentile(0.5), [0.75, 1.0])


def test_aggregates_empty_percentile_is_nan():
    agg = StreamAggregates(n_managers=1, hist_bins=4, hist_max_slowdown=2.0)
    assert np.isnan(agg.slowdown_percentile(0.5)).all()


# -------------------------- fault plan unit ------------------------- #


def test_fault_plan_hooks_and_helpers():
    plan = FaultPlan((FaultSpec("dispatch_error", 1, count=2),
                      FaultSpec("nan_poison", 2),
                      FaultSpec("kill", 3),
                      FaultSpec("straggle", 0, seconds=2.5)))
    plan.on_chunk_start(0)
    with pytest.raises(InjectedProcessKill):
        plan.on_chunk_start(3)
    with pytest.raises(InjectedDispatchError):
        plan.on_dispatch(1, 0)
    with pytest.raises(InjectedDispatchError):
        plan.on_dispatch(1, 1)
    plan.on_dispatch(1, 2)  # third attempt succeeds
    assert plan.poisons(2) and not plan.poisons(1)
    assert plan.straggle_seconds(0) == 2.5
    assert plan.kill_chunks() == [3]
    assert plan.without_kills().kill_chunks() == []
    assert FaultPlan.from_dicts(plan.to_dicts()).to_dicts() == plan.to_dicts()
    with pytest.raises(ValueError):
        FaultPlan((FaultSpec("nan_poison", 2), FaultSpec("nan_poison", 2)))
    with pytest.raises(ValueError):
        FaultSpec("frobnicate", 0)


def test_fault_plan_seeded_deterministic():
    mk = lambda: FaultPlan.seeded(9, 50, p_dispatch_error=0.2,  # noqa: E731
                                  p_nan_poison=0.1, p_straggle=0.1)
    assert mk().to_dicts() == mk().to_dicts()
    assert mk().kill_chunks() == []  # kills are never drawn randomly


# ------------------- watchdog warm-up regression -------------------- #


def test_watchdog_median_warmup_survives_compile_spike():
    """Regression: a jit-compile spike on step 0 used to seed the EWMA so
    high that genuine stragglers later never crossed threshold x ewma."""
    slow_first = [50.0, 1.0, 1.1] + [1.0] * 5 + [4.0, 4.0, 4.0]
    wd = StragglerWatchdog(threshold=2.0, quarantine_after=3, warmup=3)
    trig = [wd.observe(i, t) for i, t in enumerate(slow_first)]
    assert len(wd.events) == 3 and wd.mitigations == 1 and trig[-1]
    # the old seed-from-first-observation behaviour (warmup=1) misses them
    wd_old = StragglerWatchdog(threshold=2.0, quarantine_after=3, warmup=1)
    for i, t in enumerate(slow_first):
        assert not wd_old.observe(i, t)
    assert wd_old.events == []
    with pytest.raises(ValueError):
        StragglerWatchdog(warmup=0)


# ----------------------- stream service core ------------------------ #


def test_stream_overlap_matches_serial_bitwise():
    cfg = _cfg()
    r_overlap = run_stream(cfg, overlap=True)
    r_serial = run_stream(cfg, overlap=False)
    assert _trees_equal(r_overlap, r_serial)
    assert r_overlap.coverage == 1.0 and r_overlap.quarantined == []
    # "baseline" manager IS the equal-share reference: geomean ws == 1
    assert abs(r_overlap.geomean_ws["baseline"] - 1.0) < 1e-9
    assert r_overlap.geomean_ws["CBP"] > 1.0
    assert 0.0 < r_overlap.min_fairness["CBP"] <= 1.0


def test_stream_chunk_size_is_part_of_stream_identity(tmp_path):
    """Chunk generation is a pure function of (seed, chunk_index), so the
    chunk size IS part of the stream's identity: resuming with a different
    chunking must refuse rather than silently fold a different stream."""
    assert (_cfg(chunk_size=4).fingerprint()
            != _cfg(chunk_size=16).fingerprint())
    ckpt = str(tmp_path / "ck")
    run_stream(_cfg(checkpoint_dir=ckpt))
    with pytest.raises(CheckpointMismatchError):
        run_stream(_cfg(checkpoint_dir=ckpt, chunk_size=16), resume=True)


def test_stream_matches_direct_reference():
    """The online fold reproduces a direct (materialize-everything)
    evaluation of the same stream — aggregation adds no modelling error."""
    from repro.sim import memsys_jax, timeline_jax
    from repro.sim.runner import equal_share
    from repro.sim.sweep import _manager_spec
    from repro.sim.stream_sweep import _spec_plant

    cfg = _cfg()
    report = run_stream(cfg)
    from repro.core import CBPParams

    ws_all = {name: [] for name in cfg.manager_names}
    for c in range(cfg.n_chunks):
        params = scenario_chunk(cfg.scenario, cfg.seed, c, cfg.chunk_size)
        params.pop("mix_indices")
        n = cfg.scenario.apps_per_mix
        plant = _spec_plant(cfg.chunk_size, n, cfg.total_cache_units,
                            cfg.total_bandwidth)
        specs = [_manager_spec(plant, m, cfg.total_ms, cfg.params)
                 for m in cfg.manager_names]
        results = timeline_jax.run_timelines(
            params, specs, total_units=cfg.total_cache_units,
            total_bandwidth=cfg.total_bandwidth)
        units, bw = equal_share(n, cfg.total_cache_units,
                                cfg.total_bandwidth)
        base = np.asarray(memsys_jax.evaluate(
            params, np.tile(units.astype(np.float64), (cfg.chunk_size, 1)),
            np.tile(bw, (cfg.chunk_size, 1)),
            np.zeros((cfg.chunk_size, n), dtype=bool),
            cache_partitioned=False, bandwidth_partitioned=False,
            total_cache_units=float(cfg.total_cache_units),
            total_bandwidth_gbps=cfg.total_bandwidth).ipc)
        for name, res in zip(cfg.manager_names, results):
            ipc = res.ipc_acc / res.w_acc
            ws_all[name].append((ipc / base).mean(axis=-1))
    for name in cfg.manager_names:
        ref = np.exp(np.mean(np.log(np.concatenate(ws_all[name]))))
        assert abs(report.geomean_ws[name] - ref) < 1e-6, name


def test_stream_dispatch_budget():
    """3 recorded device programs per chunk (stacked + baseline + metrics),
    independent of chunk size — the streaming service may not regress to
    per-mix or per-manager dispatch."""
    cfg = _cfg()
    before = device_dispatches()
    run_stream(cfg)
    assert device_dispatches() - before == 3 * cfg.n_chunks


# ------------------------- fault classes ---------------------------- #


def test_stream_retry_then_success_bit_identical():
    cfg = _cfg()
    healthy = run_stream(cfg)
    slept = []
    plan = FaultPlan.single("dispatch_error", 1, count=2)
    r = run_stream(cfg, fault_plan=plan, sleep_fn=slept.append)
    assert r.retries == 2 and r.coverage == 1.0 and r.quarantined == []
    assert slept == [RetryPolicy().delay(0), RetryPolicy().delay(1)]
    assert _trees_equal(r, healthy)  # recovery leaves no trace in results


def test_stream_dispatch_exhaustion_quarantines():
    plan = FaultPlan.single("dispatch_error", 2, count=99)
    r = run_stream(_cfg(), fault_plan=plan, sleep_fn=_NO_SLEEP)
    assert [c for c, _ in r.quarantined] == [2]
    assert "dispatch_failed" in r.quarantined[0][1]
    assert "InjectedDispatchError" in r.quarantined[0][1]
    assert r.coverage == 12 / 16 and r.mixes_covered == 12


def test_stream_nan_poison_quarantined_with_named_culprit():
    plan = FaultPlan.single("nan_poison", 1)
    r = run_stream(_cfg(), fault_plan=plan, sleep_fn=_NO_SLEEP)
    assert [c for c, _ in r.quarantined] == [1]
    reason = r.quarantined[0][1]
    assert "baseline" in reason and "mix 4" in reason  # manager + global mix
    assert r.coverage == 12 / 16


def test_stream_nan_poison_raise_mode():
    plan = FaultPlan.single("nan_poison", 0)
    with pytest.raises(NumericalDivergenceError) as exc:
        run_stream(_cfg(on_divergence="raise"), fault_plan=plan,
                   sleep_fn=_NO_SLEEP)
    assert exc.value.manager == "baseline"
    assert exc.value.chunk_index == 0 and exc.value.mix_index == 0


def test_stream_aborts_on_consecutive_quarantines():
    plan = FaultPlan((FaultSpec("nan_poison", 0), FaultSpec("nan_poison", 1),
                      FaultSpec("nan_poison", 2)))
    with pytest.raises(StreamAbortedError):
        run_stream(_cfg(max_consecutive_quarantines=2), fault_plan=plan,
                   sleep_fn=_NO_SLEEP)


def test_stream_straggle_feeds_watchdog():
    plan = FaultPlan((FaultSpec("straggle", 2, seconds=50.0),
                      FaultSpec("straggle", 3, seconds=50.0)))
    r = run_stream(_cfg(watchdog_warmup=1, watchdog_threshold=3.0),
                   fault_plan=plan, sleep_fn=_NO_SLEEP)
    assert r.straggler_events == 2
    assert r.coverage == 1.0  # slow is not wrong: no quarantine


def test_stream_kill_resume_bit_parity(tmp_path):
    """The acceptance gate: dispatch failure retried, a poisoned chunk
    quarantined, a kill mid-run, resume — final aggregates bit-identical
    to the same-seed uninterrupted run with the same surviving faults."""
    ckpt = str(tmp_path / "ck")
    cfg = _cfg(checkpoint_dir=ckpt, checkpoint_every=1)
    plan = FaultPlan((FaultSpec("dispatch_error", 0, count=1),
                      FaultSpec("nan_poison", 1),
                      FaultSpec("kill", 2)))
    with pytest.raises(InjectedProcessKill):
        run_stream(cfg, fault_plan=plan, sleep_fn=_NO_SLEEP)
    resumed = run_stream(cfg, fault_plan=plan.without_kills(), resume=True,
                         sleep_fn=_NO_SLEEP)
    assert resumed.resumed_from is not None
    clean = run_stream(_cfg(), fault_plan=plan.without_kills(),
                       sleep_fn=_NO_SLEEP)
    assert _trees_equal(resumed, clean)
    assert resumed.coverage == clean.coverage == 12 / 16
    assert [c for c, _ in resumed.quarantined] == [1]
    assert resumed.retries >= 1


def test_stream_resume_refuses_foreign_checkpoint(tmp_path):
    ckpt = str(tmp_path / "ck")
    run_stream(_cfg(checkpoint_dir=ckpt))
    with pytest.raises(CheckpointMismatchError):
        run_stream(_cfg(checkpoint_dir=ckpt, seed=8), resume=True)


def test_stream_checkpoint_cadence(tmp_path):
    from repro.checkpoint import CheckpointManager

    ckpt = tmp_path / "ck"
    run_stream(_cfg(checkpoint_dir=str(ckpt), checkpoint_every=2))
    mgr = CheckpointManager(ckpt, keep=3)
    assert mgr.latest_step() == 4  # n_chunks, i.e. the stream completed
    assert mgr.all_steps() == [2, 4]  # cadence 2, keep-last-k pruned


def test_stream_config_validation():
    with pytest.raises(ValueError):
        _cfg(managers=("CBP", "nonsense"))
    with pytest.raises(ValueError):
        _cfg(on_divergence="explode")
    with pytest.raises(ValueError):
        _cfg(n_mixes=0)
    assert _cfg(n_mixes=10, chunk_size=4).n_chunks == 3
    assert _cfg().fingerprint() != _cfg(seed=8).fingerprint()
    assert _cfg().fingerprint() == _cfg().fingerprint()


# --------------- checkpoint crash-window atomicity ------------------ #


def test_checkpoint_kill_between_staging_and_rename(tmp_path, monkeypatch):
    """Crash INSIDE the atomic-rename window: the staging dir is fully
    written but the rename never happens — the previous checkpoint must
    stay restorable and the orphaned staging dir must not be mistaken
    for a step."""
    from repro.checkpoint import CheckpointManager
    from repro.checkpoint import ckpt as ckpt_mod

    mgr = CheckpointManager(tmp_path, keep=3)
    tree = {"a": np.arange(4.0)}
    mgr.save(1, tree, extra={"cursor": 1})

    real_rename = ckpt_mod.os.rename

    def killed_rename(src, dst):
        raise InjectedProcessKill("kill between staging write and rename")

    monkeypatch.setattr(ckpt_mod.os, "rename", killed_rename)
    with pytest.raises(InjectedProcessKill):
        mgr.save(2, {"a": np.arange(4.0) + 9}, extra={"cursor": 2})
    monkeypatch.setattr(ckpt_mod.os, "rename", real_rename)

    # partial state on disk: staging dir exists, step_2 does not
    assert (tmp_path / "step_0000000002.tmp").exists()
    assert not (tmp_path / "step_0000000002").exists()
    assert mgr.all_steps() == [1]
    step, restored, extra = mgr.restore_latest(tree)
    assert step == 1 and extra["cursor"] == 1
    np.testing.assert_array_equal(restored["a"], tree["a"])

    # a post-restart save of the same step overwrites the orphan cleanly
    mgr.save(2, {"a": np.arange(4.0) + 9}, extra={"cursor": 2})
    assert mgr.latest_step() == 2
    assert not (tmp_path / "step_0000000002.tmp").exists()


def test_checkpoint_kill_between_rename_and_latest(tmp_path, monkeypatch):
    """Crash after the data rename but before the LATEST pointer update:
    LATEST is stale but names a complete step — restore must succeed (the
    newer complete step is also discoverable via all_steps)."""
    from repro.checkpoint import CheckpointManager
    from repro.checkpoint import ckpt as ckpt_mod

    mgr = CheckpointManager(tmp_path, keep=3)
    tree = {"a": np.zeros(3)}
    mgr.save(1, tree)

    def killed_replace(src, dst):
        raise InjectedProcessKill("kill between rename and LATEST update")

    monkeypatch.setattr(ckpt_mod.os, "replace", killed_replace)
    with pytest.raises(InjectedProcessKill):
        mgr.save(2, tree)
    monkeypatch.undo()

    assert (tmp_path / "step_0000000002").exists()  # data IS complete
    out = mgr.restore_latest(tree)
    assert out is not None and out[0] in (1, 2)  # any complete step is safe
    assert mgr.all_steps() == [1, 2]
