"""The batched Fig. 5 static search (PR 4): parity, properties, smoke.

Contracts under test (see ``src/repro/sim/static_search.py``):

* the JAX backend matches the numpy references — both the
  ``search_static(backend="numpy")`` golden path and the independent
  ``benchmarks.paper_figs._exhaustive_best`` implementation — within
  1e-5 relative weighted speedup, with the SAME argmax/top-k config
  indices under the documented lowest-enumeration-index tie-break;
* a full search is AT MOST TWO device programs — every family's chunked
  grid scan stacked inside ONE program plus the shared baseline
  evaluation (dispatch counter), bit-identical per family to the
  one-program-per-family path (``stack_families=False``);
* enumerated grids are sum-feasible, padding masks never let a
  masked/infeasible config win, and top-k results are sorted descending
  with distinct indices;
* the workload axis shards across forced host devices with identical
  results;
* the Fig. 5 baseline construction is the shared
  :func:`repro.sim.equal_share` helper (``equal_on`` geomean pinned);
* the ``fig5_potential`` benchmark entry point reproduces the paper's
  ordering (all-three >= best two-resource subset).
"""
import itertools
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from benchmarks.paper_figs import _exhaustive_best
from repro.core import device_dispatches, reset_device_dispatches
from repro.sim import equal_share
from repro.sim.static_search import (
    FIG5_FAMILIES,
    FIG5_TWO_RESOURCE,
    FamilySpec,
    InfeasibleGridError,
    StaticOptions,
    enumerate_grid,
    family_grid,
    registry_families,
    search_static,
)
from repro.sim.workloads import random_workloads
from tests._hypothesis_compat import given, settings, st

# --------------------------------------------------------------------- #
# parity
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("n_apps,seed", [(2, 3), (3, 5)])
def test_batched_matches_numpy_backend(n_apps, seed):
    """JAX vs numpy backend: 1e-5 weighted speedup, identical top-k
    config indices (documented tie-break: lowest enumeration index)."""
    wls = random_workloads(4, n_apps, seed=seed)
    jx = search_static(wls, k=3, backend="jax")
    ref = search_static(wls, k=3, backend="numpy")
    assert jx.family_names == ref.family_names
    for fam in jx.family_names:
        np.testing.assert_allclose(jx.topk_ws[fam], ref.topk_ws[fam],
                                   rtol=1e-5, err_msg=fam)
        np.testing.assert_array_equal(jx.topk_index[fam],
                                      ref.topk_index[fam], err_msg=fam)
    np.testing.assert_allclose(jx.baseline_ipc, ref.baseline_ipc,
                               rtol=1e-5)


@pytest.mark.parametrize("n_apps,seed", [(2, 3), (3, 5)])
def test_batched_matches_exhaustive_best_reference(n_apps, seed):
    """The independent benchmarks-side numpy implementation pins the
    best weighted speedup of every (workload, family)."""
    wls = random_workloads(3, n_apps, seed=seed)
    res = search_static(wls)
    for fam, spec in FIG5_FAMILIES.items():
        for wi, w in enumerate(wls):
            ref = _exhaustive_best(w, spec.manage_cache, spec.manage_bw,
                                   spec.manage_pf, spec.pf_all_on)
            assert res.best_ws(fam)[wi] == pytest.approx(ref, rel=1e-5), \
                (fam, wi)


def test_stacked_search_is_two_device_programs():
    """The stacked dispatch contract: ONE program scanning every family
    back to back plus one shared baseline evaluation — nothing per
    family, workload or config."""
    wls = random_workloads(3, 3, seed=1)
    reset_device_dispatches()
    res = search_static(wls, k=2)
    assert device_dispatches() == 2
    for fam in res.family_names:
        assert np.isfinite(res.best_ws(fam)).all()


def test_per_family_path_dispatches_one_program_per_family():
    """The stacking parity reference keeps the PR 4 shape: len(families)
    search programs plus the shared baseline evaluation."""
    wls = random_workloads(3, 3, seed=1)
    reset_device_dispatches()
    search_static(wls, k=2, stack_families=False)
    assert device_dispatches() == len(FIG5_FAMILIES) + 1


@pytest.mark.parametrize("n_apps,k,seed", [(2, 1, 3), (3, 4, 5)])
def test_stacked_bit_identical_to_per_family_path(n_apps, k, seed):
    """THE family-stacking property: batching the family axis changes
    nothing — every family's top-k weighted speedups and config indices
    out of the stacked program equal the per-family programs bit for
    bit."""
    wls = random_workloads(4, n_apps, seed=seed)
    st = search_static(wls, k=k)
    pf = search_static(wls, k=k, stack_families=False)
    assert st.family_names == pf.family_names
    for fam in st.family_names:
        np.testing.assert_array_equal(st.topk_ws[fam], pf.topk_ws[fam],
                                      err_msg=fam)
        np.testing.assert_array_equal(st.topk_index[fam],
                                      pf.topk_index[fam], err_msg=fam)


def test_zero_feasible_configs_raise_descriptive_error():
    """A grid whose smallest per-resource options overshoot the budget
    must raise (naming the family and the violated constraint) instead of
    silently returning -inf scores / -1 indices for downstream argmax to
    consume."""
    wls = random_workloads(2, 2, seed=0)
    opts = StaticOptions(cache_options=(24.0, 32.0),
                         cache_budget_per_app=16.0)
    fams = {"cache_only": FamilySpec(manage_cache=True)}
    with pytest.raises(InfeasibleGridError) as exc:
        search_static(wls, families=fams, options=opts)
    msg = str(exc.value)
    assert "cache_only" in msg and "cache" in msg and "budget" in msg
    # the numpy backend validates identically
    with pytest.raises(InfeasibleGridError):
        search_static(wls, families=fams, options=opts, backend="numpy")
    # an unmanaged resource pinned above its budget trips the same guard
    with pytest.raises(InfeasibleGridError, match="bandwidth"):
        search_static(
            wls, families={"c": FamilySpec(manage_cache=True)},
            options=StaticOptions(bw_fixed=40.0, bw_budget_per_app=4.0))
    assert issubclass(InfeasibleGridError, ValueError)


def test_empty_topk_slot_index_refuses_config_lookup():
    """Index -1 (k beyond the feasible count) must not silently wrap to
    the last grid row when asked for its allocation."""
    wls = random_workloads(2, 2, seed=1)
    fams = {"equal_on": FIG5_FAMILIES["equal_on"]}  # 1 feasible config
    res = search_static(wls, families=fams, k=3)
    assert (res.topk_index["equal_on"][:, 1:] == -1).all()
    with pytest.raises(IndexError, match="top-k slot"):
        res.grids["equal_on"].config(res.topk_index["equal_on"])
    # valid indices keep working
    cfg = res.best_config("equal_on")
    assert cfg["cache_units"].shape == (2, 2)


def test_all3_dominates_every_subset_per_workload():
    """The potential-study invariant: the all-three grid is a superset of
    every subset family's grid, so its best is >= per workload."""
    wls = random_workloads(5, 3, seed=11)
    res = search_static(wls)
    all3 = res.best_ws("cache+bw+pref")
    for fam in res.family_names:
        assert (all3 >= res.best_ws(fam) - 1e-9).all(), fam


def test_backend_dispatch_validates():
    wls = random_workloads(2, 2, seed=0)
    with pytest.raises(ValueError):
        search_static(wls, backend="tpu")
    with pytest.raises(ValueError):
        search_static(wls, k=0)
    with pytest.raises(ValueError):
        search_static(wls, families={})
    with pytest.raises(ValueError):
        search_static([["lbm", "gcc"], ["mcf"]])  # ragged sizes


# --------------------------------------------------------------------- #
# properties (hypothesis via tests/_hypothesis_compat.py)
# --------------------------------------------------------------------- #


@settings(max_examples=15, deadline=None)
@given(n=st.integers(min_value=1, max_value=3),
       c_lo=st.integers(min_value=4, max_value=16),
       c_hi=st.integers(min_value=17, max_value=48),
       b_hi=st.floats(min_value=2.0, max_value=8.0),
       cache_budget=st.integers(min_value=16, max_value=80),
       bw_budget=st.floats(min_value=2.0, max_value=20.0))
def test_enumerated_grids_satisfy_sum_feasibility(n, c_lo, c_hi, b_hi,
                                                  cache_budget, bw_budget):
    """Every enumerated config satisfies both budget constraints, and the
    feasible count matches an itertools brute force."""
    cache_opts = [(float(c_lo), float(c_hi))] * n
    bw_opts = [(1.0, float(b_hi))] * n
    pf_opts = [(0.0, 1.0)] * n
    brute = sum(
        1
        for c in itertools.product(*cache_opts)
        for b in itertools.product(*bw_opts)
        for _ in itertools.product(*pf_opts)
        if sum(c) <= cache_budget + 1e-9 and sum(b) <= bw_budget + 1e-9
    )
    if brute == 0:
        with pytest.raises(ValueError):
            enumerate_grid(cache_opts, bw_opts, pf_opts,
                           cache_budget=cache_budget, bw_budget=bw_budget)
        return
    grid = enumerate_grid(cache_opts, bw_opts, pf_opts,
                          cache_budget=cache_budget, bw_budget=bw_budget)
    assert grid.valid.all()
    assert grid.n_configs == brute
    assert (grid.cache.sum(axis=-1) <= cache_budget + 1e-9).all()
    assert (grid.bandwidth.sum(axis=-1) <= bw_budget + 1e-9).all()
    # padding appends masked rows only
    padded = grid.pad_to(7)
    assert len(padded.valid) % 7 == 0
    assert padded.n_configs == brute
    assert not padded.valid[grid.n_configs:].any()


def test_padding_mask_never_lets_a_masked_config_win():
    """Tiny chunks force grid padding; the pad rows copy the last
    (feasible, possibly high-speedup) config but are masked — they must
    never surface in the top-k."""
    wls = random_workloads(2, 2, seed=0)
    res = search_static(wls, k=5, chunk_elements=8)
    ref = search_static(wls, k=5, backend="numpy")
    for fam in res.family_names:
        n_configs = res.grids[fam].n_configs
        ws, idx = res.topk_ws[fam], res.topk_index[fam]
        finite = np.isfinite(ws)
        assert (idx[finite] >= 0).all() and (idx[finite] < n_configs).all()
        assert (idx[~finite] == -1).all()
        # chunked+padded result == unchunked numpy result
        np.testing.assert_allclose(ws[finite].reshape(-1),
                                   ref.topk_ws[fam][finite].reshape(-1),
                                   rtol=1e-5, err_msg=fam)
        np.testing.assert_array_equal(idx, ref.topk_index[fam],
                                      err_msg=fam)


def test_infeasible_options_never_win():
    """An option value that can only appear in over-budget combos never
    shows up in a winning config."""
    opts = StaticOptions(cache_options=(8.0, 64.0),
                         cache_budget_per_app=16.0)
    fam = {"all3": FamilySpec(manage_cache=True, manage_bw=True,
                              manage_pf=True)}
    wls = random_workloads(2, 2, seed=6)
    res = search_static(wls, families=fam, options=opts, k=3)
    # budget = 32 for n=2: any combo containing 64 sums > 32.
    assert (res.grids["all3"].cache <= 8.0).all()
    assert (res.best_config("all3")["cache_units"] <= 8.0).all()


@settings(max_examples=6, deadline=None)
@given(k=st.integers(min_value=1, max_value=6),
       seed=st.integers(min_value=0, max_value=5))
def test_topk_sorted_and_deduplicated(k, seed):
    """Top-k is sorted descending with distinct config indices; unused
    slots (k beyond the feasible count) are -inf / -1."""
    wls = random_workloads(2, 2, seed=seed)
    fams = {"bw+pref": FIG5_FAMILIES["bw+pref"],
            "cache+bw+pref": FIG5_FAMILIES["cache+bw+pref"]}
    res = search_static(wls, families=fams, k=k)
    for fam in res.family_names:
        ws, idx = res.topk_ws[fam], res.topk_index[fam]
        assert ws.shape == idx.shape == (2, k)
        assert (np.diff(ws, axis=-1) <= 1e-12).all(), fam
        for row_ws, row_idx in zip(ws, idx):
            finite = np.isfinite(row_ws)
            assert len(set(row_idx[finite])) == finite.sum(), fam
            assert (row_idx[~finite] == -1).all(), fam
            assert finite.sum() == min(k, res.grids[fam].n_configs)


def test_arbitrary_napp_workloads_and_custom_grids():
    """Not just the paper's 4-app/3-level setup: 5-app workloads on a
    user-supplied finer grid search end to end."""
    opts = StaticOptions(cache_options=(8.0, 16.0, 24.0),
                         bw_options=(2.0, 5.0))
    wls = random_workloads(2, 5, seed=8)
    res = search_static(wls, families={"all3": FamilySpec(True, True, True)},
                        options=opts, k=2, backend="jax")
    ref = search_static(wls, families={"all3": FamilySpec(True, True, True)},
                        options=opts, k=2, backend="numpy")
    np.testing.assert_allclose(res.topk_ws["all3"], ref.topk_ws["all3"],
                               rtol=1e-5)
    np.testing.assert_array_equal(res.topk_index["all3"],
                                  ref.topk_index["all3"])
    cfg = res.best_config("all3")
    assert cfg["cache_units"].shape == (2, 5)
    assert (cfg["cache_units"].sum(axis=-1) <= 16.0 * 5 + 1e-9).all()
    assert (cfg["bandwidth_gbps"].sum(axis=-1) <= 4.0 * 5 + 1e-9).all()


# --------------------------------------------------------------------- #
# multi-objective (Pareto) mode + policy-registry grids
# --------------------------------------------------------------------- #


def _brute_force_front(res, fam, wi):
    """O(C^2) domination enumeration over the WHOLE grid — independent of
    the fold's sort-and-running-max shortcut.  Returns (ws, fairness,
    index) rows sorted descending by ws, exact duplicates deduplicated to
    the lowest config index (the fold's documented tie-break)."""
    from repro.sim import memsys
    from repro.sim.apps import stack
    from repro.sim.static_search import FIG5_ITERS

    grid = res.grids[fam]
    arr = stack(res.workloads[wi])
    ss = memsys.evaluate(
        arr, grid.cache, grid.bandwidth, grid.prefetch,
        total_cache_units=grid.total_cache_units,
        total_bandwidth_gbps=grid.total_bandwidth_gbps,
        iters=FIG5_ITERS)
    speedup = ss.ipc / res.baseline_ipc[wi]
    ws = np.mean(speedup, axis=-1)
    fair = np.min(speedup, axis=-1) / np.max(speedup, axis=-1)
    pts = [(ws[i], fair[i], i) for i in range(len(ws)) if grid.valid[i]]
    front, seen = [], set()
    for w_i, f_i, i in pts:
        dominated = any(
            (w_j >= w_i and f_j >= f_i and (w_j > w_i or f_j > f_i))
            for w_j, f_j, _ in pts)
        if dominated or (w_i, f_i) in seen:
            continue
        seen.add((w_i, f_i))
        front.append((w_i, f_i, i))
    front.sort(key=lambda t: (-t[0], t[2]))
    return front


def test_pareto_front_matches_brute_force_enumeration():
    """Acceptance gate: the multi-objective fold's front equals an O(C^2)
    brute-force domination enumeration over the small grid — same
    members, same (ws, fairness) values, same config indices, ws
    descending / fairness ascending down the slots."""
    wls = random_workloads(2, 3, seed=6)
    fams = {"cache+bw": FIG5_FAMILIES["cache+bw"]}
    res = search_static(wls, families=fams, k=16, backend="numpy",
                        multi_objective=True)
    assert res.multi_objective and res.topk_fairness is not None
    for wi in range(2):
        front = _brute_force_front(res, "cache+bw", wi)
        assert 2 <= len(front) <= res.k  # a real front, never truncated
        got_idx = res.topk_index["cache+bw"][wi]
        valid = got_idx >= 0
        assert valid.sum() == len(front)
        np.testing.assert_array_equal(got_idx[valid],
                                      [i for _, _, i in front])
        np.testing.assert_allclose(res.topk_ws["cache+bw"][wi][valid],
                                   [w for w, _, _ in front], rtol=0)
        np.testing.assert_allclose(res.topk_fairness["cache+bw"][wi][valid],
                                   [f for _, f, _ in front], rtol=0)
        # front shape: ws strictly decreasing, fairness strictly increasing
        ws_v = res.topk_ws["cache+bw"][wi][valid]
        f_v = res.topk_fairness["cache+bw"][wi][valid]
        assert (np.diff(ws_v) < 0).all() and (np.diff(f_v) > 0).all()
        # empty slots carry the documented sentinels
        assert (res.topk_ws["cache+bw"][wi][~valid] == -np.inf).all()
        assert (res.topk_fairness["cache+bw"][wi][~valid] == -np.inf).all()


def test_pareto_jax_matches_numpy_backend():
    """The chunked device-side Pareto fold is exact: identical front
    members, values and indices to the whole-grid numpy reference."""
    wls = random_workloads(3, 2, seed=5)
    fams = {"cache+bw": FIG5_FAMILIES["cache+bw"],
            "cache+bw+pref": FIG5_FAMILIES["cache+bw+pref"]}
    jx = search_static(wls, families=fams, k=6, multi_objective=True)
    ref = search_static(wls, families=fams, k=6, backend="numpy",
                        multi_objective=True)
    for fam in jx.family_names:
        np.testing.assert_array_equal(jx.topk_index[fam],
                                      ref.topk_index[fam], err_msg=fam)
        np.testing.assert_allclose(jx.topk_ws[fam], ref.topk_ws[fam],
                                   rtol=1e-12, err_msg=fam)
        np.testing.assert_allclose(jx.topk_fairness[fam],
                                   ref.topk_fairness[fam], rtol=1e-12,
                                   err_msg=fam)


def test_knee_index_picks_balanced_tradeoff():
    """Synthetic 3-member front: the knee is the middle member (closest
    to utopia after min-max normalization), not either extreme; a
    scalar result refuses the query."""
    from repro.sim.static_search import StaticSearchResult

    res = StaticSearchResult(
        family_names=["f"], workloads=[["a", "b"]], grids={},
        topk_ws={"f": np.array([[3.0, 2.0, 1.0], [5.0, -np.inf, -np.inf]])},
        topk_index={"f": np.array([[5, 7, 9], [2, -1, -1]])},
        baseline_ipc=np.ones((2, 2)), backend="numpy", k=3,
        topk_fairness={"f": np.array([[0.1, 0.9, 1.0],
                                      [0.4, -np.inf, -np.inf]])},
        multi_objective=True)
    # normalized: (1,0), (.5,.889), (0,1) -> middle is nearest to (1,1);
    # the single-member front degenerates to its only (best-ws) member.
    np.testing.assert_array_equal(res.knee_index("f"), [7, 2])

    scalar = search_static(random_workloads(2, 2, seed=0), k=2,
                           backend="numpy")
    with pytest.raises(ValueError, match="multi_objective"):
        scalar.knee_index("cache+bw+pref")


def test_registry_families_expose_policy_grids():
    """Every registered manager family converts to a FamilySpec; the new
    policy families carry their documented knobs (auction/qos search
    cache+bw, bank bw searches bandwidth over 4 banks)."""
    fams = registry_families()
    from repro.sim import policies
    assert set(fams) == set(policies.manager_names())
    assert fams["auction"].manage_cache and fams["auction"].manage_bw
    assert fams["qos"].manage_cache and fams["qos"].manage_bw
    assert not fams["bank bw"].manage_cache and fams["bank bw"].manage_bw
    assert fams["bank bw"].bandwidth_banks == 4
    sub = registry_families(["CBP", "bank bw"])
    assert list(sub) == ["CBP", "bank bw"]


def test_banked_family_search_end_to_end():
    """The bank-aware bandwidth model threads through the search: numpy
    and brute-force direct evaluation agree exactly, and banking shifts
    the scores away from the flat (1-bank) model."""
    from repro.sim import memsys

    wls = random_workloads(2, 2, seed=9)
    fams = registry_families(["bank bw"])
    res = search_static(wls, families=fams, k=2, backend="numpy")
    flat = search_static(
        wls, families={"bank bw": FamilySpec(manage_bw=True)}, k=2,
        backend="numpy")
    grid = res.grids["bank bw"]
    for wi in range(2):
        from repro.sim.apps import stack
        ss = memsys.evaluate(
            stack(wls[wi]), grid.cache, grid.bandwidth, grid.prefetch,
            total_cache_units=grid.total_cache_units,
            total_bandwidth_gbps=grid.total_bandwidth_gbps,
            bandwidth_banks=4, iters=40)
        ws = np.mean(ss.ipc / res.baseline_ipc[wi], axis=-1)
        best = np.argsort(-ws, kind="stable")[:2]
        np.testing.assert_array_equal(res.topk_index["bank bw"][wi], best)
        np.testing.assert_allclose(res.topk_ws["bank bw"][wi], ws[best],
                                   rtol=0)
    assert not np.allclose(res.topk_ws["bank bw"], flat.topk_ws["bank bw"])


# --------------------------------------------------------------------- #
# shared baseline construction + figure entry points
# --------------------------------------------------------------------- #


def test_equal_share_is_the_single_baseline_construction():
    units, bw = equal_share(16, 256, 64.0)
    assert (units == 16).all()
    np.testing.assert_allclose(bw, 4.0)
    # the Fig. 5 protocol shape: 4 apps, 16 units / 4 GB/s each
    units, bw = equal_share(4, 64, 16.0)
    assert (units == 16).all()
    np.testing.assert_allclose(bw, 4.0)


def test_equal_on_geomean_pinned():
    """Regression pin for the shared equal-share baseline: if the Fig. 5
    baseline construction drifts from the sweep baseline helper
    (repro.sim.equal_share), this moves."""
    wls = random_workloads(8, 4, seed=7)
    res = search_static(wls, families={"equal_on": FIG5_FAMILIES["equal_on"]},
                        backend="numpy")
    assert res.geomean("equal_on") == pytest.approx(1.11575462098291,
                                                    abs=1e-6)


def test_fig5_potential_smoke_orders_all3_above_subsets(monkeypatch,
                                                        tmp_path):
    """Tier-1 coverage for the benchmark entry point: the paper's
    headline ordering (all-three >= best two-resource subset) and the
    emitted record shape."""
    import benchmarks.common as bench_common
    from benchmarks.paper_figs import fig5_potential
    monkeypatch.setattr(bench_common, "RESULTS", tmp_path)
    derived = fig5_potential(n_workloads=8)
    assert derived["n_workloads"] == 8
    best2 = max(derived[f"geo_{f}"] for f in FIG5_TWO_RESOURCE)
    assert derived["geo_cache+bw+pref"] >= best2 - 1e-9
    assert derived["all3_vs_best2"] >= 0.0
    record = json.loads((tmp_path / "fig5_potential.json").read_text())
    assert record["derived"]["backend"] == "jax"


def test_family_grid_matches_exhaustive_best_combo_count():
    """The subsystem enumerates exactly the reference combo list."""
    n = 4
    grid = family_grid(FamilySpec(True, True, True), n)
    caches = [c for c in itertools.product(*[(8, 16, 32)] * n)
              if sum(c) <= 16 * n]
    bws = [b for b in itertools.product(*[(2.0, 4.0, 6.0)] * n)
           if sum(b) <= 4.0 * n]
    assert grid.n_configs == len(caches) * len(bws) * 2 ** n
    # spot-check enumeration order at both ends
    np.testing.assert_allclose(grid.cache[0], caches[0])
    np.testing.assert_allclose(grid.cache[-1], caches[-1])
    np.testing.assert_allclose(grid.bandwidth[0], bws[0])
    np.testing.assert_allclose(grid.prefetch[0], 0.0)
    np.testing.assert_allclose(grid.prefetch[-1], 1.0)


# --------------------------------------------------------------------- #
# multi-device sharding
# --------------------------------------------------------------------- #

_SHARD_SCRIPT = """
import json, sys
import numpy as np
import jax
from repro.sim.static_search import search_static
from repro.sim.workloads import random_workloads
assert jax.device_count() == 8, jax.device_count()
res = search_static(random_workloads(3, 3, seed=4), k=2)
json.dump({f: {"ws": res.topk_ws[f].tolist(),
               "idx": res.topk_index[f].tolist()}
           for f in res.family_names}, sys.stdout)
"""


@pytest.mark.slow
def test_workload_axis_shards_across_forced_host_devices():
    """The same search on 8 forced host devices (workload axis sharded
    via repro.distributed.shard_rows, padded 3 -> 8) matches the
    single-device run to float64 round-off, identical config indices."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        flags += " --xla_force_host_platform_device_count=8"
    env["XLA_FLAGS"] = flags.strip()
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(repo, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-c", _SHARD_SCRIPT], env=env,
        capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-2000:]
    sharded = json.loads(proc.stdout)

    ref = search_static(random_workloads(3, 3, seed=4), k=2)
    for fam in ref.family_names:
        np.testing.assert_allclose(
            np.asarray(sharded[fam]["ws"]), ref.topk_ws[fam],
            rtol=1e-12, atol=1e-12, err_msg=fam)
        np.testing.assert_array_equal(
            np.asarray(sharded[fam]["idx"]), ref.topk_index[fam],
            err_msg=fam)
