"""Fused TrainingPlant: bit-parity vs the host coordinator golden.

The fused schedule runner (``repro.runtime.plant_jax``) executes a whole
Fig. 8 knob schedule as ONE jitted ``lax.scan``; the host pair —
``CBPCoordinator`` over ``TrainingPlant`` with the numpy twin of the step
model — is the golden.  With every rounding point pinned (``pin_f64``:
XLA's CPU backend FMA-contracts and re-associates straight through
``lax.optimization_barrier``), the two knob trajectories must be
BIT-identical, not merely close, on 1 and (``slow``) 8 forced devices.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.dispatch import device_dispatches, reset_device_dispatches
from repro.core.types import CBPParams, Mode, PrefetchMode, ScheduleConfigError
from repro.runtime.plant_jax import (
    FusedTrainingPlant,
    host_reference_run,
    run_fused_schedule,
)
from repro.train.plant_model import make_stream_plant_model

FIELDS = ("kinds", "t_ms", "duration_ms", "cache_units", "bandwidth",
          "prefetch_on", "ipc", "queuing_delay_ns")

BASE = dict(n_clients=4, total_units=48, total_bandwidth=64.0, total_ms=60.0)
BASE_PARAMS = dict(reconfiguration_interval_ms=10.0, min_ways=2,
                   min_bandwidth_allocation=2.0)


def _pair(seed=0, n_clients=4, total_units=48, total_bandwidth=64.0):
    return make_stream_plant_model(n_clients, total_units, total_bandwidth,
                                   seed=seed)


def _assert_bit_identical(fused, host):
    for f in FIELDS:
        np.testing.assert_array_equal(getattr(fused, f), getattr(host, f),
                                      err_msg=f, strict=True)


def test_fused_schedule_matches_host_bitwise_in_one_dispatch():
    """The tentpole gate: a full dynamic knob schedule — cache Lookahead,
    Algorithm-1 bandwidth, Algorithm-2 A/B throttling — runs as one
    device program and lands bit-for-bit on the host trajectory."""
    step_fn, step_model = _pair()
    params = CBPParams(**BASE_PARAMS)
    host = host_reference_run(step_fn, params=params, **BASE)
    reset_device_dispatches()
    fused = run_fused_schedule(step_model, params=params, **BASE)
    assert device_dispatches() == 1
    _assert_bit_identical(fused, host)


@pytest.mark.parametrize("modes", [
    dict(cache_mode=Mode.EQUAL),
    dict(bandwidth_mode=Mode.EQUAL),
    dict(prefetch_mode=PrefetchMode.ON),
    dict(prefetch_mode=PrefetchMode.OFF),
])
def test_fused_schedule_parity_per_knob_mode(modes):
    """Each Table-3 style knob configuration (static cache, static
    bandwidth, prefetch forced on/off) keeps bit-parity — the fused cond
    branches mirror the host coordinator's mode switches exactly."""
    step_fn, step_model = _pair()
    params = CBPParams(**BASE_PARAMS)
    host = host_reference_run(step_fn, params=params, **BASE, **modes)
    fused = run_fused_schedule(step_model, params=params, **BASE, **modes)
    _assert_bit_identical(fused, host)


@pytest.mark.parametrize("seed,n,units,bw,total_ms,interval", [
    (3, 6, 64, 96.0, 85.0, 7.0),
    (7, 12, 96, 128.0, 45.0, 5.0),
    (11, 5, 40, 80.0, 400.0, 13.0),
])
def test_fused_schedule_parity_across_shapes(seed, n, units, bw, total_ms,
                                             interval):
    """Parity is not a fluke of one size: client counts spanning numpy's
    sequential and 8-way-unrolled summation regimes, long horizons (400 ms
    = hundreds of segments), and odd intervals all stay bit-identical."""
    step_fn, step_model = _pair(seed, n, units, bw)
    params = CBPParams(reconfiguration_interval_ms=interval, min_ways=2,
                       min_bandwidth_allocation=1.0)
    kw = dict(n_clients=n, total_units=units, total_bandwidth=bw,
              total_ms=total_ms, params=params)
    host = host_reference_run(step_fn, **kw)
    fused = run_fused_schedule(step_model, **kw)
    _assert_bit_identical(fused, host)


def test_fused_plant_golden_trajectory_seed0():
    """Pin the seed-0 trajectory so silent arithmetic drift in either twin
    (model constants, controller op order) shows up as a golden break, not
    just as both-sides-moved parity."""
    step_fn, step_model = _pair()
    params = CBPParams(**BASE_PARAMS)
    plant = FusedTrainingPlant(4, 48, 64.0, step_model)
    res = plant.run(60.0, params=params)
    host = host_reference_run(step_fn, params=params, **BASE)
    _assert_bit_identical(res, host)

    assert len(res.kinds) == 18
    # sample_off, sample_on, run — six Fig. 8 intervals of 10 ms.
    assert res.kinds.tolist() == [0, 1, 2] * 6
    assert res.duration_ms.sum() == 60.0
    np.testing.assert_array_equal(res.cache_units[-1], [10, 16, 14, 8])
    np.testing.assert_array_equal(res.prefetch_on[-1],
                                  [True, True, False, False])
    np.testing.assert_allclose(
        res.bandwidth[-1],
        [12.040298212087718, 19.93764745844568,
         17.58097142792976, 14.44108290153684], rtol=0, atol=0)
    np.testing.assert_allclose(
        res.mean_ipc(),
        [2.455269686809507, 2.3384549025142496,
         1.9288628566770705, 1.4381098901010647], rtol=0, atol=0)


def test_fused_plant_one_dispatch_per_run_warm():
    """Warm reruns still cost exactly one dispatch each (the compiled
    schedule is cached per (model, statics) key)."""
    _, step_model = _pair()
    params = CBPParams(**BASE_PARAMS)
    plant = FusedTrainingPlant(4, 48, 64.0, step_model)
    plant.run(60.0, params=params)
    reset_device_dispatches()
    for _ in range(3):
        plant.run(60.0, params=params)
    assert device_dispatches() == 3


def test_boundary_interval_schedule_parity():
    """Satellite 1 regression: the boundary value ``interval == 2 *
    sampling`` (all-sampling schedule, zero run segments) is legal and
    keeps host/fused parity — the old mis-scheduling drifted sample
    boundaries off the reconfiguration grid."""
    step_fn, step_model = _pair()
    params = CBPParams(reconfiguration_interval_ms=1.0,
                       prefetch_sampling_period_ms=0.5, min_ways=2,
                       min_bandwidth_allocation=2.0)
    kw = dict(n_clients=4, total_units=48, total_bandwidth=64.0,
              total_ms=30.0, params=params)
    host = host_reference_run(step_fn, **kw)
    fused = run_fused_schedule(step_model, **kw)
    _assert_bit_identical(fused, host)
    # every segment is a sample; durations cover the horizon exactly
    assert set(host.kinds.tolist()) == {0, 1}
    assert host.duration_ms.sum() == 30.0


def test_schedule_config_error_names_both_params():
    """Satellite 1: an interval too short to hold both A/B samples is a
    typed error at CBPParams construction, naming both knobs."""
    with pytest.raises(ScheduleConfigError) as ei:
        CBPParams(reconfiguration_interval_ms=0.9,
                  prefetch_sampling_period_ms=0.5)
    msg = str(ei.value)
    assert "reconfiguration_interval_ms" in msg
    assert "prefetch_sampling_period_ms" in msg
    for bad in (dict(reconfiguration_interval_ms=0.0),
                dict(prefetch_sampling_period_ms=-1.0)):
        with pytest.raises(ScheduleConfigError):
            CBPParams(**bad)


def test_fused_plant_rejects_infeasible_floors():
    """Feasibility stays hoisted on the host: bandwidth floors and
    min_ways capacity are validated before anything compiles."""
    _, step_model = _pair()
    with pytest.raises(ValueError):
        run_fused_schedule(step_model, n_clients=4, total_units=48,
                           total_bandwidth=4.0, total_ms=10.0,
                           params=CBPParams(min_bandwidth_allocation=2.0))
    with pytest.raises(ValueError):
        run_fused_schedule(step_model, n_clients=4, total_units=4,
                           total_bandwidth=64.0, total_ms=10.0,
                           params=CBPParams(min_ways=4))


_DEVICES_SCRIPT = """
import json, sys
import numpy as np
import jax
from repro.core.types import CBPParams
from repro.runtime.plant_jax import run_fused_schedule
from repro.train.plant_model import make_stream_plant_model
assert jax.device_count() == 8, jax.device_count()
_, step_model = make_stream_plant_model(4, 48, 64.0)
res = run_fused_schedule(
    step_model, n_clients=4, total_units=48, total_bandwidth=64.0,
    total_ms=60.0, params=CBPParams(reconfiguration_interval_ms=10.0,
                                    min_ways=2,
                                    min_bandwidth_allocation=2.0))
json.dump({"cache_units": res.cache_units.tolist(),
           "bandwidth": res.bandwidth.tolist(),
           "prefetch_on": res.prefetch_on.tolist(),
           "ipc": res.ipc.tolist(),
           "queuing_delay_ns": res.queuing_delay_ns.tolist()}, sys.stdout)
"""


def _forced_device_env(n: int = 8) -> dict:
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        flags += f" --xla_force_host_platform_device_count={n}"
    env["XLA_FLAGS"] = flags.strip()
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(repo, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    return env


@pytest.mark.slow
def test_fused_plant_parity_on_forced_8_devices():
    """The fused trajectory on 8 forced host devices is bit-identical to
    the host golden computed here — device count must not perturb the
    pinned rounding points."""
    proc = subprocess.run(
        [sys.executable, "-c", _DEVICES_SCRIPT], env=_forced_device_env(),
        capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-2000:]
    got = json.loads(proc.stdout)

    step_fn, _ = _pair()
    host = host_reference_run(step_fn, params=CBPParams(**BASE_PARAMS),
                              **BASE)
    np.testing.assert_array_equal(np.asarray(got["cache_units"]),
                                  host.cache_units)
    np.testing.assert_array_equal(np.asarray(got["bandwidth"]),
                                  host.bandwidth)
    np.testing.assert_array_equal(np.asarray(got["prefetch_on"]),
                                  host.prefetch_on)
    np.testing.assert_array_equal(np.asarray(got["ipc"]), host.ipc)
    np.testing.assert_array_equal(np.asarray(got["queuing_delay_ns"]),
                                  host.queuing_delay_ns)
