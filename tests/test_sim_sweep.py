"""Batched JAX sweep substrate vs the numpy golden reference.

Contract (see ``src/repro/sim/memsys_jax.py``): the jitted JAX interval
model must match ``memsys`` to 1e-5 relative tolerance, and ``run_sweep``
must reproduce the scalar manager results without ever calling the scalar
``memsys.evaluate`` per (mix, manager) pair.
"""
import numpy as np
import pytest

from repro.core import CBPParams, allocator_calls
from repro.sim import (
    MANAGER_NAMES,
    WORKLOADS,
    baseline_ipc,
    memsys,
    random_mixes,
    run_all_managers,
    run_sweep,
    stack,
    weighted_speedup,
)
from repro.sim import memsys_jax

FIELDS = ("ipc", "queuing_delay_ns", "traffic_gbps", "mpki",
          "exposed_mpki", "occupancy_units")


def _rel_err(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return float(np.max(np.abs(a - b) / (np.abs(a) + 1e-12)))


@pytest.mark.parametrize("cache_partitioned", [True, False])
@pytest.mark.parametrize("bandwidth_partitioned", [True, False])
def test_memsys_jax_matches_numpy_reference(cache_partitioned,
                                            bandwidth_partitioned):
    """Randomized (mix, allocation) batches, every partitioning regime."""
    rng = np.random.default_rng(42)
    for mix in [WORKLOADS["w1"][:8], random_mixes(1, 8, seed=5)[0]]:
        apps = stack(mix)
        n = apps.n
        cu = rng.uniform(4.0, 40.0, size=(6, n))
        bw = rng.uniform(1.0, 8.0, size=(6, n))
        pf = rng.integers(0, 2, size=(6, n)).astype(np.float64)
        kwargs = dict(
            cache_partitioned=cache_partitioned,
            bandwidth_partitioned=bandwidth_partitioned,
            total_cache_units=16.0 * n,
            total_bandwidth_gbps=4.0 * n,
        )
        ref = memsys.evaluate(apps, cu, bw, pf, **kwargs)
        jx = memsys_jax.evaluate(apps, cu, bw, pf, **kwargs)
        for field in FIELDS:
            err = _rel_err(getattr(ref, field), getattr(jx, field))
            assert err < 1e-5, (field, err)


def test_utility_curves_jax_matches_numpy_reference():
    rng = np.random.default_rng(7)
    apps = stack(WORKLOADS["w3"])
    n = apps.n
    pf = rng.integers(0, 2, size=n).astype(np.float64)
    ipc = rng.uniform(0.2, 2.0, size=n)
    ref = memsys.utility_curves(apps, pf, ipc, 64, duration_ms=1.0)
    jx = memsys_jax.utility_curves(apps, pf, ipc, 64, duration_ms=1.0)
    assert _rel_err(ref, np.asarray(jx)) < 1e-5


def test_sweep_matches_scalar_manager_path():
    """One-mix sweep == run_all_managers on the numpy reference plant.

    The batched coordinator shares the Fig. 8 schedule and controller state
    with the scalar path, so the only divergence source is the 1e-5 model
    parity gap (controller decisions are integer/boolean and identical away
    from knife-edges)."""
    mix = WORKLOADS["w1"]
    res = run_sweep([mix], total_ms=40.0)
    scalar = run_all_managers(mix, total_ms=40.0)
    base = baseline_ipc(mix)
    assert _rel_err(res.baseline_ipc[0], base) < 1e-5
    for name in MANAGER_NAMES:
        ws_batched = float(res.weighted_speedup(name)[0])
        ws_scalar = weighted_speedup(scalar[name].ipc, base)
        assert ws_batched == pytest.approx(ws_scalar, rel=1e-4), name


def test_sweep_8x10_without_scalar_evaluate(monkeypatch):
    """8 mixes x 10 managers completes with the scalar model forbidden."""
    def _forbidden(*args, **kwargs):
        raise AssertionError(
            "run_sweep must not fall back to per-pair memsys.evaluate")
    monkeypatch.setattr(memsys, "evaluate", _forbidden)
    monkeypatch.setattr(memsys, "utility_curves", _forbidden)

    mixes = random_mixes(8, 16, seed=11)
    res = run_sweep(mixes, total_ms=20.0)
    assert res.n_mixes == 8
    assert set(res.ipc) == set(MANAGER_NAMES)
    for name in MANAGER_NAMES:
        assert res.ipc[name].shape == (8, 16)
        assert np.isfinite(res.ipc[name]).all()
        assert (res.ipc[name] > 0).all()
    # Allocation invariants per mix (as in the scalar manager tests).
    cbp = res.final_alloc["CBP"]
    assert (cbp.cache_units.sum(axis=-1) == 256).all()
    assert (cbp.cache_units >= 4).all()
    np.testing.assert_allclose(cbp.bandwidth.sum(axis=-1), 64.0)


def test_sweep_preserves_cbp_beats_baseline_ordering():
    """The ordering asserted in tests/test_sim_managers.py survives the
    batched path: CBP geomean beats every single-resource manager."""
    mixes = [WORKLOADS["w1"], WORKLOADS["w2"]] + random_mixes(2, 16, seed=3)
    names = ["equal off", "only cache", "only bw", "only pref", "CBP"]
    res = run_sweep(mixes, managers=names, total_ms=40.0)
    cbp = res.geomean_speedup("CBP")
    assert cbp > 1.10
    for single in ("only cache", "only bw", "only pref", "equal off"):
        assert cbp > res.geomean_speedup(single), single
    assert (res.weighted_speedup("CBP") > 1.0).all()


def test_sweep_performs_zero_host_allocator_calls():
    """Device-resident contract: the batched sweep never calls the numpy
    ``lookahead_allocate`` per mix — reconfigurations run as batched JAX
    device calls (repro.core.cache_controller_jax)."""
    mixes = random_mixes(3, 16, seed=9)
    before = allocator_calls()
    res = run_sweep(mixes, managers=["only cache", "CPpf", "CBP"],
                    total_ms=20.0)
    assert allocator_calls() == before
    for name in ("only cache", "CPpf", "CBP"):
        assert (res.final_alloc[name].cache_units.sum(axis=-1) == 256).all()
        assert (res.final_alloc[name].cache_units >= 4).all()


def test_sweep_param_grid_batches_design_space():
    """`param_grid` adds a leading CBPParams axis; same-schedule params run
    as one device batch and every slice matches an independent sweep."""
    grid = [CBPParams(min_bandwidth_allocation=0.5),
            CBPParams(min_bandwidth_allocation=1.0),     # same schedule
            CBPParams(reconfiguration_interval_ms=5.0)]  # distinct schedule
    mixes = [WORKLOADS["w1"], WORKLOADS["w2"]]
    # "equal on" is CBPParams-independent: evaluated once, broadcast to P.
    names = ["equal on", "CBP", "CPpf"]
    res = run_sweep(mixes, managers=names, total_ms=20.0, param_grid=grid)
    assert res.param_grid == grid
    assert res.ipc["CBP"].shape == (3, 2, 16)
    assert res.weighted_speedup("CBP").shape == (3, 2)
    assert np.shape(res.geomean_speedup("CBP")) == (3,)
    for name in names:
        assert res.ipc[name].shape == (3, 2, 16)
        assert (res.final_alloc[name].cache_units.sum(axis=-1) == 256).all()
    for pi, p in enumerate(grid):
        ref = run_sweep(mixes, managers=names, total_ms=20.0, params=p)
        for name in names:
            np.testing.assert_array_equal(res.ipc[name][pi], ref.ipc[name])
    with pytest.raises(ValueError):
        run_sweep(mixes, managers=["CBP"], params=CBPParams(),
                  param_grid=grid)


def test_random_mixes_shapes_and_balance():
    mixes = random_mixes(5, 16, seed=0)
    assert len(mixes) == 5
    assert all(len(m) == 16 for m in mixes)
    from repro.sim.workloads import _CLASS_BUCKETS
    for mix in mixes:
        for bucket in _CLASS_BUCKETS.values():
            assert any(a in bucket for a in mix)
    # deterministic in the seed
    assert mixes == random_mixes(5, 16, seed=0)
    assert mixes != random_mixes(5, 16, seed=1)
