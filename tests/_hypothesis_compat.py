"""Hypothesis import shim for the tier-1 suite.

``hypothesis`` is an optional dependency: when it is installed the real
library is re-exported unchanged, and when it is missing a tiny fallback
runs each ``@given`` test over a fixed, seeded set of drawn examples (the
same spirit as hypothesis' explicit-example mode — deterministic, no
shrinking).  Test modules import ``given``/``settings``/``assume``/``st``
from here instead of from ``hypothesis`` so the suite always collects.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import assume, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import inspect

    import numpy as np

    HAVE_HYPOTHESIS = False

    _DEFAULT_MAX_EXAMPLES = 20

    class _Unsatisfied(Exception):
        """Raised by :func:`assume` to discard the current example."""

    def assume(condition):
        if not condition:
            raise _Unsatisfied
        return True

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 - mimics `hypothesis.strategies` module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                size = int(rng.integers(min_size, max_size + 1))
                return [elements.draw(rng) for _ in range(size)]
            return _Strategy(draw)

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            def runner(*args, **kwargs):
                n = getattr(runner, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                rng = np.random.default_rng(0)
                ran = attempts = 0
                while ran < n and attempts < 20 * n:
                    attempts += 1
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    try:
                        fn(*args, **{**kwargs, **drawn})
                    except _Unsatisfied:
                        continue
                    ran += 1
                if ran == 0:
                    # Mirror hypothesis' Unsatisfiable: a test that never
                    # executed an example must not pass silently.
                    raise AssertionError(
                        f"{fn.__name__}: assume() rejected all "
                        f"{attempts} drawn examples")
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            # Drawn arguments must not look like pytest fixtures.
            runner.__signature__ = inspect.Signature(
                p for p in inspect.signature(fn).parameters.values()
                if p.name not in strategies)
            return runner
        return deco
