"""Validate the CMP model against the paper's characterization (§2).

These tests pin the *reproduction claims*: the Fig. 2 classification counts,
the named per-application behaviours, and Observations 1-5.
"""
import numpy as np
import pytest

from repro.sim.apps import APP_NAMES, EXPECTED_CLASS_COUNTS
from repro.sim.characterization import (
    BASE,
    classify_all,
    leslie3d_interactions,
    prefetch_vs_allocation,
    sensitivity_table,
    _ipc,
)


@pytest.fixture(scope="module")
def table():
    return sensitivity_table()


@pytest.fixture(scope="module")
def classes():
    return classify_all()


def test_fig2_classification_counts(classes):
    """Paper Fig. 2 caption: 6 CS-BS-PS, 8 CS-BS, 6 BS-PS, 3 CS, 3 BS, 3 I."""
    counts = {}
    for cls in classes.values():
        counts[cls] = counts.get(cls, 0) + 1
    assert counts == EXPECTED_CLASS_COUNTS


def test_obs1_sensitivity_fractions(classes):
    """Observation 1: ~90% sensitive to >=1 resource, ~70% to multiple."""
    n = len(classes)
    sensitive = sum(1 for c in classes.values() if c != "I")
    multi = sum(1 for c in classes.values() if "-" in c)
    assert sensitive / n >= 0.85
    assert multi / n >= 0.65


def test_named_behaviours(classes):
    assert classes["lbm"] == "BS-PS"
    assert classes["xalancbmk"] == "CS-BS"
    assert classes["leslie3d"] == "CS-BS-PS"
    assert classes["libquantum"] == "BS-PS"
    assert classes["povray"] == "I"


def test_xalancbmk_prefetch_averse(table):
    """Paper Fig. 1/2: xalancbmk loses performance with prefetching on."""
    assert table["xalancbmk"]["P-B"] < -0.05


def test_low_allocation_sensitivity_exceeds_high(table):
    """Paper §2.1: more applications are sensitive in the low-allocation
    setting than the high-allocation setting, for both cache and bw."""
    thr = 0.10
    cl = sum(1 for r in table.values() if abs(r["C-L"]) >= thr)
    ch = sum(1 for r in table.values() if abs(r["C-H"]) >= thr)
    bl = sum(1 for r in table.values() if abs(r["B-L"]) >= thr)
    bh = sum(1 for r in table.values() if abs(r["B-H"]) >= thr)
    assert cl >= ch
    assert bl >= bh


def test_obs2_hmmer_prefetch_sensitive_at_low_alloc_only():
    """Paper Fig. 3: hmmer gains from prefetch at low allocation, not at
    baseline — prefetch sensitivity depends on cache/bw allocation."""
    r = prefetch_vs_allocation("hmmer")
    assert r["P-L"] >= 0.10
    assert r["P-B"] < 0.10


def test_obs2_gcc_prefetch_sensitive_at_high_alloc():
    """Paper Fig. 3: gcc gains more from prefetching at high allocation."""
    r = prefetch_vs_allocation("gcc")
    assert r["P-H"] > 0.0
    assert r["P-H"] >= r["P-L"]


def test_obs3_bandwidth_compensates_prefetch():
    """Observation 3: more bandwidth -> larger prefetch gain (leslie3d)."""
    fig4a = leslie3d_interactions()["fig4a"]
    gain_low = fig4a["on"][0] / fig4a["off"][0]
    gain_high = fig4a["on"][-1] / fig4a["off"][-1]
    assert gain_high > gain_low


def test_obs4_cache_prefetch_tradeoff():
    """Observation 4 (Fig. 4c): 128 kB + prefetch >= 512 kB w/o prefetch."""
    ipc_small_pf = _ipc("leslie3d", 4, BASE[1], True)
    ipc_base_nopf = _ipc("leslie3d", 16, BASE[1], False)
    assert ipc_small_pf >= 0.95 * ipc_base_nopf


def test_obs5_cache_gain_larger_at_low_bandwidth():
    """Observation 5 (Fig. 4d): cache helps more when bandwidth is scarce."""
    fig4d = leslie3d_interactions()["fig4d"]
    assert fig4d["gain"][0] > fig4d["gain"][-1]
    assert fig4d["gain"][0] >= 0.10


def test_monotonicity_cache():
    """More cache never hurts (single app, fixed bw, pf off)."""
    ipcs = [_ipc("omnetpp", u, 4.0, False) for u in (4, 8, 16, 32, 64, 128)]
    assert all(b >= a - 1e-9 for a, b in zip(ipcs, ipcs[1:]))


def test_monotonicity_bandwidth():
    """More bandwidth never hurts."""
    ipcs = [_ipc("lbm", 16, b, False) for b in (1.0, 2.0, 4.0, 8.0, 16.0)]
    assert all(b >= a - 1e-9 for a, b in zip(ipcs, ipcs[1:]))
