"""JAX-vs-numpy parity for the traced controller ports (PR 3).

The fused Fig. 8 timeline (``repro.sim.timeline_jax``) runs Algorithm 1
(:func:`repro.core.allocate_bandwidth_jax`) and Algorithm 2
(:func:`repro.core.throttle_decision_jax`) inside the jitted scan; these
property tests pin them to the numpy golden references, including the
batched ``(..., 1)`` per-row ``min_allocation`` / ``speedup_threshold``
forms used by ``run_sweep(param_grid=...)`` and the no-delay even-split
branch.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    allocate_bandwidth,
    allocate_bandwidth_jax,
    check_bandwidth_floor,
    throttle_decision,
    throttle_decision_jax,
)
from repro.sim.memsys_jax import x64_context


def _bw_jax(delay, total, min_alloc):
    with x64_context():
        import jax.numpy as jnp
        return np.asarray(allocate_bandwidth_jax(
            jnp.asarray(delay, dtype=jnp.float64), total, min_alloc))


def _throttle_jax(w, wo, thr):
    with x64_context():
        import jax.numpy as jnp
        return np.asarray(throttle_decision_jax(
            jnp.asarray(w, dtype=jnp.float64),
            jnp.asarray(wo, dtype=jnp.float64), thr))


# --------------------------------------------------------------------- #
# Algorithm 1: bandwidth partitioning
# --------------------------------------------------------------------- #


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 16),
    total=st.floats(16.0, 128.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_bandwidth_jax_matches_numpy(n, total, seed):
    rng = np.random.default_rng(seed)
    delay = rng.uniform(0.0, 100.0, size=(3, n))  # leading batch axis
    min_alloc = float(rng.uniform(0.0, total / n))
    ref = allocate_bandwidth(delay, total, min_alloc)
    jx = _bw_jax(delay, total, min_alloc)
    np.testing.assert_allclose(jx, ref, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(jx.sum(axis=-1), total, rtol=1e-12)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_bandwidth_jax_batched_min_allocation_rows(seed):
    """(P, 1) per-row floors — the param_grid batching form."""
    rng = np.random.default_rng(seed)
    P, M, n = 3, 4, 8
    total = 64.0
    delay = rng.uniform(0.0, 50.0, size=(P, M, n))
    min_rows = rng.uniform(0.0, total / n, size=(P, 1, 1))
    ref = allocate_bandwidth(delay, total, min_rows)
    jx = _bw_jax(delay, total, min_rows)
    np.testing.assert_allclose(jx, ref, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(jx.sum(axis=-1), total, rtol=1e-12)


def test_bandwidth_jax_no_delay_even_split():
    """No one queued -> the remainder splits evenly (Algorithm 1 line 8)."""
    jx = _bw_jax(np.zeros((2, 4)), 64.0, 1.0)
    np.testing.assert_allclose(jx, np.full((2, 4), 16.0))
    # ...and a single all-zero row inside a mixed batch takes the same
    # branch while the other rows stay proportional.
    delay = np.stack([np.zeros(4), np.array([3.0, 1.0, 0.0, 0.0])])
    ref = allocate_bandwidth(delay, 16.0, 1.0)
    np.testing.assert_allclose(_bw_jax(delay, 16.0, 1.0), ref, rtol=1e-12)


def test_bandwidth_floor_check_is_hoisted():
    """The traced mirror skips validation; the host check must raise."""
    with pytest.raises(ValueError):
        check_bandwidth_floor(9.0, 8, 64.0)
    with pytest.raises(ValueError):
        allocate_bandwidth(np.ones(8), 64.0, 9.0)
    # per-row floors: any infeasible row trips the check
    with pytest.raises(ValueError):
        check_bandwidth_floor(np.array([[1.0], [9.0]]), 8, 64.0)


# --------------------------------------------------------------------- #
# Algorithm 2: prefetch throttling
# --------------------------------------------------------------------- #


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 16),
    thr=st.floats(1.0, 1.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_throttle_jax_matches_numpy(n, thr, seed):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.0, 3.0, size=(2, n))
    wo = rng.uniform(0.0, 3.0, size=(2, n))
    wo[0, 0] = 0.0  # the perf_without == 0 guard branch
    ref = throttle_decision(w, wo, thr)
    jx = _throttle_jax(w, wo, thr)
    assert jx.dtype == bool
    np.testing.assert_array_equal(jx, ref)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_throttle_jax_batched_threshold_rows(seed):
    """(P, 1) per-row speedup thresholds — the param_grid batching form."""
    rng = np.random.default_rng(seed)
    P, n = 4, 8
    w = rng.uniform(0.5, 2.0, size=(P, n))
    wo = rng.uniform(0.5, 2.0, size=(P, n))
    thr = rng.uniform(1.0, 1.3, size=(P, 1))
    ref = throttle_decision(w, wo, thr)
    np.testing.assert_array_equal(_throttle_jax(w, wo, thr), ref)
