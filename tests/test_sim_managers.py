"""Integration tests: the ten Table-3 managers on the CMP plant (paper §5)."""
import numpy as np
import pytest

from repro.core import CBPCoordinator, CBPParams, Mode, PrefetchMode
from repro.sim import (
    MANAGER_NAMES,
    TABLE3_MODES,
    WORKLOADS,
    antt,
    baseline_ipc,
    run_all_managers,
    weighted_speedup,
)
from repro.sim.runner import CMPPlant


def test_registry_completeness():
    """Every registered family is fully wired: a numpy host golden, a
    traced allocator branch (valid ``cache_policy`` / ``bw_policy`` ids
    and bank count), and a static-grid vocabulary for the Fig. 5 search
    — and the derived name list IS the registry, in insertion order."""
    from repro.sim import policies

    assert MANAGER_NAMES == list(policies.REGISTRY)
    assert len(MANAGER_NAMES) == len(set(MANAGER_NAMES))
    assert set(TABLE3_MODES) == {
        name for name, fam in policies.REGISTRY.items()
        if fam.modes is not None}
    assert "equal on" in TABLE3_MODES          # once silently skipped
    for name in ("auction", "qos", "bank bw"):  # the related-work families
        assert name in MANAGER_NAMES
    for name, fam in policies.REGISTRY.items():
        assert fam.host_golden is not None, name
        assert 0 <= fam.cache_policy < len(policies.CACHE_POLICY_NAMES)
        assert 0 <= fam.bw_policy < len(policies.BW_POLICY_NAMES)
        assert fam.bandwidth_banks >= 1
        assert isinstance(fam.static_grid, dict), name


def test_unknown_manager_error_names_the_key_and_the_menu():
    from repro.sim import UnknownManagerError
    from repro.sim.managers import run_manager
    from repro.sim.sweep import run_sweep

    plant = CMPPlant(WORKLOADS["w1"])
    with pytest.raises(UnknownManagerError) as ei:
        run_manager("cpb", plant, total_ms=1.0)
    assert "cpb" in str(ei.value)
    assert "CBP" in str(ei.value) and "auction" in str(ei.value)
    assert issubclass(UnknownManagerError, ValueError)
    with pytest.raises(UnknownManagerError):
        run_sweep([WORKLOADS["w1"]], managers=["CBP", "nope"], total_ms=1.0)


@pytest.fixture(scope="module")
def w1_results():
    return run_all_managers(WORKLOADS["w1"], total_ms=60.0)


@pytest.fixture(scope="module")
def w1_base():
    return baseline_ipc(WORKLOADS["w1"])


def test_all_managers_run(w1_results):
    assert set(w1_results) == set(MANAGER_NAMES)
    for res in w1_results.values():
        assert res.ipc.shape == (16,)
        assert np.isfinite(res.ipc).all()
        assert (res.ipc > 0).all()


def test_cbp_beats_baseline(w1_results, w1_base):
    assert weighted_speedup(w1_results["CBP"].ipc, w1_base) > 1.10


def test_cbp_beats_single_resource_managers(w1_results, w1_base):
    cbp = weighted_speedup(w1_results["CBP"].ipc, w1_base)
    for single in ("only cache", "only bw", "only pref", "equal off"):
        assert cbp > weighted_speedup(w1_results[single].ipc, w1_base)


def test_cbp_fairness_improves(w1_results, w1_base):
    """Fig. 10: CBP ANTT below baseline (lower is better)."""
    assert antt(w1_results["CBP"].ipc, w1_base) < 1.0


def test_cbp_allocations_valid(w1_results):
    alloc = w1_results["CBP"].final_alloc
    assert int(alloc.cache_units.sum()) == 256
    assert (alloc.cache_units >= 4).all()
    assert np.isclose(alloc.bandwidth.sum(), 64.0)
    assert (alloc.bandwidth >= 1.0 - 1e-9).all()


def test_cbp_geomean_over_all_workloads_beats_two_technique_managers():
    """Headline claim (paper §5.1): CBP outperforms every two-technique
    manager on geomean weighted speedup across the 14 mixes."""
    names = ["bw+pref", "bw+cache", "cache+pref", "CPpf", "CBP"]
    logs = {m: [] for m in names}
    for apps in WORKLOADS.values():
        base = baseline_ipc(apps)
        res = run_all_managers(apps, total_ms=40.0, names=names)
        for m in names:
            logs[m].append(np.log(weighted_speedup(res[m].ipc, base)))
    geo = {m: float(np.exp(np.mean(v))) for m, v in logs.items()}
    assert geo["CBP"] > geo["cache+pref"]
    assert geo["CBP"] > geo["bw+cache"]
    assert geo["CBP"] > geo["bw+pref"]
    assert geo["CBP"] > geo["CPpf"]


def test_coordinator_feedback_shrinks_cache_for_prefetch_friendly():
    """Interaction #5: with prefetching on, a prefetch-friendly app's
    utility curve flattens and it receives less cache."""
    plant = CMPPlant(["leslie3d", "xalancbmk"])
    params = CBPParams()
    coord_pf = CBPCoordinator(plant, params=params,
                              prefetch_mode=PrefetchMode.DYNAMIC)
    coord_pf.run(60.0)
    coord_nopf = CBPCoordinator(plant, params=params,
                                prefetch_mode=PrefetchMode.OFF)
    coord_nopf.run(60.0)
    # leslie3d is prefetch friendly; with pf managed its cache share drops.
    assert (coord_pf.alloc.cache_units[0]
            <= coord_nopf.alloc.cache_units[0])


def test_fig1_two_app_example():
    """Paper Fig. 1: for {lbm, xalancbmk}, managing all three beats any
    pair; xalancbmk gets most of the cache, lbm most of the bandwidth."""
    from repro.sim.runner import CMPConfig
    apps = ["lbm", "xalancbmk"]
    cfg = CMPConfig(total_cache_units=64, total_bandwidth=16.0)
    base = baseline_ipc(apps, cfg)
    res = run_all_managers(apps, total_ms=60.0, config=cfg)
    cbp = weighted_speedup(res["CBP"].ipc, base)
    for pair in ("bw+pref", "bw+cache", "cache+pref"):
        assert cbp >= weighted_speedup(res[pair].ipc, base) - 1e-6
    alloc = res["CBP"].final_alloc
    assert alloc.cache_units[1] > alloc.cache_units[0]   # xalancbmk cache
    assert alloc.bandwidth[0] > alloc.bandwidth[1]       # lbm bandwidth
    assert bool(alloc.prefetch_on[0])                    # lbm: pf active
    assert not bool(alloc.prefetch_on[1])                # xalancbmk: off
