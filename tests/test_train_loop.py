"""End-to-end training-loop tests: convergence, checkpoint/restart,
CBP runtime plant coordination."""
import pathlib

import numpy as np
import pytest

from repro.core import CBPCoordinator, CBPParams, Mode, PrefetchMode
from repro.launch.train import train_loop
from repro.runtime.cbp_runtime import StreamKnobs, TrainingPlant


def test_train_loss_decreases(tmp_path):
    out = train_loop("qwen3-8b", steps=30, batch=4, seq=32,
                     log_every=0, cbp_manage=False)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first, (first, last)


def test_train_restart_from_checkpoint(tmp_path):
    ckpt = tmp_path / "ckpt"
    out1 = train_loop("mamba2-1.3b", steps=10, batch=2, seq=32,
                      ckpt_dir=ckpt, ckpt_every=5, log_every=0,
                      cbp_manage=False)
    # "crash" and restart: resumes from step 10 and continues to 16
    out2 = train_loop("mamba2-1.3b", steps=16, batch=2, seq=32,
                      ckpt_dir=ckpt, ckpt_every=5, log_every=0,
                      cbp_manage=False)
    assert len(out2["losses"]) == 6  # only steps 10..15 re-run
    assert np.isfinite(out2["final_loss"])


def test_training_plant_coordinator_integration():
    """The UNMODIFIED paper coordinator manages a synthetic training
    plant: stream 0 (input pipeline) benefits from buffers+prefetch,
    stream 1 (ckpt writer) from bandwidth; allocations should converge
    accordingly (cache to 0, bandwidth toward 1)."""
    total_units, total_bw = 64, 100.0

    def step_fn(duration_ms, knobs: StreamKnobs):
        u = np.asarray(knobs.buffer_units, dtype=np.float64)
        bw = np.asarray(knobs.bandwidth_mbps, dtype=np.float64)
        pf = np.asarray(knobs.prefetch_on, dtype=np.float64)
        # stream 0: concave gain in buffers, big prefetch benefit
        tp0 = 1.0 + 0.5 * np.log1p(u[0]) + 0.4 * pf[0]
        # stream 1: throughput ~ bandwidth, indifferent to buffers
        tp1 = 0.2 + bw[1] / total_bw
        wait = np.array([5.0 / max(bw[0], 1.0), 40.0 / max(bw[1], 1.0)])
        curves = np.stack([
            2.0 * np.log1p(np.arange(total_units + 1)),      # concave
            0.02 * np.arange(total_units + 1),               # ~flat
        ])
        return np.array([tp0, tp1]), wait, curves

    plant = TrainingPlant(2, total_units, total_bw, step_fn)
    coord = CBPCoordinator(
        plant, params=CBPParams(min_bandwidth_allocation=5.0, min_ways=2))
    coord.run(100.0)
    alloc = coord.alloc
    assert alloc.cache_units[0] > alloc.cache_units[1]
    assert alloc.bandwidth[1] > alloc.bandwidth[0]
    assert bool(alloc.prefetch_on[0])
    assert int(alloc.cache_units.sum()) == total_units
    assert np.isclose(alloc.bandwidth.sum(), total_bw)
