"""Device-dispatch counter for the sweep substrate.

Every host->device program invocation on the sweep hot path (the jitted
interval model, the batched Lookahead allocator, and the fused Fig. 8
timeline) records itself here.  Tests and the CI sweep smoke use the
counter to enforce the PR 3 contract: a full ``run_sweep`` over the
Table-3 managers is **one device program per (manager, timeline)** plus a
single baseline evaluation — zero per-segment dispatches or host
round-trips.

This counts Python-level jitted-entry invocations (the unit the host loop
pays for), not XLA-internal executions; it is the batched analogue of
:func:`repro.core.cache_controller.allocator_calls`.
"""
from __future__ import annotations

_DISPATCHES = 0


def device_dispatches() -> int:
    """Total counted device-program invocations in this process."""
    return _DISPATCHES


def reset_device_dispatches() -> None:
    global _DISPATCHES
    _DISPATCHES = 0


def record_dispatch(n: int = 1) -> None:
    """Called by the jitted-entry wrappers; ``n`` programs launched."""
    global _DISPATCHES
    _DISPATCHES += n
