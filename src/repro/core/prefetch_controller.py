"""Prefetch-throttling controller (paper §3.2.3, Algorithm 2).

Samples performance (IPC in the CMP model; tokens/sec or 1/step-time in the
TPU binding) with the prefetcher enabled and disabled over
``prefetch_sampling_period`` each, then enables prefetching for the next
``prefetch_interval`` iff the measured speedup exceeds
``speedup_threshold``.  "The prefetch throttling controller is generic enough
to support any type of prefetcher" — here it is generic over what "prefetch"
means (hardware stride prefetcher, input-pipeline depth, kernel
double-buffering, KV-page readahead).
"""
from __future__ import annotations

import numpy as np


def throttle_decision(
    perf_with: np.ndarray,
    perf_without: np.ndarray,
    speedup_threshold: float = 1.05,
) -> np.ndarray:
    """Algorithm 2: enable iff speedup > threshold.

    Args:
      perf_with: (..., n) performance sampled with prefetching enabled.
      perf_without: (..., n) performance sampled with prefetching disabled.
      speedup_threshold: paper default 1.05; may be an array broadcastable
        against the leading batch axes (shape ``(..., 1)``) so
        ``run_sweep(param_grid=...)`` can batch over it.

    Returns:
      (..., n) bool — prefetcher setting for the next prefetch interval.
    """
    w = np.asarray(perf_with, dtype=np.float64)
    wo = np.asarray(perf_without, dtype=np.float64)
    speedup = np.where(wo > 0, w / np.maximum(wo, 1e-12), 1.0)
    return speedup > speedup_threshold  # lines 3-6


def throttle_decision_jax(perf_with, perf_without, speedup_threshold=1.05):
    """Traced JAX mirror of :func:`throttle_decision`.

    Used inside the fused Fig. 8 timeline (:mod:`repro.sim.timeline_jax`)
    so the per-client A/B decision happens on device; same arithmetic as
    the numpy reference (property parity: ``tests/test_controllers_jax.py``).
    """
    import jax.numpy as jnp

    w = jnp.asarray(perf_with)
    wo = jnp.asarray(perf_without, dtype=w.dtype)
    speedup = jnp.where(wo > 0, w / jnp.maximum(wo, 1e-12), 1.0)
    return speedup > jnp.asarray(speedup_threshold, dtype=w.dtype)


class PrefetchController:
    """Stateful wrapper tracking the current per-client setting."""

    def __init__(self, n_clients: int, speedup_threshold: float = 1.05):
        self.speedup_threshold = speedup_threshold
        self.enabled = np.zeros(n_clients, dtype=bool)
        self.last_speedup = np.ones(n_clients, dtype=np.float64)

    def update(self, perf_with: np.ndarray,
               perf_without: np.ndarray) -> np.ndarray:
        w = np.asarray(perf_with, dtype=np.float64)
        wo = np.asarray(perf_without, dtype=np.float64)
        self.last_speedup = np.where(wo > 0, w / np.maximum(wo, 1e-12), 1.0)
        self.enabled = throttle_decision(w, wo, self.speedup_threshold)
        return self.enabled
