"""Shared types for the CBP resource manager (paper §3).

These types are deliberately domain-agnostic: the same controllers drive the
CMP interval model (``repro.sim`` — the faithful reproduction) and the TPU
runtime knobs (``repro.runtime`` / ``repro.serving`` — the hardware
adaptation).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import numpy as np


class ScheduleConfigError(ValueError):
    """Raised when :class:`CBPParams` cannot form a Fig. 8 timeline.

    The Fig. 8 schedule spends ``2 * prefetch_sampling_period_ms`` of every
    reconfiguration interval on the A/B prefetch samples; if the interval is
    shorter than that, the "run" segment's duration goes negative, gets
    silently dropped, and the reconfigure boundaries drift off interval
    multiples — the host loop and the fused/stacked segment tables then
    disagree.  Rejecting the configuration up front keeps every backend on
    the same timeline.
    """


class Mode(enum.Enum):
    """How one of the three resources is managed (paper Table 3)."""

    UNPARTITIONED = "unpartitioned"  # free-for-all sharing (baseline)
    EQUAL = "equal"                  # static equal split ("equal off")
    DYNAMIC = "dynamic"              # managed by the local controller


class PrefetchMode(enum.Enum):
    OFF = "off"          # disabled for everyone (baseline / "* off" managers)
    ON = "on"            # enabled for everyone ("equal on")
    DYNAMIC = "dynamic"  # Algorithm 2 per-client throttling


@dataclasses.dataclass
class Allocation:
    """A complete resource assignment for ``n`` clients.

    ``cache_units`` are allocation quanta (32 kB in the CMP model — one way of
    a 16-way 512 kB bank; KV pages or VMEM bytes in the TPU binding).
    ``bandwidth`` is in GB/s (CMP) or share-of-link (TPU).
    """

    cache_units: np.ndarray          # (n,) int
    bandwidth: np.ndarray            # (n,) float
    prefetch_on: np.ndarray          # (n,) bool
    cache_mode: Mode = Mode.DYNAMIC
    bandwidth_mode: Mode = Mode.DYNAMIC
    bandwidth_banks: int = 1         # >1: per-bank-token bandwidth regime

    @property
    def n(self) -> int:
        return len(self.cache_units)

    def copy(self) -> "Allocation":
        return Allocation(
            cache_units=self.cache_units.copy(),
            bandwidth=self.bandwidth.copy(),
            prefetch_on=self.prefetch_on.copy(),
            cache_mode=self.cache_mode,
            bandwidth_mode=self.bandwidth_mode,
            bandwidth_banks=self.bandwidth_banks,
        )


@dataclasses.dataclass
class IntervalStats:
    """Observations gathered while running one interval under an allocation.

    ``utility_curves[i, u]`` = hits client ``i`` would have seen with ``u``
    cache units during the interval (the ATD / stack-distance measurement,
    paper §3.2.1).  ``queuing_delay_ns`` is the mean per-request memory
    queuing delay (paper §3.2.2).  ``ipc`` is the performance signal sampled
    by the prefetch controller (paper §3.2.3); in the TPU binding it is
    tokens/sec or 1/step-time.
    """

    ipc: np.ndarray                   # (n,)
    queuing_delay_ns: np.ndarray      # (n,)
    utility_curves: np.ndarray        # (n, total_units + 1)
    instructions: Optional[np.ndarray] = None  # (n,) work completed

    @property
    def n(self) -> int:
        return len(self.ipc)


@dataclasses.dataclass
class CBPParams:
    """CBP tunables (paper Table 1, bottom block).

    The two decay constants govern how fast controller history washes out:
    ``atd_decay`` scales the ATD utility counters at every reconfiguration
    (paper §3.3, "the ATD values will be halved" — 0.5 is the paper's
    halving) and ``bandwidth_delay_decay`` is the
    :class:`~repro.core.BandwidthController` accumulator decay applied per
    observed interval.  Both default to the paper's 0.5 (pinned by
    ``tests/test_timeline_fused.py``) and are sweepable via
    ``run_sweep(param_grid=...)``.
    """

    reconfiguration_interval_ms: float = 10.0
    prefetch_sampling_period_ms: float = 0.5
    speedup_threshold: float = 1.05
    prefetch_interval_ms: float = 10.0
    min_bandwidth_allocation: float = 1.0   # GB/s
    min_ways: int = 4                       # allocation quanta floor
    atd_decay: float = 0.5                  # ATD scale at reconfiguration
    bandwidth_delay_decay: float = 0.5      # queuing-delay accumulator decay

    def __post_init__(self):
        if self.reconfiguration_interval_ms <= 0:
            raise ScheduleConfigError(
                "reconfiguration_interval_ms must be positive, got "
                f"{self.reconfiguration_interval_ms!r}")
        if self.prefetch_sampling_period_ms <= 0:
            raise ScheduleConfigError(
                "prefetch_sampling_period_ms must be positive, got "
                f"{self.prefetch_sampling_period_ms!r}")
        if (self.reconfiguration_interval_ms
                < 2.0 * self.prefetch_sampling_period_ms):
            raise ScheduleConfigError(
                "reconfiguration_interval_ms "
                f"({self.reconfiguration_interval_ms!r}) must cover both "
                "prefetch samples: it has to be >= 2 * "
                "prefetch_sampling_period_ms "
                f"({self.prefetch_sampling_period_ms!r}); a shorter interval "
                "drops the 'run' segment and drifts the reconfigure "
                "boundaries off interval multiples")
