"""Cache-allocation controller: UCP Lookahead (paper §3.2.1).

The controller consumes per-client *utility curves* — hits as a function of
allocated units, measured by the ATD — and produces an integer allocation
that greedily maximizes marginal utility (misses avoided per unit), exactly
as in Qureshi & Patt's Lookahead algorithm.  A ``min_units`` floor is applied
before distribution to adapt to an inclusive hierarchy (paper: "we assign a
minimum allocation of cache space (min_ways) to all the applications before
distributing the remaining capacity").

This module is the **numpy golden reference**.  The batched, jitted port
lives in :mod:`repro.core.cache_controller_jax` and must match it
bit-identically away from tie knife-edges; :class:`CacheController`
dispatches between the two via ``backend="numpy"|"jax"`` (mirroring
``CMPConfig.backend``).

Deterministic tie-breaks (shared by both backends):

* among clients with equal best marginal utility, the lowest index wins;
* within a client, the smallest step ``k`` achieving the best utility wins;
* the zero-utility spread orders clients by remaining potential gain with a
  *stable* sort, so equal-gain clients stay in index order.

``lookahead_allocate`` increments a module-level call counter so tests and
the CI sweep smoke can assert that device-resident sweeps perform **zero**
per-mix host allocator calls (see :func:`allocator_calls`).
"""
from __future__ import annotations

import numpy as np

#: Number of times the numpy ``lookahead_allocate`` has run in this process.
#: The batched JAX path never touches it, which is what the device-resident
#: sweep smoke asserts.
_ALLOCATOR_CALLS = 0


def allocator_calls() -> int:
    """Total numpy ``lookahead_allocate`` invocations so far."""
    return _ALLOCATOR_CALLS


def reset_allocator_calls() -> None:
    global _ALLOCATOR_CALLS
    _ALLOCATOR_CALLS = 0


def _max_marginal_utility(curve: np.ndarray, have: int, balance: int):
    """Lookahead's get_max_mu: best (utility/units) step from ``have``.

    Returns ``(mu, k)`` where ``k`` maximizes
    ``(curve[have + k] - curve[have]) / k`` over ``1 <= k <= balance``.
    """
    top = min(have + balance, len(curve) - 1)
    if top <= have:
        return 0.0, 0
    ks = np.arange(1, top - have + 1)
    gains = curve[have + 1: top + 1] - curve[have]
    mus = gains / ks
    best = int(np.argmax(mus))
    return float(mus[best]), int(ks[best])


def lookahead_allocate(
    utility_curves: np.ndarray,
    total_units: int,
    min_units: int = 4,
) -> np.ndarray:
    """Allocate ``total_units`` among clients by greedy marginal utility.

    Args:
      utility_curves: (n, total_units + 1); ``[i, u]`` = hits for client ``i``
        with ``u`` units.  Need not be normalized; only differences matter.
      total_units: capacity to distribute (e.g. 256 x 32 kB = 8 MB).
      min_units: floor per client (paper's ``min_ways``).

    Returns:
      (n,) int allocation summing exactly to ``total_units``.
    """
    global _ALLOCATOR_CALLS
    _ALLOCATOR_CALLS += 1
    curves = np.asarray(utility_curves, dtype=np.float64)
    n = curves.shape[0]
    if curves.shape[1] != total_units + 1:
        raise ValueError(
            f"utility curves must have {total_units + 1} points, "
            f"got {curves.shape[1]}")
    if n * min_units > total_units:
        raise ValueError("min_units * n exceeds capacity")

    alloc = np.full(n, min_units, dtype=np.int64)
    balance = total_units - int(alloc.sum())

    while balance > 0:
        best_mu = -1.0
        best_i = -1
        best_k = 0
        for i in range(n):
            mu, k = _max_marginal_utility(curves[i], int(alloc[i]), balance)
            if k > 0 and mu > best_mu:
                best_mu, best_i, best_k = mu, i, k
        if best_i < 0 or best_mu <= 0.0:
            # No client gains from more cache: spread the remainder evenly
            # (UCP leaves no capacity idle).  Stable sort: equal remaining
            # gains keep index order (the documented tie-break, which the
            # JAX port reproduces).
            order = np.argsort(
                -(curves[:, -1] - curves[np.arange(n), alloc]),
                kind="stable")
            j = 0
            while balance > 0:
                i = int(order[j % n])
                if alloc[i] < total_units:
                    alloc[i] += 1
                    balance -= 1
                j += 1
            break
        alloc[best_i] += best_k
        balance -= best_k

    assert int(alloc.sum()) == total_units
    return alloc


def cppf_allocate(
    utility_curves: np.ndarray,
    total_units: int,
    min_units: int,
    active: np.ndarray,
) -> np.ndarray:
    """CPpf allocation (paper §4.4): pin inactive clients at ``min_units``,
    UCP over the remaining capacity for the active ones.

    ``active`` marks the clients that compete for capacity (the
    prefetch-UNfriendly ones in CPpf; friendly apps take the minimum
    partition because prefetching offsets it).  With no active client the
    capacity is split evenly, distributing the remainder to the
    lowest-index clients so no unit is dropped.

    Args:
      utility_curves: (n, total_units + 1) as in :func:`lookahead_allocate`.
      total_units: capacity to distribute.
      min_units: per-client floor / pinned allocation.
      active: (n,) bool mask of clients that compete for capacity.

    Returns:
      (n,) int allocation summing exactly to ``total_units``.
    """
    curves = np.asarray(utility_curves, dtype=np.float64)
    active = np.asarray(active, dtype=bool)
    n = curves.shape[0]
    units = np.full(n, min_units, dtype=np.int64)
    others = np.where(active)[0]
    remaining = total_units - min_units * int((~active).sum())
    if len(others) > 0:
        units[others] = lookahead_allocate(
            curves[others][:, : remaining + 1], remaining, min_units)
    else:
        extra = total_units - n * min_units
        units += extra // n
        units[: extra % n] += 1
    assert int(units.sum()) == total_units
    return units


class CacheController:
    """Backend-dispatched Lookahead allocator (numpy | JAX | Pallas).

    ``allocate`` accepts utility curves with arbitrary leading batch axes
    ``(..., n, total_units + 1)`` and returns ``(..., n)`` integer
    allocations.  The numpy backend loops the golden-reference greedy over
    the batch on the host; the JAX backend runs the whole batch as one
    jitted device call (:mod:`repro.core.cache_controller_jax`), which is
    what keeps full sweeps device-resident; the Pallas backend swaps the
    batched while_loop for the per-row VMEM-resident kernel
    (:mod:`repro.kernels.lookahead_greedy`, interpret mode off-TPU) behind
    the same entry points.
    """

    def __init__(self, total_units: int, min_units: int = 4,
                 backend: str = "numpy"):
        if backend not in ("numpy", "jax", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        self.total_units = total_units
        self.min_units = min_units
        self.backend = backend

    def _min_units_array(self, min_units, batch_shape):
        mu = self.min_units if min_units is None else min_units
        return np.broadcast_to(
            np.asarray(mu, dtype=np.int64), batch_shape)

    def allocate(self, utility_curves: np.ndarray,
                 min_units=None) -> np.ndarray:
        """Lookahead over ``(..., n, U+1)`` curves -> ``(..., n)`` ints.

        ``min_units`` may override the configured floor, either as a scalar
        or per-batch-element (broadcast against the leading axes) — the
        sweep runner uses this to batch over ``CBPParams.min_ways``.
        """
        curves = np.asarray(utility_curves, dtype=np.float64)
        batch_shape = curves.shape[:-2]
        mus = self._min_units_array(min_units, batch_shape)
        if self.backend in ("jax", "pallas"):
            from repro.core import cache_controller_jax
            return np.asarray(cache_controller_jax.lookahead_allocate(
                curves, self.total_units, mus, backend=self.backend))
        if curves.ndim == 2:
            return lookahead_allocate(curves, self.total_units, int(mus))
        out = np.empty(curves.shape[:-1], dtype=np.int64)
        for idx in np.ndindex(*batch_shape):
            out[idx] = lookahead_allocate(
                curves[idx], self.total_units, int(mus[idx]))
        return out

    def allocate_masked(self, utility_curves: np.ndarray,
                        active: np.ndarray, min_units=None) -> np.ndarray:
        """CPpf-style allocation over ``(..., n, U+1)`` curves.

        ``active`` is ``(..., n)`` bool; inactive clients are pinned at the
        floor and the rest of the capacity is UCP-partitioned among the
        active ones (see :func:`cppf_allocate`).
        """
        curves = np.asarray(utility_curves, dtype=np.float64)
        active = np.asarray(active, dtype=bool)
        batch_shape = curves.shape[:-2]
        mus = self._min_units_array(min_units, batch_shape)
        if self.backend in ("jax", "pallas"):
            from repro.core import cache_controller_jax
            return np.asarray(cache_controller_jax.lookahead_allocate_masked(
                curves, self.total_units, mus, active,
                backend=self.backend))
        if curves.ndim == 2:
            return cppf_allocate(curves, self.total_units, int(mus), active)
        out = np.empty(curves.shape[:-1], dtype=np.int64)
        for idx in np.ndindex(*batch_shape):
            out[idx] = cppf_allocate(
                curves[idx], self.total_units, int(mus[idx]), active[idx])
        return out
