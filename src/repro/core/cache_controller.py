"""Cache-allocation controller: UCP Lookahead (paper §3.2.1).

The controller consumes per-client *utility curves* — hits as a function of
allocated units, measured by the ATD — and produces an integer allocation
that greedily maximizes marginal utility (misses avoided per unit), exactly
as in Qureshi & Patt's Lookahead algorithm.  A ``min_units`` floor is applied
before distribution to adapt to an inclusive hierarchy (paper: "we assign a
minimum allocation of cache space (min_ways) to all the applications before
distributing the remaining capacity").
"""
from __future__ import annotations

import numpy as np


def _max_marginal_utility(curve: np.ndarray, have: int, balance: int):
    """Lookahead's get_max_mu: best (utility/units) step from ``have``.

    Returns ``(mu, k)`` where ``k`` maximizes
    ``(curve[have + k] - curve[have]) / k`` over ``1 <= k <= balance``.
    """
    top = min(have + balance, len(curve) - 1)
    if top <= have:
        return 0.0, 0
    ks = np.arange(1, top - have + 1)
    gains = curve[have + 1: top + 1] - curve[have]
    mus = gains / ks
    best = int(np.argmax(mus))
    return float(mus[best]), int(ks[best])


def lookahead_allocate(
    utility_curves: np.ndarray,
    total_units: int,
    min_units: int = 4,
) -> np.ndarray:
    """Allocate ``total_units`` among clients by greedy marginal utility.

    Args:
      utility_curves: (n, total_units + 1); ``[i, u]`` = hits for client ``i``
        with ``u`` units.  Need not be normalized; only differences matter.
      total_units: capacity to distribute (e.g. 256 x 32 kB = 8 MB).
      min_units: floor per client (paper's ``min_ways``).

    Returns:
      (n,) int allocation summing exactly to ``total_units``.
    """
    curves = np.asarray(utility_curves, dtype=np.float64)
    n = curves.shape[0]
    if curves.shape[1] != total_units + 1:
        raise ValueError(
            f"utility curves must have {total_units + 1} points, "
            f"got {curves.shape[1]}")
    if n * min_units > total_units:
        raise ValueError("min_units * n exceeds capacity")

    alloc = np.full(n, min_units, dtype=np.int64)
    balance = total_units - int(alloc.sum())

    while balance > 0:
        best_mu = -1.0
        best_i = -1
        best_k = 0
        for i in range(n):
            mu, k = _max_marginal_utility(curves[i], int(alloc[i]), balance)
            if k > 0 and mu > best_mu:
                best_mu, best_i, best_k = mu, i, k
        if best_i < 0 or best_mu <= 0.0:
            # No client gains from more cache: spread the remainder evenly
            # (UCP leaves no capacity idle).
            order = np.argsort(-(curves[:, -1] - curves[np.arange(n), alloc]))
            j = 0
            while balance > 0:
                i = int(order[j % n])
                if alloc[i] < total_units:
                    alloc[i] += 1
                    balance -= 1
                j += 1
            break
        alloc[best_i] += best_k
        balance -= best_k

    assert int(alloc.sum()) == total_units
    return alloc


class CacheController:
    """Stateful wrapper pairing :func:`lookahead_allocate` with an ATD."""

    def __init__(self, total_units: int, min_units: int = 4):
        self.total_units = total_units
        self.min_units = min_units

    def allocate(self, utility_curves: np.ndarray) -> np.ndarray:
        return lookahead_allocate(
            utility_curves, self.total_units, self.min_units)
