"""CBP coordination mechanism (paper §3.3, Figs. 6-8).

The coordinator owns the three local controllers and runs the Fig. 8
timeline against a *plant* — anything that can execute an interval under an
allocation and report :class:`~repro.core.types.IntervalStats`.  Two plants
exist in this repo: the 16-core CMP interval model (``repro.sim.runner``,
faithful reproduction) and the TPU runtime knob binding
(``repro.runtime.cbp_runtime``).

Controller prioritization (paper §3.3): cache first ("avoiding a memory
access is typically more effective than lowering the memory access
penalty"), then bandwidth, then prefetch ("the prefetcher setting is
determined based on the current allocation of cache and bandwidth").

Inter-controller feedback is implicit in the measurement loop, exactly as in
the paper: the bandwidth controller sees queuing delays that already reflect
the cache allocation (#1) and prefetch misses (#2); prefetch A/B samples run
under the current cache+bandwidth allocation (#3, #4); the ATD counters see
prefetch hits, shrinking the next cache allocation for prefetch-friendly
clients (#5).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Protocol

import numpy as np

from repro.core.atd import SampledATD
from repro.core.bandwidth_controller import BandwidthController
from repro.core.cache_controller import CacheController
from repro.core.prefetch_controller import PrefetchController
from repro.core.types import (
    Allocation,
    CBPParams,
    IntervalStats,
    Mode,
    PrefetchMode,
    ScheduleConfigError,
)


class Plant(Protocol):
    """What the coordinator manages.

    ``allocator_backend`` selects where the Lookahead cache allocator runs
    ("numpy" host reference | "jax" batched device greedy); consumers read
    it with a "numpy" fallback, so a plant that omits it still works but
    silently stays on the host path — declare it explicitly.
    """

    n_clients: int
    total_cache_units: int
    total_bandwidth: float
    allocator_backend: str

    def run_interval(self, alloc: Allocation,
                     duration_ms: float) -> IntervalStats:
        """Execute ``duration_ms`` under ``alloc`` and report observations."""
        ...


@dataclasses.dataclass
class IntervalRecord:
    t_ms: float
    duration_ms: float
    alloc: Allocation
    stats: IntervalStats


@dataclasses.dataclass(frozen=True)
class ScheduleSegment:
    """One segment of the Fig. 8 timeline.

    ``kind`` is one of ``"reconfigure"`` (zero-duration boundary where the
    cache/bandwidth controllers fire), ``"sample_off"`` / ``"sample_on"``
    (the prefetch A/B sampling periods), and ``"run"`` (the remainder of the
    reconfiguration interval under the decided allocation).
    """

    kind: str
    duration_ms: float


def fig8_schedule(total_ms: float, params: CBPParams,
                  prefetch_dynamic: bool) -> List[ScheduleSegment]:
    """The Fig. 8 timeline as data, shared by every coordinator.

    Both :class:`CBPCoordinator` (one plant at a time) and the batched sweep
    coordinator (``repro.sim.sweep``) execute exactly this segment list, so
    the scalar and batched paths cannot drift apart on scheduling.  The
    non-boundary durations sum exactly to ``total_ms`` whenever each
    reconfiguration interval can contain its sampling overhead (see
    ``tests/test_coordinator_timeline.py``).

    :class:`~repro.core.types.CBPParams` rejects configurations whose
    sampling overhead exceeds the interval at construction; the check is
    repeated here because params are mutable dataclasses and a drifted
    schedule is silent otherwise.
    """
    if prefetch_dynamic and (params.reconfiguration_interval_ms
                             < 2.0 * params.prefetch_sampling_period_ms):
        raise ScheduleConfigError(
            "reconfiguration_interval_ms "
            f"({params.reconfiguration_interval_ms!r}) < 2 * "
            "prefetch_sampling_period_ms "
            f"({params.prefetch_sampling_period_ms!r}): the sampling "
            "overhead does not fit in the interval, so the 'run' segment "
            "would be dropped and reconfigure boundaries would drift")
    segments: List[ScheduleSegment] = []
    t = 0.0
    first = True
    while t < total_ms - 1e-9:
        if not first:
            segments.append(ScheduleSegment("reconfigure", 0.0))
        sampled = 0.0
        if prefetch_dynamic:
            p = params.prefetch_sampling_period_ms
            segments.append(ScheduleSegment("sample_off", p))
            segments.append(ScheduleSegment("sample_on", p))
            sampled = 2.0 * p
            t += sampled
        remain = min(params.reconfiguration_interval_ms - sampled,
                     total_ms - t)
        if remain > 0:
            segments.append(ScheduleSegment("run", remain))
            t += remain
        first = False
    return segments


class CBPCoordinator:
    """Dynamically manage cache, bandwidth and prefetch (paper Fig. 8).

    ``cache_mode`` / ``bandwidth_mode`` / ``prefetch_mode`` select the
    Table-3 resource-manager family; CBP proper is (DYNAMIC, DYNAMIC,
    DYNAMIC).  Subset managers (e.g. ``cache+pref``) reuse the same loop
    with the unmanaged resource pinned, which is how the paper's comparison
    configurations are built.
    """

    def __init__(
        self,
        plant: Plant,
        params: Optional[CBPParams] = None,
        cache_mode: Mode = Mode.DYNAMIC,
        bandwidth_mode: Mode = Mode.DYNAMIC,
        prefetch_mode: PrefetchMode = PrefetchMode.DYNAMIC,
    ):
        self.plant = plant
        self.params = params or CBPParams()
        self.cache_mode = cache_mode
        self.bandwidth_mode = bandwidth_mode
        self.prefetch_mode = prefetch_mode

        n = plant.n_clients
        self.atd = SampledATD(n, plant.total_cache_units)
        # Allocation is backend-dispatched: plants that keep their model on
        # device (CMPConfig(backend="jax")) also keep the Lookahead greedy
        # there (repro.core.cache_controller_jax, bit-parity tested).
        self.cache_ctl = CacheController(
            plant.total_cache_units, self.params.min_ways,
            backend=getattr(plant, "allocator_backend", "numpy"))
        self.bw_ctl = BandwidthController(
            plant.total_bandwidth, self.params.min_bandwidth_allocation,
            decay=self.params.bandwidth_delay_decay)
        self.pf_ctl = PrefetchController(n, self.params.speedup_threshold)
        self.history: List[IntervalRecord] = []
        self._t_ms = 0.0

        # Step 0 (Fig. 8): equal partitions, no miss/delay info yet.
        self.alloc = self._initial_allocation()

    # ------------------------------------------------------------------ #

    def _initial_allocation(self) -> Allocation:
        n = self.plant.n_clients
        units = np.full(n, self.plant.total_cache_units // n, dtype=np.int64)
        units[: self.plant.total_cache_units - int(units.sum())] += 1
        bw = np.full(n, self.plant.total_bandwidth / n, dtype=np.float64)
        pf = np.full(n, self.prefetch_mode == PrefetchMode.ON, dtype=bool)
        return Allocation(
            cache_units=units,
            bandwidth=bw,
            prefetch_on=pf,
            cache_mode=self.cache_mode,
            bandwidth_mode=self.bandwidth_mode,
        )

    def _run(self, alloc: Allocation, duration_ms: float,
             record: bool = True) -> IntervalStats:
        stats = self.plant.run_interval(alloc, duration_ms)
        self.atd.record(stats.utility_curves * (duration_ms / 1.0))
        self.bw_ctl.observe(stats.queuing_delay_ns * duration_ms)
        if record:
            self.history.append(
                IntervalRecord(self._t_ms, duration_ms, alloc.copy(), stats))
        self._t_ms += duration_ms
        return stats

    def _reconfigure(self) -> None:
        """Reconfiguration boundary: cache -> bandwidth (priority order)."""
        if self.cache_mode == Mode.DYNAMIC:
            # Interaction #5: the utility curves already include prefetch
            # hits, so prefetch-friendly clients present flatter curves and
            # receive less cache.
            self.alloc.cache_units = self.cache_ctl.allocate(
                self.atd.utility_curves())
        self.atd.halve(self.params.atd_decay)
        if self.bandwidth_mode == Mode.DYNAMIC:
            # Interactions #1/#2: delays reflect cache allocation and
            # prefetch misses of the prior interval.
            self.alloc.bandwidth = self.bw_ctl.allocate()

    # ------------------------------------------------------------------ #

    def run(self, total_ms: float) -> List[IntervalRecord]:
        """Run the Fig. 8 timeline for ``total_ms``.

        The A/B samples run under the *current* cache+bandwidth allocation —
        interactions #3/#4.
        """
        n = self.plant.n_clients
        stats_off: Optional[IntervalStats] = None
        schedule = fig8_schedule(
            total_ms, self.params,
            self.prefetch_mode == PrefetchMode.DYNAMIC)
        for seg in schedule:
            if seg.kind == "reconfigure":     # Steps 2-3
                self._reconfigure()
            elif seg.kind == "sample_off":    # Step 1/4
                off = self.alloc.copy()
                off.prefetch_on = np.zeros(n, dtype=bool)
                stats_off = self._run(off, seg.duration_ms)
            elif seg.kind == "sample_on":
                on = self.alloc.copy()
                on.prefetch_on = np.ones(n, dtype=bool)
                stats_on = self._run(on, seg.duration_ms)
                self.alloc.prefetch_on = self.pf_ctl.update(
                    stats_on.ipc, stats_off.ipc)
            else:
                self._run(self.alloc, seg.duration_ms)
        return self.history

    # Aggregation helpers ------------------------------------------------ #

    def mean_ipc(self) -> np.ndarray:
        """Time-weighted mean performance per client over the run."""
        total = np.zeros(self.plant.n_clients)
        t = 0.0
        for rec in self.history:
            total += rec.stats.ipc * rec.duration_ms
            t += rec.duration_ms
        return total / max(t, 1e-12)
