"""Batched JAX port of the UCP Lookahead allocator (paper §3.2.1).

:func:`lookahead_allocate` takes ``(..., n, total_units + 1)`` utility
curves and returns ``(..., n)`` integer allocations — the whole batch runs
as ONE jitted device call, a bounded-trip ``lax.while_loop`` greedy over a
masked marginal-utility argmax.  This is what lets the sweep substrate
(:mod:`repro.sim.sweep`) reconfigure every mix of a Table-3 sweep without a
single per-mix host allocator call.

Parity contract: bit-identical to the numpy golden reference
(:func:`repro.core.cache_controller.lookahead_allocate`) away from tie
knife-edges, under the shared deterministic tie-breaks (lowest client index
wins equal marginal utility; smallest step wins within a client; the
zero-utility spread orders by remaining gain with a stable sort).  Enforced
by ``tests/test_cache_controller_jax.py``.  Change the numpy reference
first, then mirror here.

:func:`lookahead_allocate_masked` is the CPpf variant
(:func:`repro.core.cache_controller.cppf_allocate`): inactive clients are
pinned at the floor and the greedy runs over the active subset, matching
the scalar subset call exactly (including the subset-local spread column).

``min_units`` may vary per batch element (a traced array), which is how
``run_sweep(param_grid=...)`` batches over ``CBPParams.min_ways``.
"""
from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import record_dispatch

try:  # pragma: no cover - present on every supported JAX
    from jax.experimental import enable_x64 as _enable_x64
except ImportError:  # pragma: no cover
    _enable_x64 = None


def _x64_context():
    """The greedy compares float64 marginal utilities; run in x64 so the
    bit-parity contract with the numpy reference holds."""
    if _enable_x64 is None:
        if not jax.config.jax_enable_x64:
            # Without x64 the float64 inputs would silently downcast and
            # the greedy could round differently from the numpy reference
            # — refuse rather than break the parity contract quietly.
            raise RuntimeError(
                "batched Lookahead needs float64: this JAX has no "
                "jax.experimental.enable_x64 and jax_enable_x64 is off; "
                "enable x64 or use CacheController(backend='numpy')")
        return contextlib.nullcontext()
    return _enable_x64()


def _resolve_backend(backend):
    """``None`` -> the platform default: the Pallas kernel where it lowers
    natively (TPU), the batched while_loop elsewhere (interpret-mode Pallas
    on CPU is a correctness harness, not a fast path)."""
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "jax"
    if backend not in ("jax", "pallas"):
        raise ValueError(f"unknown lookahead backend {backend!r}")
    return backend


@functools.partial(jax.jit, static_argnames=("total_units",))
def _greedy_loop(
    curves: jnp.ndarray,     # (B, n, U + 1) float64
    min_units: jnp.ndarray,  # (B,) int
    active: jnp.ndarray,     # (B, n) bool
    remaining: jnp.ndarray,  # (B,) int — top curve column per batch element
    total_units: int,
):
    """Bounded-trip while_loop greedy over cached per-client best steps.

    The reference recomputes every client's best ``(mu, k)`` each greedy
    iteration, but between iterations only the stepped client's curve
    position changes; any other cached best stays the exact
    argmax-with-tie-breaks as long as its ``k`` still fits the shrunken
    balance cap (the argmax over a subset that still contains the old
    argmax is unchanged).  So: one full ``(B, n, U)`` pass prefills the
    cache, then each trip refreshes at most ONE stale client per batch
    element with ``(B, U)``-sized work — ~n-fold less memory traffic per
    trip, which is what the CPU while_loop is bound by — and rows with a
    fully valid cache take their greedy step in the same trip.  (A
    full-``(B, n, U)``-recompute-per-trip variant — the Pallas kernel's
    recurrence, ``U + 2`` bound — was measured 7-15x SLOWER here: the
    per-trip ``(B, n, U)`` gathers cost far more than the extra trips.)

    A batch element whose best mu goes non-positive is *stuck*: its
    allocation no longer changes, so its mus can't either — the loop
    retires it and the reference's zero-utility spread (distribute the
    whole balance by remaining potential gain) is applied ONCE, after the
    loop, to every retired element.

    Returns ``(alloc, balance, stuck, it)`` — the greedy allocation, the
    undistributed balance for :func:`_zero_spread`, the per-row stuck
    flags, and the body-application count (two per while trip), which the
    trip-bound regression test audits.
    """
    B, n, _ = curves.shape
    U = total_units
    ks = jnp.arange(1, U + 1, dtype=jnp.int32)                 # (U,)
    ksf = ks.astype(curves.dtype)
    neg_inf = jnp.array(-jnp.inf, curves.dtype)
    iota_n = jnp.arange(n, dtype=jnp.int32)

    min32 = min_units.astype(jnp.int32)
    alloc0 = jnp.broadcast_to(min32[:, None], (B, n))
    balance0 = U - n * min32
    rem32 = remaining.astype(jnp.int32)
    stuck0 = jnp.zeros((B,), dtype=bool)

    def caps(alloc, balance):
        """Per-client step cap: k <= balance, alloc + k inside the
        (sub)curve, inactive clients excluded."""
        cap = jnp.minimum(balance[:, None], rem32[:, None] - alloc)
        return jnp.where(active, cap, 0)                        # (B, n)

    # ---- prefill: every client's best (mu, k), one full pass --------- #
    cap0 = caps(alloc0, balance0)
    idx = alloc0[:, :, None] + ks[None, None, :]                # (B, n, U)
    base = jnp.take_along_axis(curves, alloc0[:, :, None], axis=-1)
    gain = jnp.take_along_axis(curves, jnp.minimum(idx, U), axis=-1) - base
    mus = jnp.where(ks[None, None, :] <= cap0[:, :, None],
                    gain / ksf, neg_inf)
    # argmax picks the FIRST max -> smallest k: the reference tie-break.
    k_c0 = jnp.where(cap0 > 0,
                     jnp.argmax(mus, axis=-1).astype(jnp.int32) + 1, 0)
    mu_c0 = jnp.where(cap0 > 0, jnp.max(mus, axis=-1), neg_inf)
    dirty0 = jnp.zeros((B, n), dtype=bool)

    def cond(state):
        _alloc, balance, stuck, _mu, _k, _dirty, it = state
        # Trip bound: <= U greedy steps per row, and between consecutive
        # steps each client refreshes at most once -> (n + 2) * U is safe.
        return (it < (n + 2) * U) & jnp.any((balance > 0) & ~stuck)

    def body(state):
        alloc, balance, stuck, mu_c, k_c, dirty, it = state
        cap_now = caps(alloc, balance)
        # ---- refresh one stale cache entry per row ------------------- #
        invalid = active & (dirty | (k_c > cap_now))
        n_inv = jnp.sum(invalid, axis=-1)                       # (B,)
        j = jnp.argmax(invalid, axis=-1).astype(jnp.int32)      # first stale
        has_inv = n_inv > 0
        c_j = jnp.take_along_axis(curves, j[:, None, None], axis=1)[:, 0, :]
        have_j = jnp.take_along_axis(alloc, j[:, None], -1)[:, 0]
        cap_j = jnp.take_along_axis(cap_now, j[:, None], -1)[:, 0]
        idx_j = have_j[:, None] + ks[None, :]                   # (B, U)
        base_j = jnp.take_along_axis(c_j, have_j[:, None], -1)
        gain_j = jnp.take_along_axis(c_j, jnp.minimum(idx_j, U), -1) - base_j
        mu_vec = jnp.where(ks[None, :] <= cap_j[:, None],
                           gain_j / ksf, neg_inf)
        k_j = jnp.where(cap_j > 0,
                        jnp.argmax(mu_vec, axis=-1).astype(jnp.int32) + 1, 0)
        mu_j = jnp.where(cap_j > 0, jnp.max(mu_vec, axis=-1), neg_inf)
        at_j = (iota_n[None, :] == j[:, None]) & has_inv[:, None]
        mu_c = jnp.where(at_j, mu_j[:, None], mu_c)
        k_c = jnp.where(at_j, k_j[:, None], k_c)
        dirty = dirty & ~at_j

        # ---- greedy step for rows whose cache is now fully valid ----- #
        # argmax over clients picks the FIRST max -> lowest client index.
        i_best = jnp.argmax(mu_c, axis=-1).astype(jnp.int32)    # (B,)
        mu_sel = jnp.max(mu_c, axis=-1)
        k_sel = jnp.take_along_axis(k_c, i_best[:, None], -1)[:, 0]
        live = (balance > 0) & ~stuck
        ready = live & (n_inv <= 1)
        do_greedy = ready & (mu_sel > 0.0)
        at_i = (iota_n[None, :] == i_best[:, None]) & do_greedy[:, None]
        alloc = alloc + jnp.where(at_i, k_sel[:, None], 0)
        balance = balance - jnp.where(do_greedy, k_sel, 0)
        dirty = dirty | at_i
        stuck = stuck | (ready & ~(mu_sel > 0.0))
        return alloc, balance, stuck, mu_c, k_c, dirty, it + 1

    def body_quad(state):
        # Four body applications per while trip: once a row is finished
        # (balance exhausted or stuck) body is a no-op for it, so the
        # unroll preserves the exact greedy trajectory while quartering
        # the loop's per-trip overhead on CPU (the trips are tiny-op
        # bound — cond + carry rotation cost as much as the body).
        return body(body(body(body(state))))

    alloc, balance, stuck, it = (lambda s: (s[0], s[1], s[2], s[6]))(
        jax.lax.while_loop(
            cond, body_quad,
            (alloc0, balance0, stuck0, mu_c0, k_c0, dirty0, jnp.int32(0))))
    return alloc, balance, stuck, it


def _zero_spread(curves, alloc, balance, active, remaining):
    """The reference's even-spread branch: distribute the undistributed
    balance by remaining potential gain (stable order).  Runs once, outside
    the greedy loop, for elements retired with balance left — shared by the
    while_loop and Pallas backends."""
    B, n, _ = curves.shape
    cur = jnp.take_along_axis(curves, alloc[:, :, None], -1)[:, :, 0]
    top = jnp.take_along_axis(
        curves, jnp.broadcast_to(remaining[:, None, None], (B, n, 1)),
        -1)[:, :, 0]
    key = jnp.where(active, -(top - cur), jnp.inf)
    order = jnp.argsort(key, axis=-1, stable=True)
    rank = jnp.argsort(order, axis=-1)          # inverse permutation
    n_act = jnp.maximum(jnp.sum(active, axis=-1), 1)            # (B,)
    share = (balance[:, None] // n_act[:, None]
             + (rank < (balance % n_act)[:, None]))
    need = balance > 0
    alloc = jnp.where((need[:, None]) & active, alloc + share, alloc)
    return alloc


def _greedy_core(curves, min_units, active, remaining, total_units: int,
                 backend=None):
    """Backend-dispatched greedy + shared spread.

    ``backend="jax"`` runs the batched incremental-refresh while_loop;
    ``backend="pallas"`` runs the per-row VMEM-resident kernel
    (:mod:`repro.kernels.lookahead_greedy`).  Both feed the same
    :func:`_zero_spread`, so they are interchangeable bit for bit.
    """
    backend = _resolve_backend(backend)
    if backend == "pallas":
        from repro.kernels.lookahead_greedy import ops as _lookahead_ops
        alloc, balance = _lookahead_ops.lookahead_greedy(
            curves, min_units, active.astype(jnp.int32),
            remaining, total_units=total_units)
    else:
        alloc, balance, _stuck, _it = _greedy_loop(
            curves, min_units, active, remaining,
            total_units=total_units)
    return _zero_spread(curves, alloc, balance, active, remaining)


def lookahead_traced(curves, min_units, total_units: int, backend=None):
    """Traced Lookahead over ``(B, n, U+1)`` curves -> ``(B, n)`` int32.

    For use *inside* jitted programs (the fused Fig. 8 timeline scans over
    this at every reconfiguration boundary).  ``curves`` must already be
    float64 and ``min_units`` an integer ``(B,)`` array; the host-side
    feasibility checks are the caller's responsibility (hoisted out of the
    traced region, see :mod:`repro.sim.timeline_jax`).
    """
    B, n, _ = curves.shape
    return _greedy_core(
        curves, min_units, jnp.ones((B, n), dtype=bool),
        jnp.full((B,), total_units, dtype=jnp.int32),
        total_units=total_units, backend=backend)


def lookahead_masked_traced(curves, min_units, active, total_units: int,
                            backend=None):
    """Traced CPpf allocation (:func:`lookahead_allocate_masked` inside jit).

    Pins inactive clients at the floor and runs the greedy over the active
    subset; the all-inactive fallback (even split, remainder to the lowest
    indices) is folded in as a ``where`` so the whole decision stays on
    device.
    """
    B, n, _ = curves.shape
    min32 = min_units.astype(jnp.int32)
    remaining = (total_units
                 - min32 * (n - active.sum(axis=-1).astype(jnp.int32)))
    out = _greedy_core(curves, min_units, active, remaining,
                       total_units=total_units, backend=backend)
    none_active = ~active.any(axis=-1)
    extra = total_units - n * min32
    even = (min32[:, None] + extra[:, None] // n
            + (jnp.arange(n, dtype=jnp.int32)[None, :]
               < (extra % n)[:, None]))
    return jnp.where(none_active[:, None], even, out)


def _validate(curves: np.ndarray, total_units: int,
              min_units: np.ndarray) -> None:
    if curves.shape[-1] != total_units + 1:
        raise ValueError(
            f"utility curves must have {total_units + 1} points, "
            f"got {curves.shape[-1]}")
    n = curves.shape[-2]
    if np.any(min_units * n > total_units):
        raise ValueError("min_units * n exceeds capacity")


def _flatten(curves: np.ndarray, min_units) -> tuple:
    batch_shape = curves.shape[:-2]
    flat = curves.reshape((-1,) + curves.shape[-2:])
    mus = np.broadcast_to(
        np.asarray(min_units, dtype=np.int64), batch_shape).reshape(-1)
    if flat.shape[0] == 0:
        raise ValueError("empty batch")
    return batch_shape, flat, mus


def lookahead_allocate(
    utility_curves,
    total_units: int,
    min_units=4,
    backend=None,
) -> np.ndarray:
    """Batched Lookahead: ``(..., n, U+1)`` curves -> ``(..., n)`` ints.

    Drop-in batched counterpart of the numpy reference; ``min_units`` may
    be a scalar or broadcast against the leading batch axes.
    """
    curves = np.asarray(utility_curves, dtype=np.float64)
    if curves.ndim < 2:
        raise ValueError("utility curves must be at least 2-D")
    batch_shape, flat, mus = _flatten(curves, min_units)
    _validate(curves, total_units, mus)
    B, n, _ = flat.shape
    record_dispatch()
    with _x64_context():
        out = _greedy_core(
            jnp.asarray(flat, dtype=jnp.float64),
            jnp.asarray(mus),
            jnp.ones((B, n), dtype=bool),
            jnp.full((B,), total_units, dtype=jnp.int64),
            total_units=int(total_units), backend=backend)
        out = np.asarray(out)
    assert (out.sum(axis=-1) == total_units).all()
    return out.reshape(batch_shape + (n,)).astype(np.int64)


@functools.lru_cache(maxsize=None)
def _compiled_grouped(total_units_key: tuple, backend: str):
    """One jitted program running a greedy per capacity group.

    Groups with different ``total_units`` cannot share one ``_greedy_loop``
    call (the capacity is a static argument and fixes the curve width), but
    they CAN share one program: the per-group greedies are independent
    subgraphs of a single jit, so a multi-capacity plan costs one dispatch
    — the same bucketing trick as ``timeline_jax._compiled_buckets``.
    """

    def run(groups):
        outs = []
        for (curves, mins), units in zip(groups, total_units_key):
            B, n, _ = curves.shape
            outs.append(_greedy_core(
                curves, mins, jnp.ones((B, n), dtype=bool),
                jnp.full((B,), units, dtype=jnp.int64),
                total_units=units, backend=backend))
        return tuple(outs)

    return jax.jit(run)


def lookahead_allocate_grouped(
    curve_groups,
    total_units_list,
    min_units=4,
    backend=None,
):
    """Batched Lookahead over groups with *different* capacities — one call.

    Args:
      curve_groups: sequence of ``(B_g, n_g, U_g + 1)`` float64 curve
        batches, one per capacity group.
      total_units_list: per-group capacity (``U_g``), same length.
      min_units: scalar floor, or a sequence of per-group scalars /
        ``(B_g,)`` arrays.
      backend: as in :func:`lookahead_allocate`.

    Returns:
      List of ``(B_g, n_g)`` int64 allocations, bit-identical per row to
      the scalar numpy reference.  The whole multi-group plan is ONE device
      dispatch (counter-gated by the runtime smoke) — this is what lets
      ``plan_matmul_blocks_batched`` plan shapes with different VMEM
      budgets in a single program.
    """
    if len(curve_groups) != len(total_units_list):
        raise ValueError("one total_units per curve group required")
    if len(curve_groups) == 0:
        raise ValueError("empty group list")
    if np.isscalar(min_units):
        min_units = [min_units] * len(curve_groups)
    prepared = []
    for curves, units, mus in zip(curve_groups, total_units_list, min_units):
        curves = np.asarray(curves, dtype=np.float64)
        if curves.ndim != 3:
            raise ValueError("grouped curves must be (B, n, U + 1)")
        B, n, _ = curves.shape
        mus = np.broadcast_to(np.asarray(mus, dtype=np.int64), (B,))
        _validate(curves, int(units), mus)
        prepared.append((curves, int(units), mus))
    backend = _resolve_backend(backend)
    fn = _compiled_grouped(tuple(u for _, u, _m in prepared), backend)
    record_dispatch()
    with _x64_context():
        outs = fn(tuple((jnp.asarray(c, dtype=jnp.float64), jnp.asarray(m))
                        for c, _u, m in prepared))
        outs = [np.asarray(o) for o in outs]
    for out, (_c, units, _m) in zip(outs, prepared):
        assert (out.sum(axis=-1) == units).all()
    return [o.astype(np.int64) for o in outs]


def lookahead_allocate_masked(
    utility_curves,
    total_units: int,
    min_units,
    active,
    backend=None,
) -> np.ndarray:
    """Batched CPpf allocation: pin inactive clients at the floor, UCP over
    the active subset (bit-parity with
    :func:`repro.core.cache_controller.cppf_allocate` per batch element).
    """
    curves = np.asarray(utility_curves, dtype=np.float64)
    if curves.ndim < 2:
        raise ValueError("utility curves must be at least 2-D")
    batch_shape, flat, mus = _flatten(curves, min_units)
    _validate(curves, total_units, mus)
    B, n, _ = flat.shape
    act = np.broadcast_to(
        np.asarray(active, dtype=bool), batch_shape + (n,)).reshape(B, n)
    # The scalar path runs the greedy on curves sliced to the capacity left
    # after pinning — column `remaining` is that slice's last column, which
    # the spread key reads.
    remaining = total_units - mus * (n - act.sum(axis=-1))
    record_dispatch()
    with _x64_context():
        out = _greedy_core(
            jnp.asarray(flat, dtype=jnp.float64),
            jnp.asarray(mus),
            jnp.asarray(act),
            jnp.asarray(remaining),
            total_units=int(total_units), backend=backend)
        out = np.asarray(out)
    none_active = ~act.any(axis=-1)
    if none_active.any():
        # All clients pinned: split evenly, remainder to the lowest indices
        # (the reference's fixed all-friendly branch).
        extra = total_units - n * mus
        even = (mus + extra // n)[:, None] + (
            np.arange(n)[None, :] < (extra % n)[:, None])
        out = np.where(none_active[:, None], even, out)
    assert (out.sum(axis=-1) == total_units).all()
    return out.reshape(batch_shape + (n,)).astype(np.int64)
