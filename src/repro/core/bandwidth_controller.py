"""Bandwidth-allocation controller (paper §3.2.2, Algorithm 1).

Partitions total bandwidth proportionally to the per-client memory queuing
delay observed in the previous interval: clients that waited longer get more.
Every client first receives ``min_bandwidth_allocation`` ("in order to avoid
unfairly giving a very low allocation to applications with a small queuing
delay"); the remainder is split pro-rata by accumulated delay.

:func:`allocate_bandwidth` is the numpy golden reference;
:func:`allocate_bandwidth_jax` is the traced mirror used inside the fused
Fig. 8 timeline (:mod:`repro.sim.timeline_jax`).  The ``min_allocation * n
> total`` feasibility check is deliberately hoisted out of the traced
mirror — callers validate once on the host (:func:`check_bandwidth_floor`)
before compiling a timeline.
"""
from __future__ import annotations

import numpy as np


def allocate_bandwidth(
    queuing_delay: np.ndarray,
    total_bandwidth: float,
    min_allocation: float,
) -> np.ndarray:
    """Algorithm 1, verbatim, vectorized over leading batch axes.

    Args:
      queuing_delay: (..., n) accumulated per-client queuing delays (any
        unit — only proportions matter).  Leading axes (e.g. the sweep
        runner's mix axis) each get an independent allocation.
      total_bandwidth: capacity to distribute (GB/s).
      min_allocation: per-client floor (GB/s) — a scalar, or an array
        broadcastable against the leading batch axes (shape ``(..., 1)``),
        which is how ``run_sweep(param_grid=...)`` batches over
        ``CBPParams.min_bandwidth_allocation``.

    Returns:
      (..., n) float allocation summing to ``total_bandwidth`` per batch.
    """
    delay = np.asarray(queuing_delay, dtype=np.float64)
    n = delay.shape[-1]
    min_alloc = np.asarray(min_allocation, dtype=np.float64)
    check_bandwidth_floor(min_alloc, n, total_bandwidth)

    # line 2: remaining after floors (line 5: every client gets the floor)
    remaining = total_bandwidth - min_alloc * n

    total_delay = delay.sum(axis=-1, keepdims=True)  # line 4
    # lines 7-9: proportional share of the remainder; no one queued ->
    # split the remainder evenly.
    share = np.where(total_delay > 0,
                     delay / np.where(total_delay > 0, total_delay, 1.0),
                     1.0 / n)
    return min_alloc + share * remaining


def check_bandwidth_floor(min_allocation, n_clients: int,
                          total_bandwidth: float) -> None:
    """Host-side feasibility check for Algorithm 1 (raises ``ValueError``).

    Kept out of the traced :func:`allocate_bandwidth_jax` so the fused
    timeline validates once per program instead of per segment.
    """
    if np.any(np.asarray(min_allocation, dtype=np.float64) * n_clients
              > total_bandwidth):
        raise ValueError("min_allocation * n exceeds total bandwidth")


def allocate_bandwidth_jax(queuing_delay, total_bandwidth, min_allocation):
    """Traced JAX mirror of :func:`allocate_bandwidth` (no feasibility check).

    Same op-for-op arithmetic over ``jax.numpy`` so the fused timeline's
    bandwidth decisions match the numpy reference bit-for-bit (property
    parity: ``tests/test_controllers_jax.py``).  ``min_allocation`` may be
    a scalar or a ``(..., 1)`` array of per-row floors.
    """
    import jax.numpy as jnp

    delay = jnp.asarray(queuing_delay)
    n = delay.shape[-1]
    min_alloc = jnp.asarray(min_allocation, dtype=delay.dtype)
    remaining = total_bandwidth - min_alloc * n
    total_delay = delay.sum(axis=-1, keepdims=True)
    share = jnp.where(total_delay > 0,
                      delay / jnp.where(total_delay > 0, total_delay, 1.0),
                      1.0 / n)
    return min_alloc + share * remaining


class BandwidthController:
    """Stateful wrapper: accumulates delays across intervals (paper §3.3,

    "per application queuing delays are accumulated with those from the
    previous interval"), with a decay factor so stale phases wash out.
    """

    def __init__(self, total_bandwidth: float, min_allocation: float,
                 decay: float = 0.5):
        self.total_bandwidth = total_bandwidth
        self.min_allocation = min_allocation
        self.decay = decay
        self._acc: np.ndarray | None = None

    def observe(self, queuing_delay: np.ndarray) -> None:
        delay = np.asarray(queuing_delay, dtype=np.float64)
        if self._acc is None:
            self._acc = delay.copy()
        else:
            self._acc = self.decay * self._acc + delay

    def allocate(self) -> np.ndarray:
        if self._acc is None:
            raise RuntimeError("no delays observed yet")
        return allocate_bandwidth(
            self._acc, self.total_bandwidth, self.min_allocation)
