"""CBP: coordinated cache partitioning, bandwidth partitioning and prefetch
throttling (Holtryd et al., 2021) — the paper's primary contribution.

The three local controllers (paper §3.2) and the coordination mechanism
(paper §3.3) are domain-agnostic; they are bound to the CMP interval model in
``repro.sim`` (faithful reproduction) and to TPU memory-system knobs in
``repro.runtime`` / ``repro.serving`` / ``repro.kernels`` (hardware
adaptation — see DESIGN.md §2).
"""
from repro.core.atd import SampledATD, StackDistanceMonitor
from repro.core.bandwidth_controller import (
    BandwidthController,
    allocate_bandwidth,
    allocate_bandwidth_jax,
    check_bandwidth_floor,
)
from repro.core.cache_controller import (
    CacheController,
    allocator_calls,
    cppf_allocate,
    lookahead_allocate,
    reset_allocator_calls,
)
from repro.core.coordinator import (
    CBPCoordinator,
    Plant,
    ScheduleSegment,
    fig8_schedule,
)
from repro.core.dispatch import (
    device_dispatches,
    record_dispatch,
    reset_device_dispatches,
)
from repro.core.prefetch_controller import (
    PrefetchController,
    throttle_decision,
    throttle_decision_jax,
)
from repro.core.types import (
    Allocation,
    CBPParams,
    IntervalStats,
    Mode,
    PrefetchMode,
    ScheduleConfigError,
)

__all__ = [
    "SampledATD",
    "StackDistanceMonitor",
    "BandwidthController",
    "allocate_bandwidth",
    "allocate_bandwidth_jax",
    "check_bandwidth_floor",
    "CacheController",
    "allocator_calls",
    "cppf_allocate",
    "lookahead_allocate",
    "reset_allocator_calls",
    "CBPCoordinator",
    "Plant",
    "ScheduleSegment",
    "fig8_schedule",
    "device_dispatches",
    "record_dispatch",
    "reset_device_dispatches",
    "PrefetchController",
    "throttle_decision",
    "throttle_decision_jax",
    "Allocation",
    "CBPParams",
    "IntervalStats",
    "Mode",
    "PrefetchMode",
    "ScheduleConfigError",
]
