"""Auxiliary Tag Directory (ATD) machinery (paper §3.2.1 / §3.4).

The paper uses sampled ATDs [Qureshi & Patt, MICRO'06 "UMON"] to estimate,
per application, how many misses would be avoided with additional cache ways.
Two implementations are provided:

* :class:`SampledATD` — the counter container used by the cache-allocation
  controller.  The *plant* (CMP model or KV pool) feeds it per-interval
  utility measurements; counters are halved after every reconfiguration
  (paper §3.3, "The ATD values will be halved after each reconfiguration").

* :class:`StackDistanceMonitor` — an online LRU stack-distance histogram.
  This is the software ATD used by the TPU binding (``repro.serving``): each
  KV-pool client records page accesses, and the histogram converts directly
  into a hits-vs-pages utility curve, exactly like UMON-global.
"""
from __future__ import annotations

from typing import Dict, Hashable, List

import numpy as np


class SampledATD:
    """Per-client utility counters with reconfiguration-time halving."""

    def __init__(self, n_clients: int, total_units: int):
        self.n_clients = n_clients
        self.total_units = total_units
        self._counters = np.zeros((n_clients, total_units + 1), dtype=np.float64)

    def record(self, utility_curves: np.ndarray) -> None:
        """Accumulate an interval's hits-vs-units measurement.

        ``utility_curves[i, u]`` = hits client ``i`` would have observed with
        ``u`` units during the interval.  Curves must be non-decreasing in
        ``u`` (more cache never yields fewer hits under LRU inclusion).
        """
        curves = np.asarray(utility_curves, dtype=np.float64)
        if curves.shape != self._counters.shape:
            raise ValueError(
                f"expected {self._counters.shape}, got {curves.shape}")
        self._counters += curves

    def halve(self, decay: float = 0.5) -> None:
        """Decay history so recent behaviour dominates (paper §3.3).

        ``decay`` defaults to the paper's halving; callers wire it from
        ``CBPParams.atd_decay`` so the constant is sweepable.
        """
        self._counters *= decay

    def utility_curves(self) -> np.ndarray:
        """Current hits-vs-units estimate, shape (n_clients, units + 1)."""
        return self._counters.copy()

    def reset(self) -> None:
        self._counters[:] = 0.0


class StackDistanceMonitor:
    """Online LRU stack-distance histogram over an access stream.

    ``access(key)`` returns the LRU stack distance of ``key`` (0 == MRU hit,
    ``inf``/``capacity`` == cold miss) and updates the recency stack.  The
    histogram then answers: *with c units of cache, how many of the observed
    accesses would have hit?* — which is precisely the utility curve the
    Lookahead allocator consumes.
    """

    def __init__(self, max_units: int):
        self.max_units = max_units
        self._stack: List[Hashable] = []      # index 0 == MRU
        self._pos: Dict[Hashable, int] = {}   # key -> stack index (lazy)
        self._hist = np.zeros(max_units + 1, dtype=np.float64)  # [d] counts
        self._cold = 0.0
        self._accesses = 0.0

    def access(self, key: Hashable) -> int:
        self._accesses += 1
        try:
            depth = self._stack.index(key)
        except ValueError:
            depth = -1
        if depth < 0:
            self._cold += 1
            self._stack.insert(0, key)
            if len(self._stack) > self.max_units:
                self._stack.pop()
            return self.max_units
        # Hit at stack distance `depth`: with > depth units it would hit.
        if depth < len(self._hist):
            self._hist[depth] += 1
        else:
            self._cold += 1
        self._stack.pop(depth)
        self._stack.insert(0, key)
        return depth

    def utility_curve(self) -> np.ndarray:
        """hits(u) for u in 0..max_units (non-decreasing)."""
        hits = np.zeros(self.max_units + 1, dtype=np.float64)
        np.cumsum(self._hist[:-1], out=hits[1:])
        return hits

    def halve(self) -> None:
        self._hist *= 0.5
        self._cold *= 0.5
        self._accesses *= 0.5

    @property
    def accesses(self) -> float:
        return self._accesses
