"""Sharded, atomic, async checkpointing (fault-tolerance substrate).

Layout: one directory per step containing ``<leaf-path>.npy`` files plus a
msgpack manifest with the treedef, dtypes and the data-pipeline state.
Writes go to ``<dir>.tmp`` and are renamed atomically; a ``LATEST`` file is
updated last, so a crash mid-save can never corrupt the restore point
(restart always resumes from the last complete step).  ``save_async``
snapshots to host memory synchronously (cheap) and writes in a background
thread so the train loop is not blocked — the paper's "bandwidth" knob in
this substrate is the rate limit on these background writes, which the CBP
bandwidth controller can squeeze when the input pipeline is starved.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import msgpack
import numpy as np

# numpy can't round-trip bf16/fp8 natively; store them as uint16/uint8 views
_VIEW_DTYPES = {"bfloat16": (np.uint16, ml_dtypes.bfloat16),
                "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn)}


def _flatten_with_names(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[name] = np.asarray(leaf)
    return flat


def save_pytree(tree, directory: pathlib.Path,
                extra: Optional[Dict] = None,
                rate_limit_mbps: Optional[float] = None) -> None:
    directory = pathlib.Path(directory)
    tmp = directory.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten_with_names(tree)
    manifest = {"leaves": {}, "extra": extra or {}}
    for name, arr in flat.items():
        fn = name.replace("/", "__") + ".npy"
        t0 = time.monotonic()
        disk = arr
        if str(arr.dtype) in _VIEW_DTYPES:
            disk = arr.view(_VIEW_DTYPES[str(arr.dtype)][0])
        np.save(tmp / fn, disk)
        if rate_limit_mbps:
            expect = arr.nbytes / (rate_limit_mbps * 1e6)
            sleep = expect - (time.monotonic() - t0)
            if sleep > 0:
                time.sleep(sleep)
        manifest["leaves"][name] = {
            "file": fn, "dtype": str(arr.dtype), "shape": list(arr.shape)}
    (tmp / "manifest.msgpack").write_bytes(msgpack.packb(manifest))
    if directory.exists():
        shutil.rmtree(directory)
    os.rename(tmp, directory)


def load_pytree(directory: pathlib.Path, like) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  Returns (tree, extra)."""
    directory = pathlib.Path(directory)
    manifest = msgpack.unpackb(
        (directory / "manifest.msgpack").read_bytes())
    flat_like = _flatten_with_names(like) if not isinstance(like, dict) or \
        True else like
    names = list(flat_like)
    leaves_meta = manifest["leaves"]
    arrays = {}
    for name in names:
        meta = leaves_meta[name]
        arr = np.load(directory / meta["file"])
        if meta["dtype"] in _VIEW_DTYPES:
            arr = arr.view(_VIEW_DTYPES[meta["dtype"]][1])
        arrays[name] = arr
    # Rebuild in `like` order.
    flat_paths = jax.tree_util.tree_flatten_with_path(like)
    rebuilt = []
    for path, leaf in flat_paths[0]:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = arrays[name]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = np.asarray(jax.numpy.asarray(arr).astype(leaf.dtype))
        rebuilt.append(arr)
    tree = jax.tree_util.tree_unflatten(flat_paths[1], rebuilt)
    return tree, manifest.get("extra", {})


class CheckpointManager:
    """keep-last-k manager with async save and crash-safe restore."""

    def __init__(self, root: pathlib.Path, keep: int = 3):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.write_rate_limit_mbps: Optional[float] = None  # CBP bw knob

    def _step_dir(self, step: int) -> pathlib.Path:
        return self.root / f"step_{step:010d}"

    def save(self, step: int, tree, extra: Optional[Dict] = None) -> None:
        save_pytree(tree, self._step_dir(step), extra,
                    rate_limit_mbps=self.write_rate_limit_mbps)
        (self.root / "LATEST.tmp").write_text(str(step))
        os.replace(self.root / "LATEST.tmp", self.root / "LATEST")
        self._gc()

    def save_async(self, step: int, tree,
                   extra: Optional[Dict] = None) -> None:
        """Snapshot now (device->host copy), write in the background."""
        self.wait()
        snapshot = jax.tree.map(lambda x: np.asarray(x), tree)

        def _write():
            self.save(step, snapshot, extra)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def latest_step(self) -> Optional[int]:
        latest = self.root / "LATEST"
        if not latest.exists():
            return None
        step = int(latest.read_text().strip())
        if not self._step_dir(step).exists():
            # crash between data write and LATEST update: fall back
            steps = self.all_steps()
            return steps[-1] if steps else None
        return step

    def all_steps(self):
        out = []
        for p in self.root.iterdir():
            m = re.match(r"step_(\d+)$", p.name)
            if m and (p / "manifest.msgpack").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def restore_latest(self, like) -> Optional[Tuple[int, Any, Dict]]:
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = load_pytree(self._step_dir(step), like)
        return step, tree, extra

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
