"""grok-1-314b — assigned architecture config.

# [moe] 8 experts top-2 (padded to 16 for the 16-way model axis)
# [hf:xai-org/grok-1; unverified]
"""
from repro.models.config import ModelConfig
import dataclasses

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=32768,
    vocab_size=131072,
    n_experts=8,
    top_k=2,
)

# Reduced same-family smoke config: tiny widths/depths, one CPU train step.
SMOKE = dataclasses.replace(
    CONFIG,
    param_dtype='float32',
    remat='none',
    attn_chunk=64,
    seq_shard_activations=False,
    vocab_size=512,
    d_model=64,
    d_ff=64,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    n_experts=8,
    top_k=2,
)
