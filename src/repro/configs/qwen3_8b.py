"""qwen3-8b — assigned architecture config.

# [dense] qk_norm + GQA [hf:Qwen/Qwen3-8B; hf]
"""
from repro.models.config import ModelConfig
import dataclasses

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
)

# Reduced same-family smoke config: tiny widths/depths, one CPU train step.
SMOKE = dataclasses.replace(
    CONFIG,
    param_dtype='float32',
    remat='none',
    attn_chunk=64,
    seq_shard_activations=False,
    vocab_size=512,
    d_model=64,
    d_ff=128,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
)
