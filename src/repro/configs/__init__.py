"""Assigned architecture configs (exact public-literature dimensions) and
reduced smoke variants.

Usage: ``repro.configs.get("qwen3-8b")`` / ``get_smoke("qwen3-8b")`` /
``--arch qwen3-8b`` on every launcher CLI.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

_MODULES = [
    "whisper_tiny", "pixtral_12b", "qwen3_8b", "yi_9b", "yi_34b",
    "minitron_8b", "qwen3_moe_30b_a3b", "grok_1_314b", "mamba2_1_3b",
    "zamba2_7b",
]

CONFIGS: Dict[str, ModelConfig] = {}
SMOKE_CONFIGS: Dict[str, ModelConfig] = {}

for _m in _MODULES:
    mod = importlib.import_module(f"repro.configs.{_m}")
    CONFIGS[mod.CONFIG.name] = mod.CONFIG
    SMOKE_CONFIGS[mod.CONFIG.name] = mod.SMOKE


def names() -> List[str]:
    return list(CONFIGS)


def get(name: str) -> ModelConfig:
    return CONFIGS[name]


def get_smoke(name: str) -> ModelConfig:
    return SMOKE_CONFIGS[name]
