"""whisper-tiny — assigned architecture config.

# [audio] enc-dec backbone, conv frontend STUBBED (precomputed frame
# embeddings) [arXiv:2212.04356; unverified]
"""
from repro.models.config import ModelConfig
import dataclasses

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    n_enc_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    rope_theta=0.0,
    frontend='audio',
    tie_embeddings=True,
    pure_dp=True,
    seq_shard_activations=False,
)

# Reduced same-family smoke config: tiny widths/depths, one CPU train step.
SMOKE = dataclasses.replace(
    CONFIG,
    param_dtype='float32',
    remat='none',
    attn_chunk=64,
    seq_shard_activations=False,
    vocab_size=512,
    d_model=64,
    d_ff=128,
    n_layers=2,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    n_enc_layers=2,
)
