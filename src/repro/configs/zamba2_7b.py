"""zamba2-7b — assigned architecture config.

# [hybrid] Mamba2 backbone + shared attention block every 6 layers
# [arXiv:2411.15242; unverified]
"""
from repro.models.config import ModelConfig
import dataclasses

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_head=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    attn_every=6,
)

# Reduced same-family smoke config: tiny widths/depths, one CPU train step.
SMOKE = dataclasses.replace(
    CONFIG,
    param_dtype='float32',
    remat='none',
    attn_chunk=64,
    seq_shard_activations=False,
    vocab_size=512,
    d_model=64,
    d_ff=128,
    n_layers=5,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    ssm_state=16,
    ssm_chunk=16,
    attn_every=2,
)
