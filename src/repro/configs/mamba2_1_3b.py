"""mamba2-1.3b — assigned architecture config.

# [ssm] SSD (state-space duality), attn-free [arXiv:2405.21060; unverified]
"""
from repro.models.config import ModelConfig
import dataclasses

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    rope_theta=0.0,
)

# Reduced same-family smoke config: tiny widths/depths, one CPU train step.
SMOKE = dataclasses.replace(
    CONFIG,
    param_dtype='float32',
    remat='none',
    attn_chunk=64,
    seq_shard_activations=False,
    vocab_size=512,
    d_model=64,
    d_ff=0,
    n_layers=2,
    ssm_state=16,
    ssm_chunk=16,
)
