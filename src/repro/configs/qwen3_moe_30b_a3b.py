"""qwen3-moe-30b-a3b — assigned architecture config.

# [moe] 128 experts top-8, expert d_ff=768 [hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.models.config import ModelConfig
import dataclasses

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_head=128,
    d_ff=768,
    vocab_size=151936,
    n_experts=128,
    top_k=8,
    qk_norm=True,
)

# Reduced same-family smoke config: tiny widths/depths, one CPU train step.
SMOKE = dataclasses.replace(
    CONFIG,
    param_dtype='float32',
    remat='none',
    attn_chunk=64,
    seq_shard_activations=False,
    vocab_size=512,
    d_model=64,
    d_ff=64,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    n_experts=8,
    top_k=2,
)
