"""yi-9b — assigned architecture config.

# [dense] llama-arch GQA [arXiv:2403.04652; hf]
"""
from repro.models.config import ModelConfig
import dataclasses

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_head=128,
    d_ff=11008,
    vocab_size=64000,
)

# Reduced same-family smoke config: tiny widths/depths, one CPU train step.
SMOKE = dataclasses.replace(
    CONFIG,
    param_dtype='float32',
    remat='none',
    attn_chunk=64,
    seq_shard_activations=False,
    vocab_size=512,
    d_model=64,
    d_ff=128,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
)
