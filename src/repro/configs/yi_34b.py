"""yi-34b — assigned architecture config.

# [dense] llama-arch GQA; 56 q-heads pad to 64 on a 16-way model axis
# (DESIGN.md 4) [arXiv:2403.04652; hf]
"""
from repro.models.config import ModelConfig
import dataclasses

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=20480,
    vocab_size=64000,
)

# Reduced same-family smoke config: tiny widths/depths, one CPU train step.
SMOKE = dataclasses.replace(
    CONFIG,
    param_dtype='float32',
    remat='none',
    attn_chunk=64,
    seq_shard_activations=False,
    vocab_size=512,
    d_model=64,
    d_ff=128,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
)
