"""minitron-8b — assigned architecture config.

# [dense] pruned nemotron, 256k vocab [arXiv:2407.14679; hf]
"""
from repro.models.config import ModelConfig
import dataclasses

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    vocab_size=256000,
)

# Reduced same-family smoke config: tiny widths/depths, one CPU train step.
SMOKE = dataclasses.replace(
    CONFIG,
    param_dtype='float32',
    remat='none',
    attn_chunk=64,
    seq_shard_activations=False,
    vocab_size=512,
    d_model=64,
    d_ff=128,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
)
