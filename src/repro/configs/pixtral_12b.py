"""pixtral-12b — assigned architecture config.

# [vlm] pixtral-ViT frontend STUBBED (precomputed patch embeddings);
# mistral-nemo decoder backbone [hf:mistralai/Pixtral-12B-2409; unverified]
"""
from repro.models.config import ModelConfig
import dataclasses

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=131072,
    frontend='patch',
)

# Reduced same-family smoke config: tiny widths/depths, one CPU train step.
SMOKE = dataclasses.replace(
    CONFIG,
    param_dtype='float32',
    remat='none',
    attn_chunk=64,
    seq_shard_activations=False,
    vocab_size=512,
    d_model=64,
    d_ff=128,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
)
