"""Flash decode — single-token attention against a long KV cache.

Decode is the memory-roofline case (the whole KV cache streams through
VMEM once per token), so the CBP knobs bind differently than in prefill:
``block_kv`` controls the streaming granularity (prefetch depth ~ one
block in flight), and the valid-length mask means blocks entirely past
``cur_len`` are skipped — the kernel never spends HBM bandwidth on the
unwritten tail of the ring buffer.

Grid: (B*H, n_kv_blocks), kv innermost, online-softmax scratch carries
(m, l, acc).  ``cur_len`` arrives via scalar prefetch (SMEM) so the skip
predicate is known before the block's DMA is issued.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, block_kv: int, scale: float):
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    cur_len = len_ref[0]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(j * block_kv < cur_len)
    def _body():
        q = q_ref[0].astype(jnp.float32)             # (1, d)
        k = k_ref[0].astype(jnp.float32)             # (bkv, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (1, bkv)
        pos = j * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_kv), 1)
        s = jnp.where(pos < cur_len, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == nj - 1)
    def _done():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_decode(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                 cur_len, *, block_kv: int = 512,
                 interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, Dh); caches: (B, H, Smax, Dh); cur_len: () int32."""
    b, h, dh = q.shape
    smax = k_cache.shape[2]
    assert smax % block_kv == 0
    bh = b * h
    qr = q.reshape(bh, 1, dh)
    kr = k_cache.reshape(bh, smax, dh)
    vr = v_cache.reshape(bh, smax, dh)
    lens = jnp.full((1,), cur_len, jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bh, smax // block_kv),
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda g, j, lens: (g, 0, 0)),
            pl.BlockSpec((1, block_kv, dh), lambda g, j, lens: (g, j, 0)),
            pl.BlockSpec((1, block_kv, dh), lambda g, j, lens: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, dh), lambda g, j, lens: (g, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_kv=block_kv,
                          scale=dh ** -0.5),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, 1, dh), q.dtype),
        interpret=interpret,
    )(lens, qr, kr, vr)
    return out.reshape(b, h, dh)
