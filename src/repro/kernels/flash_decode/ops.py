"""Jitted wrapper for flash decode."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_decode.kernel import flash_decode as _kernel
from repro.kernels.flash_decode.ref import decode_ref


@functools.partial(jax.jit, static_argnames=("block_kv",))
def flash_decode(q, k_cache, v_cache, cur_len, *, block_kv: int = 512):
    return _kernel(q, k_cache, v_cache, cur_len, block_kv=block_kv,
                   interpret=jax.default_backend() != "tpu")


__all__ = ["flash_decode", "decode_ref"]
