"""Pure-jnp oracle for single-token decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_ref(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
               cur_len: int) -> jnp.ndarray:
    """q: (B, H, Dh); caches: (B, H, Smax, Dh); attend to [0, cur_len)."""
    dh = q.shape[-1]
    s = jnp.einsum("bhd,bhsd->bhs", q, k_cache).astype(jnp.float32)
    s = s * (dh ** -0.5)
    smax = k_cache.shape[2]
    mask = jnp.arange(smax)[None, None, :] < cur_len
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p.astype(v_cache.dtype), v_cache)
