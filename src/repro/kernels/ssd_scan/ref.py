"""Pure-jnp oracle for the Mamba2 SSD chunk scan: the *sequential*
recurrence, materialized step by step (the ground truth the chunked matmul
forms must match)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
            Bm: jnp.ndarray, Cm: jnp.ndarray) -> jnp.ndarray:
    """Sequential SSD.  x: (B, S, H, P); dt: (B, S, H); A: (H,) negative;
    Bm/Cm: (B, S, N).  Returns y: (B, S, H, P)."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    def step(state, inputs):
        xt, dtt, bt, ct = inputs      # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt * A[None, :])            # (B,H)
        state = (decay[:, :, None, None] * state
                 + jnp.einsum("bhp,bn,bh->bhpn", xt, bt, dtt))
        y = jnp.einsum("bn,bhpn->bhp", ct, state)
        return state, y

    state0 = jnp.zeros((b, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(
        step, state0,
        (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
         Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)
