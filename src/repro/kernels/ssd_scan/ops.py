"""Jitted wrapper for the SSD chunk-scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan as _kernel
from repro.kernels.ssd_scan.ref import ssd_ref


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128):
    return _kernel(x, dt, A, Bm, Cm, chunk=chunk,
                   interpret=jax.default_backend() != "tpu")


__all__ = ["ssd_scan", "ssd_ref"]
