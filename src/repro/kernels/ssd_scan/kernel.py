"""Mamba2 SSD chunk scan — Pallas TPU kernel.

The SSD duality turns the sequential state-space recurrence into per-chunk
batched matmuls (MXU work) plus a tiny sequential inter-chunk state update.
The grid runs (B*H, n_chunks) with the chunk axis innermost; the carried
state (P x N) lives in VMEM scratch across grid steps — this exploits the
TPU's sequential grid execution exactly like flash attention's online
softmax carry.

CBP knobs: the chunk length is the cache/VMEM knob (bigger chunk = more
VMEM for the (cl x cl) decay matrix but fewer sequential steps); the
streamed x/B/C blocks double-buffer (prefetch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, state_scr,
                *, chunk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (cl, P)
    dt = dt_ref[0].astype(jnp.float32)        # (1, cl) -> (cl,)
    dt = dt.reshape(chunk)
    a = a_ref[0, 0]                           # scalar A_h (negative)
    bm = b_ref[0].astype(jnp.float32)         # (cl, N)
    cm = c_ref[0].astype(jnp.float32)         # (cl, N)

    dA = dt * a                               # (cl,)
    cs = jnp.cumsum(dA)                       # inclusive
    xdt = x * dt[:, None]

    # Intra-chunk: M[i, j] = (C_i . B_j) * exp(cs_i - cs_j) for j <= i
    G = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)   # (cl, cl)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(cs[:, None] - cs[None, :])
    M = jnp.where(jj <= ii, G * decay, 0.0)
    y = jax.lax.dot_general(
        M, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # (cl, P)

    # Inter-chunk: carried state contribution + state update
    state = state_scr[...]                    # (P, N)
    sdec = jnp.exp(cs)                        # (cl,)
    y_inter = jax.lax.dot_general(
        cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)   # (cl, P)
    y = y + y_inter * sdec[:, None]

    edec = jnp.exp(cs[-1] - cs)               # decay j..chunk end
    contrib = jax.lax.dot_general(
        xdt, bm * edec[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # (P, N)
    state_scr[...] = jnp.exp(cs[-1]) * state + contrib

    o_ref[0] = y.astype(o_ref.dtype)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             Bm: jnp.ndarray, Cm: jnp.ndarray, *, chunk: int = 128,
             interpret: bool = False) -> jnp.ndarray:
    """Chunked SSD.  x: (B, S, H, P); dt: (B, S, H); A: (H,);
    Bm/Cm: (B, S, N) -> y: (B, S, H, P)."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    bh = b * h
    # (B*H, S, P); dt -> (B*H, S); B/C shared across heads: (B, S, N)
    xr = x.transpose(0, 2, 1, 3).reshape(bh, s, p)
    dtr = dt.transpose(0, 2, 1).reshape(bh, 1, s)
    ar = jnp.broadcast_to(A[None, :], (b, h)).reshape(bh, 1)

    grid = (bh, nc)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda g, j: (g, j, 0)),
            pl.BlockSpec((1, 1, chunk), lambda g, j: (g, 0, j)),
            pl.BlockSpec((1, 1), lambda g, j: (g, 0)),
            # B/C are head-shared: index the batch row b = g // h.
            pl.BlockSpec((1, chunk, n), lambda g, j: (g // h, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda g, j: (g // h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda g, j: (g, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, ar, Bm, Cm)
    return out.reshape(b, h, s, p).transpose(0, 2, 1, 3)
