"""UCP Lookahead greedy — Pallas kernel over a batch of utility curves.

One grid step per batch row: the row's ``(n, U+1)`` utility curve loads
into VMEM ONCE and the whole greedy while-loop runs against that resident
tile, instead of the batched ``lax.while_loop`` path re-streaming the full
``(B, n, U+1)`` grid from HBM on every trip
(:func:`repro.core.cache_controller_jax._greedy_loop` — the dominant term
of a stacked sweep's boundary refresh after PR 5).

Inside the kernel each trip recomputes every client's best ``(mu, k)``
step from the resident curve — a ``(n, U)`` masked argmax, exactly the
reference recurrence — then takes one greedy step.  Because each trip
either allocates >= 1 unit or retires the row, the trip bound is just
``U + 1`` (the batched path needs ``(n + 2) * U`` because it refreshes one
stale client per trip).  Tie-breaks are the repo-wide contract: ``argmax``
picks the first max, so the smallest step wins within a client and the
lowest client index wins across clients.

The zero-utility spread (a stable argsort, which Mosaic has no primitive
for) deliberately stays OUTSIDE the kernel: the kernel returns the greedy
allocation plus the undistributed balance, and the caller applies
:func:`repro.core.cache_controller_jax._zero_spread` — the same
greedy/spread split as ``ref.py``.

Validated in interpret mode off-TPU (``tests/test_lookahead_kernel.py``
pins it bit-identical to the numpy golden, incl. the masked CPpf variant).
Real-TPU lowering caveats, documented rather than hidden: the curves are
float64 (the bit-parity contract with the numpy golden is written in f64)
and the per-client gain gather (``take_along_axis`` on the resident tile)
would need a one-hot contraction on Mosaic; both are fine in interpret
mode, which is the contract this repo tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lookahead_kernel(min_ref, rem_ref, curves_ref, active_ref,
                      alloc_ref, bal_ref, *, n: int, total_units: int):
    U = total_units
    curve = curves_ref[0]                              # (n, U+1) resident
    act_col = (active_ref[...] != 0).reshape(n, 1)
    min_u = min_ref[0, 0]
    rem = rem_ref[0, 0]

    ks = jax.lax.broadcasted_iota(jnp.int32, (n, U), 1) + 1
    ksf = ks.astype(curve.dtype)
    neg_inf = jnp.array(-jnp.inf, curve.dtype)
    iota_col = jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0)

    def cond(state):
        _alloc, balance, stuck, it = state
        # Each trip allocates >= 1 unit or sets stuck -> <= U + 1 trips.
        return (it <= U) & (balance > 0) & ~stuck

    def body(state):
        alloc, balance, stuck, it = state              # alloc (n, 1) int32
        cap = jnp.minimum(balance, rem - alloc)
        cap = jnp.where(act_col, cap, 0)               # (n, 1)
        # Full best-step recompute against the VMEM-resident curve.
        idx = jnp.minimum(alloc + ks, U)               # (n, U)
        base = jnp.take_along_axis(curve, alloc, axis=1)
        gain = jnp.take_along_axis(curve, idx, axis=1) - base
        mus = jnp.where(ks <= cap, gain / ksf, neg_inf)
        k_best = jnp.argmax(mus, axis=1).astype(jnp.int32)[:, None] + 1
        mu_best = jnp.max(mus, axis=1)[:, None]        # (n, 1)
        # First max across clients -> lowest index wins ties.
        i_best = jnp.argmax(mu_best[:, 0]).astype(jnp.int32)
        mu_sel = jnp.max(mu_best)
        do_step = mu_sel > 0.0
        at_i = (iota_col == i_best) & do_step
        k_sel = jnp.sum(jnp.where(at_i, k_best, 0), dtype=jnp.int32)
        alloc = alloc + jnp.where(at_i, k_best, 0)
        balance = balance - k_sel
        stuck = ~do_step
        return alloc, balance, stuck, it + 1

    alloc0 = jnp.full((n, 1), min_u, dtype=jnp.int32)
    balance0 = jnp.int32(U) - jnp.int32(n) * min_u
    alloc, balance, _stuck, _it = jax.lax.while_loop(
        cond, body, (alloc0, balance0, jnp.bool_(False), jnp.int32(0)))
    alloc_ref[...] = alloc.reshape(1, n)
    bal_ref[0, 0] = balance


def lookahead_greedy_rows(
    curves: jnp.ndarray,     # (B, n, U + 1) float64
    min_units: jnp.ndarray,  # (B,) int — per-row floor
    active: jnp.ndarray,     # (B, n) bool — CPpf competing mask
    remaining: jnp.ndarray,  # (B,) int — top usable curve column
    *,
    total_units: int,
    interpret: bool = False,
) -> tuple:
    """Run the greedy kernel over a batch: one grid step per row.

    Returns ``(alloc, balance)`` — ``(B, n)`` int32 allocations (floors
    applied, greedy distributed) and the ``(B,)`` int32 undistributed
    balance for the caller's zero-utility spread.
    """
    B, n, U1 = curves.shape
    if U1 != total_units + 1:
        raise ValueError(f"curves must have {total_units + 1} columns")
    min2 = min_units.astype(jnp.int32).reshape(B, 1)
    rem2 = remaining.astype(jnp.int32).reshape(B, 1)
    act32 = active.astype(jnp.int32)

    kernel = functools.partial(_lookahead_kernel, n=n,
                               total_units=total_units)
    alloc, balance = pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            # Per-row scalars live in SMEM (scalars are 2-D on TPU).
            pl.BlockSpec((1, 1), lambda b: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda b: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n, U1), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, n), lambda b: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n), lambda b: (b, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        interpret=interpret,
    )(min2, rem2, curves, act32)
    return alloc, balance[:, 0]
