"""Jitted public wrapper for the Lookahead greedy kernel.

Selects interpret mode automatically off-TPU, mirroring the
flash_attention ops layer: the container validates the kernel body on CPU
(where the f64 bit-parity contract with the numpy golden is enforced);
real deployments lower it to Mosaic.

The wrapper returns the *greedy* result — ``(alloc, balance)`` — and the
dispatcher in :mod:`repro.core.cache_controller_jax` applies the shared
zero-utility spread, so ``backend="pallas"`` and ``backend="jax"`` differ
only in how the while-loop itself executes.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.lookahead_greedy.kernel import lookahead_greedy_rows
from repro.kernels.lookahead_greedy.ref import (
    lookahead_masked_ref,
    lookahead_ref,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("total_units",))
def lookahead_greedy(curves, min_units, active, remaining, *,
                     total_units: int):
    """(B, n, U+1) curves -> ((B, n) greedy alloc, (B,) leftover balance)."""
    return lookahead_greedy_rows(
        curves, min_units, active, remaining,
        total_units=total_units, interpret=not _on_tpu())


__all__ = ["lookahead_greedy", "lookahead_ref", "lookahead_masked_ref"]
