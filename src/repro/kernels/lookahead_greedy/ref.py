"""Numpy oracle for the Lookahead greedy Pallas kernel.

Independent re-derivation of UCP Lookahead (Qureshi & Patt, MICRO 2006 —
paper §3.2.1) used to validate ``kernel.py``.  It is *pinned bit-identical*
to the repo's golden reference
(:func:`repro.core.cache_controller.lookahead_allocate`, incl. the masked
CPpf variant :func:`repro.core.cache_controller.cppf_allocate`) by
``tests/test_lookahead_kernel.py`` — same deterministic tie-breaks:

* among clients with equal best marginal utility, the lowest index wins;
* within a client, the smallest step ``k`` achieving the best mu wins;
* the zero-utility spread orders clients by remaining potential gain with a
  stable sort.

Unlike the golden it mirrors the *kernel's* decomposition: the greedy loop
stops at the first non-positive best mu and returns the leftover balance,
and the spread runs as a separate step — the same split the Pallas kernel
and :func:`repro.core.cache_controller_jax._zero_spread` use.
"""
from __future__ import annotations

import numpy as np


def greedy_ref(
    curves: np.ndarray,
    min_units: int,
    active: np.ndarray,
    remaining: int,
    total_units: int,
) -> tuple:
    """The bounded greedy alone: (n, U+1) curve -> ((n,) alloc, balance).

    ``remaining`` is the top usable curve column (``total_units`` for the
    plain variant; the post-pinning capacity for the CPpf variant) — the
    step cap for client ``i`` is ``min(balance, remaining - alloc[i])``.
    Stops when no active client has a positive marginal utility and
    returns the undistributed balance for the spread step.
    """
    curves = np.asarray(curves, dtype=np.float64)
    active = np.asarray(active, dtype=bool)
    n = curves.shape[0]
    alloc = np.full(n, min_units, dtype=np.int64)
    balance = total_units - n * min_units
    while balance > 0:
        best_mu, best_i, best_k = -np.inf, -1, 0
        for i in range(n):
            cap = min(balance, remaining - int(alloc[i]))
            if not active[i] or cap <= 0:
                continue
            ks = np.arange(1, cap + 1)
            mus = (curves[i, alloc[i] + 1: alloc[i] + cap + 1]
                   - curves[i, alloc[i]]) / ks
            b = int(np.argmax(mus))          # first max -> smallest k
            if mus[b] > best_mu:             # strict -> lowest index wins
                best_mu, best_i, best_k = float(mus[b]), i, b + 1
        if best_i < 0 or best_mu <= 0.0:
            break
        alloc[best_i] += best_k
        balance -= best_k
    return alloc, int(balance)


def spread_ref(
    curves: np.ndarray,
    alloc: np.ndarray,
    balance: int,
    active: np.ndarray,
    remaining: int,
) -> np.ndarray:
    """The zero-utility even-spread: distribute ``balance`` by remaining
    potential gain (``curve[remaining] - curve[alloc]``), stable order."""
    alloc = np.array(alloc, dtype=np.int64)
    if balance <= 0:
        return alloc
    active = np.asarray(active, dtype=bool)
    n = len(alloc)
    gain = curves[np.arange(n), np.full(n, remaining)] \
        - curves[np.arange(n), alloc]
    key = np.where(active, -gain, np.inf)
    order = np.argsort(key, kind="stable")
    rank = np.argsort(order, kind="stable")
    n_act = max(int(active.sum()), 1)
    share = balance // n_act + (rank < balance % n_act)
    return np.where(active, alloc + share, alloc)


def lookahead_ref(
    curves: np.ndarray,
    total_units: int,
    min_units: int = 4,
) -> np.ndarray:
    """Plain Lookahead oracle: greedy + spread over all-active clients."""
    n = np.asarray(curves).shape[0]
    active = np.ones(n, dtype=bool)
    alloc, balance = greedy_ref(
        curves, min_units, active, total_units, total_units)
    return spread_ref(curves, alloc, balance, active, total_units)


def lookahead_masked_ref(
    curves: np.ndarray,
    total_units: int,
    min_units: int,
    active: np.ndarray,
) -> np.ndarray:
    """CPpf oracle: inactive clients pinned at the floor, greedy over the
    active subset with the capacity left after pinning; all-inactive mixes
    split evenly with the remainder to the lowest indices."""
    curves = np.asarray(curves, dtype=np.float64)
    active = np.asarray(active, dtype=bool)
    n = curves.shape[0]
    if not active.any():
        extra = total_units - n * min_units
        out = np.full(n, min_units, dtype=np.int64) + extra // n
        out[: extra % n] += 1
        return out
    remaining = total_units - min_units * int((~active).sum())
    alloc, balance = greedy_ref(
        curves, min_units, active, remaining, total_units)
    return spread_ref(curves, alloc, balance, active, remaining)
