"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True) -> jnp.ndarray:
    """q/k/v: (B, H, S, Dh) -> (B, H, S, Dh).  f32 softmax statistics."""
    _, _, sq, dh = q.shape
    sk = k.shape[2]
    scores = jnp.einsum(
        "bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * (dh ** -0.5)
    if causal:
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum(
        "bhqk,bhkd->bhqd", probs.astype(v.dtype), v)
