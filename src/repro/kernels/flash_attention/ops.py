"""Jitted public wrapper for the flash-attention kernel.

Selects interpret mode automatically off-TPU (the container validates the
kernel body on CPU; real deployments lower it to Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q",
                                             "block_kv"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_kv: int = 128):
    """(B, H, S, Dh) attention; CBP-tunable VMEM blocks."""
    return flash_attention_fwd(
        q, k, v, causal=causal, block_q=block_q, block_kv=block_kv,
        interpret=not _on_tpu())


__all__ = ["flash_attention", "attention_ref"]
