"""Flash attention forward — Pallas TPU kernel with CBP-tunable VMEM knobs.

This is where the paper's three knobs re-materialize at the VMEM level
(DESIGN.md §2, hardware adaptation):

  * cache partitioning  -> (block_q, block_kv): how the VMEM budget is split
    between the resident Q/accumulator tiles and the streamed K/V tiles;
  * prefetch throttling -> the TPU pipeline double-buffers the streamed K/V
    blocks; a larger block_kv = deeper effective prefetch per grid step
    (more VMEM for in-flight tiles), a smaller one throttles it;
  * bandwidth           -> the grid iteration order (q-major) keeps K/V
    streaming sequential in HBM, and the causal schedule skips fully-masked
    K/V blocks so no HBM bandwidth is spent on them.

``repro.runtime.cbp_runtime.KernelKnobs`` drives (block_q, block_kv) from
the CBP cache controller's VMEM budget split.

Grid: (B*H, n_q_blocks, n_kv_blocks); the kv axis is innermost (sequential
on TPU) and carries the running max/sum/acc in VMEM scratch (standard
online-softmax flash schedule).  Causal skipping uses `pl.when` so masked
blocks cost neither MXU time nor (on TPU) the HBM fetch of the block.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref,
                      m_scr, l_scr, acc_scr,
                      *, scale: float, causal: bool,
                      block_q: int, block_kv: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Causal: skip blocks strictly above the diagonal.
    run = True
    if causal:
        run = (kj * block_kv) <= (qi * block_q + block_q - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0].astype(jnp.float32)            # (bkv, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bkv)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0)
            kpos = kj * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]                         # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _done():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    *, causal: bool = True, block_q: int = 128, block_kv: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """q/k/v: (B, H, S, Dh) -> (B, H, S, Dh).

    block_q/block_kv are the CBP VMEM-partitioning knobs: VMEM use is
    roughly  block_q*(Dh + block_kv + 3) + 2*block_kv*Dh  f32 words
    (x2 for the pipeline's double buffering of the streamed operands).
    """
    b, h, sq, dh = q.shape
    sk = k.shape[2]
    assert sq % block_q == 0 and sk % block_kv == 0, (sq, sk, block_q,
                                                      block_kv)
    bh = b * h
    qr = q.reshape(bh, sq, dh)
    kr = k.reshape(bh, sk, dh)
    vr = v.reshape(bh, sk, dh)
    grid = (bh, sq // block_q, sk // block_kv)

    kernel = functools.partial(
        _flash_fwd_kernel, scale=dh ** -0.5, causal=causal,
        block_q=block_q, block_kv=block_kv)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_kv, dh), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, block_kv, dh), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, dh)
