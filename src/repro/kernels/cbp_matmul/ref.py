"""Oracle for the blocked matmul kernel."""
import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(
        a.dtype)
