"""Jitted wrapper for the CBP blocked matmul."""
from __future__ import annotations

import functools

import jax

from repro.kernels.cbp_matmul.kernel import cbp_matmul as _kernel
from repro.kernels.cbp_matmul.kernel import vmem_footprint_bytes
from repro.kernels.cbp_matmul.ref import matmul_ref


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_n", "block_k"))
def cbp_matmul(a, b, *, block_m: int = 128, block_n: int = 128,
               block_k: int = 128):
    return _kernel(a, b, block_m=block_m, block_n=block_n, block_k=block_k,
                   interpret=jax.default_backend() != "tpu")


__all__ = ["cbp_matmul", "matmul_ref", "vmem_footprint_bytes"]
