"""CBP-managed blocked matmul — the kernel-level demonstrator of the
paper's three knobs on one op (DESIGN.md §2):

  * cache partitioning: (block_m, block_n, block_k) split the VMEM budget
    between the A tile, B tile and accumulator — the exact analogue of
    LLC way allocation.  ``repro.runtime.cbp_runtime.plan_matmul_blocks``
    runs the UCP Lookahead allocator over tile-utility curves to pick them.
  * prefetch throttling: TPU pipelines double-buffer streamed inputs;
    block_k sets how much VMEM the in-flight K-panels occupy (deep
    prefetch = large block_k); throttling = shrinking it.
  * bandwidth: the (m-major, n, k-inner) grid order streams B panels
    sequentially and reuses the A tile across n — HBM traffic per output
    tile is the allocation-dependent quantity CBP trades against VMEM.

Grid (m, n, k), k innermost with an f32 VMEM accumulator carried across
k steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, o_ref, acc_scr):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def cbp_matmul(a: jnp.ndarray, b: jnp.ndarray, *, block_m: int = 128,
               block_n: int = 128, block_k: int = 128,
               interpret: bool = False) -> jnp.ndarray:
    """(M, K) @ (K, N) with explicit VMEM tiling.

    Dims need not divide the blocks: ``plan_matmul_blocks`` is pad-aware
    (a prime/odd dim gets a block tiling ``ceil(dim / block) * block``),
    so operands zero-pad up to the block multiple here — exact for a
    matmul — and the result slices back to ``(M, N)``.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    pad_m, pad_n, pad_k = -m % block_m, -n % block_n, -k % block_k
    if pad_m or pad_n or pad_k:
        a = jnp.pad(a, ((0, pad_m), (0, pad_k)))
        b = jnp.pad(b, ((0, pad_k), (0, pad_n)))
    mp, np_, kp = m + pad_m, n + pad_n, k + pad_k
    grid = (mp // block_m, np_ // block_n, kp // block_k)
    out = pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:m, :n] if (pad_m or pad_n) else out


def vmem_footprint_bytes(block_m: int, block_n: int, block_k: int,
                         dtype_bytes: int = 2) -> int:
    """VMEM bytes the tiling claims (x2 on streamed tiles for the
    pipeline's double buffering) — the quantity CBP partitions."""
    a_tile = 2 * block_m * block_k * dtype_bytes
    b_tile = 2 * block_k * block_n * dtype_bytes
    acc = block_m * block_n * 4
    out = block_m * block_n * dtype_bytes
    return a_tile + b_tile + acc + out
