"""SPEC-CPU2006-like application profiles (paper §2, §4).

The paper evaluates on the 29-application SPEC CPU2006 suite under Sniper.
We cannot ship SPEC, so each application is modelled by a compact profile
that drives the interval performance model in :mod:`repro.sim.memsys`:

* a miss-ratio curve  ``mpki(u) = floor + (peak - floor) * exp(-(u-4)/ws)``
  over cache allocation ``u`` in 32 kB units (4 units = the 128 kB minimum,
  matching the paper's C-L point; 16 = the 512 kB baseline; 64 = 2 MB C-H),
* memory intensity (LLC accesses/misses per kilo-instruction, writeback
  fraction, memory-level parallelism),
* a prefetcher response (coverage, accuracy, latency-hiding fraction, and
  cache pollution in units — pollution models the paper's prefetch-averse
  applications such as xalancbmk).

The parameters are *calibrated*, not measured: they are tuned so that the
paper's published characterization reproduces — the Fig. 2 sensitivity
classification counts (6 CS-BS-PS / 8 CS-BS / 6 BS-PS / 3 CS / 3 BS / 3 I),
the named per-application behaviours (lbm bandwidth/prefetch-bound,
xalancbmk cache-bound and prefetch-averse, leslie3d sensitive to all three
with the Fig. 4 trade-offs, hmmer prefetch-sensitive only at low allocation,
gcc prefetch-sensitive at high allocation), and the headline Fig. 9/10
manager orderings.  See ``tests/test_sim_characterization.py`` and
EXPERIMENTS.md §Repro for the validation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

# Allocation quanta: one unit = 32 kB (the paper's enforcement granularity).
UNIT_KB = 32
TOTAL_UNITS_8MB = 256          # 16 tiles x 512 kB
BASELINE_UNITS = 16            # 512 kB
MIN_UNITS = 4                  # 128 kB = paper's min_ways floor
TOTAL_BW_GBPS = 64.0           # 4 MCUs x 16 GB/s (paper Table 1)
BASELINE_BW_GBPS = 4.0         # per-app baseline (paper §2.1)


@dataclasses.dataclass(frozen=True)
class AppProfile:
    name: str
    abbrev: str
    cpi_base: float      # core-bound CPI (no LLC-miss stalls)
    apki: float          # LLC accesses per kilo-instruction
    mpki_min_alloc: float  # MPKI at 4 units (128 kB)
    mpki_floor: float    # asymptotic MPKI with unbounded cache
    ws_units: float      # miss-curve decay constant (32 kB units)
    mlp: float           # memory-level parallelism (penalty divisor)
    wb_frac: float       # writeback traffic as fraction of misses
    pf_cov: float        # prefetch coverage (fraction of misses prefetched)
    pf_acc: float        # prefetch accuracy (useful / issued)
    pf_hide: float       # latency fraction hidden for covered misses
    pf_pollution: float  # effective cache units lost to useless prefetches


# name, abbr, cpi,  apki, mpk4, mpkF,  ws,  mlp,  wb,  cov,  acc, hide, pol
_TABLE = [
    # --- CS-BS-PS (6): sensitive to all three -------------------------------
    ("mcf",        "mc",  0.90, 65.0, 48.0, 10.0,  60.0, 4.0, 0.30, 0.48, 0.75, 0.85, 1.0),
    ("leslie3d",   "le",  0.70, 28.0, 16.0,  2.5,  40.0, 3.5, 0.40, 0.55, 0.75, 0.85, 1.0),
    ("soplex",     "so",  0.80, 30.0, 20.0,  4.0,  45.0, 3.5, 0.30, 0.35, 0.70, 0.80, 1.0),
    ("sphinx3",    "sp",  0.70, 25.0, 14.0,  1.5,  35.0, 3.0, 0.20, 0.45, 0.75, 0.85, 1.0),
    ("gcc",        "gc",  0.80, 22.0, 13.0,  1.0,  80.0, 3.0, 0.40, 0.50, 0.60, 0.80, 3.0),
    ("dealII",     "de",  0.60, 18.0, 11.0,  1.2,  30.0, 2.5, 0.20, 0.40, 0.70, 0.80, 1.0),
    # --- CS-BS (8): cache + bandwidth ---------------------------------------
    ("xalancbmk",  "xa",  0.70, 24.0, 18.0,  1.5,  35.0, 1.7, 0.20, 0.25, 0.25, 0.50, 6.0),
    ("omnetpp",    "om",  0.80, 26.0, 17.0,  2.5,  50.0, 2.5, 0.30, 0.15, 0.40, 0.50, 2.0),
    ("bzip2",      "bz",  0.70, 14.0,  9.0,  1.5,  30.0, 1.5, 0.40, 0.20, 0.50, 0.60, 1.0),
    ("gobmk",      "go",  0.70, 10.0,  6.5,  0.8,  25.0, 1.4, 0.20, 0.10, 0.50, 0.50, 1.0),
    ("perlbench",  "pe",  0.60, 12.0,  8.0,  0.6,  28.0, 1.5, 0.20, 0.15, 0.50, 0.50, 1.0),
    ("calculix",   "ca",  0.55,  9.0,  6.0,  0.5,  26.0, 1.6, 0.20, 0.15, 0.60, 0.60, 1.0),
    ("hmmer",      "hm",  0.50,  8.0,  6.0,  0.3,   9.0, 1.3, 0.35, 0.33, 0.90, 0.50, 0.0),
    ("astar",      "as",  0.80, 16.0, 10.0,  1.8,  38.0, 1.3, 0.20, 0.10, 0.40, 0.50, 1.0),
    # --- BS-PS (6): streaming — flat miss curves, prefetch-friendly ---------
    ("lbm",        "lb",  0.60, 42.0, 40.0, 36.0, 500.0, 6.0, 0.80, 0.70, 0.85, 0.90, 0.0),
    ("libquantum", "li",  0.50, 35.0, 33.0, 30.0, 500.0, 5.0, 0.10, 0.80, 0.90, 0.90, 0.0),
    ("milc",       "mi",  0.60, 30.0, 28.0, 25.0, 400.0, 5.0, 0.50, 0.50, 0.80, 0.85, 0.0),
    ("bwaves",     "bw",  0.55, 32.0, 30.0, 27.0, 400.0, 5.5, 0.40, 0.60, 0.85, 0.90, 0.0),
    ("zeusmp",     "ze",  0.60, 24.0, 22.0, 19.0, 300.0, 4.5, 0.40, 0.50, 0.80, 0.85, 0.0),
    ("GemsFDTD",   "Ge",  0.65, 28.0, 26.0, 22.0, 350.0, 5.0, 0.50, 0.55, 0.92, 0.90, 0.0),
    # --- CS (3): cache only — low traffic -----------------------------------
    ("h264ref",    "h2",  0.50,  6.0,  3.0,  0.3,  12.0, 1.2, 0.10, 0.15, 0.60, 0.60, 0.0),
    ("tonto",      "to",  0.55,  6.0,  3.2,  0.35, 13.0, 1.5, 0.05, 0.10, 0.50, 0.50, 0.0),
    ("gromacs",    "gr",  0.50,  5.5,  2.8,  0.3,  12.0, 1.2, 0.20, 0.10, 0.50, 0.50, 0.0),
    # --- BS (3): bandwidth only — flat curves, prefetch-unfriendly ----------
    ("cactusADM",  "cac", 0.80, 20.0, 18.0, 15.5, 300.0, 4.0, 0.40, 0.20, 0.50, 0.55, 0.0),
    ("wrf",        "wr",  0.70, 16.0, 14.0, 12.0, 250.0, 4.0, 0.30, 0.18, 0.55, 0.60, 0.0),
    ("sjeng",      "sj",  0.70, 12.0, 11.0,  9.5, 250.0, 3.5, 0.20, 0.10, 0.40, 0.50, 0.0),
    # --- I (3): insensitive — compute bound ---------------------------------
    ("povray",     "po",  0.45,  2.0,  0.30, 0.10,  6.0, 2.0, 0.10, 0.10, 0.50, 0.50, 0.0),
    ("gamess",     "ga",  0.40,  1.5,  0.25, 0.08,  6.0, 2.0, 0.10, 0.10, 0.50, 0.50, 0.0),
    ("namd",       "na",  0.50,  2.5,  0.40, 0.12,  7.0, 2.0, 0.15, 0.15, 0.60, 0.60, 0.0),
]

PROFILES: Dict[str, AppProfile] = {
    row[0]: AppProfile(*row) for row in _TABLE
}
ABBREV: Dict[str, str] = {p.abbrev: p.name for p in PROFILES.values()}
APP_NAMES: List[str] = list(PROFILES.keys())

# Expected Fig. 2 classification (paper caption): used as the calibration
# target; tests assert the model reproduces these counts exactly.
EXPECTED_CLASS_COUNTS = {
    "CS-BS-PS": 6, "CS-BS": 8, "BS-PS": 6, "CS": 3, "BS": 3, "I": 3,
}


@dataclasses.dataclass
class AppArrays:
    """Struct-of-arrays view over a list of profiles (model input)."""

    cpi_base: np.ndarray
    apki: np.ndarray
    mpki_min_alloc: np.ndarray
    mpki_floor: np.ndarray
    ws_units: np.ndarray
    mlp: np.ndarray
    wb_frac: np.ndarray
    pf_cov: np.ndarray
    pf_acc: np.ndarray
    pf_hide: np.ndarray
    pf_pollution: np.ndarray
    names: List[str] = dataclasses.field(default_factory=list)

    @property
    def n(self) -> int:
        """Apps per workload (last axis — fields may carry a mix batch)."""
        return int(np.asarray(self.cpi_base).shape[-1])


#: Numeric model-parameter fields, the single source of truth for the
#: numpy and JAX model implementations and the stacking helpers.
MODEL_FIELDS = tuple(
    f.name for f in dataclasses.fields(AppArrays) if f.name != "names")


def stack(apps: Sequence[str]) -> AppArrays:
    """Build model-input arrays for a workload (list of app names)."""
    ps = [PROFILES[a] for a in apps]
    arrays = {
        attr: np.array([getattr(p, attr) for p in ps], dtype=np.float64)
        for attr in MODEL_FIELDS
    }
    return AppArrays(names=[p.name for p in ps], **arrays)


def stack_mixes(mixes: Sequence[Sequence[str]]) -> AppArrays:
    """Struct-of-arrays over a batch of equal-size mixes: fields are (M, n).

    The leading mix axis broadcasts straight through the interval model
    (:mod:`repro.sim.memsys` / :mod:`repro.sim.memsys_jax`), which is how the
    sweep runner evaluates every mix in one device call.
    """
    stacks = [stack(list(m)) for m in mixes]
    sizes = {s.n for s in stacks}
    if len(sizes) != 1:
        raise ValueError(f"mixes must be equal-size, got sizes {sorted(sizes)}")
    arrays = {
        attr: np.stack([getattr(s, attr) for s in stacks])
        for attr in MODEL_FIELDS
    }
    return AppArrays(names=[s.names for s in stacks], **arrays)
