"""The CMP plant: binds the interval model to the CBP coordinator.

:class:`CMPPlant` implements the :class:`repro.core.coordinator.Plant`
protocol — ``run_interval`` evaluates the steady-state model under an
allocation and reports IPC, queuing delays and ATD utility curves.  This is
the substrate on which all Table-3 resource managers execute.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.types import Allocation, IntervalStats, Mode
from repro.sim import apps as apps_mod
from repro.sim import memsys
from repro.sim.apps import AppArrays, stack


@dataclasses.dataclass
class CMPConfig:
    total_cache_units: int = apps_mod.TOTAL_UNITS_8MB
    total_bandwidth: float = apps_mod.TOTAL_BW_GBPS
    llc_extra_cycles: float = 0.0   # added LLC hit latency (bigger tiles)
    backend: str = "numpy"          # "numpy" (golden ref) | "jax" (batched)
    #: Backend for the Lookahead cache allocator.  "auto" follows the model
    #: backend (and resolves to "jax" on the batched sweep plant, keeping
    #: whole sweeps device-resident); "numpy"/"jax" force one side.
    allocator_backend: str = "auto"
    #: How the batched sweep executes the managers' Fig. 8 timelines.
    #: "stacked" batches the whole manager set into ONE jitted device
    #: program (manager knob flags stack along a leading axis, the
    #: (manager, mix) grid shards over devices —
    #: :func:`repro.sim.timeline_jax.run_timelines`); "fused" keeps the
    #: PR 3/4 path of one program per (manager, timeline) (the stacking
    #: parity reference); "segment" keeps the PR 2 host loop of one
    #: device call per segment (the parity/debug path).  "auto" stacks
    #: unless the allocator is forced onto the host
    #: (``allocator_backend="numpy"``), which implies the segment loop —
    #: the fused programs' greedy is traced and cannot honour a host
    #: allocator.
    timeline_backend: str = "auto"


def _resolve_allocator_backend(config: CMPConfig, default: str) -> str:
    backend = config.allocator_backend
    if backend == "auto":
        backend = default
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown allocator backend {backend!r}")
    return backend


def _resolve_timeline_backend(config: CMPConfig,
                              default: str = "stacked") -> str:
    backend = config.timeline_backend
    if backend == "auto":
        backend = default
    if backend not in ("stacked", "fused", "segment"):
        raise ValueError(f"unknown timeline backend {backend!r}")
    return backend


class CMPPlant:
    """16-core tiled CMP interval model (paper Table 1) as a CBP plant.

    ``config.backend`` selects the model implementation: ``"numpy"`` is the
    golden reference; ``"jax"`` dispatches to the jitted
    :mod:`repro.sim.memsys_jax` port (same math, parity-tested to 1e-5 —
    see ``tests/test_sim_sweep.py``).
    """

    def __init__(self, workload: Sequence[str],
                 config: Optional[CMPConfig] = None):
        self.apps: AppArrays = stack(list(workload))
        self.config = config or CMPConfig()
        if self.config.backend not in ("numpy", "jax"):
            raise ValueError(f"unknown backend {self.config.backend!r}")
        self.allocator_backend = _resolve_allocator_backend(
            self.config, default=self.config.backend)
        self.n_clients = self.apps.n
        self.total_cache_units = self.config.total_cache_units
        self.total_bandwidth = self.config.total_bandwidth

    def _memsys(self):
        if self.config.backend == "jax":
            from repro.sim import memsys_jax
            return memsys_jax
        return memsys

    def evaluate(self, alloc: Allocation) -> memsys.SteadyState:
        ss = self._memsys().evaluate(
            self.apps,
            alloc.cache_units.astype(np.float64),
            alloc.bandwidth,
            alloc.prefetch_on,
            cache_partitioned=alloc.cache_mode != Mode.UNPARTITIONED,
            bandwidth_partitioned=alloc.bandwidth_mode != Mode.UNPARTITIONED,
            total_cache_units=float(self.total_cache_units),
            total_bandwidth_gbps=self.total_bandwidth,
            llc_extra_cycles=self.config.llc_extra_cycles,
            bandwidth_banks=alloc.bandwidth_banks,
        )
        if self.config.backend == "jax":
            ss = memsys.SteadyState(**{
                f.name: np.asarray(getattr(ss, f.name))
                for f in dataclasses.fields(memsys.SteadyState)})
        return ss

    def run_interval(self, alloc: Allocation,
                     duration_ms: float) -> IntervalStats:
        ss = self.evaluate(alloc)
        curves = np.asarray(self._memsys().utility_curves(
            self.apps, alloc.prefetch_on, ss.ipc,
            self.total_cache_units, duration_ms=1.0))
        instr = ss.ipc * memsys.FREQ_GHZ * 1e6 * duration_ms
        return IntervalStats(
            ipc=ss.ipc,
            queuing_delay_ns=ss.queuing_delay_ns,
            utility_curves=curves,
            instructions=instr,
        )


def equal_share(n: int, total_units, total_bandwidth):
    """Equal-share per-app allocation — the ONE baseline construction.

    Every baseline in the repo splits capacity this way: ``total_units
    // n`` cache units each (integer floor) and exactly
    ``total_bandwidth / n`` GB/s each.  Shared by the scalar baseline
    (:func:`baseline_ipc`), the batched sweep baseline
    (:func:`repro.sim.sweep.baseline_ipc_batched`) and the Fig. 5 static
    search (:mod:`repro.sim.static_search`,
    ``benchmarks.paper_figs._exhaustive_best``) so the protocols cannot
    drift apart; only the partitioning mode differs per protocol.
    """
    units = np.full(n, int(total_units) // n, dtype=np.int64)
    bw = np.full(n, float(total_bandwidth) / n, dtype=np.float64)
    return units, bw


def baseline_ipc(workload: Sequence[str],
                 config: Optional[CMPConfig] = None) -> np.ndarray:
    """Paper baseline: unpartitioned cache + bandwidth, prefetch disabled."""
    plant = CMPPlant(workload, config)
    n = plant.n_clients
    units, bw = equal_share(n, plant.total_cache_units, plant.total_bandwidth)
    alloc = Allocation(
        cache_units=units,
        bandwidth=bw,
        prefetch_on=np.zeros(n, dtype=bool),
        cache_mode=Mode.UNPARTITIONED,
        bandwidth_mode=Mode.UNPARTITIONED,
    )
    return plant.evaluate(alloc).ipc


def weighted_speedup(ipc_rm: np.ndarray, ipc_base: np.ndarray) -> float:
    """Paper §4.3: (1/N) * sum(IPC_RM / IPC_baseline)."""
    return float(np.mean(ipc_rm / ipc_base))


def antt(ipc_rm: np.ndarray, ipc_base: np.ndarray) -> float:
    """Paper §4.3: average normalized turnaround time (lower is better)."""
    return float(np.mean(ipc_base / ipc_rm))
