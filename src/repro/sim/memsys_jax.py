"""Batched JAX port of the CMP interval model (:mod:`repro.sim.memsys`).

Same math, same constants, same fixed-point iteration as the numpy
reference — but written in pure ``jax.numpy`` so one jitted device call can
evaluate arbitrarily many (workload mix, allocation) pairs at once.  All
array arguments broadcast against shape ``(..., n)``; adding a leading mix
or candidate-allocation axis batches the whole solve, which is what the
Table-3 sweep runner (:mod:`repro.sim.sweep`) builds on.

Contract: for identical inputs, :func:`evaluate` / :func:`utility_curves`
here must match ``memsys.evaluate`` / ``memsys.utility_curves`` to within
1e-5 relative tolerance (enforced by ``tests/test_sim_sweep.py``).  The
solve runs in float64 (via the ``enable_x64`` context) so the parity gap is
dominated by op-ordering, not precision.  The numpy implementation stays
the golden reference — change that first, then mirror here.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Dict, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import record_dispatch
from repro.sim.apps import MODEL_FIELDS, AppArrays
from repro.sim.memsys import (
    BANK_SKEW,
    DAMPING,
    DRAM_LAT_NS,
    FIXED_POINT_ITERS,
    FREQ_GHZ,
    IF_SKEW,
    LINE_BYTES,
    PF_QUEUE_WEIGHT,
    Q_SCALE_NS,
    RHO_MAX,
    SteadyState,
)

try:  # pragma: no cover - present on every supported JAX
    from jax.experimental import enable_x64 as _enable_x64
except ImportError:  # pragma: no cover
    _enable_x64 = None

#: AppArrays fields the model consumes (single source: apps.MODEL_FIELDS).
PARAM_FIELDS = MODEL_FIELDS

Params = Dict[str, jnp.ndarray]


def x64_context():
    """Run the solve in float64 to honour the parity contract."""
    if _enable_x64 is None:
        return contextlib.nullcontext()
    return _enable_x64()


def app_params(apps: Union[AppArrays, Params]) -> Params:
    """Numeric model parameters as a dict-of-arrays pytree, shape (..., n)."""
    if isinstance(apps, AppArrays):
        return {f: np.asarray(getattr(apps, f), dtype=np.float64)
                for f in PARAM_FIELDS}
    return {f: apps[f] for f in PARAM_FIELDS}


def mpki_curve(params: Params, units: jnp.ndarray) -> jnp.ndarray:
    """JAX mirror of :func:`repro.sim.memsys.mpki_curve`."""
    u = jnp.maximum(units, 1.0)
    span = params["mpki_min_alloc"] - params["mpki_floor"]
    return params["mpki_floor"] + span * jnp.exp(-(u - 4.0) / params["ws_units"])


def _bank_affinity(n_apps: int, n_banks: int, dtype) -> jnp.ndarray:
    """JAX mirror of :func:`repro.sim.memsys.bank_affinity` (static banks)."""
    i = jnp.arange(n_apps, dtype=dtype)[:, None]
    b = jnp.arange(n_banks, dtype=dtype)[None, :]
    a = BANK_SKEW ** jnp.mod(i + b, float(n_banks))
    return a / a.sum(axis=-1, keepdims=True)


def _banked_queueing(traffic_q, bw, banks, max_banks: int):
    """Affinity-weighted per-bank queueing with a *traced* bank count.

    ``banks`` broadcasts against ``(..., n)`` (float, >= 1); ``max_banks``
    is the static bank-axis width.  Rows with ``banks == 1`` reduce
    BIT-identically to the flat partitioned channel model: affinity is
    exactly 1.0 (skew**0 / 1.0), ``x * 1.0`` and ``x / 1.0`` are IEEE
    identities, masked banks contribute exact zeros to the queue sum and
    ``+inf`` to the cap min.  Returns ``(q_ns, cap_gbps)``.
    """
    n = traffic_q.shape[-1]
    i = jnp.arange(n, dtype=traffic_q.dtype)[:, None]           # (n, 1)
    b = jnp.arange(max_banks, dtype=traffic_q.dtype)[None, :]   # (1, MAXB)
    nb = jnp.broadcast_to(banks, traffic_q.shape)[..., None]    # (..., n, 1)
    active = b < nb
    a_raw = jnp.where(active, BANK_SKEW ** jnp.mod(i + b, nb), 0.0)
    aff = a_raw / a_raw.sum(axis=-1, keepdims=True)
    bank_bw = bw[..., None] / nb
    rho_b = traffic_q[..., None] * aff / jnp.maximum(bank_bw, 1e-6)
    rho_cb = jnp.clip(rho_b, 0.0, RHO_MAX)
    q_bank = Q_SCALE_NS * rho_cb / (1.0 - rho_cb)
    q_ns = jnp.sum(aff * q_bank, axis=-1)
    cap = jnp.min(
        jnp.where(active, bank_bw / jnp.where(active, aff, 1.0), jnp.inf),
        axis=-1)
    return q_ns, cap


@functools.partial(
    jax.jit,
    static_argnames=("cache_partitioned", "bandwidth_partitioned", "iters",
                     "bandwidth_banks"))
def _evaluate_jit(
    params: Params,
    cache_units: jnp.ndarray,
    bw: jnp.ndarray,
    pf: jnp.ndarray,
    total_cache_units: jnp.ndarray,
    total_bandwidth_gbps: jnp.ndarray,
    llc_extra_cycles: jnp.ndarray,
    cache_partitioned: bool,
    bandwidth_partitioned: bool,
    iters: int,
    bandwidth_banks: int = 1,
):
    shape = jnp.broadcast_shapes(
        cache_units.shape, bw.shape, pf.shape, params["cpi_base"].shape)
    n = shape[-1]
    ipc0 = jnp.broadcast_to(1.0 / params["cpi_base"], shape)
    zeros = jnp.zeros(shape, ipc0.dtype)

    def body(_, carry):
        ipc, _q, _tr, mpki_eff, _ex, _oc = carry
        # ---- cache occupancy -------------------------------------------- #
        if cache_partitioned:
            occ = jnp.broadcast_to(cache_units, shape).astype(ipc.dtype)
        else:
            miss_rate = jnp.maximum(mpki_eff, 1e-3) * ipc
            share = miss_rate / jnp.sum(miss_rate, axis=-1, keepdims=True)
            occ = share * total_cache_units
        occ_eff = jnp.maximum(occ - params["pf_pollution"] * pf, 1.0)

        # ---- prefetch-adjusted miss stream ------------------------------ #
        m = mpki_curve(params, occ_eff)
        covered = params["pf_cov"] * pf * m
        exposed = m - covered * params["pf_hide"]
        useless = covered * (1.0 / jnp.maximum(params["pf_acc"], 1e-3) - 1.0)
        reqki = m * (1.0 + params["wb_frac"]) + useless
        reqki_q = ((m - covered) + m * params["wb_frac"]
                   + PF_QUEUE_WEIGHT * (covered + useless))

        # ---- memory queuing --------------------------------------------- #
        traffic = ipc * FREQ_GHZ * reqki * LINE_BYTES / 1000.0
        traffic_q = ipc * FREQ_GHZ * reqki_q * LINE_BYTES / 1000.0
        if bandwidth_partitioned and bandwidth_banks > 1:
            # Banked tokens (mirror of the numpy golden): affinity-weighted
            # per-bank M/M/1 queues, cap set by the first saturated bank.
            aff = _bank_affinity(n, bandwidth_banks, ipc.dtype)
            bank_bw = bw[..., None] / float(bandwidth_banks)
            rho_b = traffic_q[..., None] * aff / jnp.maximum(bank_bw, 1e-6)
            rho_cb = jnp.clip(rho_b, 0.0, RHO_MAX)
            q_bank = Q_SCALE_NS * rho_cb / (1.0 - rho_cb)
            q_ns = jnp.sum(aff * q_bank, axis=-1)
            cap_gbps = jnp.broadcast_to(
                jnp.min(bank_bw / aff, axis=-1), shape).astype(ipc.dtype)
        elif bandwidth_partitioned:
            rho = traffic_q / jnp.maximum(bw, 1e-6)
            cap_gbps = jnp.broadcast_to(bw, shape).astype(ipc.dtype)
            rho_c = jnp.clip(rho, 0.0, RHO_MAX)
            q_ns = Q_SCALE_NS * rho_c / (1.0 - rho_c)
        else:
            tot = jnp.sum(traffic_q, axis=-1, keepdims=True)
            rho = jnp.broadcast_to(tot / total_bandwidth_gbps, shape)
            tot_full = jnp.sum(traffic, axis=-1, keepdims=True)
            safe_tot = jnp.where(tot_full > 0, tot_full, 1.0)
            frac = jnp.where(tot_full > 0, traffic / safe_tot, 1.0 / n)
            cap_gbps = frac * total_bandwidth_gbps
            rho_c = jnp.clip(rho, 0.0, RHO_MAX)
            q_ns = Q_SCALE_NS * rho_c / (1.0 - rho_c)
            q_ns = q_ns * (1.0 + IF_SKEW * (1.0 - frac))

        # ---- IPC --------------------------------------------------------- #
        penalty_cyc = (DRAM_LAT_NS + q_ns) * FREQ_GHZ / params["mlp"]
        cpi = (params["cpi_base"]
               + params["apki"] / 1000.0 * llc_extra_cycles
               + exposed / 1000.0 * penalty_cyc)
        ipc_demand = 1.0 / cpi
        ipc_cap = RHO_MAX * cap_gbps / jnp.maximum(
            FREQ_GHZ * reqki * LINE_BYTES / 1000.0, 1e-9)
        ipc_new = jnp.minimum(ipc_demand, ipc_cap)
        ipc = DAMPING * ipc + (1.0 - DAMPING) * ipc_new
        return (ipc, q_ns, traffic, m, exposed, occ)

    init = (ipc0, zeros, zeros, zeros, zeros, zeros)
    return jax.lax.fori_loop(0, iters, body, init)


def _evaluate_rowflags(
    params: Params,
    cache_units: jnp.ndarray,
    bw: jnp.ndarray,
    pf: jnp.ndarray,
    total_cache_units,
    total_bandwidth_gbps,
    llc_extra_cycles,
    cache_partitioned: jnp.ndarray,
    bandwidth_partitioned: jnp.ndarray,
    iters: int,
    bandwidth_banks=None,
    max_banks: int = 1,
):
    """:func:`_evaluate_jit` with *traced per-row* partitioning flags.

    The stacked Fig. 8 timeline (:mod:`repro.sim.timeline_jax`) batches
    managers with different Table-3 modes into one program, so
    ``cache_partitioned`` / ``bandwidth_partitioned`` become boolean
    arrays broadcasting against the batch axes instead of static trace
    flags.  Both branches of each regime are computed and selected
    elementwise; every op of the selected branch is identical to the
    static-flag path, so per-row results are bit-identical to
    :func:`_evaluate_jit` with that row's flags (pinned by
    ``tests/test_timeline_fused.py``).  Meant to be called inside an
    enclosing jitted program — it is not jitted itself.

    ``bandwidth_banks`` (traced, broadcasting against the batch axes) and
    the static ``max_banks`` select the banked-token regime per row: when
    ``max_banks > 1`` every partitioned row goes through the generalized
    bank formula, whose 1-bank rows are bit-identical to the flat model
    (:func:`_banked_queueing`) — so mixing banked and flat rows in one
    stack preserves the stacked-vs-fused parity contract.
    """
    shape = jnp.broadcast_shapes(
        cache_units.shape, bw.shape, pf.shape, params["cpi_base"].shape)
    n = shape[-1]
    ipc0 = jnp.broadcast_to(1.0 / params["cpi_base"], shape)
    zeros = jnp.zeros(shape, ipc0.dtype)
    cache_part = jnp.broadcast_to(cache_partitioned, shape)
    bw_part = jnp.broadcast_to(bandwidth_partitioned, shape)

    def body(_, carry):
        ipc, _q, _tr, mpki_eff, _ex, _oc = carry
        # ---- cache occupancy -------------------------------------------- #
        occ_p = jnp.broadcast_to(cache_units, shape).astype(ipc.dtype)
        miss_rate = jnp.maximum(mpki_eff, 1e-3) * ipc
        share = miss_rate / jnp.sum(miss_rate, axis=-1, keepdims=True)
        occ = jnp.where(cache_part, occ_p, share * total_cache_units)
        occ_eff = jnp.maximum(occ - params["pf_pollution"] * pf, 1.0)

        # ---- prefetch-adjusted miss stream ------------------------------ #
        m = mpki_curve(params, occ_eff)
        covered = params["pf_cov"] * pf * m
        exposed = m - covered * params["pf_hide"]
        useless = covered * (1.0 / jnp.maximum(params["pf_acc"], 1e-3) - 1.0)
        reqki = m * (1.0 + params["wb_frac"]) + useless
        reqki_q = ((m - covered) + m * params["wb_frac"]
                   + PF_QUEUE_WEIGHT * (covered + useless))

        # ---- memory queuing --------------------------------------------- #
        traffic = ipc * FREQ_GHZ * reqki * LINE_BYTES / 1000.0
        traffic_q = ipc * FREQ_GHZ * reqki_q * LINE_BYTES / 1000.0
        if max_banks > 1:
            q_p, cap_p = _banked_queueing(
                traffic_q, bw, bandwidth_banks, max_banks)
            cap_p = jnp.broadcast_to(cap_p, shape).astype(ipc.dtype)
        else:
            rho_p = traffic_q / jnp.maximum(bw, 1e-6)
            rho_cp = jnp.clip(rho_p, 0.0, RHO_MAX)
            q_p = Q_SCALE_NS * rho_cp / (1.0 - rho_cp)
            cap_p = jnp.broadcast_to(bw, shape).astype(ipc.dtype)
        tot = jnp.sum(traffic_q, axis=-1, keepdims=True)
        rho_u = jnp.broadcast_to(tot / total_bandwidth_gbps, shape)
        tot_full = jnp.sum(traffic, axis=-1, keepdims=True)
        safe_tot = jnp.where(tot_full > 0, tot_full, 1.0)
        frac = jnp.where(tot_full > 0, traffic / safe_tot, 1.0 / n)
        rho_cu = jnp.clip(rho_u, 0.0, RHO_MAX)
        q_u = Q_SCALE_NS * rho_cu / (1.0 - rho_cu)
        q_u = q_u * (1.0 + IF_SKEW * (1.0 - frac))
        cap_gbps = jnp.where(bw_part, cap_p, frac * total_bandwidth_gbps)
        q_ns = jnp.where(bw_part, q_p, q_u)

        # ---- IPC --------------------------------------------------------- #
        penalty_cyc = (DRAM_LAT_NS + q_ns) * FREQ_GHZ / params["mlp"]
        cpi = (params["cpi_base"]
               + params["apki"] / 1000.0 * llc_extra_cycles
               + exposed / 1000.0 * penalty_cyc)
        ipc_demand = 1.0 / cpi
        ipc_cap = RHO_MAX * cap_gbps / jnp.maximum(
            FREQ_GHZ * reqki * LINE_BYTES / 1000.0, 1e-9)
        ipc_new = jnp.minimum(ipc_demand, ipc_cap)
        ipc = DAMPING * ipc + (1.0 - DAMPING) * ipc_new
        return (ipc, q_ns, traffic, m, exposed, occ)

    init = (ipc0, zeros, zeros, zeros, zeros, zeros)
    return jax.lax.fori_loop(0, iters, body, init)


def evaluate(
    apps: Union[AppArrays, Params],
    cache_units,
    bandwidth_gbps,
    prefetch_on,
    *,
    cache_partitioned: bool = True,
    bandwidth_partitioned: bool = True,
    total_cache_units: float = 256.0,
    total_bandwidth_gbps: float = 64.0,
    llc_extra_cycles: float = 0.0,
    bandwidth_banks: int = 1,
    iters: int = FIXED_POINT_ITERS,
) -> SteadyState:
    """Batched JAX counterpart of :func:`repro.sim.memsys.evaluate`.

    Returns a :class:`SteadyState` of device arrays; call ``np.asarray`` on
    the fields to bring them to host.
    """
    params = app_params(apps)
    record_dispatch()
    with x64_context():
        f64 = functools.partial(jnp.asarray, dtype=jnp.float64)
        p = {k: f64(v) for k, v in params.items()}
        ipc, q_ns, traffic, mpki_eff, exposed, occ = _evaluate_jit(
            p, f64(cache_units), f64(bandwidth_gbps), f64(prefetch_on),
            f64(total_cache_units), f64(total_bandwidth_gbps),
            f64(llc_extra_cycles),
            cache_partitioned=cache_partitioned,
            bandwidth_partitioned=bandwidth_partitioned,
            iters=iters, bandwidth_banks=bandwidth_banks)
    return SteadyState(
        ipc=ipc, queuing_delay_ns=q_ns, traffic_gbps=traffic,
        mpki=mpki_eff, exposed_mpki=exposed, occupancy_units=occ)


@functools.partial(jax.jit, static_argnames=("total_units",))
def _utility_curves_jit(
    params: Params,
    pf: jnp.ndarray,
    ipc: jnp.ndarray,
    duration_ms: jnp.ndarray,
    total_units: int,
):
    u = jnp.arange(total_units + 1, dtype=pf.dtype)          # (U+1,)
    p = {k: v[..., :, None] for k, v in params.items()}      # (..., n, 1)
    units = u - p["pf_pollution"] * pf[..., :, None]
    m = mpki_curve(p, units)                                 # (..., n, U+1)
    eff_miss = m * (1.0 - p["pf_cov"] * pf[..., :, None])
    hits = jnp.maximum(p["apki"] - eff_miss, 0.0)
    kilo_instr = ipc[..., :, None] * FREQ_GHZ * 1e6 * duration_ms / 1000.0
    return hits * kilo_instr


def utility_curves(
    apps: Union[AppArrays, Params],
    prefetch_on,
    ipc,
    total_units: int,
    duration_ms: float = 1.0,
) -> jnp.ndarray:
    """Batched JAX counterpart of :func:`repro.sim.memsys.utility_curves`.

    Shape ``(..., n, total_units + 1)`` — unlike the numpy reference this
    accepts leading batch axes on every argument.
    """
    params = app_params(apps)
    record_dispatch()
    with x64_context():
        f64 = functools.partial(jnp.asarray, dtype=jnp.float64)
        p = {k: f64(v) for k, v in params.items()}
        return _utility_curves_jit(
            p, f64(prefetch_on), f64(ipc), f64(duration_ms),
            total_units=int(total_units))
