"""16-core CMP evaluation substrate for the faithful CBP reproduction.

Interval performance model (paper §4 methodology) + the ten Table-3
resource-manager configurations + the paper's workloads.
"""
from repro.sim.apps import (
    APP_NAMES,
    BASELINE_BW_GBPS,
    BASELINE_UNITS,
    MIN_UNITS,
    PROFILES,
    TOTAL_BW_GBPS,
    TOTAL_UNITS_8MB,
    AppArrays,
    stack,
)
from repro.sim.managers import MANAGER_NAMES, ManagerResult, run_all_managers, run_manager
from repro.sim.memsys import SteadyState, evaluate, mpki_curve, utility_curves
from repro.sim.runner import CMPConfig, CMPPlant, antt, baseline_ipc, weighted_speedup
from repro.sim.workloads import WORKLOADS, random_workloads

__all__ = [
    "APP_NAMES", "BASELINE_BW_GBPS", "BASELINE_UNITS", "MIN_UNITS",
    "PROFILES", "TOTAL_BW_GBPS", "TOTAL_UNITS_8MB", "AppArrays", "stack",
    "MANAGER_NAMES", "ManagerResult", "run_all_managers", "run_manager",
    "SteadyState", "evaluate", "mpki_curve", "utility_curves",
    "CMPConfig", "CMPPlant", "antt", "baseline_ipc", "weighted_speedup",
    "WORKLOADS", "random_workloads",
]
