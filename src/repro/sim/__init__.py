"""16-core CMP evaluation substrate for the faithful CBP reproduction.

Interval performance model (paper §4 methodology) + the Table-3
resource-manager configurations + the paper's workloads.
"""
from repro.sim.apps import (
    APP_NAMES,
    BASELINE_BW_GBPS,
    BASELINE_UNITS,
    MIN_UNITS,
    PROFILES,
    TOTAL_BW_GBPS,
    TOTAL_UNITS_8MB,
    AppArrays,
    stack,
    stack_mixes,
)
from repro.sim.managers import (
    MANAGER_NAMES,
    TABLE3_MODES,
    ManagerResult,
    policy_loop,
    run_all_managers,
    run_manager,
)
from repro.sim.memsys import SteadyState, evaluate, mpki_curve, utility_curves
from repro.sim.policies import (
    REGISTRY,
    PolicyFamily,
    UnknownManagerError,
    get_family,
    manager_names,
    table3_modes,
    validate_manager_names,
)
from repro.sim.runner import (
    CMPConfig,
    CMPPlant,
    antt,
    baseline_ipc,
    equal_share,
    weighted_speedup,
)
from repro.sim.workloads import WORKLOADS, random_mixes, random_workloads

# The sweep and static-search substrates pull in jax; load them lazily
# (PEP 562) so the scalar numpy path stays importable without paying JAX
# startup cost.
_SWEEP_EXPORTS = (
    "BatchedCMPPlant", "BatchedCoordinator", "SweepResult",
    "baseline_ipc_batched", "run_sweep",
)
_STATIC_SEARCH_EXPORTS = (
    "FIG5_FAMILIES", "FIG5_TWO_RESOURCE", "FamilySpec", "StaticGrid",
    "StaticOptions", "StaticSearchResult", "enumerate_grid", "family_grid",
    "registry_families", "search_static",
)
_STREAM_EXPORTS = (
    "CheckpointMismatchError", "NumericalDivergenceError", "RetryPolicy",
    "StreamAbortedError", "StreamAggregates", "StreamConfig", "StreamReport",
    "run_stream",
)


def __getattr__(name):
    if name in ("memsys_jax", "timeline_jax", "static_search",
                "stream_sweep"):
        import importlib
        return importlib.import_module(f"repro.sim.{name}")
    if name in _SWEEP_EXPORTS:
        import importlib
        return getattr(importlib.import_module("repro.sim.sweep"), name)
    if name in _STATIC_SEARCH_EXPORTS:
        import importlib
        return getattr(importlib.import_module("repro.sim.static_search"),
                       name)
    if name in _STREAM_EXPORTS:
        import importlib
        return getattr(importlib.import_module("repro.sim.stream_sweep"),
                       name)
    raise AttributeError(f"module 'repro.sim' has no attribute {name!r}")

__all__ = [
    "APP_NAMES", "BASELINE_BW_GBPS", "BASELINE_UNITS", "MIN_UNITS",
    "PROFILES", "TOTAL_BW_GBPS", "TOTAL_UNITS_8MB", "AppArrays", "stack",
    "stack_mixes",
    "MANAGER_NAMES", "TABLE3_MODES", "ManagerResult", "policy_loop",
    "run_all_managers", "run_manager",
    "REGISTRY", "PolicyFamily", "UnknownManagerError", "get_family",
    "manager_names", "table3_modes", "validate_manager_names",
    "SteadyState", "evaluate", "mpki_curve", "utility_curves",
    "CMPConfig", "CMPPlant", "antt", "baseline_ipc", "equal_share",
    "weighted_speedup",
    "BatchedCMPPlant", "BatchedCoordinator", "SweepResult",
    "baseline_ipc_batched", "run_sweep",
    *_STATIC_SEARCH_EXPORTS,
    *_STREAM_EXPORTS,
    "WORKLOADS", "random_mixes", "random_workloads",
]
