"""The manager-family policy registry (ROADMAP item 4).

Every resource-manager family the repo can evaluate is declared HERE, once,
as a :class:`PolicyFamily`.  A family registers three things:

* a **numpy host golden** — the scalar reference loop
  (:mod:`repro.sim.managers` attaches it at import time, so the registry
  never imports the plant stack and stays cycle-free);
* a **traced allocator branch** — the ``cache_policy`` / ``bw_policy`` ids
  select the family's boundary allocators inside the stacked Fig. 8 scan
  (:mod:`repro.sim.timeline_jax` builds its ``lax.switch`` branch tables
  from :data:`CACHE_POLICY_NAMES` / :data:`BW_POLICY_NAMES`, so an id
  outside those tables cannot trace), and ``bandwidth_banks`` selects the
  interval model's bandwidth regime
  (:mod:`repro.sim.memsys` / :mod:`repro.sim.memsys_jax`);
* a **static-grid vocabulary** — which knobs the family's Fig. 5 static
  search may move (:func:`repro.sim.static_search.registry_families`
  turns it into a ``FamilySpec``).

``MANAGER_NAMES`` and ``TABLE3_MODES`` are *derived* from the registry
(:func:`manager_names` / :func:`table3_modes`) instead of hand-pinned
lists, so adding family #15 is: declare it here, attach its host golden,
give its traced branch an id — every sweep/search/stream entry point picks
it up (``tests/test_sim_managers.py`` pins registry completeness).

The three non-Table-3 families added with the registry:

* ``"auction"`` — CARMA-style market allocation (arxiv 1710.00073): each
  client spends a unit budget across cache and bandwidth in proportion to
  its normalized desire for each (ATD marginal hits resp. accumulated
  queuing delay); allocations are pro-rata in spend over the floors.
* ``"qos"`` — QoS-constrained throughput maximization (Nejat et al.,
  arxiv 1911.05114): demand-proportional shares, boosted for clients whose
  slowdown against their first-interval (equal-share) reference exceeds
  the bound — the traced form carries that slowdown signal in the scan.
* ``"bank bw"`` — per-bank bandwidth tokens (arxiv 2410.14003): Algorithm-1
  bandwidth partitioning evaluated under the banked-token memory model
  (``bandwidth_banks > 1``), of which the flat partitioned mode is the
  1-bank special case.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.types import Mode, PrefetchMode

# --------------------------------------------------------------------- #
# traced branch tables
# --------------------------------------------------------------------- #

#: Cache boundary allocator branch ids (``lax.switch`` order inside the
#: stacked scan — :mod:`repro.sim.timeline_jax`).
CACHE_LOOKAHEAD, CACHE_AUCTION, CACHE_QOS = 0, 1, 2
CACHE_POLICY_NAMES: Tuple[str, ...] = ("lookahead", "auction", "qos")

#: Bandwidth boundary allocator branch ids.
BW_ALG1, BW_AUCTION, BW_QOS = 0, 1, 2
BW_POLICY_NAMES: Tuple[str, ...] = ("alg1", "auction", "qos")

#: Per-client auction budget (CARMA's per-agent endowment; only spend
#: *proportions* matter, the scale cancels in the pro-rata shares).
AUCTION_BUDGET = 1.0
AUCTION_EPS = 1e-12

#: QoS family tunables: clients whose slowdown against their first-interval
#: (equal-share) reference exceeds the bound get their demand weight
#: boosted by ``1 + gain * violation``.
QOS_SLOWDOWN_BOUND = 1.05
QOS_VIOLATION_GAIN = 8.0


class UnknownManagerError(ValueError):
    """An unregistered manager-family name reached a sweep entry point.

    Raised by :func:`get_family` (and therefore ``run_manager`` /
    ``run_sweep`` / ``stream_sweep``) naming the bad key and listing the
    registered families — instead of the bare ``KeyError`` a missing dict
    entry used to die with.  Consistent with
    :class:`~repro.sim.static_search.InfeasibleGridError` /
    :class:`~repro.core.types.ScheduleConfigError`: a typed, actionable
    configuration error.
    """

    def __init__(self, name: str, extra: Tuple[str, ...] = ()):
        valid = list(extra) + manager_names()
        super().__init__(
            f"unknown manager {name!r}; registered families: {valid}")
        self.name = name
        self.valid = valid


@dataclasses.dataclass
class PolicyFamily:
    """One manager family's registry entry.

    ``modes`` is the Table-3 ``(cache, bandwidth, prefetch)`` mode triple
    for the classic mode-combination families (``None`` for families with
    their own wiring — CPpf's variant timeline, the auction/QoS boundary
    policies, the banked-bandwidth model regime).  ``host_golden`` is
    attached by :mod:`repro.sim.managers` at import time; it maps
    ``(plant, total_ms, params) -> ManagerResult``.  ``static_grid`` is
    the Fig. 5 vocabulary as plain kwargs (``manage_cache`` /
    ``manage_bw`` / ``manage_pf`` / ``pf_all_on`` / ``bandwidth_banks``)
    so the registry never imports the search stack.
    """

    name: str
    modes: Optional[Tuple[Mode, Mode, PrefetchMode]] = None
    variant: str = "fig8"              # timeline variant ("fig8" | "cppf")
    cache_policy: int = CACHE_LOOKAHEAD
    bw_policy: int = BW_ALG1
    bandwidth_banks: int = 1
    static_grid: Optional[Dict[str, object]] = None
    host_golden: Optional[Callable] = None

    def __post_init__(self):
        if not 0 <= self.cache_policy < len(CACHE_POLICY_NAMES):
            raise ValueError(
                f"{self.name!r}: cache_policy {self.cache_policy} has no "
                f"traced branch (table: {CACHE_POLICY_NAMES})")
        if not 0 <= self.bw_policy < len(BW_POLICY_NAMES):
            raise ValueError(
                f"{self.name!r}: bw_policy {self.bw_policy} has no traced "
                f"branch (table: {BW_POLICY_NAMES})")
        if self.bandwidth_banks < 1:
            raise ValueError(
                f"{self.name!r}: bandwidth_banks must be >= 1, got "
                f"{self.bandwidth_banks}")


REGISTRY: Dict[str, PolicyFamily] = {}


def register(family: PolicyFamily) -> PolicyFamily:
    if family.name in REGISTRY:
        raise ValueError(f"family {family.name!r} already registered")
    REGISTRY[family.name] = family
    return family


def manager_names() -> List[str]:
    """Registry insertion order — THE manager-name list of every sweep."""
    return list(REGISTRY)


def table3_modes() -> Dict[str, Tuple[Mode, Mode, PrefetchMode]]:
    """The classic mode-combination families (``modes`` is not ``None``)."""
    return {name: fam.modes for name, fam in REGISTRY.items()
            if fam.modes is not None}


def get_family(name: str) -> PolicyFamily:
    try:
        return REGISTRY[name]
    except KeyError:
        raise UnknownManagerError(name) from None


def validate_manager_names(names, extra: Tuple[str, ...] = ()) -> None:
    """Raise :class:`UnknownManagerError` on the first unregistered name.

    ``extra`` admits caller-specific pseudo-families (the streaming sweep
    accepts them on top of the registry).
    """
    for name in names:
        if name not in REGISTRY and name not in extra:
            raise UnknownManagerError(name, tuple(extra))


# --------------------------------------------------------------------- #
# numpy host allocators (golden references; jax mirrors below)
# --------------------------------------------------------------------- #

def _per_client(value, like: np.ndarray) -> np.ndarray:
    """Broadcast a scalar / per-batch-row tunable against (..., n) state."""
    arr = np.asarray(value)
    arr = arr.reshape(arr.shape + (1,) * (like.ndim - arr.ndim))
    return np.broadcast_to(arr, like.shape)


def _shares(weights: np.ndarray, n: int) -> np.ndarray:
    """Pro-rata shares with the Algorithm-1 zero-total fallback (1/n)."""
    total = weights.sum(axis=-1, keepdims=True)
    return np.where(total > 0,
                    weights / np.where(total > 0, total, 1.0),
                    1.0 / n)


def largest_remainder_round(target: np.ndarray,
                            total_units: int) -> np.ndarray:
    """Round per-client float targets to ints summing exactly to capacity.

    Floor everything, then grant the leftover units to the largest
    fractional parts (stable: equal fractions break toward the lowest
    client index).  ``target`` must sum to ``total_units`` per batch row
    up to float noise and sit at or above any integer floor the caller
    already folded in — both hold for pro-rata-over-floors targets.
    """
    base = np.floor(target)
    frac = target - base
    deficit = np.rint(total_units - base.sum(axis=-1)).astype(np.int64)
    order = np.argsort(-frac, axis=-1, kind="stable")
    rank = np.argsort(order, axis=-1, kind="stable")
    return (base + (rank < deficit[..., None])).astype(np.int64)


def _cache_desire(curves: np.ndarray, min_ways: np.ndarray) -> np.ndarray:
    """Marginal ATD utility: hits gained going from the floor to the whole
    cache — a client whose curve is flat past its floor desires nothing."""
    top = curves[..., -1]
    at_min = np.take_along_axis(
        curves, min_ways[..., None].astype(np.int64), axis=-1)[..., 0]
    return np.maximum(top - at_min, 0.0)


def auction_allocate(
    curves: np.ndarray,
    bw_delay: np.ndarray,
    *,
    min_ways,
    total_units: int,
    min_bandwidth,
    total_bandwidth: float,
) -> Tuple[np.ndarray, np.ndarray]:
    """CARMA-style auction over cache ways and bandwidth (numpy golden).

    Each client splits a unit budget between the two resources in
    proportion to its *normalized* desires (mean-normalized so the two
    signals' units cancel): marginal ATD hits for cache, accumulated
    queuing delay for bandwidth.  Resources are then allocated pro-rata in
    spend over the per-client floors; cache spends round to integers by
    largest remainder.

    Args:
      curves: (..., n, U+1) accumulated ATD utility curves.
      bw_delay: (..., n) accumulated queuing delays.
      min_ways: scalar or per-batch-row floor (ways).
      min_bandwidth: scalar or (..., 1) per-row floor (GB/s).

    Returns:
      ``(cache_units, bandwidth)`` — (..., n) int64 summing to
      ``total_units`` and (..., n) float summing to ``total_bandwidth``.
    """
    n = bw_delay.shape[-1]
    mw = _per_client(min_ways, bw_delay).astype(np.float64)
    cd = _cache_desire(curves, mw)
    cd_n = cd / np.maximum(cd.mean(axis=-1, keepdims=True), AUCTION_EPS)
    bd_n = bw_delay / np.maximum(
        bw_delay.mean(axis=-1, keepdims=True), AUCTION_EPS)
    frac_cache = cd_n / (cd_n + bd_n + AUCTION_EPS)
    spend_cache = AUCTION_BUDGET * frac_cache
    spend_bw = AUCTION_BUDGET - spend_cache

    target = mw + _shares(spend_cache, n) * (
        total_units - mw.sum(axis=-1, keepdims=True))
    units = largest_remainder_round(target, total_units)
    min_bw = np.asarray(min_bandwidth, dtype=np.float64)
    bandwidth = min_bw + _shares(spend_bw, n) * (
        total_bandwidth - min_bw * n)
    return units, bandwidth


def qos_allocate(
    curves: np.ndarray,
    bw_delay: np.ndarray,
    slowdown: np.ndarray,
    *,
    min_ways,
    total_units: int,
    min_bandwidth,
    total_bandwidth: float,
    bound: float = QOS_SLOWDOWN_BOUND,
    gain: float = QOS_VIOLATION_GAIN,
) -> Tuple[np.ndarray, np.ndarray]:
    """QoS-constrained allocation (numpy golden).

    Throughput-maximizing demand-proportional shares (marginal ATD hits
    for cache, accumulated delay for bandwidth), with the weight of any
    client violating its slowdown bound boosted by ``1 + gain *
    violation`` — resources flow to the constraint violators until their
    slowdown drops back under the bound.  ``slowdown`` is each client's
    first-interval (equal-share) reference IPC over its current IPC.
    """
    n = bw_delay.shape[-1]
    mw = _per_client(min_ways, bw_delay).astype(np.float64)
    boost = 1.0 + gain * np.maximum(slowdown - bound, 0.0)
    cache_w = _cache_desire(curves, mw) * boost
    bw_w = bw_delay * boost

    target = mw + _shares(cache_w, n) * (
        total_units - mw.sum(axis=-1, keepdims=True))
    units = largest_remainder_round(target, total_units)
    min_bw = np.asarray(min_bandwidth, dtype=np.float64)
    bandwidth = min_bw + _shares(bw_w, n) * (total_bandwidth - min_bw * n)
    return units, bandwidth


# --------------------------------------------------------------------- #
# traced mirrors (same op order as the numpy goldens)
# --------------------------------------------------------------------- #

def _shares_jax(weights, n: int):
    import jax.numpy as jnp

    total = weights.sum(axis=-1, keepdims=True)
    return jnp.where(total > 0,
                     weights / jnp.where(total > 0, total, 1.0),
                     1.0 / n)


def largest_remainder_round_jax(target, total_units: int):
    """Traced mirror of :func:`largest_remainder_round` (same tie-break:
    stable descending fraction sort, lowest index first)."""
    import jax.numpy as jnp

    base = jnp.floor(target)
    frac = target - base
    deficit = jnp.rint(total_units - base.sum(axis=-1)).astype(jnp.int32)
    order = jnp.argsort(-frac, axis=-1, stable=True)
    rank = jnp.argsort(order, axis=-1, stable=True)
    return (base + (rank < deficit[..., None])).astype(jnp.int32)


def _cache_desire_jax(curves, mw_f):
    import jax.numpy as jnp

    top = curves[..., -1]
    at_min = jnp.take_along_axis(
        curves, mw_f[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return jnp.maximum(top - at_min, 0.0)


def auction_allocate_jax(curves, bw_delay, *, min_ways, total_units: int,
                         min_bandwidth, total_bandwidth):
    """Traced mirror of :func:`auction_allocate` (op-for-op)."""
    import jax.numpy as jnp

    n = bw_delay.shape[-1]
    mw = jnp.broadcast_to(min_ways, bw_delay.shape).astype(bw_delay.dtype)
    cd = _cache_desire_jax(curves, mw)
    cd_n = cd / jnp.maximum(cd.mean(axis=-1, keepdims=True), AUCTION_EPS)
    bd_n = bw_delay / jnp.maximum(
        bw_delay.mean(axis=-1, keepdims=True), AUCTION_EPS)
    frac_cache = cd_n / (cd_n + bd_n + AUCTION_EPS)
    spend_cache = AUCTION_BUDGET * frac_cache
    spend_bw = AUCTION_BUDGET - spend_cache

    target = mw + _shares_jax(spend_cache, n) * (
        total_units - mw.sum(axis=-1, keepdims=True))
    units = largest_remainder_round_jax(target, total_units)
    min_bw = jnp.asarray(min_bandwidth, dtype=bw_delay.dtype)
    bandwidth = min_bw + _shares_jax(spend_bw, n) * (
        total_bandwidth - min_bw * n)
    return units, bandwidth


def qos_allocate_jax(curves, bw_delay, slowdown, *, min_ways,
                     total_units: int, min_bandwidth, total_bandwidth,
                     bound, gain):
    """Traced mirror of :func:`qos_allocate` (op-for-op; ``bound`` /
    ``gain`` may be per-row ``(..., 1)`` arrays inside the stacked scan)."""
    import jax.numpy as jnp

    n = bw_delay.shape[-1]
    mw = jnp.broadcast_to(min_ways, bw_delay.shape).astype(bw_delay.dtype)
    boost = 1.0 + gain * jnp.maximum(slowdown - bound, 0.0)
    cache_w = _cache_desire_jax(curves, mw) * boost
    bw_w = bw_delay * boost

    target = mw + _shares_jax(cache_w, n) * (
        total_units - mw.sum(axis=-1, keepdims=True))
    units = largest_remainder_round_jax(target, total_units)
    min_bw = jnp.asarray(min_bandwidth, dtype=bw_delay.dtype)
    bandwidth = min_bw + _shares_jax(bw_w, n) * (
        total_bandwidth - min_bw * n)
    return units, bandwidth


# --------------------------------------------------------------------- #
# the registered families
# --------------------------------------------------------------------- #

def _grid(**kwargs) -> Dict[str, object]:
    return kwargs


# Classic Table-3 mode combinations (the paper's comparison menu).
register(PolicyFamily(
    "baseline",
    modes=(Mode.UNPARTITIONED, Mode.UNPARTITIONED, PrefetchMode.OFF),
    static_grid=_grid()))
register(PolicyFamily(
    "equal off",
    modes=(Mode.EQUAL, Mode.EQUAL, PrefetchMode.OFF),
    static_grid=_grid()))
register(PolicyFamily(
    "equal on",
    modes=(Mode.EQUAL, Mode.EQUAL, PrefetchMode.ON),
    static_grid=_grid(pf_all_on=True)))
register(PolicyFamily(
    "only cache",
    modes=(Mode.DYNAMIC, Mode.UNPARTITIONED, PrefetchMode.OFF),
    static_grid=_grid(manage_cache=True)))
register(PolicyFamily(
    "only bw",
    modes=(Mode.UNPARTITIONED, Mode.DYNAMIC, PrefetchMode.OFF),
    static_grid=_grid(manage_bw=True)))
register(PolicyFamily(
    "only pref",
    modes=(Mode.UNPARTITIONED, Mode.UNPARTITIONED, PrefetchMode.DYNAMIC),
    static_grid=_grid(manage_pf=True)))
register(PolicyFamily(
    "bw+pref",
    modes=(Mode.UNPARTITIONED, Mode.DYNAMIC, PrefetchMode.DYNAMIC),
    static_grid=_grid(manage_bw=True, manage_pf=True)))
register(PolicyFamily(
    "bw+cache",
    modes=(Mode.DYNAMIC, Mode.DYNAMIC, PrefetchMode.OFF),
    static_grid=_grid(manage_cache=True, manage_bw=True)))
register(PolicyFamily(
    "cache+pref",
    modes=(Mode.DYNAMIC, Mode.UNPARTITIONED, PrefetchMode.DYNAMIC),
    static_grid=_grid(manage_cache=True, manage_pf=True)))
register(PolicyFamily(
    "CPpf",
    variant="cppf",
    static_grid=_grid(manage_cache=True, pf_all_on=True)))
register(PolicyFamily(
    "CBP",
    modes=(Mode.DYNAMIC, Mode.DYNAMIC, PrefetchMode.DYNAMIC),
    static_grid=_grid(manage_cache=True, manage_bw=True, manage_pf=True)))

# New families from related work (ROADMAP item 4), ridden on the same
# stacked manager axis.
register(PolicyFamily(
    "auction",
    cache_policy=CACHE_AUCTION,
    bw_policy=BW_AUCTION,
    static_grid=_grid(manage_cache=True, manage_bw=True)))
register(PolicyFamily(
    "qos",
    cache_policy=CACHE_QOS,
    bw_policy=BW_QOS,
    static_grid=_grid(manage_cache=True, manage_bw=True)))
register(PolicyFamily(
    "bank bw",
    bandwidth_banks=4,
    static_grid=_grid(manage_bw=True, bandwidth_banks=4)))
