"""Fault-tolerant streaming sweep service: 10^5-10^6 mixes, chunked and
double-buffered, with online aggregation and checkpoint/resume.

ROADMAP item 3: consolidation decisions over millions of users mean
evaluating the Table-3 manager set over 10^5-10^6 workload mixes streamed
continuously — far past what ``run_sweep``'s materialize-all-rows shape
can hold, and far past the runtime where "nothing ever fails" is a usable
assumption.  This module is both the *scale* layer (chunked pipeline,
online aggregates, bounded memory) and the *robustness* layer (retry,
quarantine, finite guards, watchdog, atomic checkpoint/resume) over
:func:`repro.sim.timeline_jax.run_timelines`.

Pipeline
    The stream is processed in fixed-size chunks.  Chunk c's device
    program is dispatched and fetched on a single worker thread while the
    host thread generates chunk c+1's scenario arrays
    (:func:`repro.sim.workloads.scenario_chunk`) — classic double
    buffering, built on :func:`repro.sim.timeline_jax.run_timelines_async`
    so the dispatch never blocks on the transfer.  Every chunk is
    **3 recorded device programs** (stacked manager set + shared baseline
    + the metrics/finite-guard reduction, counter
    :func:`repro.core.device_dispatches`) regardless of chunk size.

Online aggregates
    Nothing materializes per-mix rows: each chunk folds into
    :class:`StreamAggregates` — running log-sum for the geomean weighted
    speedup, a fixed-bin histogram sketch for p50/p90/p99 per-app
    slowdown, running max-slowdown and min-fairness — all plain float64
    numpy, folded in chunk order, so the final aggregates of a resumed run
    are *bit-identical* to an uninterrupted one.

Robustness contract (each layer is fault-injectable via
:class:`repro.runtime.faultinject.FaultPlan`):

* chunk dispatch failures retry with exponential backoff
  (:class:`RetryPolicy`); a chunk that exhausts its retries is
  **quarantined** and the stream keeps going — the report carries an
  explicit ``coverage`` fraction and names every quarantined chunk
  (graceful degradation, never silent truncation);
* an in-trace finite guard (the metrics program reduces
  ``isfinite`` over every (manager, mix) row on device) surfaces
  :class:`NumericalDivergenceError` naming the offending (manager, mix);
  the service quarantines the chunk by default (``on_divergence="raise"``
  propagates instead);
* per-chunk walls feed a :class:`repro.runtime.fault.StragglerWatchdog`
  (median-seeded warm-up so jit compilation cannot poison the baseline);
* the full service state — aggregation sketches, chunk cursor, quarantine
  list, total retry count — checkpoints atomically through
  :class:`repro.checkpoint.CheckpointManager` every ``checkpoint_every``
  chunks; a killed run resumes from the last complete checkpoint and
  reproduces the uninterrupted run's final aggregates bit-for-bit
  (chunk generation is a pure function of ``(seed, chunk_index)`` —
  no RNG state threads between chunks, so the cursor IS the RNG state);
* ``max_consecutive_quarantines`` bounds pathological streams: a service
  that quarantines everything is broken, not degraded, and must say so.

CI: ``benchmarks/stream_bench.py --smoke`` gates the resume-parity
contract (dispatch failure + retry, NaN-poisoned chunk quarantine, mid-run
kill + resume -> bit-identical aggregates), the per-chunk dispatch budget
and the overlap-vs-serial pipeline; ``tools/stream_sweep.py`` is the CLI.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import hashlib
import json
import pathlib
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import CBPParams
from repro.core.dispatch import record_dispatch
from repro.runtime.fault import StragglerWatchdog
from repro.runtime.faultinject import FaultPlan
from repro.sim import memsys_jax, timeline_jax
from repro.sim import policies
from repro.sim.managers import MANAGER_NAMES
from repro.sim.runner import equal_share
from repro.sim.workloads import StreamScenario, scenario_chunk


class NumericalDivergenceError(RuntimeError):
    """A (manager, mix) row produced a non-finite result.

    Raised off the in-trace finite guard; names the offending manager and
    the *global* mix index so a 10^6-mix stream pinpoints the row.
    """

    def __init__(self, manager: str, mix_index: int, chunk_index: int):
        self.manager = manager
        self.mix_index = mix_index
        self.chunk_index = chunk_index
        super().__init__(
            f"non-finite result for manager {manager!r}, mix {mix_index} "
            f"(chunk {chunk_index})")


class CheckpointMismatchError(RuntimeError):
    """A resume was attempted against a checkpoint of a different stream
    (different seed/shape/scenario): resuming would corrupt aggregates."""


class StreamAbortedError(RuntimeError):
    """Too many consecutive chunk quarantines — the stream is broken, not
    degraded, and refusing to continue beats silently reporting ~0
    coverage after hours of wall time."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff for chunk dispatch failures."""

    max_retries: int = 3
    backoff_s: float = 0.05
    multiplier: float = 2.0
    max_backoff_s: float = 2.0

    def delay(self, attempt: int) -> float:
        return min(self.backoff_s * self.multiplier ** attempt,
                   self.max_backoff_s)


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Shape + policy of one streaming sweep run."""

    n_mixes: int
    chunk_size: int = 512
    managers: Optional[Tuple[str, ...]] = None   # None = all MANAGER_NAMES
    total_ms: float = 50.0
    seed: int = 0
    scenario: StreamScenario = dataclasses.field(
        default_factory=StreamScenario)
    total_cache_units: int = 256
    total_bandwidth: float = 64.0
    llc_extra_cycles: float = 0.0
    params: CBPParams = dataclasses.field(default_factory=CBPParams)
    # Aggregation sketch: fixed uniform bins over [0, hist_max_slowdown)
    # plus a final overflow bin.
    hist_bins: int = 512
    hist_max_slowdown: float = 8.0
    # Robustness policy.
    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    on_divergence: str = "quarantine"            # "quarantine" | "raise"
    max_consecutive_quarantines: int = 8
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 8
    watchdog_threshold: float = 3.0
    watchdog_warmup: int = 3

    def __post_init__(self):
        if self.n_mixes < 1 or self.chunk_size < 1:
            raise ValueError("n_mixes and chunk_size must be >= 1")
        if self.on_divergence not in ("quarantine", "raise"):
            raise ValueError(
                f"unknown on_divergence {self.on_divergence!r}")
        if self.hist_bins < 2:
            raise ValueError("hist_bins must be >= 2")
        # UnknownManagerError (a ValueError) on the first unregistered name.
        policies.validate_manager_names(self.manager_names)

    @property
    def manager_names(self) -> List[str]:
        return (list(MANAGER_NAMES) if self.managers is None
                else list(self.managers))

    @property
    def n_chunks(self) -> int:
        return -(-self.n_mixes // self.chunk_size)

    def fingerprint(self) -> str:
        """Stream identity — a resumed run must match it exactly."""
        payload = {
            "n_mixes": self.n_mixes, "chunk_size": self.chunk_size,
            "managers": self.manager_names, "total_ms": self.total_ms,
            "seed": self.seed,
            "scenario": dataclasses.asdict(self.scenario),
            "caps": [self.total_cache_units, self.total_bandwidth,
                     self.llc_extra_cycles],
            "params": dataclasses.asdict(self.params),
            "hist": [self.hist_bins, self.hist_max_slowdown],
        }
        blob = json.dumps(payload, sort_keys=True, default=str)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]


class StreamAggregates:
    """Online per-manager aggregates — the whole memory footprint of a
    10^6-mix stream is these few (K,)- and (K, bins)-shaped arrays.

    Folds are plain float64 numpy in chunk order, so aggregates are
    bit-reproducible across resume (and independent of pipeline overlap,
    which never reorders folds).
    """

    def __init__(self, n_managers: int, hist_bins: int,
                 hist_max_slowdown: float):
        self.hist_bins = int(hist_bins)
        self.hist_max = float(hist_max_slowdown)
        # Uniform bins over [0, hist_max) with bin (hist_bins - 1) as the
        # overflow bucket; width excludes the overflow bin.
        self.bin_width = self.hist_max / (self.hist_bins - 1)
        k = int(n_managers)
        self.mix_count = np.zeros(k, dtype=np.int64)
        self.log_ws_sum = np.zeros(k, dtype=np.float64)
        self.slowdown_hist = np.zeros((k, self.hist_bins), dtype=np.int64)
        self.max_slowdown = np.zeros(k, dtype=np.float64)
        self.min_fairness = np.full(k, np.inf, dtype=np.float64)

    def fold(self, ws: np.ndarray, slowdown: np.ndarray,
             fairness: np.ndarray) -> None:
        """Fold one chunk: ws (K, M), slowdown (K, M, n), fairness (K, M)."""
        ws = np.asarray(ws, dtype=np.float64)
        slowdown = np.asarray(slowdown, dtype=np.float64)
        fairness = np.asarray(fairness, dtype=np.float64)
        k, m = ws.shape
        self.mix_count += m
        self.log_ws_sum += np.log(ws).sum(axis=1)
        bins = np.clip(
            (slowdown / self.bin_width).astype(np.int64),
            0, self.hist_bins - 1)
        for ki in range(k):
            self.slowdown_hist[ki] += np.bincount(
                bins[ki].ravel(), minlength=self.hist_bins)
        self.max_slowdown = np.maximum(
            self.max_slowdown, slowdown.max(axis=(1, 2)))
        self.min_fairness = np.minimum(
            self.min_fairness, fairness.min(axis=1))

    # -------------------------------------------------------- queries #

    def geomean_ws(self) -> np.ndarray:
        with np.errstate(invalid="ignore"):
            return np.exp(self.log_ws_sum / np.maximum(self.mix_count, 1))

    def slowdown_percentile(self, q: float) -> np.ndarray:
        """Histogram-sketch percentile per manager (q in (0, 1))."""
        out = np.zeros(len(self.mix_count), dtype=np.float64)
        for ki, hist in enumerate(self.slowdown_hist):
            total = hist.sum()
            if total == 0:
                out[ki] = np.nan
                continue
            target = q * total
            cum = np.cumsum(hist)
            b = int(np.searchsorted(cum, target))
            prev = cum[b - 1] if b > 0 else 0
            frac = ((target - prev) / hist[b]) if hist[b] else 0.0
            out[ki] = (b + frac) * self.bin_width
        return out

    # ---------------------------------------------- checkpoint pytree #

    def to_tree(self) -> Dict[str, np.ndarray]:
        return {
            "mix_count": self.mix_count,
            "log_ws_sum": self.log_ws_sum,
            "slowdown_hist": self.slowdown_hist,
            "max_slowdown": self.max_slowdown,
            "min_fairness": self.min_fairness,
        }

    def load_tree(self, tree: Dict[str, np.ndarray]) -> None:
        for key, value in self.to_tree().items():
            arr = np.asarray(tree[key], dtype=value.dtype)
            if arr.shape != value.shape:
                raise CheckpointMismatchError(
                    f"aggregate {key!r} shape {arr.shape} != "
                    f"expected {value.shape}")
            setattr(self, {"mix_count": "mix_count",
                           "log_ws_sum": "log_ws_sum",
                           "slowdown_hist": "slowdown_hist",
                           "max_slowdown": "max_slowdown",
                           "min_fairness": "min_fairness"}[key], arr)


@dataclasses.dataclass
class StreamReport:
    """The deliverable of one stream run (resumed or not)."""

    manager_names: List[str]
    n_mixes: int
    mixes_covered: int
    coverage: float
    chunks: int
    quarantined: List[Tuple[int, str]]
    retries: int
    geomean_ws: Dict[str, float]
    p50_slowdown: Dict[str, float]
    p90_slowdown: Dict[str, float]
    p99_slowdown: Dict[str, float]
    max_slowdown: Dict[str, float]
    min_fairness: Dict[str, float]
    straggler_events: int
    straggler_mitigations: int
    wall_s: float
    resumed_from: Optional[int]
    aggregates: StreamAggregates

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d.pop("aggregates")
        d["quarantined"] = [[int(c), r] for c, r in self.quarantined]
        return d


@dataclasses.dataclass
class _ChunkOutcome:
    """What the worker thread hands back for one chunk."""

    status: str                       # "ok" | "dispatch_failed"
    retries: int = 0
    error: Optional[str] = None
    ws: Optional[np.ndarray] = None          # (K, M_valid)
    slowdown: Optional[np.ndarray] = None    # (K, M_valid, n)
    fairness: Optional[np.ndarray] = None    # (K, M_valid)
    finite: Optional[np.ndarray] = None      # (K, M_valid) bool


def _spec_plant(m: int, n: int, total_units: int, total_bandwidth: float):
    """The duck-typed plant ``sweep._manager_spec`` needs — shape + caps."""
    import types

    return types.SimpleNamespace(
        n_mixes=m, n_clients=n, total_cache_units=total_units,
        total_bandwidth=total_bandwidth)


def _build_specs(cfg: StreamConfig, n: int):
    """One TimelineSpec per manager at the chunk shape (built once; every
    chunk shares schedules and step-0 state, so jit stays warm)."""
    from repro.sim.sweep import _manager_spec

    plant = _spec_plant(cfg.chunk_size, n, cfg.total_cache_units,
                        cfg.total_bandwidth)
    return [_manager_spec(plant, name, cfg.total_ms, cfg.params)
            for name in cfg.manager_names]


def _chunk_metrics(ipc_stack, w_accs, base_ipc):
    """The in-trace metrics + finite-guard program (runs on device).

    ipc_stack (K, M, n) time-weighted IPC sums; w_accs (K, 1, 1);
    base_ipc (M, n).  Returns ws (K, M), slowdown (K, M, n), fairness
    (K, M) and the finite guard (K, M) — ``isfinite`` reduced over apps in
    the same program, so divergence detection costs no extra transfer and
    no host-side row scan.
    """
    import jax.numpy as jnp

    ipc = ipc_stack / w_accs
    speedup = ipc / base_ipc[None]
    ws = jnp.mean(speedup, axis=-1)
    slowdown = base_ipc[None] / ipc
    fairness = jnp.min(speedup, axis=-1) / jnp.max(speedup, axis=-1)
    finite = (jnp.isfinite(ipc).all(axis=-1)
              & jnp.isfinite(base_ipc).all(axis=-1)[None]
              & (ipc > 0.0).all(axis=-1))
    return ws, slowdown, fairness, finite


class _StreamRunner:
    """One stream execution: pipeline, fault handling, checkpointing."""

    def __init__(self, cfg: StreamConfig, plan: Optional[FaultPlan],
                 overlap: bool, sleep_fn: Callable[[float], None]):
        self.cfg = cfg
        self.plan = plan or FaultPlan()
        self.overlap = overlap
        self.sleep_fn = sleep_fn
        self.names = cfg.manager_names
        self.K = len(self.names)
        self.n = cfg.scenario.apps_per_mix
        self.specs = _build_specs(cfg, self.n)
        self.agg = StreamAggregates(self.K, cfg.hist_bins,
                                    cfg.hist_max_slowdown)
        self.quarantined: List[Tuple[int, str]] = []
        self.retries = 0
        self.cursor = 0
        self.resumed_from: Optional[int] = None
        self.watchdog = StragglerWatchdog(
            threshold=cfg.watchdog_threshold,
            warmup=cfg.watchdog_warmup)
        self._consecutive_quarantines = 0
        self._metrics_jit = None
        self.ckpt = None
        if cfg.checkpoint_dir:
            from repro.checkpoint import CheckpointManager

            self.ckpt = CheckpointManager(
                pathlib.Path(cfg.checkpoint_dir), keep=3)

    # ----------------------------------------------------- checkpoint #

    def try_resume(self) -> None:
        if self.ckpt is None:
            return
        restored = self.ckpt.restore_latest(self.agg.to_tree())
        if restored is None:
            return
        step, tree, extra = restored
        if extra.get("fingerprint") != self.cfg.fingerprint():
            raise CheckpointMismatchError(
                f"checkpoint at {self.cfg.checkpoint_dir} belongs to a "
                f"different stream (fingerprint "
                f"{extra.get('fingerprint')!r} != "
                f"{self.cfg.fingerprint()!r})")
        self.agg.load_tree(tree)
        self.cursor = int(extra["cursor"])
        self.resumed_from = self.cursor
        self.quarantined = [(int(c), str(r))
                            for c, r in extra.get("quarantined", [])]
        self.retries = int(extra.get("retries", 0))

    def checkpoint(self, next_chunk: int) -> None:
        if self.ckpt is None:
            return
        self.ckpt.save(
            next_chunk, self.agg.to_tree(),
            extra={
                "fingerprint": self.cfg.fingerprint(),
                "cursor": next_chunk,
                "quarantined": [[int(c), r] for c, r in self.quarantined],
                "retries": self.retries,
                "seed": self.cfg.seed,
            })

    # ------------------------------------------------------- pipeline #

    def _valid_rows(self, chunk_idx: int) -> int:
        start = chunk_idx * self.cfg.chunk_size
        return min(self.cfg.chunk_size, self.cfg.n_mixes - start)

    def _generate(self, chunk_idx: int) -> Dict[str, np.ndarray]:
        params = scenario_chunk(self.cfg.scenario, self.cfg.seed,
                                chunk_idx, self.cfg.chunk_size)
        params.pop("mix_indices", None)
        return params

    def _dispatch_and_fetch(self, chunk_idx: int,
                            params: Dict[str, np.ndarray]) -> _ChunkOutcome:
        """The worker-thread body: retrying dispatch, then the blocking
        fetch of the chunk's metrics.  Runs fully off the host thread in
        overlap mode so generation of the next chunk proceeds meanwhile.
        """
        cfg = self.cfg
        attempt = 0
        while True:
            try:
                self.plan.on_dispatch(chunk_idx, attempt)
                pending = timeline_jax.run_timelines_async(
                    params, self.specs,
                    total_units=cfg.total_cache_units,
                    total_bandwidth=cfg.total_bandwidth,
                    llc_extra_cycles=cfg.llc_extra_cycles,
                    min_ways=cfg.params.min_ways,
                    speedup_threshold=cfg.params.speedup_threshold,
                    min_bandwidth_allocation=(
                        cfg.params.min_bandwidth_allocation),
                    atd_decay=cfg.params.atd_decay,
                    bandwidth_delay_decay=cfg.params.bandwidth_delay_decay,
                    # Chunk c's grid buffers are donated to its program:
                    # the stream never holds two chunks' (K, M, n) grids
                    # live at once (results/dispatch count unchanged).
                    donate=True,
                )
                base = self._baseline(params)
                break
            except Exception as exc:  # noqa: BLE001 — quarantine barrier
                if attempt >= cfg.retry.max_retries:
                    return _ChunkOutcome(
                        status="dispatch_failed", retries=attempt,
                        error=f"{type(exc).__name__}: {exc}")
                self.sleep_fn(cfg.retry.delay(attempt))
                attempt += 1
                self.retries += 1

        import jax
        import jax.numpy as jnp

        with memsys_jax.x64_context():
            ipc_stack = jnp.stack(
                [d["ipc_acc"] for d in pending.device_results])
            if self.plan.poisons(chunk_idx):
                # Poison the device-resident results so the injected
                # divergence flows through the SAME in-trace finite guard
                # a genuine solver blow-up would hit.
                ipc_stack = jnp.full_like(ipc_stack, np.nan)
            if self._metrics_jit is None:
                self._metrics_jit = jax.jit(_chunk_metrics)
            w_accs = np.asarray(pending.w_accs,
                                dtype=np.float64)[:, None, None]
            record_dispatch()
            ws, slowdown, fairness, finite = self._metrics_jit(
                ipc_stack, w_accs, base)
        valid = self._valid_rows(chunk_idx)
        return _ChunkOutcome(
            status="ok", retries=attempt,
            ws=np.asarray(ws)[:, :valid],
            slowdown=np.asarray(slowdown)[:, :valid],
            fairness=np.asarray(fairness)[:, :valid],
            finite=np.asarray(finite)[:, :valid])

    def _baseline(self, params: Dict[str, np.ndarray]):
        """Shared unpartitioned baseline for this chunk (device array)."""
        cfg = self.cfg
        m = cfg.chunk_size
        units, bw = equal_share(self.n, cfg.total_cache_units,
                                cfg.total_bandwidth)
        ss = memsys_jax.evaluate(
            params,
            np.tile(units.astype(np.float64), (m, 1)),
            np.tile(bw, (m, 1)),
            np.zeros((m, self.n), dtype=bool),
            cache_partitioned=False,
            bandwidth_partitioned=False,
            total_cache_units=float(cfg.total_cache_units),
            total_bandwidth_gbps=cfg.total_bandwidth,
            llc_extra_cycles=cfg.llc_extra_cycles,
        )
        return ss.ipc

    def _quarantine(self, chunk_idx: int, reason: str) -> None:
        self.quarantined.append((chunk_idx, reason))
        self._consecutive_quarantines += 1
        if (self._consecutive_quarantines
                > self.cfg.max_consecutive_quarantines):
            raise StreamAbortedError(
                f"{self._consecutive_quarantines} consecutive chunks "
                f"quarantined (last: chunk {chunk_idx}: {reason}); the "
                f"stream is broken, not degraded — aborting instead of "
                f"reporting near-zero coverage")

    def _finish(self, chunk_idx: int, outcome: _ChunkOutcome,
                wall_s: float) -> None:
        """Fold/quarantine one fetched chunk (host thread, in order)."""
        wall_s += self.plan.straggle_seconds(chunk_idx)
        # Mitigation on a single host is log-only; counts go in the report.
        self.watchdog.observe(chunk_idx, wall_s)
        if outcome.status != "ok":
            self._quarantine(
                chunk_idx, f"dispatch_failed after "
                f"{outcome.retries} retries ({outcome.error})")
            return
        if not outcome.finite.all():
            k, m = np.argwhere(~outcome.finite)[0]
            err = NumericalDivergenceError(
                self.names[int(k)],
                chunk_idx * self.cfg.chunk_size + int(m),
                chunk_idx)
            if self.cfg.on_divergence == "raise":
                raise err
            self._quarantine(chunk_idx, str(err))
            return
        self._consecutive_quarantines = 0
        self.agg.fold(outcome.ws, outcome.slowdown, outcome.fairness)

    def run(self) -> StreamReport:
        cfg = self.cfg
        t_start = time.monotonic()
        n_chunks = cfg.n_chunks
        pool = (concurrent.futures.ThreadPoolExecutor(max_workers=1)
                if self.overlap else None)
        # Depth-2 pipeline: chunk c is SUBMITTED to the worker before
        # chunk c-1 is joined, so the fold/checkpoint of c-1 and the
        # generation of c+1 run on the main thread while the worker is
        # inside chunk c's compute/fetch.  Joins are FIFO, so aggregate
        # folds happen in chunk order and bit-parity with the serial
        # path is preserved.
        queue: List[Tuple[int, object, float]] = []
        try:
            for c in range(self.cursor, n_chunks):
                self.plan.on_chunk_start(c)
                params = self._generate(c)
                if self.overlap:
                    t0 = time.monotonic()
                    fut = pool.submit(self._dispatch_and_fetch, c, params)
                    queue.append((c, fut, t0))
                    if len(queue) > 1:
                        self._join(queue.pop(0))
                else:
                    t0 = time.monotonic()
                    outcome = self._dispatch_and_fetch(c, params)
                    self._finish(c, outcome, time.monotonic() - t0)
                    self._maybe_checkpoint(c)
            while queue:
                self._join(queue.pop(0))
        finally:
            if pool is not None:
                pool.shutdown(wait=False, cancel_futures=True)
        return self._report(time.monotonic() - t_start)

    def _join(self, pending: Tuple[int, object, float]) -> None:
        c, fut, t0 = pending
        outcome = fut.result()
        self._finish(c, outcome, time.monotonic() - t0)
        self._maybe_checkpoint(c)

    def _maybe_checkpoint(self, chunk_idx: int) -> None:
        done = chunk_idx + 1
        if self.ckpt is not None and (done % self.cfg.checkpoint_every == 0
                                      or done == self.cfg.n_chunks):
            self.checkpoint(done)

    def _report(self, wall_s: float) -> StreamReport:
        cfg = self.cfg
        quarantined_mixes = sum(self._valid_rows(c)
                                for c, _ in self.quarantined)
        covered = cfg.n_mixes - quarantined_mixes
        per = {}
        for label, arr in (
                ("geomean_ws", self.agg.geomean_ws()),
                ("p50", self.agg.slowdown_percentile(0.50)),
                ("p90", self.agg.slowdown_percentile(0.90)),
                ("p99", self.agg.slowdown_percentile(0.99)),
                ("max_slowdown", self.agg.max_slowdown),
                ("min_fairness", self.agg.min_fairness)):
            per[label] = {name: round(float(v), 6)
                          for name, v in zip(self.names, arr)}
        return StreamReport(
            manager_names=list(self.names),
            n_mixes=cfg.n_mixes,
            mixes_covered=covered,
            coverage=covered / cfg.n_mixes,
            chunks=cfg.n_chunks,
            quarantined=list(self.quarantined),
            retries=self.retries,
            geomean_ws=per["geomean_ws"],
            p50_slowdown=per["p50"],
            p90_slowdown=per["p90"],
            p99_slowdown=per["p99"],
            max_slowdown=per["max_slowdown"],
            min_fairness=per["min_fairness"],
            straggler_events=len(self.watchdog.events),
            straggler_mitigations=self.watchdog.mitigations,
            wall_s=wall_s,
            resumed_from=self.resumed_from,
            aggregates=self.agg,
        )


def run_stream(
    cfg: StreamConfig,
    *,
    fault_plan: Optional[FaultPlan] = None,
    resume: bool = False,
    overlap: bool = True,
    sleep_fn: Callable[[float], None] = time.sleep,
) -> StreamReport:
    """Run (or resume) a streaming sweep.

    Args:
      cfg: stream shape + robustness policy.
      fault_plan: injected faults (tests/smokes); ``None`` = healthy run.
      resume: restore aggregates/cursor/quarantine from
        ``cfg.checkpoint_dir``'s latest complete checkpoint; a fresh run
        otherwise (an existing mismatched checkpoint raises
        :class:`CheckpointMismatchError` rather than being overwritten
        with data from a different stream).
      overlap: double-buffer (device computes chunk c while the host
        generates chunk c+1); ``False`` = serial chunk dispatch, the
        bench's comparison baseline.
      sleep_fn: injected for backoff in tests (defaults to real sleep).

    Returns a :class:`StreamReport`; ``report.aggregates`` carries the raw
    sketches for bit-exact comparison.
    """
    runner = _StreamRunner(cfg, fault_plan, overlap, sleep_fn)
    if resume:
        runner.try_resume()
    return runner.run()


__all__ = [
    "CheckpointMismatchError", "NumericalDivergenceError", "RetryPolicy",
    "StreamAbortedError", "StreamAggregates", "StreamConfig",
    "StreamReport", "run_stream",
]
