"""Fused Fig. 8 timeline: one jitted device program per (manager, timeline).

PR 2 made every timeline *segment* a device call; this module removes the
remaining host loop.  A manager's entire Fig. 8 decision timeline — cache
reallocation (batched Lookahead greedy), Algorithm-1 bandwidth partitioning
and Algorithm-2 prefetch throttling — compiles into a single
``jax.lax.scan`` over a precomputed static segment table, carrying
(cache units, bandwidth, prefetch mask, friendly mask, ATD accumulators,
bandwidth-delay EMA, IPC accumulator, sampled off-IPC) as scan state.  A
full Table-3 sweep is then **one device program per (manager, timeline)**:
inputs transfer once, results transfer once, zero per-segment host
round-trips (counter: :func:`repro.core.device_dispatches`).

Segment table
    :func:`segment_table` encodes a :func:`~repro.core.fig8_schedule`
    segment list as (kind, duration, reconfigure?) arrays.  Zero-duration
    ``reconfigure`` boundaries are folded into the *following* segment as a
    flag (a trailing boundary becomes a zero-duration ``NOOP`` row), so
    every scan step is: maybe-reconfigure, then run one interval of the
    model and update controller state elementwise by segment kind.

Controllers in the traced region
    The cache step calls the PR 2 batched greedy
    (:func:`repro.core.cache_controller_jax.lookahead_traced` /
    ``lookahead_masked_traced`` for the CPpf variant); bandwidth uses
    :func:`repro.core.allocate_bandwidth_jax` and prefetch
    :func:`repro.core.throttle_decision_jax` — all batched over mixes and
    ``param_grid`` rows, with the ``min_allocation * n > total``
    feasibility checks hoisted out of the traced region (validated once on
    the host per program).

Sharding
    The leading mix axis is sharded across devices with
    :func:`repro.distributed.shard_rows` (``shard_map`` + ``make_mesh``)
    whenever more than one device is visible — force N host devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to test
    locally.  Rows are padded to a multiple of the device count and the
    padding is sliced off after the program returns, so results are
    identical on 1 and N devices (``tests/test_timeline_fused.py``).

Parity contract: fused trajectories match the PR 2 segment-loop path (and
therefore the scalar numpy reference within its 1e-5 model tolerance) —
bit-identical controller decisions away from knife-edges, enforced by
``tests/test_timeline_fused.py``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import distributed
from repro.core.bandwidth_controller import (
    allocate_bandwidth_jax,
    check_bandwidth_floor,
)
from repro.core.cache_controller_jax import (
    lookahead_masked_traced,
    lookahead_traced,
)
from repro.core.coordinator import ScheduleSegment
from repro.core.dispatch import record_dispatch
from repro.core.prefetch_controller import throttle_decision_jax
from repro.sim import memsys_jax
from repro.sim.apps import AppArrays
from repro.sim.memsys import FIXED_POINT_ITERS

#: Segment kinds of the fused table.  ``NOOP`` only appears as the carrier
#: of a trailing reconfigure boundary (CPpf reallocates after its final
#: interval); its zero-duration model evaluation never accumulates.
SAMPLE_OFF, SAMPLE_ON, RUN, NOOP = 0, 1, 2, 3

_KIND_CODES = {"sample_off": SAMPLE_OFF, "sample_on": SAMPLE_ON, "run": RUN}


def segment_table(
    schedule: Sequence[ScheduleSegment],
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Encode a segment list as (kinds, durations_ms, reconfigure_flags).

    ``reconfigure`` boundaries are zero-duration in the schedule; folding
    each into the next segment's flag keeps the scan length equal to the
    number of *intervals actually executed* and lets one scan step be
    "maybe reconfigure, then run the segment".
    """
    rows: List[Tuple[int, float, bool]] = []
    pending = False
    for seg in schedule:
        if seg.kind == "reconfigure":
            pending = True
            continue
        rows.append((_KIND_CODES[seg.kind], seg.duration_ms, pending))
        pending = False
    if pending:
        rows.append((NOOP, 0.0, True))
    if not rows:
        raise ValueError("cannot fuse an empty schedule")
    kinds = np.array([r[0] for r in rows], dtype=np.int32)
    durations = np.array([r[1] for r in rows], dtype=np.float64)
    reconf = np.array([r[2] for r in rows], dtype=bool)
    return kinds, durations, reconf


def cppf_schedule(total_ms: float, params) -> List[ScheduleSegment]:
    """CPpf's timeline as data (mirrors ``sweep._run_cppf_batched``).

    An A/B friendliness probe at equal partitioning (excluded from the
    time-weighted mean), then per reconfiguration interval: run, then
    reallocate — including after the final interval, which is why the
    segment list *ends* with a reconfigure boundary.
    """
    p = params.prefetch_sampling_period_ms
    segments = [ScheduleSegment("sample_off", p),
                ScheduleSegment("sample_on", p)]
    t = 0.0
    while t < total_ms - 1e-9:
        dt = min(params.reconfiguration_interval_ms, total_ms - t)
        segments.append(ScheduleSegment("run", dt))
        segments.append(ScheduleSegment("reconfigure", 0.0))
        t += dt
    return segments


@dataclasses.dataclass
class TimelineResult:
    """Final state of one fused (manager, timeline) program over M mixes."""

    ipc_acc: np.ndarray        # (M, n) time-weighted IPC sum
    w_acc: float               # accumulated weight (ms) — static per table
    cache_units: np.ndarray    # (M, n) int64 final allocation
    bandwidth: np.ndarray      # (M, n) final bandwidth split
    prefetch_on: np.ndarray    # (M, n) bool final prefetcher setting
    active: np.ndarray         # (M, n) bool CPpf competing mask (fig8: all)

    def mean_ipc(self) -> np.ndarray:
        return self.ipc_acc / max(self.w_acc, 1e-12)


@functools.lru_cache(maxsize=None)
def _compiled_timeline(
    variant: str,
    cache_dynamic: bool,
    bandwidth_dynamic: bool,
    cache_partitioned: bool,
    bandwidth_partitioned: bool,
    has_sampling: bool,
    total_units: int,
    iters: int,
    n_shards: int,
):
    """Build the jitted (optionally shard_mapped) timeline executor.

    Cached per static configuration so repeated sweeps reuse both the
    Python wrapper and XLA's compilation cache; jit retraces on new array
    shapes (different M, n or segment count) as usual.  Controller state
    that a manager's modes can never read (ATD counters without a dynamic
    cache, the delay EMA without dynamic bandwidth, the A/B machinery
    without sampling segments) is statically dropped from the step.
    """
    f64 = jnp.float64
    total_cache_f = float(total_units)
    track_atd = cache_dynamic  # CPpf is always cache-dynamic

    def worker(sharded, replicated):
        p = {k: sharded["p_" + k] for k in memsys_jax.PARAM_FIELDS}
        min_ways = sharded["min_ways"]                  # (M,) int32
        thr = sharded["speedup_threshold"]              # (M, 1)
        min_bw = sharded["min_bandwidth_allocation"]    # (M, 1)
        atd_decay = sharded["atd_decay"]                # (M, 1, 1)
        bw_decay = sharded["bandwidth_delay_decay"]     # (M, 1)
        total_bw = replicated["total_bandwidth"]
        llc_extra = replicated["llc_extra_cycles"]

        def reconfigure(operand):
            """Boundary step: cache -> bandwidth (paper priority order)."""
            units, bw, atd, bw_acc, active = operand
            if cache_dynamic:
                if variant == "cppf":
                    fresh = lookahead_masked_traced(
                        atd, min_ways, active, total_units)
                else:
                    fresh = lookahead_traced(atd, min_ways, total_units)
                units = fresh.astype(units.dtype)
            atd = atd * atd_decay
            if bandwidth_dynamic:
                bw = allocate_bandwidth_jax(bw_acc, total_bw, min_bw)
            return units, bw, atd

        def step(carry, seg):
            kind, dt, reconf = seg
            units, bw, pf, active, atd, bw_acc, ipc_acc, off_ipc = carry
            units, bw, atd = jax.lax.cond(
                reconf, reconfigure,
                lambda op: (op[0], op[1], op[2]),
                (units, bw, atd, bw_acc, active))

            # The A/B samples force the prefetcher off/on for everyone;
            # other segments run the current per-client setting.
            if has_sampling:
                pf_f = jnp.where(kind == SAMPLE_OFF, 0.0,
                                 jnp.where(kind == SAMPLE_ON, 1.0,
                                           pf.astype(f64)))
            else:
                pf_f = pf.astype(f64)
            out = memsys_jax._evaluate_jit(
                p, units.astype(f64), bw, pf_f,
                jnp.asarray(total_cache_f, f64), total_bw, llc_extra,
                cache_partitioned=cache_partitioned,
                bandwidth_partitioned=bandwidth_partitioned,
                iters=iters)
            ipc, q_ns = out[0], out[1]

            # fig8 accumulates every executed segment (samples included);
            # CPpf's probe intervals are outside the measured window.
            if variant == "cppf":
                acc_dt = jnp.where(kind == RUN, dt, 0.0)
            else:
                acc_dt = dt
            if track_atd:
                curves = memsys_jax._utility_curves_jit(
                    p, pf_f, ipc, jnp.asarray(1.0, f64),
                    total_units=total_units)
                atd = atd + curves * acc_dt
            ipc_acc = ipc_acc + ipc * acc_dt
            if bandwidth_dynamic:
                bw_acc = bw_decay * bw_acc + q_ns * acc_dt

            if has_sampling:
                decision = throttle_decision_jax(ipc, off_ipc, thr)
                if variant == "cppf":
                    active = jnp.where(kind == SAMPLE_ON, ~decision, active)
                else:
                    pf = jnp.where(kind == SAMPLE_ON, decision, pf)
                off_ipc = jnp.where(kind == SAMPLE_OFF, ipc, off_ipc)
            return ((units, bw, pf, active, atd, bw_acc, ipc_acc, off_ipc),
                    None)

        carry0 = (sharded["units0"], sharded["bw0"], sharded["pf0"],
                  sharded["active0"], sharded["atd0"], sharded["bw_acc0"],
                  sharded["ipc_acc0"], sharded["off_ipc0"])
        xs = (replicated["kinds"], replicated["durations"],
              replicated["reconf"])
        carry, _ = jax.lax.scan(step, carry0, xs)
        units, bw, pf, active, _atd, _bw_acc, ipc_acc, _off = carry
        return {"ipc_acc": ipc_acc, "cache_units": units, "bandwidth": bw,
                "prefetch_on": pf, "active": active}

    if n_shards > 1:
        worker = distributed.shard_rows(worker, n_shards)
    return jax.jit(worker)


def _per_row(value, shape: Tuple[int, ...], dtype) -> np.ndarray:
    """Materialize a scalar-or-per-row tunable at its full batch shape.

    Per-row tunables must carry the leading mix axis explicitly so
    ``shard_map`` can split them alongside the model state.
    """
    arr = np.asarray(value, dtype=dtype)
    arr = arr.reshape(arr.shape + (1,) * (len(shape) - arr.ndim))
    return np.ascontiguousarray(np.broadcast_to(arr, shape))


def run_timeline(
    apps: Union[AppArrays, dict],
    schedule: Sequence[ScheduleSegment],
    *,
    variant: str = "fig8",
    init_units: np.ndarray,
    init_bandwidth: np.ndarray,
    init_prefetch: np.ndarray,
    cache_dynamic: bool,
    bandwidth_dynamic: bool,
    cache_partitioned: bool,
    bandwidth_partitioned: bool,
    total_units: int,
    total_bandwidth: float,
    llc_extra_cycles: float = 0.0,
    min_ways=4,
    speedup_threshold=1.05,
    min_bandwidth_allocation=1.0,
    atd_decay=0.5,
    bandwidth_delay_decay=0.5,
    iters: int = FIXED_POINT_ITERS,
    shard: Optional[bool] = None,
) -> TimelineResult:
    """Execute one manager's whole timeline as ONE device program.

    Args:
      apps: mix-stacked application profiles, every field ``(M, n)``.
      schedule: the Fig. 8 segment list (or :func:`cppf_schedule`).
      variant: ``"fig8"`` (coordinator semantics) or ``"cppf"``.
      init_units / init_bandwidth / init_prefetch: ``(M, n)`` step-0 state.
      cache_dynamic / bandwidth_dynamic: whether the boundary controllers
        fire (static — Table-3 manager modes).
      min_ways / speedup_threshold / min_bandwidth_allocation / atd_decay /
        bandwidth_delay_decay: scalars or per-row arrays (``param_grid``).
      shard: ``None`` auto-shards the mix axis over all visible devices
        (padding M as needed); ``False`` forces single-device execution.

    Returns:
      :class:`TimelineResult` of host arrays — the only device->host
      transfer of the whole timeline.
    """
    if variant not in ("fig8", "cppf"):
        raise ValueError(f"unknown timeline variant {variant!r}")
    params = memsys_jax.app_params(apps)
    shape = np.asarray(params["cpi_base"]).shape
    if len(shape) != 2:
        raise ValueError(f"apps must be mix-stacked (M, n); got {shape}")
    M, n = shape

    # Feasibility checks hoisted out of the traced region (the numpy
    # controllers validate per call; the fused program validates once).
    if bandwidth_dynamic:
        check_bandwidth_floor(min_bandwidth_allocation, n, total_bandwidth)
    if cache_dynamic and np.any(
            np.asarray(min_ways, dtype=np.int64) * n > total_units):
        raise ValueError("min_ways * n exceeds capacity")

    kinds, durations, reconf = segment_table(schedule)
    if variant == "cppf":
        w_acc = float(durations[kinds == RUN].sum())
    else:
        w_acc = float(durations.sum())

    sharded = {"p_" + k: np.ascontiguousarray(
        np.broadcast_to(np.asarray(v, np.float64), (M, n)))
        for k, v in params.items()}
    sharded.update(
        units0=np.asarray(init_units, dtype=np.int32),
        bw0=np.asarray(init_bandwidth, dtype=np.float64),
        pf0=np.asarray(init_prefetch, dtype=bool),
        active0=np.ones((M, n), dtype=bool),
        atd0=np.zeros((M, n, total_units + 1), dtype=np.float64),
        bw_acc0=np.zeros((M, n), dtype=np.float64),
        ipc_acc0=np.zeros((M, n), dtype=np.float64),
        off_ipc0=np.zeros((M, n), dtype=np.float64),
        min_ways=_per_row(min_ways, (M,), np.int32),
        speedup_threshold=_per_row(speedup_threshold, (M, 1), np.float64),
        min_bandwidth_allocation=_per_row(
            min_bandwidth_allocation, (M, 1), np.float64),
        atd_decay=_per_row(atd_decay, (M, 1, 1), np.float64),
        bandwidth_delay_decay=_per_row(
            bandwidth_delay_decay, (M, 1), np.float64),
    )
    replicated = {
        "kinds": kinds,
        "durations": durations,
        "reconf": reconf,
        "total_bandwidth": np.float64(total_bandwidth),
        "llc_extra_cycles": np.float64(llc_extra_cycles),
    }

    n_shards = 1 if shard is False else distributed.row_shard_count(M)
    m_pad = -(-M // n_shards) * n_shards
    if m_pad != M:
        # Pad with copies of the last row; sliced off after the program.
        sharded = {
            k: np.concatenate(
                [v, np.repeat(v[-1:], m_pad - M, axis=0)], axis=0)
            for k, v in sharded.items()
        }

    has_sampling = bool(np.isin(kinds, (SAMPLE_OFF, SAMPLE_ON)).any())
    fn = _compiled_timeline(
        variant, bool(cache_dynamic), bool(bandwidth_dynamic),
        bool(cache_partitioned), bool(bandwidth_partitioned),
        has_sampling, int(total_units), int(iters), n_shards)
    record_dispatch()
    with memsys_jax.x64_context():
        out = {k: np.asarray(v)[:M] for k, v in fn(sharded,
                                                   replicated).items()}
    return TimelineResult(
        ipc_acc=out["ipc_acc"],
        w_acc=w_acc,
        cache_units=out["cache_units"].astype(np.int64),
        bandwidth=out["bandwidth"],
        prefetch_on=out["prefetch_on"],
        active=out["active"],
    )
