"""Fused Fig. 8 timelines: ONE jitted device program for a whole manager set.

PR 2 made every timeline *segment* a device call; PR 3 removed the
per-segment host loop (one program per (manager, timeline)); this revision
removes the per-manager host loop too.  Every Table-3 manager keeps its own
segment table, the tables stack along a new leading *manager* axis (shorter
timelines pad with frozen ``NOOP`` rows), and the per-manager knob flags —
``cache_dynamic``, ``bandwidth_dynamic``, ``cache_partitioned``,
``bandwidth_partitioned``, the CPpf variant mask — become traced ``(K,)``
arrays instead of static trace constants.  A full Table-3 sweep is then
**one device program total** (plus the shared baseline evaluation): inputs
transfer once, results transfer once, zero per-manager or per-segment host
round-trips (counter: :func:`repro.core.device_dispatches`).

Stacking is exact, not approximate
    Batch rows never interact — the model, the batched Lookahead greedy,
    Algorithm-1 bandwidth partitioning and Algorithm-2 throttling are all
    row-independent — so manager k executing rows ``0..S_k-1`` of the
    stacked table reproduces its standalone fused trajectory bit-for-bit;
    rows past ``S_k`` are ``NOOP``: zero accumulation weight, no
    reconfigure flag, no controller update (``x + v*0`` and masked
    ``where`` updates are bitwise no-ops).  :func:`run_timeline` (one
    manager) is literally the K=1 case of :func:`run_timelines`, and
    ``tests/test_timeline_fused.py`` pins stacked == per-manager for every
    Table-3 manager on 1 and 8 forced host devices.

Segment tables
    :func:`segment_table` encodes a :func:`~repro.core.fig8_schedule`
    segment list as (kind, duration, reconfigure?) arrays; zero-duration
    ``reconfigure`` boundaries fold into the *following* segment's flag (a
    trailing boundary becomes a zero-duration ``NOOP`` row).
    :func:`stack_tables` right-pads the per-manager tables to the longest
    and stacks them ``(K, S)``.  Each scan step is: maybe-reconfigure
    (per-manager flag), run one interval of the model, update controller
    state elementwise by per-manager segment kind.

Controllers in the traced region
    The cache step calls the PR 2 batched greedy
    (:mod:`repro.core.cache_controller_jax`) through the masked entry
    point — non-CPpf rows pass an all-active mask, which reduces to the
    plain Lookahead exactly, and rows not reconfiguring at this step pass
    an all-inactive mask, which retires them from the greedy's while_loop
    after a single trip; bandwidth uses
    :func:`repro.core.allocate_bandwidth_jax` and prefetch
    :func:`repro.core.throttle_decision_jax`, with the ``min_allocation *
    n > total`` feasibility checks hoisted out of the traced region.
    The interval model runs through
    :func:`repro.sim.memsys_jax._evaluate_rowflags` so each manager row
    gets its own partitioned/unpartitioned regime.

Sharding
    The (manager, mix) grid is sharded across devices with
    :func:`repro.distributed.shard_grid` (2-D ``make_mesh`` +
    ``shard_map``): manager groups spread over the first mesh axis, mixes
    over the second, so different managers' timelines execute on
    different devices concurrently.  Shard counts come from
    :func:`repro.distributed.grid_shard_counts` (clamped per axis, most
    balanced factorization); both axes pad by replicating their last row
    and the padding is sliced off after the program returns, so results
    are identical on 1 and N devices.  Force N host devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to test
    locally.

Parity contract: fused trajectories match the PR 2 segment-loop path (and
therefore the scalar numpy reference within its 1e-5 model tolerance) —
bit-identical controller decisions away from knife-edges, enforced by
``tests/test_timeline_fused.py``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import distributed
from repro.core.bandwidth_controller import (
    allocate_bandwidth_jax,
    check_bandwidth_floor,
)
from repro.core.cache_controller_jax import lookahead_masked_traced
from repro.core.coordinator import ScheduleSegment
from repro.core.dispatch import record_dispatch
from repro.core.prefetch_controller import throttle_decision_jax
from repro.sim import memsys_jax, policies
from repro.sim.apps import AppArrays
from repro.sim.memsys import FIXED_POINT_ITERS, FREQ_GHZ

#: Segment kinds of the fused table.  ``NOOP`` rows freeze a manager: the
#: zero-duration model evaluation never accumulates and no controller
#: fires.  They appear as the carrier of a trailing reconfigure boundary
#: (CPpf reallocates after its final interval) and as right-padding when
#: managers with shorter timelines stack against longer ones.
SAMPLE_OFF, SAMPLE_ON, RUN, NOOP = 0, 1, 2, 3

_KIND_CODES = {"sample_off": SAMPLE_OFF, "sample_on": SAMPLE_ON, "run": RUN}

#: Grid leaves that are scan CARRY state: each has exactly one output of
#: identical shape/dtype (units0 -> cache_units, bw0 -> bandwidth, pf0 ->
#: prefetch_on, active0 -> active), so a ``donate=True`` dispatch hands
#: precisely these buffers to XLA for in-place reuse — every donation is
#: consumed, none wasted (no "unusable donation" lowering warnings).
_CARRY_KEYS = ("units0", "bw0", "pf0", "active0")


def segment_table(
    schedule: Sequence[ScheduleSegment],
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Encode a segment list as (kinds, durations_ms, reconfigure_flags).

    ``reconfigure`` boundaries are zero-duration in the schedule; folding
    each into the next segment's flag keeps the scan length equal to the
    number of *intervals actually executed* and lets one scan step be
    "maybe reconfigure, then run the segment".
    """
    rows: List[Tuple[int, float, bool]] = []
    pending = False
    for seg in schedule:
        if seg.kind == "reconfigure":
            pending = True
            continue
        rows.append((_KIND_CODES[seg.kind], seg.duration_ms, pending))
        pending = False
    if pending:
        rows.append((NOOP, 0.0, True))
    if not rows:
        raise ValueError("cannot fuse an empty schedule")
    kinds = np.array([r[0] for r in rows], dtype=np.int32)
    durations = np.array([r[1] for r in rows], dtype=np.float64)
    reconf = np.array([r[2] for r in rows], dtype=bool)
    return kinds, durations, reconf


def cppf_schedule(total_ms: float, params) -> List[ScheduleSegment]:
    """CPpf's timeline as data (mirrors ``sweep._run_cppf_batched``).

    An A/B friendliness probe at equal partitioning (excluded from the
    time-weighted mean), then per reconfiguration interval: run, then
    reallocate — including after the final interval, which is why the
    segment list *ends* with a reconfigure boundary.
    """
    p = params.prefetch_sampling_period_ms
    segments = [ScheduleSegment("sample_off", p),
                ScheduleSegment("sample_on", p)]
    t = 0.0
    while t < total_ms - 1e-9:
        dt = min(params.reconfiguration_interval_ms, total_ms - t)
        segments.append(ScheduleSegment("run", dt))
        segments.append(ScheduleSegment("reconfigure", 0.0))
        t += dt
    return segments


@dataclasses.dataclass
class TimelineSpec:
    """One manager's timeline + knobs inside a stacked program.

    ``init_units`` / ``init_bandwidth`` / ``init_prefetch`` are the
    ``(M, n)`` step-0 state; the booleans are the Table-3 mode flags that
    used to be static per-program trace constants and now ride the
    manager axis as data.

    ``cache_policy`` / ``bw_policy`` select the family's boundary
    allocator branch from the registry's ``lax.switch`` tables
    (:data:`repro.sim.policies.CACHE_POLICY_NAMES` /
    :data:`~repro.sim.policies.BW_POLICY_NAMES`; 0 = the classic
    Lookahead / Algorithm-1 pair).  ``bandwidth_banks > 1`` evaluates the
    row under the banked-token memory regime.  ``qos_bound`` /
    ``qos_gain`` parameterize the QoS branch (ignored elsewhere).
    """

    schedule: Sequence[ScheduleSegment]
    variant: str                       # "fig8" | "cppf"
    cache_dynamic: bool
    bandwidth_dynamic: bool
    cache_partitioned: bool
    bandwidth_partitioned: bool
    init_units: np.ndarray
    init_bandwidth: np.ndarray
    init_prefetch: np.ndarray
    name: str = ""
    cache_policy: int = policies.CACHE_LOOKAHEAD
    bw_policy: int = policies.BW_ALG1
    bandwidth_banks: int = 1
    qos_bound: float = policies.QOS_SLOWDOWN_BOUND
    qos_gain: float = policies.QOS_VIOLATION_GAIN

    def __post_init__(self):
        if self.variant not in ("fig8", "cppf"):
            raise ValueError(f"unknown timeline variant {self.variant!r}")
        if not 0 <= self.cache_policy < len(policies.CACHE_POLICY_NAMES):
            raise ValueError(
                f"cache_policy {self.cache_policy} has no traced branch "
                f"(table: {policies.CACHE_POLICY_NAMES})")
        if not 0 <= self.bw_policy < len(policies.BW_POLICY_NAMES):
            raise ValueError(
                f"bw_policy {self.bw_policy} has no traced branch "
                f"(table: {policies.BW_POLICY_NAMES})")
        if self.bandwidth_banks < 1:
            raise ValueError("bandwidth_banks must be >= 1")
        if (self.cache_policy or self.bw_policy) and not (
                self.cache_dynamic and self.bandwidth_dynamic):
            raise ValueError(
                "policy-branch rows must be cache_dynamic and "
                "bandwidth_dynamic (the branch fires at reconfigure "
                "boundaries gated by those flags)")
        if self.cache_policy != self.bw_policy:
            raise ValueError(
                "cache_policy and bw_policy must select the same branch: "
                "a boundary branch allocates both resources from the same "
                "signals (register a combined branch for mixed pairs)")


def stack_tables(
    tables: Sequence[Tuple[np.ndarray, np.ndarray, np.ndarray]],
    accumulate_kinds: Sequence[Optional[int]],
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stack per-manager segment tables into (K, S) arrays.

    Any order-preserving injection of a manager's rows into the unified
    slot axis is exact: batch rows never interact, and the frozen ``NOOP``
    slots between a manager's rows are bitwise no-ops for its scan state.
    This placement exploits that freedom twice:

    * shorter tables right-pad with ``NOOP`` slots (zero duration, no
      reconfigure);
    * reconfigure-carrying rows snap onto the *longest* table's
      reconfigure slots whenever the ordering allows, so the stacked
      program fires its (batch-wide) Lookahead greedy at as few slots as
      possible — e.g. the Table-3 set's non-sampling managers and CPpf
      reallocate on the same slots as the sampling managers instead of
      interleaving 1.7x more boundary steps.

    ``accumulate_kinds[k]`` restricts manager k's accumulation weight to
    one segment kind (CPpf's probe intervals are outside the measured
    window: only ``RUN`` accumulates); ``None`` accumulates every row.
    """
    lens = [len(t[0]) for t in tables]
    s_max = max(lens)
    host_reconf = np.flatnonzero(tables[int(np.argmax(lens))][2])
    K = len(tables)
    kinds = np.full((K, s_max), NOOP, dtype=np.int32)
    acc = np.zeros((K, s_max), dtype=np.float64)
    reconf = np.zeros((K, s_max), dtype=bool)
    for k, ((kk, dd, rr), only) in enumerate(zip(tables, accumulate_kinds)):
        L = len(kk)
        s = 0
        for j in range(L):
            sj = s
            if rr[j]:
                # Snap to the next shared reconfigure slot if one fits
                # before the remaining rows run out of room.
                cand = host_reconf[(host_reconf >= s)
                                   & (host_reconf <= s_max - (L - j))]
                if cand.size:
                    sj = int(cand[0])
            kinds[k, sj] = kk[j]
            acc[k, sj] = (dd[j] if only is None or kk[j] == only else 0.0)
            reconf[k, sj] = rr[j]
            s = sj + 1
    return kinds, acc, reconf


@dataclasses.dataclass
class TimelineResult:
    """Final state of one manager's fused timeline over M mixes."""

    ipc_acc: np.ndarray        # (M, n) time-weighted IPC sum
    w_acc: float               # accumulated weight (ms) — static per table
    cache_units: np.ndarray    # (M, n) int64 final allocation
    bandwidth: np.ndarray      # (M, n) final bandwidth split
    prefetch_on: np.ndarray    # (M, n) bool final prefetcher setting
    active: np.ndarray         # (M, n) bool CPpf competing mask (fig8: all)

    def mean_ipc(self) -> np.ndarray:
        return self.ipc_acc / max(self.w_acc, 1e-12)


def _make_worker(
    has_sampling: bool,
    any_cache_dynamic: bool,
    any_bandwidth_dynamic: bool,
    max_concurrent_realloc: int,
    total_units: int,
    iters: int,
    any_policy: bool = False,
    max_banks: int = 1,
):
    """Build one stacked-timeline worker for a (sub)set of managers.

    Manager knobs are *traced* ``(K,)`` arrays, so e.g. every all-static
    manager subset shares one compilation; only controller machinery no
    manager in the batch can ever reach (ATD counters without a dynamic
    cache, the delay EMA without dynamic bandwidth, the A/B sampling
    state) is statically dropped from the step.  The bucketed executor
    (:func:`_compiled_buckets`) instantiates one worker per
    segment-length bucket, which is how a bucket of fully-static managers
    sheds the sampling and ATD machinery entirely.

    ``any_policy`` (some manager uses a non-default registry branch)
    switches the boundary step to dispatch each reconfiguring manager's
    block through the registry ``lax.switch`` tables and adds the
    slowdown-reference carries the QoS branch consumes; ``max_banks``
    is the static bank-axis width of the banked-token model (1 = flat).
    Both default off, so every pre-registry call site compiles the exact
    program it used to.
    """
    f64 = jnp.float64
    total_cache_f = float(total_units)

    def worker(grid, mgr, replicated):
        # The whole scan runs in FLATTENED (K*M, ...) row form: XLA CPU's
        # codegen for the model's axis(-1) reductions is bit-stable across
        # 2-D row counts but not across 3-D leading shapes, and the
        # stacked-vs-per-manager bit-parity contract rides on that
        # (``tests/test_timeline_fused.py``).  The (K, M) structure only
        # reappears on the outputs so shard_map can split both mesh axes.
        K, M, n = grid["p_cpi_base"].shape
        B = K * M

        def rows(a):
            return a.reshape((B,) + a.shape[2:])

        p = {k: rows(grid["p_" + k])
             for k in memsys_jax.PARAM_FIELDS}       # (B, n)
        min_ways = rows(grid["min_ways"])            # (B,) int32
        thr = rows(grid["speedup_threshold"])        # (B, 1)
        min_bw = rows(grid["min_bandwidth_allocation"])
        atd_decay = rows(grid["atd_decay"])          # (B, 1, 1)
        bw_decay = rows(grid["bandwidth_delay_decay"])
        total_bw = replicated["total_bandwidth"]
        llc_extra = replicated["llc_extra_cycles"]

        # Per-manager knob flags expanded to per-row (B, 1) masks.
        def per_row(flag):
            return jnp.repeat(flag, M)[:, None]

        cache_dyn_k = mgr["cache_dynamic"]                 # (K,)
        bw_dyn = per_row(mgr["bandwidth_dynamic"])
        cache_part = per_row(mgr["cache_partitioned"])
        bw_part = per_row(mgr["bandwidth_partitioned"])
        is_cppf = per_row(mgr["is_cppf"])
        if any_policy:
            cache_pol_k = mgr["cache_policy"]              # (K,) int32
            qos_bound = per_row(mgr["qos_bound"])          # (B, 1)
            qos_gain = per_row(mgr["qos_gain"])
        banks_row = (per_row(mgr["bandwidth_banks"])
                     if max_banks > 1 else None)           # (B, 1) f64

        if any_cache_dynamic:
            # The ATD is a LINEAR functional of the per-step hit curves,
            # and the hit curves take only two values per client over the
            # whole timeline (prefetch on / off — ``pf`` is always exactly
            # 0.0 or 1.0).  So instead of accumulating a (B, n, U+1) ATD
            # grid every step, the scan carries two (B, n) weight
            # accumulators — the decayed kilo-instruction mass observed
            # with the prefetcher off resp. on — and the full ATD grid
            # ``hits_off * w_off + hits_on * w_on`` materializes only at
            # reconfigure boundaries, right where the Lookahead greedy
            # consumes it.  The exp-heavy ``mpki_curve`` grids precompute
            # once per program.  (The per-step accumulation used to be
            # ~70% of a Table-3 sweep's wall time.)
            u_pts = jnp.arange(total_units + 1, dtype=f64)
            pc = {k: v[..., :, None] for k, v in p.items()}  # (B, n, 1)

            def hits_for(pf_const):
                units_g = u_pts - pc["pf_pollution"] * pf_const
                m_g = memsys_jax.mpki_curve(pc, units_g)
                eff_miss = m_g * (1.0 - pc["pf_cov"] * pf_const)
                return jnp.maximum(pc["apki"] - eff_miss, 0.0)

            hits_off = hits_for(jnp.asarray(0.0, f64))
            hits_on = hits_for(jnp.asarray(1.0, f64))

        def reconfigure(operand):
            """Boundary step: cache -> bandwidth (paper priority order).

            Cache reallocation gathers every reconfiguring manager's M-row
            block (traced ``dynamic_slice``; up to the static
            ``max_concurrent_realloc`` bound), materializes their ATD
            grids from the two weight coefficients, and runs ONE
            concatenated ``(G*M, n)`` masked greedy instead of G
            sequential mini-greedies: the while_loop pays the *max* trip
            count over the blocks, not the sum — on CPU the trips are
            tiny-op bound, so batching the boundary refresh is the big
            win.  Exact because the greedy is row-independent and its only
            float reductions are max/argmax (order-insensitive), so
            results are bit-invariant to the batch row count — unlike the
            model eval, which is why the scan itself stays flattened 2-D.
            Slot alignment (:func:`stack_tables`) keeps the number of
            boundary slots minimal; managers not reallocating here are
            untouched.
            """
            if any_policy:
                (units, bw, w_off, w_on, bw_acc, active, do_r, realloc_k,
                 ref_ipc, prev_ipc) = operand
            else:
                units, bw, w_off, w_on, bw_acc, active, do_r, realloc_k \
                    = operand
            if any_bandwidth_dynamic:
                # Algorithm-1 bandwidth update first: it reads none of the
                # cache state, and running it before the cache gather lets
                # the registry branches below see the post-update array —
                # identity rows keep it bit-for-bit, policy rows override
                # their own block from the same boundary signals.
                bw = jnp.where(do_r & bw_dyn,
                               allocate_bandwidth_jax(bw_acc, total_bw,
                                                      min_bw),
                               bw)
            # Under manager-axis sharding the global concurrency bound
            # can exceed this shard's manager count — clamp.
            G = min(max_concurrent_realloc, K)
            if any_cache_dynamic and G > 0:
                # Reallocating managers first (ascending index, stable) —
                # real managers outrank any K-padding duplicates.
                order = jnp.argsort(~realloc_k, stable=True)
                min32 = min_ways.astype(jnp.int32)

                def blk(a, off):
                    return jax.lax.dynamic_slice_in_dim(a, off, M, axis=0)

                offs = [order[g] * M for g in range(G)]
                valids = [realloc_k[order[g]] for g in range(G)]
                # An all-inactive mask (non-CPpf rows pass all-active,
                # which reduces to the plain Lookahead; invalid sentinel
                # blocks retire after one trip).
                act_all = jnp.concatenate(
                    [blk(active, offs[g]) & valids[g] for g in range(G)],
                    axis=0)
                atd_all = jnp.concatenate(
                    [blk(hits_off, offs[g])
                     * blk(w_off, offs[g])[..., :, None]
                     + blk(hits_on, offs[g])
                     * blk(w_on, offs[g])[..., :, None]
                     for g in range(G)], axis=0)
                min_all = jnp.concatenate(
                    [blk(min32, offs[g]) for g in range(G)], axis=0)
                fresh = lookahead_masked_traced(
                    atd_all, min_all, act_all, total_units)
                if any_policy:
                    # Registry dispatch: each reconfiguring manager's block
                    # goes through its family's boundary branch.  Branch 0
                    # returns the Lookahead slice + the (post-Algorithm-1)
                    # bandwidth slice untouched, so classic managers stay
                    # bit-identical; the auction/QoS branches compute both
                    # resources from the same boundary signals (ATD grid,
                    # delay EMA, and the slowdown vs the first-interval
                    # reference the scan carries for the QoS constraint).
                    slow = jnp.where(
                        prev_ipc > 0,
                        ref_ipc / jnp.where(prev_ipc > 0, prev_ipc, 1.0),
                        1.0)

                    def _classic_branch(op):
                        return op[0], op[1]

                    def _auction_branch(op):
                        look_b, bw_b, atd_b, min_b, acc_b, floor_b, \
                            slow_b, qb, qg = op
                        return policies.auction_allocate_jax(
                            atd_b, acc_b, min_ways=min_b,
                            total_units=total_units,
                            min_bandwidth=floor_b,
                            total_bandwidth=total_bw)

                    def _qos_branch(op):
                        look_b, bw_b, atd_b, min_b, acc_b, floor_b, \
                            slow_b, qb, qg = op
                        return policies.qos_allocate_jax(
                            atd_b, acc_b, slow_b, min_ways=min_b,
                            total_units=total_units,
                            min_bandwidth=floor_b,
                            total_bandwidth=total_bw,
                            bound=qb, gain=qg)

                    branches = [_classic_branch, _auction_branch,
                                _qos_branch]
                for g in range(G):
                    units_b = fresh[g * M:(g + 1) * M].astype(units.dtype)
                    if any_policy:
                        bw_b = blk(bw, offs[g])
                        op_g = (units_b, bw_b,
                                atd_all[g * M:(g + 1) * M],
                                blk(min32, offs[g])[:, None],
                                blk(bw_acc, offs[g]),
                                blk(min_bw, offs[g]),
                                blk(slow, offs[g]),
                                blk(qos_bound, offs[g]),
                                blk(qos_gain, offs[g]))
                        units_b, bw_new_b = jax.lax.switch(
                            cache_pol_k[order[g]], branches, op_g)
                        new_bw_b = jnp.where(
                            valids[g] & blk(bw_dyn, offs[g]),
                            bw_new_b, bw_b)
                        bw = jax.lax.dynamic_update_slice_in_dim(
                            bw, new_bw_b, offs[g], axis=0)
                    old_b = blk(units, offs[g])
                    new_b = jnp.where(valids[g], units_b, old_b)
                    units = jax.lax.dynamic_update_slice_in_dim(
                        units, new_b, offs[g], axis=0)
            if any_cache_dynamic:
                # The boundary ATD decay is a scalar multiply of the whole
                # grid, i.e. of both weight coefficients.
                decay_w = atd_decay[..., 0]                    # (B, 1)
                w_off = jnp.where(do_r, w_off * decay_w, w_off)
                w_on = jnp.where(do_r, w_on * decay_w, w_on)
            return units, bw, w_off, w_on

        def step(carry, seg):
            kind_k, acc_k, reconf_k = seg                      # (K,) each
            if any_policy:
                (units, bw, pf, active, w_off, w_on, bw_acc, ipc_acc,
                 off_ipc, ref_ipc, prev_ipc) = carry
            else:
                (units, bw, pf, active, w_off, w_on, bw_acc, ipc_acc,
                 off_ipc) = carry
            kind = jnp.repeat(kind_k, M)[:, None]              # (B, 1)
            acc_dt = jnp.repeat(acc_k, M)[:, None]
            do_r = jnp.repeat(reconf_k, M)[:, None]
            operand = (units, bw, w_off, w_on, bw_acc, active, do_r,
                       reconf_k & cache_dyn_k)
            if any_policy:
                operand = operand + (ref_ipc, prev_ipc)
            units, bw, w_off, w_on = jax.lax.cond(
                jnp.any(reconf_k), reconfigure,
                lambda op: (op[0], op[1], op[2], op[3]),
                operand)

            # The A/B samples force the prefetcher off/on for everyone;
            # other segments run the current per-client setting.
            if has_sampling:
                pf_f = jnp.where(kind == SAMPLE_OFF, 0.0,
                                 jnp.where(kind == SAMPLE_ON, 1.0,
                                           pf.astype(f64)))
            else:
                pf_f = pf.astype(f64)
            out = memsys_jax._evaluate_rowflags(
                p, units.astype(f64), bw, pf_f,
                jnp.asarray(total_cache_f, f64), total_bw, llc_extra,
                cache_part, bw_part, iters=iters,
                bandwidth_banks=banks_row, max_banks=max_banks)
            ipc, q_ns = out[0], out[1]
            if any_policy:
                # Slowdown signal for the QoS branch: the reference is
                # each row's FIRST executed segment (the equal-share
                # initial state — reconfigures fold onto the following
                # segment, so the first run always precedes any boundary),
                # the denominator its most recent one.  Frozen NOOP slots
                # update neither.
                executed = kind != NOOP
                ref_ipc = jnp.where((ref_ipc == 0.0) & executed,
                                    ipc, ref_ipc)
                prev_ipc = jnp.where(executed, ipc, prev_ipc)

            # Accumulation weights come from the stacked table: fig8
            # accumulates every executed segment (samples included),
            # CPpf's probe intervals and all NOOP rows carry weight 0 —
            # a bitwise no-op on the accumulators.
            if any_cache_dynamic:
                kappa = (ipc * FREQ_GHZ * 1e6
                         * jnp.asarray(1.0, f64) / 1000.0) * acc_dt
                on_mask = pf_f == 1.0
                w_on = w_on + jnp.where(on_mask, kappa, 0.0)
                w_off = w_off + jnp.where(on_mask, 0.0, kappa)
            ipc_acc = ipc_acc + ipc * acc_dt
            if any_bandwidth_dynamic:
                # The delay EMA advances once per *executed* segment of
                # the manager's own table — frozen NOOP rows must not
                # decay it, so the update is gated, not weight-folded.
                executes = (kind != NOOP) & bw_dyn
                bw_acc = jnp.where(executes,
                                   bw_decay * bw_acc + q_ns * acc_dt,
                                   bw_acc)

            if has_sampling:
                decision = throttle_decision_jax(ipc, off_ipc, thr)
                sample_on = kind == SAMPLE_ON
                active = jnp.where(sample_on & is_cppf, ~decision, active)
                pf = jnp.where(sample_on & ~is_cppf, decision, pf)
                off_ipc = jnp.where(kind == SAMPLE_OFF, ipc, off_ipc)
            new_carry = (units, bw, pf, active, w_off, w_on, bw_acc,
                         ipc_acc, off_ipc)
            if any_policy:
                new_carry = new_carry + (ref_ipc, prev_ipc)
            return new_carry, None

        zeros = jnp.zeros((B, n), dtype=f64)
        carry0 = (rows(grid["units0"]), rows(grid["bw0"]),
                  rows(grid["pf0"]), rows(grid["active0"]),
                  zeros, zeros, zeros, zeros, zeros)
        if any_policy:
            carry0 = carry0 + (zeros, zeros)
        xs = (mgr["kinds"].T, mgr["acc"].T, mgr["reconf"].T)   # (S, K)
        carry, _ = jax.lax.scan(step, carry0, xs)
        units, bw, pf, active, _woff, _won, _bw_acc, ipc_acc, _off \
            = carry[:9]
        return {k: v.reshape(K, M, n) for k, v in
                {"ipc_acc": ipc_acc, "cache_units": units, "bandwidth": bw,
                 "prefetch_on": pf, "active": active}.items()}

    return worker


@functools.lru_cache(maxsize=None)
def _compiled_stacked(
    has_sampling: bool,
    any_cache_dynamic: bool,
    any_bandwidth_dynamic: bool,
    max_concurrent_realloc: int,
    total_units: int,
    iters: int,
    grid_shards: Tuple[int, int],
    donate: bool = False,
    any_policy: bool = False,
    max_banks: int = 1,
):
    """Build the jitted (optionally shard_mapped) stacked-timeline executor.

    Cached per static configuration so repeated sweeps reuse both the
    Python wrapper and XLA's compilation cache; jit retraces on new array
    shapes (different K, M, n or segment count) as usual.  ``donate=True``
    compiles with the ``_CARRY_KEYS`` grid leaves split into a donated
    first argument: the chunk's carry-state buffers are reused in place
    for the outputs, so a streaming caller does not hold two chunks'
    worth of carry buffers live at once (the PR 8 leftover in ROADMAP
    item 3).
    """
    worker = _make_worker(has_sampling, any_cache_dynamic,
                          any_bandwidth_dynamic, max_concurrent_realloc,
                          total_units, iters, any_policy, max_banks)
    if grid_shards != (1, 1):
        worker = distributed.shard_grid(worker, grid_shards)
    if not donate:
        return jax.jit(worker)

    def donating(carry0, grid_rest, mgr, replicated):
        return worker({**grid_rest, **carry0}, mgr, replicated)

    return jax.jit(donating, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _compiled_buckets(
    bucket_statics: Tuple[Tuple[bool, bool, bool, int, bool, int], ...],
    total_units: int,
    iters: int,
    mix_shards: int,
    donate: bool = False,
):
    """Build the jitted multi-bucket stacked executor: one worker per
    segment-length bucket, all inside ONE jitted program (one dispatch).

    Frozen-row skipping: a manager bucketed with peers of similar table
    length scans only ~its own slot count instead of the whole set's
    ``s_max``, and each bucket's worker drops the controller machinery its
    managers never reach.  Every bucket still runs the flattened 2-D
    ``(K_g * M, n)`` row scan, so the stacked-vs-fused bit-parity contract
    is untouched.

    Sharding: bucket programs may only split the MIX axis — all buckets
    must then address the SAME device subset (jit rejects shard_maps over
    different device sets in one program), which a shared ``(1,
    mix_shards)`` mesh guarantees.  Manager-axis sharding keeps the
    single-bucket path (:func:`_compiled_stacked`).
    """
    workers = []
    for (has_sampling, cache_dyn, bw_dyn, max_realloc, any_policy,
         max_banks) in bucket_statics:
        w = _make_worker(has_sampling, cache_dyn, bw_dyn, max_realloc,
                         total_units, iters, any_policy, max_banks)
        if mix_shards > 1:
            w = distributed.shard_grid(w, (1, mix_shards))
        workers.append(w)

    def fn(bucket_grids, bucket_mgrs, replicated):
        return tuple(
            w(g, m, replicated)
            for w, g, m in zip(workers, bucket_grids, bucket_mgrs))

    if not donate:
        return jax.jit(fn)

    def donating(bucket_carries, bucket_rests, bucket_mgrs, replicated):
        grids = tuple({**g, **c}
                      for g, c in zip(bucket_rests, bucket_carries))
        return fn(grids, bucket_mgrs, replicated)

    return jax.jit(donating, donate_argnums=(0,))


def _length_buckets(lens: Sequence[int]) -> List[List[int]]:
    """Group manager indices for the bucketed stacked scan.

    Managers share a bucket exactly when their segment-table lengths are
    equal: equal lengths mean zero frozen ``NOOP`` rows inside a bucket,
    and same-length Table-3 tables share their reconfigure slots, so
    bucket-mates' boundary refreshes merge into ONE concatenated greedy
    whose while_loop cost is sublinear in the row count.  (Two rejected
    rules, both measured against per-manager fused on warm wall time:
    merge-within-2x-length traded frozen rows for fewer buckets and
    consistently lost; splitting further by the (sampling, cache_dynamic,
    bandwidth_dynamic) statics triple un-merged those boundary greedies
    and gave back ~1% — the per-slot machinery a non-dynamic manager
    over-pays inside a mixed bucket is masked ``(B, n)`` arithmetic,
    cheaper than a separate bucket's serial while trips.  All buckets
    run inside ONE device program, so bucket count is free at dispatch
    level.)  Stable: equal lengths keep spec order.
    """
    order = sorted(range(len(lens)), key=lambda i: (lens[i], i))
    buckets: List[List[int]] = []
    for i in order:
        if buckets and lens[i] == lens[buckets[-1][0]]:
            buckets[-1].append(i)
        else:
            buckets.append([i])
    return buckets


def _per_row(value, shape: Tuple[int, ...], dtype) -> np.ndarray:
    """Materialize a scalar-or-per-row tunable at its full batch shape.

    Per-row tunables must carry the leading (manager, mix) axes explicitly
    so ``shard_map`` can split them alongside the model state.
    """
    arr = np.asarray(value, dtype=dtype)
    # Scalars and per-mix arrays gain trailing singletons, then broadcast
    # along the leading manager axis (the tunables are manager-shared).
    arr = arr.reshape(arr.shape + (1,) * (len(shape) - 1 - arr.ndim))
    return np.ascontiguousarray(np.broadcast_to(arr, shape))


def _pad_axis(tree: dict, axis: int, target: int) -> dict:
    """Right-pad every leaf's ``axis`` to ``target`` rows by replication."""
    out = {}
    for key, v in tree.items():
        cur = v.shape[axis]
        if cur == target:
            out[key] = v
            continue
        idx = (slice(None),) * axis
        last = v[idx + (slice(cur - 1, cur),)]
        reps = np.repeat(last, target - cur, axis=axis)
        out[key] = np.concatenate([v, reps], axis=axis)
    return out


@dataclasses.dataclass
class PendingTimelines:
    """An in-flight stacked-timeline dispatch (asynchronous handle).

    The device program is already enqueued when this object exists;
    ``device_results`` holds per-spec dicts of *device* arrays.  Nothing
    blocks until :meth:`result` performs the device->host transfer, so a
    caller can overlap host work (generating the next chunk of a stream)
    with the device computing this one — the double-buffering contract of
    :mod:`repro.sim.stream_sweep`.

    ``donated_inputs`` (``donate=True`` dispatches only) are the device
    handles of the grid buffers handed to XLA: after the dispatch they are
    consumed (``is_deleted()``), the proof the streaming caller is not
    holding chunk c's grid alive while chunk c+1 transfers.
    """

    device_results: List[dict]      # per-spec {field: (M, n) device array}
    w_accs: List[float]
    donated_inputs: Optional[List] = None

    def block_until_ready(self) -> "PendingTimelines":
        jax.block_until_ready([d for d in self.device_results])
        return self

    def result(self) -> List[TimelineResult]:
        """Blocking device->host transfer into :class:`TimelineResult`s."""
        out = []
        for w_acc, dev in zip(self.w_accs, self.device_results):
            host = {k: np.asarray(v) for k, v in dev.items()}
            out.append(TimelineResult(
                ipc_acc=host["ipc_acc"],
                w_acc=w_acc,
                cache_units=host["cache_units"].astype(np.int64),
                bandwidth=host["bandwidth"],
                prefetch_on=host["prefetch_on"],
                active=host["active"],
            ))
        return out


def run_timelines(
    apps: Union[AppArrays, dict],
    specs: Sequence[TimelineSpec],
    *,
    total_units: int,
    total_bandwidth: float,
    llc_extra_cycles: float = 0.0,
    min_ways=4,
    speedup_threshold=1.05,
    min_bandwidth_allocation=1.0,
    atd_decay=0.5,
    bandwidth_delay_decay=0.5,
    iters: int = FIXED_POINT_ITERS,
    shard: Optional[bool] = None,
    donate: bool = False,
) -> List[TimelineResult]:
    """Execute a whole manager set's timelines as ONE device program.

    Args:
      apps: mix-stacked application profiles, every field ``(M, n)``.
      specs: one :class:`TimelineSpec` per manager — each keeps its own
        segment list and Table-3 knob flags; the tables stack along the
        leading manager axis (see :func:`stack_tables`).
      min_ways / speedup_threshold / min_bandwidth_allocation / atd_decay /
        bandwidth_delay_decay: scalars or per-mix arrays (``param_grid``),
        shared by every manager in the batch — exactly how ``run_sweep``
        applies one ``CBPParams`` across the Table-3 set.
      shard: ``None`` auto-shards the (manager, mix) grid over all visible
        devices (:func:`repro.distributed.grid_shard_counts`, padding both
        axes as needed); ``False`` forces single-device execution.

    Returns:
      One :class:`TimelineResult` of host arrays per spec — the only
      device->host transfer of all K timelines.
    """
    return run_timelines_async(
        apps, specs,
        total_units=total_units,
        total_bandwidth=total_bandwidth,
        llc_extra_cycles=llc_extra_cycles,
        min_ways=min_ways,
        speedup_threshold=speedup_threshold,
        min_bandwidth_allocation=min_bandwidth_allocation,
        atd_decay=atd_decay,
        bandwidth_delay_decay=bandwidth_delay_decay,
        iters=iters,
        shard=shard,
        donate=donate,
    ).result()


def run_timelines_async(
    apps: Union[AppArrays, dict],
    specs: Sequence[TimelineSpec],
    *,
    total_units: int,
    total_bandwidth: float,
    llc_extra_cycles: float = 0.0,
    min_ways=4,
    speedup_threshold=1.05,
    min_bandwidth_allocation=1.0,
    atd_decay=0.5,
    bandwidth_delay_decay=0.5,
    iters: int = FIXED_POINT_ITERS,
    shard: Optional[bool] = None,
    donate: bool = False,
) -> PendingTimelines:
    """:func:`run_timelines` without the blocking device->host transfer.

    Dispatches the stacked program(s) and returns a
    :class:`PendingTimelines` handle holding device arrays; call
    ``.result()`` for the host-side :class:`TimelineResult`s.  Argument
    semantics are identical to :func:`run_timelines` (which is literally
    this followed by ``.result()``).

    ``donate=True`` transfers the carry-state grid leaves (``units0`` /
    ``bw0`` / ``pf0`` / ``active0``) to the device first and donates
    exactly those buffers to the program — each aliases the final-state
    output of identical shape/dtype, so a chunked stream
    (:mod:`repro.sim.stream_sweep`) reuses chunk c's carry buffers for
    chunk c's outputs instead of allocating fresh ones.  Donation changes
    buffer *lifetime* only — results are bit-identical to the non-donated
    path and the dispatch count is unchanged.
    """
    if not specs:
        raise ValueError("need at least one TimelineSpec")
    params = memsys_jax.app_params(apps)
    shape = np.asarray(params["cpi_base"]).shape
    if len(shape) != 2:
        raise ValueError(f"apps must be mix-stacked (M, n); got {shape}")
    M, n = shape
    K = len(specs)

    # Feasibility checks hoisted out of the traced region (the numpy
    # controllers validate per call; the fused program validates once).
    if any(s.bandwidth_dynamic for s in specs):
        check_bandwidth_floor(min_bandwidth_allocation, n, total_bandwidth)
    if any(s.cache_dynamic for s in specs) and np.any(
            np.asarray(min_ways, dtype=np.int64) * n > total_units):
        raise ValueError("min_ways * n exceeds capacity")

    tables = [segment_table(s.schedule) for s in specs]
    accum = [RUN if s.variant == "cppf" else None for s in specs]

    grid = {"p_" + k: np.ascontiguousarray(
        np.broadcast_to(np.asarray(v, np.float64), (K, M, n)))
        for k, v in params.items()}
    grid.update(
        units0=np.stack([np.broadcast_to(
            np.asarray(s.init_units, dtype=np.int32), (M, n))
            for s in specs]),
        bw0=np.stack([np.broadcast_to(
            np.asarray(s.init_bandwidth, dtype=np.float64), (M, n))
            for s in specs]),
        pf0=np.stack([np.broadcast_to(
            np.asarray(s.init_prefetch, dtype=bool), (M, n))
            for s in specs]),
        active0=np.ones((K, M, n), dtype=bool),
        min_ways=_per_row(min_ways, (K, M), np.int32),
        speedup_threshold=_per_row(speedup_threshold, (K, M, 1), np.float64),
        min_bandwidth_allocation=_per_row(
            min_bandwidth_allocation, (K, M, 1), np.float64),
        atd_decay=_per_row(atd_decay, (K, M, 1, 1), np.float64),
        bandwidth_delay_decay=_per_row(
            bandwidth_delay_decay, (K, M, 1), np.float64),
    )
    flags = {
        "cache_dynamic": np.array([s.cache_dynamic for s in specs]),
        "bandwidth_dynamic": np.array(
            [s.bandwidth_dynamic for s in specs]),
        "cache_partitioned": np.array(
            [s.cache_partitioned for s in specs]),
        "bandwidth_partitioned": np.array(
            [s.bandwidth_partitioned for s in specs]),
        "is_cppf": np.array([s.variant == "cppf" for s in specs]),
        "cache_policy": np.array(
            [s.cache_policy for s in specs], dtype=np.int32),
        "qos_bound": np.array(
            [s.qos_bound for s in specs], dtype=np.float64),
        "qos_gain": np.array(
            [s.qos_gain for s in specs], dtype=np.float64),
        "bandwidth_banks": np.array(
            [float(s.bandwidth_banks) for s in specs], dtype=np.float64),
    }
    replicated = {
        "total_bandwidth": np.float64(total_bandwidth),
        "llc_extra_cycles": np.float64(llc_extra_cycles),
    }

    grid_shards = ((1, 1) if shard is False
                   else distributed.grid_shard_counts(K, M))
    # Donation is the single-host streaming optimization: under sharding
    # the committed carry buffers would be resharded before use and the
    # donation wasted (XLA cannot alias across shardings), so it degrades
    # to the plain path there.
    donate = donate and grid_shards == (1, 1)
    buckets = _length_buckets([len(t[0]) for t in tables])
    if grid_shards[0] == 1 and len(buckets) > 1:
        # Frozen-row-skipping path: short-table managers stop paying for
        # every slot of the longest table.  Only the mix axis may shard
        # here (all buckets then share one mesh over one device subset);
        # a sharded manager axis takes the single-bucket path below.
        return _dispatch_buckets(
            buckets, tables, accum, grid, flags, replicated,
            K, M, grid_shards[1], int(total_units), int(iters), donate)
    kinds, acc, reconf = stack_tables(
        [tables[i] for i in range(K)], accum)
    mgr = {"kinds": kinds, "acc": acc, "reconf": reconf, **flags}
    k_pad = -(-K // grid_shards[0]) * grid_shards[0]
    m_pad = -(-M // grid_shards[1]) * grid_shards[1]
    # Pad with copies of the last manager/mix row; sliced off after
    # the program (padding rows are duplicates, never feed real rows).
    grid = _pad_axis(_pad_axis(grid, 1, m_pad), 0, k_pad)
    mgr = _pad_axis(mgr, 0, k_pad)

    has_sampling = bool(np.isin(kinds, (SAMPLE_OFF, SAMPLE_ON)).any())
    # The most cache-dynamic managers that ever reallocate on the same
    # slot — the static bound on mini-greedies per boundary step.
    cache_dyn_col = flags["cache_dynamic"][:, None]
    max_realloc = int(
        (reconf & cache_dyn_col).sum(axis=0).max(initial=0))
    fn = _compiled_stacked(
        has_sampling,
        any(s.cache_dynamic for s in specs),
        any(s.bandwidth_dynamic for s in specs),
        max_realloc, int(total_units), int(iters), grid_shards, donate,
        any(s.cache_policy or s.bw_policy for s in specs),
        max(s.bandwidth_banks for s in specs))
    record_dispatch()
    donated = None
    with memsys_jax.x64_context():
        if donate:
            # Stable device identities for the donated carry buffers:
            # transfer first, keep the handles, and hand exactly those
            # buffers to the program.  They are consumed by the dispatch
            # (``is_deleted()`` afterwards) — the streaming smoke's gate.
            carry0 = jax.device_put({k: grid.pop(k) for k in _CARRY_KEYS})
            donated = list(carry0.values())
            res = fn(carry0, grid, mgr, replicated)
        else:
            res = fn(grid, mgr, replicated)
        # Per-spec device-side slices: no transfer, no block — padding
        # rows fall away exactly as the host-side [:K, :M] slice used to
        # do.  Sliced inside the x64 context: slicing a sharded float64
        # result is itself a traced program and must lower at the same
        # precision the stacked program produced.
        device_results = [{f: res[f][k, :M] for f in res}
                          for k in range(K)]
    w_accs = [float(a.sum()) for a in acc]
    return PendingTimelines(device_results, w_accs, donated)


def _dispatch_buckets(buckets, tables, accum, grid, flags, replicated,
                      K: int, M: int, mix_shards: int,
                      total_units: int, iters: int,
                      donate: bool = False) -> PendingTimelines:
    """Dispatch the stacked set as per-length bucket scans in ONE program.

    Each bucket stacks only its own tables (:func:`stack_tables` snaps
    reconfigure slots within the bucket) and carries its own static knob
    summary, so e.g. the fully-static bucket drops the ATD precompute and
    sampling machinery outright.  Returns a :class:`PendingTimelines`
    whose per-spec device slices restore spec order.
    """
    m_pad = -(-M // mix_shards) * mix_shards
    statics = []
    bucket_grids = []
    bucket_mgrs = []
    w_accs = {}
    for idx_g in buckets:
        sel = np.asarray(idx_g)
        kinds_g, acc_g, reconf_g = stack_tables(
            [tables[i] for i in idx_g], [accum[i] for i in idx_g])
        for row, i in enumerate(idx_g):
            w_accs[i] = float(acc_g[row].sum())
        mgr_g = {"kinds": kinds_g, "acc": acc_g, "reconf": reconf_g,
                 **{k: v[sel] for k, v in flags.items()}}
        grid_g = _pad_axis({k: v[sel] for k, v in grid.items()}, 1, m_pad)
        cache_dyn_col = mgr_g["cache_dynamic"][:, None]
        statics.append((
            bool(np.isin(kinds_g, (SAMPLE_OFF, SAMPLE_ON)).any()),
            bool(mgr_g["cache_dynamic"].any()),
            bool(mgr_g["bandwidth_dynamic"].any()),
            int((reconf_g & cache_dyn_col).sum(axis=0).max(initial=0)),
            bool(mgr_g["cache_policy"].any()),
            int(mgr_g["bandwidth_banks"].max(initial=1)),
        ))
        bucket_grids.append(grid_g)
        bucket_mgrs.append(mgr_g)

    fn = _compiled_buckets(tuple(statics), total_units, iters, mix_shards,
                           donate)
    record_dispatch()
    donated = None
    with memsys_jax.x64_context():
        if donate:
            # See run_timelines_async: transfer the carry leaves, keep
            # the handles, donate exactly those.
            carries = jax.device_put(tuple(
                {k: g.pop(k) for k in _CARRY_KEYS} for g in bucket_grids))
            donated = [v for c in carries for v in c.values()]
            outs = fn(carries, tuple(bucket_grids), tuple(bucket_mgrs),
                      replicated)
        else:
            outs = fn(tuple(bucket_grids), tuple(bucket_mgrs), replicated)
        # Sliced inside the x64 context — see run_timelines_async.
        device_results: List[Optional[dict]] = [None] * K
        for idx_g, o in zip(buckets, outs):
            for row, i in enumerate(idx_g):
                device_results[i] = {k: v[row, :M] for k, v in o.items()}
    return PendingTimelines(device_results, [w_accs[i] for i in range(K)],
                            donated)


def run_timeline(
    apps: Union[AppArrays, dict],
    schedule: Sequence[ScheduleSegment],
    *,
    variant: str = "fig8",
    init_units: np.ndarray,
    init_bandwidth: np.ndarray,
    init_prefetch: np.ndarray,
    cache_dynamic: bool,
    bandwidth_dynamic: bool,
    cache_partitioned: bool,
    bandwidth_partitioned: bool,
    total_units: int,
    total_bandwidth: float,
    llc_extra_cycles: float = 0.0,
    min_ways=4,
    speedup_threshold=1.05,
    min_bandwidth_allocation=1.0,
    atd_decay=0.5,
    bandwidth_delay_decay=0.5,
    iters: int = FIXED_POINT_ITERS,
    shard: Optional[bool] = None,
) -> TimelineResult:
    """Execute one manager's whole timeline as ONE device program.

    The K=1 case of :func:`run_timelines` — the per-manager fused path the
    stacked sweep is parity-pinned against.  See ``run_timelines`` for
    argument semantics.
    """
    spec = TimelineSpec(
        schedule=schedule,
        variant=variant,
        cache_dynamic=bool(cache_dynamic),
        bandwidth_dynamic=bool(bandwidth_dynamic),
        cache_partitioned=bool(cache_partitioned),
        bandwidth_partitioned=bool(bandwidth_partitioned),
        init_units=init_units,
        init_bandwidth=init_bandwidth,
        init_prefetch=init_prefetch,
    )
    return run_timelines(
        apps, [spec],
        total_units=total_units,
        total_bandwidth=total_bandwidth,
        llc_extra_cycles=llc_extra_cycles,
        min_ways=min_ways,
        speedup_threshold=speedup_threshold,
        min_bandwidth_allocation=min_bandwidth_allocation,
        atd_decay=atd_decay,
        bandwidth_delay_decay=bandwidth_delay_decay,
        iters=iters,
        shard=shard,
    )[0]
