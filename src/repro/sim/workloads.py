"""Workload mixes (paper Table 2), random generation (§2.3) and the
streaming scenario service.

Table 2's 14 mixes of 16 applications are transcribed from the paper via the
abbreviation lists (each row resolves to exactly 16 applications).  The
random 4-app workloads reproduce the §2.3 potential study setup.

Beyond the paper's 32-mix reports, the streaming sweep service
(:mod:`repro.sim.stream_sweep`) consumes mixes at 10^5-10^6 scale, which
this module serves **chunk-wise** so no run ever materializes a giant
Python list-of-lists:

* :func:`mix_index_chunk` — one ``(chunk_size, apps_per_mix)`` int32 array
  of app indices per chunk, derived from ``(seed, chunk_index)`` alone, so
  any chunk regenerates independently (that statelessness is what makes
  checkpoint/resume of a stream bit-exact — no RNG state threads between
  chunks).
* :func:`params_from_indices` — index arrays -> the ``(M, n)``
  model-parameter dict the batched interval model consumes, via one fancy
  index into a precomputed per-app parameter matrix (no per-mix Python
  loop, unlike :func:`repro.sim.apps.stack_mixes`).
* :class:`StreamScenario` / :func:`scenario_chunk` — the scenario knobs of
  the streaming service: heavy-tailed (Zipf) mix popularity over a
  deterministic template catalog, diurnal phases that shift the draw
  toward cache- vs bandwidth-sensitive classes over a configurable period,
  and phase-changing applications whose miss curves drift per chunk
  (per-chunk parameter modulation — the within-timeline analogue rides the
  PR 5 per-segment ATD weight-coefficient swap).

Seed stability of :func:`random_mixes` and :func:`mix_index_chunk` is
pinned by golden tests (``tests/test_stream_sweep.py``): checkpoints store
only ``(seed, cursor)``, so the generators must keep producing identical
streams across refactors or every saved checkpoint silently goes stale.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from repro.sim.apps import ABBREV, MODEL_FIELDS, PROFILES

# Paper Table 2, "Benchmarks" column, verbatim abbreviation strings.
_TABLE2 = {
    "w1":  "xa,gr,li(2),h2,ze,to,so,lb,pe,ca,mi,sp,bw,go,ga",
    "w2":  "lb,to,pe,go,gc,mi,li(2),na,h2,cac,ze(2),ca,so,as",
    "w3":  "bw(2),po(2),sj(2),sp(2),na(2),ze,Ge,cac,li,mi,wr",
    "w4":  "po,bw(2),h2,sj,li(2),gr,na,mi(2),as,Ge,ga,wr,lb",
    "w5":  "de,om(2),go(2),hm,xa,le,bz(2),gc,so,mc,pe,ca(2)",
    "w6":  "sp,bw(2),h2,om,li,gr,go,mi(2),as,hm,ga,le,lb,ca",
    "w7":  "po(2),to,sj,h2(2),na,lb(2),ze(2),gr,Ge,as,wr,ga",
    "w8":  "de,bw(3),xa,mi(3),om,li(2),bz,go,so,hm,pe",
    "w9":  "gc,po,to,hm,sj,h2,bz,ze,gr,so,Ge,as,pe,wr,ga,cac",
    "w10": "sj,bw(2),de,na,li(2),om,ze,mi(2),xa,Ge,bz,wr,gc",
    "w11": "po,om,sj,go,na(2),le,ze,xa,Ge,bz,wr,ca,sj,sp,gc",
    "w12": "de,to,go,h2(2),hm,gr,xa,as(2),bz,ga,gc,lb,so,ca",
    "w13": "to,po,h2,sj,gr,na,as,ze,ga,Ge,lb(2),li,to,mi,wr",
    "w14": "de,bw,go,po,hm,na,xa,ze,so,Ge,mc,li,pe,mi,ca,wr",
}


def _parse(spec: str) -> List[str]:
    apps: List[str] = []
    for tok in spec.split(","):
        tok = tok.strip()
        if "(" in tok:
            ab, count = tok[:-1].split("(")
            apps.extend([ABBREV[ab]] * int(count))
        else:
            apps.append(ABBREV[tok])
    return apps


WORKLOADS: Dict[str, List[str]] = {k: _parse(v) for k, v in _TABLE2.items()}

for _k, _apps in WORKLOADS.items():
    assert len(_apps) == 16, (_k, len(_apps))


def random_workloads(n_workloads: int, apps_per_workload: int = 4,
                     seed: int = 0) -> List[List[str]]:
    """Randomly generated workloads (paper §2.3: 640 x 4 apps)."""
    from repro.sim.apps import APP_NAMES
    rng = np.random.default_rng(seed)
    return [
        [APP_NAMES[i] for i in rng.integers(0, len(APP_NAMES),
                                            size=apps_per_workload)]
        for _ in range(n_workloads)
    ]


# Sensitivity-class buckets (paper Fig. 2 / the _TABLE blocks in apps.py),
# used to draw Table-2-like mixes that always exercise all three resources.
_CLASS_BUCKETS = {
    "CS-BS-PS": ["mcf", "leslie3d", "soplex", "sphinx3", "gcc", "dealII"],
    "CS-BS": ["xalancbmk", "omnetpp", "bzip2", "gobmk", "perlbench",
              "calculix", "hmmer", "astar"],
    "BS-PS": ["lbm", "libquantum", "milc", "bwaves", "zeusmp", "GemsFDTD"],
    "CS": ["h264ref", "tonto", "gromacs"],
    "BS": ["cactusADM", "wrf", "sjeng"],
    "I": ["povray", "gamess", "namd"],
}


def random_mixes(n_mixes: int, apps_per_mix: int = 16, seed: int = 0,
                 balanced: bool = True) -> List[List[str]]:
    """Random 16-app mixes for the Table-3 sweep (``repro.sim.sweep``).

    With ``balanced=True`` (default) each mix draws at least one application
    from every sensitivity class before filling uniformly, mirroring the
    composition of the paper's Table 2 mixes — every mix then has cache-,
    bandwidth- and prefetch-sensitive clients for the managers to trade off.
    Uniform draws (``balanced=False``) reproduce the §2.3 potential-study
    style instead.
    """
    from repro.sim.apps import APP_NAMES
    if balanced and apps_per_mix < len(_CLASS_BUCKETS):
        raise ValueError(
            f"balanced mixes need >= {len(_CLASS_BUCKETS)} apps per mix")
    rng = np.random.default_rng(seed)
    mixes: List[List[str]] = []
    for _ in range(n_mixes):
        apps: List[str] = []
        if balanced:
            for bucket in _CLASS_BUCKETS.values():
                apps.append(bucket[int(rng.integers(0, len(bucket)))])
        fill = apps_per_mix - len(apps)
        apps.extend(APP_NAMES[i]
                    for i in rng.integers(0, len(APP_NAMES), size=fill))
        rng.shuffle(apps)
        mixes.append(apps)
    return mixes


# --------------------------------------------------------------------- #
# Chunk-wise mix generation (the 10^5-10^6 streaming scale)
# --------------------------------------------------------------------- #

#: (n_apps, len(MODEL_FIELDS)) per-application parameter matrix — the
#: single fancy-index source for :func:`params_from_indices`.
from repro.sim.apps import APP_NAMES as _APP_NAMES  # noqa: E402

_PARAM_MATRIX = np.array(
    [[getattr(PROFILES[name], field) for field in MODEL_FIELDS]
     for name in _APP_NAMES], dtype=np.float64)

#: Class-bucket membership as index arrays (same order as _CLASS_BUCKETS).
_BUCKET_INDICES = [
    np.array([_APP_NAMES.index(a) for a in bucket], dtype=np.int32)
    for bucket in _CLASS_BUCKETS.values()
]

#: Cache-sensitive vs bandwidth-sensitive app index sets for the diurnal
#: phase bias (apps can be in both; the bias re-weights, never excludes).
_CACHE_SENSITIVE = np.array(
    sorted({_APP_NAMES.index(a)
            for key, bucket in _CLASS_BUCKETS.items() if "CS" in key
            for a in bucket}), dtype=np.int64)
_BW_SENSITIVE = np.array(
    sorted({_APP_NAMES.index(a)
            for key, bucket in _CLASS_BUCKETS.items() if "BS" in key
            for a in bucket}), dtype=np.int64)


def _chunk_rng(seed: int, chunk_idx: int, salt: int = 0):
    """The chunk's RNG — a pure function of (seed, chunk, salt)."""
    return np.random.default_rng([int(seed), int(chunk_idx), int(salt)])


def _draw_mix_indices(rng, n_mixes: int, apps_per_mix: int, balanced: bool,
                     fill_p: Optional[np.ndarray] = None) -> np.ndarray:
    """Vectorized mix drawing -> (n_mixes, apps_per_mix) int32 indices.

    Mirrors :func:`random_mixes`' composition (one app per sensitivity
    class, then uniform fill, then shuffle) without any Python-level
    per-mix loop; ``fill_p`` optionally biases the fill draw (the diurnal
    knob).  NOT stream-compatible with ``random_mixes`` — the chunk form
    has its own golden test.
    """
    n_apps = len(_APP_NAMES)
    cols: List[np.ndarray] = []
    if balanced:
        if apps_per_mix < len(_BUCKET_INDICES):
            raise ValueError(
                f"balanced mixes need >= {len(_BUCKET_INDICES)} apps per mix")
        for bucket in _BUCKET_INDICES:
            picks = rng.integers(0, len(bucket), size=n_mixes)
            cols.append(bucket[picks])
    fill = apps_per_mix - len(cols)
    if fill > 0:
        if fill_p is None:
            filler = rng.integers(0, n_apps, size=(n_mixes, fill))
        else:
            filler = rng.choice(n_apps, size=(n_mixes, fill), p=fill_p)
        cols.append(filler.T)
    idx = np.vstack(cols).T.astype(np.int32)
    # Per-row shuffle so class picks don't sit in fixed slots.
    return rng.permuted(idx, axis=1)


def mix_index_chunk(seed: int, chunk_idx: int, chunk_size: int,
                    apps_per_mix: int = 16,
                    balanced: bool = True) -> np.ndarray:
    """One chunk of random mixes as a ``(chunk_size, apps_per_mix)`` int32
    index array into ``APP_NAMES``.

    Derived from ``(seed, chunk_idx)`` alone: chunk c of a stream is the
    same array whether the run started cold, resumed from a checkpoint, or
    regenerated just that chunk — the property the streaming service's
    bit-identical resume contract rests on.  Seed-stability is pinned by a
    golden test; changing the draw order here invalidates every
    checkpointed stream.
    """
    rng = _chunk_rng(seed, chunk_idx)
    return _draw_mix_indices(rng, chunk_size, apps_per_mix, balanced)


def iter_mix_index_chunks(n_mixes: int, chunk_size: int, *, seed: int = 0,
                          apps_per_mix: int = 16,
                          balanced: bool = True) -> Iterator[np.ndarray]:
    """Generate ``n_mixes`` mixes as a sequence of index-array chunks.

    The last chunk is truncated to ``n_mixes`` total; peak memory is one
    chunk, never the stream (10^6 mixes stream through a few MB).
    """
    if chunk_size < 1:
        raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
    n_chunks = -(-n_mixes // chunk_size)
    for c in range(n_chunks):
        chunk = mix_index_chunk(seed, c, chunk_size, apps_per_mix, balanced)
        remain = n_mixes - c * chunk_size
        yield chunk[:remain] if remain < chunk_size else chunk


def params_from_indices(idx: np.ndarray) -> Dict[str, np.ndarray]:
    """App-index arrays -> the model-parameter dict (each field (M, n)).

    The dict form feeds :func:`repro.sim.timeline_jax.run_timelines` and
    :func:`repro.sim.memsys_jax.evaluate` directly (they accept
    ``AppArrays`` or a params dict); one fancy index replaces
    ``stack_mixes``' per-mix Python loop, which matters at 10^5+ mixes.
    """
    idx = np.asarray(idx)
    if idx.ndim != 2:
        raise ValueError(f"expected (n_mixes, apps_per_mix), got {idx.shape}")
    gathered = _PARAM_MATRIX[idx]        # (M, n, F)
    return {field: np.ascontiguousarray(gathered[..., j])
            for j, field in enumerate(MODEL_FIELDS)}


def names_from_indices(idx: np.ndarray) -> List[List[str]]:
    """Index arrays -> name lists (for parity against the list-based API)."""
    return [[_APP_NAMES[int(i)] for i in row] for row in np.asarray(idx)]


# --------------------------------------------------------------------- #
# Streaming scenario service
# --------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class StreamScenario:
    """Scenario knobs of the streaming sweep service.

    ``popularity="zipf"`` draws each mix from a deterministic template
    catalog with Zipf(``zipf_exponent``) rank popularity — the
    heavy-tailed "many users run few distinct consolidations" regime —
    instead of fresh i.i.d. mixes.  ``diurnal_period_chunks > 0`` sweeps a
    sinusoidal phase over the stream that biases the uniform fill draw
    toward cache-sensitive apps at the peak and bandwidth-sensitive apps
    in the trough (amplitude in [0, 1]).  ``phase_app_fraction > 0`` makes
    that fraction of each mix's slots *phase-changing*: their miss-curve
    parameters drift sinusoidally per chunk (period
    ``phase_period_chunks``, relative amplitude ``phase_amplitude``) —
    the cross-chunk face of the paper's time-varying application phases
    (within one timeline the PR 5 per-segment ATD weight-coefficient swap
    plays the same trick per segment).
    """

    apps_per_mix: int = 16
    balanced: bool = True
    popularity: str = "uniform"          # "uniform" | "zipf"
    zipf_exponent: float = 1.2
    catalog_size: int = 4096
    diurnal_period_chunks: int = 0       # 0 = no diurnal modulation
    diurnal_amplitude: float = 0.5
    phase_app_fraction: float = 0.0      # 0 = no phase-changing apps
    phase_amplitude: float = 0.25
    phase_period_chunks: int = 8

    def __post_init__(self):
        if self.popularity not in ("uniform", "zipf"):
            raise ValueError(
                f"unknown popularity model {self.popularity!r}")
        if not 0.0 <= self.phase_app_fraction <= 1.0:
            raise ValueError("phase_app_fraction must be in [0, 1]")
        if not 0.0 <= self.diurnal_amplitude <= 1.0:
            raise ValueError("diurnal_amplitude must be in [0, 1]")
        if self.popularity == "zipf" and self.zipf_exponent <= 1.0:
            raise ValueError("zipf_exponent must be > 1")


def _diurnal_fill_p(scenario: StreamScenario,
                    chunk_idx: int) -> Optional[np.ndarray]:
    """Fill-draw probabilities for this chunk's diurnal phase (or None)."""
    if scenario.diurnal_period_chunks <= 0:
        return None
    phase = math.sin(
        2.0 * math.pi * chunk_idx / scenario.diurnal_period_chunks)
    bias = scenario.diurnal_amplitude * phase
    w = np.ones(len(_APP_NAMES), dtype=np.float64)
    # Day (+phase): cache-sensitive demand; night (-phase): bandwidth.
    w[_CACHE_SENSITIVE] *= 1.0 + max(bias, 0.0)
    w[_BW_SENSITIVE] *= 1.0 + max(-bias, 0.0)
    return w / w.sum()


def _catalog_rows(scenario: StreamScenario, seed: int,
                  catalog_ids: np.ndarray) -> np.ndarray:
    """Template-catalog mixes for ``catalog_ids`` — each row a pure
    function of (seed, catalog id), generated only for the ids actually
    drawn (the catalog itself never materializes)."""
    uniq, inverse = np.unique(catalog_ids, return_inverse=True)
    rows = np.empty((len(uniq), scenario.apps_per_mix), dtype=np.int32)
    for j, cid in enumerate(uniq):
        rng = _chunk_rng(seed, int(cid), salt=0xCA7A)
        rows[j] = _draw_mix_indices(
            rng, 1, scenario.apps_per_mix, scenario.balanced)[0]
    return rows[inverse]


def scenario_chunk(scenario: StreamScenario, seed: int, chunk_idx: int,
                   chunk_size: int) -> Dict[str, np.ndarray]:
    """One scenario chunk: the model-parameter dict (+ ``mix_indices``).

    Deterministic in ``(scenario, seed, chunk_idx, chunk_size)`` — the
    streaming service's resume contract.  Returns the params dict of
    :func:`params_from_indices` with phase-changing drift applied, plus
    the raw ``(chunk_size, apps_per_mix)`` index array under
    ``"mix_indices"`` for reporting.
    """
    if scenario.popularity == "zipf":
        rng = _chunk_rng(seed, chunk_idx, salt=0x21BF)
        ranks = rng.zipf(scenario.zipf_exponent, size=chunk_size)
        catalog_ids = (ranks - 1) % scenario.catalog_size
        idx = _catalog_rows(scenario, seed, catalog_ids)
    else:
        rng = _chunk_rng(seed, chunk_idx)
        idx = _draw_mix_indices(
            rng, chunk_size, scenario.apps_per_mix, scenario.balanced,
            fill_p=_diurnal_fill_p(scenario, chunk_idx))
    params = params_from_indices(idx)

    if scenario.phase_app_fraction > 0.0:
        # Phase-changing apps: a deterministic subset of slots per mix
        # drifts its miss curve sinusoidally across chunks.  The drift
        # multiplies mpki_min_alloc/mpki_floor (pressure swells and
        # shrinks) and divides ws_units (the working set sharpens as
        # pressure peaks); parameters stay strictly positive.
        sel_rng = _chunk_rng(seed, 0, salt=0xFA5E)
        n = scenario.apps_per_mix
        n_phase = max(1, int(round(scenario.phase_app_fraction * n)))
        slots = sel_rng.permutation(n)[:n_phase]
        offsets = sel_rng.uniform(0.0, 2.0 * math.pi, size=n_phase)
        drift = scenario.phase_amplitude * np.sin(
            2.0 * math.pi * chunk_idx / scenario.phase_period_chunks
            + offsets)
        factor = np.ones(n, dtype=np.float64)
        factor[slots] = 1.0 + drift
        params["mpki_min_alloc"] = params["mpki_min_alloc"] * factor
        params["mpki_floor"] = params["mpki_floor"] * factor
        params["ws_units"] = params["ws_units"] / factor
    params["mix_indices"] = idx
    return params
