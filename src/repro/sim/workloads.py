"""Workload mixes (paper Table 2) and random workload generation (paper §2.3).

Table 2's 14 mixes of 16 applications are transcribed from the paper via the
abbreviation lists (each row resolves to exactly 16 applications).  The
random 4-app workloads reproduce the §2.3 potential study setup.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.sim.apps import ABBREV

# Paper Table 2, "Benchmarks" column, verbatim abbreviation strings.
_TABLE2 = {
    "w1":  "xa,gr,li(2),h2,ze,to,so,lb,pe,ca,mi,sp,bw,go,ga",
    "w2":  "lb,to,pe,go,gc,mi,li(2),na,h2,cac,ze(2),ca,so,as",
    "w3":  "bw(2),po(2),sj(2),sp(2),na(2),ze,Ge,cac,li,mi,wr",
    "w4":  "po,bw(2),h2,sj,li(2),gr,na,mi(2),as,Ge,ga,wr,lb",
    "w5":  "de,om(2),go(2),hm,xa,le,bz(2),gc,so,mc,pe,ca(2)",
    "w6":  "sp,bw(2),h2,om,li,gr,go,mi(2),as,hm,ga,le,lb,ca",
    "w7":  "po(2),to,sj,h2(2),na,lb(2),ze(2),gr,Ge,as,wr,ga",
    "w8":  "de,bw(3),xa,mi(3),om,li(2),bz,go,so,hm,pe",
    "w9":  "gc,po,to,hm,sj,h2,bz,ze,gr,so,Ge,as,pe,wr,ga,cac",
    "w10": "sj,bw(2),de,na,li(2),om,ze,mi(2),xa,Ge,bz,wr,gc",
    "w11": "po,om,sj,go,na(2),le,ze,xa,Ge,bz,wr,ca,sj,sp,gc",
    "w12": "de,to,go,h2(2),hm,gr,xa,as(2),bz,ga,gc,lb,so,ca",
    "w13": "to,po,h2,sj,gr,na,as,ze,ga,Ge,lb(2),li,to,mi,wr",
    "w14": "de,bw,go,po,hm,na,xa,ze,so,Ge,mc,li,pe,mi,ca,wr",
}


def _parse(spec: str) -> List[str]:
    apps: List[str] = []
    for tok in spec.split(","):
        tok = tok.strip()
        if "(" in tok:
            ab, count = tok[:-1].split("(")
            apps.extend([ABBREV[ab]] * int(count))
        else:
            apps.append(ABBREV[tok])
    return apps


WORKLOADS: Dict[str, List[str]] = {k: _parse(v) for k, v in _TABLE2.items()}

for _k, _apps in WORKLOADS.items():
    assert len(_apps) == 16, (_k, len(_apps))


def random_workloads(n_workloads: int, apps_per_workload: int = 4,
                     seed: int = 0) -> List[List[str]]:
    """Randomly generated workloads (paper §2.3: 640 x 4 apps)."""
    from repro.sim.apps import APP_NAMES
    rng = np.random.default_rng(seed)
    return [
        [APP_NAMES[i] for i in rng.integers(0, len(APP_NAMES),
                                            size=apps_per_workload)]
        for _ in range(n_workloads)
    ]


# Sensitivity-class buckets (paper Fig. 2 / the _TABLE blocks in apps.py),
# used to draw Table-2-like mixes that always exercise all three resources.
_CLASS_BUCKETS = {
    "CS-BS-PS": ["mcf", "leslie3d", "soplex", "sphinx3", "gcc", "dealII"],
    "CS-BS": ["xalancbmk", "omnetpp", "bzip2", "gobmk", "perlbench",
              "calculix", "hmmer", "astar"],
    "BS-PS": ["lbm", "libquantum", "milc", "bwaves", "zeusmp", "GemsFDTD"],
    "CS": ["h264ref", "tonto", "gromacs"],
    "BS": ["cactusADM", "wrf", "sjeng"],
    "I": ["povray", "gamess", "namd"],
}


def random_mixes(n_mixes: int, apps_per_mix: int = 16, seed: int = 0,
                 balanced: bool = True) -> List[List[str]]:
    """Random 16-app mixes for the Table-3 sweep (``repro.sim.sweep``).

    With ``balanced=True`` (default) each mix draws at least one application
    from every sensitivity class before filling uniformly, mirroring the
    composition of the paper's Table 2 mixes — every mix then has cache-,
    bandwidth- and prefetch-sensitive clients for the managers to trade off.
    Uniform draws (``balanced=False``) reproduce the §2.3 potential-study
    style instead.
    """
    from repro.sim.apps import APP_NAMES
    if balanced and apps_per_mix < len(_CLASS_BUCKETS):
        raise ValueError(
            f"balanced mixes need >= {len(_CLASS_BUCKETS)} apps per mix")
    rng = np.random.default_rng(seed)
    mixes: List[List[str]] = []
    for _ in range(n_mixes):
        apps: List[str] = []
        if balanced:
            for bucket in _CLASS_BUCKETS.values():
                apps.append(bucket[int(rng.integers(0, len(bucket)))])
        fill = apps_per_mix - len(apps)
        apps.extend(APP_NAMES[i]
                    for i in rng.integers(0, len(APP_NAMES), size=fill))
        rng.shuffle(apps)
        mixes.append(apps)
    return mixes
