"""Single-application characterization (paper §2.1, Fig. 2-4).

Reproduces the paper's sensitivity study: one application on one core, with
baseline allocation 512 kB / 4 GB/s, prefetch off; perturb one resource at a
time and classify:

  C-L: cache ->128 kB     C-H: cache ->2 MB
  B-L: bandwidth ->1 GB/s B-H: bandwidth ->16 GB/s
  P-B: prefetch on at baseline allocation

An application is cache/bandwidth/prefetch *sensitive* if any corresponding
perturbation moves IPC by >= 10% (paper: "10% deviation from the baseline
IPC"; prefetch slowdowns also count as sensitivity to throttling, since
disabling the prefetcher is the profitable action for them).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.sim import apps as apps_mod
from repro.sim import memsys
from repro.sim.apps import APP_NAMES, stack

SENSITIVITY_THRESHOLD = 0.10

# Single-app allocation points (units of 32 kB, GB/s).
BASE = (16, 4.0)     # 512 kB, 4 GB/s
C_L, C_H = 4, 64     # 128 kB, 2 MB
B_L, B_H = 1.0, 16.0


def _ipc(app: str, units: float, bw: float, pf: bool) -> float:
    arr = stack([app])
    ss = memsys.evaluate(
        arr, np.array([units], dtype=np.float64), np.array([bw]),
        np.array([pf]), cache_partitioned=True, bandwidth_partitioned=True)
    return float(ss.ipc[0])


def sensitivity_table() -> Dict[str, Dict[str, float]]:
    """Relative IPC change for every perturbation, per app (Fig. 2 data)."""
    out: Dict[str, Dict[str, float]] = {}
    for app in APP_NAMES:
        base = _ipc(app, *BASE, pf=False)
        out[app] = {
            "base_ipc": base,
            "C-L": _ipc(app, C_L, BASE[1], False) / base - 1.0,
            "C-H": _ipc(app, C_H, BASE[1], False) / base - 1.0,
            "B-L": _ipc(app, BASE[0], B_L, False) / base - 1.0,
            "B-H": _ipc(app, BASE[0], B_H, False) / base - 1.0,
            "P-B": _ipc(app, *BASE, pf=True) / base - 1.0,
        }
    return out


def classify(row: Dict[str, float]) -> str:
    cs = (abs(row["C-L"]) >= SENSITIVITY_THRESHOLD
          or abs(row["C-H"]) >= SENSITIVITY_THRESHOLD)
    bs = (abs(row["B-L"]) >= SENSITIVITY_THRESHOLD
          or abs(row["B-H"]) >= SENSITIVITY_THRESHOLD)
    # Paper §2.1: the PS class counts applications that are "sensitive to
    # prefetching and experience a speedup"; prefetch-averse applications
    # (e.g. xalancbmk) are handled by throttling but not labelled PS.
    ps = row["P-B"] >= SENSITIVITY_THRESHOLD
    tags = [t for t, on in (("CS", cs), ("BS", bs), ("PS", ps)) if on]
    return "-".join(tags) if tags else "I"


def classify_all() -> Dict[str, str]:
    return {app: classify(row) for app, row in sensitivity_table().items()}


def prefetch_vs_allocation(app: str) -> Dict[str, float]:
    """Fig. 3: prefetch speedup at L/B/H allocation scenarios."""
    res = {}
    for tag, (units, bw) in {
        "P-L": (C_L, B_L), "P-B": BASE, "P-H": (C_H, B_H),
    }.items():
        off = _ipc(app, units, bw, False)
        on = _ipc(app, units, bw, True)
        res[tag] = on / off - 1.0
    return res


def leslie3d_interactions() -> Dict[str, object]:
    """Fig. 4: pairwise interaction curves for leslie3d."""
    app = "leslie3d"
    bw_points = [1.0, 2.0, 4.0, 8.0, 16.0]
    cache_points = [4, 8, 16, 32, 64]
    fig4a = {  # IPC vs bandwidth, pf on/off (cache at baseline)
        "bw": bw_points,
        "off": [_ipc(app, BASE[0], b, False) for b in bw_points],
        "on": [_ipc(app, BASE[0], b, True) for b in bw_points],
    }
    fig4b = {  # prefetch speedup vs cache allocation (bw at baseline)
        "cache": cache_points,
        "speedup": [
            _ipc(app, c, BASE[1], True) / _ipc(app, c, BASE[1], False)
            for c in cache_points],
    }
    fig4c = {  # IPC vs cache allocation, pf on/off
        "cache": cache_points,
        "off": [_ipc(app, c, BASE[1], False) for c in cache_points],
        "on": [_ipc(app, c, BASE[1], True) for c in cache_points],
    }
    fig4d = {  # gain from 512kB->2MB vs bandwidth allocation
        "bw": bw_points,
        "gain": [
            _ipc(app, C_H, b, False) / _ipc(app, BASE[0], b, False) - 1.0
            for b in bw_points],
    }
    return {"fig4a": fig4a, "fig4b": fig4b, "fig4c": fig4c, "fig4d": fig4d}
