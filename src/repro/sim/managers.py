"""The resource-manager configurations of paper Table 3.

Every manager runs on the same :class:`~repro.sim.runner.CMPPlant`; the
subset managers reuse the CBP coordinator with the unmanaged resources
pinned, exactly mirroring how the paper builds its comparison points.
CPpf [Xiao et al. '19] is implemented per paper §4.4: prefetch-friendly
applications receive the minimum partition; UCP partitions the remaining
capacity among the rest; prefetching enabled; bandwidth unpartitioned.

``MANAGER_NAMES`` covers every ``TABLE3_MODES`` entry plus CPpf —
including "equal on" (equal partitions, prefetch enabled for everyone),
which earlier revisions silently skipped; ``tests/test_sim_managers.py``
pins the two in sync.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import (
    Allocation,
    CacheController,
    CBPCoordinator,
    CBPParams,
    Mode,
    PrefetchMode,
    throttle_decision,
)
from repro.core.atd import SampledATD
from repro.sim.runner import CMPPlant

MANAGER_NAMES = [
    "baseline", "equal off", "equal on", "only cache", "only bw",
    "only pref", "bw+pref", "bw+cache", "cache+pref", "CPpf", "CBP",
]

# (cache_mode, bandwidth_mode, prefetch_mode) per Table 3.
TABLE3_MODES = {
    "baseline":   (Mode.UNPARTITIONED, Mode.UNPARTITIONED, PrefetchMode.OFF),
    "equal off":  (Mode.EQUAL,         Mode.EQUAL,         PrefetchMode.OFF),
    "equal on":   (Mode.EQUAL,         Mode.EQUAL,         PrefetchMode.ON),
    "only cache": (Mode.DYNAMIC,       Mode.UNPARTITIONED, PrefetchMode.OFF),
    "only bw":    (Mode.UNPARTITIONED, Mode.DYNAMIC,       PrefetchMode.OFF),
    "only pref":  (Mode.UNPARTITIONED, Mode.UNPARTITIONED, PrefetchMode.DYNAMIC),
    "bw+pref":    (Mode.UNPARTITIONED, Mode.DYNAMIC,       PrefetchMode.DYNAMIC),
    "bw+cache":   (Mode.DYNAMIC,       Mode.DYNAMIC,       PrefetchMode.OFF),
    "cache+pref": (Mode.DYNAMIC,       Mode.UNPARTITIONED, PrefetchMode.DYNAMIC),
    "CBP":        (Mode.DYNAMIC,       Mode.DYNAMIC,       PrefetchMode.DYNAMIC),
}


@dataclasses.dataclass
class ManagerResult:
    name: str
    ipc: np.ndarray                 # time-weighted mean per-app IPC
    final_alloc: Optional[Allocation] = None


def run_manager(
    name: str,
    plant: CMPPlant,
    total_ms: float = 100.0,
    params: Optional[CBPParams] = None,
) -> ManagerResult:
    params = params or CBPParams()
    if name == "CPpf":
        return _run_cppf(plant, total_ms, params)
    cache_mode, bw_mode, pf_mode = TABLE3_MODES[name]
    coord = CBPCoordinator(
        plant, params=params,
        cache_mode=cache_mode, bandwidth_mode=bw_mode, prefetch_mode=pf_mode)
    coord.run(total_ms)
    return ManagerResult(name=name, ipc=coord.mean_ipc(),
                         final_alloc=coord.alloc)


def _run_cppf(plant: CMPPlant, total_ms: float,
              params: CBPParams) -> ManagerResult:
    """CPpf: prefetch-aware LLC partitioning (paper §4.4 implementation).

    Prefetch-friendly apps -> min allocation (prefetching offsets the small
    partition); UCP over the remaining capacity for the others; bandwidth
    unpartitioned; prefetching enabled.
    """
    n = plant.n_clients
    total_units = plant.total_cache_units
    atd = SampledATD(n, total_units)
    cache_ctl = CacheController(
        total_units, params.min_ways,
        backend=getattr(plant, "allocator_backend", "numpy"))

    equal_units = np.full(n, total_units // n, dtype=np.int64)
    bw = np.full(n, plant.total_bandwidth / n)

    def make_alloc(units: np.ndarray, pf_on: np.ndarray) -> Allocation:
        return Allocation(
            cache_units=units, bandwidth=bw.copy(), prefetch_on=pf_on,
            cache_mode=Mode.DYNAMIC, bandwidth_mode=Mode.UNPARTITIONED)

    # Friendliness probe (A/B sample at equal partitioning).
    off = plant.run_interval(
        make_alloc(equal_units, np.zeros(n, dtype=bool)),
        params.prefetch_sampling_period_ms)
    on = plant.run_interval(
        make_alloc(equal_units, np.ones(n, dtype=bool)),
        params.prefetch_sampling_period_ms)
    friendly = throttle_decision(on.ipc, off.ipc, params.speedup_threshold)

    pf_on = np.ones(n, dtype=bool)  # Table 3: prefetch setting "enabled"
    units = equal_units.copy()
    t = 0.0
    ipc_acc = np.zeros(n)
    w_acc = 0.0
    while t < total_ms - 1e-9:
        dt = min(params.reconfiguration_interval_ms, total_ms - t)
        stats = plant.run_interval(make_alloc(units, pf_on), dt)
        atd.record(stats.utility_curves * dt)
        ipc_acc += stats.ipc * dt
        w_acc += dt
        t += dt
        # Reallocate: friendly pinned at min; UCP for the rest over the
        # remaining capacity.
        curves = atd.utility_curves()
        atd.halve(params.atd_decay)
        units = cache_ctl.allocate_masked(curves, ~friendly)
    return ManagerResult(
        name="CPpf", ipc=ipc_acc / w_acc,
        final_alloc=make_alloc(units, pf_on))


def run_all_managers(
    workload: Sequence[str],
    total_ms: float = 100.0,
    names: Optional[List[str]] = None,
    params: Optional[CBPParams] = None,
    config=None,
) -> Dict[str, ManagerResult]:
    plant = CMPPlant(workload, config)
    return {
        name: run_manager(name, plant, total_ms, params)
        for name in (names or MANAGER_NAMES)
    }
