"""The resource-manager configurations of paper Table 3 plus the
registry's related-work families.

Every manager runs on the same :class:`~repro.sim.runner.CMPPlant`; the
subset managers reuse the CBP coordinator with the unmanaged resources
pinned, exactly mirroring how the paper builds its comparison points.
CPpf [Xiao et al. '19] is implemented per paper §4.4: prefetch-friendly
applications receive the minimum partition; UCP partitions the remaining
capacity among the rest; prefetching enabled; bandwidth unpartitioned.
The auction / QoS / banked-bandwidth families declared in
:mod:`repro.sim.policies` run through :func:`policy_loop`, the shared
numpy host golden the batched sweep's segment path reuses verbatim.

``MANAGER_NAMES`` and ``TABLE3_MODES`` are *derived* from the policy
registry (``tests/test_sim_managers.py`` pins registry completeness:
every family has a host golden, a traced branch and a static-grid
vocabulary), and this module attaches each family's ``host_golden`` at
import time so the registry itself stays free of plant imports.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import (
    Allocation,
    CacheController,
    CBPCoordinator,
    CBPParams,
    Mode,
    PrefetchMode,
    fig8_schedule,
    throttle_decision,
)
from repro.core.atd import SampledATD
from repro.core.bandwidth_controller import allocate_bandwidth
from repro.sim import policies
from repro.sim.policies import UnknownManagerError  # re-export
from repro.sim.runner import CMPPlant

MANAGER_NAMES = policies.manager_names()

# (cache_mode, bandwidth_mode, prefetch_mode) per Table 3 — the classic
# mode-combination subset of the registry.
TABLE3_MODES = policies.table3_modes()


@dataclasses.dataclass
class ManagerResult:
    name: str
    ipc: np.ndarray                 # time-weighted mean per-app IPC
    final_alloc: Optional[Allocation] = None


def run_manager(
    name: str,
    plant: CMPPlant,
    total_ms: float = 100.0,
    params: Optional[CBPParams] = None,
) -> ManagerResult:
    params = params or CBPParams()
    family = policies.get_family(name)   # raises UnknownManagerError
    if family.variant == "cppf":
        return _run_cppf(plant, total_ms, params)
    if family.modes is None:
        ipc, alloc = policy_loop(plant, family, total_ms, params)
        return ManagerResult(name=name, ipc=ipc, final_alloc=alloc)
    cache_mode, bw_mode, pf_mode = family.modes
    coord = CBPCoordinator(
        plant, params=params,
        cache_mode=cache_mode, bandwidth_mode=bw_mode, prefetch_mode=pf_mode)
    coord.run(total_ms)
    return ManagerResult(name=name, ipc=coord.mean_ipc(),
                         final_alloc=coord.alloc)


def policy_loop(
    plant,
    family: policies.PolicyFamily,
    total_ms: float,
    params: CBPParams,
    *,
    min_ways=None,
    min_bandwidth=None,
    atd_decay=None,
    bandwidth_delay_decay=None,
):
    """Numpy host golden for the registry's policy / banked families.

    Mirrors the stacked scan's boundary semantics op-for-op
    (:mod:`repro.sim.timeline_jax`): per executed interval the ATD
    counters accumulate ``curves * dt`` and the delay EMA advances by
    ``decay * acc + q_ns * dt`` (which starts as a plain copy, matching
    :class:`~repro.core.BandwidthController`'s first observe); the QoS
    slowdown reference is the first executed interval's IPC (the
    equal-share initial state) over the most recent one; at each Fig. 8
    boundary the family's allocators fire and THEN the ATD decays.

    Shape-agnostic over a leading batch axis: ``plant`` may be the scalar
    :class:`~repro.sim.runner.CMPPlant` (state ``(n,)``) or the sweep's
    ``BatchedCMPPlant`` (state ``(M, n)``), with the per-row tunable
    overrides the batched segment path threads through — which is how the
    sweep's segment backend and the scalar golden stay ONE function.

    Returns ``(mean_ipc, final Allocation)``.
    """
    n = plant.n_clients
    total_units = plant.total_cache_units
    total_bw = plant.total_bandwidth
    m = getattr(plant, "n_mixes", None)
    lead = () if m is None else (m,)

    if min_ways is None:
        min_ways = params.min_ways
    if min_bandwidth is None:
        min_bandwidth = params.min_bandwidth_allocation
    if atd_decay is None:
        atd_decay = params.atd_decay
    if bandwidth_delay_decay is None:
        bandwidth_delay_decay = params.bandwidth_delay_decay

    # auction/qos allocate both resources from their boundary branch;
    # "bank bw" keeps cache at the equal split and runs Algorithm 1
    # under the banked-token memory regime.
    is_policy = family.cache_policy != policies.CACHE_LOOKAHEAD
    cache_mode = Mode.DYNAMIC if is_policy else Mode.EQUAL

    units = np.full(n, total_units // n, dtype=np.int64)
    units[: total_units - int(units.sum())] += 1
    units = np.broadcast_to(units, lead + (n,)).copy()
    bw = np.full(lead + (n,), total_bw / n)
    pf = np.zeros(lead + (n,), dtype=bool)

    def make_alloc(units, bw):
        return Allocation(
            cache_units=units, bandwidth=bw, prefetch_on=pf,
            cache_mode=cache_mode, bandwidth_mode=Mode.DYNAMIC,
            bandwidth_banks=family.bandwidth_banks)

    atd = np.zeros(lead + (n, total_units + 1))
    bw_acc = np.zeros(lead + (n,))
    ref_ipc = np.zeros(lead + (n,))
    prev_ipc = np.zeros(lead + (n,))
    ipc_acc = np.zeros(lead + (n,))
    w_acc = 0.0
    for seg in fig8_schedule(total_ms, params, False):
        if seg.kind == "reconfigure":
            curves = atd.copy()
            if family.cache_policy == policies.CACHE_AUCTION:
                units, bw = policies.auction_allocate(
                    curves, bw_acc, min_ways=min_ways,
                    total_units=total_units, min_bandwidth=min_bandwidth,
                    total_bandwidth=total_bw)
            elif family.cache_policy == policies.CACHE_QOS:
                slow = np.where(
                    prev_ipc > 0,
                    ref_ipc / np.where(prev_ipc > 0, prev_ipc, 1.0), 1.0)
                units, bw = policies.qos_allocate(
                    curves, bw_acc, slow, min_ways=min_ways,
                    total_units=total_units, min_bandwidth=min_bandwidth,
                    total_bandwidth=total_bw)
            else:
                bw = allocate_bandwidth(bw_acc, total_bw, min_bandwidth)
            atd *= atd_decay
        else:
            dt = seg.duration_ms
            stats = plant.run_interval(make_alloc(units, bw), dt)
            atd += stats.utility_curves * dt
            bw_acc = bandwidth_delay_decay * bw_acc \
                + stats.queuing_delay_ns * dt
            ref_ipc = np.where(ref_ipc == 0.0, stats.ipc, ref_ipc)
            prev_ipc = stats.ipc
            ipc_acc += stats.ipc * dt
            w_acc += dt
    return ipc_acc / max(w_acc, 1e-12), make_alloc(units, bw)


def _run_cppf(plant: CMPPlant, total_ms: float,
              params: CBPParams) -> ManagerResult:
    """CPpf: prefetch-aware LLC partitioning (paper §4.4 implementation).

    Prefetch-friendly apps -> min allocation (prefetching offsets the small
    partition); UCP over the remaining capacity for the others; bandwidth
    unpartitioned; prefetching enabled.
    """
    n = plant.n_clients
    total_units = plant.total_cache_units
    atd = SampledATD(n, total_units)
    cache_ctl = CacheController(
        total_units, params.min_ways,
        backend=getattr(plant, "allocator_backend", "numpy"))

    equal_units = np.full(n, total_units // n, dtype=np.int64)
    bw = np.full(n, plant.total_bandwidth / n)

    def make_alloc(units: np.ndarray, pf_on: np.ndarray) -> Allocation:
        return Allocation(
            cache_units=units, bandwidth=bw.copy(), prefetch_on=pf_on,
            cache_mode=Mode.DYNAMIC, bandwidth_mode=Mode.UNPARTITIONED)

    # Friendliness probe (A/B sample at equal partitioning).
    off = plant.run_interval(
        make_alloc(equal_units, np.zeros(n, dtype=bool)),
        params.prefetch_sampling_period_ms)
    on = plant.run_interval(
        make_alloc(equal_units, np.ones(n, dtype=bool)),
        params.prefetch_sampling_period_ms)
    friendly = throttle_decision(on.ipc, off.ipc, params.speedup_threshold)

    pf_on = np.ones(n, dtype=bool)  # Table 3: prefetch setting "enabled"
    units = equal_units.copy()
    t = 0.0
    ipc_acc = np.zeros(n)
    w_acc = 0.0
    while t < total_ms - 1e-9:
        dt = min(params.reconfiguration_interval_ms, total_ms - t)
        stats = plant.run_interval(make_alloc(units, pf_on), dt)
        atd.record(stats.utility_curves * dt)
        ipc_acc += stats.ipc * dt
        w_acc += dt
        t += dt
        # Reallocate: friendly pinned at min; UCP for the rest over the
        # remaining capacity.
        curves = atd.utility_curves()
        atd.halve(params.atd_decay)
        units = cache_ctl.allocate_masked(curves, ~friendly)
    return ManagerResult(
        name="CPpf", ipc=ipc_acc / w_acc,
        final_alloc=make_alloc(units, pf_on))


def run_all_managers(
    workload: Sequence[str],
    total_ms: float = 100.0,
    names: Optional[List[str]] = None,
    params: Optional[CBPParams] = None,
    config=None,
) -> Dict[str, ManagerResult]:
    plant = CMPPlant(workload, config)
    return {
        name: run_manager(name, plant, total_ms, params)
        for name in (names or MANAGER_NAMES)
    }


# Attach every family's scalar host golden to the registry (the registry
# module itself never imports the plant stack, so this is the one place
# the binding can happen without an import cycle).
for _name in policies.manager_names():
    _fam = policies.get_family(_name)
    if _fam.host_golden is None:
        _fam.host_golden = functools.partial(run_manager, _name)
del _name, _fam
