"""Batched Table-3 sweep runner (the Figs. 9-12 evaluation substrate).

The paper's headline results come from running ten resource-manager
configurations over dozens of 16-core workload mixes.  The scalar path
(:func:`repro.sim.managers.run_all_managers`) evaluates one (mix, manager)
pair at a time; this module stacks all mixes along a leading batch axis and
drives the jitted JAX interval model (:mod:`repro.sim.memsys_jax`), so each
timeline segment of each manager is ONE device call covering every mix —
no Python loop ever calls ``memsys.evaluate`` per (mix, manager) pair.

Structure:

* :class:`BatchedCMPPlant` — the CMP interval model over M stacked mixes;
  ``run_interval`` takes (M, n) allocation arrays and returns (M, n) stats.
* :class:`BatchedCoordinator` — :class:`~repro.core.CBPCoordinator`
  vectorized over the mix axis.  It executes exactly the same
  :func:`~repro.core.fig8_schedule` segment list, so scalar and batched
  trajectories cannot drift apart on scheduling.  Only the integer
  Lookahead allocator runs per mix (a data-dependent greedy loop).
* :func:`run_sweep` — evaluate a set of managers over a set of mixes;
  returns a :class:`SweepResult` with per-mix IPC, weighted speedup and
  ANTT against the shared unpartitioned baseline.

Parity contract: with the same mixes and parameters, per-mix results match
the scalar numpy path up to the 1e-5 model tolerance (and bit-identical
controller decisions away from knife-edges) — see ``tests/test_sim_sweep.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import (
    Allocation,
    BandwidthController,
    CBPParams,
    Mode,
    PrefetchMode,
    fig8_schedule,
    lookahead_allocate,
    throttle_decision,
)
from repro.core.types import IntervalStats
from repro.sim import memsys, memsys_jax
from repro.sim.apps import AppArrays, stack_mixes
from repro.sim.managers import MANAGER_NAMES, TABLE3_MODES
from repro.sim.runner import CMPConfig


class BatchedCMPPlant:
    """The 16-core CMP interval model over M stacked workload mixes.

    Allocation arrays carry a leading mix axis — ``cache_units`` etc. are
    (M, n) — and every ``run_interval`` is one jitted device call.
    """

    def __init__(self, mixes: Sequence[Sequence[str]],
                 config: Optional[CMPConfig] = None):
        self.mixes: List[List[str]] = [list(m) for m in mixes]
        self.apps: AppArrays = stack_mixes(self.mixes)
        self.config = config or CMPConfig()
        if self.config.backend not in ("numpy", "jax"):
            raise ValueError(f"unknown backend {self.config.backend!r}")
        # config.backend selects the SCALAR plant's model implementation;
        # the batched plant is the JAX path by construction and uses the
        # remaining CMPConfig fields (capacities, llc_extra_cycles) as-is.
        self.n_mixes, self.n_clients = np.asarray(self.apps.cpi_base).shape
        self.total_cache_units = self.config.total_cache_units
        self.total_bandwidth = self.config.total_bandwidth

    def evaluate(self, alloc: Allocation) -> memsys.SteadyState:
        return memsys_jax.evaluate(
            self.apps,
            np.asarray(alloc.cache_units, dtype=np.float64),
            alloc.bandwidth,
            alloc.prefetch_on,
            cache_partitioned=alloc.cache_mode != Mode.UNPARTITIONED,
            bandwidth_partitioned=alloc.bandwidth_mode != Mode.UNPARTITIONED,
            total_cache_units=float(self.total_cache_units),
            total_bandwidth_gbps=self.total_bandwidth,
            llc_extra_cycles=self.config.llc_extra_cycles,
        )

    def run_interval(self, alloc: Allocation,
                     duration_ms: float) -> IntervalStats:
        ss = self.evaluate(alloc)
        curves = memsys_jax.utility_curves(
            self.apps, alloc.prefetch_on, ss.ipc,
            self.total_cache_units, duration_ms=1.0)
        ipc = np.asarray(ss.ipc)
        return IntervalStats(
            ipc=ipc,
            queuing_delay_ns=np.asarray(ss.queuing_delay_ns),
            utility_curves=np.asarray(curves),
            instructions=ipc * memsys.FREQ_GHZ * 1e6 * duration_ms,
        )


def baseline_ipc_batched(plant: BatchedCMPPlant) -> np.ndarray:
    """Paper baseline per mix: unpartitioned everything, prefetch off."""
    m, n = plant.n_mixes, plant.n_clients
    alloc = Allocation(
        cache_units=np.full((m, n), plant.total_cache_units // n),
        bandwidth=np.full((m, n), plant.total_bandwidth / n),
        prefetch_on=np.zeros((m, n), dtype=bool),
        cache_mode=Mode.UNPARTITIONED,
        bandwidth_mode=Mode.UNPARTITIONED,
    )
    return np.asarray(plant.evaluate(alloc).ipc)


class BatchedCoordinator:
    """One Table-3 manager, coordinated across all mixes in lockstep.

    Mirrors :class:`repro.core.CBPCoordinator` state-for-state with a
    leading mix axis: ATD counters are (M, n, U+1), the shared
    :class:`~repro.core.BandwidthController` accumulates (M, n) delays,
    and the prefetch A/B decision is elementwise.  All mixes share one
    Fig. 8 timeline (it depends only on the manager's prefetch mode),
    which is what makes lockstep exact.
    """

    def __init__(
        self,
        plant: BatchedCMPPlant,
        params: Optional[CBPParams] = None,
        cache_mode: Mode = Mode.DYNAMIC,
        bandwidth_mode: Mode = Mode.DYNAMIC,
        prefetch_mode: PrefetchMode = PrefetchMode.DYNAMIC,
    ):
        self.plant = plant
        self.params = params or CBPParams()
        self.cache_mode = cache_mode
        self.bandwidth_mode = bandwidth_mode
        self.prefetch_mode = prefetch_mode

        m, n = plant.n_mixes, plant.n_clients
        self._atd = np.zeros((m, n, plant.total_cache_units + 1))
        self.bw_ctl = BandwidthController(
            plant.total_bandwidth, self.params.min_bandwidth_allocation)
        self._ipc_acc = np.zeros((m, n))
        self._w_acc = 0.0

        units = np.full(n, plant.total_cache_units // n, dtype=np.int64)
        units[: plant.total_cache_units - int(units.sum())] += 1
        self.alloc = Allocation(
            cache_units=np.tile(units, (m, 1)),
            bandwidth=np.full((m, n), plant.total_bandwidth / n),
            prefetch_on=np.full((m, n), prefetch_mode == PrefetchMode.ON,
                                dtype=bool),
            cache_mode=cache_mode,
            bandwidth_mode=bandwidth_mode,
        )

    # ------------------------------------------------------------------ #

    def _run(self, alloc: Allocation, duration_ms: float) -> IntervalStats:
        stats = self.plant.run_interval(alloc, duration_ms)
        self._atd += stats.utility_curves * duration_ms
        self.bw_ctl.observe(stats.queuing_delay_ns * duration_ms)
        self._ipc_acc += stats.ipc * duration_ms
        self._w_acc += duration_ms
        return stats

    def _reconfigure(self) -> None:
        if self.cache_mode == Mode.DYNAMIC:
            for i in range(self.plant.n_mixes):
                self.alloc.cache_units[i] = lookahead_allocate(
                    self._atd[i], self.plant.total_cache_units,
                    self.params.min_ways)
        self._atd *= 0.5
        if self.bandwidth_mode == Mode.DYNAMIC:
            self.alloc.bandwidth = self.bw_ctl.allocate()

    def _with_prefetch(self, value: bool) -> Allocation:
        alloc = self.alloc.copy()
        alloc.prefetch_on = np.full(
            (self.plant.n_mixes, self.plant.n_clients), value, dtype=bool)
        return alloc

    # ------------------------------------------------------------------ #

    def run(self, total_ms: float) -> None:
        stats_off: Optional[IntervalStats] = None
        schedule = fig8_schedule(
            total_ms, self.params,
            self.prefetch_mode == PrefetchMode.DYNAMIC)
        for seg in schedule:
            if seg.kind == "reconfigure":
                self._reconfigure()
            elif seg.kind == "sample_off":
                stats_off = self._run(self._with_prefetch(False),
                                      seg.duration_ms)
            elif seg.kind == "sample_on":
                stats_on = self._run(self._with_prefetch(True),
                                     seg.duration_ms)
                self.alloc.prefetch_on = throttle_decision(
                    stats_on.ipc, stats_off.ipc,
                    self.params.speedup_threshold)
            else:
                self._run(self.alloc, seg.duration_ms)

    def mean_ipc(self) -> np.ndarray:
        return self._ipc_acc / max(self._w_acc, 1e-12)


def _run_cppf_batched(plant: BatchedCMPPlant, total_ms: float,
                      params: CBPParams):
    """Vectorized CPpf (mirrors ``managers._run_cppf`` per mix)."""
    m, n = plant.n_mixes, plant.n_clients
    total_units = plant.total_cache_units
    equal_units = np.full((m, n), total_units // n, dtype=np.int64)
    bw = np.full((m, n), plant.total_bandwidth / n)

    def make_alloc(units: np.ndarray, pf_on: np.ndarray) -> Allocation:
        return Allocation(
            cache_units=units, bandwidth=bw.copy(), prefetch_on=pf_on,
            cache_mode=Mode.DYNAMIC, bandwidth_mode=Mode.UNPARTITIONED)

    off = plant.run_interval(
        make_alloc(equal_units, np.zeros((m, n), dtype=bool)),
        params.prefetch_sampling_period_ms)
    on = plant.run_interval(
        make_alloc(equal_units, np.ones((m, n), dtype=bool)),
        params.prefetch_sampling_period_ms)
    friendly = throttle_decision(on.ipc, off.ipc, params.speedup_threshold)

    pf_on = np.ones((m, n), dtype=bool)
    units = equal_units.copy()
    atd = np.zeros((m, n, total_units + 1))
    ipc_acc = np.zeros((m, n))
    w_acc = 0.0
    t = 0.0
    while t < total_ms - 1e-9:
        dt = min(params.reconfiguration_interval_ms, total_ms - t)
        stats = plant.run_interval(make_alloc(units, pf_on), dt)
        atd += stats.utility_curves * dt
        ipc_acc += stats.ipc * dt
        w_acc += dt
        t += dt
        curves = atd.copy()
        atd *= 0.5
        for i in range(m):
            others = np.where(~friendly[i])[0]
            u = np.full(n, params.min_ways, dtype=np.int64)
            remaining = total_units - params.min_ways * int(friendly[i].sum())
            if len(others) > 0:
                u[others] = lookahead_allocate(
                    curves[i][others][:, : remaining + 1], remaining,
                    params.min_ways)
            else:
                u += (total_units - int(u.sum())) // n
            units[i] = u
    return ipc_acc / w_acc, make_alloc(units, pf_on)


@dataclasses.dataclass
class SweepResult:
    """Per-(manager, mix, app) outcome of one sweep."""

    manager_names: List[str]
    mixes: List[List[str]]
    ipc: Dict[str, np.ndarray]            # name -> (M, n)
    final_alloc: Dict[str, Allocation]    # name -> batched (M, n) allocation
    baseline_ipc: np.ndarray              # (M, n)

    @property
    def n_mixes(self) -> int:
        return len(self.mixes)

    def weighted_speedup(self, name: str) -> np.ndarray:
        """Paper §4.3 weighted speedup per mix, shape (M,)."""
        return np.mean(self.ipc[name] / self.baseline_ipc, axis=-1)

    def antt(self, name: str) -> np.ndarray:
        """Paper §4.3 avg normalized turnaround time per mix, shape (M,)."""
        return np.mean(self.baseline_ipc / self.ipc[name], axis=-1)

    def geomean_speedup(self, name: str) -> float:
        return float(np.exp(np.mean(np.log(self.weighted_speedup(name)))))

    def summary(self) -> Dict[str, float]:
        """Geomean weighted speedup per manager over all mixes."""
        return {name: round(self.geomean_speedup(name), 4)
                for name in self.manager_names}


def run_sweep(
    mixes: Sequence[Sequence[str]],
    managers: Optional[Sequence[str]] = None,
    total_ms: float = 100.0,
    params: Optional[CBPParams] = None,
    config: Optional[CMPConfig] = None,
) -> SweepResult:
    """Evaluate Table-3 managers over many mixes in batched device calls.

    Args:
      mixes: equal-size workload mixes (lists of app names) — e.g.
        ``list(WORKLOADS.values())`` or :func:`repro.sim.random_mixes`.
      managers: manager names (default: all ten ``MANAGER_NAMES``).
      total_ms / params / config: as in ``managers.run_manager``.
    """
    plant = BatchedCMPPlant(mixes, config)
    params = params or CBPParams()
    names = list(MANAGER_NAMES) if managers is None else list(managers)
    unknown = [n for n in names if n != "CPpf" and n not in TABLE3_MODES]
    if unknown:
        raise ValueError(
            f"unknown managers {unknown}; valid: {MANAGER_NAMES}")
    ipc: Dict[str, np.ndarray] = {}
    final: Dict[str, Allocation] = {}
    for name in names:
        if name == "CPpf":
            ipc[name], final[name] = _run_cppf_batched(
                plant, total_ms, params)
            continue
        cache_mode, bw_mode, pf_mode = TABLE3_MODES[name]
        coord = BatchedCoordinator(
            plant, params=params, cache_mode=cache_mode,
            bandwidth_mode=bw_mode, prefetch_mode=pf_mode)
        coord.run(total_ms)
        ipc[name] = coord.mean_ipc()
        final[name] = coord.alloc
    return SweepResult(
        manager_names=names,
        mixes=plant.mixes,
        ipc=ipc,
        final_alloc=final,
        baseline_ipc=baseline_ipc_batched(plant),
    )
