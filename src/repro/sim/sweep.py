"""Batched Table-3 sweep runner (the Figs. 9-12 evaluation substrate).

The paper's headline results come from running the Table-3 resource-manager
configurations over dozens of 16-core workload mixes.  The scalar path
(:func:`repro.sim.managers.run_all_managers`) evaluates one (mix, manager)
pair at a time; this module stacks all mixes along a leading batch axis and
drives the jitted JAX interval model (:mod:`repro.sim.memsys_jax`), so each
timeline segment of each manager is ONE device call covering every mix —
no Python loop ever calls ``memsys.evaluate`` per (mix, manager) pair.

Since PR 2 the Lookahead cache allocator is batched too
(:mod:`repro.core.cache_controller_jax`): every reconfiguration boundary is
one jitted device call over all mixes, so a full sweep performs **zero**
per-mix host allocator calls (assert with
:func:`repro.core.allocator_calls`) and host transfers drop to one per
Fig. 8 segment.  CPpf's friendly-mask allocation is vectorized the same
way (`CacheController.allocate_masked`).

Since PR 3 the whole Fig. 8 timeline of each manager is ONE jitted device
program (:mod:`repro.sim.timeline_jax`): the bandwidth controller and the
prefetch throttle run inside the scan next to the batched Lookahead
allocator, so a full sweep performs zero per-segment host transfers.
Since PR 5 the *manager axis* is batched too: every Table-3 manager's
segment table and knob flags stack along a leading axis inside one
program (:func:`repro.sim.timeline_jax.run_timelines`), so a full sweep
is AT MOST TWO device dispatches — the stacked manager set plus the
shared baseline evaluation (counter:
:func:`repro.core.device_dispatches`) — and the 2-D (manager, mix) grid
shards across devices via :func:`repro.distributed.shard_grid`.  The
PR 3/4 one-program-per-manager path survives as
``CMPConfig(timeline_backend="fused")`` (the stacking parity reference —
bit-identical per-(manager, mix) results), the PR 2 per-segment host
loop as ``CMPConfig(timeline_backend="segment")`` (parity/debug).

Structure:

* :class:`BatchedCMPPlant` — the CMP interval model over M stacked mixes;
  ``run_interval`` takes (M, n) allocation arrays and returns (M, n) stats.
* :class:`BatchedCoordinator` — :class:`~repro.core.CBPCoordinator`
  vectorized over the mix axis.  It executes exactly the same
  :func:`~repro.core.fig8_schedule` segment list (fused into one program
  by default), so scalar and batched trajectories cannot drift apart on
  scheduling.  ``params_rows`` lets each batch row carry its own
  non-schedule ``CBPParams`` (min_ways, speedup_threshold,
  min_bandwidth_allocation, atd_decay, bandwidth_delay_decay), which is
  how ``param_grid`` sweeps batch the Fig. 12 design space.
* :func:`run_sweep` — evaluate a set of managers over a set of mixes (and
  optionally a leading ``CBPParams`` axis via ``param_grid=``); returns a
  :class:`SweepResult` with per-mix IPC, weighted speedup and ANTT against
  the shared unpartitioned baseline.

Parity contract: with the same mixes and parameters, per-mix results match
the scalar numpy path up to the 1e-5 model tolerance (and bit-identical
controller decisions away from knife-edges) — see ``tests/test_sim_sweep.py``,
``tests/test_timeline_fused.py`` and ``tests/test_cache_controller_jax.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import (
    Allocation,
    BandwidthController,
    CacheController,
    CBPParams,
    Mode,
    PrefetchMode,
    ScheduleSegment,
    fig8_schedule,
    throttle_decision,
)
from repro.core.types import IntervalStats
from repro.sim import memsys, memsys_jax, policies, timeline_jax
from repro.sim.apps import AppArrays, stack_mixes
from repro.sim.managers import MANAGER_NAMES, TABLE3_MODES, policy_loop
from repro.sim.runner import (
    CMPConfig,
    _resolve_allocator_backend,
    _resolve_timeline_backend,
    equal_share,
)


class CapacityInvariantError(RuntimeError):
    """An allocation violated its sums-to-capacity invariant.

    Raised (never ``assert``-ed: the check must survive ``python -O``)
    when a batched cache allocation does not sum to ``total_cache_units``
    per mix, or a dynamic bandwidth allocation does not sum to
    ``total_bandwidth``.
    """


def _check_units_capacity(units: np.ndarray, total_units: int,
                          where: str) -> None:
    sums = np.asarray(units).sum(axis=-1)
    if not (sums == total_units).all():
        raise CapacityInvariantError(
            f"{where}: cache allocation sums {np.unique(sums)} != "
            f"total_cache_units {total_units}")


def _check_bandwidth_capacity(bandwidth: np.ndarray, total_bandwidth: float,
                              where: str) -> None:
    sums = np.asarray(bandwidth).sum(axis=-1)
    if not np.allclose(sums, total_bandwidth, rtol=1e-9, atol=1e-6):
        raise CapacityInvariantError(
            f"{where}: bandwidth allocation sums in "
            f"[{sums.min()}, {sums.max()}] != total_bandwidth "
            f"{total_bandwidth}")


class BatchedCMPPlant:
    """The 16-core CMP interval model over M stacked workload mixes.

    Allocation arrays carry a leading mix axis — ``cache_units`` etc. are
    (M, n) — and every ``run_interval`` is one jitted device call.
    """

    def __init__(self, mixes: Sequence[Sequence[str]],
                 config: Optional[CMPConfig] = None):
        self.mixes: List[List[str]] = [list(m) for m in mixes]
        self.apps: AppArrays = stack_mixes(self.mixes)
        self.config = config or CMPConfig()
        if self.config.backend not in ("numpy", "jax"):
            raise ValueError(f"unknown backend {self.config.backend!r}")
        # config.backend selects the SCALAR plant's model implementation;
        # the batched plant is the JAX path by construction and uses the
        # remaining CMPConfig fields (capacities, llc_extra_cycles) as-is.
        # The allocator follows suit: "auto" keeps allocation on device,
        # and "auto" timelines stack the whole manager set into one device
        # program — unless the allocator was forced onto the host, which
        # only the segment loop can honour (the fused greedy is traced).
        self.allocator_backend = _resolve_allocator_backend(
            self.config, default="jax")
        self.timeline_backend = _resolve_timeline_backend(
            self.config,
            default="stacked" if self.allocator_backend == "jax"
            else "segment")
        self.n_mixes, self.n_clients = np.asarray(self.apps.cpi_base).shape
        self.total_cache_units = self.config.total_cache_units
        self.total_bandwidth = self.config.total_bandwidth

    def evaluate(self, alloc: Allocation) -> memsys.SteadyState:
        return memsys_jax.evaluate(
            self.apps,
            np.asarray(alloc.cache_units, dtype=np.float64),
            alloc.bandwidth,
            alloc.prefetch_on,
            cache_partitioned=alloc.cache_mode != Mode.UNPARTITIONED,
            bandwidth_partitioned=alloc.bandwidth_mode != Mode.UNPARTITIONED,
            total_cache_units=float(self.total_cache_units),
            total_bandwidth_gbps=self.total_bandwidth,
            llc_extra_cycles=self.config.llc_extra_cycles,
            bandwidth_banks=alloc.bandwidth_banks,
        )

    def run_interval(self, alloc: Allocation,
                     duration_ms: float) -> IntervalStats:
        ss = self.evaluate(alloc)
        curves = memsys_jax.utility_curves(
            self.apps, alloc.prefetch_on, ss.ipc,
            self.total_cache_units, duration_ms=1.0)
        ipc = np.asarray(ss.ipc)
        return IntervalStats(
            ipc=ipc,
            queuing_delay_ns=np.asarray(ss.queuing_delay_ns),
            utility_curves=np.asarray(curves),
            instructions=ipc * memsys.FREQ_GHZ * 1e6 * duration_ms,
        )


def baseline_ipc_batched(plant: BatchedCMPPlant) -> np.ndarray:
    """Paper baseline per mix: unpartitioned everything, prefetch off."""
    m, n = plant.n_mixes, plant.n_clients
    units, bw = equal_share(n, plant.total_cache_units, plant.total_bandwidth)
    alloc = Allocation(
        cache_units=np.tile(units, (m, 1)),
        bandwidth=np.tile(bw, (m, 1)),
        prefetch_on=np.zeros((m, n), dtype=bool),
        cache_mode=Mode.UNPARTITIONED,
        bandwidth_mode=Mode.UNPARTITIONED,
    )
    return np.asarray(plant.evaluate(alloc).ipc)


@dataclasses.dataclass
class RowParams:
    """Per-batch-row ``CBPParams`` tunables, broadcast-ready.

    ``schedule`` carries the schedule-shaping fields (common to the whole
    batch); the five non-schedule tunables are scalars without
    ``params_rows`` and per-row arrays with it — min_ways ``(M,)``,
    speedup_threshold / min_bandwidth_allocation / bandwidth_delay_decay
    ``(M, 1)`` (broadcasting against (M, n) state) and atd_decay
    ``(M, 1, 1)`` (against the (M, n, U+1) ATD counters).
    """

    schedule: CBPParams
    min_ways: object
    speedup_threshold: object
    min_bandwidth_allocation: object
    atd_decay: object
    bandwidth_delay_decay: object


def _per_row_params(
    params: CBPParams,
    params_rows: Optional[Sequence[CBPParams]],
    n_rows: int,
) -> RowParams:
    """Resolve the per-row tunables of a (possibly params-batched) sweep.

    With ``params_rows`` the non-schedule tunables become per-row arrays;
    the schedule-shaping fields must agree across rows because every batch
    row executes the same Fig. 8 segment list in lockstep.
    """
    if params_rows is None:
        return RowParams(
            schedule=params,
            min_ways=params.min_ways,
            speedup_threshold=params.speedup_threshold,
            min_bandwidth_allocation=params.min_bandwidth_allocation,
            atd_decay=params.atd_decay,
            bandwidth_delay_decay=params.bandwidth_delay_decay,
        )
    rows = list(params_rows)
    if len(rows) != n_rows:
        raise ValueError(
            f"params_rows has {len(rows)} entries for {n_rows} batch rows")
    sched = {(p.reconfiguration_interval_ms, p.prefetch_sampling_period_ms)
             for p in rows}
    if len(sched) > 1:
        raise ValueError(
            "params_rows must share reconfiguration_interval_ms and "
            "prefetch_sampling_period_ms (the Fig. 8 schedule is common to "
            f"the whole batch); got {sorted(sched)}")
    return RowParams(
        schedule=rows[0],
        min_ways=np.array([p.min_ways for p in rows], dtype=np.int64),
        speedup_threshold=np.array(
            [p.speedup_threshold for p in rows])[:, None],
        min_bandwidth_allocation=np.array(
            [p.min_bandwidth_allocation for p in rows])[:, None],
        atd_decay=np.array([p.atd_decay for p in rows])[:, None, None],
        bandwidth_delay_decay=np.array(
            [p.bandwidth_delay_decay for p in rows])[:, None],
    )


class BatchedCoordinator:
    """One Table-3 manager, coordinated across all mixes in lockstep.

    Mirrors :class:`repro.core.CBPCoordinator` state-for-state with a
    leading mix axis: ATD counters are (M, n, U+1), the shared
    :class:`~repro.core.BandwidthController` accumulates (M, n) delays,
    and the prefetch A/B decision is elementwise.  All mixes share one
    Fig. 8 timeline (it depends only on the manager's prefetch mode and
    the schedule-shaping params), which is what makes lockstep exact.
    Cache allocation is one batched device call per reconfiguration
    boundary (:class:`~repro.core.CacheController` with the plant's
    allocator backend) — never a per-mix host loop.
    """

    def __init__(
        self,
        plant: BatchedCMPPlant,
        params: Optional[CBPParams] = None,
        cache_mode: Mode = Mode.DYNAMIC,
        bandwidth_mode: Mode = Mode.DYNAMIC,
        prefetch_mode: PrefetchMode = PrefetchMode.DYNAMIC,
        params_rows: Optional[Sequence[CBPParams]] = None,
    ):
        self.plant = plant
        self.cache_mode = cache_mode
        self.bandwidth_mode = bandwidth_mode
        self.prefetch_mode = prefetch_mode

        m, n = plant.n_mixes, plant.n_clients
        self.rows = _per_row_params(params or CBPParams(), params_rows, m)
        self.params = self.rows.schedule
        self._min_ways = self.rows.min_ways
        self._thr = self.rows.speedup_threshold
        self._ipc_acc = np.zeros((m, n))
        self._w_acc = 0.0

        units = np.full(n, plant.total_cache_units // n, dtype=np.int64)
        units[: plant.total_cache_units - int(units.sum())] += 1
        self.alloc = Allocation(
            cache_units=np.tile(units, (m, 1)),
            bandwidth=np.full((m, n), plant.total_bandwidth / n),
            prefetch_on=np.full((m, n), prefetch_mode == PrefetchMode.ON,
                                dtype=bool),
            cache_mode=cache_mode,
            bandwidth_mode=bandwidth_mode,
        )

    # ------------------------------------------------------------------ #

    def _run(self, alloc: Allocation, duration_ms: float) -> IntervalStats:
        stats = self.plant.run_interval(alloc, duration_ms)
        self._atd += stats.utility_curves * duration_ms
        self.bw_ctl.observe(stats.queuing_delay_ns * duration_ms)
        self._ipc_acc += stats.ipc * duration_ms
        self._w_acc += duration_ms
        return stats

    def _reconfigure(self) -> None:
        if self.cache_mode == Mode.DYNAMIC:
            self.alloc.cache_units = self.cache_ctl.allocate(
                self._atd, min_units=self._min_ways)
        self._atd *= self.rows.atd_decay
        if self.bandwidth_mode == Mode.DYNAMIC:
            self.alloc.bandwidth = self.bw_ctl.allocate()

    def _with_prefetch(self, value: bool) -> Allocation:
        alloc = self.alloc.copy()
        alloc.prefetch_on = np.full(
            (self.plant.n_mixes, self.plant.n_clients), value, dtype=bool)
        return alloc

    # ------------------------------------------------------------------ #

    def run(self, total_ms: float) -> None:
        """Execute the Fig. 8 timeline over every batch row.

        The default path compiles the whole timeline — every controller
        decision included — into one jitted device program (the K=1 case
        of :func:`repro.sim.timeline_jax.run_timelines`, built from the
        same :func:`_fig8_spec` wiring the stacked sweep uses); the
        "segment" path is the PR 2 host loop of one device call per
        segment, kept for parity testing and debugging.  Both execute the
        identical :func:`~repro.core.fig8_schedule` segment list.
        """
        if self.plant.timeline_backend == "segment":
            self._run_segments(fig8_schedule(
                total_ms, self.params,
                self.prefetch_mode == PrefetchMode.DYNAMIC))
        else:
            # "fused" and "stacked" coincide for a single manager: the
            # per-manager fused program IS the K=1 stacked program.
            self._run_fused(total_ms)
        if self.cache_mode == Mode.DYNAMIC:
            _check_units_capacity(
                self.alloc.cache_units, self.plant.total_cache_units,
                "BatchedCoordinator.run")
        if self.bandwidth_mode == Mode.DYNAMIC:
            _check_bandwidth_capacity(
                self.alloc.bandwidth, self.plant.total_bandwidth,
                "BatchedCoordinator.run")

    def _run_fused(self, total_ms: float) -> None:
        spec = _fig8_spec(self.plant, self.cache_mode, self.bandwidth_mode,
                          self.prefetch_mode, total_ms, self.params)
        res = timeline_jax.run_timelines(
            self.plant.apps, [spec],
            total_units=self.plant.total_cache_units,
            total_bandwidth=self.plant.total_bandwidth,
            llc_extra_cycles=self.plant.config.llc_extra_cycles,
            min_ways=self._min_ways,
            speedup_threshold=self._thr,
            min_bandwidth_allocation=self.rows.min_bandwidth_allocation,
            atd_decay=self.rows.atd_decay,
            bandwidth_delay_decay=self.rows.bandwidth_delay_decay,
        )[0]
        self._ipc_acc = res.ipc_acc
        self._w_acc = res.w_acc
        self.alloc.cache_units = res.cache_units
        self.alloc.bandwidth = res.bandwidth
        self.alloc.prefetch_on = res.prefetch_on

    def _run_segments(self, schedule) -> None:
        # Host-side controller state exists only on this path: the fused
        # program keeps the ATD counters, the delay accumulator and the
        # greedy entirely on device, so building these in __init__ would
        # leave ~1 MB of dead, stale arrays per fused coordinator.
        plant = self.plant
        m, n = plant.n_mixes, plant.n_clients
        self.cache_ctl = CacheController(
            plant.total_cache_units, self.params.min_ways,
            backend=plant.allocator_backend)
        self._atd = np.zeros((m, n, plant.total_cache_units + 1))
        self.bw_ctl = BandwidthController(
            plant.total_bandwidth, self.rows.min_bandwidth_allocation,
            decay=self.rows.bandwidth_delay_decay)
        stats_off: Optional[IntervalStats] = None
        for seg in schedule:
            if seg.kind == "reconfigure":
                self._reconfigure()
            elif seg.kind == "sample_off":
                stats_off = self._run(self._with_prefetch(False),
                                      seg.duration_ms)
            elif seg.kind == "sample_on":
                stats_on = self._run(self._with_prefetch(True),
                                     seg.duration_ms)
                self.alloc.prefetch_on = throttle_decision(
                    stats_on.ipc, stats_off.ipc, self._thr)
            else:
                self._run(self.alloc, seg.duration_ms)

    def mean_ipc(self) -> np.ndarray:
        return self._ipc_acc / max(self._w_acc, 1e-12)


def _run_cppf_batched(plant: BatchedCMPPlant, total_ms: float,
                      params: CBPParams,
                      params_rows: Optional[Sequence[CBPParams]] = None):
    """Vectorized CPpf on the SEGMENT path (mirrors ``managers._run_cppf``).

    Each friendly-mask allocation is ONE batched device call per
    reconfiguration (``CacheController.allocate_masked``).  The fused
    paths never come here: :func:`_manager_spec` is the single source of
    CPpf's fused timeline wiring (``variant="cppf"`` via
    ``timeline_jax.run_timelines``).
    """
    m, n = plant.n_mixes, plant.n_clients
    total_units = plant.total_cache_units
    rows = _per_row_params(params, params_rows, m)
    params = rows.schedule
    equal_units = np.full((m, n), total_units // n, dtype=np.int64)
    bw = np.full((m, n), plant.total_bandwidth / n)

    def make_alloc(units: np.ndarray, pf_on: np.ndarray) -> Allocation:
        return Allocation(
            cache_units=units, bandwidth=bw.copy(), prefetch_on=pf_on,
            cache_mode=Mode.DYNAMIC, bandwidth_mode=Mode.UNPARTITIONED)

    def check(units: np.ndarray) -> None:
        _check_units_capacity(units, total_units, "CPpf")
        _check_bandwidth_capacity(bw, plant.total_bandwidth, "CPpf")

    cache_ctl = CacheController(
        total_units, params.min_ways, backend=plant.allocator_backend)
    off = plant.run_interval(
        make_alloc(equal_units, np.zeros((m, n), dtype=bool)),
        params.prefetch_sampling_period_ms)
    on = plant.run_interval(
        make_alloc(equal_units, np.ones((m, n), dtype=bool)),
        params.prefetch_sampling_period_ms)
    friendly = throttle_decision(on.ipc, off.ipc, rows.speedup_threshold)

    pf_on = np.ones((m, n), dtype=bool)
    units = equal_units.copy()
    atd = np.zeros((m, n, total_units + 1))
    ipc_acc = np.zeros((m, n))
    w_acc = 0.0
    t = 0.0
    while t < total_ms - 1e-9:
        dt = min(params.reconfiguration_interval_ms, total_ms - t)
        stats = plant.run_interval(make_alloc(units, pf_on), dt)
        atd += stats.utility_curves * dt
        ipc_acc += stats.ipc * dt
        w_acc += dt
        t += dt
        curves = atd.copy()
        atd *= rows.atd_decay
        units = cache_ctl.allocate_masked(
            curves, ~friendly, min_units=rows.min_ways)
        check(units)
    return ipc_acc / w_acc, make_alloc(units, pf_on)


def _run_one_manager(
    plant: BatchedCMPPlant,
    name: str,
    total_ms: float,
    params: CBPParams,
    params_rows: Optional[Sequence[CBPParams]] = None,
) -> Tuple[np.ndarray, Allocation]:
    """One manager over every batch row of ``plant`` -> ((M, n) ipc, alloc)."""
    family = policies.get_family(name)
    if family.variant == "cppf":
        return _run_cppf_batched(plant, total_ms, params, params_rows)
    if family.modes is None:
        # Registry policy / banked families: the scalar host golden IS the
        # batched segment path (``policy_loop`` is shape-agnostic), with
        # the per-row tunables threaded through.
        rows = _per_row_params(params, params_rows, plant.n_mixes)
        ipc, alloc = policy_loop(
            plant, family, total_ms, rows.schedule,
            min_ways=rows.min_ways,
            min_bandwidth=rows.min_bandwidth_allocation,
            atd_decay=rows.atd_decay,
            bandwidth_delay_decay=rows.bandwidth_delay_decay)
        where = f"run_sweep[{name}]"
        if _family_modes(family)[0] == Mode.DYNAMIC:
            _check_units_capacity(
                alloc.cache_units, plant.total_cache_units, where)
        _check_bandwidth_capacity(
            alloc.bandwidth, plant.total_bandwidth, where)
        return ipc, alloc
    cache_mode, bw_mode, pf_mode = family.modes
    coord = BatchedCoordinator(
        plant, params=params, cache_mode=cache_mode,
        bandwidth_mode=bw_mode, prefetch_mode=pf_mode,
        params_rows=params_rows)
    coord.run(total_ms)
    return coord.mean_ipc(), coord.alloc


def _family_modes(family: policies.PolicyFamily
                  ) -> Tuple[Mode, Mode, PrefetchMode]:
    """Effective (cache, bandwidth, prefetch) modes of a registry family.

    Classic Table-3 families carry them verbatim; the auction/QoS boundary
    policies manage cache and bandwidth dynamically with prefetch off; the
    banked-bandwidth family keeps cache at the equal split and manages
    bandwidth via Algorithm 1; CPpf partitions cache over unpartitioned
    bandwidth with prefetch enabled.
    """
    if family.modes is not None:
        return family.modes
    if family.variant == "cppf":
        return (Mode.DYNAMIC, Mode.UNPARTITIONED, PrefetchMode.ON)
    if family.cache_policy != policies.CACHE_LOOKAHEAD:
        return (Mode.DYNAMIC, Mode.DYNAMIC, PrefetchMode.OFF)
    return (Mode.EQUAL, Mode.DYNAMIC, PrefetchMode.OFF)


def _fig8_spec(plant: BatchedCMPPlant, cache_mode: Mode, bw_mode: Mode,
               pf_mode: PrefetchMode, total_ms: float, params: CBPParams,
               name: str = "") -> timeline_jax.TimelineSpec:
    """A Fig. 8 coordinator timeline as a TimelineSpec — THE single
    source of the fused fig8 wiring (mode flags, step-0 state,
    schedule), shared by the stacked sweep, the per-manager fused
    reference path and :class:`BatchedCoordinator`.
    """
    m, n = plant.n_mixes, plant.n_clients
    units = np.full(n, plant.total_cache_units // n, dtype=np.int64)
    units[: plant.total_cache_units - int(units.sum())] += 1
    if (cache_mode != Mode.DYNAMIC and bw_mode != Mode.DYNAMIC
            and pf_mode != PrefetchMode.DYNAMIC):
        # Fully static managers have no boundaries to hit and a
        # segmentation-invariant time-weighted mean: one segment spanning
        # the whole timeline evaluates the identical model exactly once
        # instead of once per reconfiguration interval.
        schedule = [ScheduleSegment("run", total_ms)]
    else:
        schedule = fig8_schedule(total_ms, params,
                                 pf_mode == PrefetchMode.DYNAMIC)
    return timeline_jax.TimelineSpec(
        schedule=schedule,
        variant="fig8",
        cache_dynamic=cache_mode == Mode.DYNAMIC,
        bandwidth_dynamic=bw_mode == Mode.DYNAMIC,
        cache_partitioned=cache_mode != Mode.UNPARTITIONED,
        bandwidth_partitioned=bw_mode != Mode.UNPARTITIONED,
        init_units=np.tile(units, (m, 1)),
        init_bandwidth=np.full((m, n), plant.total_bandwidth / n),
        init_prefetch=np.full((m, n), pf_mode == PrefetchMode.ON,
                              dtype=bool),
        name=name)


def _manager_spec(plant: BatchedCMPPlant, name: str, total_ms: float,
                  params: CBPParams) -> timeline_jax.TimelineSpec:
    """One Table-3 manager as a :class:`~repro.sim.timeline_jax.TimelineSpec`.

    Mirrors :func:`_run_cppf_batched`'s segment-path setup exactly — same
    schedules, same step-0 state — so stacking the specs reproduces the
    per-manager runs bit-for-bit.
    """
    m, n = plant.n_mixes, plant.n_clients
    family = policies.get_family(name)
    if family.variant == "cppf":
        return timeline_jax.TimelineSpec(
            schedule=timeline_jax.cppf_schedule(total_ms, params),
            variant="cppf",
            cache_dynamic=True,
            bandwidth_dynamic=False,
            cache_partitioned=True,
            bandwidth_partitioned=False,
            init_units=np.full((m, n), plant.total_cache_units // n,
                               dtype=np.int64),
            init_bandwidth=np.full((m, n), plant.total_bandwidth / n),
            init_prefetch=np.ones((m, n), dtype=bool),
            name=name)
    cache_mode, bw_mode, pf_mode = _family_modes(family)
    spec = _fig8_spec(plant, cache_mode, bw_mode, pf_mode, total_ms,
                      params, name=name)
    if family.modes is None:
        # Registry policy / banked families ride the same fig8 wiring with
        # their traced branch ids and bandwidth regime stamped on.
        spec = dataclasses.replace(
            spec, cache_policy=family.cache_policy,
            bw_policy=family.bw_policy,
            bandwidth_banks=family.bandwidth_banks)
    return spec


def _run_managers_stacked(
    plant: BatchedCMPPlant,
    names: Sequence[str],
    total_ms: float,
    params: CBPParams,
    params_rows: Optional[Sequence[CBPParams]] = None,
) -> Dict[str, Tuple[np.ndarray, Allocation]]:
    """The whole manager set over every batch row — ONE device program.

    Each manager keeps its own segment table and knob flags; the tables
    stack along the leading manager axis and the (manager, mix) grid
    shards over devices (:func:`repro.sim.timeline_jax.run_timelines`).
    Capacity invariants are checked per manager exactly as on the
    per-manager paths.
    """
    rows = _per_row_params(params, params_rows, plant.n_mixes)
    specs = [_manager_spec(plant, name, total_ms, rows.schedule)
             for name in names]
    results = timeline_jax.run_timelines(
        plant.apps, specs,
        total_units=plant.total_cache_units,
        total_bandwidth=plant.total_bandwidth,
        llc_extra_cycles=plant.config.llc_extra_cycles,
        min_ways=rows.min_ways,
        speedup_threshold=rows.speedup_threshold,
        min_bandwidth_allocation=rows.min_bandwidth_allocation,
        atd_decay=rows.atd_decay,
        bandwidth_delay_decay=rows.bandwidth_delay_decay,
    )
    out: Dict[str, Tuple[np.ndarray, Allocation]] = {}
    for spec, res in zip(specs, results):
        if spec.variant == "cppf":
            cache_mode, bw_mode = Mode.DYNAMIC, Mode.UNPARTITIONED
            _check_units_capacity(
                res.cache_units, plant.total_cache_units, "CPpf")
            _check_bandwidth_capacity(
                res.bandwidth, plant.total_bandwidth, "CPpf")
        else:
            cache_mode, bw_mode, _pf = _family_modes(
                policies.get_family(spec.name))
            where = f"run_sweep[{spec.name}]"
            if cache_mode == Mode.DYNAMIC:
                _check_units_capacity(
                    res.cache_units, plant.total_cache_units, where)
            if bw_mode == Mode.DYNAMIC:
                _check_bandwidth_capacity(
                    res.bandwidth, plant.total_bandwidth, where)
        alloc = Allocation(
            cache_units=res.cache_units,
            bandwidth=res.bandwidth,
            prefetch_on=res.prefetch_on,
            cache_mode=cache_mode,
            bandwidth_mode=bw_mode,
            bandwidth_banks=spec.bandwidth_banks,
        )
        out[spec.name] = (res.mean_ipc(), alloc)
    return out


def _run_managers(
    plant: BatchedCMPPlant,
    names: Sequence[str],
    total_ms: float,
    params: CBPParams,
    params_rows: Optional[Sequence[CBPParams]] = None,
) -> Dict[str, Tuple[np.ndarray, Allocation]]:
    """Dispatch a manager set to the plant's timeline backend.

    "stacked" runs every manager in one device program; "fused" runs the
    SAME specs one program per manager (the stacking parity reference —
    bit-identical by construction plus greedy/model batch invariance);
    "segment" loops the PR 2 host path per manager.
    """
    if plant.timeline_backend == "segment":
        return {name: _run_one_manager(plant, name, total_ms, params,
                                       params_rows)
                for name in names}
    if plant.timeline_backend == "stacked" and names:
        return _run_managers_stacked(
            plant, names, total_ms, params, params_rows)
    out: Dict[str, Tuple[np.ndarray, Allocation]] = {}
    for name in names:
        out.update(_run_managers_stacked(
            plant, [name], total_ms, params, params_rows))
    return out


@dataclasses.dataclass
class SweepResult:
    """Per-(manager, mix, app) outcome of one sweep.

    Without ``param_grid`` the arrays are (M, n); with it they gain a
    leading params axis, (P, M, n), and the metric helpers broadcast
    accordingly (``weighted_speedup`` -> (P, M), ``geomean_speedup`` ->
    (P,)).  The baseline is parameter-independent and stays (M, n).
    """

    manager_names: List[str]
    mixes: List[List[str]]
    ipc: Dict[str, np.ndarray]            # name -> (M, n) | (P, M, n)
    final_alloc: Dict[str, Allocation]    # name -> batched allocation
    baseline_ipc: np.ndarray              # (M, n)
    param_grid: Optional[List[CBPParams]] = None

    @property
    def n_mixes(self) -> int:
        return len(self.mixes)

    def weighted_speedup(self, name: str) -> np.ndarray:
        """Paper §4.3 weighted speedup per mix, shape (M,) (or (P, M))."""
        return np.mean(self.ipc[name] / self.baseline_ipc, axis=-1)

    def antt(self, name: str) -> np.ndarray:
        """Paper §4.3 avg normalized turnaround time per mix, (M,)/(P, M)."""
        return np.mean(self.baseline_ipc / self.ipc[name], axis=-1)

    def geomean_speedup(self, name: str):
        """Geomean over mixes: float, or (P,) with a ``param_grid``."""
        g = np.exp(np.mean(np.log(self.weighted_speedup(name)), axis=-1))
        return float(g) if np.ndim(g) == 0 else g

    def summary(self) -> Dict[str, object]:
        """Geomean weighted speedup per manager over all mixes."""
        out: Dict[str, object] = {}
        for name in self.manager_names:
            g = self.geomean_speedup(name)
            out[name] = (round(g, 4) if np.ndim(g) == 0
                         else [round(float(x), 4) for x in np.asarray(g)])
        return out


def run_sweep(
    mixes: Sequence[Sequence[str]],
    managers: Optional[Sequence[str]] = None,
    total_ms: float = 100.0,
    params: Optional[CBPParams] = None,
    config: Optional[CMPConfig] = None,
    param_grid: Optional[Sequence[CBPParams]] = None,
) -> SweepResult:
    """Evaluate Table-3 managers over many mixes in batched device calls.

    Args:
      mixes: equal-size workload mixes (lists of app names) — e.g.
        ``list(WORKLOADS.values())`` or :func:`repro.sim.random_mixes`.
      managers: manager names (default: all ``MANAGER_NAMES``).
      total_ms / params / config: as in ``managers.run_manager``.
      param_grid: optional sequence of ``CBPParams`` — adds a leading P
        axis to the results (Fig. 12 design-space exploration as one
        sweep).  Params sharing a Fig. 8 schedule are stacked into a
        single device-resident batch of P_g x M rows; schedule-distinct
        params run as separate batches of the same sweep.  Mutually
        exclusive with ``params``.
    """
    plant = BatchedCMPPlant(mixes, config)
    names = list(MANAGER_NAMES) if managers is None else list(managers)
    policies.validate_manager_names(names)   # UnknownManagerError on a typo

    if param_grid is None:
        params = params or CBPParams()
        ipc: Dict[str, np.ndarray] = {}
        final: Dict[str, Allocation] = {}
        for name, (mipc, alloc) in _run_managers(
                plant, names, total_ms, params).items():
            ipc[name], final[name] = mipc, alloc
        return SweepResult(
            manager_names=names,
            mixes=plant.mixes,
            ipc=ipc,
            final_alloc=final,
            baseline_ipc=baseline_ipc_batched(plant),
        )

    if params is not None:
        raise ValueError("pass either params or param_grid, not both")
    grid = list(param_grid)
    if not grid:
        raise ValueError("param_grid must be non-empty")
    P, M, n = len(grid), plant.n_mixes, plant.n_clients
    ipc = {name: np.empty((P, M, n)) for name in names}
    units = {name: np.empty((P, M, n), dtype=np.int64) for name in names}
    bws = {name: np.empty((P, M, n)) for name in names}
    pfs = {name: np.empty((P, M, n), dtype=bool) for name in names}
    modes: Dict[str, Tuple[Mode, Mode]] = {}

    def _params_static(name: str) -> bool:
        """True when no CBPParams field can change the manager's result:
        nothing dynamic means no reconfiguration, no A/B sampling, and a
        time-weighted mean that is segmentation-invariant."""
        family = policies.get_family(name)
        if family.modes is None:
            # CPpf and the registry policy / banked families all manage
            # at least one resource dynamically.
            return False
        cm, bm, pm = family.modes
        return (cm != Mode.DYNAMIC and bm != Mode.DYNAMIC
                and pm != PrefetchMode.DYNAMIC)

    static_names = [name for name in names if _params_static(name)]
    for name, (mipc, alloc) in _run_managers(
            plant, static_names, total_ms, grid[0]).items():
        ipc[name][:] = np.asarray(mipc)[None]
        units[name][:] = np.asarray(alloc.cache_units)[None]
        bws[name][:] = np.asarray(alloc.bandwidth)[None]
        pfs[name][:] = np.asarray(alloc.prefetch_on)[None]
        modes[name] = (alloc.cache_mode, alloc.bandwidth_mode)
    grid_names = [name for name in names if name not in static_names]

    groups: Dict[Tuple[float, float], List[int]] = {}
    for pi, p in enumerate(grid):
        key = (p.reconfiguration_interval_ms, p.prefetch_sampling_period_ms)
        groups.setdefault(key, []).append(pi)

    for idxs in (groups.values() if grid_names else ()):
        tiled = [mix for _ in idxs for mix in mixes]
        gplant = BatchedCMPPlant(tiled, config)
        rows = [grid[pi] for pi in idxs for _ in range(M)]
        G = len(idxs)
        for name, (mipc, alloc) in _run_managers(
                gplant, grid_names, total_ms, rows[0],
                params_rows=rows).items():
            ipc[name][idxs] = np.asarray(mipc).reshape(G, M, n)
            units[name][idxs] = np.asarray(
                alloc.cache_units).reshape(G, M, n)
            bws[name][idxs] = np.asarray(alloc.bandwidth).reshape(G, M, n)
            pfs[name][idxs] = np.asarray(
                alloc.prefetch_on).reshape(G, M, n)
            modes[name] = (alloc.cache_mode, alloc.bandwidth_mode)

    final = {
        name: Allocation(
            cache_units=units[name], bandwidth=bws[name],
            prefetch_on=pfs[name], cache_mode=modes[name][0],
            bandwidth_mode=modes[name][1])
        for name in names
    }
    return SweepResult(
        manager_names=names,
        mixes=plant.mixes,
        ipc=ipc,
        final_alloc=final,
        baseline_ipc=baseline_ipc_batched(plant),
        param_grid=grid,
    )
