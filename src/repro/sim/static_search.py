"""Batched static-allocation search (the Fig. 5 potential-study substrate).

The paper's potential study (§2.3 / Fig. 5) exhaustively searches static
(cache, bandwidth, prefetch) allocations per workload and per manager
*family* (the subset of resources a manager may move) to show that
coordinating all three resources beats any two-resource subset.  The old
path looped ``benchmarks.paper_figs._exhaustive_best`` on the host — one
vectorized numpy solve per (workload, family), ~3840 host dispatches for
the 640-workload study.  This module turns each family into ONE jitted
device program:

* the constrained config grid is enumerated on the host
  (:func:`enumerate_grid` — per-resource option products, sum-feasibility
  filtered, in ``itertools.product`` order) and padded to a chunk multiple
  with a validity mask;
* the program scans config chunks on device, evaluating the batched
  interval model (:mod:`repro.sim.memsys_jax`) for every (workload,
  config) pair in the chunk and folding a running top-k of weighted
  speedups — memory stays bounded at ``n_workloads x chunk`` regardless
  of grid size;
* the workload axis shards across devices via
  :func:`repro.distributed.shard_rows`, exactly like the fused Fig. 8
  timelines.

A full :func:`search_static` is therefore ``len(families)`` device
programs plus one shared baseline evaluation (counter:
:func:`repro.core.device_dispatches`).

Parity contract: ``backend="numpy"`` runs the same search on the numpy
golden reference (:func:`repro.sim.memsys.evaluate`, one host solve per
workload — the ``_exhaustive_best`` protocol); the JAX backend must match
it within 1e-5 relative weighted speedup and return the SAME argmax
config under the documented tie-break (enforced by
``tests/test_static_search.py``).

Tie-breaks: among configs with equal weighted speedup the LOWEST
enumeration index wins, where enumeration order is ``itertools.product``
nesting — cache combinations outermost, then bandwidth, then prefetch,
each with the last application varying fastest (the `_exhaustive_best`
combo order).  Top-k results are sorted descending by weighted speedup
with distinct config indices; slots beyond the number of feasible
configs hold ``-inf`` / index ``-1``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.sim import memsys
from repro.sim.apps import MODEL_FIELDS, AppArrays, stack_mixes
from repro.sim.runner import equal_share

#: Fixed-point iterations of the Fig. 5 protocol (fewer than the plant's
#: 60: static allocations converge fast and the reference always used 40).
FIG5_ITERS = 40

#: Target elements (workloads x configs x apps) per on-device scan step;
#: bounds peak memory at a few hundred MB of f64 temporaries.
CHUNK_ELEMENTS = 1 << 21


class InfeasibleGridError(ValueError):
    """A static config grid has zero feasible configurations.

    Raised with the violated constraint (and, from :func:`search_static`,
    the family name) instead of silently searching an empty grid — an
    empty grid's top-k would be all ``-inf`` scores and ``-1`` indices,
    which downstream argmax/``config`` lookups consume as garbage.
    """


@dataclasses.dataclass(frozen=True)
class FamilySpec:
    """Which resources a Fig. 5 family may allocate statically.

    Unmanaged resources pin to the equal-share fixed point
    (``StaticOptions.cache_fixed`` / ``bw_fixed``); an unmanaged
    prefetcher is off unless ``pf_all_on`` forces it on for everyone.
    """

    manage_cache: bool = False
    manage_bw: bool = False
    manage_pf: bool = False
    pf_all_on: bool = False
    bandwidth_banks: int = 1     # >1: banked-token bandwidth regime


#: The Fig. 5 manager families (paper §2.3), insertion order = plot order.
FIG5_FAMILIES: Dict[str, FamilySpec] = {
    "equal_on": FamilySpec(pf_all_on=True),
    "only_pref": FamilySpec(manage_pf=True),
    "bw+pref": FamilySpec(manage_bw=True, manage_pf=True),
    "cache+bw": FamilySpec(manage_cache=True, manage_bw=True),
    "cache+pref": FamilySpec(manage_cache=True, manage_pf=True),
    "cache+bw+pref": FamilySpec(manage_cache=True, manage_bw=True,
                                manage_pf=True),
}

#: The two-resource subsets the all-three family is compared against.
FIG5_TWO_RESOURCE = ("bw+pref", "cache+bw", "cache+pref")


def registry_families(
        names: Optional[Sequence[str]] = None) -> Dict[str, FamilySpec]:
    """Manager families' static-grid vocabularies as :class:`FamilySpec`.

    Converts the policy registry's plain ``static_grid`` kwargs
    (:mod:`repro.sim.policies`) into the search's family specs, so
    ``search_static(families=registry_families(["CBP", "bank bw"]))``
    explores exactly the knobs each manager family may move.  Default:
    every registered family.
    """
    from repro.sim import policies

    resolved = policies.manager_names() if names is None else list(names)
    out: Dict[str, FamilySpec] = {}
    for name in resolved:
        fam = policies.get_family(name)   # UnknownManagerError on a typo
        out[name] = FamilySpec(**(fam.static_grid or {}))
    return out


@dataclasses.dataclass(frozen=True)
class StaticOptions:
    """The static design-space option values (paper §2.3 defaults).

    Budgets are per application: a workload of ``n`` apps searches under
    ``sum(cache) <= cache_budget_per_app * n`` (ditto bandwidth), and the
    budgets double as the model's total capacities — exactly the
    ``_exhaustive_best`` protocol.  Replace the option tuples for finer
    or larger grids; they need not contain the fixed points.
    """

    cache_options: Tuple[float, ...] = (8.0, 16.0, 32.0)
    cache_fixed: float = 16.0
    bw_options: Tuple[float, ...] = (2.0, 4.0, 6.0)
    bw_fixed: float = 4.0
    cache_budget_per_app: float = 16.0
    bw_budget_per_app: float = 4.0

    def per_app(self, spec: FamilySpec, n: int):
        """Per-application option tuples for one family."""
        cache = (tuple(float(c) for c in self.cache_options)
                 if spec.manage_cache else (float(self.cache_fixed),))
        bw = (tuple(float(b) for b in self.bw_options)
              if spec.manage_bw else (float(self.bw_fixed),))
        pf = ((0.0, 1.0) if spec.manage_pf
              else ((1.0,) if spec.pf_all_on else (0.0,)))
        return [cache] * n, [bw] * n, [pf] * n


@dataclasses.dataclass
class StaticGrid:
    """Feasible static configurations, one row per (cache, bw, pf) combo.

    ``cache`` / ``bandwidth`` / ``prefetch`` are ``(C, n)``; ``valid`` is
    ``(C,)`` and is all-True straight out of :func:`enumerate_grid` —
    :meth:`pad_to` appends masked copies of the last row so the device
    scan sees a rectangular chunk grid, and the search reductions ignore
    every ``valid == False`` row.
    """

    cache: np.ndarray
    bandwidth: np.ndarray
    prefetch: np.ndarray
    valid: np.ndarray
    total_cache_units: float
    total_bandwidth_gbps: float

    @property
    def n_configs(self) -> int:
        """Feasible (unmasked) configurations."""
        return int(self.valid.sum())

    @property
    def n_apps(self) -> int:
        return int(self.cache.shape[-1])

    def pad_to(self, multiple: int) -> "StaticGrid":
        """Pad rows to a multiple of ``multiple`` with ``valid=False``."""
        c = len(self.valid)
        pad = -(-c // multiple) * multiple - c
        if pad == 0:
            return self

        def ext(a: np.ndarray) -> np.ndarray:
            return np.concatenate([a, np.repeat(a[-1:], pad, axis=0)])

        return dataclasses.replace(
            self, cache=ext(self.cache), bandwidth=ext(self.bandwidth),
            prefetch=ext(self.prefetch),
            valid=np.concatenate([self.valid, np.zeros(pad, dtype=bool)]))

    def config(self, index) -> Dict[str, np.ndarray]:
        """Allocation arrays for (an array of) config indices.

        Index ``-1`` marks an empty top-k slot (fewer feasible configs
        than ``k``); refusing it here beats numpy's silent wrap-around to
        the last grid row, which would hand the caller an allocation that
        never won anything.
        """
        idx = np.asarray(index)
        if idx.size and (idx < 0).any():
            raise IndexError(
                "config index -1 marks an empty top-k slot (fewer "
                "feasible configurations than k) — no allocation exists "
                "for it")
        return {
            "cache_units": self.cache[idx],
            "bandwidth_gbps": self.bandwidth[idx],
            "prefetch_on": self.prefetch[idx],
        }


def _options_product(opts: Sequence[Tuple[float, ...]]) -> np.ndarray:
    """All per-app combinations, ``itertools.product`` order, ``(K, n)``."""
    grids = np.meshgrid(*[np.asarray(o, np.float64) for o in opts],
                        indexing="ij")
    return np.stack([g.reshape(-1) for g in grids], axis=-1)


def enumerate_grid(
    cache_options: Sequence[Tuple[float, ...]],
    bw_options: Sequence[Tuple[float, ...]],
    pf_options: Sequence[Tuple[float, ...]],
    *,
    cache_budget: float,
    bw_budget: float,
) -> StaticGrid:
    """Enumerate the feasible static grid for one workload size.

    Each ``*_options`` entry is the option tuple of one application.
    Per-resource combinations whose sum exceeds the budget are dropped
    (sum-feasibility), then the three resources cross — preserving the
    reference enumeration order (cache outermost, then bandwidth, then
    prefetch, last application fastest).
    """
    n = len(cache_options)
    if not (len(bw_options) == n and len(pf_options) == n):
        raise ValueError(
            f"per-app option lists disagree on n: {len(cache_options)}, "
            f"{len(bw_options)}, {len(pf_options)}")
    caches = _options_product(cache_options)
    caches = caches[caches.sum(axis=-1) <= cache_budget + 1e-9]
    bws = _options_product(bw_options)
    bws = bws[bws.sum(axis=-1) <= bw_budget + 1e-9]
    pfs = _options_product(pf_options)
    if len(caches) == 0 or len(bws) == 0:
        violations = []
        for label, opts, budget, combos in (
                ("cache", cache_options, cache_budget, caches),
                ("bandwidth", bw_options, bw_budget, bws)):
            if len(combos) == 0:
                min_sum = (sum(min(o) for o in opts)
                           if all(len(o) for o in opts) else None)
                violations.append(
                    f"{label}: empty per-app option tuple" if min_sum is None
                    else f"{label}: smallest per-app options sum to "
                         f"{min_sum} > budget {budget}")
        raise InfeasibleGridError(
            "no feasible configuration — " + "; ".join(violations))
    cc, cb, cp = len(caches), len(bws), len(pfs)
    return StaticGrid(
        cache=np.repeat(caches, cb * cp, axis=0),
        bandwidth=np.tile(np.repeat(bws, cp, axis=0), (cc, 1)),
        prefetch=np.tile(pfs, (cc * cb, 1)),
        valid=np.ones(cc * cb * cp, dtype=bool),
        total_cache_units=float(cache_budget),
        total_bandwidth_gbps=float(bw_budget),
    )


def family_grid(spec: FamilySpec, n: int,
                options: Optional[StaticOptions] = None) -> StaticGrid:
    """The constrained config grid of one family for ``n``-app workloads."""
    options = options or StaticOptions()
    cache_opts, bw_opts, pf_opts = options.per_app(spec, n)
    return enumerate_grid(
        cache_opts, bw_opts, pf_opts,
        cache_budget=options.cache_budget_per_app * n,
        bw_budget=options.bw_budget_per_app * n)


@dataclasses.dataclass
class StaticSearchResult:
    """Per-(family, workload) best static allocations.

    ``topk_ws`` / ``topk_index`` are ``(W, k)`` — sorted descending by
    weighted speedup, distinct config indices into ``grids[family]``,
    with ``-inf`` / ``-1`` filling slots beyond the feasible count.

    With ``multi_objective`` the slots hold the Pareto front over
    (weighted speedup, min-fairness) instead of the scalar top-k:
    still sorted descending by weighted speedup — so fairness strictly
    increases down the slots — with ``topk_fairness`` carrying each
    front member's min-fairness and ``k`` doubling as the front
    capacity (fronts wider than ``k`` keep their ``k`` best-ws members).
    """

    family_names: List[str]
    workloads: List[List[str]]
    grids: Dict[str, StaticGrid]
    topk_ws: Dict[str, np.ndarray]
    topk_index: Dict[str, np.ndarray]
    baseline_ipc: np.ndarray            # (W, n)
    backend: str
    k: int
    topk_fairness: Optional[Dict[str, np.ndarray]] = None   # (W, k)
    multi_objective: bool = False

    def knee_index(self, family: str) -> np.ndarray:
        """Per-workload config index of the front's knee point, ``(W,)``.

        The knee is the front member closest (Euclidean) to the utopia
        point after min-max normalizing both objectives over the front —
        the standard balanced-trade-off pick.  Ties and degenerate
        (single-member or zero-span) fronts resolve toward the
        best-weighted-speedup end.  Multi-objective results only.
        """
        if not self.multi_objective:
            raise ValueError(
                "knee_index needs a multi_objective=True search result")
        ws = np.asarray(self.topk_ws[family], dtype=np.float64)
        f = np.asarray(self.topk_fairness[family], dtype=np.float64)
        idx = np.asarray(self.topk_index[family])
        valid = idx >= 0

        def norm(x):
            lo = np.min(np.where(valid, x, np.inf), axis=-1, keepdims=True)
            hi = np.max(np.where(valid, x, -np.inf), axis=-1, keepdims=True)
            span = hi - lo
            return np.where(span > 0, (x - lo) / np.where(span > 0, span, 1.0),
                            1.0)

        dist = (1.0 - norm(ws)) ** 2 + (1.0 - norm(f)) ** 2
        dist = np.where(valid, dist, np.inf)
        pos = np.argmin(dist, axis=-1)       # first minimum: best-ws end
        return np.take_along_axis(idx, pos[:, None], axis=-1)[:, 0]

    @property
    def n_workloads(self) -> int:
        return int(self.baseline_ipc.shape[0])

    def best_ws(self, family: str) -> np.ndarray:
        """Best weighted speedup per workload, shape ``(W,)``."""
        return self.topk_ws[family][:, 0]

    def best_index(self, family: str) -> np.ndarray:
        return self.topk_index[family][:, 0]

    def best_config(self, family: str) -> Dict[str, np.ndarray]:
        """Winning allocation arrays per workload, each ``(W, n)``."""
        return self.grids[family].config(self.best_index(family))

    def geomean(self, family: str) -> float:
        """Geometric-mean best weighted speedup over workloads."""
        return float(np.exp(np.mean(np.log(self.best_ws(family)))))

    def frac_at_least(self, family: str, threshold: float = 1.10) -> float:
        """Fraction of workloads at or above ``threshold`` (Fig. 5b)."""
        return float(np.mean(self.best_ws(family) >= threshold))

    def summary(self) -> Dict[str, float]:
        return {name: round(self.geomean(name), 4)
                for name in self.family_names}


def _resolve_families(
    families: Optional[Mapping[str, Union[FamilySpec, Mapping[str, bool]]]],
) -> Dict[str, FamilySpec]:
    if families is None:
        return dict(FIG5_FAMILIES)
    out: Dict[str, FamilySpec] = {}
    for name, spec in families.items():
        out[name] = spec if isinstance(spec, FamilySpec) else FamilySpec(**spec)
    if not out:
        raise ValueError("families must be non-empty")
    return out


def _row_apps(stacked: AppArrays, wi: int) -> AppArrays:
    names = stacked.names[wi] if stacked.names else []
    return AppArrays(
        names=list(names),
        **{f: np.asarray(getattr(stacked, f))[wi] for f in MODEL_FIELDS})


# --------------------------------------------------------------------- #
# numpy golden-reference backend
# --------------------------------------------------------------------- #

def _pareto_topk(ws: np.ndarray, fairness: np.ndarray, index: np.ndarray,
                 k: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The ``k`` best-ws Pareto-front members of one candidate set.

    Sort by (ws desc, fairness desc, index asc); an entry is on the front
    iff its fairness strictly exceeds the exclusive running max — which
    drops strictly dominated entries, weakly dominated ones (equal in one
    objective, worse in the other) and exact duplicates (keeping the
    lowest index) in one rule.  Masked candidates carry ``-inf`` in both
    objectives and can never be kept.  The JAX fold
    (:func:`_family_scan`) applies the identical rule per merge step.
    """
    order = np.lexsort((index, -fairness, -ws))
    s_ws, s_f, s_idx = ws[order], fairness[order], index[order]
    run_max = np.concatenate(
        [[-np.inf], np.maximum.accumulate(s_f)[:-1]])
    kept_ws = np.where(s_f > run_max, s_ws, -np.inf)
    sel = np.argsort(-kept_ws, kind="stable")[:k]
    out_ws, out_f, out_idx = kept_ws[sel], s_f[sel], s_idx[sel]
    empty = np.isinf(out_ws)
    pad = k - len(sel)
    return (np.concatenate([out_ws, np.full(pad, -np.inf)]),
            np.concatenate([np.where(empty, -np.inf, out_f),
                            np.full(pad, -np.inf)]),
            np.concatenate([np.where(empty, -1, out_idx),
                            np.full(pad, -1, out_idx.dtype)]))


def _search_numpy_family(
    apps_rows: List[AppArrays],
    grid: StaticGrid,
    baseline_ipc: np.ndarray,
    k: int,
    iters: int,
    banks: int = 1,
    multi: bool = False,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One host solve per workload over the whole (unpadded) grid."""
    w = len(apps_rows)
    top_ws = np.full((w, k), -np.inf)
    top_f = np.full((w, k), -np.inf)
    top_idx = np.full((w, k), -1, dtype=np.int64)
    for wi, arr in enumerate(apps_rows):
        ss = memsys.evaluate(
            arr, grid.cache, grid.bandwidth, grid.prefetch,
            total_cache_units=grid.total_cache_units,
            total_bandwidth_gbps=grid.total_bandwidth_gbps,
            bandwidth_banks=banks, iters=iters)
        speedup = ss.ipc / baseline_ipc[wi]
        ws = np.mean(speedup, axis=-1)
        ws = np.where(grid.valid, ws, -np.inf)
        if multi:
            fair = np.min(speedup, axis=-1) / np.max(speedup, axis=-1)
            fair = np.where(grid.valid, fair, -np.inf)
            idx = np.arange(len(ws), dtype=np.int64)
            top_ws[wi], top_f[wi], top_idx[wi] = _pareto_topk(
                ws, fair, idx, k)
            continue
        # Stable descending sort: equal speedups keep enumeration order,
        # i.e. the lowest config index wins (the documented tie-break).
        order = np.argsort(-ws, kind="stable")[:k]
        top_ws[wi, : len(order)] = ws[order]
        top_idx[wi, : len(order)] = order
    return top_ws, top_idx, top_f


# --------------------------------------------------------------------- #
# JAX device backend
# --------------------------------------------------------------------- #

def _family_scan(p, base, tables, k: int, iters: int, banks: int = 1,
                 multi: bool = False):
    """The chunked top-k fold of ONE family, shared by both program shapes.

    ``tables`` holds the family's chunked config grid (``(s, chunk, n)``
    plus validity/index rows); the scan evaluates the interval model for
    the full (workload, chunk) block and folds a running top-k.  Both
    ``lax.top_k`` calls break value ties toward earlier positions, and
    the running entries (earlier chunks = lower config indices) are
    concatenated first, so the global tie-break is "lowest enumeration
    index" — matching the numpy reference's stable argsort.

    With ``multi`` the carry folds the Pareto front over (weighted
    speedup, min-fairness) instead: each step merges the running front
    with the WHOLE chunk under the :func:`_pareto_topk` keep rule
    (sort by ws desc / fairness desc / index asc, keep iff fairness
    strictly beats the exclusive running max) and retains the ``k``
    best-ws survivors — ``k`` is the front capacity.
    """
    import jax
    import jax.numpy as jnp

    from repro.sim import memsys_jax

    total_units = tables["total_cache_units"]
    total_bw = tables["total_bandwidth"]
    llc_extra = tables["llc_extra_cycles"]

    def step(carry, xs):
        c_cache, c_bw, c_pf, c_valid, c_idx = xs
        out = memsys_jax._evaluate_jit(
            p, c_cache, c_bw, c_pf, total_units, total_bw, llc_extra,
            cache_partitioned=True, bandwidth_partitioned=True,
            iters=iters, bandwidth_banks=banks)
        speedup = out[0] / base[:, None, :]                # (W, chunk, n)
        ws = jnp.mean(speedup, axis=-1)                    # (W, chunk)
        ws = jnp.where(c_valid[None, :], ws, -jnp.inf)
        if not multi:
            top_ws, top_idx = carry
            cand_ws, cand_loc = jax.lax.top_k(ws, k)
            cand_idx = c_idx[cand_loc]
            merged_ws = jnp.concatenate([top_ws, cand_ws], axis=-1)
            merged_idx = jnp.concatenate([top_idx, cand_idx], axis=-1)
            top_ws, sel = jax.lax.top_k(merged_ws, k)
            top_idx = jnp.take_along_axis(merged_idx, sel, axis=-1)
            return (top_ws, top_idx), None

        top_ws, top_f, top_idx = carry
        fair = (jnp.min(speedup, axis=-1)
                / jnp.max(speedup, axis=-1))
        fair = jnp.where(c_valid[None, :], fair, -jnp.inf)
        w_rows = ws.shape[0]
        m_ws = jnp.concatenate([top_ws, ws], axis=-1)
        m_f = jnp.concatenate([top_f, fair], axis=-1)
        m_idx = jnp.concatenate(
            [top_idx, jnp.broadcast_to(c_idx, ws.shape)], axis=-1)
        # _pareto_topk, vectorized over workload rows.
        order = jnp.lexsort((m_idx, -m_f, -m_ws), axis=-1)
        s_ws = jnp.take_along_axis(m_ws, order, axis=-1)
        s_f = jnp.take_along_axis(m_f, order, axis=-1)
        s_idx = jnp.take_along_axis(m_idx, order, axis=-1)
        run_max = jnp.concatenate(
            [jnp.full((w_rows, 1), -jnp.inf, s_f.dtype),
             jax.lax.cummax(s_f, axis=1)[:, :-1]], axis=-1)
        kept_ws = jnp.where(s_f > run_max, s_ws, -jnp.inf)
        top_ws, sel = jax.lax.top_k(kept_ws, k)
        top_f = jnp.take_along_axis(s_f, sel, axis=-1)
        top_idx = jnp.take_along_axis(s_idx, sel, axis=-1)
        empty = jnp.isinf(top_ws)
        top_f = jnp.where(empty, -jnp.inf, top_f)
        top_idx = jnp.where(empty, -1, top_idx)
        return (top_ws, top_f, top_idx), None

    w = base.shape[0]
    if multi:
        init = (jnp.full((w, k), -jnp.inf, base.dtype),
                jnp.full((w, k), -jnp.inf, base.dtype),
                jnp.full((w, k), -1, jnp.int32))
    else:
        init = (jnp.full((w, k), -jnp.inf, base.dtype),
                jnp.full((w, k), -1, jnp.int32))
    carry, _ = jax.lax.scan(
        step, init,
        (tables["cache"], tables["bandwidth"], tables["prefetch"],
         tables["valid"], tables["index"]))
    if multi:
        return carry
    return carry[0], carry[1]


def _pack_scan_out(scan_out, suffix: str = "") -> Dict[str, object]:
    if len(scan_out) == 3:
        top_ws, top_f, top_idx = scan_out
        return {f"topk_ws{suffix}": top_ws,
                f"topk_fairness{suffix}": top_f,
                f"topk_index{suffix}": top_idx}
    top_ws, top_idx = scan_out
    return {f"topk_ws{suffix}": top_ws, f"topk_index{suffix}": top_idx}


@functools.lru_cache(maxsize=None)
def _compiled_search(k: int, iters: int, n_shards: int, banks: int,
                     multi: bool):
    """Build the jitted (optionally shard_mapped) ONE-family program.

    Cached per static configuration (``banks`` selects the family's
    bandwidth regime, ``multi`` the Pareto fold); jit retraces on new
    array shapes (different W, n, chunking) as usual.  This is the
    per-family reference path the stacked program is parity-pinned
    against.
    """
    import jax

    from repro import distributed
    from repro.sim import memsys_jax

    def worker(sharded, replicated):
        p = {f: sharded["p_" + f][:, None, :]
             for f in memsys_jax.PARAM_FIELDS}          # (W, 1, n)
        base = sharded["baseline_ipc"]                  # (W, n)
        return _pack_scan_out(
            _family_scan(p, base, replicated, k, iters, banks, multi))

    if n_shards > 1:
        worker = distributed.shard_rows(worker, n_shards)
    return jax.jit(worker)


@functools.lru_cache(maxsize=None)
def _compiled_stacked_search(banks_per_family: Tuple[int, ...], k: int,
                             iters: int, n_shards: int, multi: bool):
    """Build the jitted (optionally shard_mapped) ALL-families program.

    Every family keeps its own chunk shape (and bank count) and runs its
    own :func:`_family_scan` — the family axis concatenates the
    per-family scans *sequentially inside one program*, so each family's
    subcomputation is shape-identical to the per-family path (bit-parity
    by construction) while a full :func:`search_static` drops from
    ``len(families) + 1`` device dispatches to 2.  The workload axis
    shards exactly as before.
    """
    import jax

    from repro import distributed
    from repro.sim import memsys_jax

    def worker(sharded, replicated):
        p = {f: sharded["p_" + f][:, None, :]
             for f in memsys_jax.PARAM_FIELDS}          # (W, 1, n)
        base = sharded["baseline_ipc"]                  # (W, n)
        out = {}
        for fi, banks in enumerate(banks_per_family):
            out.update(_pack_scan_out(
                _family_scan(p, base, replicated[f"family{fi}"], k,
                             iters, banks, multi), str(fi)))
        return out

    if n_shards > 1:
        worker = distributed.shard_rows(worker, n_shards)
    return jax.jit(worker)


def _family_tables(grid: StaticGrid, w_pad: int, k: int,
                   chunk_elements: int) -> Dict[str, np.ndarray]:
    """Chunk one family's config grid into the scan tables it runs over.

    The chunk shape depends only on this family's grid and the padded
    workload count, NOT on which program (per-family or stacked) consumes
    it — that is what keeps the two program shapes bit-identical per
    family.
    """
    n = grid.n_apps
    chunk = max(k, min(len(grid.valid),
                       max(1, chunk_elements // max(1, w_pad * n))))
    padded = grid.pad_to(chunk)
    s = len(padded.valid) // chunk
    return {
        "cache": padded.cache.reshape(s, chunk, n),
        "bandwidth": padded.bandwidth.reshape(s, chunk, n),
        "prefetch": padded.prefetch.reshape(s, chunk, n),
        "valid": padded.valid.reshape(s, chunk),
        "index": np.arange(s * chunk, dtype=np.int32).reshape(s, chunk),
        "total_cache_units": np.float64(grid.total_cache_units),
        "total_bandwidth": np.float64(grid.total_bandwidth_gbps),
        "llc_extra_cycles": np.float64(0.0),
    }


def _search_jax_family(
    sharded: Dict[str, np.ndarray],
    grid: StaticGrid,
    w: int,
    k: int,
    iters: int,
    n_shards: int,
    chunk_elements: int,
    banks: int = 1,
    multi: bool = False,
) -> Tuple[np.ndarray, np.ndarray, Optional[np.ndarray]]:
    """One device program: chunked grid scan + top-k for one family."""
    from repro.core.dispatch import record_dispatch
    from repro.sim import memsys_jax

    w_pad = sharded["baseline_ipc"].shape[0]
    replicated = _family_tables(grid, w_pad, k, chunk_elements)
    fn = _compiled_search(k, iters, n_shards, banks, multi)
    record_dispatch()
    with memsys_jax.x64_context():
        out = fn(sharded, replicated)
        top_ws = np.asarray(out["topk_ws"])[:w]
        top_idx = np.asarray(out["topk_index"])[:w].astype(np.int64)
        top_f = (np.asarray(out["topk_fairness"])[:w] if multi else None)
    return top_ws, top_idx, top_f


def _search_jax_stacked(
    sharded: Dict[str, np.ndarray],
    grids: Dict[str, StaticGrid],
    w: int,
    k: int,
    iters: int,
    n_shards: int,
    chunk_elements: int,
    banks_per_family: Tuple[int, ...],
    multi: bool = False,
):
    """ONE device program scanning every family's grid back to back."""
    from repro.core.dispatch import record_dispatch
    from repro.sim import memsys_jax

    w_pad = sharded["baseline_ipc"].shape[0]
    names = list(grids)
    replicated = {
        f"family{fi}": _family_tables(grids[name], w_pad, k, chunk_elements)
        for fi, name in enumerate(names)
    }
    fn = _compiled_stacked_search(banks_per_family, k, iters, n_shards,
                                  multi)
    record_dispatch()
    topk_ws: Dict[str, np.ndarray] = {}
    topk_idx: Dict[str, np.ndarray] = {}
    topk_f: Dict[str, np.ndarray] = {}
    with memsys_jax.x64_context():
        out = fn(sharded, replicated)
        for fi, name in enumerate(names):
            topk_ws[name] = np.asarray(out[f"topk_ws{fi}"])[:w]
            topk_idx[name] = np.asarray(
                out[f"topk_index{fi}"])[:w].astype(np.int64)
            if multi:
                topk_f[name] = np.asarray(out[f"topk_fairness{fi}"])[:w]
    return topk_ws, topk_idx, topk_f


# --------------------------------------------------------------------- #
# entry point
# --------------------------------------------------------------------- #

def search_static(
    workloads: Union[Sequence[Sequence[str]], AppArrays],
    families: Optional[Mapping[str, Union[FamilySpec, Mapping]]] = None,
    *,
    k: int = 1,
    backend: str = "jax",
    options: Optional[StaticOptions] = None,
    iters: int = FIG5_ITERS,
    shard: Optional[bool] = None,
    chunk_elements: int = CHUNK_ELEMENTS,
    stack_families: bool = True,
    multi_objective: bool = False,
) -> StaticSearchResult:
    """Best static (cache, bandwidth, prefetch) allocation per workload.

    Args:
      workloads: equal-size workloads — lists of app names (any n, not
        just the paper's 4) or an already-stacked ``(W, n)`` AppArrays.
      families: name -> :class:`FamilySpec` (or kwargs dict); default the
        paper's :data:`FIG5_FAMILIES`.
      k: how many best configs to return per workload (sorted, distinct).
      backend: ``"jax"`` (every family in ONE device program, workload
        axis sharded over devices) or ``"numpy"`` (the golden host
        reference, one vectorized solve per workload) — mirroring
        ``CacheController(backend=...)``.
      options: the option grid / budgets (:class:`StaticOptions`).
      iters: fixed-point iterations (Fig. 5 protocol default 40).
      shard: ``None`` auto-shards over visible devices; ``False`` forces
        single-device execution.  JAX backend only.
      chunk_elements: on-device scan chunk budget (W x chunk x n).
      stack_families: run all families back to back inside one jitted
        program (2 dispatches total, the default); ``False`` keeps the
        PR 4 one-program-per-family path (``len(families) + 1``
        dispatches) — the stacking parity reference, bit-identical per
        family.  JAX backend only.
      multi_objective: fold the Pareto front over (weighted speedup,
        min-fairness) instead of the scalar top-k — ``topk_*`` then hold
        the front's ``k`` best-ws members (ws descending, fairness
        ascending down the slots) and ``topk_fairness`` is populated;
        ``k`` doubles as the front capacity.  Min-fairness is
        ``min(speedup) / max(speedup)`` per workload.

    Returns:
      :class:`StaticSearchResult`; weighted speedups are against the
      equal-share static partitioned baseline (prefetch off), the
      ``_exhaustive_best`` normalization.
    """
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}")
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    fams = _resolve_families(families)
    options = options or StaticOptions()

    stacked = (workloads if isinstance(workloads, AppArrays)
               else stack_mixes([list(w) for w in workloads]))
    shape = np.asarray(stacked.cpi_base).shape
    if len(shape) != 2 or shape[0] == 0:
        raise ValueError(
            f"workloads must stack to a non-empty (W, n); got {shape}")
    w, n = shape
    names = [list(m) for m in stacked.names] if stacked.names else []

    grids = {}
    for name, spec in fams.items():
        try:
            grid = family_grid(spec, n, options)
        except InfeasibleGridError as exc:
            raise InfeasibleGridError(f"family {name!r}: {exc}") from None
        if grid.n_configs == 0:
            raise InfeasibleGridError(
                f"family {name!r} has zero feasible configurations")
        grids[name] = grid
    total_units = options.cache_budget_per_app * n
    total_bw = options.bw_budget_per_app * n
    units_eq, bw_eq = equal_share(n, total_units, total_bw)
    pf_off = np.zeros(n)

    banks = {name: int(spec.bandwidth_banks) for name, spec in fams.items()}
    if backend == "numpy":
        base = memsys.evaluate(
            stacked, units_eq.astype(np.float64), bw_eq, pf_off,
            total_cache_units=total_units, total_bandwidth_gbps=total_bw,
            iters=iters).ipc
        apps_rows = [_row_apps(stacked, wi) for wi in range(w)]
        topk_ws, topk_idx, topk_f = {}, {}, {}
        for name, grid in grids.items():
            topk_ws[name], topk_idx[name], topk_f[name] = \
                _search_numpy_family(apps_rows, grid, base, k, iters,
                                     banks[name], multi_objective)
    else:
        from repro import distributed
        from repro.sim import memsys_jax

        # One shared baseline evaluation (family-independent): dispatch 1.
        base = np.asarray(memsys_jax.evaluate(
            stacked, units_eq.astype(np.float64), bw_eq, pf_off,
            total_cache_units=total_units, total_bandwidth_gbps=total_bw,
            iters=iters).ipc)

        n_shards = 1 if shard is False else distributed.row_shard_count(w)
        w_pad = -(-w // n_shards) * n_shards
        params = memsys_jax.app_params(stacked)
        sharded = {"p_" + f: np.ascontiguousarray(
            np.broadcast_to(np.asarray(v, np.float64), (w, n)))
            for f, v in params.items()}
        sharded["baseline_ipc"] = np.asarray(base, dtype=np.float64)
        if w_pad != w:
            sharded = {
                key: np.concatenate(
                    [v, np.repeat(v[-1:], w_pad - w, axis=0)])
                for key, v in sharded.items()
            }
        if stack_families:
            topk_ws, topk_idx, topk_f = _search_jax_stacked(
                sharded, grids, w, k, iters, n_shards, chunk_elements,
                tuple(banks[name] for name in grids), multi_objective)
        else:
            topk_ws, topk_idx, topk_f = {}, {}, {}
            for name, grid in grids.items():
                topk_ws[name], topk_idx[name], topk_f[name] = \
                    _search_jax_family(
                        sharded, grid, w, k, iters, n_shards,
                        chunk_elements, banks[name], multi_objective)

    return StaticSearchResult(
        family_names=list(fams),
        workloads=names,
        grids=grids,
        topk_ws=topk_ws,
        topk_index=topk_idx,
        baseline_ipc=np.asarray(base),
        backend=backend,
        k=k,
        topk_fairness=topk_f if multi_objective else None,
        multi_objective=multi_objective,
    )
