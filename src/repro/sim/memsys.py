"""Interval performance model of the 16-core tiled CMP (paper §4, Table 1).

This is the evaluation *plant* for the faithful reproduction: a steady-state
analytic model with the same signal structure the paper's controllers
consume — per-application miss curves (ATD), memory queuing delays, and IPC
under a given (cache, bandwidth, prefetch) allocation.

Model structure (per application i):

  CPI_i  = cpi_base_i + exposed_mpki_i / 1000 * miss_penalty_i
  miss_penalty_i = (DRAM_latency + queuing_delay_i) * freq / mlp_i
  queuing_delay_i = Q_SCALE * rho_i / (1 - rho_i)          (M/M/1-shaped)
  rho_i = traffic_i / bandwidth_i                (partitioned: own channel)
        = sum(traffic) / total_bandwidth         (unpartitioned: shared queue)
  traffic_i = IPC_i * freq * reqki_i / 1000 * 64 B

with prefetching folding in as: covered misses are (partially) hidden,
useless prefetches add traffic, pollution shrinks the effective allocation
(paper §2.2 observations 2-4).  Unpartitioned cache is modelled as
access-rate-proportional LRU occupancy (high-APKI applications steal space —
the contention CBP's cache partitioning removes).  IPC <-> traffic <->
queuing is a fixed point, solved by damped iteration; a bandwidth cap
bounds IPC when a partition saturates (observation 5's "cost of a miss is
much higher in the case of lower bandwidth allocation").

Everything is vectorized over a leading batch dimension so the Fig. 5
exhaustive search (~10^5 configurations x 640 workloads) runs as one
broadcasted evaluation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.sim.apps import AppArrays

FREQ_GHZ = 4.0            # paper Table 1: 4 GHz cores
DRAM_LAT_NS = 80.0        # paper Table 1: 80 ns memory latency
LINE_BYTES = 64.0
Q_SCALE_NS = 42.0         # queuing-delay scale (calibrated)
IF_SKEW = 0.8             # shared-queue unfairness: low-traffic clients wait
                          # behind streaming bursts (FR-FCFS-like skew)
PF_QUEUE_WEIGHT = 0.55     # prefetch fills are issued off the critical path
                          # (deprioritized by the MC): they consume bandwidth
                          # (cap) but add little demand-queue delay
RHO_MAX = 0.98            # queue stability clip
FIXED_POINT_ITERS = 60
DAMPING = 0.5
BANK_SKEW = 0.6           # banked-token mode: per-bank access affinity decay
                          # (each client concentrates on "its" banks; row-
                          # buffer locality makes the spread geometric)
DEFAULT_BANDWIDTH_BANKS = 4


@dataclasses.dataclass
class SteadyState:
    """Model outputs for one (workload, allocation) evaluation."""

    ipc: np.ndarray            # (..., n)
    queuing_delay_ns: np.ndarray
    traffic_gbps: np.ndarray
    mpki: np.ndarray           # effective demand MPKI (post-prefetch-pollution)
    exposed_mpki: np.ndarray   # misses whose latency the core actually eats
    occupancy_units: np.ndarray  # effective cache units used


def mpki_curve(apps: AppArrays, units: np.ndarray) -> np.ndarray:
    """Miss curve: MPKI as a function of allocated units (32 kB each).

    Defined for real-valued ``units`` (unpartitioned occupancy is
    fractional).  Below the 4-unit reference point the curve continues to
    rise smoothly.
    """
    u = np.maximum(np.asarray(units, dtype=np.float64), 1.0)
    span = apps.mpki_min_alloc - apps.mpki_floor
    return apps.mpki_floor + span * np.exp(-(u - 4.0) / apps.ws_units)


def bank_affinity(n_apps: int, n_banks: int) -> np.ndarray:
    """Per-(client, bank) access affinity for the banked-token mode.

    Client i concentrates geometrically (``BANK_SKEW``) on bank
    ``(i + b) % n_banks`` order — a stand-in for address-interleaving +
    row-buffer locality — normalized so each client's affinities sum to 1.
    For ``n_banks == 1`` this is exactly 1.0 (skew**0 / 1.0), which makes
    the banked formulas reduce BIT-identically to the flat partitioned
    channel model.
    """
    i = np.arange(n_apps, dtype=np.float64)[:, None]
    b = np.arange(n_banks, dtype=np.float64)[None, :]
    a = BANK_SKEW ** np.mod(i + b, float(n_banks))
    return a / a.sum(axis=-1, keepdims=True)


def evaluate(
    apps: AppArrays,
    cache_units: np.ndarray,
    bandwidth_gbps: np.ndarray,
    prefetch_on: np.ndarray,
    *,
    cache_partitioned: bool = True,
    bandwidth_partitioned: bool = True,
    total_cache_units: float = 256.0,
    total_bandwidth_gbps: float = 64.0,
    llc_extra_cycles: float = 0.0,
    bandwidth_banks: int = 1,
    iters: int = FIXED_POINT_ITERS,
) -> SteadyState:
    """Solve the IPC <-> traffic <-> queuing fixed point.

    All array arguments broadcast against shape (..., n) where n = #apps.
    ``cache_units``/``bandwidth_gbps`` are ignored for the dimensions that
    are unpartitioned (the shared model applies instead).

    ``bandwidth_banks > 1`` switches the partitioned-bandwidth regime to
    per-bank tokens (arxiv 2410.14003): each client's allocation is split
    evenly across banks, its traffic spreads by :func:`bank_affinity`, and
    queuing is the affinity-weighted sum of per-bank M/M/1 delays — a hot
    bank saturates before the client's aggregate allocation does.  The
    flat partitioned model is the exact 1-bank special case.
    """
    cache_units = np.asarray(cache_units, dtype=np.float64)
    bw = np.asarray(bandwidth_gbps, dtype=np.float64)
    pf = np.asarray(prefetch_on, dtype=np.float64)

    ipc = 1.0 / np.broadcast_to(
        apps.cpi_base, np.broadcast_shapes(
            cache_units.shape, bw.shape, pf.shape, apps.cpi_base.shape)
    ).copy()

    q_ns = np.zeros_like(ipc)
    traffic = np.zeros_like(ipc)
    mpki_eff = np.zeros_like(ipc)
    exposed = np.zeros_like(ipc)
    occ = np.zeros_like(ipc)

    for _ in range(iters):
        # ---- cache occupancy -------------------------------------------- #
        if cache_partitioned:
            occ = np.broadcast_to(cache_units, ipc.shape).astype(np.float64)
        else:
            # Shared LRU: occupancy ~ insertion-rate share (misses/sec).
            # Fixed point: occupancy depends on miss rate depends on
            # occupancy — resolved by the outer iteration.
            miss_rate = np.maximum(mpki_eff, 1e-3) * ipc  # misses/cycle*1e3
            share = miss_rate / np.sum(miss_rate, axis=-1, keepdims=True)
            occ = share * total_cache_units
        occ_eff = np.maximum(occ - apps.pf_pollution * pf, 1.0)

        # ---- prefetch-adjusted miss stream ------------------------------- #
        m = mpki_curve(apps, occ_eff)
        mpki_eff = m
        covered = apps.pf_cov * pf * m
        exposed = m - covered * apps.pf_hide
        useless = covered * (1.0 / np.maximum(apps.pf_acc, 1e-3) - 1.0)
        reqki = m * (1.0 + apps.wb_frac) + useless
        # Demand-critical request stream: prefetch fills (covered misses
        # fetched early + useless prefetches) are deprioritized by the
        # memory controller, so they barely lengthen the queue that demand
        # misses wait in — but they do consume channel bandwidth (cap).
        reqki_q = ((m - covered) + m * apps.wb_frac
                   + PF_QUEUE_WEIGHT * (covered + useless))

        # ---- memory queuing ---------------------------------------------- #
        traffic = ipc * FREQ_GHZ * reqki * LINE_BYTES / 1000.0  # GB/s
        traffic_q = ipc * FREQ_GHZ * reqki_q * LINE_BYTES / 1000.0
        if bandwidth_partitioned and bandwidth_banks > 1:
            # Banked tokens: affinity-weighted per-bank M/M/1 queues; the
            # effective cap is set by the first bank a client saturates.
            aff = bank_affinity(traffic_q.shape[-1], bandwidth_banks)
            bank_bw = bw[..., None] / float(bandwidth_banks)
            rho_b = traffic_q[..., None] * aff / np.maximum(bank_bw, 1e-6)
            rho_cb = np.clip(rho_b, 0.0, RHO_MAX)
            q_bank = Q_SCALE_NS * rho_cb / (1.0 - rho_cb)
            q_ns = np.sum(aff * q_bank, axis=-1)
            cap_gbps = np.min(bank_bw / aff, axis=-1)
        elif bandwidth_partitioned:
            rho = traffic_q / np.maximum(bw, 1e-6)
            cap_gbps = bw
        else:
            tot = np.sum(traffic_q, axis=-1, keepdims=True)
            rho = np.broadcast_to(
                tot / total_bandwidth_gbps, traffic_q.shape)
            # Unpartitioned: an app can use up to the whole pipe, but the
            # aggregate is capped — model per-app cap as proportional share
            # of the total when saturated.
            tot_full = np.sum(traffic, axis=-1, keepdims=True)
            with np.errstate(invalid="ignore", divide="ignore"):
                frac = np.where(tot_full > 0, traffic / tot_full,
                                1.0 / traffic.shape[-1])
            cap_gbps = frac * total_bandwidth_gbps
        if not (bandwidth_partitioned and bandwidth_banks > 1):
            rho_c = np.clip(rho, 0.0, RHO_MAX)
            q_ns = Q_SCALE_NS * rho_c / (1.0 - rho_c)
        if not bandwidth_partitioned:
            # FR-FCFS-style unfairness: clients with a small share of the
            # traffic wait behind other clients' bursts; heavy streaming
            # clients ride their own row hits.  Partitioning (MBA-like
            # virtual channels) removes exactly this term — it is the
            # interference the paper's bandwidth controller targets.
            q_ns = q_ns * (1.0 + IF_SKEW * (1.0 - frac))

        # ---- IPC ---------------------------------------------------------- #
        penalty_cyc = (DRAM_LAT_NS + q_ns) * FREQ_GHZ / apps.mlp
        # Larger LLCs cost extra access latency on every LLC access
        # (CACTI scaling — the paper's Fig. 12b effect).
        cpi = (apps.cpi_base + apps.apki / 1000.0 * llc_extra_cycles
               + exposed / 1000.0 * penalty_cyc)
        ipc_demand = 1.0 / cpi
        # Bandwidth cap: IPC such that traffic <= RHO_MAX * cap.
        ipc_cap = RHO_MAX * cap_gbps / np.maximum(
            FREQ_GHZ * reqki * LINE_BYTES / 1000.0, 1e-9)
        ipc_new = np.minimum(ipc_demand, ipc_cap)
        ipc = DAMPING * ipc + (1.0 - DAMPING) * ipc_new

    return SteadyState(
        ipc=ipc, queuing_delay_ns=q_ns, traffic_gbps=traffic,
        mpki=mpki_eff, exposed_mpki=exposed, occupancy_units=occ)


def utility_curves(
    apps: AppArrays,
    prefetch_on: np.ndarray,
    ipc: np.ndarray,
    total_units: int,
    duration_ms: float = 1.0,
) -> np.ndarray:
    """ATD measurement: hits(u) for u in 0..total_units per app.

    Paper interaction #5: when prefetching is on, prefetched lines appear as
    ATD hits regardless of allocation, flattening the utility curve — the
    cache controller then assigns less space to prefetch-friendly apps.
    """
    u = np.arange(total_units + 1, dtype=np.float64)
    m = mpki_curve(
        dataclasses.replace(apps),  # same params
        u[:, None] - apps.pf_pollution[None, :] * prefetch_on[None, :],
    )  # (U+1, n)
    m = np.moveaxis(m, 0, -1)  # (n, U+1)
    pf = np.asarray(prefetch_on, dtype=np.float64)[..., None]
    eff_miss = m * (1.0 - apps.pf_cov[:, None] * pf)
    hits = np.maximum(apps.apki[:, None] - eff_miss, 0.0)
    kilo_instr = (np.asarray(ipc)[..., None] * FREQ_GHZ * 1e6 * duration_ms
                  / 1000.0)
    return hits * kilo_instr
