"""Batched serving engine with full CBP coordination (host reference).

The engine runs greedy decode over a fixed slot batch (continuous batching:
finished requests release their slot to the queue) and binds all three CBP
knobs:

  * cache      — the :class:`PagedKVPool` partitions KV pages across
    request streams (UCP over stack-distance curves);
  * bandwidth  — per-stream token-bucket admission: each stream's share of
    decode slots is allocated proportionally to its measured queue wait
    (Algorithm 1, units = slots/interval instead of GB/s);
  * prefetch   — KV-page readahead per stream, A/B sampled and throttled
    by the measured DEMAND hit-rate speedup (Algorithm 2; readahead
    touches are tagged prefetch in the pool so they cannot inflate their
    own A/B signal).

This host loop is the golden reference for the device-resident engine
(:mod:`repro.serving.engine_jax`): everything that decides tokens or
scheduling is deterministic —

  * per-slot positions travel to ``decode_step`` as a VECTOR, so a newly
    admitted slot decodes at ITS position 0 while its neighbours sit
    mid-sequence (a scalar ``pos.max()`` used to make staggered
    admissions write/attend at the wrong cache rows);
  * queue wait is accounted in decode STEPS keyed by an engine-assigned
    request id (wall-clock timestamps made Algorithm 1 nondeterministic,
    and ``t_in if t_in else ...`` misfired on the falsy-but-valid zeroth
    tick and on re-admission);
  * the token-bucket admission pick is a per-STREAM deficit argmax with a
    lowest-stream-index tie-break, then FIFO within the winning stream
    (was a first-come scan over the pending list, i.e. the tie-break
    depended on interleaving).

On-CPU tests drive it with tiny models; the decode step is the same jitted
``model.decode_step`` the dry-run lowers for the production mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bandwidth_controller import (
    allocate_bandwidth,
    check_bandwidth_floor,
)
from repro.core.prefetch_controller import throttle_decision
from repro.models.model import Model
from repro.serving.kv_cache import PagedKVPool


@dataclasses.dataclass
class Request:
    stream: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int = 16
    # filled in by the engine:
    generated: Optional[List[int]] = None
    slot: int = -1
    pages_touched: int = 0
    rid: int = -1                      # engine-assigned id; stable across
    #                                    re-admission (id(req) is not)


@dataclasses.dataclass
class EngineConfig:
    batch_slots: int = 4
    max_len: int = 128
    page_tokens: int = 16              # tokens per KV page
    total_pages: int = 64
    reconfig_every_steps: int = 32     # CBP reconfiguration interval
    speedup_threshold: float = 1.05
    min_slot_share: float = 0.5


class ServingEngine:
    def __init__(self, model: Model, params, n_streams: int,
                 cfg: Optional[EngineConfig] = None):
        self.model = model
        self.params = params
        self.cfg = cfg or EngineConfig()
        self.n_streams = n_streams
        check_bandwidth_floor(self.cfg.min_slot_share, n_streams,
                              float(self.cfg.batch_slots))
        self.pool = PagedKVPool(self.cfg.total_pages, n_streams)
        self.kv = model.init_cache(self.cfg.batch_slots, self.cfg.max_len,
                                   dtype=jnp.float32)
        self._decode = jax.jit(model.decode_step)
        # CBP state
        self.slot_share = np.full(n_streams,
                                  self.cfg.batch_slots / n_streams)
        self.readahead = np.zeros(n_streams, dtype=bool)
        self.queue_wait = np.zeros(n_streams)
        self.tokens_done = np.zeros(n_streams)
        self.steps = 0
        self.reconfigs = 0
        self._next_rid = 0

    # ------------------------------------------------------------- #

    def _touch_pages(self, req: Request, pos: int) -> None:
        page = pos // self.cfg.page_tokens
        self.pool.access(req.stream, (req.stream, req.rid, page))
        if self.readahead[req.stream]:
            self.pool.access(req.stream, (req.stream, req.rid, page + 1),
                             prefetch=True)
        req.pages_touched += 1

    def run(self, requests: List[Request], max_steps: int = 10_000
            ) -> List[Request]:
        """Continuous batching over the request list."""
        cfgE = self.cfg
        pending: List[Request] = list(requests)
        active: List[Optional[Request]] = [None] * cfgE.batch_slots
        tokens = np.zeros((cfgE.batch_slots, 1), dtype=np.int32)
        pos = np.zeros(cfgE.batch_slots, dtype=np.int64)
        enqueue_step: Dict[int, int] = {}
        stream_active = np.zeros(self.n_streams)

        def admit():
            for i in range(cfgE.batch_slots):
                if active[i] is not None:
                    continue
                if not pending:
                    break
                # token-bucket: the pending STREAM most under its slot
                # share wins; exact deficit ties break to the lowest
                # stream index, then FIFO within the stream.
                deficit = self.slot_share - stream_active
                has_pending = np.zeros(self.n_streams, dtype=bool)
                for r in pending:
                    has_pending[r.stream] = True
                deficit = np.where(has_pending, deficit, -np.inf)
                s = int(np.argmax(deficit))   # first max = lowest index
                best_j = next(j for j, r in enumerate(pending)
                              if r.stream == s)
                req = pending.pop(best_j)
                req.generated = []
                req.slot = i
                active[i] = req
                stream_active[req.stream] += 1
                t_in = enqueue_step.pop(req.rid, None)
                # `is not None`: step 0 is a perfectly valid enqueue tick.
                self.queue_wait[req.stream] += (
                    self.steps - t_in if t_in is not None else 0.0)
                tokens[i, 0] = req.prompt[0]
                pos[i] = 0

        for r in pending:
            r.rid = self._next_rid
            self._next_rid += 1
            enqueue_step[r.rid] = self.steps
        admit()

        steps = 0
        while any(a is not None for a in active) and steps < max_steps:
            # Per-slot positions go down as a VECTOR: each slot writes and
            # attends at its own position (a scalar max() corrupted newly
            # admitted slots whose position had reset to 0).
            logits, self.kv = self._decode(
                self.params, self.kv, jnp.asarray(tokens),
                jnp.asarray(pos, jnp.int32))
            nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
            for i, req in enumerate(active):
                if req is None:
                    continue
                self._touch_pages(req, int(pos[i]))
                p = int(pos[i]) + 1
                if p < len(req.prompt):
                    tokens[i, 0] = req.prompt[p]      # teacher-force prompt
                else:
                    req.generated.append(int(nxt[i]))
                    tokens[i, 0] = int(nxt[i])
                pos[i] = p
                self.tokens_done[req.stream] += 1
                done = (len(req.generated) >= req.max_new_tokens
                        or p >= cfgE.max_len - 1)
                if done:
                    stream_active[req.stream] -= 1
                    active[i] = None
            admit()
            steps += 1
            self.steps += 1
            if self.steps % cfgE.reconfig_every_steps == 0:
                self._reconfigure()
        return requests

    # ---------------- CBP coordination ---------------- #

    def _reconfigure(self) -> None:
        """Priority order per the paper: cache -> bandwidth -> prefetch."""
        self.reconfigs += 1
        # 1. cache: UCP over stack-distance curves
        self.pool.reconfigure()
        # 2. bandwidth: slots proportional to queue wait (Algorithm 1)
        self.slot_share = allocate_bandwidth(
            self.queue_wait + 1e-6, float(self.cfg.batch_slots),
            self.cfg.min_slot_share)
        self.queue_wait *= 0.5  # accumulate-with-decay (paper §3.3)
        # 3. prefetch: A/B throttle readahead on per-stream DEMAND
        # hit-rate gain (tokens/sec proxy on CPU): enable readahead for
        # streams whose demand hit rate improved while it was on —
        # prefetch touches are tagged in the pool and excluded here.
        rates = np.array([s.hit_rate for s in self.pool.stats])
        base = getattr(self, "_last_rates", rates)
        self.readahead = throttle_decision(
            rates + 1e-9, base + 1e-9, self.cfg.speedup_threshold)
        self._last_rates = rates
