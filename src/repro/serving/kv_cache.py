"""Paged KV-cache pool with CBP cache partitioning (DESIGN.md §2).

The HBM KV-page pool is the serving analogue of the paper's shared LLC:
concurrent request streams (tenants) contend for pages; prefix/context
reuse means a stream's hit rate is a concave function of its page
allocation — exactly a miss-ratio curve.  Each stream owns a
:class:`StackDistanceMonitor` (the software ATD), and the pool reallocates
partitions with UCP/Lookahead every reconfiguration interval, with the
same ``min_units`` floor and counter halving as the paper's cache
controller.

Pages within a stream's partition are managed LRU; exceeding the partition
evicts that stream's own LRU page (no cross-stream interference once
partitioned — enforcement).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Hashable, List, Optional, Tuple

import numpy as np

from repro.core.atd import StackDistanceMonitor
from repro.core.cache_controller import CacheController


@dataclasses.dataclass
class StreamStats:
    """Per-stream counters with demand accesses separated from prefetch.

    ``hits``/``misses`` count DEMAND accesses only; readahead touches land
    in ``prefetch_hits``/``prefetch_misses``.  Algorithm 2 throttles on the
    demand hit-rate gain — folding prefetch touches into the same counters
    let the prefetcher inflate its own A/B signal (every readahead touch of
    an already-resident page counted as a "hit" the prefetcher caused).
    """

    hits: int = 0
    misses: int = 0
    evictions: int = 0
    prefetch_hits: int = 0
    prefetch_misses: int = 0

    @property
    def hit_rate(self) -> float:
        """Demand hit rate — the Algorithm-2 A/B signal."""
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    @property
    def prefetch_hit_rate(self) -> float:
        total = self.prefetch_hits + self.prefetch_misses
        return self.prefetch_hits / total if total else 0.0


class PagedKVPool:
    """Fixed pool of KV pages partitioned across streams by CBP."""

    def __init__(self, total_pages: int, n_streams: int,
                 min_pages: int = 2, allocator_backend: str = "numpy"):
        if min_pages * n_streams > total_pages:
            raise ValueError("pool too small for min_pages floor")
        self.total_pages = total_pages
        self.n_streams = n_streams
        self.min_pages = min_pages
        # Backend-dispatched UCP/Lookahead (repro.core.cache_controller):
        # "jax" runs the repartition on device, useful when many pools
        # reconfigure together (e.g. a pool per model replica).
        self.controller = CacheController(
            total_pages, min_pages, backend=allocator_backend)
        self.partition = np.full(n_streams, total_pages // n_streams,
                                 dtype=np.int64)
        self.partition[: total_pages - int(self.partition.sum())] += 1
        self._resident: List[OrderedDict] = [OrderedDict()
                                             for _ in range(n_streams)]
        self.monitors = [StackDistanceMonitor(total_pages)
                         for _ in range(n_streams)]
        self.stats = [StreamStats() for _ in range(n_streams)]

    # ---------------- access path ---------------- #

    def access(self, stream: int, page_key: Hashable,
               prefetch: bool = False) -> bool:
        """Touch a page; returns True on hit.  Misses insert the page,
        evicting the stream's LRU page when over partition.

        ``prefetch=True`` tags a readahead touch: it moves pages and feeds
        the stack-distance monitor exactly like a demand access (prefetched
        pages genuinely occupy the partition, so the utility curve must see
        them), but the hit/miss lands in the prefetch counters so
        :attr:`StreamStats.hit_rate` stays a pure demand signal.
        """
        self.monitors[stream].access(page_key)
        res = self._resident[stream]
        st = self.stats[stream]
        hit = page_key in res
        if hit:
            res.move_to_end(page_key)
            if prefetch:
                st.prefetch_hits += 1
            else:
                st.hits += 1
        else:
            if prefetch:
                st.prefetch_misses += 1
            else:
                st.misses += 1
            res[page_key] = True
        self._enforce(stream)
        return hit

    def _enforce(self, stream: int) -> None:
        res = self._resident[stream]
        limit = int(self.partition[stream])
        while len(res) > limit:
            res.popitem(last=False)
            self.stats[stream].evictions += 1

    # ---------------- CBP cache controller ---------------- #

    def utility_curves(self) -> np.ndarray:
        return np.stack([m.utility_curve() for m in self.monitors])

    def reconfigure(self) -> np.ndarray:
        """UCP/Lookahead over the measured stack-distance curves
        (paper §3.2.1), then halve the ATD counters (paper §3.3)."""
        curves = self.utility_curves()
        self.partition = self.controller.allocate(curves)
        for m in self.monitors:
            m.halve()
        for s in range(self.n_streams):
            self._enforce(s)
        return self.partition

    def occupancy(self) -> np.ndarray:
        return np.array([len(r) for r in self._resident])
