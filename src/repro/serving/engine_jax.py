"""Device-resident continuous-batching serving engine with in-trace CBP.

:class:`JitServingEngine` rebuilds :class:`repro.serving.engine.
ServingEngine`'s per-token host loop as ONE jitted program per
reconfiguration interval: a ``lax.scan`` over decode steps with donated KV
buffers, a device-side pending-request queue, in-trace slot release and
token-bucket admission, and the three CBP knobs applied in-trace at
``reconfig_every_steps`` boundaries by reusing the traced controllers
(``lookahead_traced``, ``allocate_bandwidth_jax``,
``throttle_decision_jax``).  Between reconfigurations there are ZERO host
round-trips; the driver records one dispatch per interval
(:mod:`repro.core.dispatch`), well under the <= 2-per-interval budget.

Scheduling is the host engine's, op for op:

  * admission is a ``lax.while_loop`` that admits ONE request per group
    per trip — lowest-index empty slot, per-stream deficit
    ``slot_share - stream_active`` masked to pending streams, argmax with
    the lowest-stream-index tie-break, FIFO within the winning stream —
    exactly the host ``admit()``; trips amortize to (steps + admissions),
    not slots * pending;
  * queue wait is decode-steps-at-admission keyed by position in the
    request list (the host engine's step-keyed ``rid`` accounting);
  * per-slot positions go to ``decode_step`` as a vector, so tokens are
    identical to the host loop under greedy decode (pinned by
    ``tests/test_serving_jax.py``).

The paged-KV pool is ported to device arrays: the partition vector,
per-stream occupancy counters and a COARSE stack-distance histogram
carried through the scan (the way ``timeline_jax`` carries ATD weights).
Coarse model: a re-touched page's stack distance is the same-stream pages
touched since its last touch, ``active * (1 + readahead) - 1``; a page
crossing is cold unless readahead already pulled the page in; a touch
hits iff its distance < the stream's partition.  It feeds the same
Algorithm-2 demand-vs-prefetch split as the host pool, but is a proxy,
not a bit-mirror, of the LRU stack (tokens and scheduling do not depend
on it).

Scaling: ``n_groups`` splits streams/slots/pages into independent engine
shards laid out on a 2-D grid and sharded with
:func:`repro.distributed.shard_grid`; the KV cache shards its slot axis
(axis 1 of every cache leaf) in place via per-leaf PartitionSpecs, no
transposes.  Grouping is static, so results are device-count invariant;
``n_groups=1`` is bit-identical to the host engine's schedule.  The
encoder-decoder family is unsupported (its cache carries a batchless
``enc_len`` leaf).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bandwidth_controller import (
    allocate_bandwidth_jax,
    check_bandwidth_floor,
)
from repro.core.cache_controller_jax import lookahead_traced
from repro.core.dispatch import record_dispatch
from repro.core.prefetch_controller import throttle_decision_jax
from repro.distributed import PartitionSpec, shard_grid
from repro.models.model import Model
from repro.serving.engine import EngineConfig, Request

# Reconfiguration cadences above this run CBP-off: the scan chunk is capped
# and the in-trace reconfigure is compiled out (the --no-cbp baselines use
# reconfig_every_steps=10**9, which would otherwise ask for a 10**9-step
# scan).
_CHUNK_CAP = 1024
_OFF_CHUNK = 64


def _divisors(n: int) -> List[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def _plan_grid(n_groups: int) -> Tuple[int, int, int, int]:
    """Arrange ``n_groups`` on a (K, M) grid sharded (a, b) ways.

    Mirrors :func:`repro.distributed.grid_shard_counts`' preference —
    among plans using the most devices, the most balanced mesh wins —
    but constrains shard counts to divisors so groups never pad: a | K,
    b | M, K * M == n_groups.  (1, 1) shards mean "skip shard_map".
    """
    d = jax.device_count()
    best, best_key = (n_groups, 1, 1, 1), (1, 1)
    for K in _divisors(n_groups):
        M = n_groups // K
        for a in _divisors(K):
            if a > d:
                continue
            b = max(x for x in _divisors(M) if x <= d // a)
            key = (a * b, min(a, b))
            if key > best_key:
                best_key, best = key, (K, M, a, b)
    return best


class JitServingEngine:
    """Continuous batching + CBP as one jitted interval program.

    Same constructor surface as the host :class:`ServingEngine` plus
    ``n_groups`` (independent engine shards; streams, slots and pages must
    divide evenly).  ``run()`` launches one donated device program per
    reconfiguration interval and fetches a single "any slot still active"
    scalar between intervals.
    """

    def __init__(self, model: Model, params, n_streams: int,
                 cfg: Optional[EngineConfig] = None, n_groups: int = 1,
                 min_pages: int = 2):
        self.model = model
        self.params = params
        self.cfg = cfg or EngineConfig()
        self.n_streams = n_streams
        if model.cfg.family == "encdec":
            raise ValueError("encdec caches carry a batchless enc_len leaf; "
                             "use the host ServingEngine")
        for name in ("n_streams", "batch_slots", "total_pages"):
            val = n_streams if name == "n_streams" else getattr(self.cfg,
                                                               name)
            if val % n_groups:
                raise ValueError(f"{name}={val} not divisible by "
                                 f"n_groups={n_groups}")
        self.n_groups = n_groups
        self._spg = self.cfg.batch_slots // n_groups       # slots/group
        self._npg = n_streams // n_groups                  # streams/group
        self._pages_pg = self.cfg.total_pages // n_groups  # pages/group
        self._min_pages = min_pages
        if min_pages * self._npg > self._pages_pg:
            raise ValueError("pool too small for min_pages floor")
        check_bandwidth_floor(self.cfg.min_slot_share, self._npg,
                              float(self._spg))
        self._cbp_on = self.cfg.reconfig_every_steps <= _CHUNK_CAP
        self._chunk = (self.cfg.reconfig_every_steps if self._cbp_on
                       else _OFF_CHUNK)
        self._grid = _plan_grid(n_groups)
        self._interval_jit = jax.jit(self._interval, donate_argnums=(0,))
        # filled by run():
        self.steps = 0
        self.reconfigs = 0
        self.intervals = 0

    # ------------------------------------------------------------- #
    # state construction (host side, once per run)
    # ------------------------------------------------------------- #

    def _build_state(self, requests: List[Request]) -> Dict:
        G, spg, npg = self.n_groups, self._spg, self._npg
        cfgE = self.cfg
        per_group: List[List[int]] = [[] for _ in range(G)]
        for i, r in enumerate(requests):
            if not (0 <= r.stream < self.n_streams):
                raise ValueError(f"request stream {r.stream} out of range")
            if len(r.prompt) < 1:
                raise ValueError("empty prompt")
            r.rid = i
            per_group[r.stream // npg].append(i)
        R = max(1, max(len(g) for g in per_group))
        P = max(1, max((len(r.prompt) for r in requests), default=1))
        C = max(1, max((r.max_new_tokens for r in requests), default=1))
        self._req_loc = {}

        prompts = np.zeros((G, R, P), dtype=np.int32)
        prompt_len = np.ones((G, R), dtype=np.int32)
        req_stream = np.zeros((G, R), dtype=np.int32)
        max_new = np.zeros((G, R), dtype=np.int32)
        admitted = np.ones((G, R), dtype=bool)   # padding pre-admitted
        done = np.ones((G, R), dtype=bool)       # ... and pre-done
        enqueue_step = np.zeros((G, R), dtype=np.int32)
        pend_count = np.zeros((G, npg), dtype=np.int32)
        for g, idxs in enumerate(per_group):
            for r_loc, i in enumerate(idxs):
                req = requests[i]
                self._req_loc[i] = (g, r_loc)
                p = np.asarray(req.prompt, dtype=np.int32)
                prompts[g, r_loc, : len(p)] = p
                prompt_len[g, r_loc] = len(p)
                req_stream[g, r_loc] = req.stream % npg
                max_new[g, r_loc] = req.max_new_tokens
                admitted[g, r_loc] = False
                done[g, r_loc] = False
                pend_count[g, req.stream % npg] += 1

        U = self._pages_pg
        part = np.full((G, npg), U // npg, dtype=np.int32)
        part[:, : U - int(part[0].sum())] += 1
        q = {
            "tokens": np.zeros((G, spg), dtype=np.int32),
            "pos": np.zeros((G, spg), dtype=np.int32),
            "active": np.zeros((G, spg), dtype=bool),
            "slot_req": np.zeros((G, spg), dtype=np.int32),
            "slot_stream": np.zeros((G, spg), dtype=np.int32),
            "steps": np.zeros((G,), dtype=np.int32),
            "prompts": prompts, "prompt_len": prompt_len,
            "req_stream": req_stream, "max_new": max_new,
            "admitted": admitted, "done": done,
            "enqueue_step": enqueue_step, "pend_count": pend_count,
            "out_tokens": np.zeros((G, R, C), dtype=np.int32),
            "n_gen": np.zeros((G, R), dtype=np.int32),
            "partition": part,
            "slot_share": np.full((G, npg), spg / npg, dtype=np.float32),
            "readahead": np.zeros((G, npg), dtype=bool),
            "queue_wait": np.zeros((G, npg), dtype=np.float32),
            "stream_active": np.zeros((G, npg), dtype=np.int32),
            "sd_hist": np.zeros((G, npg, U + 1), dtype=np.float32),
            "demand_hits": np.zeros((G, npg), dtype=np.int32),
            "demand_misses": np.zeros((G, npg), dtype=np.int32),
            "prefetch_hits": np.zeros((G, npg), dtype=np.int32),
            "prefetch_misses": np.zeros((G, npg), dtype=np.int32),
            "occupancy": np.zeros((G, npg), dtype=np.int32),
            "evictions": np.zeros((G, npg), dtype=np.int32),
            "tokens_done": np.zeros((G, npg), dtype=np.int32),
            "last_rates": np.zeros((G, npg), dtype=np.float32),
            "reconfigs": np.zeros((G,), dtype=np.int32),
        }
        self._prime(q)
        kv = self.model.init_cache(G * spg, cfgE.max_len, dtype=jnp.float32)
        S = G * spg
        for leaf in jax.tree.leaves(kv):
            if leaf.ndim < 2 or leaf.shape[1] != S:
                raise ValueError(
                    "cache leaf without a slot axis at position 1: "
                    f"shape {leaf.shape} (family {self.model.cfg.family})")
        return {"kv": kv,
                "q": {k: jnp.asarray(v) for k, v in q.items()}}

    def _prime(self, q: Dict) -> None:
        """Initial admission, host-side numpy: the exact in-trace pick
        (lowest empty slot; deficit argmax over pending streams, lowest
        stream index on ties; FIFO within the stream) — saves one device
        dispatch before the first interval."""
        G, spg = q["active"].shape
        for g in range(G):
            for i in range(spg):
                if not q["pend_count"][g].sum():
                    break
                deficit = (q["slot_share"][g]
                           - q["stream_active"][g].astype(np.float32))
                deficit = np.where(q["pend_count"][g] > 0, deficit, -np.inf)
                s = int(np.argmax(deficit))
                cand = (~q["admitted"][g] & ~q["done"][g]
                        & (q["req_stream"][g] == s))
                r = int(np.argmax(cand))
                q["admitted"][g, r] = True
                q["active"][g, i] = True
                q["slot_req"][g, i] = r
                q["slot_stream"][g, i] = s
                q["tokens"][g, i] = q["prompts"][g, r, 0]
                q["pos"][g, i] = 0
                q["stream_active"][g, s] += 1
                q["pend_count"][g, s] -= 1
                q["queue_wait"][g, s] += float(
                    q["steps"][g] - q["enqueue_step"][g, r])

    # ------------------------------------------------------------- #
    # traced interval program
    # ------------------------------------------------------------- #

    def _one_step(self, st: Dict, params, max_steps) -> Dict:
        cfgE = self.cfg
        q = st["q"]
        G, spg = q["active"].shape
        R = q["admitted"].shape[1]
        P = q["prompts"].shape[2]
        U = self._pages_pg
        f32 = jnp.float32
        gi = jnp.arange(G, dtype=jnp.int32)
        gi2 = jnp.broadcast_to(gi[:, None], (G, spg))
        live = q["active"].any(-1) & (q["steps"] < max_steps)   # (G,)
        upd = q["active"] & live[:, None]                       # (G, spg)

        # ---- decode every slot at ITS position (satellite: vector pos) --
        logits, kv = self.model.decode_step(
            params, st["kv"], q["tokens"].reshape(G * spg, 1),
            q["pos"].reshape(G * spg))
        nxt = jnp.argmax(logits[:, -1, :], axis=-1)
        nxt = nxt.astype(jnp.int32).reshape(G, spg)

        # ---- coarse paged-KV accounting at the current position ---------
        strm = q["slot_stream"]
        ra = jnp.take_along_axis(q["readahead"], strm, 1)
        acnt = jnp.take_along_axis(q["stream_active"], strm, 1)
        part = jnp.take_along_axis(q["partition"], strm, 1)
        new_page = (q["pos"] % cfgE.page_tokens) == 0
        d_re = acnt * (1 + ra.astype(jnp.int32)) - 1
        cold = (q["pos"] == 0) | (new_page & ~ra)
        dist = jnp.where(cold, U, jnp.minimum(d_re, U))
        hit = upd & ~cold & (dist < part)
        miss = upd & ~hit
        sd_hist = q["sd_hist"].at[gi2, strm, dist].add(upd.astype(f32))
        # readahead touch of (page + 1): first touch per page is a cold
        # insert, later touches re-touch at the same coarse distance.
        pf = upd & ra
        pf_hit = pf & ~new_page & (d_re < part)
        pf_miss = pf & ~pf_hit
        pf_idx = jnp.where(new_page, U, jnp.minimum(d_re, U))
        sd_hist = sd_hist.at[gi2, strm, pf_idx].add(pf.astype(f32))
        demand_hits = q["demand_hits"].at[gi2, strm].add(
            hit.astype(jnp.int32))
        demand_misses = q["demand_misses"].at[gi2, strm].add(
            miss.astype(jnp.int32))
        prefetch_hits = q["prefetch_hits"].at[gi2, strm].add(
            pf_hit.astype(jnp.int32))
        prefetch_misses = q["prefetch_misses"].at[gi2, strm].add(
            pf_miss.astype(jnp.int32))
        occupancy = q["occupancy"].at[gi2, strm].add(
            miss.astype(jnp.int32) + pf_miss.astype(jnp.int32))
        over = jnp.maximum(occupancy - q["partition"], 0)  # LRU enforcement
        evictions = q["evictions"] + over
        occupancy = occupancy - over
        tokens_done = q["tokens_done"].at[gi2, strm].add(
            upd.astype(jnp.int32))

        # ---- advance: teacher-force the prompt, emit, retire ------------
        p1 = q["pos"] + 1
        plen = jnp.take_along_axis(q["prompt_len"], q["slot_req"], 1)
        prompt_tok = q["prompts"][gi2, q["slot_req"],
                                  jnp.clip(p1, 0, P - 1)]
        in_prompt = p1 < plen
        tok_next = jnp.where(in_prompt, prompt_tok, nxt)
        gen_now = upd & ~in_prompt
        req_sel = jnp.where(gen_now, q["slot_req"], R)  # OOB rows dropped
        ci = jnp.take_along_axis(q["n_gen"], q["slot_req"], 1)
        out_tokens = q["out_tokens"].at[gi2, req_sel, ci].set(
            nxt, mode="drop")
        n_gen = q["n_gen"].at[gi2, req_sel].add(1, mode="drop")
        maxnew = jnp.take_along_axis(q["max_new"], q["slot_req"], 1)
        ng_after = ci + gen_now.astype(jnp.int32)
        done_now = upd & ((ng_after >= maxnew) | (p1 >= cfgE.max_len - 1))
        tokens = jnp.where(upd, tok_next, q["tokens"])
        pos = jnp.where(upd, p1, q["pos"])
        active = q["active"] & ~done_now
        stream_active = q["stream_active"].at[gi2, strm].add(
            -done_now.astype(jnp.int32))
        done = q["done"].at[gi2, jnp.where(done_now, q["slot_req"], R)].set(
            True, mode="drop")

        # ---- admission: one request per group per while trip ------------
        def adm_cond(c):
            c_active = c[0]
            c_pend = c[6]
            return ((live & (~c_active).any(-1)
                     & (c_pend.sum(-1) > 0)).any())

        def adm_body(c):
            (c_active, c_sreq, c_sstrm, c_pos, c_tok, c_sact, c_pend,
             c_qw, c_adm) = c
            empty = ~c_active
            slot_i = jnp.argmax(empty, -1).astype(jnp.int32)      # (G,)
            deficit = q["slot_share"] - c_sact.astype(f32)
            deficit = jnp.where(c_pend > 0, deficit, -jnp.inf)
            s = jnp.argmax(deficit, -1).astype(jnp.int32)         # (G,)
            can = live & empty.any(-1) & (c_pend.sum(-1) > 0)
            cand = ~c_adm & ~done & (q["req_stream"] == s[:, None])
            r = jnp.argmax(cand, -1).astype(jnp.int32)            # FIFO
            can = can & cand.any(-1)
            rsel = jnp.where(can, r, R)
            ssel = jnp.where(can, slot_i, spg)
            c_adm = c_adm.at[gi, rsel].set(True, mode="drop")
            c_active = c_active.at[gi, ssel].set(True, mode="drop")
            c_sreq = c_sreq.at[gi, ssel].set(r, mode="drop")
            c_sstrm = c_sstrm.at[gi, ssel].set(s, mode="drop")
            c_pos = c_pos.at[gi, ssel].set(0, mode="drop")
            tok0 = q["prompts"][gi, jnp.clip(r, 0, R - 1), 0]
            c_tok = c_tok.at[gi, ssel].set(tok0, mode="drop")
            inc = can.astype(jnp.int32)
            c_sact = c_sact.at[gi, s].add(inc)
            c_pend = c_pend.at[gi, s].add(-inc)
            enq = q["enqueue_step"][gi, jnp.clip(r, 0, R - 1)]
            wait = jnp.where(can, (q["steps"] - enq).astype(f32), 0.0)
            c_qw = c_qw.at[gi, s].add(wait)
            return (c_active, c_sreq, c_sstrm, c_pos, c_tok, c_sact,
                    c_pend, c_qw, c_adm)

        def adm_quad(c):
            # Four admissions per while trip: once nothing is admittable
            # the body is a no-op (`can` gates every scatter to dropped
            # indices and zero adds), so the unroll preserves the exact
            # one-at-a-time deficit schedule while quartering the
            # while_loop's per-trip overhead — the same trick as
            # ``cache_controller_jax._greedy_loop``'s body_quad, and for
            # the same reason: on CPU the trips are tiny-op bound.
            return adm_body(adm_body(adm_body(adm_body(c))))

        (active, slot_req, slot_stream, pos, tokens, stream_active,
         pend_count, queue_wait, admitted) = jax.lax.while_loop(
            adm_cond, adm_quad,
            (active, q["slot_req"], q["slot_stream"], pos, tokens,
             stream_active, q["pend_count"], q["queue_wait"],
             q["admitted"]))

        q2 = dict(
            q, tokens=tokens, pos=pos, active=active, slot_req=slot_req,
            slot_stream=slot_stream, steps=q["steps"] + live.astype(
                jnp.int32),
            admitted=admitted, done=done, pend_count=pend_count,
            out_tokens=out_tokens, n_gen=n_gen, sd_hist=sd_hist,
            demand_hits=demand_hits, demand_misses=demand_misses,
            prefetch_hits=prefetch_hits, prefetch_misses=prefetch_misses,
            occupancy=occupancy, evictions=evictions,
            stream_active=stream_active, queue_wait=queue_wait,
            tokens_done=tokens_done)
        return {"kv": kv, "q": q2}

    def _reconfigure(self, st: Dict, did_full) -> Dict:
        """Cache -> bandwidth -> prefetch, the paper's priority order,
        gated per group on having advanced a full interval."""
        q = st["q"]
        G, n = q["partition"].shape
        U = self._pages_pg
        f32 = jnp.float32
        a1 = did_full[:, None]
        # 1. cache: UCP/Lookahead over the coarse stack-distance curves
        # (curve[0] = 0; curve[k] = hits with k pages = cumsum of the
        # finite-distance histogram — StackDistanceMonitor.utility_curve).
        hist = q["sd_hist"]
        curve = jnp.concatenate(
            [jnp.zeros((G, n, 1), f32),
             jnp.cumsum(hist[..., :U], axis=-1)], axis=-1)
        part_new = lookahead_traced(
            curve, jnp.full((G,), self._min_pages, jnp.int32),
            total_units=U, backend="jax").astype(jnp.int32)
        partition = jnp.where(a1, part_new, q["partition"])
        sd_hist = jnp.where(did_full[:, None, None], hist * 0.5, hist)
        over = jnp.where(a1, jnp.maximum(q["occupancy"] - partition, 0), 0)
        evictions = q["evictions"] + over
        occupancy = q["occupancy"] - over
        # 2. bandwidth: Algorithm 1 over accumulated queue wait
        share_new = allocate_bandwidth_jax(
            q["queue_wait"] + 1e-6, float(self._spg),
            self.cfg.min_slot_share).astype(f32)
        slot_share = jnp.where(a1, share_new, q["slot_share"])
        queue_wait = jnp.where(a1, q["queue_wait"] * 0.5, q["queue_wait"])
        # 3. prefetch: Algorithm 2 on the DEMAND hit-rate gain
        tot = q["demand_hits"] + q["demand_misses"]
        rates = jnp.where(tot > 0,
                          q["demand_hits"].astype(f32)
                          / jnp.maximum(tot, 1).astype(f32), 0.0)
        base = jnp.where((q["reconfigs"] == 0)[:, None], rates,
                         q["last_rates"])
        ra_new = throttle_decision_jax(rates + 1e-9, base + 1e-9,
                                       self.cfg.speedup_threshold)
        readahead = jnp.where(a1, ra_new, q["readahead"])
        last_rates = jnp.where(a1, rates, q["last_rates"])
        q2 = dict(q, partition=partition, sd_hist=sd_hist,
                  evictions=evictions, occupancy=occupancy,
                  slot_share=slot_share, queue_wait=queue_wait,
                  readahead=readahead, last_rates=last_rates,
                  reconfigs=q["reconfigs"] + did_full.astype(jnp.int32))
        return {"kv": st["kv"], "q": q2}

    def _group_body(self, st: Dict, params, max_steps) -> Dict:
        start = st["q"]["steps"]

        def step(s, _):
            # Skip the decode entirely once every group is frozen (all
            # done or at max_steps): the scan length is static, so the
            # tail of the final interval would otherwise burn full decode
            # steps on a dead batch.
            any_live = jnp.any(s["q"]["active"].any(-1)
                               & (s["q"]["steps"] < max_steps))
            return jax.lax.cond(
                any_live, lambda x: self._one_step(x, params, max_steps),
                lambda x: x, s), None

        st, _ = jax.lax.scan(step, st, None, length=self._chunk)
        if self._cbp_on:
            # Freezing (all-done / max_steps) is permanent, so a group
            # either advanced the whole interval or never will again.
            st = self._reconfigure(st, (st["q"]["steps"] - start)
                                   == self._chunk)
        return st

    def _interval(self, state: Dict, params, max_steps):
        K, M, a, b = self._grid
        if a * b == 1:
            st = self._group_body(state, params, max_steps)
        else:
            spg = self._spg

            def to_grid(s):
                return {
                    "kv": jax.tree.map(
                        lambda l: l.reshape(l.shape[:1] + (K, M, spg)
                                            + l.shape[2:]), s["kv"]),
                    "q": jax.tree.map(
                        lambda l: l.reshape((K, M) + l.shape[1:]), s["q"]),
                }

            def from_grid(s):
                return {
                    "kv": jax.tree.map(
                        lambda l: l.reshape(l.shape[:1] + (K * M * spg,)
                                            + l.shape[4:]), s["kv"]),
                    "q": jax.tree.map(
                        lambda l: l.reshape((K * M,) + l.shape[2:]),
                        s["q"]),
                }

            def worker(grid, _gids, repl):
                p, ms = repl
                Kl = grid["q"]["steps"].shape[0]
                Ml = grid["q"]["steps"].shape[1]
                loc = {
                    "kv": jax.tree.map(
                        lambda l: l.reshape(l.shape[:1] + (Kl * Ml * spg,)
                                            + l.shape[4:]), grid["kv"]),
                    "q": jax.tree.map(
                        lambda l: l.reshape((Kl * Ml,) + l.shape[2:]),
                        grid["q"]),
                }
                out = self._group_body(loc, p, ms)
                return {
                    "kv": jax.tree.map(
                        lambda l: l.reshape(l.shape[:1] + (Kl, Ml, spg)
                                            + l.shape[2:]), out["kv"]),
                    "q": jax.tree.map(
                        lambda l: l.reshape((Kl, Ml) + l.shape[1:]),
                        out["q"]),
                }

            g, r = "sg", "sr"
            grid_specs = {
                # cache leaves: slot axis lives at position 1 — shard the
                # (K, M) split of that axis in place, layer axis untouched.
                "kv": PartitionSpec(None, g, r),
                "q": PartitionSpec(g, r),
            }
            st = from_grid(shard_grid(
                worker, (a, b), (g, r), grid_specs=grid_specs)(
                    to_grid(state), jnp.arange(K), (params, max_steps)))
        return st, st["q"]["active"].any()

    # ------------------------------------------------------------- #
    # driver
    # ------------------------------------------------------------- #

    def run(self, requests: List[Request], max_steps: int = 10_000
            ) -> List[Request]:
        """Continuous batching over the request list; one device dispatch
        per reconfiguration interval."""
        if not requests:
            return requests
        state = self._build_state(requests)
        ms = jnp.int32(min(max_steps, np.iinfo(np.int32).max))
        n_intervals = max(1, math.ceil(max_steps / self._chunk))
        self.intervals = 0
        for _ in range(n_intervals):
            record_dispatch()
            state, any_active = self._interval_jit(state, self.params, ms)
            self.intervals += 1
            if not bool(any_active):
                break
        self._finalize(state, requests)
        return requests

    def _finalize(self, state: Dict, requests: List[Request]) -> None:
        q = {k: np.asarray(v) for k, v in state["q"].items()}
        for i, req in enumerate(requests):
            g, r = self._req_loc[i]
            if q["admitted"][g, r]:
                k = int(q["n_gen"][g, r])
                req.generated = [int(t) for t in q["out_tokens"][g, r, :k]]

        def flat(name):
            return q[name].reshape(-1)  # stream s = g * npg + s_local

        self.steps = int(q["steps"].max())
        self.reconfigs = int(q["reconfigs"].max())
        self.slot_share = flat("slot_share").astype(np.float64)
        self.queue_wait = flat("queue_wait").astype(np.float64)
        self.readahead = flat("readahead")
        self.partition = flat("partition").astype(np.int64)
        self.occupancy = flat("occupancy").astype(np.int64)
        self.evictions = flat("evictions").astype(np.int64)
        self.tokens_done = flat("tokens_done").astype(np.float64)
        hits, misses = flat("demand_hits"), flat("demand_misses")
        tot = np.maximum(hits + misses, 1)
        self.demand_hit_rate = np.where(hits + misses > 0,
                                        hits / tot, 0.0)
        ph, pm = flat("prefetch_hits"), flat("prefetch_misses")
        self.prefetch_hit_rate = np.where(ph + pm > 0,
                                          ph / np.maximum(ph + pm, 1), 0.0)
