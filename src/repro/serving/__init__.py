from repro.serving.engine import EngineConfig, Request, ServingEngine
from repro.serving.engine_jax import JitServingEngine
from repro.serving.kv_cache import PagedKVPool

__all__ = ["ServingEngine", "EngineConfig", "Request", "PagedKVPool",
           "JitServingEngine"]
