"""Post-SPMD HLO text analysis for the roofline (spec: ROOFLINE ANALYSIS).

``compiled.as_text()`` prints per-device shapes (post-partitioning) but XLA's
``cost_analysis()`` counts while-loop (scan) bodies ONCE — useless for
scan-over-layers models.  This parser rebuilds the call graph
(ENTRY -> fusion/call/while computations), reads each while op's
``known_trip_count`` backend config, and accumulates:

  * dot/convolution FLOPs (2 * prod(out) * prod(contracting dims)),
  * per-instruction HBM bytes (operands + outputs of top-level scheduled
    instructions — fusions counted at their interface, a good model of TPU
    HBM traffic since fused interiors stay in VMEM/registers),
  * collective bytes by kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), with ring-model wire bytes.

Everything scales by the product of enclosing trip counts.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^)]*?\)?[\w\[\],{}/ ]*?)\s+"
    r"([\w\-]+)\((.*)$")


def _parse_shape_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _parse_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    out_type: str
    op: str
    rest: str


@dataclasses.dataclass
class CostSummary:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_wire_bytes: float = 0.0
    collective_count: Dict[str, int] = dataclasses.field(
        default_factory=dict)

    def add(self, other: "CostSummary", times: float = 1.0):
        self.flops += other.flops * times
        self.hbm_bytes += other.hbm_bytes * times
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = (
                self.collective_bytes.get(k, 0.0) + v * times)
        self.collective_wire_bytes += other.collective_wire_bytes * times
        for k, v in other.collective_count.items():
            self.collective_count[k] = (
                self.collective_count.get(k, 0) + int(v * times))

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


class HloModuleCosts:
    def __init__(self, hlo_text: str):
        self.computations = self._split_computations(hlo_text)
        self.entry = next(
            (n for n in self.computations if n.startswith("ENTRY:")), None)
        self._memo: Dict[str, CostSummary] = {}
        # symbol table: per computation, instr name -> out_type
        self._types: Dict[str, Dict[str, str]] = {}
        for cname, instrs in self.computations.items():
            self._types[cname] = {i.name: i.out_type for i in instrs}

    # ---------------- parsing ---------------- #

    @staticmethod
    def _split_computations(text: str) -> Dict[str, List[Instr]]:
        comps: Dict[str, List[Instr]] = {}
        cur: Optional[str] = None
        for line in text.splitlines():
            if not line.startswith(" ") and "{" in line:
                header = line.strip()
                m = re.match(r"(ENTRY\s+)?%?([\w.\-]+)\s*\(", header)
                if m:
                    name = m.group(2)
                    cur = ("ENTRY:" + name) if m.group(1) else name
                    comps[cur] = []
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _INSTR_RE.match(line)
            if m:
                comps[cur].append(Instr(*m.groups()))
        return comps

    def _lookup(self, comp: str, operand: str) -> str:
        return self._types.get(comp, {}).get(operand.strip().lstrip("%"), "")

    # ---------------- cost model ---------------- #

    def _dot_flops(self, comp: str, instr: Instr) -> float:
        out = _parse_dims(instr.out_type)
        if out is None:
            return 0.0
        _, out_dims = out
        out_n = 1
        for d in out_dims:
            out_n *= d
        # contracting dims from lhs shape + lhs_contracting_dims
        ops = instr.rest.split(")", 1)[0]
        operands = [o.strip().lstrip("%") for o in ops.split(",")]
        mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
        contract = 1
        if mc and operands:
            lhs_type = self._lookup(comp, operands[0])
            lhs = _parse_dims(lhs_type)
            if lhs:
                _, lhs_dims = lhs
                for ci in mc.group(1).split(","):
                    if ci and int(ci) < len(lhs_dims):
                        contract *= lhs_dims[int(ci)]
        return 2.0 * out_n * contract

    def _conv_flops(self, comp: str, instr: Instr) -> float:
        out = _parse_dims(instr.out_type)
        if out is None:
            return 0.0
        _, out_dims = out
        out_n = 1
        for d in out_dims:
            out_n *= d
        ops = instr.rest.split(")", 1)[0]
        operands = [o.strip().lstrip("%") for o in ops.split(",")]
        kernel_n = 1
        if len(operands) >= 2:
            k = _parse_dims(self._lookup(comp, operands[1]))
            if k:
                _, kd = k
                for d in kd:
                    kernel_n *= d
        mg = re.search(r"feature_group_count=(\d+)", instr.rest)
        groups = int(mg.group(1)) if mg else 1
        return 2.0 * out_n * max(kernel_n // max(groups, 1), 1)

    def _group_size(self, instr: Instr) -> int:
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]", instr.rest)
        if m:
            return int(m.group(2))
        m = re.search(r"replica_groups=\{\{([^}]*)\}", instr.rest)
        if m:
            return len([x for x in m.group(1).split(",") if x.strip()])
        return 2

    def _collective(self, instr: Instr, cost: CostSummary):
        kind = instr.op
        nbytes = _parse_shape_bytes(instr.out_type)
        g = self._group_size(instr)
        cost.collective_bytes[kind] = (
            cost.collective_bytes.get(kind, 0.0) + nbytes)
        cost.collective_count[kind] = cost.collective_count.get(kind, 0) + 1
        # Ring-model bytes actually crossing each device's links:
        if kind == "all-gather":
            wire = nbytes * (g - 1) / g
        elif kind == "all-reduce":
            wire = 2.0 * nbytes * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = nbytes * (g - 1)            # out is the scattered shard
        elif kind == "all-to-all":
            wire = nbytes * (g - 1) / g
        else:  # collective-permute
            wire = nbytes
        cost.collective_wire_bytes += wire

    def _called(self, instr: Instr) -> List[Tuple[str, float]]:
        """(computation, multiplier) pairs invoked by this instruction."""
        out = []
        if instr.op == "while":
            mb = re.search(r"body=%?([\w.\-]+)", instr.rest)
            mc = re.search(r"condition=%?([\w.\-]+)", instr.rest)
            mt = re.search(r'known_trip_count[="{\s:]+\{?"?n"?[":\s]+(\d+)',
                           instr.rest)
            trips = float(mt.group(1)) if mt else 1.0
            if mb:
                out.append((mb.group(1), trips))
            if mc:
                out.append((mc.group(1), trips))
        elif instr.op in ("fusion", "call", "custom-call", "async-start"):
            m = re.search(r"calls=%?([\w.\-]+)", instr.rest)
            if m:
                out.append((m.group(1), 1.0))
        elif instr.op == "conditional":
            for m in re.finditer(
                    r"(?:true_computation|false_computation|branch_computations=\{)[^,}]*%?([\w.\-]+)",
                    instr.rest):
                out.append((m.group(1), 1.0))
        return out

    def computation_cost(self, name: str, top_level: bool) -> CostSummary:
        key = f"{name}@{top_level}"
        if key in self._memo:
            return self._memo[key]
        cost = CostSummary()
        instrs = self.computations.get(name) or self.computations.get(
            "ENTRY:" + name, [])
        for instr in instrs:
            if instr.op == "dot":
                cost.flops += self._dot_flops(name, instr)
            elif instr.op == "convolution":
                cost.flops += self._conv_flops(name, instr)
            elif instr.op in COLLECTIVES or any(
                    instr.op.startswith(c + "-") for c in COLLECTIVES):
                base = instr.op
                for c in COLLECTIVES:
                    if instr.op.startswith(c):
                        base = c
                if instr.op.endswith("-done"):
                    continue
                inst2 = dataclasses.replace(instr, op=base)
                self._collective(inst2, cost)
            # HBM bytes: top-level scheduled instrs move operands+outputs.
            if top_level and instr.op not in (
                    "parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "while", "conditional"):
                nbytes = _parse_shape_bytes(instr.out_type)
                ops = instr.rest.split(")", 1)[0]
                for o in ops.split(","):
                    t = self._lookup(name, o)
                    nbytes += _parse_shape_bytes(t)
                cost.hbm_bytes += nbytes
            for callee, times in self._called(instr):
                sub_top = top_level and instr.op in ("while", "conditional",
                                                     "call")
                cost.add(self.computation_cost(callee, sub_top), times)
        self._memo[key] = cost
        return cost

    def entry_cost(self) -> CostSummary:
        if self.entry is None:
            return CostSummary()
        return self.computation_cost(self.entry, top_level=True)


def analyze(hlo_text: str) -> CostSummary:
    return HloModuleCosts(hlo_text).entry_cost()
