"""Serving launcher: CBP-managed batched decode for any --arch.

CPU runs use the reduced smoke config end-to-end; on a TPU slice the same
engine binds the full config (the dry-run proves serve_step compiles on
the production mesh).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b \
      --requests 12 --streams 3 [--no-cbp] [--engine jit]

``--engine jit`` swaps in the device-resident continuous-batching engine
(one jitted program per reconfiguration interval, in-trace CBP); with
``--groups G`` its stream groups shard across visible devices.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.models import build
from repro.serving import (
    EngineConfig,
    JitServingEngine,
    Request,
    ServingEngine,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=configs.names())
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--streams", type=int, default=3)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--no-cbp", action="store_true")
    ap.add_argument("--engine", default="host", choices=("host", "jit"),
                    help="host = per-token Python loop; "
                         "jit = device-resident interval programs")
    ap.add_argument("--groups", type=int, default=1,
                    help="stream groups for --engine jit (sharded across "
                         "devices when more than one is visible)")
    ap.add_argument("--full", action="store_true",
                    help="full (non-smoke) config — TPU only")
    args = ap.parse_args()

    cfg = configs.get(args.arch) if args.full else configs.get_smoke(
        args.arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(
        batch_slots=args.slots, max_len=96, total_pages=16 * args.streams,
        page_tokens=8,
        reconfig_every_steps=(10 ** 9 if args.no_cbp else 24))
    if args.engine == "jit":
        engine = JitServingEngine(model, params, n_streams=args.streams,
                                  cfg=ecfg, n_groups=args.groups)
    else:
        engine = ServingEngine(model, params, n_streams=args.streams,
                               cfg=ecfg)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        stream = i % args.streams
        if stream == 0:  # hot shared prefix
            prompt = np.concatenate(
                [np.arange(8), rng.integers(8, 64, 4)])
        else:
            prompt = rng.integers(0, cfg.vocab_size - 1, 16)
        reqs.append(Request(stream=stream, prompt=prompt.astype(np.int32),
                            max_new_tokens=args.max_new))

    engine.run(reqs, max_steps=5000)
    print(f"arch={args.arch} engine={args.engine} "
          f"cbp={'off' if args.no_cbp else 'on'} "
          f"steps={engine.steps} reconfigs={engine.reconfigs}")
    if args.engine == "jit":
        partition, hit_rate = engine.partition, engine.demand_hit_rate
    else:
        partition = engine.pool.partition
        hit_rate = [engine.pool.stats[s].hit_rate
                    for s in range(args.streams)]
    for s in range(args.streams):
        print(f"  stream {s}: pages={int(partition[s]):3d} "
              f"hit-rate={hit_rate[s]:5.1%} "
              f"slots={engine.slot_share[s]:.2f}")
    done = sum(1 for r in reqs if r.generated)
    print(f"  completed {done}/{len(reqs)}")


if __name__ == "__main__":
    main()
