"""Analytic per-device memory model (no compilation needed)."""
from __future__ import annotations

from typing import Dict


def analytic_memory(cfg, spec, chips: int, optimizer: str) -> Dict:
    """Ground-truth per-device residency in bytes (native TPU dtypes —
    the CPU backend's memory_analysis inflates bf16 buffers to f32 around
    collectives/updates, so this analytic model is the capacity proof and
    memory_analysis is corroborating evidence; both are recorded)."""
    n = cfg.param_count()
    mdl = max(cfg.mesh_model, 1)
    params_b = 2.0 * n / (mdl if not cfg.pure_dp else chips // 1)
    if cfg.pure_dp:
        params_b = 2.0 * n  # replicated
    out = {"params_bytes": params_b}
    if spec.kind == "train":
        if optimizer == "adamw":
            opt = 12.0 * n            # f32 master+m+v
        else:
            opt = 4.2 * n             # f32 master + factored moments
        out["opt_bytes"] = opt / chips  # ZeRO-1 over data x model
        out["grads_bytes"] = 2.0 * n / mdl
    if spec.kind == "decode":
        sites = cfg.n_layers
        if cfg.family == "hybrid":
            sites = (cfg.n_layers + cfg.attn_every - 1) // cfg.attn_every
        kv = 0.0
        if cfg.family not in ("ssm",):
            kv = (2.0 * sites * spec.global_batch * spec.seq_len
                  * cfg.n_kv_heads * cfg.head_dim * 2.0)
            if cfg.family == "encdec":
                kv *= 2.0  # cross-attention K/V
        state = 0.0
        if cfg.family in ("ssm", "hybrid"):
            state = (4.0 * cfg.n_layers * spec.global_batch
                     * (cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
                        + (cfg.ssm_conv - 1) * cfg.d_inner))
        out["kv_cache_bytes"] = (kv + state) / chips
    out["total_bytes"] = float(sum(out.values()))
    return out

