import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (spec: MULTI-POD DRY-RUN).

For every (architecture x input-shape x mesh) cell: build the step function
(train_step / prefill / serve_step per the shape kind), attach shardings,
``.lower().compile()`` against the production mesh, and record
memory/cost/collective analysis to a JSON cache.  The XLA_FLAGS line above
MUST stay the first statement — jax locks the device count on first init.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import pathlib
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.distributed import set_dp_axes, use_mesh
from repro.launch import shardings as sh
from repro.launch.analytic import analytic_memory
from repro.launch.hlo_parse import analyze
from repro.launch.mesh import dp_size, make_production_mesh, model_size
from repro.models import SHAPES, build
from repro.models.model import Model
from repro.train.step import TrainStepConfig, build_train_step

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

# TPU v5e roofline constants (spec: ROOFLINE ANALYSIS)
PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
LINK_BW = 50e9            # B/s / ICI link

# Large-model configs use a factored optimizer (DESIGN.md §4: full AdamW
# state for 314B params does not fit a 256-chip v5e pod).
OPTIMIZER = {
    "grok-1-314b": "adafactor",
    "qwen3-moe-30b-a3b": "adafactor",
    "yi-34b": "adamw",
}

# Microbatching for the biggest activation footprints.
MICROBATCHES = {
    ("grok-1-314b", "train_4k"): 8,
    ("yi-34b", "train_4k"): 4,
    ("pixtral-12b", "train_4k"): 4,
}


def default_microbatches(cfg, shape_name: str) -> int:
    if SHAPES[shape_name].kind != "train":
        return 1
    mb = MICROBATCHES.get((cfg.name, shape_name))
    if mb:
        return mb
    return 2 if cfg.param_count() > 1e9 else 1


def _cell_path(mesh_kind: str, arch: str, shape: str) -> pathlib.Path:
    return RESULTS_DIR / f"{mesh_kind}__{arch}__{shape}.json"


def build_cell(model: Model, shape_name: str, mesh, optimizer: str,
               microbatches: int):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs)."""
    cfg = model.cfg
    spec = SHAPES[shape_name]
    batch_shapes = model.input_specs(shape_name)

    if spec.kind == "train":
        tcfg = TrainStepConfig(optimizer=optimizer,
                               microbatches=microbatches)
        init_opt, train_step = build_train_step(model, tcfg)
        params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        opt_shape = jax.eval_shape(init_opt, params_shape)
        pspec = sh.param_specs(cfg, params_shape, mesh)
        ospec = sh.opt_state_specs(cfg, opt_shape, params_shape, mesh,
                                   optimizer)
        bspec = sh.batch_specs(cfg, batch_shapes, mesh)
        fn = jax.jit(
            train_step,
            in_shardings=(sh.named(pspec, mesh), sh.named(ospec, mesh),
                          sh.named(bspec, mesh)),
            out_shardings=(sh.named(pspec, mesh), sh.named(ospec, mesh),
                           None),
            donate_argnums=(0, 1),
        )
        return fn, (params_shape, opt_shape, batch_shapes)

    if spec.kind == "prefill":
        params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        pspec = sh.param_specs(cfg, params_shape, mesh)
        bspec = sh.batch_specs(cfg, batch_shapes, mesh)

        def prefill(params, batch):
            return model.prefill(params, batch)

        fn = jax.jit(
            prefill,
            in_shardings=(sh.named(pspec, mesh), sh.named(bspec, mesh)))
        if "labels" in batch_shapes and cfg.family != "encdec" \
                and "tokens" in batch_shapes:
            pass
        return fn, (params_shape, batch_shapes)

    # decode
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    b, s = spec.global_batch, spec.seq_len
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(b, s, dtype=jnp.bfloat16))
    pspec = sh.param_specs(cfg, params_shape, mesh)
    cspec = sh.cache_specs(cfg, cache_shape, mesh)
    bspec = sh.batch_specs(cfg, batch_shapes, mesh)

    def serve_step(params, cache, batch):
        return model.decode_step(params, cache, batch["tokens"],
                                 batch["cur_len"])

    fn = jax.jit(
        serve_step,
        in_shardings=(sh.named(pspec, mesh), sh.named(cspec, mesh),
                      sh.named(bspec, mesh)),
        out_shardings=(None, sh.named(cspec, mesh)),
        donate_argnums=(1,),
    )
    return fn, (params_shape, cache_shape, batch_shapes)



def model_flops(cfg, spec, chips: int) -> float:
    """Spec formula: 6*N*D (train) / 2*N*D (inference), N_active for MoE."""
    n = cfg.active_param_count()
    if spec.kind == "train":
        d = spec.global_batch * spec.seq_len
        return 6.0 * n * d / chips
    if spec.kind == "prefill":
        d = spec.global_batch * spec.seq_len
        return 2.0 * n * d / chips
    return 2.0 * n * spec.global_batch / chips  # decode: one token/seq


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             force: bool = False) -> Dict:
    out_path = _cell_path(mesh_kind, arch, shape_name)
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.size
    cfg = configs.get(arch).with_mesh(model_size(mesh), dp_size(mesh))
    model = build(cfg)
    spec = SHAPES[shape_name]

    rec: Dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "chips": chips, "kind": spec.kind,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "status": "skip",
    }
    if not model.supports_shape(shape_name):
        rec["reason"] = ("long_500k requires sub-quadratic sequence mixing;"
                        f" {arch} is pure full-attention (DESIGN.md §5)")
        _write(out_path, rec)
        return rec

    t0 = time.time()
    try:
        set_dp_axes(sh.dp_axes_for(cfg))
        with use_mesh(mesh):
            fn, args = build_cell(
                model, shape_name, mesh,
                OPTIMIZER.get(arch, "adamw"),
                default_microbatches(cfg, shape_name))
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            hlo = compiled.as_text()
            cost = analyze(hlo)

        mf = model_flops(cfg, spec, chips)
        compute_s = cost.flops / PEAK_FLOPS
        memory_s = cost.hbm_bytes / HBM_BW
        collective_s = cost.total_collective_bytes / LINK_BW
        terms = {"compute_s": compute_s, "memory_s": memory_s,
                 "collective_s": collective_s}
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_estimate_bytes": (mem.argument_size_in_bytes
                                        + mem.temp_size_in_bytes),
                "analytic": analytic_memory(
                    cfg, spec, chips, OPTIMIZER.get(arch, "adamw")),
            },
            "xla_cost_analysis": {
                "flops": ca.get("flops", 0.0),
                "bytes_accessed": ca.get("bytes accessed", 0.0),
            },
            "parsed": {
                "flops_per_device": cost.flops,
                "hbm_bytes_per_device": cost.hbm_bytes,
                "collective_bytes": cost.collective_bytes,
                "collective_counts": cost.collective_count,
                "collective_wire_bytes": cost.collective_wire_bytes,
                "total_collective_bytes": cost.total_collective_bytes,
            },
            "roofline": {
                **terms,
                "dominant": max(terms, key=terms.get),
                "model_flops_per_device": mf,
                "useful_flops_ratio": (mf / cost.flops
                                       if cost.flops else 0.0),
                "step_time_bound_s": max(terms.values()),
                "roofline_fraction": (compute_s / max(terms.values())
                                      if max(terms.values()) > 0 else 0.0),
            },
        })
    except Exception as exc:  # noqa: BLE001 — record failures as data
        rec["status"] = "error"
        rec["error"] = f"{type(exc).__name__}: {exc}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    finally:
        set_dp_axes(("pod", "data"))
    _write(out_path, rec)
    return rec


def _write(path: pathlib.Path, rec: Dict) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(rec, indent=1, default=float))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = configs.names() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mesh_kind, force=args.force)
                status = rec["status"]
                if status == "ok":
                    r = rec["roofline"]
                    print(f"[{mesh_kind}] {arch} x {shape}: OK "
                          f"compile={rec['compile_s']}s "
                          f"dom={r['dominant']} "
                          f"frac={r['roofline_fraction']:.2f} "
                          f"mem/dev={rec['memory']['peak_estimate_bytes']/2**30:.2f}GiB",
                          flush=True)
                elif status == "skip":
                    print(f"[{mesh_kind}] {arch} x {shape}: SKIP "
                          f"({rec['reason'][:60]}...)", flush=True)
                else:
                    failures += 1
                    print(f"[{mesh_kind}] {arch} x {shape}: ERROR "
                          f"{rec['error'][:160]}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
