"""Training launcher: fault-tolerant loop with CBP-managed input pipeline.

On this CPU container it runs reduced configs end-to-end (see
``examples/train_lm.py``); on a TPU pod slice, the identical code path runs
under the production mesh (``--mesh single|multi``) — the dry-run proves
those configs compile.

Features exercised here (and tested in tests/test_train_loop.py):
  * checkpoint/restart (atomic, keep-k, async) with pipeline resume,
  * straggler watchdog on step times,
  * CBP coordination of pipeline prefetch depth + checkpoint write rate,
  * microbatched train step, AdamW/Adafactor, optional grad compression.
"""
from __future__ import annotations

import argparse
import pathlib
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import PrefetchPipeline, SyntheticTokens
from repro.models import build
from repro.runtime.fault import StragglerWatchdog
from repro.train.step import TrainStepConfig, build_train_step


def train_loop(
    arch: str,
    steps: int = 50,
    batch: int = 8,
    seq: int = 64,
    lr: float = 1e-3,
    optimizer: str = "adamw",
    microbatches: int = 1,
    ckpt_dir: Optional[pathlib.Path] = None,
    ckpt_every: int = 20,
    smoke: bool = True,
    log_every: int = 10,
    cbp_manage: bool = True,
) -> Dict:
    cfg = configs.get_smoke(arch) if smoke else configs.get(arch)
    model = build(cfg)
    tcfg = TrainStepConfig(optimizer=optimizer, lr=lr,
                           microbatches=microbatches)
    init_opt, train_step = build_train_step(model, tcfg)
    step_fn = jax.jit(train_step, donate_argnums=(0, 1))

    params = model.init(jax.random.PRNGKey(0))
    opt_state = init_opt(params)
    source = SyntheticTokens(batch, seq, cfg.vocab_size, seed=1)
    pipe = PrefetchPipeline(source, depth=2)
    watchdog = StragglerWatchdog()
    mgr = CheckpointManager(ckpt_dir, keep=2) if ckpt_dir else None

    start_step = 0
    if mgr is not None:
        restored = mgr.restore_latest({"params": params, "opt": opt_state})
        if restored is not None:
            start_step, tree, extra = restored
            params, opt_state = tree["params"], tree["opt"]
            if "data" in extra:
                source.restore(extra["data"])

    losses = []
    mitigations = 0
    pf_decision_log = []
    for step in range(start_step, steps):
        batch_np = next(pipe)
        t0 = time.monotonic()
        params, opt_state, metrics = step_fn(
            params, opt_state,
            {k: jnp.asarray(v) for k, v in batch_np.items()})
        loss = float(metrics["loss"])
        dt = time.monotonic() - t0
        if watchdog.observe(step, dt):
            mitigations += 1
        losses.append(loss)

        # CBP prefetch throttle: A/B the pipeline depth on step throughput
        if cbp_manage and step > 0 and step % 16 == 0:
            tp_with = pipe.throughput()
            pipe.set_depth(0 if pipe.depth else 2)
            pf_decision_log.append((step, pipe.depth, tp_with))

        if mgr is not None and (step + 1) % ckpt_every == 0:
            mgr.save_async(step + 1,
                           {"params": params, "opt": opt_state},
                           extra={"data": source.state()})
        if log_every and step % log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} {dt*1e3:.0f}ms",
                  flush=True)
    if mgr is not None:
        mgr.wait()
    pipe.stop()
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "mitigations": mitigations, "params": params}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=configs.names())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--full", action="store_true",
                    help="full (non-smoke) config — TPU pods only")
    args = ap.parse_args()
    out = train_loop(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        lr=args.lr, optimizer=args.optimizer,
        microbatches=args.microbatches,
        ckpt_dir=pathlib.Path(args.ckpt) if args.ckpt else None,
        smoke=not args.full)
    print(f"final loss: {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
