"""PartitionSpec assignment for parameters, optimizer state, caches and
batches (DESIGN.md §4).

Rules are *leaf-name based* and rank-aware so the same table covers stacked
(``(L, ...)``) and unstacked (hybrid shared block) parameters:

  wq / wg / wu / wi / wx / wz / wdt  -> shard LAST dim over "model"
        (query heads / d_ff / ssm channels; column-parallel)
  wo / wd / out                      -> shard dim -2 over "model"
        (row-parallel: contraction dim sharded, output partial-summed)
  wk / wv / router / norms / biases  -> replicated (GQA KV replication)
  moe wg/wu/wd (rank 4)              -> shard EXPERT dim over "model" (EP)
  embed (V, d)                       -> shard d (gather stays local)
  head (d, V)                        -> shard V (vocab-parallel logits)
  A_log / D / dt_bias / norm (rank 2)-> shard last (ssm heads/channels)

Batches shard over the DP axes; decode KV caches shard the *sequence* dim
over "model" (split-KV decode) and SSM states shard heads.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.optim.optimizers import OptState

LAST = {"wg", "wu", "wi", "wx", "wz", "wdt", "embed"}
ROW = {"wo", "wd", "out"}
REPL = {"wk", "wv", "router", "ln", "ln1", "ln2", "lnx", "q_norm",
        "k_norm", "final_norm", "enc_norm", "dt_bias_repl"}
VEC_LAST = {"A_log", "D", "dt_bias", "norm", "conv"}


def dp_axes_for(cfg: ModelConfig):
    if cfg.pure_dp:
        return ("pod", "data", "model")
    return ("pod", "data")


def _dp(mesh, cfg: Optional[ModelConfig] = None) -> Optional[tuple]:
    wanted = dp_axes_for(cfg) if cfg is not None else ("pod", "data")
    axes = tuple(a for a in wanted if a in mesh.axis_names)
    return axes if axes else None


def _mdl(mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


def param_spec_for(path: tuple, leaf, cfg: ModelConfig, mesh) -> P:
    name = None
    for entry in reversed(path):
        if hasattr(entry, "key"):
            name = entry.key
            break
    m = _mdl(mesh)
    rank = len(leaf.shape)
    if m is None or cfg.pure_dp:
        return P(*([None] * rank))
    heads_ok = cfg.heads_shardable

    if name == "wq":
        return P(*([None] * (rank - 1)), m if heads_ok else None)
    if name == "wo":
        spec = [None] * rank
        if heads_ok:
            spec[rank - 2] = m
        return P(*spec)
    if name in ("wk", "wv"):
        return P(*([None] * rank))
    if name in ("wg", "wu", "wd") and rank == 4:   # MoE experts
        if cfg.moe_ep:
            return P(None, m, None, None)          # EP over experts
        dat = "data" if "data" in mesh.axis_names else None
        if name == "wd":                           # (L, E, f, d)
            return P(None, None, m, dat)
        return P(None, None, dat, m)               # TP(f) x FSDP(d)
    if name in LAST:
        if name == "embed":
            return P(None, m)  # (V, d): shard d -> local gather
        return P(*([None] * (rank - 1)), m)
    if name in ROW:
        spec = [None] * rank
        spec[rank - 2] = m
        return P(*spec)
    if name == "head":
        return P(None, m)
    if name in VEC_LAST:
        if name == "conv":                          # (L, di, K)
            spec = [None] * rank
            spec[rank - 2] = m
            return P(*spec)
        if name == "norm" and rank >= 2:            # (L, di)
            return P(*([None] * (rank - 1)), m)
        if name in ("A_log", "D", "dt_bias") and rank >= 1:
            return P(*([None] * (rank - 1)), m)
    return P(*([None] * rank))


def _validated(spec: P, leaf, mesh) -> P:
    """Drop axes whose mesh size does not divide the dim (reduced smoke
    configs and elastic odd-sized meshes)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = int(np.prod([mesh.shape[a] for a in axes]))
        dim = leaf.shape[i] if i < len(leaf.shape) else 0
        out.append(entry if dim % n == 0 and dim >= n else None)
    return P(*out)


def param_specs(cfg: ModelConfig, params_shape, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _validated(
            param_spec_for(path, leaf, cfg, mesh), leaf, mesh),
        params_shape)


def _zero1(pspec: P, leaf, mesh) -> P:
    """ZeRO-1: additionally shard optimizer state over the "data" axis on
    the first unsharded dim that divides (the update is elementwise, so
    any layout is local; the only cost is the per-step master->param
    all-gather over "data")."""
    if "data" not in mesh.axis_names:
        return pspec
    n = mesh.shape["data"]
    spec = list(pspec) + [None] * (len(leaf.shape) - len(pspec))
    used = {a for s in spec if s for a in
            (s if isinstance(s, tuple) else (s,))}
    if "data" in used:   # already FSDP-sharded over data (grok experts)
        return pspec
    best = -1
    for i, (dim, s) in enumerate(zip(leaf.shape, spec)):
        if s is None and dim % n == 0 and dim >= n:
            if best < 0 or dim > leaf.shape[best]:
                best = i
    if best >= 0:
        spec[best] = "data"
    return P(*spec)


def opt_state_specs(cfg: ModelConfig, opt_shape: OptState, params_shape,
                    mesh, kind: str) -> OptState:
    """Optimizer state mirrors parameter sharding + ZeRO-1 over "data";
    adafactor factored moments drop the reduced dim from the spec."""
    pspecs = param_specs(cfg, params_shape, mesh)

    if kind == "sgd":
        return OptState(P(), None, None, None)

    zspecs = jax.tree.map(
        lambda s, l: _zero1(s, l, mesh), pspecs, params_shape,
        is_leaf=lambda x: isinstance(x, P))

    if kind == "adamw":
        return OptState(
            step=P(), master=zspecs,
            m=zspecs, v=zspecs)

    # adafactor: v leaves are tuples (vr, vc) or (vfull,)
    def v_spec(pspec: P, vleaf):
        if len(vleaf) == 2:
            vr = P(*pspec[:-1])
            vc = P(*(pspec[:-2] + (pspec[-1],)))
            return (_zero1(vr, vleaf[0], mesh), _zero1(vc, vleaf[1], mesh))
        return (_zero1(pspec, vleaf[0], mesh),)

    is_v = lambda x: isinstance(x, tuple) and not isinstance(x, P) and all(
        hasattr(e, "shape") for e in x)
    v = jax.tree.map(v_spec, pspecs, opt_shape.v,
                     is_leaf=lambda x: isinstance(x, P) or is_v(x))
    return OptState(step=P(), master=zspecs, m=None, v=v)


def _best_dp_subset(mesh, cfg, b: int) -> Optional[tuple]:
    """Largest prefix of the DP axes whose product divides the batch."""
    axes = list(_dp(mesh, cfg) or ())
    while axes:
        n = int(np.prod([mesh.shape[a] for a in axes]))
        if b % n == 0 and b >= n:
            return tuple(axes)
        axes.pop()
    return None


def batch_specs(cfg: ModelConfig, batch_shape: Dict, mesh) -> Dict:
    def one(leaf):
        if leaf.ndim == 0:
            return P()
        lead = _best_dp_subset(mesh, cfg, leaf.shape[0])
        return P(lead, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(one, batch_shape)


def cache_specs(cfg: ModelConfig, cache_shape: Dict, mesh) -> Dict:
    m = _mdl(mesh) if not cfg.pure_dp else None

    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if leaf.ndim == 0:
            return P()
        spec = [None] * leaf.ndim
        b = leaf.shape[1] if leaf.ndim > 1 else 0
        spec[1] = _best_dp_subset(mesh, cfg, b) if b else None
        if name in ("k", "v", "xk", "xv") and leaf.ndim == 5:
            spec[2] = m              # (L, B, Smax, Hkv, Dh): shard sequence
        elif name == "state" and leaf.ndim == 5:
            spec[2] = m              # (L, B, H, P, N): shard ssm heads
        elif name == "conv" and leaf.ndim == 4:
            spec[3] = m              # (L, B, K-1, di): shard channels
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def named(tree, mesh):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree, is_leaf=lambda x: isinstance(x, P))
