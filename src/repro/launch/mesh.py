"""Production mesh definitions (spec: MULTI-POD DRY-RUN step 1).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = ("data", "model") — 256 chips (v5e pod).
    Multi-pod: (2, 16, 16) = ("pod", "data", "model") — 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (axes present, size 1)."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def dp_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def model_size(mesh) -> int:
    return mesh.shape.get("model", 1)
