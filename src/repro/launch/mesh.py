"""Production mesh definitions (spec: MULTI-POD DRY-RUN step 1).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state.
"""
from __future__ import annotations

from repro.distributed import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = ("data", "model") — 256 chips (v5e pod).
    Multi-pod: (2, 16, 16) = ("pod", "data", "model") — 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (axes present, size 1)."""
    return make_mesh((1, 1), ("data", "model"))


def dp_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def model_size(mesh) -> int:
    return mesh.shape.get("model", 1)
