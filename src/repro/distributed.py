"""Ambient mesh context for sharding constraints inside model code.

Model code calls :func:`constrain` on activations; when a mesh has been
installed by the launcher the call lowers to
``jax.lax.with_sharding_constraint`` with a :class:`NamedSharding`, and when
running unsharded (CPU smoke tests) it is a no-op.  Axis names that are not
present in the installed mesh are dropped from the spec, so the same model
code serves the (data, model), (pod, data, model) and single-device cases.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

_state = threading.local()


def axis_types_kwargs(n_axes: int) -> dict:
    """Version shim: ``jax.sharding.AxisType`` landed after 0.4.x.

    On new JAX, ``jax.make_mesh`` wants explicit axis types; on old JAX the
    attribute (and the ``axis_types`` kwarg) does not exist.  Returns the
    kwargs dict to splat into ``jax.make_mesh``.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(shape, axis_names) -> Mesh:
    """``jax.make_mesh`` with Auto axis types on JAX versions that have them."""
    return jax.make_mesh(shape, axis_names, **axis_types_kwargs(len(shape)))


def shard_map(worker, mesh, in_specs, out_specs):
    """Version shim over ``shard_map``'s migration into the jax namespace.

    New JAX: ``jax.shard_map(..., check_vma=...)``; old JAX:
    ``jax.experimental.shard_map.shard_map(..., check_rep=...)``.  Replication
    checking is disabled (callers use collectives the checker cannot type).
    """
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn(worker, mesh=mesh, in_specs=in_specs,
                  out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as exp_shard_map
    return exp_shard_map(worker, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)

def row_shard_count(n_rows: int) -> int:
    """How many ways a leading batch axis of ``n_rows`` should shard.

    Uses every visible device (``XLA_FLAGS=--xla_force_host_platform_
    device_count=N`` forces N host devices for local testing); returns 1
    when a single device is present or the batch is empty, which callers
    treat as "skip shard_map entirely".
    """
    if n_rows <= 0:
        return 1
    return max(1, jax.device_count())


def shard_rows(worker, n_shards: int, axis_name: str = "mix"):
    """shard_map ``worker(sharded_tree, replicated_tree)`` over rows.

    Builds a 1-D mesh of ``n_shards`` devices and maps the worker with the
    first argument's leaves sharded on their leading axis (every leaf must
    carry the batch axis, padded to a multiple of ``n_shards`` by the
    caller) and the second argument replicated.  This is how the fused
    Fig. 8 timeline (:mod:`repro.sim.timeline_jax`) spreads the mix axis
    of hundreds-of-mixes sweeps across devices.
    """
    mesh = make_mesh((n_shards,), (axis_name,))
    return shard_map(
        worker, mesh,
        in_specs=(PartitionSpec(axis_name), PartitionSpec()),
        out_specs=PartitionSpec(axis_name))


# Logical axis groups: "dp" spreads over every data-parallel mesh axis.
DP_AXES = ("pod", "data")


def set_dp_axes(axes) -> None:
    """Override which mesh axes count as data-parallel ("dp") — e.g.
    ("pod", "data", "model") for pure-DP tiny models."""
    _state.dp_axes = tuple(axes)


def get_dp_axes():
    return getattr(_state, "dp_axes", DP_AXES)


def set_mesh(mesh: Optional[Mesh]) -> None:
    _state.mesh = mesh


def get_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    prev = get_mesh()
    set_mesh(mesh)
    try:
        yield mesh
    finally:
        set_mesh(prev)


def _resolve_axis(axis, mesh: Mesh):
    """Map a logical axis (or tuple) to the axes present in ``mesh``."""
    if axis is None:
        return None
    if axis == "dp":
        present = tuple(a for a in get_dp_axes() if a in mesh.axis_names)
        return present if present else None
    if isinstance(axis, tuple):
        present = tuple(a for a in axis if a in mesh.axis_names)
        return present if present else None
    return axis if axis in mesh.axis_names else None


def spec(*axes) -> PartitionSpec:
    """Build a PartitionSpec against the ambient mesh ("dp" = all DP axes).

    Mesh axes already claimed by an earlier entry are dropped from later
    entries (e.g. pure-DP mode resolves "dp" to ("data", "model"), so a
    subsequent explicit "model" entry becomes None)."""
    mesh = get_mesh()
    if mesh is None:
        return PartitionSpec(*([None] * len(axes)))
    used = set()
    out = []
    for a in axes:
        r = _resolve_axis(a, mesh)
        if r is None:
            out.append(None)
            continue
        if isinstance(r, tuple):
            r = tuple(x for x in r if x not in used)
            used.update(r)
            out.append(r if r else None)
        else:
            if r in used:
                out.append(None)
            else:
                used.add(r)
                out.append(r)
    return PartitionSpec(*out)


def constrain(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint against the ambient mesh (no-op if none)."""
    mesh = get_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec(*axes)))
