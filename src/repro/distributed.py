"""Ambient mesh context for sharding constraints inside model code.

Model code calls :func:`constrain` on activations; when a mesh has been
installed by the launcher the call lowers to
``jax.lax.with_sharding_constraint`` with a :class:`NamedSharding`, and when
running unsharded (CPU smoke tests) it is a no-op.  Axis names that are not
present in the installed mesh are dropped from the spec, so the same model
code serves the (data, model), (pod, data, model) and single-device cases.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

_state = threading.local()


def axis_types_kwargs(n_axes: int) -> dict:
    """Version shim: ``jax.sharding.AxisType`` landed after 0.4.x.

    On new JAX, ``jax.make_mesh`` wants explicit axis types; on old JAX the
    attribute (and the ``axis_types`` kwarg) does not exist.  Returns the
    kwargs dict to splat into ``jax.make_mesh``.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(shape, axis_names, devices=None) -> Mesh:
    """``jax.make_mesh`` with Auto axis types on JAX versions that have them.

    ``devices`` restricts the mesh to a device subset (the shard-count
    clamps in :func:`row_shard_count` / :func:`grid_shard_counts` can pick
    fewer shards than visible devices so tiny batches are not mostly
    padding); ``None`` keeps jax.make_mesh's all-devices default.
    """
    kwargs = axis_types_kwargs(len(shape))
    if devices is not None:
        kwargs["devices"] = devices
    try:
        return jax.make_mesh(shape, axis_names, **kwargs)
    except TypeError:  # pragma: no cover - pre-`devices=` JAX
        if devices is None:
            raise
        import numpy as np
        return Mesh(np.asarray(devices).reshape(shape), axis_names)


def shard_map(worker, mesh, in_specs, out_specs):
    """Version shim over ``shard_map``'s migration into the jax namespace.

    New JAX: ``jax.shard_map(..., check_vma=...)``; old JAX:
    ``jax.experimental.shard_map.shard_map(..., check_rep=...)``.  Replication
    checking is disabled (callers use collectives the checker cannot type).
    """
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn(worker, mesh=mesh, in_specs=in_specs,
                  out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as exp_shard_map
    return exp_shard_map(worker, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)

def row_shard_count(n_rows: int) -> int:
    """How many ways a leading batch axis of ``n_rows`` should shard.

    Uses the visible devices (``XLA_FLAGS=--xla_force_host_platform_
    device_count=N`` forces N host devices for local testing), clamped to
    ``n_rows`` so a tiny batch never shards wider than it has rows (8
    forced devices and 3 mixes used to build 8 shards whose padding
    outnumbered the real rows).  Returns 1 when a single device is present
    or the batch is empty, which callers treat as "skip shard_map
    entirely".
    """
    if n_rows <= 0:
        return 1
    return max(1, min(n_rows, jax.device_count()))


def shard_rows(worker, n_shards: int, axis_name: str = "mix"):
    """shard_map ``worker(sharded_tree, replicated_tree)`` over rows.

    Builds a 1-D mesh of ``n_shards`` devices (the first ``n_shards`` of
    the visible devices — :func:`row_shard_count` may clamp below the
    device count) and maps the worker with the first argument's leaves
    sharded on their leading axis (every leaf must carry the batch axis,
    padded to a multiple of ``n_shards`` by the caller) and the second
    argument replicated.  This is how the fused Fig. 8 timeline
    (:mod:`repro.sim.timeline_jax`) spreads the mix axis of
    hundreds-of-mixes sweeps across devices.
    """
    devices = None
    if n_shards < jax.device_count():
        devices = jax.devices()[:n_shards]
    mesh = make_mesh((n_shards,), (axis_name,), devices=devices)
    return shard_map(
        worker, mesh,
        in_specs=(PartitionSpec(axis_name), PartitionSpec()),
        out_specs=PartitionSpec(axis_name))


def grid_shard_counts(n_groups: int, n_rows: int) -> Tuple[int, int]:
    """Factor the visible devices into a (group, row) shard grid.

    For the stacked Fig. 8 timelines the grid is (manager, mix): manager
    groups shard on the first mesh axis, mixes on the second, so different
    managers' timelines execute on different devices concurrently.  Each
    axis is clamped to its extent (shards <= rows, like
    :func:`row_shard_count`); among factorizations using the most devices
    the most balanced one wins (maximal ``min(a, b)``, then maximal row
    shards), which keeps per-axis padding small and exercises a genuine
    2-D mesh whenever both axes have room.  ``(1, 1)`` means "skip
    shard_map entirely".
    """
    d = jax.device_count()
    if n_groups <= 0 or n_rows <= 0 or d <= 1:
        return (1, 1)
    best = (1, 1)
    best_key = (1, 1, 1)
    for a in range(1, min(n_groups, d) + 1):
        b = min(n_rows, d // a)
        key = (a * b, min(a, b), b)
        if key > best_key:
            best, best_key = (a, b), key
    return best


def shard_grid(worker, grid_shards: Tuple[int, int],
               axis_names: Tuple[str, str] = ("mgr", "mix"),
               grid_specs=None):
    """shard_map ``worker(grid_tree, group_tree, replicated_tree)`` over a
    2-D (group x row) grid.

    ``grid_tree`` leaves carry two leading batch axes ``(K, M, ...)`` and
    shard on both mesh axes; ``group_tree`` leaves carry only the group
    axis ``(K, ...)`` (per-manager segment tables and knob flags) and
    shard on the first axis alone; ``replicated_tree`` is replicated.
    Callers pad K and M to multiples of the shard counts.  With
    ``grid_shards == (1, n)`` this degenerates to :func:`shard_rows` over
    the row axis (the single-group / single-device fallback); callers skip
    shard_map entirely at ``(1, 1)``.

    ``grid_specs`` optionally overrides the grid tree's partition specs
    with a pytree (prefix) of :class:`PartitionSpec` — for leaves whose
    grid axes are NOT leading (the serving engine's KV cache carries its
    slot axis at position 1, so its leaves use
    ``PartitionSpec(None, g, r)``).  The same specs describe the worker's
    outputs, which must mirror the grid tree's structure.
    """
    a, b = grid_shards
    devices = None
    if a * b < jax.device_count():
        devices = jax.devices()[: a * b]
    mesh = make_mesh((a, b), axis_names, devices=devices)
    g, r = axis_names
    if grid_specs is None:
        grid_specs = PartitionSpec(g, r)
    return shard_map(
        worker, mesh,
        in_specs=(grid_specs, PartitionSpec(g), PartitionSpec()),
        out_specs=grid_specs)


# Logical axis groups: "dp" spreads over every data-parallel mesh axis.
DP_AXES = ("pod", "data")


def set_dp_axes(axes) -> None:
    """Override which mesh axes count as data-parallel ("dp") — e.g.
    ("pod", "data", "model") for pure-DP tiny models."""
    _state.dp_axes = tuple(axes)


def get_dp_axes():
    return getattr(_state, "dp_axes", DP_AXES)


def set_mesh(mesh: Optional[Mesh]) -> None:
    _state.mesh = mesh


def get_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    prev = get_mesh()
    set_mesh(mesh)
    try:
        yield mesh
    finally:
        set_mesh(prev)


def _resolve_axis(axis, mesh: Mesh):
    """Map a logical axis (or tuple) to the axes present in ``mesh``."""
    if axis is None:
        return None
    if axis == "dp":
        present = tuple(a for a in get_dp_axes() if a in mesh.axis_names)
        return present if present else None
    if isinstance(axis, tuple):
        present = tuple(a for a in axis if a in mesh.axis_names)
        return present if present else None
    return axis if axis in mesh.axis_names else None


def spec(*axes) -> PartitionSpec:
    """Build a PartitionSpec against the ambient mesh ("dp" = all DP axes).

    Mesh axes already claimed by an earlier entry are dropped from later
    entries (e.g. pure-DP mode resolves "dp" to ("data", "model"), so a
    subsequent explicit "model" entry becomes None)."""
    mesh = get_mesh()
    if mesh is None:
        return PartitionSpec(*([None] * len(axes)))
    used = set()
    out = []
    for a in axes:
        r = _resolve_axis(a, mesh)
        if r is None:
            out.append(None)
            continue
        if isinstance(r, tuple):
            r = tuple(x for x in r if x not in used)
            used.update(r)
            out.append(r if r else None)
        else:
            if r in used:
                out.append(None)
            else:
                used.add(r)
                out.append(r)
    return PartitionSpec(*out)


def constrain(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint against the ambient mesh (no-op if none)."""
    mesh = get_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec(*axes)))
