"""Fused TrainingPlant: the whole Fig. 8 knob schedule as ONE program.

:class:`repro.runtime.cbp_runtime.TrainingPlant` + the host
:class:`~repro.core.coordinator.CBPCoordinator` pay a host round-trip per
schedule segment — fine for a handful of intervals, a non-starter for the
per-step control loops the runtime wants.  This module ports the fused
fig8-timeline pattern (:mod:`repro.sim.timeline_jax`) to the training
plant: the segment list is encoded as a ``(kinds, durations, reconfigure)``
table and a single jitted ``lax.scan`` executes every segment — staging
buffer reallocation via ``lookahead_traced``, Algorithm-1 bandwidth splits
via ``allocate_bandwidth_jax``, Algorithm-2 A/B throttling via
``throttle_decision_jax`` at the interval boundaries — so a full knob
schedule is O(1) device dispatches per run (dispatch-counter gated by
``benchmarks/runtime_bench.py``).

The host coordinator path stays as the parity golden: with a step model
written once over an array namespace (see :mod:`repro.train.plant_model`)
the fused trajectory is BIT-identical to the host knob trajectory on 1 and
8 forced devices (``tests/test_plant_jax.py``), riding the same backend
ladder discipline as the simulator (numpy golden -> traced mirrors ->
fused scan).

The step model is the traced mirror of ``TrainingPlant.step_fn``::

    model(duration_ms, units_f64, bandwidth, prefetch_f64)
        -> (throughput (n,), queue_wait_ms (n,), utility_curves (n, U+1))

It must be pure ``jax.numpy`` (it runs inside the scan) and, for host
parity, arithmetically identical to the host step function — elementwise
float64 ops only, shared precomputed constants.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.bandwidth_controller import check_bandwidth_floor
from repro.core.coordinator import IntervalRecord, fig8_schedule
from repro.core.dispatch import record_dispatch
from repro.core.prefetch_controller import throttle_decision_jax
from repro.core.types import CBPParams, Mode, PrefetchMode

#: Segment kind codes — shared with the simulator's fused timeline so the
#: two fused subsystems cannot drift on schedule encoding.
from repro.sim.timeline_jax import NOOP, RUN, SAMPLE_OFF, SAMPLE_ON, segment_table


@dataclasses.dataclass
class PlantScheduleResult:
    """Per-segment knob trajectory + observations of one fused run.

    Rows are the *executed* (non-boundary) segments of the Fig. 8 schedule,
    in order — exactly the rows the host coordinator appends to
    ``history``.  ``kinds`` uses the ``timeline_jax`` codes
    (``SAMPLE_OFF/SAMPLE_ON/RUN``); host-derived trajectories reconstruct
    them from the same ``fig8_schedule`` call.
    """

    kinds: np.ndarray          # (S,) int32 segment kind codes
    t_ms: np.ndarray           # (S,) segment start times
    duration_ms: np.ndarray    # (S,)
    cache_units: np.ndarray    # (S, n) int64 — staging-buffer partitions
    bandwidth: np.ndarray      # (S, n) float64
    prefetch_on: np.ndarray    # (S, n) bool (as applied, incl. A/B forcing)
    ipc: np.ndarray            # (S, n) throughput observed per segment
    queuing_delay_ns: np.ndarray  # (S, n) queue wait observed per segment

    def mean_ipc(self) -> np.ndarray:
        """Time-weighted mean throughput per client (host ``mean_ipc``)."""
        w = self.duration_ms[:, None]
        return (self.ipc * w).sum(axis=0) / max(self.duration_ms.sum(), 1e-12)


def _segment_starts(durations: np.ndarray) -> np.ndarray:
    """Start times by the host coordinator's exact accumulation order."""
    t, starts = 0.0, []
    for d in durations:
        starts.append(t)
        t += float(d)
    return np.array(starts, dtype=np.float64)


def pin_f64(x, zero):
    """Pin a float64 value's bits: round-trip through int64, xor ``zero``.

    XLA's CPU backend emits mul+add chains with LLVM contraction (FMA — a
    single rounding where numpy rounds twice) and re-association enabled,
    and ``lax.optimization_barrier`` does NOT survive to that level — the
    mul and add still land in one fused loop body and contract.  Bit-exact
    parity with a numpy golden therefore needs each rounding point forced
    through the *integer* domain: LLVM cannot contract or re-associate
    across a bitcast, and the xor with a runtime-opaque zero (a traced
    input, so never constant-folded) keeps instcombine from collapsing the
    bitcast pair back to identity.  Value-wise this is the identity
    function.

    Traced step models that want bit-parity with their numpy twin should
    pin every binary-op result with this (see
    :func:`repro.train.plant_model.make_stream_plant_model`).
    """
    from jax import lax
    import jax.numpy as jnp

    return lax.bitcast_convert_type(
        lax.bitcast_convert_type(x, jnp.int64) ^ zero, jnp.float64)


@functools.lru_cache(maxsize=None)
def _compiled_schedule(model: Callable, n: int, total_units: int,
                       cache_dynamic: bool, bandwidth_dynamic: bool,
                       prefetch_dynamic: bool, backend: Optional[str]):
    """Build + jit the scan for one (model, statics) combination.

    The scan step mirrors ``CBPCoordinator.run`` op for op: maybe
    reconfigure (cache -> ATD decay -> bandwidth, the paper's priority
    order), force the A/B prefetch setting, evaluate the plant model,
    accumulate the ATD counters and the decayed queuing-delay accumulator,
    and fold the throttle decision after each ``sample_on`` segment.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.cache_controller_jax import lookahead_traced

    def run(kinds, durs, reconf, units0, bw0, pf0, scalars, zero):
        min_ways, total_bw, min_bw, atd_decay, bw_decay, threshold = scalars

        def pin(x):
            return pin_f64(x, zero)

        def numpy_order_sum(vec):
            """Sum a static-length (n,) vector in numpy's exact rounding
            order.

            XLA lowers ``reduce`` through SIMD lanes whose accumulation
            tree differs from numpy's pairwise summation, so
            ``delay.sum()`` inside the scan lands 1 ulp off the host
            golden.  ``n`` is static, so the add tree unrolls in Python,
            mirroring numpy's ``pairwise_sum``: sequential under 8
            elements, 8-way unrolled accumulators up to 128, recursive
            halving (on an 8-multiple split) beyond.  Every partial sum is
            pinned so LLVM cannot re-associate the chain.
            """
            def psum(lo, m):
                if m < 8:
                    acc = vec[..., lo]
                    for i in range(lo + 1, lo + m):
                        acc = pin(acc + vec[..., i])
                    return acc
                if m <= 128:
                    r = [vec[..., lo + j] for j in range(8)]
                    i = 8
                    while i < m - (m % 8):
                        for j in range(8):
                            r[j] = pin(r[j] + vec[..., lo + i + j])
                        i += 8
                    res = pin(pin(pin(r[0] + r[1]) + pin(r[2] + r[3]))
                              + pin(pin(r[4] + r[5]) + pin(r[6] + r[7])))
                    for k in range(lo + i, lo + m):
                        res = pin(res + vec[..., k])
                    return res
                m2 = (m // 2) - ((m // 2) % 8)
                return pin(psum(lo, m2) + psum(lo + m2, m - m2))

            return psum(0, vec.shape[-1])[..., None]

        def allocate_bw(delay):
            """``allocate_bandwidth_jax`` with numpy's rounding pinned.

            Every float op result is pinned and the delay reduction runs
            in :func:`numpy_order_sum`'s order so Algorithm 1's splits
            match the host golden bit for bit inside the scan.
            """
            remaining = pin(total_bw - pin(min_bw * n))
            total_delay = numpy_order_sum(delay)
            share = pin(jnp.where(
                total_delay > 0,
                delay / jnp.where(total_delay > 0, total_delay, 1.0),
                1.0 / n))
            return pin(min_bw + pin(share * remaining))

        def reconfigure(args):
            units, bw, atd, bw_acc = args
            if cache_dynamic:
                units = lookahead_traced(
                    atd[None], min_ways[None], total_units,
                    backend=backend)[0].astype(units.dtype)
            atd = pin(atd * atd_decay)
            if bandwidth_dynamic:
                bw = allocate_bw(bw_acc)
            return units, bw, atd, bw_acc

        def step(carry, row):
            units, bw, pf, atd, bw_acc, off_ipc = carry
            kind, dt, rec = row
            units, bw, atd, bw_acc = jax.lax.cond(
                rec, reconfigure, lambda a: a, (units, bw, atd, bw_acc))
            is_off = kind == SAMPLE_OFF
            is_on = kind == SAMPLE_ON
            pf_used = jnp.where(is_off, False, jnp.where(is_on, True, pf))
            thr, wait, curves = model(
                dt, units.astype(jnp.float64), bw,
                pf_used.astype(jnp.float64))
            # Pin the model outputs too, in case the model skips its own
            # pinning — one canonical rounded tensor per observable.
            thr, wait, curves = pin(thr), pin(wait), pin(curves)
            # NOOP rows (stacking/trailing-boundary padding) are bitwise
            # no-ops: zero accumulation weight, no controller update.
            execs = kind != NOOP
            w = jnp.where(execs, dt, 0.0)
            atd = pin(atd + pin(curves * w))
            q_ns = pin(wait * 1e6)   # TrainingPlant.run_interval's scaling
            obs = pin(q_ns * w)
            decayed = pin(bw_decay * bw_acc)
            bw_acc = jnp.where(execs, pin(decayed + obs), bw_acc)
            off_ipc = jnp.where(is_off, thr, off_ipc)
            if prefetch_dynamic:
                pf = jnp.where(is_on,
                               throttle_decision_jax(thr, off_ipc, threshold),
                               pf)
            carry = (units, bw, pf, atd, bw_acc, off_ipc)
            return carry, (units, bw, pf_used, thr, q_ns)

        atd0 = jnp.zeros((n, total_units + 1), dtype=jnp.float64)
        acc0 = jnp.zeros((n,), dtype=jnp.float64)
        off0 = jnp.zeros((n,), dtype=jnp.float64)
        _carry, ys = jax.lax.scan(
            step, (units0, bw0, pf0, atd0, acc0, off0),
            (kinds, durs, reconf))
        return ys

    return jax.jit(run)


def run_fused_schedule(
    model: Callable,
    *,
    n_clients: int,
    total_units: int,
    total_bandwidth: float,
    total_ms: float,
    params: Optional[CBPParams] = None,
    cache_mode: Mode = Mode.DYNAMIC,
    bandwidth_mode: Mode = Mode.DYNAMIC,
    prefetch_mode: PrefetchMode = PrefetchMode.DYNAMIC,
    allocator_backend: Optional[str] = None,
) -> PlantScheduleResult:
    """Run a full Fig. 8 knob schedule as ONE jitted scan program.

    Feasibility checks (bandwidth floor, ``min_ways`` capacity, schedule
    well-formedness via ``CBPParams``) are hoisted out of the traced
    region, exactly like the simulator's fused path.
    """
    from repro.core.cache_controller_jax import _x64_context

    import jax.numpy as jnp

    params = params or CBPParams()
    n = n_clients
    check_bandwidth_floor(params.min_bandwidth_allocation, n, total_bandwidth)
    if params.min_ways * n > total_units:
        raise ValueError("min_ways * n_clients exceeds total_units")

    schedule = fig8_schedule(total_ms, params,
                             prefetch_mode == PrefetchMode.DYNAMIC)
    kinds, durs, reconf = segment_table(schedule)

    # Step 0 (Fig. 8): equal partitions, remainder to the lowest indices —
    # identical to CBPCoordinator._initial_allocation.
    units0 = np.full(n, total_units // n, dtype=np.int64)
    units0[: total_units - int(units0.sum())] += 1
    bw0 = np.full(n, total_bandwidth / n, dtype=np.float64)
    pf0 = np.full(n, prefetch_mode == PrefetchMode.ON, dtype=bool)

    fn = _compiled_schedule(
        model, n, int(total_units),
        cache_mode == Mode.DYNAMIC,
        bandwidth_mode == Mode.DYNAMIC,
        prefetch_mode == PrefetchMode.DYNAMIC,
        allocator_backend)
    record_dispatch()
    with _x64_context():
        scalars = (jnp.asarray(params.min_ways, dtype=jnp.int64),
                   jnp.asarray(total_bandwidth, dtype=jnp.float64),
                   jnp.asarray(params.min_bandwidth_allocation,
                               dtype=jnp.float64),
                   jnp.asarray(params.atd_decay, dtype=jnp.float64),
                   jnp.asarray(params.bandwidth_delay_decay,
                               dtype=jnp.float64),
                   jnp.asarray(params.speedup_threshold, dtype=jnp.float64))
        units, bw, pf_used, thr, q_ns = fn(
            jnp.asarray(kinds), jnp.asarray(durs), jnp.asarray(reconf),
            jnp.asarray(units0), jnp.asarray(bw0), jnp.asarray(pf0),
            scalars, jnp.asarray(0, dtype=jnp.int64))
        units = np.asarray(units).astype(np.int64)
        bw = np.asarray(bw)
        pf_used = np.asarray(pf_used)
        thr = np.asarray(thr)
        q_ns = np.asarray(q_ns)

    live = kinds != NOOP
    return PlantScheduleResult(
        kinds=kinds[live],
        t_ms=_segment_starts(durs)[live],
        duration_ms=durs[live],
        cache_units=units[live],
        bandwidth=bw[live],
        prefetch_on=pf_used[live],
        ipc=thr[live],
        queuing_delay_ns=q_ns[live],
    )


class FusedTrainingPlant:
    """Device-resident sibling of ``TrainingPlant`` + ``CBPCoordinator``.

    Holds the traced step model and the capacity constants; each ``run``
    is one dispatch.  The host pair — ``CBPCoordinator(TrainingPlant(...,
    step_fn))`` with the numpy twin of the model — is the parity golden
    (see :func:`host_reference_run`).
    """

    def __init__(self, n_clients: int, total_buffer_units: int,
                 total_bandwidth_mbps: float, step_model: Callable,
                 allocator_backend: Optional[str] = None):
        self.n_clients = n_clients
        self.total_cache_units = total_buffer_units
        self.total_bandwidth = total_bandwidth_mbps
        self.allocator_backend = allocator_backend
        self._model = step_model

    def run(self, total_ms: float,
            params: Optional[CBPParams] = None,
            cache_mode: Mode = Mode.DYNAMIC,
            bandwidth_mode: Mode = Mode.DYNAMIC,
            prefetch_mode: PrefetchMode = PrefetchMode.DYNAMIC,
            ) -> PlantScheduleResult:
        return run_fused_schedule(
            self._model,
            n_clients=self.n_clients,
            total_units=self.total_cache_units,
            total_bandwidth=self.total_bandwidth,
            total_ms=total_ms,
            params=params,
            cache_mode=cache_mode,
            bandwidth_mode=bandwidth_mode,
            prefetch_mode=prefetch_mode,
            allocator_backend=self.allocator_backend)


def host_reference_run(
    step_fn: Callable,
    *,
    n_clients: int,
    total_units: int,
    total_bandwidth: float,
    total_ms: float,
    params: Optional[CBPParams] = None,
    cache_mode: Mode = Mode.DYNAMIC,
    bandwidth_mode: Mode = Mode.DYNAMIC,
    prefetch_mode: PrefetchMode = PrefetchMode.DYNAMIC,
) -> PlantScheduleResult:
    """The golden path: host ``CBPCoordinator`` over a host ``TrainingPlant``.

    Returns the knob trajectory in the same shape as
    :func:`run_fused_schedule` so parity tests and the runtime smoke can
    compare the two bit for bit.
    """
    from repro.core.coordinator import CBPCoordinator
    from repro.runtime.cbp_runtime import TrainingPlant

    params = params or CBPParams()
    plant = TrainingPlant(n_clients, total_units, total_bandwidth, step_fn)
    coord = CBPCoordinator(plant, params, cache_mode=cache_mode,
                           bandwidth_mode=bandwidth_mode,
                           prefetch_mode=prefetch_mode)
    history = coord.run(total_ms)
    schedule = fig8_schedule(total_ms, params,
                             prefetch_mode == PrefetchMode.DYNAMIC)
    kinds, _durs, _rec = segment_table(schedule)
    kinds = kinds[kinds != NOOP]
    return trajectory_from_history(history, kinds)


def trajectory_from_history(history: List[IntervalRecord],
                            kinds: Optional[Sequence[int]] = None,
                            ) -> PlantScheduleResult:
    """Convert a host coordinator ``history`` into a trajectory struct."""
    S = len(history)
    kinds = (np.asarray(kinds, dtype=np.int32) if kinds is not None
             else np.full(S, RUN, dtype=np.int32))
    return PlantScheduleResult(
        kinds=kinds,
        t_ms=np.array([r.t_ms for r in history], dtype=np.float64),
        duration_ms=np.array([r.duration_ms for r in history],
                             dtype=np.float64),
        cache_units=np.stack(
            [np.asarray(r.alloc.cache_units, dtype=np.int64)
             for r in history]),
        bandwidth=np.stack(
            [np.asarray(r.alloc.bandwidth, dtype=np.float64)
             for r in history]),
        prefetch_on=np.stack(
            [np.asarray(r.alloc.prefetch_on, dtype=bool) for r in history]),
        ipc=np.stack([np.asarray(r.stats.ipc, dtype=np.float64)
                      for r in history]),
        queuing_delay_ns=np.stack(
            [np.asarray(r.stats.queuing_delay_ns, dtype=np.float64)
             for r in history]),
    )
