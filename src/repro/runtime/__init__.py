from repro.runtime.cbp_runtime import (
    TrainingPlant,
    plan_kernel_blocks,
    plan_matmul_blocks,
    plan_matmul_blocks_batched,
)
from repro.runtime.fault import ElasticMesh, StragglerWatchdog, factorize_mesh
from repro.runtime.faultinject import (
    FAULT_KINDS,
    FaultPlan,
    FaultSpec,
    InjectedDispatchError,
    InjectedFault,
    InjectedProcessKill,
    poison_tree,
)
from repro.runtime.plant_jax import (
    FusedTrainingPlant,
    PlantScheduleResult,
    host_reference_run,
    run_fused_schedule,
)

__all__ = [
    "TrainingPlant", "plan_kernel_blocks", "plan_matmul_blocks",
    "plan_matmul_blocks_batched",
    "FusedTrainingPlant", "PlantScheduleResult", "host_reference_run",
    "run_fused_schedule",
    "ElasticMesh", "StragglerWatchdog", "factorize_mesh",
    "FAULT_KINDS", "FaultPlan", "FaultSpec", "InjectedDispatchError",
    "InjectedFault", "InjectedProcessKill", "poison_tree",
]
