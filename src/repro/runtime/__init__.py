from repro.runtime.cbp_runtime import TrainingPlant, plan_matmul_blocks
from repro.runtime.fault import ElasticMesh, StragglerWatchdog, factorize_mesh

__all__ = [
    "TrainingPlant", "plan_matmul_blocks", "ElasticMesh",
    "StragglerWatchdog", "factorize_mesh",
]
