from repro.runtime.cbp_runtime import TrainingPlant, plan_matmul_blocks
from repro.runtime.fault import ElasticMesh, StragglerWatchdog, factorize_mesh
from repro.runtime.faultinject import (
    FAULT_KINDS,
    FaultPlan,
    FaultSpec,
    InjectedDispatchError,
    InjectedFault,
    InjectedProcessKill,
    poison_tree,
)

__all__ = [
    "TrainingPlant", "plan_matmul_blocks", "ElasticMesh",
    "StragglerWatchdog", "factorize_mesh",
    "FAULT_KINDS", "FaultPlan", "FaultSpec", "InjectedDispatchError",
    "InjectedFault", "InjectedProcessKill", "poison_tree",
]
