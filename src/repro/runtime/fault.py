"""Fault tolerance: straggler mitigation + elastic re-meshing.

On real multi-pod deployments these hook the cluster control plane; the
logic itself is hardware-independent and fully tested on CPU:

* :class:`StragglerWatchdog` — EWMA step-time monitor; steps slower than
  ``threshold x`` the moving average are flagged; after ``quarantine_after``
  consecutive flags the policy asks for mitigation (re-mesh without the
  slow pod / reroute).  This is CBP thinking applied to time: the watchdog
  is a queuing-delay monitor over steps.
* :class:`ElasticMesh` — given a (changed) healthy-device count, recompute
  the best (dp, model) mesh factorization subject to the model's
  divisibility constraints, preferring to keep the model axis, so training
  resumes from the latest checkpoint after losing nodes.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import List, Optional, Tuple


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    ewma: float


class StragglerWatchdog:
    """EWMA step-time monitor with a median-seeded warm-up window.

    The baseline is seeded from the *median* of the first ``warmup``
    observations, never from the first observation alone: step 0 is
    routinely 10-100x slower than steady state (jit compilation, cold
    caches), and seeding the EWMA with it would inflate the baseline so
    far that genuine stragglers later never cross ``threshold x ewma``.
    ``warmup=1`` reproduces the old seed-from-first-step behaviour.
    """

    def __init__(self, threshold: float = 2.0, alpha: float = 0.1,
                 quarantine_after: int = 3, warmup: int = 3):
        if warmup < 1:
            raise ValueError(f"warmup must be >= 1, got {warmup}")
        self.threshold = threshold
        self.alpha = alpha
        self.quarantine_after = quarantine_after
        self.warmup = warmup
        self.ewma: Optional[float] = None
        self.events: List[StragglerEvent] = []
        self._consecutive = 0
        self._warmup_times: List[float] = []
        self.mitigations = 0

    def observe(self, step: int, step_time: float) -> bool:
        """Returns True when mitigation should trigger."""
        if self.ewma is None:
            # Warm-up window: no baseline yet, nothing can be flagged.
            self._warmup_times.append(step_time)
            if len(self._warmup_times) >= self.warmup:
                self.ewma = statistics.median(self._warmup_times)
                self._warmup_times.clear()
            return False
        flagged = step_time > self.threshold * self.ewma
        if flagged:
            self.events.append(StragglerEvent(step, step_time, self.ewma))
            self._consecutive += 1
        else:
            self._consecutive = 0
            # only healthy steps update the baseline
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * step_time
        if self._consecutive >= self.quarantine_after:
            self._consecutive = 0
            self.mitigations += 1
            return True
        return False


def factorize_mesh(n_devices: int, *, model_divisors: Tuple[int, ...],
                   prefer_model: int) -> Optional[Tuple[int, int]]:
    """Best (dp, model) for ``n_devices``: the largest feasible model-axis
    size <= prefer_model that divides n_devices and satisfies the model's
    divisibility constraints (d_ff, heads, experts)."""
    for m in sorted({d for d in model_divisors if d <= prefer_model},
                    reverse=True):
        if m > 0 and n_devices % m == 0:
            return n_devices // m, m
    return None


class ElasticMesh:
    """Recompute the mesh when the healthy-device count changes."""

    def __init__(self, model_divisors: Tuple[int, ...] = (1, 2, 4, 8, 16),
                 prefer_model: int = 16):
        self.model_divisors = model_divisors
        self.prefer_model = prefer_model
        self.history: List[Tuple[int, Tuple[int, int]]] = []

    def remesh(self, n_devices: int) -> Tuple[int, int]:
        shape = factorize_mesh(
            n_devices, model_divisors=self.model_divisors,
            prefer_model=self.prefer_model)
        if shape is None:
            raise ValueError(
                f"no feasible mesh for {n_devices} devices "
                f"(model divisors {self.model_divisors})")
        self.history.append((n_devices, shape))
        return shape
