"""Deterministic seeded fault injection for the streaming sweep service.

The streaming pipeline (:mod:`repro.sim.stream_sweep`) must survive chunk
dispatch failures, NaN/Inf result poisoning, process death and stragglers.
None of those occur on a healthy CI host, so the pipeline threads a
:class:`FaultPlan` through every layer and the tests/smokes inject each
fault class on purpose:

* ``dispatch_error`` — the chunk's device dispatch raises
  :class:`InjectedDispatchError`; ``count`` consecutive attempts fail, so
  ``count <= max_retries`` exercises retry-with-backoff and
  ``count > max_retries`` exercises quarantine + graceful degradation.
* ``nan_poison`` — the chunk's device-resident results are overwritten
  with NaN *before* the in-trace finite guard runs, so the poisoned chunk
  flows through the same divergence detection a genuinely diverged solve
  would hit.
* ``kill`` — :class:`InjectedProcessKill` (a ``BaseException``, like a
  real ``SIGKILL`` it must not be swallowed by ``except Exception``
  recovery paths) fires at the start of the chunk, simulating process
  death between checkpoints.  Resume harnesses re-run the same plan via
  :meth:`FaultPlan.without_kills` — the crash already happened.
* ``straggle`` — inflates the observed chunk wall time by ``seconds``
  (artificial, no real sleep) so the
  :class:`repro.runtime.fault.StragglerWatchdog` path is testable in
  milliseconds.

Plans are plain data keyed by chunk index: the same plan applied to the
same stream is bit-reproducible, which is what lets the resume-parity CI
gate compare a killed-and-resumed run against an uninterrupted one.
:meth:`FaultPlan.seeded` derives a pseudo-random plan from a seed for
soak-style testing; it is deterministic in (seed, n_chunks, rates).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

FAULT_KINDS = ("dispatch_error", "nan_poison", "kill", "straggle")


class InjectedFault(RuntimeError):
    """Base class for recoverable injected faults."""


class InjectedDispatchError(InjectedFault):
    """An injected chunk-dispatch failure (retryable)."""


class InjectedProcessKill(BaseException):
    """Simulated process death.

    Deliberately a ``BaseException``: the pipeline's recovery machinery
    catches ``Exception`` and a kill must tear straight through it, the
    way a real ``SIGKILL`` would.  Test harnesses catch it explicitly.
    """


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injected fault at one chunk.

    ``count`` only applies to ``dispatch_error`` (consecutive failing
    attempts); ``seconds`` only to ``straggle`` (artificial wall
    inflation).
    """

    kind: str
    chunk: int
    count: int = 1
    seconds: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; valid: {FAULT_KINDS}")
        if self.chunk < 0:
            raise ValueError(f"fault chunk must be >= 0, got {self.chunk}")
        if self.kind == "dispatch_error" and self.count < 1:
            raise ValueError("dispatch_error needs count >= 1")


@dataclasses.dataclass
class FaultPlan:
    """A deterministic schedule of injected faults, keyed by chunk index.

    The pipeline calls the ``on_*`` hooks at the matching points; a plan
    with no spec for a chunk is a no-op there, so ``FaultPlan()`` is the
    healthy-run identity.
    """

    specs: Tuple[FaultSpec, ...] = ()

    def __post_init__(self):
        self.specs = tuple(self.specs)
        self._by_chunk: Dict[str, Dict[int, FaultSpec]] = {
            kind: {} for kind in FAULT_KINDS}
        for spec in self.specs:
            prev = self._by_chunk[spec.kind].setdefault(spec.chunk, spec)
            if prev is not spec:
                raise ValueError(
                    f"duplicate {spec.kind} fault at chunk {spec.chunk}")

    # ------------------------------------------------------------ hooks #

    def on_chunk_start(self, chunk: int) -> None:
        """Raise :class:`InjectedProcessKill` if this chunk is a kill."""
        if chunk in self._by_chunk["kill"]:
            raise InjectedProcessKill(f"injected kill at chunk {chunk}")

    def on_dispatch(self, chunk: int, attempt: int) -> None:
        """Fail dispatch ``attempt`` (0-based) if the plan says so."""
        spec = self._by_chunk["dispatch_error"].get(chunk)
        if spec is not None and attempt < spec.count:
            raise InjectedDispatchError(
                f"injected dispatch failure at chunk {chunk} "
                f"(attempt {attempt + 1}/{spec.count})")

    def poisons(self, chunk: int) -> bool:
        """True when this chunk's results must be NaN-poisoned."""
        return chunk in self._by_chunk["nan_poison"]

    def straggle_seconds(self, chunk: int) -> float:
        """Artificial wall-time inflation for this chunk (0.0 = none)."""
        spec = self._by_chunk["straggle"].get(chunk)
        return float(spec.seconds) if spec is not None else 0.0

    # ---------------------------------------------------------- helpers #

    def without_kills(self) -> "FaultPlan":
        """The same plan minus process kills — what a resumed run uses:
        the death already happened, the surviving faults are still live."""
        return FaultPlan(tuple(s for s in self.specs if s.kind != "kill"))

    def kill_chunks(self) -> List[int]:
        return sorted(self._by_chunk["kill"])

    @classmethod
    def single(cls, kind: str, chunk: int, *, count: int = 1,
               seconds: float = 0.0) -> "FaultPlan":
        return cls((FaultSpec(kind, chunk, count=count, seconds=seconds),))

    @classmethod
    def seeded(cls, seed: int, n_chunks: int, *,
               p_dispatch_error: float = 0.0,
               p_nan_poison: float = 0.0,
               p_straggle: float = 0.0,
               straggle_seconds: float = 1.0,
               max_dispatch_failures: int = 2) -> "FaultPlan":
        """Derive a pseudo-random plan — deterministic in its arguments.

        Kills are never drawn randomly: a kill needs a matching resume
        harness, so it is always placed explicitly.
        """
        rng = np.random.default_rng([int(seed), 0x5EED])
        specs: List[FaultSpec] = []
        draws = rng.random((n_chunks, 3))
        counts = rng.integers(1, max_dispatch_failures + 1, size=n_chunks)
        for c in range(n_chunks):
            if draws[c, 0] < p_dispatch_error:
                specs.append(FaultSpec("dispatch_error", c,
                                       count=int(counts[c])))
            if draws[c, 1] < p_nan_poison:
                specs.append(FaultSpec("nan_poison", c))
            if draws[c, 2] < p_straggle:
                specs.append(FaultSpec("straggle", c,
                                       seconds=straggle_seconds))
        return cls(tuple(specs))

    @classmethod
    def from_dicts(cls, dicts: Iterable[Dict]) -> "FaultPlan":
        """Build a plan from JSON-ish dicts (the CLI's --fault-plan)."""
        return cls(tuple(FaultSpec(**d) for d in dicts))

    def to_dicts(self) -> List[Dict]:
        return [dataclasses.asdict(s) for s in self.specs]


def poison_tree(tree, value: float = float("nan")):
    """Overwrite every array leaf of ``tree`` with ``value``.

    Works on device arrays (returns device arrays, so the in-trace finite
    guard still sees the poison) and on host numpy alike.
    """
    import jax

    return jax.tree.map(
        lambda a: (np.full_like(np.asarray(a), value)
                   if isinstance(a, np.ndarray)
                   else jax.numpy.full_like(a, value)), tree)


__all__ = [
    "FAULT_KINDS", "FaultPlan", "FaultSpec", "InjectedDispatchError",
    "InjectedFault", "InjectedProcessKill", "poison_tree",
]
