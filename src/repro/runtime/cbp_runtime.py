"""CBP runtime binding: the paper's coordinator driving TPU-substrate knobs.

:class:`TrainingPlant` adapts a (train loop + input pipeline + checkpoint
writer) into the :class:`repro.core.coordinator.Plant` protocol so the
UNMODIFIED CBPCoordinator manages it:

  clients            = competing memory-system streams
                       {0: input pipeline, 1: checkpoint writer,
                        2..: compute streams}
  cache units        = host staging-buffer pages (pipeline depth x batch)
  bandwidth          = host<->device/DCN bandwidth shares (MB/s)
  prefetch           = pipeline prefetch depth on/off

:func:`plan_matmul_blocks` is the kernel-level binding: it runs the UCP
Lookahead allocator over *tile-utility curves* (arithmetic-intensity gain
as a function of VMEM bytes given to each operand tile) to choose
(block_m, block_n, block_k) for ``repro.kernels.cbp_matmul`` under a VMEM
budget — cache partitioning at the VMEM level.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.cache_controller import CacheController
from repro.core.types import Allocation, IntervalStats

VMEM_BYTES = 128 * 1024 * 1024   # v5e VMEM per core (order of magnitude)


# ------------------------------------------------------------------ #
# Kernel-level binding: VMEM partitioning for cbp_matmul
# ------------------------------------------------------------------ #


def _tile_utility_curves(m: int, n: int, k: int, dtype_bytes: int,
                         unit_bytes: int, total_units: int) -> np.ndarray:
    """Utility of giving VMEM units to (A-tile, B-tile, ACC) for a
    (m x k) @ (k x n) matmul: utility = HBM traffic avoided.

    Bigger block_m (A rows resident) divides B-panel re-reads; bigger
    block_n divides A re-reads; bigger block_k amortizes accumulator
    spills.  Concave in each — exactly the miss-curve shape UCP expects.
    """
    units = np.arange(total_units + 1, dtype=np.float64)
    vm = units * unit_bytes
    # A-tile: block_m ~ vm / (2*block_k*dtype); traffic_B ~ n*k*(m/block_m)
    bm = np.maximum(vm / (2 * 128 * dtype_bytes), 8)
    util_a = n * k * dtype_bytes * (m / 8.0 - m / bm)
    bn = np.maximum(vm / (2 * 128 * dtype_bytes), 8)
    util_b = m * k * dtype_bytes * (n / 8.0 - n / bn)
    bk = np.maximum(vm / ((128 + 128) * dtype_bytes), 8)
    util_acc = m * n * 4.0 * (k / 8.0 - k / bk)
    return np.stack([util_a, util_b, util_acc])


_PLAN_UNIT = 8192                                 # 8 KiB VMEM "ways"
_PLAN_MIN_UNITS = 2


def _round_up(x: int, q: int) -> int:
    return -(-x // q) * q


def _snap_block(raw: float, dim: int, *, align: int = 8,
                mxu: Optional[int] = 128) -> int:
    """Snap a budget-derived tile size to the largest feasible aligned block.

    Pad-aware: a block is *feasible* when it either divides ``dim`` exactly
    (zero padding) or is a multiple of ``align`` tiling the padded extent
    ``ceil(dim / block) * block`` (the caller — or Mosaic's trailing-tile
    masking — pads the operand).  Among feasible candidates the one with the
    smallest padded extent wins, the larger block on ties, so exact aligned
    divisors always beat padding and prime/odd dims (no aligned divisor)
    keep a full-width aligned block instead of collapsing to 1-wide tiles.
    """
    if dim <= align:
        return dim                    # whole extent: one sublane-padded tile
    ext = _round_up(dim, align)
    p = 2 ** int(np.floor(np.log2(max(raw, 1))))
    b = int(min(max(p, align), ext))
    # hardware alignment: MXU wants multiples of 128 when possible
    if mxu is not None and ext >= mxu and b >= mxu // 2:
        b = max(b, mxu)
    if dim % b == 0:
        return b
    cands = [b] + [d for d in range(align, b + 1, align) if dim % d == 0]
    return min(cands, key=lambda c: (_round_up(dim, c), -c))


def _plan_from_alloc(m: int, n: int, k: int, alloc: np.ndarray,
                     dtype_bytes: int) -> Tuple[int, int, int]:
    """Shared alloc -> (block_m, block_n, block_k) snap, so the scalar and
    batched planners cannot disagree given identical allocations."""
    block_m = _snap_block(alloc[0] * _PLAN_UNIT / (2 * 128 * dtype_bytes), m)
    block_n = _snap_block(alloc[1] * _PLAN_UNIT / (2 * 128 * dtype_bytes), n)
    block_k = _snap_block(alloc[2] * _PLAN_UNIT / (256 * dtype_bytes), k,
                          mxu=None)
    return max(block_m, 1), max(block_n, 1), max(block_k, 1)


def plan_matmul_blocks(m: int, n: int, k: int, *, dtype_bytes: int = 2,
                       vmem_budget: int = VMEM_BYTES // 8,
                       allocator_backend: str = "numpy",
                       ) -> Tuple[int, int, int]:
    """UCP-allocate the VMEM budget among A/B/ACC tiles -> block sizes.

    ``allocator_backend="jax"`` runs the Lookahead greedy on device; both
    backends return identical blocks (bit-parity contract).  To plan many
    shapes in one device call use :func:`plan_matmul_blocks_batched`.

    Blocks are pad-aware (see :func:`_snap_block`): for dims with no
    aligned divisor the returned block tiles ``ceil(dim / block) * block``
    and the caller pads the operand to that extent.
    """
    total_units = max(vmem_budget // _PLAN_UNIT, 6)
    curves = _tile_utility_curves(m, n, k, dtype_bytes, _PLAN_UNIT,
                                  total_units)
    alloc = CacheController(
        total_units, min_units=_PLAN_MIN_UNITS,
        backend=allocator_backend).allocate(curves)
    return _plan_from_alloc(m, n, k, alloc, dtype_bytes)


def plan_matmul_blocks_batched(
    shapes: List[Tuple[int, int, int]], *,
    dtype_bytes=2,
    vmem_budget=VMEM_BYTES // 8,
    allocator_backend: str = "jax",
) -> List[Tuple[int, int, int]]:
    """Plan many ``(m, n, k)`` shapes in ONE device call.

    ``dtype_bytes`` / ``vmem_budget`` may be scalars or per-shape
    sequences.  Shapes are grouped by capacity (``vmem_budget`` fixes the
    utility-curve width) and the whole multi-group Lookahead runs as one
    jitted program (:func:`repro.core.cache_controller_jax.
    lookahead_allocate_grouped`), so planning a fleet of kernels costs one
    dispatch instead of one per shape.  Per shape, the returned blocks are
    identical to :func:`plan_matmul_blocks` (bit-parity contract of the
    batched greedy; the snap logic is shared).

    ``allocator_backend="numpy"`` falls back to the scalar host planner per
    shape — the golden reference the parity tests pin the batch against.
    """
    B = len(shapes)
    if B == 0:
        return []
    dbs = [int(d) for d in (np.broadcast_to(dtype_bytes, (B,)))]
    budgets = [int(v) for v in (np.broadcast_to(vmem_budget, (B,)))]
    if allocator_backend == "numpy":
        return [plan_matmul_blocks(m, n, k, dtype_bytes=db, vmem_budget=vb,
                                   allocator_backend="numpy")
                for (m, n, k), db, vb in zip(shapes, dbs, budgets)]

    from repro.core.cache_controller_jax import lookahead_allocate_grouped

    total_units = [max(vb // _PLAN_UNIT, 6) for vb in budgets]
    groups: Dict[int, List[int]] = {}
    for i, units in enumerate(total_units):
        groups.setdefault(units, []).append(i)
    keys = sorted(groups)
    curve_groups = []
    for units in keys:
        curve_groups.append(np.stack([
            _tile_utility_curves(*shapes[i], dbs[i], _PLAN_UNIT, units)
            for i in groups[units]]))
    allocs = lookahead_allocate_grouped(
        curve_groups, keys, min_units=_PLAN_MIN_UNITS,
        backend=allocator_backend)
    out: List[Optional[Tuple[int, int, int]]] = [None] * B
    for units, alloc in zip(keys, allocs):
        for j, i in enumerate(groups[units]):
            out[i] = _plan_from_alloc(*shapes[i], alloc[j], dbs[i])
    return out  # type: ignore[return-value]


# Per-kernel mapping of shape dims onto the (m, n, k) tile-utility query
# and of the planned (block_m, block_n, block_k) back onto the kernel's
# block knobs.  flash_decode queries with an 8-row Q tile (one padded
# sublane of queries streams the whole KV); ssd_scan's chunk is both sides
# of the (chunk x chunk) intra-chunk decay matmul.
_KERNEL_PLAN_QUERIES: Dict[str, Callable] = {
    "cbp_matmul": lambda d: (d["m"], d["n"], d["k"]),
    "flash_attention": lambda d: (d["seq_q"], d["seq_kv"], d["head_dim"]),
    "flash_decode": lambda d: (8, d["seq_kv"], d["head_dim"]),
    "ssd_scan": lambda d: (d["seq_len"], d["seq_len"], d["state_dim"]),
}
_KERNEL_PLAN_KNOBS: Dict[str, Callable] = {
    "cbp_matmul": lambda bm, bn, bk: {
        "block_m": bm, "block_n": bn, "block_k": bk},
    "flash_attention": lambda bm, bn, bk: {"block_q": bm, "block_kv": bn},
    "flash_decode": lambda bm, bn, bk: {"block_kv": bn},
    "ssd_scan": lambda bm, bn, bk: {"chunk": min(bm, bn)},
}


def plan_kernel_blocks(specs: List[Dict], *,
                       allocator_backend: str = "jax") -> List[Dict]:
    """Auto-plan block knobs for a fleet of Pallas kernels in one dispatch.

    Each spec is ``{"kernel": <name>, "dtype_bytes": ..,
    "vmem_budget": .., <dims>}`` where ``<dims>`` are the kernel's shape
    fields (see ``_KERNEL_PLAN_QUERIES``): ``cbp_matmul`` takes
    ``m/n/k``, ``flash_attention`` ``seq_q/seq_kv/head_dim``,
    ``flash_decode`` ``seq_kv/head_dim``, ``ssd_scan``
    ``seq_len/state_dim``.  Returns one knob dict per spec, planned by a
    single :func:`plan_matmul_blocks_batched` call.
    """
    shapes, dbs, budgets = [], [], []
    for spec in specs:
        kern = spec["kernel"]
        if kern not in _KERNEL_PLAN_QUERIES:
            raise ValueError(f"unknown kernel {kern!r}; have "
                             f"{sorted(_KERNEL_PLAN_QUERIES)}")
        shapes.append(_KERNEL_PLAN_QUERIES[kern](spec))
        dbs.append(int(spec.get("dtype_bytes", 2)))
        budgets.append(int(spec.get("vmem_budget", VMEM_BYTES // 8)))
    blocks = plan_matmul_blocks_batched(
        shapes, dtype_bytes=dbs, vmem_budget=budgets,
        allocator_backend=allocator_backend)
    return [_KERNEL_PLAN_KNOBS[spec["kernel"]](*blk)
            for spec, blk in zip(specs, blocks)]


# ------------------------------------------------------------------ #
# Training-loop binding
# ------------------------------------------------------------------ #


@dataclasses.dataclass
class StreamKnobs:
    """What the plant applies to each client before an interval."""

    buffer_units: np.ndarray      # cache partition (staging pages)
    bandwidth_mbps: np.ndarray    # host-side bandwidth shares
    prefetch_on: np.ndarray


class TrainingPlant:
    """Adapts (pipeline, checkpointer, step_fn) to the CBP Plant protocol.

    ``step_fn(interval_ms, knobs)`` must run the training loop for the
    interval under the given knobs and return per-client
    (throughput, queue_wait_ms, buffer_utility_curves).
    """

    def __init__(self, n_clients: int, total_buffer_units: int,
                 total_bandwidth_mbps: float,
                 step_fn: Callable[[float, StreamKnobs],
                                   Tuple[np.ndarray, np.ndarray,
                                         np.ndarray]],
                 allocator_backend: str = "numpy"):
        self.n_clients = n_clients
        self.total_cache_units = total_buffer_units
        self.total_bandwidth = total_bandwidth_mbps
        self.allocator_backend = allocator_backend
        self._step_fn = step_fn

    def run_interval(self, alloc: Allocation,
                     duration_ms: float) -> IntervalStats:
        knobs = StreamKnobs(
            buffer_units=alloc.cache_units,
            bandwidth_mbps=alloc.bandwidth,
            prefetch_on=alloc.prefetch_on,
        )
        throughput, wait_ms, curves = self._step_fn(duration_ms, knobs)
        return IntervalStats(
            ipc=np.asarray(throughput, dtype=np.float64),
            queuing_delay_ns=np.asarray(wait_ms, dtype=np.float64) * 1e6,
            utility_curves=np.asarray(curves, dtype=np.float64),
        )
