"""CBP runtime binding: the paper's coordinator driving TPU-substrate knobs.

:class:`TrainingPlant` adapts a (train loop + input pipeline + checkpoint
writer) into the :class:`repro.core.coordinator.Plant` protocol so the
UNMODIFIED CBPCoordinator manages it:

  clients            = competing memory-system streams
                       {0: input pipeline, 1: checkpoint writer,
                        2..: compute streams}
  cache units        = host staging-buffer pages (pipeline depth x batch)
  bandwidth          = host<->device/DCN bandwidth shares (MB/s)
  prefetch           = pipeline prefetch depth on/off

:func:`plan_matmul_blocks` is the kernel-level binding: it runs the UCP
Lookahead allocator over *tile-utility curves* (arithmetic-intensity gain
as a function of VMEM bytes given to each operand tile) to choose
(block_m, block_n, block_k) for ``repro.kernels.cbp_matmul`` under a VMEM
budget — cache partitioning at the VMEM level.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.cache_controller import CacheController
from repro.core.types import Allocation, IntervalStats

VMEM_BYTES = 128 * 1024 * 1024   # v5e VMEM per core (order of magnitude)


# ------------------------------------------------------------------ #
# Kernel-level binding: VMEM partitioning for cbp_matmul
# ------------------------------------------------------------------ #


def _tile_utility_curves(m: int, n: int, k: int, dtype_bytes: int,
                         unit_bytes: int, total_units: int) -> np.ndarray:
    """Utility of giving VMEM units to (A-tile, B-tile, ACC) for a
    (m x k) @ (k x n) matmul: utility = HBM traffic avoided.

    Bigger block_m (A rows resident) divides B-panel re-reads; bigger
    block_n divides A re-reads; bigger block_k amortizes accumulator
    spills.  Concave in each — exactly the miss-curve shape UCP expects.
    """
    units = np.arange(total_units + 1, dtype=np.float64)
    vm = units * unit_bytes
    # A-tile: block_m ~ vm / (2*block_k*dtype); traffic_B ~ n*k*(m/block_m)
    bm = np.maximum(vm / (2 * 128 * dtype_bytes), 8)
    util_a = n * k * dtype_bytes * (m / 8.0 - m / bm)
    bn = np.maximum(vm / (2 * 128 * dtype_bytes), 8)
    util_b = m * k * dtype_bytes * (n / 8.0 - n / bn)
    bk = np.maximum(vm / ((128 + 128) * dtype_bytes), 8)
    util_acc = m * n * 4.0 * (k / 8.0 - k / bk)
    return np.stack([util_a, util_b, util_acc])


def plan_matmul_blocks(m: int, n: int, k: int, *, dtype_bytes: int = 2,
                       vmem_budget: int = VMEM_BYTES // 8,
                       allocator_backend: str = "numpy",
                       ) -> Tuple[int, int, int]:
    """UCP-allocate the VMEM budget among A/B/ACC tiles -> block sizes.

    ``allocator_backend="jax"`` runs the Lookahead greedy on device
    (useful when planning many matmul shapes in one batch is added later);
    both backends return identical blocks (bit-parity contract).
    """
    unit = 8192                                   # 8 KiB VMEM "ways"
    total_units = max(vmem_budget // unit, 6)
    curves = _tile_utility_curves(m, n, k, dtype_bytes, unit, total_units)
    alloc = CacheController(
        total_units, min_units=2,
        backend=allocator_backend).allocate(curves)

    def _pow2_clamp(x, lo, hi):
        p = 2 ** int(np.floor(np.log2(max(x, 1))))
        return int(min(max(p, lo), hi))

    block_m = _pow2_clamp(alloc[0] * unit / (2 * 128 * dtype_bytes), 8, m)
    block_n = _pow2_clamp(alloc[1] * unit / (2 * 128 * dtype_bytes), 8, n)
    block_k = _pow2_clamp(alloc[2] * unit / (256 * dtype_bytes), 8, k)
    # hardware alignment: MXU wants multiples of 128 when possible
    if m >= 128:
        block_m = max(block_m, 128) if block_m >= 64 else block_m
    if n >= 128:
        block_n = max(block_n, 128) if block_n >= 64 else block_n
    while m % block_m:
        block_m //= 2
    while n % block_n:
        block_n //= 2
    while k % block_k:
        block_k //= 2
    return max(block_m, 1), max(block_n, 1), max(block_k, 1)


# ------------------------------------------------------------------ #
# Training-loop binding
# ------------------------------------------------------------------ #


@dataclasses.dataclass
class StreamKnobs:
    """What the plant applies to each client before an interval."""

    buffer_units: np.ndarray      # cache partition (staging pages)
    bandwidth_mbps: np.ndarray    # host-side bandwidth shares
    prefetch_on: np.ndarray


class TrainingPlant:
    """Adapts (pipeline, checkpointer, step_fn) to the CBP Plant protocol.

    ``step_fn(interval_ms, knobs)`` must run the training loop for the
    interval under the given knobs and return per-client
    (throughput, queue_wait_ms, buffer_utility_curves).
    """

    def __init__(self, n_clients: int, total_buffer_units: int,
                 total_bandwidth_mbps: float,
                 step_fn: Callable[[float, StreamKnobs],
                                   Tuple[np.ndarray, np.ndarray,
                                         np.ndarray]],
                 allocator_backend: str = "numpy"):
        self.n_clients = n_clients
        self.total_cache_units = total_buffer_units
        self.total_bandwidth = total_bandwidth_mbps
        self.allocator_backend = allocator_backend
        self._step_fn = step_fn

    def run_interval(self, alloc: Allocation,
                     duration_ms: float) -> IntervalStats:
        knobs = StreamKnobs(
            buffer_units=alloc.cache_units,
            bandwidth_mbps=alloc.bandwidth,
            prefetch_on=alloc.prefetch_on,
        )
        throughput, wait_ms, curves = self._step_fn(duration_ms, knobs)
        return IntervalStats(
            ipc=np.asarray(throughput, dtype=np.float64),
            queuing_delay_ns=np.asarray(wait_ms, dtype=np.float64) * 1e6,
            utility_curves=np.asarray(curves, dtype=np.float64),
        )
