"""Zamba2-style hybrid: Mamba2 backbone + a *shared* attention block
(arXiv:2411.15242) applied every ``attn_every`` layers.

One set of attention+MLP weights is reused at every application site (the
Zamba2 parameter-sharing trick); per-site LoRA deltas are omitted
(documented simplification, DESIGN.md §Arch-applicability).  The layer scan
carries the shared block application as a ``lax.cond`` keyed on a static
per-layer flag so the whole stack remains a single while loop.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T
from repro.models.config import ModelConfig


def init_params(key, cfg: ModelConfig) -> Dict:
    d, v = cfg.d_model, cfg.padded_vocab
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    shared = {
        "attn": jax.tree.map(lambda x: x[0],
                             T.init_attn(ks[0], cfg, 1)),
        "mlp": jax.tree.map(lambda x: x[0], T.init_mlp(ks[1], cfg, 1)),
        "ln1": jnp.ones((d,), dt),
        "ln2": jnp.ones((d,), dt),
    }
    return {
        "embed": L.embed_init(ks[2], (v, d), dt),
        "layers": S.init_mamba(ks[3], cfg, cfg.n_layers),
        "shared": shared,
        "final_norm": jnp.ones((d,), dt),
        "head": L.dense_init(ks[4], (d, v), dt, in_axis=0),
    }


def _shared_block(shared, cfg: ModelConfig, x, positions):
    h = L.rms_norm(x, shared["ln1"], cfg.norm_eps)
    x = x + T.attention_block(shared["attn"], cfg, h, positions)
    h = L.rms_norm(x, shared["ln2"], cfg.norm_eps)
    x = x + L.swiglu(h, shared["mlp"]["wg"], shared["mlp"]["wu"],
                     shared["mlp"]["wd"])
    return x


def forward(params, cfg: ModelConfig, x, positions) -> jnp.ndarray:
    flags = (jnp.arange(cfg.n_layers) % max(cfg.attn_every, 1)) == 0
    shared = params["shared"]

    def body(x, inputs):
        lp, flag = inputs
        x = jax.lax.cond(
            flag,
            lambda x: _shared_block(shared, cfg, x, positions),
            lambda x: x,
            x)
        x = S.mamba_block(lp, cfg, x)
        seq = "model" if cfg.seq_shard_activations else None
        return constrain(x, "dp", seq, None), None

    body = T._maybe_remat(body, cfg)
    x, _ = jax.lax.scan(body, x, (params["layers"], flags))
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def loss_fn(params, cfg: ModelConfig, batch) -> jnp.ndarray:
    x = T.embed(params, cfg, batch["tokens"])
    positions = jnp.arange(x.shape[1])
    hidden = forward(params, cfg, x, positions)
    logits = T.logits_fn(params, cfg, hidden)
    return L.softmax_xent(logits, batch["labels"], cfg.vocab_size)


def n_attn_sites(cfg: ModelConfig) -> int:
    return (cfg.n_layers + cfg.attn_every - 1) // max(cfg.attn_every, 1)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict:
    sites = n_attn_sites(cfg)
    dh = cfg.head_dim
    cache = S.init_ssm_cache(cfg, batch, cfg.n_layers)
    cache["k"] = jnp.zeros(
        (sites, batch, max_len, cfg.n_kv_heads, dh), dtype)
    cache["v"] = jnp.zeros(
        (sites, batch, max_len, cfg.n_kv_heads, dh), dtype)
    return cache


def decode_step(params, cfg: ModelConfig, cache, tokens, cur_len):
    """One-token step: scan over attention sites (shared block + its
    following mamba sub-stack)."""
    x = T.embed(params, cfg, tokens)
    shared = params["shared"]
    sites = n_attn_sites(cfg)
    k = cfg.attn_every
    # Pad the mamba stack so it reshapes to (sites, k, ...) cleanly.
    pad = sites * k - cfg.n_layers

    def pad_stack(a):
        if pad == 0:
            return a
        cfgpad = jnp.zeros((pad,) + a.shape[1:], a.dtype)
        return jnp.concatenate([a, cfgpad], axis=0)

    mamba = jax.tree.map(
        lambda a: pad_stack(a).reshape((sites, k) + a.shape[1:]),
        params["layers"])
    conv = pad_stack(cache["conv"]).reshape(
        (sites, k) + cache["conv"].shape[1:])
    state = pad_stack(cache["state"]).reshape(
        (sites, k) + cache["state"].shape[1:])
    live = (jnp.arange(sites * k) < cfg.n_layers).reshape(sites, k)

    def site_body(x, inputs):
        sp, conv_s, state_s, ck, cv, live_s = inputs
        h = L.rms_norm(x, shared["ln1"], cfg.norm_eps)
        att, nk, nv = T.attention_decode(
            shared["attn"], cfg, h, ck, cv, cur_len)
        x = x + att
        h = L.rms_norm(x, shared["ln2"], cfg.norm_eps)
        x = x + L.swiglu(h, shared["mlp"]["wg"], shared["mlp"]["wu"],
                         shared["mlp"]["wd"])

        def mamba_body(x, inner):
            lp, cs, ss, alive = inner
            nx, nc, ns = S.mamba_decode(lp, cfg, x, cs, ss)
            nx = jnp.where(alive, nx, x)
            return nx, (nc, ns)

        x, (nc, ns) = jax.lax.scan(
            mamba_body, x, (sp, conv_s, state_s, live_s))
        return x, (nc, ns, nk, nv)

    x, (nc, ns, nk, nv) = jax.lax.scan(
        site_body, x, (mamba, conv, state, cache["k"], cache["v"], live))
    hidden = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = T.logits_fn(params, cfg, hidden)
    new_cache = {
        "conv": nc.reshape((-1,) + nc.shape[2:])[: cfg.n_layers],
        "state": ns.reshape((-1,) + ns.shape[2:])[: cfg.n_layers],
        "k": nk,
        "v": nv,
    }
    return logits, new_cache
