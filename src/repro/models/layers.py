"""Shared model building blocks (pure JAX, functional style).

Parameters are plain pytrees (nested dicts of ``jnp.ndarray``); every init
function takes an ``nk`` (named key) and returns the subtree.  Compute dtype
is bf16 with f32 for normalization/softmax statistics (TPU-native policy);
parameter dtype is configurable (bf16 for the dry-run, f32 for tiny CPU
smoke training).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm with f32 statistics (Llama/Qwen convention)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


def swiglu(x: jnp.ndarray, wg: jnp.ndarray, wu: jnp.ndarray,
           wd: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU MLP: down( silu(x @ wg) * (x @ wu) )."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, wg))
    u = jnp.einsum("...d,df->...f", x, wu)
    return jnp.einsum("...f,fd->...d", g * u, wd)


def gelu_mlp(x: jnp.ndarray, wi: jnp.ndarray, wo: jnp.ndarray) -> jnp.ndarray:
    """GELU MLP (whisper-style two-matrix FFN)."""
    return jnp.einsum("...f,fd->...d", jax.nn.gelu(
        jnp.einsum("...d,df->...f", x, wi)), wo)


def rope_frequencies(head_dim: int, max_pos: int, theta: float = 1e4
                     ) -> jnp.ndarray:
    """(max_pos, head_dim/2) complex rotation angles for RoPE."""
    inv = 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))
    t = np.arange(max_pos)
    freqs = np.outer(t, inv)  # (max_pos, head_dim/2)
    return jnp.asarray(freqs, dtype=jnp.float32)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 1e4) -> jnp.ndarray:
    """Rotary embedding.  x: (..., S, H, Dh); positions: broadcastable (S,)
    or (..., S)."""
    dh = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    # angles: (..., S, Dh/2)
    ang = positions.astype(jnp.float32)[..., None] * inv
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, Dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d_model: int) -> jnp.ndarray:
    """Whisper-style sinusoid table (seq, d_model)."""
    half = d_model // 2
    scale = np.log(10000.0) / max(half - 1, 1)
    inv = np.exp(-scale * np.arange(half))
    pos = np.arange(seq)[:, None] * inv[None, :]
    return jnp.asarray(
        np.concatenate([np.sin(pos), np.cos(pos)], axis=1), dtype=jnp.float32)


# ------------------------------------------------------------------ #
# Initializers
# ------------------------------------------------------------------ #


def dense_init(key, shape, dtype, in_axis: int = 0) -> jnp.ndarray:
    """Truncated-normal fan-in init (matches common LM training setups)."""
    fan_in = shape[in_axis] if isinstance(in_axis, int) else int(
        np.prod([shape[a] for a in in_axis]))
    std = 1.0 / np.sqrt(fan_in)
    return (std * jax.random.truncated_normal(
        key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


def embed_init(key, shape, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 vocab_size: int) -> jnp.ndarray:
    """Mean token cross-entropy; labels < 0 are masked.  Padded vocab
    entries (>= vocab_size) are excluded from the partition function by
    masking their logits."""
    v_pad = logits.shape[-1]
    if v_pad > vocab_size:
        mask = (jnp.arange(v_pad) < vocab_size)
        logits = jnp.where(mask, logits, -1e30)
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    valid = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)
