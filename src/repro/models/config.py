"""Model configuration shared by every architecture family."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 0           # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    qk_norm: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128

    # Hybrid (zamba2-style): shared attention block applied every k layers
    attn_every: int = 0

    # Enc-dec (whisper): n_layers == decoder layers
    n_enc_layers: int = 0

    # Modality frontend stub: "none" | "audio" | "patch"
    frontend: str = "none"

    # Numerics / distribution
    param_dtype: str = "bfloat16"
    remat: str = "full"            # none | full | dots
    attn_chunk: int = 1024
    seq_shard_activations: bool = True   # Megatron-SP-style residual shard
    mesh_model: int = 1            # model-axis size padding is computed for
    moe_groups: int = 1            # MoE dispatch groups (= DP size so the
                                   # token gather/scatter stays shard-local)
    pure_dp: bool = False          # tiny models: use the model axis as extra
                                   # DP instead of TP (whisper-tiny)
    decode_cache_update: str = "onehot"  # "dus" | "onehot" (§Perf C1/C3)
    decode_gqa: str = "grouped"        # "repeat" | "grouped" (§Perf C4)
    moe_gather_weights: bool = False   # TPxFSDP experts: gather weights
                                       # before the einsum (AG weights once
                                       # instead of AR partial activations)
    kv_cache_dtype: str = "bfloat16"   # "bfloat16" | "int8" (quantized KV)

    # ----- derived ----------------------------------------------------- #

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_heads(self) -> int:
        """Query heads padded up to a multiple of the model axis (yi-34b:
        56 -> 64) when head-sharding is used at all."""
        m = self.mesh_model
        if m <= 1 or self.n_heads % m == 0:
            return self.n_heads
        if self.n_heads >= m:
            return _ceil_to(self.n_heads, m)
        return self.n_heads  # tiny models: attention stays replicated

    @property
    def heads_shardable(self) -> bool:
        return self.mesh_model > 1 and self.padded_heads % self.mesh_model == 0

    @property
    def padded_experts(self) -> int:
        m = self.mesh_model
        if self.n_experts == 0 or m <= 1 or self.n_experts < m:
            return self.n_experts     # few-big-experts: TPxFSDP, no padding
        return _ceil_to(self.n_experts, m)

    @property
    def moe_ep(self) -> bool:
        """Experts shardable over the model axis (EP); otherwise the
        expert FFN weights shard d_ff over model (TP) and d over data
        (FSDP) — the grok-1 layout (8 huge experts on a 16-way axis)."""
        m = self.mesh_model
        return m <= 1 or (self.padded_experts % m == 0
                          and self.padded_experts >= m)

    @property
    def padded_vocab(self) -> int:
        return _ceil_to(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def n_rep(self) -> int:
        return self.padded_heads // self.n_kv_heads

    def with_mesh(self, mesh_model: int, dp: int = 1) -> "ModelConfig":
        return dataclasses.replace(
            self, mesh_model=mesh_model,
            moe_groups=dp if self.n_experts else 1)

    def param_count(self) -> int:
        """Exact parameter count (excluding padding), for MODEL_FLOPS."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        h, kv, dh = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * h * dh + 2 * d * kv * dh + h * dh * d
        if self.qk_norm:
            attn += 2 * dh
        mlp = 3 * d * f
        norms = 2 * d
        total = 0
        if self.family in ("dense", "vlm"):
            total = L * (attn + mlp + norms)
        elif self.family == "moe":
            moe = 3 * d * f * self.n_experts + d * self.n_experts
            total = L * (attn + moe + norms)
        elif self.family == "ssm":
            total = L * self._mamba_block_params()
        elif self.family == "hybrid":
            total = L * self._mamba_block_params() + (attn + mlp + norms)
        elif self.family == "encdec":
            enc = self.n_enc_layers * (attn + mlp + norms)
            dec = L * (2 * attn + mlp + 3 * d)
            total = enc + dec
        total += v * d            # embedding
        if not self.tie_embeddings:
            total += d * v        # head
        total += d                # final norm
        return total

    def _mamba_block_params(self) -> int:
        d, di = self.d_model, self.d_inner
        n, hh = self.ssm_state, self.ssm_heads
        # in projections (z, x, B, C, dt) + conv + A/D + gated norm + out
        return (d * (2 * di + 2 * n + hh) + di * self.ssm_conv
                + 2 * hh + di + di * d + d)

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        dense_part = self.param_count() - L * 3 * d * f * self.n_experts
        return dense_part + L * 3 * d * f * self.top_k
