"""Model facade: family registry + uniform init/loss/decode interface.

``Model`` wraps a family module with a uniform API consumed by the
training step builder, the serving engine and the dry-run:

  init(rng)                 -> params pytree
  loss(params, batch)       -> scalar
  init_cache(batch, maxlen) -> decode cache pytree
  decode_step(params, cache, tokens, cur_len) -> (logits, cache)
  input_specs(shape)        -> {name: ShapeDtypeStruct} for a named shape
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, ssm, transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        fam = cfg.family
        if fam in ("dense", "moe", "vlm"):
            self._mod = transformer
        elif fam == "ssm":
            self._mod = ssm
        elif fam == "hybrid":
            self._mod = hybrid
        elif fam == "encdec":
            self._mod = encdec
        else:
            raise ValueError(f"unknown family {fam}")

    # ---------------- core API ---------------- #

    def init(self, rng) -> Dict:
        return self._mod.init_params(rng, self.cfg)

    def loss(self, params, batch) -> jnp.ndarray:
        return self._mod.loss_fn(params, self.cfg, batch)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        if self.cfg.family == "ssm":
            return ssm.init_ssm_cache(self.cfg, batch, self.cfg.n_layers)
        return self._mod.init_cache(self.cfg, batch, max_len, dtype)

    def decode_step(self, params, cache, tokens, cur_len):
        return self._mod.decode_step(params, self.cfg, cache, tokens,
                                     cur_len)

    def prefill(self, params, batch):
        """Inference prefill: full-sequence forward, LAST-position logits
        (the head is never evaluated on earlier positions, as in a real
        serving engine — XLA DCEs the rest)."""
        cfg = self.cfg
        if cfg.family == "encdec":
            enc = encdec.encode(params, cfg,
                                batch["frames"].astype(
                                    jnp.dtype(cfg.param_dtype)))
            hidden = encdec.decode_train(params, cfg, batch["tokens"], enc)
            return transformer.logits_fn(params, cfg, hidden[:, -1:, :])
        if "embeddings" in batch:
            x = batch["embeddings"].astype(jnp.dtype(cfg.param_dtype))
        else:
            x = transformer.embed(params, cfg, batch["tokens"])
        positions = jnp.arange(x.shape[1])
        if cfg.family == "hybrid":
            hidden = hybrid.forward(params, cfg, x, positions)
        elif cfg.family == "ssm":
            from repro.distributed import constrain
            seq = "model" if cfg.seq_shard_activations else None
            x = constrain(x, "dp", seq, None)

            def body(x, lp):
                return ssm.mamba_block(lp, cfg, x), None
            body = transformer._maybe_remat(body, cfg)
            hidden, _ = jax.lax.scan(body, x, params["layers"])
            from repro.models import layers as L
            hidden = L.rms_norm(hidden, params["final_norm"], cfg.norm_eps)
        else:
            hidden = transformer.forward(params, cfg, x, positions)
        return transformer.logits_fn(params, cfg, hidden[:, -1:, :])

    # ---------------- dry-run input specs ---------------- #

    def input_specs(self, shape: str) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of a cell."""
        spec = SHAPES[shape] if isinstance(shape, str) else shape
        cfg = self.cfg
        b, s = spec.global_batch, spec.seq_len
        i32 = jnp.int32
        bf16 = jnp.bfloat16
        if spec.kind in ("train", "prefill"):
            if cfg.family == "encdec":
                return {
                    "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), bf16),
                    "tokens": jax.ShapeDtypeStruct((b, s), i32),
                    "labels": jax.ShapeDtypeStruct((b, s), i32),
                }
            if cfg.frontend in ("audio", "patch"):
                return {
                    "embeddings": jax.ShapeDtypeStruct(
                        (b, s, cfg.d_model), bf16),
                    "labels": jax.ShapeDtypeStruct((b, s), i32),
                }
            return {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        # decode: one new token against a cache of length seq_len
        if cfg.frontend in ("audio", "patch") and cfg.family != "encdec":
            tok = jax.ShapeDtypeStruct((b, 1, cfg.d_model), bf16)
        else:
            tok = jax.ShapeDtypeStruct((b, 1), i32)
        return {"tokens": tok,
                "cur_len": jax.ShapeDtypeStruct((), i32)}

    def supports_shape(self, shape: str) -> bool:
        """long_500k requires sub-quadratic sequence mixing (spec policy:
        run for SSM/hybrid, skip for pure full-attention archs)."""
        if shape != "long_500k":
            if shape in ("decode_32k", "long_500k"):
                return self.cfg.family != "none"
            return True
        return self.cfg.family in ("ssm", "hybrid")


def build(cfg: ModelConfig) -> Model:
    return Model(cfg)
