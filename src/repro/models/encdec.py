"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The audio frontend (mel conv stem) is a STUB per the assignment:
``input_specs`` feeds precomputed frame embeddings (B, S_enc, d_model).
Encoder: non-causal self-attention + GELU MLP with sinusoidal positions.
Decoder: causal self-attention + cross-attention + GELU MLP.
(RMSNorm replaces LayerNorm and biases are omitted — documented
simplification; the backbone dimensions match whisper-tiny exactly.)
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.attention import causal_attention, decode_attention, repeat_kv
from repro.models.config import ModelConfig


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def _init_xattn(key, cfg: ModelConfig, n_layers: int) -> Dict:
    d, dh = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.padded_heads, cfg.n_kv_heads
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(ks[0], (n_layers, d, hq * dh), dt, in_axis=1),
        "wk": L.dense_init(ks[1], (n_layers, d, hkv * dh), dt, in_axis=1),
        "wv": L.dense_init(ks[2], (n_layers, d, hkv * dh), dt, in_axis=1),
        "wo": L.dense_init(ks[3], (n_layers, hq * dh, d), dt, in_axis=1),
    }


def _init_gelu_mlp(key, cfg: ModelConfig, n_layers: int) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    return {
        "wi": L.dense_init(k1, (n_layers, d, f), dt, in_axis=1),
        "wo": L.dense_init(k2, (n_layers, f, d), dt, in_axis=1),
    }


def init_params(key, cfg: ModelConfig) -> Dict:
    d, v = cfg.d_model, cfg.padded_vocab
    dt = _dtype(cfg)
    ks = jax.random.split(key, 10)
    enc = {
        "attn": _init_xattn(ks[0], cfg, cfg.n_enc_layers),
        "mlp": _init_gelu_mlp(ks[1], cfg, cfg.n_enc_layers),
        "ln1": jnp.ones((cfg.n_enc_layers, d), dt),
        "ln2": jnp.ones((cfg.n_enc_layers, d), dt),
    }
    dec = {
        "attn": _init_xattn(ks[2], cfg, cfg.n_layers),
        "xattn": _init_xattn(ks[3], cfg, cfg.n_layers),
        "mlp": _init_gelu_mlp(ks[4], cfg, cfg.n_layers),
        "ln1": jnp.ones((cfg.n_layers, d), dt),
        "lnx": jnp.ones((cfg.n_layers, d), dt),
        "ln2": jnp.ones((cfg.n_layers, d), dt),
    }
    return {
        "encoder": enc,
        "decoder": dec,
        "embed": L.embed_init(ks[5], (v, d), dt),
        "enc_norm": jnp.ones((d,), dt),
        "final_norm": jnp.ones((d,), dt),
        "head": L.dense_init(ks[6], (d, v), dt, in_axis=0),
    }


def _mha(p, cfg, xq, xkv, causal):
    b, sq, d = xq.shape
    dh = cfg.head_dim
    q = jnp.einsum("bsd,dk->bsk", xq, p["wq"]).reshape(
        b, sq, cfg.padded_heads, dh)
    k = jnp.einsum("bsd,dk->bsk", xkv, p["wk"]).reshape(
        b, xkv.shape[1], cfg.n_kv_heads, dh)
    v = jnp.einsum("bsd,dk->bsk", xkv, p["wv"]).reshape(
        b, xkv.shape[1], cfg.n_kv_heads, dh)
    k = repeat_kv(k, cfg.n_rep)
    v = repeat_kv(v, cfg.n_rep)
    o = causal_attention(q, k, v, chunk=cfg.attn_chunk, causal=causal)
    return jnp.einsum("bsk,kd->bsd", o.reshape(b, sq, -1), p["wo"])


def encode(params, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, S_enc, d) stub embeddings -> encoder hidden."""
    pos = L.sinusoidal_positions(frames.shape[1], cfg.d_model)
    x = frames + pos[None].astype(frames.dtype)
    x = constrain(x, "dp", None, None)

    def body(x, lp):
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        x = x + _mha(lp["attn"], cfg, h, h, causal=False)
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.gelu_mlp(h, lp["mlp"]["wi"], lp["mlp"]["wo"])
        return constrain(x, "dp", None, None), None

    body = T._maybe_remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def decode_train(params, cfg: ModelConfig, tokens, enc_hidden) -> jnp.ndarray:
    x = T.embed(params, cfg, tokens)
    pos = L.sinusoidal_positions(x.shape[1], cfg.d_model)
    x = x + pos[None].astype(x.dtype)

    def body(x, lp):
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        x = x + _mha(lp["attn"], cfg, h, h, causal=True)
        h = L.rms_norm(x, lp["lnx"], cfg.norm_eps)
        x = x + _mha(lp["xattn"], cfg, h, enc_hidden, causal=False)
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.gelu_mlp(h, lp["mlp"]["wi"], lp["mlp"]["wo"])
        return constrain(x, "dp", None, None), None

    body = T._maybe_remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params["decoder"])
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def loss_fn(params, cfg: ModelConfig, batch) -> jnp.ndarray:
    enc_hidden = encode(params, cfg, batch["frames"].astype(_dtype(cfg)))
    hidden = decode_train(params, cfg, batch["tokens"], enc_hidden)
    logits = T.logits_fn(params, cfg, hidden)
    return L.softmax_xent(logits, batch["labels"], cfg.vocab_size)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict:
    dh = cfg.head_dim
    n, hkv = cfg.n_layers, cfg.n_kv_heads
    return {
        "k": jnp.zeros((n, batch, max_len, hkv, dh), dtype),
        "v": jnp.zeros((n, batch, max_len, hkv, dh), dtype),
        # Cross-attention K/V are computed once from the encoder output.
        "xk": jnp.zeros((n, batch, max_len, hkv, dh), dtype),
        "xv": jnp.zeros((n, batch, max_len, hkv, dh), dtype),
        "enc_len": jnp.zeros((), jnp.int32),
    }


def decode_step(params, cfg: ModelConfig, cache, tokens, cur_len):
    x = T.embed(params, cfg, tokens)
    pos = L.sinusoidal_positions(1, cfg.d_model)  # position enc simplified
    x = x + pos[None].astype(x.dtype)
    enc_len = cache["enc_len"]

    def body(x, inputs):
        lp, ck, cv, xk, xv = inputs
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        att, nk, nv = T.attention_decode(lp["attn"], cfg, h, ck, cv, cur_len)
        x = x + att
        h = L.rms_norm(x, lp["lnx"], cfg.norm_eps)
        b = x.shape[0]
        dh = cfg.head_dim
        q = jnp.einsum("bsd,dk->bsk", h, lp["xattn"]["wq"]).reshape(
            b, 1, cfg.padded_heads, dh)
        o = decode_attention(q, repeat_kv(xk, cfg.n_rep),
                             repeat_kv(xv, cfg.n_rep), enc_len)
        x = x + jnp.einsum("bsk,kd->bsd", o.reshape(b, 1, -1),
                           lp["xattn"]["wo"])
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.gelu_mlp(h, lp["mlp"]["wi"], lp["mlp"]["wo"])
        return x, (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["decoder"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    hidden = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = T.logits_fn(params, cfg, hidden)
    new_cache = dict(cache)
    new_cache["k"] = nk
    new_cache["v"] = nv
    return logits, new_cache
