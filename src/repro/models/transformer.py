"""Decoder-only transformer (dense, MoE and VLM-backbone families).

Layers are stacked (leading ``L`` dim on every parameter) and executed with
``lax.scan`` so the lowered HLO stays compact — a 512-device SPMD compile of
a 60-layer model is one while loop, not 60 inlined layers (MaxText-style).
Rematerialization wraps the scanned body.

MoE uses gather-based dispatch (sort -> position-in-expert -> capacity
gather), batched expert matmul, and scatter-add combine.  Tokens are
replicated across the "model" axis (they are data-sharded only), experts
are sharded over "model": the gather is comm-free and the combine lowers to
one partial-sum all-reduce of the activation — the same per-layer collective
cost as a Megatron TP FFN, with FLOPs proportional to *active* experts only
(capacity_factor overhead aside).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.models import layers as L
from repro.models.attention import causal_attention, decode_attention, repeat_kv
from repro.models.config import ModelConfig


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ------------------------------------------------------------------ #
# Init
# ------------------------------------------------------------------ #


def init_attn(key, cfg: ModelConfig, n_layers: int) -> Dict:
    d, dh = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.padded_heads, cfg.n_kv_heads
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], (n_layers, d, hq * dh), dt, in_axis=1),
        "wk": L.dense_init(ks[1], (n_layers, d, hkv * dh), dt, in_axis=1),
        "wv": L.dense_init(ks[2], (n_layers, d, hkv * dh), dt, in_axis=1),
        "wo": L.dense_init(ks[3], (n_layers, hq * dh, d), dt, in_axis=1),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((n_layers, dh), dt)
        p["k_norm"] = jnp.ones((n_layers, dh), dt)
    return p


def init_mlp(key, cfg: ModelConfig, n_layers: int) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    return {
        "wg": L.dense_init(ks[0], (n_layers, d, f), dt, in_axis=1),
        "wu": L.dense_init(ks[1], (n_layers, d, f), dt, in_axis=1),
        "wd": L.dense_init(ks[2], (n_layers, f, d), dt, in_axis=1),
    }


def init_moe(key, cfg: ModelConfig, n_layers: int) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.padded_experts
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    return {
        "router": L.dense_init(ks[0], (n_layers, d, e), jnp.float32, in_axis=1),
        "wg": L.dense_init(ks[1], (n_layers, e, d, f), dt, in_axis=2),
        "wu": L.dense_init(ks[2], (n_layers, e, d, f), dt, in_axis=2),
        "wd": L.dense_init(ks[3], (n_layers, e, f, d), dt, in_axis=2),
    }


def init_params(key, cfg: ModelConfig) -> Dict:
    d, v = cfg.d_model, cfg.padded_vocab
    dt = _dtype(cfg)
    keys = jax.random.split(key, 6)
    layers = {
        "attn": init_attn(keys[0], cfg, cfg.n_layers),
        "ln1": jnp.ones((cfg.n_layers, d), dt),
        "ln2": jnp.ones((cfg.n_layers, d), dt),
    }
    if cfg.family == "moe":
        layers["moe"] = init_moe(keys[1], cfg, cfg.n_layers)
    else:
        layers["mlp"] = init_mlp(keys[1], cfg, cfg.n_layers)
    params = {
        "embed": L.embed_init(keys[2], (v, d), dt),
        "layers": layers,
        "final_norm": jnp.ones((d,), dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(keys[3], (d, v), dt, in_axis=0)
    return params


# ------------------------------------------------------------------ #
# Attention sublayer
# ------------------------------------------------------------------ #


def _project_qkv(p, cfg: ModelConfig, h):
    b, s, _ = h.shape
    dh = cfg.head_dim
    q = jnp.einsum("bsd,dk->bsk", h, p["wq"]).reshape(
        b, s, cfg.padded_heads, dh)
    k = jnp.einsum("bsd,dk->bsk", h, p["wk"]).reshape(
        b, s, cfg.n_kv_heads, dh)
    v = jnp.einsum("bsd,dk->bsk", h, p["wv"]).reshape(
        b, s, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def attention_block(p, cfg: ModelConfig, x, positions,
                    causal: bool = True) -> jnp.ndarray:
    """Full-sequence attention (train / prefill)."""
    b, s, d = x.shape
    q, k, v = _project_qkv(p, cfg, x)
    if cfg.rope_theta > 0:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    hq = "model" if cfg.heads_shardable else None
    q = constrain(q, "dp", None, hq, None)
    k = repeat_kv(k, cfg.n_rep)
    v = repeat_kv(v, cfg.n_rep)
    o = causal_attention(q, k, v, chunk=cfg.attn_chunk, causal=causal)
    o = constrain(o, "dp", None, hq, None)
    return jnp.einsum("bsk,kd->bsd", o.reshape(b, s, -1), p["wo"])


def attention_decode(p, cfg: ModelConfig, x, cache_k, cache_v, cur_len):
    """One-token attention against the cache; returns (out, new_k, new_v).

    cache_k/v: (B, Smax, Hkv, Dh), sequence-sharded over "model".
    ``cur_len`` is either a scalar () — every row writes/attends at the
    same position — or a per-row ``(B,)`` vector (continuous batching:
    each slot sits at its own position).  The vector form always takes
    the per-row scatter path: a per-row dynamic slice would unroll to B
    DUSes, while the scatter writes exactly B rows.
    """
    b = x.shape[0]
    q, k, v = _project_qkv(p, cfg, x)   # (B, 1, H*, Dh)
    if cfg.rope_theta > 0:
        pos = jnp.reshape(cur_len, (-1,))[:, None]  # (B|1, 1)
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)
    per_row = jnp.ndim(cur_len) >= 1
    if per_row:
        # Per-row scatter: touches B rows instead of masking the whole
        # (B, Smax) plane.  The vector form is only consumed by the
        # serving engines, whose caches are unsharded or BATCH-sharded
        # (slot axis) — on a sequence-sharded cache this scatter would
        # hit the same GSPMD all-gather as the DUS path below.
        write_at = jnp.asarray(cur_len, jnp.int32).reshape(-1)  # (B,)
        rows = jnp.arange(b)
        cache_k = cache_k.at[rows, write_at].set(
            _kv_store(cfg, k, cache_k)[:, 0])
        cache_v = cache_v.at[rows, write_at].set(
            _kv_store(cfg, v, cache_v)[:, 0])
    elif cfg.decode_cache_update == "onehot":
        write_at = jnp.asarray(cur_len, jnp.int32).reshape(())
        # Sharded-friendly ring-buffer write: a dynamic-index DUS on a
        # sequence-SHARDED dim makes GSPMD all-gather the whole cache;
        # the equivalent one-hot masked update is elementwise and stays
        # sharded (§Perf iteration C1).
        sel = (jnp.arange(cache_k.shape[1]) == write_at)[None, :, None,
                                                         None]
        cache_k = jnp.where(sel, _kv_store(cfg, k, cache_k), cache_k)
        cache_v = jnp.where(sel, _kv_store(cfg, v, cache_v), cache_v)
    else:
        write_at = jnp.asarray(cur_len, jnp.int32).reshape(())
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, _kv_store(cfg, k, cache_k), write_at, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, _kv_store(cfg, v, cache_v), write_at, axis=1)
    # Pin the ring-buffer layout (batch over DP when divisible, sequence
    # over model) so GSPMD never round-trips the cache through a reshard.
    if not cfg.pure_dp:
        from repro.distributed import get_dp_axes, get_mesh
        mesh = get_mesh()
        bax = None
        if mesh is not None:
            dp_n = 1
            for a in get_dp_axes():
                if a in mesh.axis_names:
                    dp_n *= mesh.shape[a]
            if cache_k.shape[0] % dp_n == 0 and cache_k.shape[0] >= dp_n:
                bax = "dp"
        cache_k = constrain(cache_k, bax, "model", None, None)
        cache_v = constrain(cache_v, bax, "model", None, None)
        # Split-KV decode: the cache stays sequence-sharded, so the tiny
        # (B, 1, H, Dh) query must be REPLICATED across "model" — letting
        # wq's head sharding propagate here makes GSPMD all-gather the
        # repeat_kv broadcast (2 GiB/layer for qwen3-8b; §Perf C2).
        q = constrain(q, bax, None, None, None)
    ckd = _kv_load(cfg, cache_k)
    cvd = _kv_load(cfg, cache_v)
    if cfg.decode_gqa == "grouped" and cfg.n_rep > 1:
        from repro.models.attention import decode_attention_gqa
        o = decode_attention_gqa(q, ckd, cvd, write_at + 1)
    else:
        ck = repeat_kv(ckd, cfg.n_rep)
        cv = repeat_kv(cvd, cfg.n_rep)
        o = decode_attention(q, ck, cv, write_at + 1)
    if not cfg.pure_dp:
        o = constrain(o, bax, None, None, None)
    out = jnp.einsum("bsk,kd->bsd", o.reshape(b, 1, -1), p["wo"])
    return out, cache_k, cache_v


# ------------------------------------------------------------------ #
# MoE FFN
# ------------------------------------------------------------------ #


def moe_ffn(p, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Gather-dispatch MoE (see module docstring).  x: (B, S, d).

    Tokens are processed in ``cfg.moe_groups`` groups (one per DP shard in
    production) so every gather/scatter is *batched over the group dim* —
    GSPMD keeps them shard-local instead of all-gathering tokens across DP.
    """
    b, s, d = x.shape
    e, k = cfg.padded_experts, cfg.top_k
    ng = cfg.moe_groups
    t = b * s
    assert t % ng == 0, (t, ng)
    tg = t // ng
    xg = constrain(x.reshape(ng, tg, d), "dp", None, None)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    if e > cfg.n_experts:  # padded experts are unroutable
        pad_mask = jnp.arange(e) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    gates, topi = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Position-in-expert via per-group sort (no (T, E) one-hots).
    flat_e = topi.reshape(ng, tg * k)
    order = jnp.argsort(flat_e, axis=-1)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    run_start = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(e), side="left"))(
            sorted_e)                               # (G, E)
    pos_sorted = (jnp.arange(tg * k)[None, :]
                  - jnp.take_along_axis(run_start, sorted_e, axis=-1))
    pos = jax.vmap(
        lambda o, ps: jnp.zeros_like(ps).at[o].set(ps))(order, pos_sorted)

    cap = int(max(1, round(cfg.capacity_factor * tg * k / e)))
    keep = pos < cap
    sentinel = tg * k
    slot_ids = jnp.broadcast_to(
        jnp.arange(tg * k, dtype=jnp.int32)[None, :], (ng, tg * k))

    def scatter_idx(fe, po, kp, sl):
        buf = jnp.full((e, cap), sentinel, dtype=jnp.int32)
        return buf.at[(fe, jnp.minimum(po, cap - 1))].set(
            jnp.where(kp, sl, sentinel), mode="drop")

    idx = jax.vmap(scatter_idx)(flat_e, pos, keep, slot_ids)  # (G, E, C)
    valid = idx < sentinel
    tok = jnp.minimum(idx, sentinel - 1) // k       # token id per slot

    expert_in = jax.vmap(lambda xx, tt: xx[tt.reshape(-1)])(
        xg, tok).reshape(ng, e, cap, d)
    expert_in = jnp.where(valid[..., None], expert_in, 0.0)
    espec = "model" if cfg.moe_ep else None
    expert_in = constrain(expert_in, "dp", espec, None, None)

    wg, wu, wd = p["wg"], p["wu"], p["wd"]
    if cfg.moe_gather_weights and not cfg.moe_ep:
        # FSDP experts: force the d-dim gather of the weights BEFORE the
        # einsum — one AG of weights per layer instead of partial-sum
        # all-reduces of the (much larger) activation intermediates
        # (§Perf iteration B4).
        wg = constrain(wg, espec, None, "model")
        wu = constrain(wu, espec, None, "model")
        wd = constrain(wd, espec, "model", None)
    gg = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, wg))
    uu = jnp.einsum("gecd,edf->gecf", expert_in, wu)
    y = jnp.einsum("gecf,efd->gecd", gg * uu, wd)  # (G, E, C, d)
    y = constrain(y, "dp", espec, None, None)

    # Combine: scatter-add weighted expert outputs back to token slots.
    w = jnp.where(
        valid, jnp.take_along_axis(
            gates.reshape(ng, tg * k),
            jnp.minimum(idx, sentinel - 1).reshape(ng, -1),
            axis=-1).reshape(ng, e, cap), 0.0)
    contrib = (y * w[..., None].astype(y.dtype)).reshape(ng, e * cap, d)
    target = jnp.where(valid, tok, tg).reshape(ng, e * cap)
    out = jax.vmap(
        lambda cc, tt: jnp.zeros((tg + 1, d), cc.dtype).at[tt].add(
            cc, mode="drop"))(contrib, target)
    out = constrain(out[:, :tg], "dp", None, None)
    return out.reshape(b, s, d)


# ------------------------------------------------------------------ #
# Layer + model forward
# ------------------------------------------------------------------ #


def _layer(p, cfg: ModelConfig, x, positions):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + attention_block(p["attn"], cfg, h, positions)
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        x = x + moe_ffn(p["moe"], cfg, h)
    else:
        x = x + L.swiglu(h, p["mlp"]["wg"], p["mlp"]["wu"], p["mlp"]["wd"])
    seq = "model" if cfg.seq_shard_activations else None
    x = constrain(x, "dp", seq, None)
    return x


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def forward(params, cfg: ModelConfig, x_embed, positions) -> jnp.ndarray:
    """Run the layer stack on embedded inputs; returns final hidden."""
    seq = "model" if cfg.seq_shard_activations else None
    x = constrain(x_embed, "dp", seq, None)

    body = _maybe_remat(
        lambda x, lp: (_layer(lp, cfg, x, positions), None), cfg)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def embed(params, cfg: ModelConfig, tokens) -> jnp.ndarray:
    return jnp.take(params["embed"], tokens, axis=0)


def logits_fn(params, cfg: ModelConfig, hidden) -> jnp.ndarray:
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    out = jnp.einsum("bsd,dv->bsv", hidden, head)
    return constrain(out, "dp", None, "model")


def loss_fn(params, cfg: ModelConfig, batch) -> jnp.ndarray:
    if "embeddings" in batch:   # vlm/stub frontends feed embeddings
        x = batch["embeddings"].astype(_dtype(cfg))
    else:
        x = embed(params, cfg, batch["tokens"])
    positions = jnp.arange(x.shape[1])
    hidden = forward(params, cfg, x, positions)
    logits = logits_fn(params, cfg, hidden)
    return L.softmax_xent(logits, batch["labels"], cfg.vocab_size)


# ------------------------------------------------------------------ #
# Decode (serving)
# ------------------------------------------------------------------ #


KV_INT8_SCALE = 0.05   # fixed quantization step for int8 KV caches


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict:
    dh = cfg.head_dim
    if cfg.kv_cache_dtype == "int8":
        dtype = jnp.int8
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, dh),
                       dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, dh),
                       dtype),
    }


def _kv_store(cfg: ModelConfig, x, like):
    """Quantize new K/V entries for an int8 cache."""
    if cfg.kv_cache_dtype == "int8":
        return jnp.clip(jnp.round(x.astype(jnp.float32) / KV_INT8_SCALE),
                        -127, 127).astype(jnp.int8)
    return x.astype(like.dtype)


def _kv_load(cfg: ModelConfig, cache):
    if cfg.kv_cache_dtype == "int8":
        return cache.astype(jnp.bfloat16) * KV_INT8_SCALE
    return cache


def decode_step(params, cfg: ModelConfig, cache, tokens, cur_len):
    """One greedy decode step.  tokens: (B, 1) int32 (or embeddings
    (B, 1, d) for stub frontends); cur_len: () current cache length.
    Returns (logits, new_cache)."""
    if tokens.ndim == 3:
        x = tokens.astype(_dtype(cfg))
    else:
        x = embed(params, cfg, tokens)

    def body(x, lp_and_cache):
        lp, ck, cv = lp_and_cache
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        att, nk, nv = attention_decode(lp["attn"], cfg, h, ck, cv, cur_len)
        x = x + att
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            x = x + moe_ffn(lp["moe"], cfg, h)
        else:
            x = x + L.swiglu(h, lp["mlp"]["wg"], lp["mlp"]["wu"],
                             lp["mlp"]["wd"])
        return x, (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]))
    hidden = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, cfg, hidden)
    return logits, {"k": nk, "v": nv}
