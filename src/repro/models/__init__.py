"""Architecture families (pure JAX): dense/MoE/VLM decoders, Mamba2 SSD,
Zamba2 hybrid, Whisper enc-dec — scan-over-layers, GSPMD-shardable."""
from repro.models.config import ModelConfig
from repro.models.model import SHAPES, Model, ShapeSpec, build

__all__ = ["ModelConfig", "Model", "ShapeSpec", "SHAPES", "build"]
