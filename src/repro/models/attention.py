"""GQA attention: training (chunked causal), prefill, and decode paths.

Sharding contract (see launch/shardings.py):
  * query heads are sharded over the "model" mesh axis when divisible
    (padded up when close — yi-34b pads 56->64); KV heads are replicated
    per model shard (GQA-natural tensor parallelism — the kv projection is
    tiny, so each shard computes all kv heads and attends with its q-head
    slice).
  * decode shards the KV-cache *sequence* over the "model" axis instead
    (split-KV flash decode at mesh scale — works for any head count); the
    softmax over the sharded axis lowers to a small all-reduce pair.

On real TPU hardware the chunked-causal path is replaced by the Pallas
flash-attention kernel in ``repro.kernels.flash_attention`` (true triangular
schedule — XLA's dense formulation below computes masked chunk pairs too);
the jnp path remains the oracle and the CPU/dry-run implementation.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, S, Hkv, Dh) -> (B, S, Hkv*n_rep, Dh)."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(
        k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(b, s, h * n_rep, d)


def causal_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     chunk: int = 1024, causal: bool = True,
                     remat_chunk: bool = True) -> jnp.ndarray:
    """Chunked attention.  q: (B, Sq, H, Dh); k/v: (B, Sk, H, Dh).

    Scores are computed q-chunk at a time so the live score buffer is
    (B, H, chunk, Sk) — flash-attention-shaped memory behaviour under XLA.
    ``remat_chunk`` rematerializes each chunk's scores/probs in the
    backward pass so the scan does not stack per-chunk residuals.
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    scale = dh ** -0.5
    chunk = min(chunk, sq)
    n_chunks = max(sq // chunk, 1)
    rem = sq - n_chunks * chunk

    kT = k.transpose(0, 2, 3, 1)  # (B, H, Dh, Sk)
    vT = v.transpose(0, 2, 1, 3)  # (B, H, Sk, Dh)

    def one_chunk(q_chunk: jnp.ndarray, start) -> jnp.ndarray:
        # q_chunk: (B, C, H, Dh)
        qT = q_chunk.transpose(0, 2, 1, 3)  # (B, H, C, Dh)
        scores = jnp.einsum(
            "bhcd,bhds->bhcs", qT, kT).astype(jnp.float32) * scale
        if causal:
            c = q_chunk.shape[1]
            qpos = start + jnp.arange(c)[:, None]
            kpos = jnp.arange(sk)[None, :]
            scores = jnp.where(kpos <= qpos, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhcs,bhsd->bhcd", probs, vT)
        return out.transpose(0, 2, 1, 3)  # (B, C, H, Dh)

    if remat_chunk:
        one_chunk = jax.checkpoint(
            one_chunk, policy=jax.checkpoint_policies.nothing_saveable)

    if n_chunks > 1:
        qs = q[:, : n_chunks * chunk].reshape(b, n_chunks, chunk, h, dh)
        starts = jnp.arange(n_chunks) * chunk

        def body(carry, xs):
            qc, st = xs
            return carry, one_chunk(qc, st)

        _, outs = jax.lax.scan(body, 0, (qs.transpose(1, 0, 2, 3, 4), starts))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * chunk, h, dh)
        if rem:
            out = jnp.concatenate(
                [out, one_chunk(q[:, n_chunks * chunk:], n_chunks * chunk)],
                axis=1)
        return out
    return one_chunk(q, 0)


def decode_attention_gqa(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, cur_len: jnp.ndarray
                         ) -> jnp.ndarray:
    """Grouped-query decode WITHOUT materializing repeated KV.

    q: (B, 1, Hq, Dh); caches: (B, Smax, Hkv, Dh), Hq = G * Hkv (kv-major
    head layout, matching ``repeat_kv``).  The grouped einsum lets XLA
    broadcast KV virtually — on a 32k cache with G=4 this removes 3/4 of
    the decode HBM traffic (§Perf iteration C4).
    """
    b, one, hq, dh = q.shape
    hkv = k_cache.shape[2]
    g = hq // hkv
    scale = dh ** -0.5
    qg = q.reshape(b, hkv, g, dh)
    scores = jnp.einsum(
        "bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32) * scale
    smax = k_cache.shape[1]
    mask = jnp.arange(smax)[None, :] < jnp.reshape(cur_len, (-1, 1))
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v_cache)
    return out.reshape(b, 1, hq, dh)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, cur_len: jnp.ndarray
                     ) -> jnp.ndarray:
    """Single-position attention against a (possibly seq-sharded) cache.

    q: (B, 1, H, Dh); k_cache/v_cache: (B, Smax, H, Dh); cur_len: () or (B,)
    number of valid cache positions.  The softmax over Smax lowers to an
    all-reduce pair when Smax is sharded over the model axis.
    """
    dh = q.shape[-1]
    scale = dh ** -0.5
    scores = jnp.einsum(
        "bqhd,bshd->bhqs", q, k_cache).astype(jnp.float32) * scale
    smax = k_cache.shape[1]
    pos = jnp.arange(smax)
    mask = pos[None, :] < jnp.reshape(cur_len, (-1, 1))  # (B, Smax)
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", probs, v_cache)
