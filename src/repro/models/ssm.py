"""Mamba2 (state-space duality / SSD) blocks — arXiv:2405.21060.

The SSD recurrence  h_t = exp(dt_t A) h_{t-1} + dt_t B_t (x) ,
y_t = C_t . h_t + D x_t  is evaluated with the chunked matmul-form
algorithm (intra-chunk attention-like block + inter-chunk state
recurrence), which is what makes it MXU-friendly on TPU.  ``lax.scan``
runs over chunks (sequential inter-chunk state) and the per-chunk math is
batched matmuls; on real TPU hardware the per-chunk body is the Pallas
kernel in ``repro.kernels.ssd_scan`` and this jnp path is its oracle.

Sharding: SSD heads are sharded over the "model" axis (64 heads for
mamba2-1.3b, 112 for zamba2-7b — both divisible by 16); B/C projections are
group-shared (n_groups=1) and replicated; the conv is depthwise over the
head-sharded channel dim, so the whole block is comm-free except the
in/out projections' boundary collectives.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.models import layers as L
from repro.models.config import ModelConfig


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def init_mamba(key, cfg: ModelConfig, n_layers: int) -> Dict:
    d, di = cfg.d_model, cfg.d_inner
    n, h, k = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
    dt = _dtype(cfg)
    ks = jax.random.split(key, 8)
    return {
        "wx": L.dense_init(ks[0], (n_layers, d, di), dt, in_axis=1),
        "wz": L.dense_init(ks[1], (n_layers, d, di), dt, in_axis=1),
        "wB": L.dense_init(ks[2], (n_layers, d, n), dt, in_axis=1),
        "wC": L.dense_init(ks[3], (n_layers, d, n), dt, in_axis=1),
        "wdt": L.dense_init(ks[4], (n_layers, d, h), dt, in_axis=1),
        "dt_bias": jnp.zeros((n_layers, h), dt),
        "A_log": jnp.zeros((n_layers, h), jnp.float32),
        "D": jnp.ones((n_layers, h), dt),
        "conv": (jax.random.normal(ks[5], (n_layers, di, k), jnp.float32)
                 * (1.0 / k)).astype(dt),
        "norm": jnp.ones((n_layers, di), dt),
        "out": L.dense_init(ks[6], (n_layers, di, d), dt, in_axis=1),
        "ln": jnp.ones((n_layers, d), dt),
    }


def causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv.  x: (B, S, C); w: (C, K)."""
    k = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # (B, S+K-1, C) -> windows via conv_general_dilated, depthwise.
    out = jax.lax.conv_general_dilated(
        xp.transpose(0, 2, 1)[:, :, None, :],            # (B, C, 1, S+K-1)
        w[:, None, None, :].astype(x.dtype),             # (C, 1, 1, K)
        window_strides=(1, 1), padding="VALID",
        feature_group_count=w.shape[0],
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return out[:, :, 0, :].transpose(0, 2, 1)            # (B, S, C)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int,
                initial_state=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan (pure-jnp oracle).

    x: (B, S, H, P); dt: (B, S, H); A: (H,) negative; Bm/Cm: (B, S, N).
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    cl = min(chunk, s)
    assert s % cl == 0, (s, cl)
    nc = s // cl

    xr = x.reshape(b, nc, cl, h, p).astype(jnp.float32)
    dtr = dt.reshape(b, nc, cl, h).astype(jnp.float32)
    Br = Bm.reshape(b, nc, cl, n).astype(jnp.float32)
    Cr = Cm.reshape(b, nc, cl, n).astype(jnp.float32)
    dA = dtr * A[None, None, None, :]               # (B,nc,cl,H) log-decay
    cs = jnp.cumsum(dA, axis=2)                     # inclusive cumsum

    xdt = xr * dtr[..., None]                       # dt-weighted inputs

    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)

    def chunk_body(state, inputs):
        xc, dAc, csc, Bc, Cc = inputs  # (B,cl,H,P) (B,cl,H) (B,cl,H) ...
        # Intra-chunk ("diag block"): M[i,j] = (C_i.B_j) exp(cs_i-cs_j), j<=i
        G = jnp.einsum("bin,bjn->bij", Cc, Bc)      # (B,cl,cl)
        decay = jnp.exp(csc[:, :, None, :] - csc[:, None, :, :])  # (B,i,j,H)
        causal = jnp.tril(jnp.ones((xc.shape[1], xc.shape[1])))
        M = G[:, :, :, None] * decay * causal[None, :, :, None]
        y_intra = jnp.einsum("bijh,bjhp->bihp", M, xc)
        # Contribution of the carried state: exp(cs_i) C_i . state
        sdec = jnp.exp(csc)                          # (B,cl,H)
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", Cc, state, sdec)
        # Next state: chunk-end decay of current + new outer products
        edec = jnp.exp(csc[:, -1:, :] - csc)         # decay j..end (B,cl,H)
        new_state = jnp.einsum("bjn,bjhp,bjh->bhpn", Bc, xc, edec)
        state = (jnp.exp(csc[:, -1, :])[:, :, None, None] * state
                 + new_state)
        return state, y_intra + y_inter

    inputs = (
        xdt.transpose(1, 0, 2, 3, 4),
        dA.transpose(1, 0, 2, 3),
        cs.transpose(1, 0, 2, 3),
        Br.transpose(1, 0, 2, 3),
        Cr.transpose(1, 0, 2, 3),
    )
    final_state, ys = jax.lax.scan(chunk_body, initial_state, inputs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y.astype(x.dtype), final_state


def mamba_block(p, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """One Mamba2 block (train/prefill).  x: (B, S, d)."""
    b, s, d = x.shape
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    xi = jnp.einsum("bsd,de->bse", h, p["wx"])       # (B,S,di)
    z = jnp.einsum("bsd,de->bse", h, p["wz"])
    Bm = jnp.einsum("bsd,dn->bsn", h, p["wB"])
    Cm = jnp.einsum("bsd,dn->bsn", h, p["wC"])
    dt_raw = jnp.einsum("bsd,dh->bsh", h, p["wdt"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    xi = causal_conv(xi, p["conv"])
    xi = jax.nn.silu(xi)
    xi = constrain(xi, "dp", None, "model")
    hh, pp = cfg.ssm_heads, cfg.ssm_head_dim
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(
        xi.reshape(b, s, hh, pp), dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + xi.reshape(b, s, hh, pp) * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(b, s, cfg.d_inner)
    y = L.rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out"])
    return x + out


def init_ssm_cache(cfg: ModelConfig, batch: int, n_layers: int,
                   dtype=jnp.float32) -> Dict:
    return {
        "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv - 1, cfg.d_inner),
                          dtype),
        "state": jnp.zeros((n_layers, batch, cfg.ssm_heads,
                            cfg.ssm_head_dim, cfg.ssm_state), dtype),
    }


def mamba_decode(p, cfg: ModelConfig, x, conv_state, ssm_state):
    """One-token Mamba2 step.  x: (B, 1, d).  Returns (out, new_conv,
    new_state)."""
    b = x.shape[0]
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)[:, 0]   # (B, d)
    xi = h @ p["wx"]
    z = h @ p["wz"]
    Bm = (h @ p["wB"]).astype(jnp.float32)           # (B, N)
    Cm = (h @ p["wC"]).astype(jnp.float32)
    dt = jax.nn.softplus((h @ p["wdt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B, H)
    # conv ring: conv_state (B, K-1, di) holds the previous inputs.
    window = jnp.concatenate(
        [conv_state, xi[:, None, :].astype(conv_state.dtype)], axis=1)
    conv_out = jnp.einsum("bkc,ck->bc", window, p["conv"].astype(jnp.float32))
    new_conv = window[:, 1:, :]
    xi = jax.nn.silu(conv_out)                       # (B, di)
    hh, pp = cfg.ssm_heads, cfg.ssm_head_dim
    xh = xi.reshape(b, hh, pp).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A[None, :])                 # (B, H)
    new_state = (decay[:, :, None, None] * ssm_state
                 + jnp.einsum("bhp,bn,bh->bhpn", xh, Bm, dt))
    y = jnp.einsum("bn,bhpn->bhp", Cm, new_state)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, cfg.d_inner).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y @ p["out"])[:, None, :]                 # (B, 1, d)
    return x + out, new_conv, new_state


def loss_fn(params, cfg: ModelConfig, batch) -> jnp.ndarray:
    from repro.models import transformer as T
    x = T.embed(params, cfg, batch["tokens"])
    seq = "model" if cfg.seq_shard_activations else None
    x = constrain(x, "dp", seq, None)

    def body(x, lp):
        return mamba_block(lp, cfg, x), None

    body = T._maybe_remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = T.logits_fn(params, cfg, x)
    return L.softmax_xent(logits, batch["labels"], cfg.vocab_size)


def init_params(key, cfg: ModelConfig) -> Dict:
    d, v = cfg.d_model, cfg.padded_vocab
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    return {
        "embed": L.embed_init(ks[0], (v, d), dt),
        "layers": init_mamba(ks[1], cfg, cfg.n_layers),
        "final_norm": jnp.ones((d,), dt),
        "head": L.dense_init(ks[2], (d, v), dt, in_axis=0),
    }


def decode_step(params, cfg: ModelConfig, cache, tokens, cur_len):
    from repro.models import transformer as T
    x = T.embed(params, cfg, tokens)

    def body(x, lp_cache):
        lp, cs, ss = lp_cache
        x, nc, ns = mamba_decode(lp, cfg, x, cs, ss)
        return x, (nc, ns)

    x, (nc, ns) = jax.lax.scan(
        body, x, (params["layers"], cache["conv"], cache["state"]))
    hidden = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = T.logits_fn(params, cfg, hidden)
    return logits, {"conv": nc, "state": ns}
