"""Deterministic synthetic training-plant model, host + traced twins.

The fused schedule runner (:mod:`repro.runtime.plant_jax`) needs a step
model expressible in ``jax.numpy``; the host parity golden
(``CBPCoordinator`` over ``TrainingPlant``) needs the same model as a
numpy ``step_fn``.  Writing the rates ONCE over an array namespace — with
every data-dependent constant precomputed in numpy and shared — keeps the
two paths arithmetically identical op for op (elementwise float64 only, no
reductions, no transcendentals), which is what makes the fused-vs-host
knob trajectories BIT-identical rather than merely close
(``tests/test_plant_jax.py``).

The model is a stylized training job with ``n`` memory-system streams
(input pipeline, checkpoint writer, compute streams): throughput rises
with staging-buffer share and bandwidth share; prefetching helps
bandwidth-rich streams and pollutes buffer-poor ones (so the A/B throttle
has a real decision to make); queue wait falls with bandwidth; and the
buffer utility curves are per-stream concave profiles whose height tracks
the prefetch setting (interaction #5: prefetch hits flatten the curve the
cache controller sees).
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import numpy as np

from repro.runtime.cbp_runtime import StreamKnobs


def _stream_rates(xp, c: Dict[str, np.ndarray], duration_ms, units,
                  bandwidth, prefetch, total_units: int,
                  total_bandwidth: float, pin=None):
    """The shared arithmetic: elementwise float64, both namespaces.

    ``units`` / ``prefetch`` arrive as float64 (the callers cast), so the
    op *sequence* is identical under numpy and XLA CPU.  ``pin`` marks
    every rounding point: the traced twin passes
    :func:`repro.runtime.plant_jax.pin_f64` so LLVM cannot contract the
    mul+add chains into FMAs (whose unrounded products would drift the
    trajectory 1 ulp off the numpy twin); the host twin leaves it as
    identity.
    """
    p = pin if pin is not None else (lambda x: x)
    # Multiply by the precomputed reciprocal instead of dividing: LLVM's
    # fast-math rewrites division-by-constant into reciprocal multiplies
    # anyway (for non-power-of-two totals that is a different rounding than
    # fdiv), so make BOTH twins do the same two-rounding arithmetic.
    u = p(units * (1.0 / total_units))
    b = p(bandwidth * (1.0 / total_bandwidth))
    pollute = p(c["pf_pollution"] / p(0.25 + u))
    thr = p(p(p(c["base"] * p(1.0 + p(c["cache_gain"] * u)))
              * p(1.0 + p(c["bw_gain"] * b)))
            * p(1.0 + p(prefetch * p(c["pf_gain"] - pollute))))
    wait = p(p(c["wait_base"] / p(b + 0.125))
             * p(1.0 + p(c["pf_wait"] * prefetch)))
    scale = p(1.0 + p(c["pf_flatten"] * prefetch))
    curves = p(p(c["curve_amp"] * scale)[:, None] * c["curve"])
    return thr, wait, curves


def make_stream_plant_model(
    n_clients: int,
    total_units: int,
    total_bandwidth: float,
    seed: int = 0,
) -> Tuple[Callable, Callable]:
    """Build the (host ``step_fn``, traced ``step_model``) twin pair.

    Both close over the same numpy constants; the traced twin only swaps
    the namespace.  Deterministic in ``seed`` — the TrainingPlant golden
    test pins trajectories from seed 0.
    """
    rng = np.random.default_rng(seed)
    units_axis = np.arange(total_units + 1, dtype=np.float64)
    knee = rng.uniform(0.08, 0.45, n_clients) * total_units
    c = {
        "base": rng.uniform(0.6, 1.4, n_clients),
        "cache_gain": rng.uniform(0.2, 1.0, n_clients),
        "bw_gain": rng.uniform(0.5, 2.0, n_clients),
        "pf_gain": rng.uniform(0.0, 0.35, n_clients),
        "pf_pollution": rng.uniform(0.0, 0.12, n_clients),
        "pf_wait": rng.uniform(-0.2, 0.3, n_clients),
        "pf_flatten": rng.uniform(-0.3, 0.1, n_clients),
        "wait_base": rng.uniform(20.0, 120.0, n_clients),
        "curve_amp": rng.uniform(50.0, 400.0, n_clients),
        # concave hits-vs-units profiles (saturating rational, precomputed
        # so curve *shape* costs zero per-step cross-backend arithmetic)
        "curve": units_axis[None, :] / (units_axis[None, :] + knee[:, None]),
    }
    c = {k: np.asarray(v, dtype=np.float64) for k, v in c.items()}

    def step_model(duration_ms, units, bandwidth, prefetch):
        import jax.numpy as jnp

        from repro.runtime.plant_jax import pin_f64

        # Runtime-opaque int64 zero (duration is a traced value, so XLA
        # cannot constant-fold the xor inside pin_f64 away).
        zero = (jnp.asarray(duration_ms) < 0).astype(jnp.int64)
        return _stream_rates(jnp, c, duration_ms, units, bandwidth,
                             prefetch, total_units, total_bandwidth,
                             pin=lambda x: pin_f64(x, zero))

    def step_fn(duration_ms: float, knobs: StreamKnobs):
        return _stream_rates(
            np, c, duration_ms,
            np.asarray(knobs.buffer_units, dtype=np.float64),
            np.asarray(knobs.bandwidth_mbps, dtype=np.float64),
            np.asarray(knobs.prefetch_on, dtype=np.float64),
            total_units, total_bandwidth)

    return step_fn, step_model
