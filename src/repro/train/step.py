"""Train-step builder: loss -> grads (optionally microbatched) -> optimizer.

The returned function is pure (params, opt_state, batch) ->
(params, opt_state, metrics) and is jit/pjit-compatible; the launcher
attaches shardings.  Gradient accumulation splits the global batch into
``microbatches`` scanned slices (the activation-memory lever alongside
remat and sequence-sharded activations — DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim.optimizers import make_optimizer


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    optimizer: str = "adamw"
    lr: float = 3e-4
    microbatches: int = 1
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def build_train_step(model: Model, tcfg: TrainStepConfig
                     ) -> Tuple[Callable, Callable]:
    """Returns (init_opt_state, train_step)."""
    kw: Dict[str, Any] = {}
    if tcfg.optimizer == "adamw":
        kw = dict(weight_decay=tcfg.weight_decay, grad_clip=tcfg.grad_clip)
    init_opt, update = make_optimizer(tcfg.optimizer, tcfg.lr, **kw)

    def grads_fn(params, batch):
        if tcfg.microbatches <= 1:
            return jax.value_and_grad(
                lambda p: model.loss(p, batch))(params)

        k = tcfg.microbatches

        def split(x):
            if x.ndim == 0:
                return x
            b = x.shape[0]
            assert b % k == 0, (b, k)
            return x.reshape((k, b // k) + x.shape[1:])

        mbatches = jax.tree.map(split, batch)
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(
                lambda p: model.loss(p, mb))(params)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (loss_acc + loss, g_acc), None

        (loss_sum, g_sum), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), mbatches)
        inv = 1.0 / k
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, g_sum)

    def train_step(params, opt_state, batch):
        loss, grads = grads_fn(params, batch)
        params, opt_state = update(params, grads, opt_state)
        metrics = {"loss": loss.astype(jnp.float32)}
        return params, opt_state, metrics

    return init_opt, train_step
