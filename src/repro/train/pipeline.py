"""Pipeline parallelism over the "pod" mesh axis (DESIGN.md §4).

GPipe-style microbatched pipeline built with ``shard_map`` + ``ppermute``:
stage s (= pod s) holds layers [s*L/S, (s+1)*L/S); activations flow
stage-to-stage over the (slow, DCN-like) pod axis while each stage's inner
layers run under the usual GSPMD TP/DP sharding.  This is the multi-pod
layout that trades the pod-axis DP gradient all-reduce for S-1 activation
hops per microbatch — the right trade when inter-pod bandwidth is the
scarce resource (the CBP bandwidth controller's signal decides which
layout a deployment uses).

The schedule is the classic jax ppermute pipeline: time t processes
microbatch (t - stage) at each stage; the loop runs n_micro + n_stages - 1
ticks.  jax AD differentiates through the ppermute loop, so the same
function serves training.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import shard_map


def pipeline_apply(
    stage_fn: Callable,      # (stage_params, x) -> x
    stage_params,            # pytree, leading dim = n_stages (sharded "pod")
    x: jnp.ndarray,          # (n_micro, mb, ...) microbatched input
    mesh,
    axis: str = "pod",
) -> jnp.ndarray:
    """Run the stage pipeline; returns outputs (n_micro, mb, ...)."""
    n_stages = mesh.shape[axis]

    def worker(params, xs):
        # params: (1, ...) this stage's slice; xs: (n_micro, mb, ...) —
        # every stage receives the full microbatch stream but only stage 0
        # consumes it (others take the ppermute input).
        params = jax.tree.map(lambda a: a[0], params)
        stage = jax.lax.axis_index(axis)
        n_micro = xs.shape[0]
        ticks = n_micro + n_stages - 1
        state = jnp.zeros_like(xs[0])          # in-flight activation
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            state, outs = carry
            mb_in = t                           # microbatch entering stage 0
            inp = jnp.where(
                stage == 0,
                xs[jnp.clip(mb_in, 0, n_micro - 1)],
                state)
            out = stage_fn(params, inp)
            # pass to the next stage (ring; last->0 result is ignored)
            nxt = jax.lax.ppermute(
                out, axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            # last stage writes its finished microbatch t - (S - 1)
            mb_done = t - (n_stages - 1)
            write = jnp.logical_and(stage == n_stages - 1, mb_done >= 0)
            outs = jnp.where(
                write,
                outs.at[jnp.clip(mb_done, 0, n_micro - 1)].set(out),
                outs)
            return (nxt, outs), None

        (state, outs), _ = jax.lax.scan(
            tick, (state, outs), jnp.arange(ticks))
        # broadcast final outputs from the last stage to everyone so the
        # loss is computed replicated across pods (masked psum = one-to-all
        # broadcast; ppermute requires a true permutation).
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, 0.0), axis)
        return outs

    in_specs = (
        jax.tree.map(lambda _: P(axis), stage_params),
        P(),          # microbatch stream replicated across stages
    )
    fn = shard_map(worker, mesh=mesh, in_specs=in_specs, out_specs=P())
    return fn(stage_params, x)
