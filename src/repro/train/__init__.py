from repro.train.plant_model import make_stream_plant_model
from repro.train.step import TrainStepConfig, build_train_step

__all__ = ["TrainStepConfig", "build_train_step",
           "make_stream_plant_model"]
