from repro.train.step import TrainStepConfig, build_train_step

__all__ = ["TrainStepConfig", "build_train_step"]
