"""Input pipeline with CBP-managed prefetch.

``PrefetchPipeline`` wraps any batch iterator with a background prefetch
queue whose DEPTH is the paper's prefetch knob in this substrate: depth 0
disables prefetching (synchronous fetch), larger depths hide host latency
at the cost of host memory ("cache") and host->device bandwidth.  The CBP
prefetch controller A/B samples step throughput with different depths and
throttles exactly like Algorithm 2; the queue's measured wait times feed
the bandwidth controller.

The pipeline is resumable: ``state()`` returns the batch counter, which is
persisted in checkpoints and restored on restart (fault tolerance).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, Iterator, Optional

import numpy as np


class SyntheticTokens:
    """Deterministic synthetic LM batches (seeded; resumable by index)."""

    def __init__(self, batch: int, seq: int, vocab: int, seed: int = 0,
                 start_index: int = 0):
        self.batch = batch
        self.seq = seq
        self.vocab = vocab
        self.seed = seed
        self.index = start_index

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, self.index))
        toks = rng.integers(
            0, self.vocab, size=(self.batch, self.seq), dtype=np.int32)
        self.index += 1
        return {"tokens": toks, "labels": toks}

    def state(self) -> Dict:
        return {"index": self.index, "seed": self.seed}

    def restore(self, state: Dict) -> None:
        self.index = int(state["index"])
        self.seed = int(state["seed"])


class PrefetchPipeline:
    """Background prefetcher with a dynamic depth knob.

    Metrics exposed for the CBP controllers:
      * ``mean_wait_ms``   — time the consumer blocked on the queue
        (the "queuing delay" signal for the bandwidth controller),
      * ``throughput``     — batches/sec delivered (the IPC analogue for
        the prefetch controller's A/B sampling).
    """

    def __init__(self, source, depth: int = 2,
                 fetch_cost_s: float = 0.0):
        self.source = source
        self._fetch_cost = fetch_cost_s
        self._depth = max(int(depth), 0)
        self._queue: "queue.Queue" = queue.Queue(maxsize=max(self._depth, 1))
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._waits = []
        self._deliveries = 0
        self._t_start = time.monotonic()
        if self._depth > 0:
            self._start()

    # ------------------------------------------------------------- #

    def _start(self):
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while not self._stop.is_set():
            batch = next(self.source)
            if self._fetch_cost:
                time.sleep(self._fetch_cost)
            while not self._stop.is_set():
                try:
                    self._queue.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def set_depth(self, depth: int) -> None:
        """Prefetch throttle: 0 = off.  Restarts the worker if needed."""
        depth = max(int(depth), 0)
        if depth == self._depth:
            return
        self.stop()
        self._stop = threading.Event()
        self._depth = depth
        self._queue = queue.Queue(maxsize=max(depth, 1))
        if depth > 0:
            self._start()

    @property
    def depth(self) -> int:
        return self._depth

    def __next__(self) -> Dict[str, np.ndarray]:
        t0 = time.monotonic()
        if self._depth == 0:
            batch = next(self.source)
            if self._fetch_cost:
                time.sleep(self._fetch_cost)
        else:
            batch = self._queue.get()
        self._waits.append(time.monotonic() - t0)
        self._deliveries += 1
        return batch

    def __iter__(self):
        return self

    # ---------------- CBP metric surface ---------------- #

    def mean_wait_ms(self, reset: bool = True) -> float:
        if not self._waits:
            return 0.0
        w = 1000.0 * float(np.mean(self._waits))
        if reset:
            self._waits = []
        return w

    def throughput(self, reset: bool = True) -> float:
        dt = time.monotonic() - self._t_start
        tp = self._deliveries / max(dt, 1e-9)
        if reset:
            self._deliveries = 0
            self._t_start = time.monotonic()
        return tp

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            # drain so the worker unblocks
            try:
                while True:
                    self._queue.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=2.0)
            self._thread = None
