"""Optimizers (from scratch, pytree-native): AdamW and Adafactor.

Policy: parameters are stored/computed in their model dtype (bf16 for
production configs) with an f32 master copy inside the optimizer state;
AdamW keeps f32 first/second moments (3x f32 per param), Adafactor keeps a
factored second moment (rows+cols) for matrices — the right choice for the
300B-class configs where full AdamW state would not fit a v5e pod
(DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    master: Any          # f32 params
    m: Any               # adamw: f32 momentum | adafactor: f32 momentum/None
    v: Any               # adamw: f32 second moment | adafactor: (vr, vc, vfull)


# ----------------------------- AdamW ------------------------------- #


def adamw_init(params) -> OptState:
    # copy=True: with f32 params, astype would alias the param buffer and
    # break double-donation of (params, opt_state) in the train step.
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        master=jax.tree.map(f32, params),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def adamw_update(params, grads, state: OptState, *, lr: float,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_clip: float = 1.0) -> Tuple[Any, OptState]:
    step = state.step + 1
    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(master, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / (1 - b1 ** step)
        vh = v / (1 - b2 ** step)
        master = master - lr * (mh / (jnp.sqrt(vh) + eps)
                                + weight_decay * master)
        return master, m, v

    flat_p, tdef = jax.tree.flatten(state.master)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    master = tdef.unflatten([o[0] for o in out])
    m = tdef.unflatten([o[1] for o in out])
    v = tdef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(
        lambda mp, p: mp.astype(p.dtype), master, params)
    return new_params, OptState(step, master, m, v)


# --------------------------- Adafactor ----------------------------- #


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params) -> OptState:
    def second_moment(p):
        if _factored(p.shape):
            vr = jnp.zeros(p.shape[:-1], jnp.float32)           # row
            vc = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return (vr, vc)
        return (jnp.zeros(p.shape, jnp.float32),)

    return OptState(
        step=jnp.zeros((), jnp.int32),
        master=jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params),
        m=None,
        v=jax.tree.map(second_moment, params,
                       is_leaf=lambda x: isinstance(x, jnp.ndarray)),
    )


def adafactor_update(params, grads, state: OptState, *, lr: float,
                     decay: float = 0.8, eps: float = 1e-30,
                     clip_threshold: float = 1.0,
                     weight_decay: float = 0.0) -> Tuple[Any, OptState]:
    step = state.step + 1
    beta2 = 1.0 - jnp.power(step.astype(jnp.float32), -decay)

    def upd(master, g, v):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + eps
        if len(v) == 2:
            vr, vc = v
            vr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            rfac = jax.lax.rsqrt(
                vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                + eps)
            cfac = jax.lax.rsqrt(vc + eps)
            u = g * rfac[..., None] * cfac[..., None, :]
            newv = (vr, vc)
        else:
            (vf,) = v
            vf = beta2 * vf + (1 - beta2) * g2
            u = g * jax.lax.rsqrt(vf + eps)
            newv = (vf,)
        # update clipping by RMS
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        master = master - lr * (u + weight_decay * master)
        return master, newv

    is_v = lambda x: isinstance(x, tuple) and all(
        isinstance(e, jnp.ndarray) for e in x)
    flat_p, tdef = jax.tree.flatten(state.master)
    flat_g = jax.tree.leaves(grads)
    flat_v, _ = jax.tree.flatten(state.v, is_leaf=is_v)
    out = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
    master = tdef.unflatten([o[0] for o in out])
    v = tdef.unflatten([o[1] for o in out])
    new_params = jax.tree.map(
        lambda mp, p: mp.astype(p.dtype), master, params)
    return new_params, OptState(step, master, None, v)


# ----------------------------- factory ----------------------------- #


def make_optimizer(kind: str, lr: float = 3e-4, **kw):
    """Returns (init_fn, update_fn(params, grads, state) -> (params, state))."""
    if kind == "adamw":
        return adamw_init, lambda p, g, s: adamw_update(p, g, s, lr=lr, **kw)
    if kind == "adafactor":
        return adafactor_init, lambda p, g, s: adafactor_update(
            p, g, s, lr=lr, **kw)
    if kind == "sgd":
        init = lambda params: OptState(
            jnp.zeros((), jnp.int32), None, None, None)
        upd = lambda p, g, s: (
            jax.tree.map(lambda pp, gg: pp - lr * gg.astype(pp.dtype), p, g),
            OptState(s.step + 1, None, None, None))
        return init, upd
    raise ValueError(kind)
