from repro.optim.optimizers import (
    OptState,
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    make_optimizer,
)
from repro.optim.grad_compress import compress_grads, decompress_grads

__all__ = [
    "OptState", "adamw_init", "adamw_update", "adafactor_init",
    "adafactor_update", "make_optimizer", "compress_grads",
    "decompress_grads",
]
