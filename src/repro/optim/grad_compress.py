"""Int8 gradient compression with error feedback (distributed-optimization
trick for DP sync over slow links, e.g. the multi-pod DCN axis).

Gradients are quantized per-tensor to int8 with an f32 scale before the
data-parallel reduction; the quantization error is carried in an error-
feedback accumulator so the compression is unbiased over time (1-bit
Adam-style).  4x fewer bytes on the wire for the gradient all-reduce.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_grads(grads, error_feedback=None) -> Tuple[Any, Any, Any]:
    """Returns (q_grads int8, scales f32, new_error_feedback)."""
    if error_feedback is None:
        error_feedback = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        err = g - q.astype(jnp.float32) * scale
        return q, scale, err

    flat, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_feedback)
    out = [one(g, e) for g, e in zip(flat, flat_e)]
    q = tdef.unflatten([o[0] for o in out])
    scales = tdef.unflatten([o[1] for o in out])
    err = tdef.unflatten([o[2] for o in out])
    return q, scales, err


def decompress_grads(q_grads, scales):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, q_grads, scales)
